//! Pruning-experiment report: renders Figs. 8-10 and Tables I/III from
//! the JSON traces written by `python -m compile.experiments all`,
//! checking the paper's relational claims as it goes.
//!
//! ```bash
//! cargo run --release --example pruning_report
//! ```

use anyhow::{Context, Result};

use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::util::json::Json;

fn bar(frac: f64, width: usize) -> String {
    let n = (frac.clamp(0.0, 1.0) * width as f64).round() as usize;
    format!("{}{}", "#".repeat(n), ".".repeat(width - n))
}

fn main() -> Result<()> {
    let dir = Manifest::default_dir().join("experiments");

    // ---- Fig. 8: hybrid vs unstructured at matched compression ----
    let fig8 = Json::from_file(&dir.join("fig8.json"))
        .context("fig8.json (run `python -m compile.experiments fig8`)")?;
    let dense_acc = fig8.get("dense_acc")?.as_f64()?;
    println!("Fig. 8 -- hybrid vs unstructured pruning (dense acc {:.2}%)",
             dense_acc * 100.0);
    println!("reduction  hybrid     unstructured  hybrid+quant");
    let mut hybrid_wins = 0;
    let mut rows = 0;
    for p in fig8.get("points")?.as_arr()? {
        let red = p.get("param_reduction")?.as_f64()?;
        let h = p.get("hybrid_acc")?.as_f64()?;
        let u = p.get("unstructured_acc")?.as_f64()?;
        let q = p.get("hybrid_quant_acc")?.as_f64()?;
        println!(
            "{:>8.1}%  {:>6.2}%    {:>6.2}%       {:>6.2}%   {}",
            red * 100.0,
            h * 100.0,
            u * 100.0,
            q * 100.0,
            p.get("schedule")?.as_str()?,
        );
        hybrid_wins += usize::from(h >= u - 0.01);
        rows += 1;
    }
    println!(
        "hybrid >= unstructured (within 1pt) in {hybrid_wins}/{rows} \
         settings (paper: 'better in most cases')\n"
    );

    // ---- Fig. 9: channel dropping ----
    let fig9 = Json::from_file(&dir.join("fig9.json"))?;
    println!("Fig. 9 -- channel-drop exploration");
    println!("schedule  acc      graph_skip  param_red");
    for r in fig9.get("rows")?.as_arr()? {
        println!(
            "{:<8}  {:>6.2}%  {:>8.1}%  {:>8.1}%",
            r.get("schedule")?.as_str()?,
            r.get("test_acc")?.as_f64()? * 100.0,
            r.get("graph_skip_ratio")?.as_f64()? * 100.0,
            r.get("param_reduction")?.as_f64()? * 100.0,
        );
    }
    println!();

    // ---- Fig. 10: cavity schemes ----
    let fig10 = Json::from_file(&dir.join("fig10.json"))?;
    println!("Fig. 10 -- fine-grained cavity schemes (on drop-1)");
    println!("scheme     prune   spread  acc");
    let mut acc_of = std::collections::BTreeMap::new();
    for r in fig10.get("rows")?.as_arr()? {
        let name = r.get("scheme")?.as_str()?.to_string();
        let acc = r.get("test_acc")?.as_f64()?;
        println!(
            "{:<9}  {:>5.1}%  {:>6}  {:>6.2}%  {}",
            name,
            r.get("prune_ratio")?.as_f64()? * 100.0,
            r.get("balance_spread")?.as_usize()?,
            acc * 100.0,
            bar(acc, 30),
        );
        acc_of.insert(name, acc);
    }
    if let (Some(b), Some(u)) = (acc_of.get("cav-70-1"), acc_of.get("cav-70-2")) {
        println!(
            "balanced cav-70-1 vs unbalanced cav-70-2: {:+.2} pts \
             (paper: balanced wins)",
            (b - u) * 100.0
        );
    }
    println!();

    // ---- Table I accuracy ----
    if let Ok(t1) = Json::from_file(&dir.join("table1_acc.json")) {
        println!(
            "Table I (accuracy): w/C {:.2}%  w/o C {:.2}%  (paper: 93.70 vs 93.40)",
            t1.get("acc_with_ck")?.as_f64()? * 100.0,
            t1.get("acc_without_ck")?.as_f64()? * 100.0
        );
    }

    // ---- Table III sparsity ----
    if let Ok(t3) = Json::from_file(&dir.join("table3_sparsity.json")) {
        println!("\nTable III -- feature sparsity distribution (buckets I-IV)");
        for (name, s) in t3.get("layers")?.as_obj()? {
            let b = s.get("buckets_I_II_III_IV")?.f64_vec()?;
            println!(
                "{:<10} mean {:>5.1}%   I {:>5.1}%  II {:>5.1}%  III {:>5.1}%  IV {:>5.1}%",
                name,
                s.get("mean_sparsity")?.as_f64()? * 100.0,
                b[0] * 100.0,
                b[1] * 100.0,
                b[2] * 100.0,
                b[3] * 100.0,
            );
        }
    }
    Ok(())
}
