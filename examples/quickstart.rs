//! Quickstart: load the AOT-compiled pruned 2s-AGCN and classify one
//! batch of synthetic skeleton clips -- the 30-second tour of the API.
//!
//! ```bash
//! make artifacts            # once: python AOT export
//! cargo run --release --example quickstart
//! ```

use rfc_hypgcn::data::{GenConfig, SkeletonGen};
use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // 1. the manifest describes every artifact the Python side exported
    let manifest = Manifest::load(&Manifest::default_dir())?;
    println!(
        "model: {} blocks, {:.2}x compressed, {:.1}% graph work skipped",
        manifest.blocks.len(),
        manifest.compression_ratio,
        manifest.graph_skip_ratio * 100.0
    );

    // 2. one PJRT CPU engine per process; executables are cached
    let engine = Engine::cpu()?;
    let model = engine.load_hlo(
        &manifest.hlo_path(&manifest.model_pruned.hlo),
    )?;

    // 3. make a batch of synthetic skeleton clips (N, 3, T, 25)
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: manifest.num_classes,
            seq_len: manifest.seq_len,
            noise: 0.02,
        },
        42,
    );
    let (batch, labels) = gen.batch(manifest.batch);

    // 4. run and read logits
    let logits = model.run1(&[batch])?;
    println!("logits: {:?}", logits.shape);
    let classes = manifest.num_classes;
    let mut correct = 0;
    for (i, &label) in labels.iter().enumerate() {
        let row = &logits.data[i * classes..(i + 1) * classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(c, _)| c)
            .unwrap();
        println!(
            "  clip {i}: predicted class {pred:2}  (generated as {label:2})"
        );
        correct += usize::from(pred == label);
    }
    println!(
        "{correct}/{} match the generator's labels",
        labels.len()
    );
    Ok(())
}
