//! Serving demo: the full coordinator (dynamic batcher -> 11-stage layer
//! pipeline -> delivery) under an open-loop request stream, reporting
//! throughput, latency percentiles and batching efficiency.
//!
//! ```bash
//! cargo run --release --example serve_pipeline -- [requests] [rate_fps]
//! ```

use std::time::{Duration, Instant};

use rfc_hypgcn::coordinator::{BatchPolicy, Server};
use rfc_hypgcn::data::{GenConfig, SkeletonGen};
use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(96);
    let rate_fps: f64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0.0);

    let manifest = Manifest::load(&Manifest::default_dir())?;
    let engine = Engine::cpu()?;
    println!(
        "compiling 11 pipeline stages (batch {}, T {})...",
        manifest.batch, manifest.seq_len
    );
    let t0 = Instant::now();
    let server = Server::start(
        &engine,
        &manifest,
        BatchPolicy {
            batch_size: manifest.batch,
            max_wait: Duration::from_millis(25),
            seq_len: manifest.seq_len,
        },
    )?;
    println!("up in {:.2}s", t0.elapsed().as_secs_f64());

    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: manifest.num_classes,
            seq_len: manifest.seq_len,
            noise: 0.02,
        },
        7,
    );
    // open-loop arrivals at `rate_fps` (0 = as fast as possible)
    let gap = if rate_fps > 0.0 {
        Duration::from_secs_f64(1.0 / rate_fps)
    } else {
        Duration::ZERO
    };
    let mut rxs = Vec::with_capacity(requests);
    let t_sub = Instant::now();
    for i in 0..requests {
        rxs.push(server.submit(gen.sample().0));
        if !gap.is_zero() {
            let target = t_sub + gap * (i as u32 + 1);
            if let Some(d) = target.checked_duration_since(Instant::now()) {
                std::thread::sleep(d);
            }
        }
    }
    let mut class_histogram = vec![0usize; manifest.num_classes];
    for rx in rxs {
        let resp = rx.recv()?;
        // error responses carry no logits; don't let them skew the
        // histogram toward class 0
        if resp.is_ok() {
            class_histogram[resp.predicted] += 1;
        }
    }
    let wall = t_sub.elapsed().as_secs_f64();
    println!(
        "\n{} responses in {:.2}s = {:.2} fps sustained",
        requests,
        wall,
        requests as f64 / wall
    );
    println!("{}", server.metrics.report());
    println!("prediction histogram: {class_histogram:?}");
    server.shutdown();
    Ok(())
}
