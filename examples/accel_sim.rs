//! Accelerator simulation walkthrough: maps the paper-scale pruned
//! 2s-AGCN onto the XCKU-115 model and prints Tables II & IV plus
//! Fig. 11, then a per-stage pipeline breakdown.
//!
//! ```bash
//! cargo run --release --example accel_sim [-- --table2 --table4 --fig11]
//! ```

use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::model::ModelConfig;
use rfc_hypgcn::sim::pipeline::{map_chip, workloads};
use rfc_hypgcn::sim::reports;
use rfc_hypgcn::sim::resource::XCKU115;
use rfc_hypgcn::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let all = args.is_empty();
    let has = |k: &str| all || args.iter().any(|a| a == k);
    let manifest = Manifest::load(&Manifest::default_dir()).ok();
    if manifest.is_none() {
        eprintln!("(artifacts not built -- paper-default sparsity used)\n");
    }

    if has("--table2") {
        println!("{}", reports::table2(manifest.as_ref()));
    }
    if has("--fig11") {
        println!("{}", reports::fig11(manifest.as_ref()));
    }
    if has("--table4") {
        println!("{}", reports::table4(manifest.as_ref()));
    }

    // per-stage breakdown of the mapped chip
    let cfg = ModelConfig::paper_full();
    let specs = cfg.block_specs();
    let kept_in: Vec<usize> = specs
        .iter()
        .enumerate()
        .map(|(l, s)| if l == 0 { 3 } else { s.in_channels / 2 })
        .collect();
    let kept_f: Vec<usize> = (0..specs.len())
        .map(|l| {
            if l + 1 < specs.len() {
                kept_in[l + 1]
            } else {
                specs[l].out_channels
            }
        })
        .collect();
    let sparsities = reports::block_sparsities(manifest.as_ref(), 10);
    let works = workloads(&cfg, &kept_in, &kept_f, &sparsities);
    let mut rng = Rng::new(3);
    let plan = map_chip(
        &works,
        &manifest
            .as_ref()
            .map(|m| m.cavity.clone())
            .unwrap_or_else(reports::default_cavity),
        &XCKU115,
        3500,
        &mut rng,
    );
    println!("pipeline stages (paper-scale mapping):");
    println!("block  scm_pes  tcm_pes  dsp   scm_cyc   tcm_cyc   II");
    for s in &plan.stages {
        println!(
            "{:5}  {:7}  {:7}  {:4}  {:8}  {:8}  {:8}",
            s.block, s.scm_pes, s.tcm_pes, s.dsp, s.scm_cycles,
            s.tcm_cycles, s.ii()
        );
    }
    println!(
        "\nII = {} cycles @ {:.0} MHz -> {:.2} fps; {:.1} GOP/s executed, \
         {:.1} GOP/s dense-equivalent; {} DSP ({:.3} GOP/s/DSP)",
        plan.ii_cycles(),
        plan.clock_hz / 1e6,
        plan.fps(),
        plan.gops(),
        plan.effective_gops(),
        plan.usage.dsp,
        plan.dsp_efficiency(),
    );
}
