//! Q8.8 quantized-path demo: run the AOT `quant_demo` kernel (int16 in,
//! int16 out) through PJRT and cross-check it bit-for-bit against the
//! host reference -- the integer datapath the paper's DSPs execute.
//!
//! ```bash
//! cargo run --release --example quant_inference
//! ```

use anyhow::Result;

use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::quant;
use rfc_hypgcn::runtime::Engine;
use rfc_hypgcn::util::rng::Rng;

fn main() -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let engine = Engine::cpu()?;
    let exe = engine.load_hlo(
        &manifest.hlo_path(&manifest.quant_demo.hlo),
    )?;
    let (m, k) = (64usize, 32usize);
    let n = 32usize;

    // float operands -> Q8.8
    let mut rng = Rng::new(2024);
    let x: Vec<f32> = (0..m * k).map(|_| rng.f32() * 4.0 - 2.0).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let xq = quant::quantize_slice(&x);
    let wq = quant::quantize_slice(&w);

    // device path
    let mut xl =
        xla::Literal::create_from_shape(xla::PrimitiveType::S16, &[m, k]);
    xl.copy_raw_from(&xq)?;
    let mut wl =
        xla::Literal::create_from_shape(xla::PrimitiveType::S16, &[k, n]);
    wl.copy_raw_from(&wq)?;
    let out = exe.run_literals(&[xl, wl])?;
    let device: Vec<i16> = out[0].to_vec()?;

    // host reference
    let host = quant::quant_matmul_ref(&xq, &wq, m, k, n);
    assert_eq!(device, host, "device and host Q8.8 semantics must agree");

    // accuracy vs float
    let mut max_err = 0f32;
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for l in 0..k {
                acc += x[i * k + l] * w[l * n + j];
            }
            let got = quant::dequantize(device[i * n + j]);
            max_err = max_err.max((acc - got).abs());
        }
    }
    println!("device == host reference: OK ({} values)", device.len());
    println!(
        "max |float - Q8.8| over {}x{} @ K={}: {:.4} \
         (theoretical per-op bound {:.4} x K)",
        m,
        n,
        k,
        max_err,
        quant::MAX_QUANT_ERROR
    );
    println!(
        "sample: float {:.4} -> Q8.8 {:.4}",
        x[0] * w[0],
        quant::dequantize(host[0])
    );
    Ok(())
}
