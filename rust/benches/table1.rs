//! Table I -- cost of the self-similarity graph C_k: accuracy (from the
//! Python experiment trace) and measured throughput / power efficiency of
//! the with-C vs without-C model variants on this testbed.

mod common;

use rfc_hypgcn::util::json::Json;

fn main() {
    let m = common::manifest_or_exit();
    let engine = common::engine();

    // accuracy side: written by `python -m compile.experiments table1`
    let acc = Json::from_file(
        &m.dir.join("experiments").join("table1_acc.json"),
    )
    .ok();
    let (acc_ck, acc_plain) = match &acc {
        Some(v) => (
            v.get("acc_with_ck").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
            v.get("acc_without_ck")
                .and_then(|x| x.as_f64())
                .unwrap_or(f64::NAN),
        ),
        None => (f64::NAN, f64::NAN),
    };

    let x = common::batch_for(&m, m.seq_len, 42);
    let ck = engine.load_hlo(&m.hlo_path(&m.model_ck.hlo)).unwrap();
    let dense = engine.load_hlo(&m.hlo_path(&m.model_dense.hlo)).unwrap();
    let s_ck = common::time_exe(&ck, &x, 2, 10);
    let s_plain = common::time_exe(&dense, &x, 2, 10);
    let fps_ck = common::fps(m.batch, &s_ck);
    let fps_plain = common::fps(m.batch, &s_plain);
    // testbed "power efficiency": fps per assumed 65 W CPU package
    const CPU_W: f64 = 65.0;

    println!("Table I -- computing cost of self-similarity graph C_k");
    println!("variant          accuracy   throughput      fps/W");
    println!(
        "2sAGCN(w/C)      {:>7.2}%   {:>8.2} fps   {:>7.4}",
        acc_ck * 100.0,
        fps_ck,
        fps_ck / CPU_W
    );
    println!(
        "2sAGCN(w/o C)    {:>7.2}%   {:>8.2} fps   {:>7.4}",
        acc_plain * 100.0,
        fps_plain,
        fps_plain / CPU_W
    );
    println!(
        "\nw/o-C speedup: {:.2}x (paper: 98.87/69.38 = 1.43x); \
         accuracy cost: {:+.2} pts (paper: -0.30)",
        fps_plain / fps_ck,
        (acc_plain - acc_ck) * 100.0
    );
    println!("timing w/C  : {s_ck}");
    println!("timing w/o C: {s_plain}");
}
