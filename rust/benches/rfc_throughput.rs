//! Bench: runtime RFC codec throughput and compression ratio vs dense
//! transport (runs without AOT artifacts).
//!
//! For a mid-pipeline activation shape, sweeps post-ReLU sparsity and
//! reports (a) the wire-size ratio of compressed vs dense transport,
//! (b) encode throughput serial and sharded, (c) decode throughput, and
//! (d) the dense memcpy baseline the pipeline would otherwise pay per
//! stage boundary.

use std::time::Instant;

use rfc_hypgcn::rfc::{self, EncoderConfig};
use rfc_hypgcn::runtime::Tensor;
use rfc_hypgcn::util::stats::Summary;

fn sparse_tensor(shape: Vec<usize>, sparsity: f64, seed: u64) -> Tensor {
    Tensor::random_sparse(shape, sparsity, seed)
}

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> Summary {
    // warmup
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

fn mbps(bytes: usize, s: &Summary) -> f64 {
    bytes as f64 / s.mean_s / 1e6
}

fn main() {
    // (N, T, V, C): one batch of mid-pipeline activations
    let shape = vec![8usize, 64, 25, 64];
    let bytes: usize = shape.iter().product::<usize>() * 4;
    let serial = EncoderConfig {
        shards: 1,
        min_sparsity: 0.0,
        parallel_threshold: usize::MAX,
    };
    let sharded = EncoderConfig {
        min_sparsity: 0.0,
        parallel_threshold: 0,
        ..EncoderConfig::default()
    };
    let iters = 12;

    println!(
        "RFC runtime codec vs dense transport -- shape {:?} ({:.1} MB), {} shards",
        shape,
        bytes as f64 / 1e6,
        sharded.shards
    );
    println!(
        "{:>8}  {:>7}  {:>6}  {:>12}  {:>12}  {:>12}  {:>12}",
        "sparsity", "ratio", "save%", "enc(1) MB/s", "enc(N) MB/s", "dec MB/s", "memcpy MB/s"
    );
    for s10 in [0u64, 25, 50, 75, 90] {
        let sparsity = s10 as f64 / 100.0;
        let t = sparse_tensor(shape.clone(), sparsity, 42 + s10);

        let ct = rfc::encode(&t, &sharded);
        let ratio = ct.compression_ratio();
        let save = 1.0 - ct.compressed_bits() as f64 / ct.dense_bits() as f64;

        let enc1 = time_it(iters, || {
            std::hint::black_box(rfc::encode(&t, &serial));
        });
        let encn = time_it(iters, || {
            std::hint::black_box(rfc::encode(&t, &sharded));
        });
        let dec = time_it(iters, || {
            std::hint::black_box(rfc::decode(&ct, &sharded));
        });
        let copy = time_it(iters, || {
            std::hint::black_box(t.data.clone());
        });

        println!(
            "{:>7.0}%  {:>6.2}x  {:>5.1}%  {:>12.1}  {:>12.1}  {:>12.1}  {:>12.1}",
            sparsity * 100.0,
            ratio,
            save * 100.0,
            mbps(bytes, &enc1),
            mbps(bytes, &encn),
            mbps(bytes, &dec),
            mbps(bytes, &copy),
        );
    }

    // wire codec v1: serialize/deserialize cost of shipping the same
    // activations across a process boundary (shard links)
    println!("\nwire codec v1 (same shape):");
    println!(
        "{:>8}  {:>10}  {:>12}  {:>12}",
        "sparsity", "frame MB", "ser MB/s", "deser MB/s"
    );
    for s10 in [25u64, 50, 75, 90] {
        let sparsity = s10 as f64 / 100.0;
        let t = sparse_tensor(shape.clone(), sparsity, 142 + s10);
        let ct = rfc::encode(&t, &serial);
        let frame = rfc_hypgcn::rfc::wire::to_bytes(&ct).unwrap();
        let ser = time_it(iters, || {
            std::hint::black_box(
                rfc_hypgcn::rfc::wire::to_bytes(&ct).unwrap(),
            );
        });
        let deser = time_it(iters, || {
            std::hint::black_box(
                rfc_hypgcn::rfc::wire::from_bytes(&frame).unwrap(),
            );
        });
        println!(
            "{:>7.0}%  {:>10.2}  {:>12.1}  {:>12.1}",
            sparsity * 100.0,
            frame.len() as f64 / 1e6,
            mbps(bytes, &ser),
            mbps(bytes, &deser),
        );
    }

    // batcher view: padded batches are where compression always wins
    println!("\npadded-batch transport (batch 8, 1..8 real rows):");
    let row = sparse_tensor(vec![1, 3, 64, 25], 0.0, 7);
    for real in [1usize, 4, 8] {
        let mut parts: Vec<rfc_hypgcn::rfc::CompressedTensor> =
            (0..real).map(|_| rfc::encode(&row, &serial)).collect();
        if real < 8 {
            parts.push(rfc_hypgcn::rfc::CompressedTensor::zeros(vec![
                8 - real,
                3,
                64,
                25,
            ]));
        }
        let batch =
            rfc_hypgcn::rfc::CompressedTensor::concat_batch(parts).unwrap();
        println!(
            "  real {real}/8: ratio {:>5.2}x  ({} -> {} bits)",
            batch.compression_ratio(),
            batch.dense_bits(),
            batch.compressed_bits()
        );
    }
}
