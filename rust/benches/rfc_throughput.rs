//! Bench: runtime RFC codec, wire, batching and compressed-domain
//! kernel throughput vs their dense baselines (runs without AOT
//! artifacts).
//!
//! Sections (run all, or one via
//! `-- --section <codec|wire|batch|kernel|node|admission>`):
//!
//! * `codec`  -- encode/decode throughput and wire-size ratio vs dense
//!   transport plus the memcpy baseline;
//! * `wire`   -- wire format v1 serialize/deserialize cost;
//! * `batch`  -- padded-batch transport ratios;
//! * `kernel` -- dense GEMM vs decode+dense GEMM vs compressed-domain
//!   (input-skipping) GEMM across sparsities.  Also emits the
//!   machine-readable `BENCH_rfc.json` at the repo root so the perf
//!   trajectory is recorded run over run (CI uploads it as an artifact);
//! * `node`   -- shard-cluster batch round-trip over the loopback link
//!   vs localhost TCP node agents (the socket transport's framing +
//!   syscall overhead on top of identical wire bytes), plus batch
//!   latency under a 1-of-3 node kill with shard retry on vs off (the
//!   price of masking a fault vs failing the batch), merged into
//!   `BENCH_rfc.json` as the top-level `node` object;
//! * `admission` -- the bounded front door under a sustained-rate sweep
//!   crossing the pipeline's serveable rate: shed/expired fractions and
//!   per-submit cost at each offered rate, merged into `BENCH_rfc.json`
//!   as the top-level `admission` object (context for the trajectory;
//!   the ratchet only reads the kernel `results` rows).

use std::time::Instant;

use rfc_hypgcn::rfc::kernel::{
    cpu_features, gemm_dense_f32, spmm_f32, GemmF32, KernelConfig, LaneDispatch,
};
use rfc_hypgcn::rfc::{self, EncoderConfig};
use rfc_hypgcn::runtime::Tensor;
use rfc_hypgcn::util::json::{obj, Json};
use rfc_hypgcn::util::stats::Summary;

fn sparse_tensor(shape: Vec<usize>, sparsity: f64, seed: u64) -> Tensor {
    Tensor::random_sparse(shape, sparsity, seed)
}

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> Summary {
    // warmup
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

fn mbps(bytes: usize, s: &Summary) -> f64 {
    bytes as f64 / s.mean_s / 1e6
}

fn serial_cfg() -> EncoderConfig {
    EncoderConfig {
        shards: 1,
        min_sparsity: 0.0,
        parallel_threshold: usize::MAX,
    }
}

fn sharded_cfg() -> EncoderConfig {
    EncoderConfig {
        min_sparsity: 0.0,
        parallel_threshold: 0,
        ..EncoderConfig::default()
    }
}

fn codec_section() {
    // (N, T, V, C): one batch of mid-pipeline activations
    let shape = vec![8usize, 64, 25, 64];
    let bytes: usize = shape.iter().product::<usize>() * 4;
    let serial = serial_cfg();
    let sharded = sharded_cfg();
    let iters = 12;

    println!(
        "RFC runtime codec vs dense transport -- shape {:?} ({:.1} MB), {} shards",
        shape,
        bytes as f64 / 1e6,
        sharded.shards
    );
    println!(
        "{:>8}  {:>7}  {:>6}  {:>12}  {:>12}  {:>12}  {:>12}",
        "sparsity", "ratio", "save%", "enc(1) MB/s", "enc(N) MB/s", "dec MB/s", "memcpy MB/s"
    );
    for s10 in [0u64, 25, 50, 75, 90] {
        let sparsity = s10 as f64 / 100.0;
        let t = sparse_tensor(shape.clone(), sparsity, 42 + s10);

        let ct = rfc::encode(&t, &sharded);
        let ratio = ct.compression_ratio();
        let save = 1.0 - ct.compressed_bits() as f64 / ct.dense_bits() as f64;

        let enc1 = time_it(iters, || {
            std::hint::black_box(rfc::encode(&t, &serial));
        });
        let encn = time_it(iters, || {
            std::hint::black_box(rfc::encode(&t, &sharded));
        });
        let dec = time_it(iters, || {
            std::hint::black_box(rfc::decode(&ct, &sharded));
        });
        let copy = time_it(iters, || {
            std::hint::black_box(t.data.clone());
        });

        println!(
            "{:>7.0}%  {:>6.2}x  {:>5.1}%  {:>12.1}  {:>12.1}  {:>12.1}  {:>12.1}",
            sparsity * 100.0,
            ratio,
            save * 100.0,
            mbps(bytes, &enc1),
            mbps(bytes, &encn),
            mbps(bytes, &dec),
            mbps(bytes, &copy),
        );
    }
}

fn wire_section() {
    let shape = vec![8usize, 64, 25, 64];
    let bytes: usize = shape.iter().product::<usize>() * 4;
    let serial = serial_cfg();
    let iters = 12;

    // wire codec v1: serialize/deserialize cost of shipping the same
    // activations across a process boundary (shard links)
    println!("\nwire codec v1 (shape {shape:?}):");
    println!(
        "{:>8}  {:>10}  {:>12}  {:>12}",
        "sparsity", "frame MB", "ser MB/s", "deser MB/s"
    );
    for s10 in [25u64, 50, 75, 90] {
        let sparsity = s10 as f64 / 100.0;
        let t = sparse_tensor(shape.clone(), sparsity, 142 + s10);
        let ct = rfc::encode(&t, &serial);
        let frame = rfc_hypgcn::rfc::wire::to_bytes(&ct).unwrap();
        let ser = time_it(iters, || {
            std::hint::black_box(
                rfc_hypgcn::rfc::wire::to_bytes(&ct).unwrap(),
            );
        });
        let deser = time_it(iters, || {
            std::hint::black_box(
                rfc_hypgcn::rfc::wire::from_bytes(&frame).unwrap(),
            );
        });
        println!(
            "{:>7.0}%  {:>10.2}  {:>12.1}  {:>12.1}",
            sparsity * 100.0,
            frame.len() as f64 / 1e6,
            mbps(bytes, &ser),
            mbps(bytes, &deser),
        );
    }
}

fn batch_section() {
    let serial = serial_cfg();
    // batcher view: padded batches are where compression always wins
    println!("\npadded-batch transport (batch 8, 1..8 real rows):");
    let row = sparse_tensor(vec![1, 3, 64, 25], 0.0, 7);
    for real in [1usize, 4, 8] {
        let mut parts: Vec<rfc_hypgcn::rfc::CompressedTensor> =
            (0..real).map(|_| rfc::encode(&row, &serial)).collect();
        if real < 8 {
            parts.push(rfc_hypgcn::rfc::CompressedTensor::zeros(vec![
                8 - real,
                3,
                64,
                25,
            ]));
        }
        let batch =
            rfc_hypgcn::rfc::CompressedTensor::concat_batch(parts).unwrap();
        println!(
            "  real {real}/8: ratio {:>5.2}x  ({} -> {} bits)",
            batch.compression_ratio(),
            batch.dense_bits(),
            batch.compressed_bits()
        );
    }
}

/// One kernel-section measurement row (also serialized to BENCH_rfc.json).
struct KernelRow {
    sparsity: f64,
    dense_s: f64,
    decode_dense_s: f64,
    spmm_serial_s: f64,
    spmm_scalar_s: f64,
    spmm_pooled_s: f64,
    skip_fraction: f64,
}

fn kernel_section() {
    // GEMM over one batch of flattened stage activations:
    // X[m, k] . W[k, n], k bank-aligned (the per-joint feature transform)
    let (m, k, n) = (512usize, 256usize, 64usize);
    let serial = serial_cfg();
    let pooled = KernelConfig {
        rows_per_job: 8,
        par_threshold_macs: 0,
        ..KernelConfig::default()
    };
    let forced_scalar =
        KernelConfig::serial().with_dispatch(LaneDispatch::ForceScalar);
    let isa = LaneDispatch::Auto.resolve();
    let iters = 10;
    let w: Vec<f32> = {
        let mut rng = rfc_hypgcn::util::rng::Rng::new(0xBE7C);
        (0..k * n).map(|_| rng.f32() * 2.0 - 1.0).collect()
    };
    let gemm = GemmF32::new(w, k, n).unwrap();

    println!(
        "\ncompressed-domain kernel -- X[{m}, {k}] . W[{k}, {n}], \
         isa {}, {} workers pooled",
        isa.name(),
        pooled.workers
    );
    println!(
        "{:>8}  {:>10}  {:>12}  {:>11}  {:>11}  {:>11}  {:>8}",
        "sparsity",
        "dense ms",
        "dec+dense ms",
        "spmm(1) ms",
        "scalar ms",
        "spmm(N) ms",
        "speedup"
    );
    let mut rows = Vec::new();
    for s10 in [50u64, 70, 90] {
        let sparsity = s10 as f64 / 100.0;
        let t = sparse_tensor(vec![m, k], sparsity, 242 + s10);
        let ct = rfc::encode(&t, &serial);
        let (_, stats) = spmm_f32(&ct, &gemm, &KernelConfig::serial()).unwrap();

        let dense = time_it(iters, || {
            std::hint::black_box(gemm_dense_f32(&t.data, m, &gemm));
        });
        let decode_dense = time_it(iters, || {
            let x = rfc::decode(&ct, &serial);
            std::hint::black_box(gemm_dense_f32(&x.data, m, &gemm));
        });
        let spmm1 = time_it(iters, || {
            std::hint::black_box(
                spmm_f32(&ct, &gemm, &KernelConfig::serial()).unwrap(),
            );
        });
        // the scalar reference path, timed on every runner: the ratchet
        // reads simd_speedup_vs_scalar off this column, and a scalar-only
        // host simply shows 1.0x
        let scalar = time_it(iters, || {
            std::hint::black_box(
                spmm_f32(&ct, &gemm, &forced_scalar).unwrap(),
            );
        });
        let spmmn = time_it(iters, || {
            std::hint::black_box(spmm_f32(&ct, &gemm, &pooled).unwrap());
        });
        let best = spmm1.mean_s.min(spmmn.mean_s);
        println!(
            "{:>7.0}%  {:>10.3}  {:>12.3}  {:>11.3}  {:>11.3}  {:>11.3}  {:>7.2}x",
            sparsity * 100.0,
            dense.mean_s * 1e3,
            decode_dense.mean_s * 1e3,
            spmm1.mean_s * 1e3,
            scalar.mean_s * 1e3,
            spmmn.mean_s * 1e3,
            decode_dense.mean_s / best,
        );
        rows.push(KernelRow {
            sparsity,
            dense_s: dense.mean_s,
            decode_dense_s: decode_dense.mean_s,
            spmm_serial_s: spmm1.mean_s,
            spmm_scalar_s: scalar.mean_s,
            spmm_pooled_s: spmmn.mean_s,
            skip_fraction: stats.skip_fraction(),
        });
    }
    emit_json(m, k, n, &rows);
}

/// Best-effort commit id for the emission: CI exports `GITHUB_SHA`;
/// local runs ask git; `"unknown"` keeps the file self-describing even
/// without either.
fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    if let Ok(out) = std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
    {
        if out.status.success() {
            if let Ok(s) = String::from_utf8(out.stdout) {
                let s = s.trim();
                if !s.is_empty() {
                    return s.to_string();
                }
            }
        }
    }
    "unknown".to_string()
}

/// Write the kernel results to `BENCH_rfc.json` at the repo root so the
/// perf trajectory is machine-readable across runs.
///
/// Schema v2 (consumed by `tools/bench_ratchet` -- keep the two in
/// sync): top-level `schema_version`, `bench`, `section`, `git_sha`,
/// problem dims, and a `machine` object whose `fingerprint`
/// (`<arch>/<isa>/<cpus>cpu`) gates ratchet comparisons -- results from
/// different fingerprints are never compared, only skipped.  Metric
/// fields end in `_s` (seconds, lower is better); every other numeric
/// field is context, not a ratcheted metric.
fn emit_json(m: usize, k: usize, n: usize, rows: &[KernelRow]) {
    let isa = LaneDispatch::Auto.resolve();
    let arch = std::env::consts::ARCH;
    let cpus = std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1);
    let machine = obj([
        ("arch", Json::Str(arch.to_string())),
        ("cpus", Json::Num(cpus as f64)),
        ("isa", Json::Str(isa.name().to_string())),
        (
            "cpu_features",
            Json::Arr(
                cpu_features()
                    .iter()
                    .map(|f| Json::Str(f.to_string()))
                    .collect(),
            ),
        ),
        (
            "fingerprint",
            Json::Str(format!("{arch}/{}/{cpus}cpu", isa.name())),
        ),
    ]);
    let results: Vec<Json> = rows
        .iter()
        .map(|r| {
            let best = r.spmm_serial_s.min(r.spmm_pooled_s);
            obj([
                ("sparsity", Json::Num(r.sparsity)),
                ("dense_s", Json::Num(r.dense_s)),
                ("decode_dense_s", Json::Num(r.decode_dense_s)),
                ("spmm_serial_s", Json::Num(r.spmm_serial_s)),
                ("spmm_scalar_s", Json::Num(r.spmm_scalar_s)),
                ("spmm_pooled_s", Json::Num(r.spmm_pooled_s)),
                (
                    "speedup_vs_decode_dense",
                    Json::Num(r.decode_dense_s / best),
                ),
                (
                    "simd_speedup_vs_scalar",
                    Json::Num(r.spmm_scalar_s / r.spmm_serial_s),
                ),
                ("skip_fraction", Json::Num(r.skip_fraction)),
            ])
        })
        .collect();
    let doc = obj([
        ("schema_version", Json::Num(2.0)),
        ("bench", Json::Str("rfc_throughput".to_string())),
        ("section", Json::Str("kernel".to_string())),
        ("git_sha", Json::Str(git_sha())),
        ("machine", machine),
        ("m", Json::Num(m as f64)),
        ("k", Json::Num(k as f64)),
        ("n", Json::Num(n as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_rfc.json");
    let mut body = doc.to_string_pretty();
    body.push('\n');
    match std::fs::write(&path, body) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

fn node_section() {
    use rfc_hypgcn::coordinator::{
        dense_entry, spawn_local_agents, ShardCluster, ShardFn,
    };
    use rfc_hypgcn::rfc::Payload;
    use std::sync::Arc;

    node_transport_subsection();
    node_failover_subsection();

    fn cheap_model(classes: usize) -> ShardFn {
        Arc::new(move |t| {
            let rows = t.shape[0];
            let row: usize = t.shape[1..].iter().product();
            let mut out = vec![0f32; rows * classes];
            for r in 0..rows {
                let s: f32 = t.data[r * row..(r + 1) * row].iter().sum();
                for (c, slot) in
                    out[r * classes..(r + 1) * classes].iter_mut().enumerate()
                {
                    *slot = s * (c + 1) as f32;
                }
            }
            rfc_hypgcn::runtime::Tensor::new(vec![rows, classes], out)
        })
    }

    fn node_transport_subsection() {
        // a cheap row-local model, so the measurement is dominated by
        // the transport (split, frame, ship, reassemble), not the
        // compute
        let model = cheap_model(8);
        let enc = serial_cfg();
        let shape = vec![8usize, 64, 25, 64];
        let bytes: usize = shape.iter().product::<usize>() * 4;
        let nodes = 2usize;
        let iters = 8;

        println!(
            "\nnode transport -- {nodes}-node cluster round trip, shape \
             {shape:?} ({:.1} MB dense)",
            bytes as f64 / 1e6
        );
        println!(
            "{:>8}  {:>12}  {:>12}  {:>12}  {:>9}",
            "sparsity", "frame MB", "loop ms", "tcp ms", "tcp MB/s"
        );
        for s10 in [50u64, 90] {
            let sparsity = s10 as f64 / 100.0;
            let t = sparse_tensor(shape.clone(), sparsity, 342 + s10);
            let p = Payload::from_tensor(t, &enc);
            let frame_mb = p.transport_bits() as f64 / 8.0 / 1e6;

            let mut loopback =
                ShardCluster::loopback(nodes, model.clone(), enc);
            let loop_t = time_it(iters, || {
                std::hint::black_box(loopback.infer(&p, None).unwrap());
            });
            loopback.shutdown();

            let (agents, addrs) = spawn_local_agents(
                nodes,
                dense_entry(model.clone(), enc),
                enc,
            )
            .unwrap();
            let mut tcp = ShardCluster::connect(&addrs, enc).unwrap();
            let tcp_t = time_it(iters, || {
                std::hint::black_box(tcp.infer(&p, None).unwrap());
            });
            tcp.shutdown();
            for a in agents {
                a.shutdown();
            }

            println!(
                "{:>7.0}%  {:>12.2}  {:>12.3}  {:>12.3}  {:>9.1}",
                sparsity * 100.0,
                frame_mb,
                loop_t.mean_s * 1e3,
                tcp_t.mean_s * 1e3,
                frame_mb / tcp_t.mean_s,
            );
        }
    }

    fn node_failover_subsection() {
        use rfc_hypgcn::coordinator::{ReconnectPolicy, RetryPolicy};
        use std::time::Duration;

        // batch latency under a 1-of-3 node kill: with shard retry on,
        // the kill-spanning batch succeeds late (one extra shard round
        // trip); with retry off it fails and only later batches recover.
        // The cost of masking -- kill-batch latency vs the healthy mean
        // -- is the number this records.
        let model = cheap_model(8);
        let enc = serial_cfg();
        let shape = vec![12usize, 64, 25, 16];
        let iters = 8;

        println!(
            "\nnode failover -- 3-node TCP cluster, 1 killed mid-run, \
             shape {shape:?}"
        );
        println!(
            "{:>9}  {:>11}  {:>11}  {:>8}  {:>12}",
            "retry", "healthy ms", "kill ms", "kill ok", "degraded ms"
        );
        let mut rows = Vec::new();
        for retry_on in [true, false] {
            let t = sparse_tensor(shape.clone(), 0.5, 542);
            let p = Payload::from_tensor(t, &enc);
            let (mut agents, addrs) = spawn_local_agents(
                3,
                dense_entry(model.clone(), enc),
                enc,
            )
            .unwrap();
            let mut cluster = ShardCluster::connect(&addrs, enc).unwrap();
            // the killed node must stay Down for the whole measurement:
            // a mid-measurement reconnect attempt would pollute the
            // degraded numbers
            cluster.set_reconnect_policy(ReconnectPolicy {
                base: Duration::from_secs(3600),
                cap: Duration::from_secs(3600),
                connect_timeout: Duration::from_millis(100),
                attempts_per_heal: 1,
                promote_after: Duration::from_secs(3600),
            });
            if !retry_on {
                cluster.set_retry_policy(RetryPolicy::disabled());
            }
            let healthy = time_it(iters, || {
                std::hint::black_box(cluster.infer(&p, None).unwrap());
            });
            agents.remove(1).shutdown();
            // the kill-spanning batch, timed alone
            let t0 = Instant::now();
            let kill_result = cluster.infer(&p, None);
            let kill_s = t0.elapsed().as_secs_f64();
            let kill_ok = kill_result.is_ok();
            let degraded = time_it(iters, || {
                std::hint::black_box(cluster.infer(&p, None).unwrap());
            });
            cluster.shutdown();
            for a in agents {
                a.shutdown();
            }
            println!(
                "{:>9}  {:>11.3}  {:>11.3}  {:>8}  {:>12.3}",
                if retry_on { "on" } else { "off" },
                healthy.mean_s * 1e3,
                kill_s * 1e3,
                kill_ok,
                degraded.mean_s * 1e3,
            );
            rows.push(FailoverRow {
                retry_on,
                healthy_mean_s: healthy.mean_s,
                kill_batch_s: kill_s,
                kill_batch_ok: kill_ok,
                degraded_mean_s: degraded.mean_s,
            });
        }
        emit_node_json(&rows);
    }
}

/// One failover measurement row (merged into `BENCH_rfc.json` under the
/// top-level `node` object).
struct FailoverRow {
    retry_on: bool,
    healthy_mean_s: f64,
    kill_batch_s: f64,
    kill_batch_ok: bool,
    degraded_mean_s: f64,
}

/// Merge the failover measurements into `BENCH_rfc.json` as the
/// top-level `node` object, following the [`emit_admission_json`]
/// pattern: the ratchet reads only the top-level `results` rows, so
/// this is trajectory context, never a gate.
fn emit_node_json(rows: &[FailoverRow]) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_rfc.json");
    let mut doc = match Json::from_file(&path) {
        Ok(Json::Obj(m)) => m,
        _ => {
            eprintln!(
                "note: {} missing or unreadable; run the kernel section \
                 first -- node results printed only",
                path.display()
            );
            return;
        }
    };
    let failover: Vec<Json> = rows
        .iter()
        .map(|r| {
            obj([
                ("retry_on", Json::Bool(r.retry_on)),
                ("healthy_mean_s", Json::Num(r.healthy_mean_s)),
                ("kill_batch_s", Json::Num(r.kill_batch_s)),
                ("kill_batch_ok", Json::Bool(r.kill_batch_ok)),
                ("degraded_mean_s", Json::Num(r.degraded_mean_s)),
            ])
        })
        .collect();
    doc.insert(
        "node".to_string(),
        obj([
            ("nodes", Json::Num(3.0)),
            ("killed", Json::Num(1.0)),
            ("failover", Json::Arr(failover)),
        ]),
    );
    let mut body = Json::Obj(doc).to_string_pretty();
    body.push('\n');
    match std::fs::write(&path, body) {
        Ok(()) => println!("merged node results into {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

/// One admission-section measurement row (merged into `BENCH_rfc.json`
/// under the top-level `admission` object).
struct AdmissionRow {
    offered_rps: f64,
    achieved_rps: f64,
    served: u64,
    shed: u64,
    expired: u64,
    failed: u64,
    shed_fraction: f64,
    submit_mean_s: f64,
}

fn admission_section() {
    use rfc_hypgcn::coordinator::{
        AdmissionPolicy, BatchPolicy, Server, ShardCluster, ShardFn,
    };
    use rfc_hypgcn::model::NUM_JOINTS;
    use std::sync::Arc;
    use std::time::Duration;

    // a pipeline pinned at ~5 ms per batch, so the serveable rate is
    // known (~batch_size / 5 ms at full batches) and the offered-rate
    // sweep crosses it
    const CLASSES: usize = 8;
    let seq_len = 8usize;
    let row = 3 * seq_len * NUM_JOINTS;
    let service = Duration::from_millis(5);
    let model: ShardFn = Arc::new(move |t| {
        std::thread::sleep(service);
        let rows = t.shape[0];
        let per: usize = t.shape[1..].iter().product();
        let mut out = vec![0f32; rows * CLASSES];
        for r in 0..rows {
            let s: f32 = t.data[r * per..(r + 1) * per].iter().sum();
            for (c, slot) in
                out[r * CLASSES..(r + 1) * CLASSES].iter_mut().enumerate()
            {
                *slot = s * (c + 1) as f32;
            }
        }
        rfc_hypgcn::runtime::Tensor::new(vec![rows, CLASSES], out)
    });
    let enc = serial_cfg();
    let batch = BatchPolicy {
        batch_size: 8,
        max_wait: Duration::from_millis(1),
        seq_len,
    };
    let admission = AdmissionPolicy {
        capacity: 32,
        max_queue_wait: Duration::from_millis(50),
        default_deadline: None,
    };
    let clip = sparse_tensor(vec![row], 0.5, 442).data;
    let n = 160usize;

    println!(
        "\nadmission front door -- capacity {}, queue bound {:?}, \
         batch {} @ ~{service:?}/batch, {n} submits per rate",
        admission.capacity, admission.max_queue_wait, batch.batch_size,
    );
    println!(
        "{:>11}  {:>9}  {:>6}  {:>6}  {:>7}  {:>6}  {:>10}",
        "offered r/s", "achieved", "served", "shed", "expired", "shed%",
        "submit us"
    );
    let mut rows_out = Vec::new();
    for rps in [400u64, 1600, 6400] {
        let cluster = ShardCluster::loopback(2, model.clone(), enc);
        let server = Server::start_cluster_admitted(
            batch.clone(),
            admission.clone(),
            enc,
            cluster,
            CLASSES,
        );
        let interval = Duration::from_secs_f64(1.0 / rps as f64);
        let start = Instant::now();
        let mut submit_s = 0f64;
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            let due = start + interval * i as u32;
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            let t0 = Instant::now();
            rxs.push(server.submit(clip.clone()));
            submit_s += t0.elapsed().as_secs_f64();
        }
        let achieved = n as f64 / start.elapsed().as_secs_f64();
        let (mut served, mut shed, mut expired, mut failed) =
            (0u64, 0u64, 0u64, 0u64);
        for rx in rxs {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(r) if r.is_ok() => served += 1,
                Ok(r) if r.is_shed() => shed += 1,
                Ok(r)
                    if r.error
                        .as_deref()
                        .is_some_and(|e| e.contains("deadline")) =>
                {
                    expired += 1
                }
                _ => failed += 1,
            }
        }
        server.shutdown();
        let shed_fraction = shed as f64 / n as f64;
        let submit_mean_s = submit_s / n as f64;
        println!(
            "{:>11.0}  {:>9.0}  {:>6}  {:>6}  {:>7}  {:>5.1}%  {:>10.1}",
            rps as f64,
            achieved,
            served,
            shed,
            expired,
            shed_fraction * 100.0,
            submit_mean_s * 1e6,
        );
        rows_out.push(AdmissionRow {
            offered_rps: rps as f64,
            achieved_rps: achieved,
            served,
            shed,
            expired,
            failed,
            shed_fraction,
            submit_mean_s,
        });
    }
    emit_admission_json(
        admission.capacity,
        admission.max_queue_wait.as_secs_f64() * 1e3,
        batch.batch_size,
        n,
        &rows_out,
    );
}

/// Merge the admission sweep into `BENCH_rfc.json` as the top-level
/// `admission` object.  The file is produced by [`emit_json`] (kernel
/// section, which CI runs first); `tools/bench_ratchet` reads only the
/// top-level `results` rows, so this object is trajectory context, not
/// a ratcheted metric.
fn emit_admission_json(
    capacity: usize,
    queue_wait_ms: f64,
    batch_size: usize,
    submitted: usize,
    rows: &[AdmissionRow],
) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_rfc.json");
    let mut doc = match Json::from_file(&path) {
        Ok(Json::Obj(m)) => m,
        _ => {
            eprintln!(
                "note: {} missing or unreadable; run the kernel section \
                 first -- admission results printed only",
                path.display()
            );
            return;
        }
    };
    let rates: Vec<Json> = rows
        .iter()
        .map(|r| {
            obj([
                ("offered_rps", Json::Num(r.offered_rps)),
                ("achieved_rps", Json::Num(r.achieved_rps)),
                ("served", Json::Num(r.served as f64)),
                ("shed", Json::Num(r.shed as f64)),
                ("expired", Json::Num(r.expired as f64)),
                ("failed", Json::Num(r.failed as f64)),
                ("shed_fraction", Json::Num(r.shed_fraction)),
                ("submit_mean_s", Json::Num(r.submit_mean_s)),
            ])
        })
        .collect();
    doc.insert(
        "admission".to_string(),
        obj([
            ("capacity", Json::Num(capacity as f64)),
            ("max_queue_wait_ms", Json::Num(queue_wait_ms)),
            ("batch_size", Json::Num(batch_size as f64)),
            ("submitted_per_rate", Json::Num(submitted as f64)),
            ("rates", Json::Arr(rates)),
        ]),
    );
    let mut body = Json::Obj(doc).to_string_pretty();
    body.push('\n');
    match std::fs::write(&path, body) {
        Ok(()) => println!("merged admission results into {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

const SECTIONS: [&str; 6] =
    ["codec", "wire", "batch", "kernel", "node", "admission"];

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let section = args
        .iter()
        .position(|a| a == "--section")
        .and_then(|i| args.get(i + 1))
        .cloned();
    // a typo'd section would otherwise run nothing and exit 0, and the
    // CI symptom (missing BENCH_rfc.json at artifact upload) points far
    // away from the cause
    if let Some(s) = section.as_deref() {
        if !SECTIONS.contains(&s) {
            eprintln!("unknown --section {s:?} (expected one of {SECTIONS:?})");
            std::process::exit(2);
        }
    }
    let want = |name: &str| section.as_deref().map_or(true, |s| s == name);
    if want("codec") {
        codec_section();
    }
    if want("wire") {
        wire_section();
    }
    if want("batch") {
        batch_section();
    }
    if want("kernel") {
        kernel_section();
    }
    if want("node") {
        node_section();
    }
    if want("admission") {
        admission_section();
    }
}
