//! Shared helpers for the hand-rolled bench harness (offline build: no
//! criterion in the vendor set; each bench is a `harness = false` binary
//! that prints the corresponding paper table).

// Each bench binary compiles its own copy of this module and uses a
// different subset of it.
#![allow(dead_code)]

use std::time::Instant;

use rfc_hypgcn::data::{GenConfig, SkeletonGen};
use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::runtime::{Engine, Executable, Tensor};
use rfc_hypgcn::util::stats::Summary;

/// Load the manifest or explain how to build it.
pub fn manifest_or_exit() -> Manifest {
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!(
                "cannot load artifacts from {}: {e:#}\nrun `make artifacts` first",
                dir.display()
            );
            std::process::exit(2);
        }
    }
}

/// Generate a deterministic input batch for a variant.
pub fn batch_for(m: &Manifest, seq_len: usize, seed: u64) -> Tensor {
    SkeletonGen::new(
        GenConfig {
            num_classes: m.num_classes,
            seq_len,
            noise: 0.02,
        },
        seed,
    )
    .batch(m.batch)
    .0
}

/// Time `iters` executions after `warmup` runs; returns per-call summary.
pub fn time_exe(
    exe: &Executable,
    input: &Tensor,
    warmup: usize,
    iters: usize,
) -> Summary {
    for _ in 0..warmup {
        exe.run1(&[input.clone()]).expect("warmup run");
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        let out = exe.run1(&[input.clone()]).expect("bench run");
        samples.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(out);
    }
    Summary::of(&samples)
}

/// Samples/second given a per-batch summary.
pub fn fps(batch: usize, s: &Summary) -> f64 {
    batch as f64 / s.mean_s
}

#[allow(dead_code)]
pub fn engine() -> Engine {
    Engine::cpu().expect("PJRT cpu engine")
}
