//! Table V -- throughput comparison: ours vs 2080Ti vs V100 across the
//! three model variants (original w/C, w/o C, input-skip).
//!
//! Three measurement sources, labelled in the output:
//!  * `ours(sim)`   -- the chip-mapped cycle simulator at paper scale;
//!  * `ours(cpu)`   -- the real AOT artifacts on this testbed's XLA-CPU
//!    runtime (shape check: variant ratios must match the paper's);
//!  * GPU columns   -- roofline models fitted to the paper's measured
//!    original-model fps (DESIGN.md SSSubstitutions).

mod common;

use rfc_hypgcn::baseline::{paper_gpus, VariantFlops};
use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::model::{dense_macs, ModelConfig};
use rfc_hypgcn::sim::pipeline::{map_chip, workloads};
use rfc_hypgcn::sim::reports;
use rfc_hypgcn::sim::resource::XCKU115;
use rfc_hypgcn::util::rng::Rng;

fn main() {
    // ---- paper-scale simulation + rooflines ----
    let cfg = ModelConfig::paper_full();
    let dense_flops: u64 =
        dense_macs(&cfg).iter().map(|m| m.flops()).sum();
    let flops = VariantFlops::from_dense(dense_flops as f64);
    let (g2080, v100) = paper_gpus(&flops);

    let specs = cfg.block_specs();
    let kept_in: Vec<usize> = specs
        .iter()
        .enumerate()
        .map(|(l, s)| if l == 0 { 3 } else { s.in_channels / 2 })
        .collect();
    let kept_f: Vec<usize> = (0..specs.len())
        .map(|l| {
            if l + 1 < specs.len() {
                kept_in[l + 1]
            } else {
                specs[l].out_channels
            }
        })
        .collect();
    let manifest = Manifest::load(&Manifest::default_dir()).ok();
    let sparsities = reports::block_sparsities(manifest.as_ref(), 10);
    let works = workloads(&cfg, &kept_in, &kept_f, &sparsities);
    let mut rng = Rng::new(5);
    let plan = map_chip(
        &works,
        &manifest
            .as_ref()
            .map(|m| m.cavity.clone())
            .unwrap_or_else(reports::default_cavity),
        &XCKU115,
        3500,
        &mut rng,
    );
    // input-skip halves every stage's work -> ~2x fps
    let ours = plan.fps() * 2.0; // skip variant is the shipped design

    println!("Table V -- throughput (fps) vs high-end GPUs, paper scale");
    println!(
        "           ours(sim)  2080Ti-orig  V100-orig  2080Ti(w/oC)  V100(w/oC)  2080Ti-skip  V100-skip"
    );
    println!(
        "throughput {:>9.2}  {:>11.2}  {:>9.2}  {:>12.2}  {:>10.2}  {:>11.2}  {:>9.2}",
        ours,
        g2080.fps(flops.with_ck),
        v100.fps(flops.with_ck),
        g2080.fps(flops.without_ck),
        v100.fps(flops.without_ck),
        g2080.fps(flops.skip),
        v100.fps(flops.skip),
    );
    println!(
        "speed-up   {:>9}  {:>11.2}  {:>9.2}  {:>12.2}  {:>10.2}  {:>11.2}  {:>9.2}",
        "--",
        ours / g2080.fps(flops.with_ck),
        ours / v100.fps(flops.with_ck),
        ours / g2080.fps(flops.without_ck),
        ours / v100.fps(flops.without_ck),
        ours / g2080.fps(flops.skip),
        ours / v100.fps(flops.skip),
    );
    println!(
        "(paper:      271.25        29.53      69.38         45.42       98.87       104.00     199.09)"
    );
    println!(
        "(paper x:                   9.19       3.91          5.97        2.74         2.61       1.36)"
    );

    // ---- testbed measurement: variant ratio shape check ----
    if let Some(m) = manifest {
        let engine = common::engine();
        println!("\ntestbed (XLA-CPU, batch {}):", m.batch);
        let mut fps_of = |hlo: &str, seq: usize, label: &str| -> f64 {
            let exe = engine
                .load_hlo(&m.hlo_path(hlo))
                .expect("load variant");
            let x = common::batch_for(&m, seq, 7);
            let s = common::time_exe(&exe, &x, 2, 8);
            let f = common::fps(m.batch, &s);
            println!("  {label:<14} {f:>8.2} fps   ({s})");
            f
        };
        let f_ck = fps_of(&m.model_ck.hlo.clone(), m.seq_len, "original(w/C)");
        let f_plain =
            fps_of(&m.model_dense.hlo.clone(), m.seq_len, "w/o C");
        let f_pruned =
            fps_of(&m.model_pruned.hlo.clone(), m.seq_len, "pruned");
        let f_skip =
            fps_of(&m.model_skip.hlo.clone(), m.seq_len / 2, "pruned+skip");
        println!(
            "  ratios: w/oC vs w/C {:.2}x (paper 1.43x); skip vs w/C {:.2}x \
             (paper 3.52x); pruned vs w/oC {:.2}x",
            f_plain / f_ck,
            f_skip / f_ck,
            f_pruned / f_plain
        );
    }
}
