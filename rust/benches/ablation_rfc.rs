//! Ablation: RFC design choices (DESIGN.md SSExperiment-index extension).
//!
//! Sweeps (a) mini-bank sizing headroom via bucket mixes, (b) bank width
//! sensitivity through the trace replayer, and (c) dynamic-vs-static
//! Dyn-Mult-PE sizing across feature sparsity -- quantifying the design
//! margins the paper fixes by fiat (16-wide banks, 4 mini-banks, eq. 6
//! DSP allocation).

mod common;

use rfc_hypgcn::runtime::Tensor;
use rfc_hypgcn::sim::dyn_pe;
use rfc_hypgcn::sim::trace::{measure_bank_buckets, replay};
use rfc_hypgcn::util::rng::Rng;

fn sparse_tensor(n: usize, c: usize, sparsity: f64, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let data: Vec<f32> = (0..n * c)
        .map(|_| {
            if rng.chance(sparsity) {
                0.0
            } else {
                rng.f32() + 0.01
            }
        })
        .collect();
    Tensor::new(vec![n, c], data).unwrap()
}

fn main() {
    println!("== ablation: RFC storage across trace sparsity ==");
    println!("sparsity  save_vs_dense  trunc  lossless  rfc_cyc/csc_cyc");
    for s10 in [2u64, 4, 5, 6, 8] {
        let s = s10 as f64 / 10.0;
        let x = sparse_tensor(2048, 64, s, 42 + s10);
        let r = replay(&x, measure_bank_buckets(&x)).unwrap();
        println!(
            "{:>7.1}%  {:>12.2}%  {:>5}  {:>8}  {:>6.3}",
            s * 100.0,
            r.saving_vs_dense() * 100.0,
            r.truncated_lines,
            r.lossless,
            r.rfc_cycles as f64 / r.csc_cycles as f64,
        );
    }

    println!("\n== ablation: sizing headroom (mis-specified buckets) ==");
    let x = sparse_tensor(2048, 64, 0.5, 7);
    let honest = measure_bank_buckets(&x);
    let optimistic = [0.8, 0.15, 0.05, 0.0];
    let pessimistic = [0.0, 0.0, 0.0, 1.0];
    for (name, b) in [
        ("measured", honest),
        ("optimistic", optimistic),
        ("worst-case", pessimistic),
    ] {
        let r = replay(&x, b).unwrap();
        println!(
            "{:<10} save {:>6.2}%  trunc {:>4}  lossless {}",
            name,
            r.saving_vs_dense() * 100.0,
            r.truncated_lines,
            r.lossless
        );
    }

    println!("\n== ablation: eq.6 DSP sizing vs fixed allocations ==");
    println!("sparsity  d=eq6   eff_dyn  delay   d=q(static-like)  d=1");
    let mut rng = Rng::new(11);
    for s10 in [2u64, 4, 5, 6, 8] {
        let s = s10 as f64 / 10.0;
        let q = 3usize;
        let d6 = dyn_pe::dsp_allocation(q, s).min(q);
        let a = dyn_pe::simulate(q, d6, 4096, s, 8, &mut rng);
        let b = dyn_pe::simulate(q, q, 4096, s, 8, &mut rng);
        let c = dyn_pe::simulate(q, 1, 4096, s, 8, &mut rng);
        println!(
            "{:>7.1}%  d={}    {:>6.2}%  {:>5.2}%  eff {:>6.2}%        eff {:>6.2}% delay {:>6.2}%",
            s * 100.0,
            d6,
            a.efficiency() * 100.0,
            a.delay() * 100.0,
            b.efficiency() * 100.0,
            c.efficiency() * 100.0,
            c.delay() * 100.0,
        );
    }
}
