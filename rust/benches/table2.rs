//! Table II -- Dyn-MultPE DSP utilization, working efficiency and max
//! delay, dynamic vs static scheduling (cycle simulation, eq. 6 sizing).

mod common;

use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::sim::reports;

fn main() {
    let m = Manifest::load(&Manifest::default_dir()).ok();
    if m.is_none() {
        eprintln!("(no artifacts: using paper-default sparsity)");
    }
    print!("{}", reports::table2(m.as_ref()));
}
