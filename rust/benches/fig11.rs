//! Fig. 11 -- storage cost of three data formats (dense / CSC / RFC)
//! over the traced per-layer sparsity distributions.

mod common;

use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::sim::reports;

fn main() {
    let m = Manifest::load(&Manifest::default_dir()).ok();
    print!("{}", reports::fig11(m.as_ref()));
}
