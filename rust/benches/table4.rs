//! Table IV -- resource utilization & performance of the mapped
//! accelerator vs Ding et al. [10] (chip mapping + resource model).

mod common;

use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::sim::reports;

fn main() {
    let m = Manifest::load(&Manifest::default_dir()).ok();
    print!("{}", reports::table4(m.as_ref()));
}
