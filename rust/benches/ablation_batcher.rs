//! Ablation: coordinator batching policy -- latency/throughput/padding
//! trade-off of the size-or-timeout batcher across wait budgets and
//! arrival rates.  (The paper's pipeline assumes saturating input; a
//! deployed system does not, and this quantifies the gap.)

mod common;

use std::time::{Duration, Instant};

use rfc_hypgcn::coordinator::{BatchPolicy, Server};
use rfc_hypgcn::data::{GenConfig, SkeletonGen};
use rfc_hypgcn::runtime::Engine;

fn main() {
    let m = common::manifest_or_exit();
    let engine = Engine::cpu().expect("engine");
    println!("== ablation: batch-wait vs latency/throughput/padding ==");
    println!("wait_ms  rate_fps  fps_out   p50_ms   p99_ms  padding");
    for (wait_ms, rate) in [
        (5u64, 40.0f64),
        (25, 40.0),
        (100, 40.0),
        (25, 10.0),
        (25, 120.0),
    ] {
        let server = Server::start(
            &engine,
            &m,
            BatchPolicy {
                batch_size: m.batch,
                max_wait: Duration::from_millis(wait_ms),
                seq_len: m.seq_len,
            },
        )
        .expect("server");
        let mut gen = SkeletonGen::new(
            GenConfig {
                num_classes: m.num_classes,
                seq_len: m.seq_len,
                noise: 0.02,
            },
            9,
        );
        let n = 48;
        let gap = Duration::from_secs_f64(1.0 / rate);
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..n {
            rxs.push(server.submit(gen.sample().0));
            let target = t0 + gap * (i as u32 + 1);
            if let Some(d) = target.checked_duration_since(Instant::now()) {
                std::thread::sleep(d);
            }
        }
        for rx in rxs {
            rx.recv().expect("response");
        }
        let wall = t0.elapsed().as_secs_f64();
        let lat = server.metrics.latency_summary();
        println!(
            "{:>7}  {:>8.0}  {:>7.2}  {:>7.1}  {:>7.1}  {:>6.1}%",
            wait_ms,
            rate,
            n as f64 / wall,
            lat.p50_s * 1e3,
            lat.p99_s * 1e3,
            server.metrics.padding_fraction() * 100.0,
        );
        server.shutdown();
    }
    println!(
        "\nexpected shape: longer waits -> fuller batches (less padding), \
         higher p50; slow arrivals -> padding dominates"
    );
}
