//! `rfc-hypgcn` CLI: inference, serving, and accelerator simulation over
//! the AOT artifacts.  Hand-rolled argument parsing (offline build).
//!
//! ```text
//! rfc-hypgcn infer      [--artifacts DIR] [--variant pruned|dense|ck|skip] [--batches N]
//! rfc-hypgcn serve      [--artifacts DIR] [--requests N] [--rate FPS] [--batch-wait MS]
//!                       [--admission-capacity N] [--default-deadline-ms MS]
//!                       [--nodes HOST:PORT[|STANDBY:PORT],...]
//!                       [--retry-attempts N] [--promote-after-ms MS]
//! rfc-hypgcn serve-node [--artifacts DIR] [--listen HOST:PORT]
//! rfc-hypgcn simulate   [--table2] [--table4] [--fig11] [--all]
//! rfc-hypgcn report     [--artifacts DIR]
//! ```

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use rfc_hypgcn::coordinator::{
    AdmissionPolicy, BatchPolicy, NodeSpec, ReconnectPolicy, RetryPolicy,
    Server, ShardCluster,
};
use rfc_hypgcn::data::{GenConfig, SkeletonGen};
use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::runtime::Engine;
use rfc_hypgcn::sim;

/// Tiny flag parser: `--key value` and bare `--switch` forms.
pub struct Args {
    pub cmd: String,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse() -> Args {
        let mut argv = std::env::args().skip(1);
        let cmd = argv.next().unwrap_or_else(|| "help".into());
        let rest: Vec<String> = argv.collect();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < rest.len() {
            let k = rest[i].trim_start_matches("--").to_string();
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                flags.push((k, Some(rest[i + 1].clone())));
                i += 2;
            } else {
                flags.push((k, None));
                i += 1;
            }
        }
        Args { cmd, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    fn artifacts(&self) -> PathBuf {
        self.get("artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(Manifest::default_dir)
    }
}

fn main() -> Result<()> {
    let args = Args::parse();
    match args.cmd.as_str() {
        "infer" => infer(&args),
        "serve" => serve(&args),
        "serve-node" => serve_node_cmd(&args),
        "simulate" => simulate(&args),
        "report" => report(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "\
rfc-hypgcn -- RFC-HyPGCN accelerator reproduction

USAGE:
  rfc-hypgcn infer      [--artifacts DIR] [--variant pruned|dense|ck|skip|blocks] [--batches N]
  rfc-hypgcn serve      [--artifacts DIR] [--requests N] [--rate FPS] [--batch-wait MS]
                        [--admission-capacity N] [--default-deadline-ms MS]
                        (bounded front door: shed over N queued, deadline per request)
                        [--nodes HOST:PORT[|STANDBY:PORT],...]
                        (drive remote node agents over TCP; a | suffix names
                         a standby address promoted into the slot when the
                         primary stays down past --promote-after-ms)
                        [--retry-attempts N]    (dispatch attempts per shard,
                         first try included; 1 disables fault-masking retry)
                        [--promote-after-ms MS] (Down budget before a slot's
                         standby is dialed; default 10000)
  rfc-hypgcn serve-node [--artifacts DIR] [--listen HOST:PORT]   (worker-node agent)
  rfc-hypgcn simulate   [--table2|--table4|--fig11|--all]
  rfc-hypgcn report     [--artifacts DIR]";

fn infer(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&args.artifacts())?;
    let engine = Engine::cpu()?;
    let variant = args.get("variant").unwrap_or("pruned");
    let batches = args.usize("batches", 4)?;
    let seq_len = if variant == "skip" {
        manifest.seq_len / 2
    } else {
        manifest.seq_len
    };
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: manifest.num_classes,
            seq_len,
            noise: 0.02,
        },
        42,
    );

    let t_load = Instant::now();
    let logits = if variant == "blocks" {
        let pipeline =
            rfc_hypgcn::coordinator::Pipeline::load(&engine, &manifest)?;
        println!(
            "compiled {} stages in {:.2}s",
            pipeline.stages.len() + 1,
            t_load.elapsed().as_secs_f64()
        );
        let mut last = None;
        let t0 = Instant::now();
        for _ in 0..batches {
            let (x, _) = gen.batch(manifest.batch);
            last = Some(pipeline.run_sync(&x)?);
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{} batches x {} samples in {:.3}s = {:.2} fps",
            batches,
            manifest.batch,
            dt,
            (batches * manifest.batch) as f64 / dt
        );
        // per-stage profile (perf pass: find the bottleneck stage)
        let (x, _) = gen.batch(manifest.batch);
        let times = pipeline.time_stages(&x)?;
        for (i, t) in times.iter().enumerate() {
            let label = if i < manifest.blocks.len() {
                format!("block {:2}", i + 1)
            } else {
                "head    ".into()
            };
            println!("  {label}  {:8.3} ms", t * 1e3);
        }
        last.unwrap()
    } else {
        let art = match variant {
            "pruned" => &manifest.model_pruned,
            "dense" => &manifest.model_dense,
            "ck" => &manifest.model_ck,
            "skip" => &manifest.model_skip,
            v => bail!("unknown variant {v:?}"),
        };
        let exe = engine.load_hlo(&manifest.hlo_path(&art.hlo))?;
        println!(
            "compiled {} in {:.2}s",
            art.hlo,
            t_load.elapsed().as_secs_f64()
        );
        let mut last = None;
        let t0 = Instant::now();
        for _ in 0..batches {
            let (x, _) = gen.batch(manifest.batch);
            last = Some(exe.run1(&[x])?);
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{} batches x {} samples in {:.3}s = {:.2} fps",
            batches,
            manifest.batch,
            dt,
            (batches * manifest.batch) as f64 / dt
        );
        last.unwrap()
    };
    println!(
        "logits shape {:?}; first row: {:?}",
        logits.shape,
        &logits.data[..logits.shape[1].min(8)]
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    // precedence: defaults < --config file < RFC_* env < CLI flags
    let cfg = rfc_hypgcn::config::ServeConfig::resolve(
        args.get("config").map(std::path::Path::new),
    )?;
    let artifacts = if args.has("artifacts") {
        args.artifacts()
    } else {
        cfg.artifacts.clone()
    };
    let manifest = Manifest::load(&artifacts)?;
    let requests = args.usize("requests", 64)?;
    let wait_ms = args.usize(
        "batch-wait",
        cfg.batch_wait.as_millis() as usize,
    )?;
    let policy = BatchPolicy {
        batch_size: manifest.batch,
        max_wait: std::time::Duration::from_millis(wait_ms as u64),
        seq_len: manifest.seq_len,
    };
    // bounded front door: defaults < config file/env < CLI flags
    let capacity = args.usize("admission-capacity", cfg.admission_capacity)?;
    let deadline_ms = args.usize(
        "default-deadline-ms",
        cfg.default_deadline
            .map(|d| d.as_millis() as usize)
            .unwrap_or(0),
    )?;
    let admission = AdmissionPolicy {
        capacity,
        max_queue_wait: cfg.max_queue_wait,
        default_deadline: (deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(deadline_ms as u64)),
    };
    println!(
        "starting coordinator (batch={}, wait={}ms, admission={} slots, \
         deadline={})...",
        policy.batch_size,
        wait_ms,
        admission.capacity,
        match admission.default_deadline {
            Some(d) => format!("{}ms", d.as_millis()),
            None => "none".into(),
        },
    );
    // --nodes addr[|standby],addr: the shard cluster spans real
    // machines -- the coordinator connects TCP links to `serve-node`
    // agents and needs no local engine at all (the nodes own the
    // model).  Retry and promotion policy come from the CLI so an
    // operator can tune fault-masking without a rebuild.
    let server = if let Some(nodes) = args.get("nodes") {
        let specs = nodes
            .split(',')
            .map(NodeSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let with_standby = specs.iter().filter(|s| !s.standbys.is_empty()).count();
        println!(
            "connecting to {} node agents ({} with standby): {nodes}",
            specs.len(),
            with_standby,
        );
        let retry_attempts = args.usize("retry-attempts", 3)?.max(1);
        let promote_after_ms = args.usize("promote-after-ms", 10_000)?;
        let enc = rfc_hypgcn::rfc::EncoderConfig::default();
        let mut cluster = ShardCluster::connect_specs(
            &specs,
            enc,
            Some(rfc_hypgcn::coordinator::shard::DEFAULT_NODE_IO_TIMEOUT),
        )?;
        cluster.set_retry_policy(RetryPolicy {
            max_attempts: retry_attempts,
            per_shard_timeout: None,
        });
        cluster.set_reconnect_policy(ReconnectPolicy {
            promote_after: std::time::Duration::from_millis(
                promote_after_ms as u64,
            ),
            ..ReconnectPolicy::default()
        });
        Server::start_cluster_admitted(
            policy,
            admission,
            enc,
            cluster,
            manifest.num_classes,
        )
    } else {
        let engine = Engine::cpu()?;
        Server::start_planned_admitted(
            &engine,
            &manifest,
            policy,
            admission,
            rfc_hypgcn::rfc::EncoderConfig::default(),
            Vec::new(),
        )?
    };
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: manifest.num_classes,
            seq_len: manifest.seq_len,
            noise: 0.02,
        },
        7,
    );
    let mut rxs = Vec::with_capacity(requests);
    for _ in 0..requests {
        let (clip, _) = gen.sample();
        rxs.push(server.submit(clip));
    }
    // failures now arrive as delivered error Responses (not channel
    // disconnects), so count Response::is_ok, not channel delivery;
    // shed answers (retry_after set) are broken out -- they are
    // backpressure working, not the server failing
    let mut ok = 0;
    let mut shed = 0;
    let mut failed = 0;
    for rx in rxs {
        match rx.recv() {
            Ok(resp) if resp.is_ok() => ok += 1,
            Ok(resp) if resp.is_shed() => shed += 1,
            _ => failed += 1,
        }
    }
    if shed > 0 || failed > 0 {
        println!("{ok}/{requests} answered ({shed} shed, {failed} failed)");
    } else {
        println!("{ok}/{requests} answered");
    }
    println!("{}", server.metrics.report());
    // cluster mode: per-node link supervision state, so a degraded
    // (Down, reconnected, or standby-promoted) node is visible from the
    // coordinator's exit summary, not just the node's own logs --
    // including how many shards each slot served and how many of those
    // were retries absorbed from a dead sibling
    if args.get("nodes").is_some() {
        let transport = server.metrics.node_transport();
        for (i, h) in server.metrics.node_health().iter().enumerate() {
            let (shards, retried_onto) = transport
                .get(i)
                .map(|t| (t.shards, t.retries))
                .unwrap_or((0, 0));
            println!(
                "node {i} [{}]: {} shards={} retried_onto={} reconnects={} \
                 promotions={} consecutive_failures={}",
                h.label,
                if h.up { "up" } else { "down" },
                shards,
                retried_onto,
                h.reconnects,
                h.promotions,
                h.consecutive_failures,
            );
        }
    }
    server.shutdown();
    Ok(())
}

/// Run one worker node of a TCP shard cluster: compile the stage chain
/// from the local artifacts, bind the listener, and service coordinator
/// connections forever (see `coordinator::node::serve_node`).
fn serve_node_cmd(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&args.artifacts())?;
    let engine = Engine::cpu()?;
    let listen = args.get("listen").unwrap_or("127.0.0.1:7070");
    let enc = rfc_hypgcn::rfc::EncoderConfig::default();
    let t0 = Instant::now();
    let pipeline = std::sync::Arc::new(
        rfc_hypgcn::coordinator::Pipeline::load(&engine, &manifest)?,
    );
    println!(
        "compiled {} stages in {:.2}s",
        pipeline.stages.len() + 1,
        t0.elapsed().as_secs_f64()
    );
    let compute =
        rfc_hypgcn::coordinator::dense_entry(pipeline.shard_fn(), enc);
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding {listen}"))?;
    println!("node agent listening on {}", listener.local_addr()?);
    rfc_hypgcn::coordinator::serve_node(listener, compute, enc)
}

fn simulate(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&args.artifacts()).ok();
    let all = args.has("all") || (!args.has("table2") && !args.has("table4")
        && !args.has("fig11"));
    if args.has("table2") || all {
        println!("{}", sim::reports::table2(manifest.as_ref()));
    }
    if args.has("fig11") || all {
        println!("{}", sim::reports::fig11(manifest.as_ref()));
    }
    if args.has("table4") || all {
        println!("{}", sim::reports::table4(manifest.as_ref()));
    }
    Ok(())
}

fn report(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&args.artifacts())?;
    println!("artifacts:        {}", manifest.dir.display());
    println!("batch:            {}", manifest.batch);
    println!("seq_len:          {}", manifest.seq_len);
    println!("schedule:         {}", manifest.schedule);
    println!("cavity:           {}", manifest.cavity.name);
    println!("compression:      {:.2}x", manifest.compression_ratio);
    println!("graph skip:       {:.2}%", manifest.graph_skip_ratio * 100.0);
    println!(
        "dense GFLOP/smp:  {:.4}",
        manifest.total_flops(false) / 1e9
    );
    println!(
        "pruned GFLOP/smp: {:.4}",
        manifest.total_flops(true) / 1e9
    );
    println!("blocks:");
    for (i, b) in manifest.blocks.iter().enumerate() {
        println!(
            "  {:2}: {:>3} -> {:<3} stride {} kept_in {:>3}/{:<3} hlo {}",
            i + 1,
            b.in_channels,
            b.out_channels,
            b.stride,
            b.kept_in.len(),
            b.in_channels,
            b.hlo
        );
    }
    Ok(())
}
