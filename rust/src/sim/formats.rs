//! Storage-format cost models: dense, CSC, RFC -- the Fig. 11 comparison
//! and the access-cycle table (1-cycle RFC load vs ~64-cycle serial CSC).

use super::resource::bram36_for;
use super::rfc::{BankStorage, BANK_WIDTH, ELEM_BITS, MINI_PER_BANK, MINI_WIDTH};

/// A layer's inter-block activation traffic, as the storage sees it.
#[derive(Debug, Clone)]
pub struct LayerTraffic {
    pub name: String,
    /// feature vectors buffered between layers (shortcut + pipeline)
    pub lines: usize,
    /// channels per vector (padded to a bank multiple by the encoder)
    pub channels: usize,
    /// mean activation sparsity
    pub mean_sparsity: f64,
    /// sparsity-bucket distribution I..IV (0.75-1, 0.5-0.75, 0.25-0.5, 0-0.25)
    pub buckets: [f64; 4],
}

impl LayerTraffic {
    pub fn banks_per_line(&self) -> usize {
        self.channels.div_ceil(BANK_WIDTH)
    }
}

/// Storage cost of one layer in one format.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FormatCost {
    pub bits: u64,
    pub bram36: u32,
    /// cycles to load one feature vector
    pub load_cycles: u64,
    /// cycles to encode/decode one feature vector (0 = none needed)
    pub codec_cycles: u64,
}

/// Dense: every element stored, no codec, 1-cycle wide load.
pub fn dense_cost(t: &LayerTraffic) -> FormatCost {
    let bits =
        t.lines as u64 * t.banks_per_line() as u64 * BANK_WIDTH as u64 * ELEM_BITS as u64;
    FormatCost {
        bits,
        bram36: bram36_for(bits, (BANK_WIDTH as u32) * ELEM_BITS),
        load_cycles: 1,
        codec_cycles: 0,
    }
}

/// CSC-style compact: values + 16-bit row indices per nonzero, plus a
/// column (vector) pointer array.  Capacity must be provisioned for the
/// layer's worst case, which runtime data can't bound tightly -- the
/// paper provisions for the observed densest vectors; we take the
/// conservative bound implied by the bucket distribution (the densest
/// occupied bucket's upper edge).  Serial decode: one element per cycle.
pub fn csc_cost(t: &LayerTraffic) -> FormatCost {
    let elems_per_line = t.banks_per_line() * BANK_WIDTH;
    // densest occupied bucket upper bound on nnz
    let worst_density = if t.buckets[3] > 0.001 {
        1.0
    } else if t.buckets[2] > 0.001 {
        0.75
    } else if t.buckets[1] > 0.001 {
        0.5
    } else {
        0.25
    };
    let cap_nnz =
        ((elems_per_line as f64) * worst_density).ceil() as u64;
    let value_bits = t.lines as u64 * cap_nnz * ELEM_BITS as u64;
    let index_bits = t.lines as u64 * cap_nnz * 16; // 16-bit row index
    let ptr_bits = (t.lines as u64 + 1) * 32;
    let bits = value_bits + index_bits + ptr_bits;
    // serial access: nnz elements one by one (paper: ~64 cycles typical)
    let mean_nnz =
        (elems_per_line as f64 * (1.0 - t.mean_sparsity)).ceil() as u64;
    FormatCost {
        bits,
        bram36: bram36_for(bits, 32),
        load_cycles: mean_nnz.max(1),
        codec_cycles: mean_nnz.max(1),
    }
}

/// RFC: per-bank mini-bank storage sized from the bucket distribution,
/// parallel 1-cycle load, 4-stage pipelined codec (4 data per stage).
pub fn rfc_cost(t: &LayerTraffic) -> FormatCost {
    let banks = t.banks_per_line();
    let depths = BankStorage::depths_from_buckets(t.buckets, t.lines);
    let store = BankStorage::new(depths);
    let bits_per_bank = store.provisioned_bits(t.lines);
    let bits = bits_per_bank * banks as u64;
    FormatCost {
        bits,
        // each mini-bank is an independently-enabled narrow memory
        bram36: banks as u32
            * depths
                .iter()
                .map(|&d| {
                    bram36_for(
                        (d * MINI_WIDTH) as u64 * ELEM_BITS as u64,
                        MINI_WIDTH as u32 * ELEM_BITS,
                    )
                })
                .sum::<u32>()
            + bram36_for(
                t.lines as u64 * (BANK_WIDTH + MINI_PER_BANK) as u64,
                18,
            ),
        load_cycles: 1,
        codec_cycles: BANK_WIDTH as u64 / 4, // 4 stages, 4 data each
    }
}

/// Fig. 11 row: the three formats side by side for one layer.
#[derive(Debug, Clone)]
pub struct Fig11Row {
    pub layer: String,
    pub dense: FormatCost,
    pub csc: FormatCost,
    pub rfc: FormatCost,
}

pub fn compare(t: &LayerTraffic) -> Fig11Row {
    Fig11Row {
        layer: t.name.clone(),
        dense: dense_cost(t),
        csc: csc_cost(t),
        rfc: rfc_cost(t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traffic(sparsity: f64, buckets: [f64; 4]) -> LayerTraffic {
        LayerTraffic {
            name: "test".into(),
            lines: 512,
            channels: 64,
            mean_sparsity: sparsity,
            buckets,
        }
    }

    #[test]
    fn rfc_beats_dense_on_sparse_traffic() {
        let t = traffic(0.6, [0.3, 0.4, 0.2, 0.1]);
        let row = compare(&t);
        assert!(
            (row.rfc.bits as f64) < row.dense.bits as f64 * 0.8,
            "rfc {} vs dense {}",
            row.rfc.bits,
            row.dense.bits
        );
    }

    #[test]
    fn rfc_loads_in_one_cycle_csc_serial() {
        let t = traffic(0.5, [0.25, 0.25, 0.25, 0.25]);
        let row = compare(&t);
        assert_eq!(row.rfc.load_cycles, 1);
        assert!(row.csc.load_cycles > 10);
        assert_eq!(row.rfc.codec_cycles, 4);
    }

    #[test]
    fn dense_traffic_gives_rfc_no_advantage() {
        // all vectors dense: every mini-bank provisioned full depth
        let t = traffic(0.02, [0.0, 0.0, 0.0, 1.0]);
        let row = compare(&t);
        assert!(row.rfc.bits >= row.dense.bits, "hot codes cost extra");
    }

    #[test]
    fn csc_worst_case_provisioning_hurts() {
        // mostly sparse but a dense tail forces full CSC capacity
        let t = traffic(0.7, [0.6, 0.3, 0.05, 0.05]);
        let row = compare(&t);
        // CSC must provision (16+16) bits per worst-case nnz: at full
        // density that's 2x dense storage
        assert!(row.csc.bits > row.dense.bits);
        assert!(row.rfc.bits < row.csc.bits);
    }

    #[test]
    fn paper_headline_rfc_reduction_band() {
        // Table III-like mix (50% mean sparsity, even quartiles) should
        // land near the paper's 35.93% BRAM reduction vs sparse(raw)
        let t = traffic(0.5, [0.25, 0.25, 0.25, 0.25]);
        let row = compare(&t);
        let saving = 1.0 - row.rfc.bits as f64 / row.dense.bits as f64;
        assert!(
            (0.15..0.45).contains(&saving),
            "saving {saving:.3}"
        );
    }

    #[test]
    fn bank_rounding() {
        let t = LayerTraffic {
            name: "x".into(),
            lines: 8,
            channels: 17, // not a bank multiple -> 2 banks
            mean_sparsity: 0.5,
            buckets: [0.25, 0.25, 0.25, 0.25],
        };
        assert_eq!(t.banks_per_line(), 2);
    }
}
