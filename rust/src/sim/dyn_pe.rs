//! Dyn-Mult-PE: the TCM's compute unit with waiting queues and dynamic
//! DSP scheduling (paper SSV-B, eq. 6, Table II).
//!
//! One Dyn-Mult-PE owns one *row* of sub-filters: `q` waiting queues, one
//! per kept (non-pruned) weight of the row, and `d <= q` DSPs.  Each cycle
//! every queue receives a candidate feature; a Logic-AND of weight mask
//! and feature hot code drops zero features before enqueue, then the
//! dynamic scheduler dispatches up to `d` queued MACs to DSPs.
//! With `d < q` DSPs the PE saves hardware but can fall behind when more
//! than `d` queues hold work -- the "max delay" column of Table II.
//!
//! Eq. 6 gives the expected number of *valid* (nonzero-feature) MACs per
//! cycle; the DSP count per PE is chosen as its ceiling.

use crate::util::rng::Rng;

/// Expected valid MACs per cycle for a sub-filter row with `q` kept
/// weights under feature sparsity `s` -- the binomial mean `q * (1 - s)`
/// (the paper's eq. 6 expands this for q = 6).
pub fn expected_valid(q: usize, s: f64) -> f64 {
    q as f64 * (1.0 - s)
}

/// Paper eq. 6 as printed: `E(D) = 3(1-s)^3 + 3s^2(1-s) + 6s(1-s)^2`.
/// This is the binomial expectation `sum d*p(d)` for one 3-weight half of
/// a 6-weight sub-filter, and algebraically equals `3(1-s)` -- the tests
/// cross-check the expansion against `expected_valid(3, s)`.
pub fn eq6_expectation(s: f64) -> f64 {
    3.0 * (1.0 - s).powi(3)
        + 3.0 * s * s * (1.0 - s)
        + 6.0 * s * (1.0 - s).powi(2)
}

/// Choose the DSP count for a PE: ceil of the expectation, at least 1.
pub fn dsp_allocation(q: usize, s: f64) -> usize {
    expected_valid(q, s).ceil().max(1.0) as usize
}

/// Result of simulating one Dyn-Mult-PE over a workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeStats {
    /// cycles the dynamic PE needed
    pub cycles: u64,
    /// cycles a static PE (one DSP per queue) would need
    pub static_cycles: u64,
    /// valid MACs executed
    pub macs: u64,
    /// MAC candidates offered to the admission logic (fed steps x queues)
    pub offered: u64,
    /// candidates the Logic-AND admitted (nonzero features enqueued)
    pub admitted: u64,
    /// DSPs in this PE
    pub dsps: usize,
    /// queues (kept weights) in this PE
    pub queues: usize,
}

impl PeStats {
    /// Fraction of DSP-cycles doing useful MACs.
    pub fn efficiency(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.macs as f64 / (self.cycles as f64 * self.dsps as f64)
    }

    /// Efficiency of the static design (q DSPs, no sharing).
    pub fn static_efficiency(&self) -> f64 {
        if self.static_cycles == 0 {
            return 0.0;
        }
        self.macs as f64 / (self.static_cycles as f64 * self.queues as f64)
    }

    /// Extra latency of dynamic scheduling vs static (>= 0).
    pub fn delay(&self) -> f64 {
        if self.static_cycles == 0 {
            return 0.0;
        }
        (self.cycles as f64 - self.static_cycles as f64).max(0.0)
            / self.static_cycles as f64
    }

    /// MAC candidates the Logic-AND admission dropped (zero features):
    /// offered minus admitted -- directly comparable to the runtime
    /// kernel's skipped-lane counter
    /// (`crate::rfc::kernel::SpmmStats::skipped_lanes`) when both see
    /// the same feature stream.  Counted at the admission point, so the
    /// figure stays truthful even if the simulation's safety valve
    /// aborts with queue backlog still undrained.
    pub fn skipped_macs(&self) -> u64 {
        self.offered - self.admitted
    }
}

/// Cycle-accurate simulation of one Dyn-Mult-PE.
///
/// * `q`: waiting queues (kept weights in the sub-filter row);
/// * `d`: DSPs;
/// * `steps`: input feature vectors streamed through (one per cycle of
///   input arrival);
/// * `sparsity`: probability a feature element is zero;
/// * `queue_cap`: waiting-queue depth (backpressure: input stalls when a
///   queue is full, adding cycles).
pub fn simulate(
    q: usize,
    d: usize,
    steps: u64,
    sparsity: f64,
    queue_cap: usize,
    rng: &mut Rng,
) -> PeStats {
    assert!(q >= 1 && d >= 1 && d <= q);
    // sample the admission flags up front (same order the feed loop
    // consumed them historically: one per queue per input step) and run
    // the explicit-stream simulation over them
    let mut hot = Vec::with_capacity(steps as usize * q);
    for _ in 0..steps {
        for _ in 0..q {
            hot.push(!rng.chance(sparsity));
        }
    }
    simulate_stream(q, d, &hot, queue_cap)
}

/// [`simulate`] over an explicit admission stream instead of a sampled
/// sparsity: `hot` holds `steps * q` flags in step-major `[steps][q]`
/// order, `true` meaning that queue's candidate feature is nonzero.
///
/// This is the shared-fixture entry point: feeding a real tensor's zero
/// pattern here must drop exactly the MACs the runtime compressed-domain
/// kernel skips on the same tensor ([`PeStats::skipped_macs`] vs
/// `SpmmStats::skipped_lanes` -- enforced by `tests/rfc_equivalence.rs`).
pub fn simulate_stream(q: usize, d: usize, hot: &[bool], queue_cap: usize) -> PeStats {
    assert!(q >= 1 && d >= 1 && d <= q);
    assert_eq!(hot.len() % q, 0, "hot stream must be step-major [steps][q]");
    let steps = (hot.len() / q) as u64;
    let mut queues = vec![0usize; q]; // occupancy per queue
    let mut macs = 0u64;
    let mut cycles = 0u64;
    let mut fed = 0u64;
    let mut offered = 0u64;
    let mut admitted = 0u64;
    // static reference: one DSP per queue, drains every cycle; its cycle
    // count equals the number of input steps (no backlog possible).
    let static_cycles = steps;
    while fed < steps || queues.iter().any(|&o| o > 0) {
        cycles += 1;
        // feed one feature element to every queue (if input remains and
        // no queue is saturated -- a full queue stalls the whole input
        // row, matching a synchronous feature broadcast)
        if fed < steps && queues.iter().all(|&o| o < queue_cap) {
            let row = &hot[fed as usize * q..(fed as usize + 1) * q];
            offered += q as u64;
            for (occ, &h) in queues.iter_mut().zip(row) {
                if h {
                    *occ += 1; // nonzero feature enqueued
                    admitted += 1;
                }
            }
            fed += 1;
        }
        // dynamic dispatch: up to d MACs from the most-backlogged queues
        let mut budget = d;
        // simple two-pass scheduler: serve nonempty queues round-robin
        while budget > 0 {
            let Some(idx) = queues
                .iter()
                .enumerate()
                .filter(|(_, &o)| o > 0)
                .max_by_key(|(_, &o)| o)
                .map(|(i, _)| i)
            else {
                break;
            };
            queues[idx] -= 1;
            macs += 1;
            budget -= 1;
        }
        // safety valve against pathological parameterizations
        if cycles > steps * 16 + 64 {
            break;
        }
    }
    PeStats {
        cycles,
        static_cycles,
        macs,
        offered,
        admitted,
        dsps: d,
        queues: q,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq6_matches_binomial_mean() {
        // the printed expansion equals the binomial mean 3(1-s) of a
        // 3-weight half sub-filter
        for s in [0.0, 0.25, 0.5, 0.75, 0.9] {
            let lhs = eq6_expectation(s);
            let rhs = expected_valid(3, s);
            assert!(
                (lhs - rhs).abs() < 1e-9,
                "s={s}: eq6 {lhs} vs binomial {rhs}"
            );
        }
    }

    #[test]
    fn allocation_shrinks_with_sparsity() {
        assert_eq!(dsp_allocation(6, 0.0), 6);
        assert!(dsp_allocation(6, 0.5) <= 3);
        assert_eq!(dsp_allocation(6, 0.95), 1);
    }

    #[test]
    fn dense_input_full_dsp_static() {
        // s = 0, d = q: every DSP busy every cycle, zero delay
        let mut rng = Rng::new(0);
        let st = simulate(4, 4, 1000, 0.0, 8, &mut rng);
        assert_eq!(st.macs, 4 * 1000);
        assert!(st.efficiency() > 0.99);
        assert!(st.delay() < 0.01);
    }

    #[test]
    fn dynamic_beats_static_efficiency_under_sparsity() {
        let mut rng = Rng::new(1);
        let s = 0.5;
        let d = dsp_allocation(6, s); // 3 DSPs
        let dy = simulate(6, d, 4000, s, 8, &mut rng);
        assert!(
            dy.efficiency() > dy.static_efficiency() + 0.1,
            "dyn {:.3} vs static {:.3}",
            dy.efficiency(),
            dy.static_efficiency()
        );
    }

    #[test]
    fn delay_small_when_sized_by_expectation() {
        let mut rng = Rng::new(2);
        let s = 0.5;
        let st = simulate(6, dsp_allocation(6, s), 4000, s, 8, &mut rng);
        assert!(st.delay() < 0.15, "delay {:.3}", st.delay());
    }

    #[test]
    fn undersized_pe_accumulates_delay() {
        let mut rng = Rng::new(3);
        // 6 queues, dense input, only 2 DSPs: must run ~3x longer
        let st = simulate(6, 2, 1000, 0.0, 64, &mut rng);
        assert!(st.delay() > 1.5, "delay {:.3}", st.delay());
        // but efficiency is perfect: DSPs never idle
        assert!(st.efficiency() > 0.95);
    }

    #[test]
    fn stream_simulation_counts_admissions_exactly() {
        // 3 queues, 4 steps, known zero pattern: 6 admitted, 6 dropped
        let hot = [
            true, false, true, false, false, false, true, true, true, false,
            true, false,
        ];
        let st = simulate_stream(3, 3, &hot, 8);
        assert_eq!(st.macs, 6);
        assert_eq!(st.skipped_macs(), 6);
        assert_eq!(st.static_cycles, 4);
    }

    #[test]
    fn skipped_macs_counts_admission_drops_not_backlog() {
        // q=32, d=1, fully dense: the safety valve truncates long
        // before the backlog drains, but zero candidates were dropped
        // by admission -- skipped_macs must say 0, not the backlog
        let hot = vec![true; 32 * 100];
        let st = simulate_stream(32, 1, &hot, 1024);
        assert_eq!(st.skipped_macs(), 0);
        assert_eq!(st.offered, 3200);
        assert!(st.macs < st.admitted, "valve truncated the drain");
    }

    #[test]
    fn sampled_simulation_is_a_stream_simulation() {
        // simulate() must be exactly simulate_stream over the flags it
        // would sample -- same seed, same stats
        let mut r1 = Rng::new(42);
        let a = simulate(5, 2, 200, 0.4, 8, &mut r1);
        let mut r2 = Rng::new(42);
        let mut hot = Vec::new();
        for _ in 0..200 {
            for _ in 0..5 {
                hot.push(!r2.chance(0.4));
            }
        }
        let b = simulate_stream(5, 2, &hot, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn all_macs_eventually_execute() {
        let mut rng = Rng::new(4);
        let st = simulate(4, 2, 500, 0.3, 16, &mut rng);
        // expected valid macs ~ 4 * 0.7 * 500 = 1400
        let expect = 4.0 * 0.7 * 500.0;
        assert!(
            (st.macs as f64 - expect).abs() < expect * 0.1,
            "macs {} vs {expect}",
            st.macs
        );
    }
}
