//! Functional CSC (compressed sparse column) codec -- the baseline format
//! the paper argues against (Fig. 11 and the access-cycle comparison).
//!
//! The cost model lives in [`super::formats`]; this module is the
//! *functional* counterpart used to cross-validate that both formats are
//! lossless while exhibiting their access patterns: CSC appends nonzeros
//! with explicit indices and must be walked serially to reconstruct a
//! vector, whereas RFC's bank layout loads in one cycle.

/// CSC storage for a stream of fixed-width feature vectors ("columns").
#[derive(Debug, Clone, Default)]
pub struct CscStore {
    pub values: Vec<f32>,
    pub row_idx: Vec<u16>,
    /// col_ptr[i]..col_ptr[i+1] spans vector i's nonzeros
    pub col_ptr: Vec<u32>,
    pub width: usize,
}

/// Cycle cost of one CSC operation under the paper's serial-port model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CscAccess {
    pub cycles: u64,
}

impl CscStore {
    pub fn new(width: usize) -> CscStore {
        CscStore {
            values: Vec::new(),
            row_idx: Vec::new(),
            col_ptr: vec![0],
            width,
        }
    }

    /// Append one vector; encoding walks it serially (one element/cycle).
    pub fn store(&mut self, v: &[f32]) -> CscAccess {
        assert_eq!(v.len(), self.width);
        for (i, &x) in v.iter().enumerate() {
            if x != 0.0 {
                self.values.push(x);
                self.row_idx.push(i as u16);
            }
        }
        self.col_ptr.push(self.values.len() as u32);
        CscAccess {
            cycles: self.width as u64,
        }
    }

    pub fn len(&self) -> usize {
        self.col_ptr.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode vector `i`; serial: one nonzero per cycle plus pointer read.
    pub fn load(&self, i: usize) -> Option<(Vec<f32>, CscAccess)> {
        if i >= self.len() {
            return None;
        }
        let lo = self.col_ptr[i] as usize;
        let hi = self.col_ptr[i + 1] as usize;
        let mut out = vec![0f32; self.width];
        for j in lo..hi {
            out[self.row_idx[j] as usize] = self.values[j];
        }
        Some((
            out,
            CscAccess {
                cycles: (hi - lo) as u64 + 1,
            },
        ))
    }

    /// Bits held (values @16b + indices @16b + pointers @32b).
    pub fn stored_bits(&self) -> u64 {
        self.values.len() as u64 * 16
            + self.row_idx.len() as u64 * 16
            + self.col_ptr.len() as u64 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rfc::{decode_bank, encode_bank, BANK_WIDTH};
    use crate::util::rng::Rng;

    fn vec_with(width: usize, pairs: &[(usize, f32)]) -> Vec<f32> {
        let mut v = vec![0f32; width];
        for &(i, x) in pairs {
            v[i] = x;
        }
        v
    }

    #[test]
    fn roundtrip() {
        let mut st = CscStore::new(8);
        let a = vec_with(8, &[(0, 1.0), (7, 2.0)]);
        let b = vec_with(8, &[(3, -4.0)]);
        st.store(&a);
        st.store(&b);
        assert_eq!(st.load(0).unwrap().0, a);
        assert_eq!(st.load(1).unwrap().0, b);
        assert!(st.load(2).is_none());
    }

    #[test]
    fn serial_access_cost_scales_with_nnz() {
        let mut st = CscStore::new(64);
        let dense: Vec<f32> = (1..=64).map(|i| i as f32).collect();
        let sparse = vec_with(64, &[(5, 1.0)]);
        st.store(&dense);
        st.store(&sparse);
        let (_, a_dense) = st.load(0).unwrap();
        let (_, a_sparse) = st.load(1).unwrap();
        assert_eq!(a_dense.cycles, 65); // paper's ~64-cycle serial decode
        assert_eq!(a_sparse.cycles, 2);
    }

    #[test]
    fn csc_and_rfc_agree_functionally() {
        // both formats must be lossless over the same random banks
        let mut rng = Rng::new(42);
        let mut csc = CscStore::new(BANK_WIDTH);
        let mut originals = Vec::new();
        for _ in 0..50 {
            let s = rng.f64();
            let bank: Vec<f32> = (0..BANK_WIDTH)
                .map(|_| {
                    if rng.chance(s) {
                        0.0
                    } else {
                        rng.f32() + 0.01
                    }
                })
                .collect();
            csc.store(&bank);
            originals.push(bank);
        }
        for (i, orig) in originals.iter().enumerate() {
            let (via_csc, _) = csc.load(i).unwrap();
            let via_rfc = decode_bank(&encode_bank(orig).unwrap()).to_vec();
            assert_eq!(&via_csc, orig);
            assert_eq!(&via_rfc, orig);
        }
    }

    #[test]
    fn stored_bits_accounting() {
        let mut st = CscStore::new(4);
        st.store(&[1.0, 0.0, 2.0, 0.0]);
        // 2 values*16 + 2 idx*16 + 2 ptr*32 = 128
        assert_eq!(st.stored_bits(), 2 * 16 + 2 * 16 + 2 * 32);
    }
}
