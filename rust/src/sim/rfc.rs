//! RFC -- Runtime sparse Feature Compress (paper SSV-C, Fig. 7, Fig. 11).
//!
//! Functional + cost model of the paper's compressed inter-layer storage:
//!
//! * **Encoding**: a feature vector is split into 16-element *banks*
//!   across channels.  ReLU produces the value and a 16-bit hot code
//!   (nonzero mask); valid elements are packed to the high positions; a
//!   *mini-bank hot code* (mbhot) marks how many mini-banks the packed
//!   data occupies.
//! * **Storage**: each bank's storage is split into depth-variable
//!   mini-banks of 4 elements; a write enables only the mini-banks named
//!   by mbhot (each with its own write pointer `pt`), so sparse vectors
//!   consume shallow storage while dense ones spill into tail mini-banks.
//! * **Decoding**: data-fetch reads all enabled mini-banks in one cycle
//!   and re-expands to sparse form via the hot code in a 4-stage pipeline
//!   (4 elements per stage).
//!
//! The functional model below is bit-exact w.r.t. this scheme (pack,
//! mbhot, per-mini-bank pts, zero-fill on decode) and the cost model
//! reproduces Fig. 11's BRAM accounting and the 1-cycle load / 4-cycle
//! encode vs 64-cycle serial CSC comparison.

use anyhow::{bail, ensure, Result};

/// Elements per bank (the paper's encoding grain).
pub const BANK_WIDTH: usize = 16;
/// Elements per mini-bank (4 mini-banks per bank line).
pub const MINI_WIDTH: usize = 4;
/// Mini-banks per bank.
pub const MINI_PER_BANK: usize = BANK_WIDTH / MINI_WIDTH;
/// Bits per stored element (Q8.8).
pub const ELEM_BITS: u32 = 16;

/// One encoded bank line: packed values + hot codes.
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedBank {
    /// nonzero values packed at the head ("gathered at higher bits")
    pub packed: Vec<f32>,
    /// 16-bit element hot code: bit i set iff element i was nonzero
    pub hot: u16,
    /// mini-bank hot code: bit m set iff mini-bank m receives data
    pub mbhot: u8,
}

impl EncodedBank {
    pub fn nnz(&self) -> usize {
        self.hot.count_ones() as usize
    }

    /// Expected mini-bank hot code for `nnz` packed values: the
    /// `ceil(nnz/4)` head mini-banks, contiguously from the head.
    pub fn mbhot_for(nnz: usize) -> u8 {
        ((1u16 << nnz.div_ceil(MINI_WIDTH)) - 1) as u8
    }

    /// Structural validation: the hot code must name exactly the packed
    /// values and mbhot must cover exactly `ceil(nnz/4)` mini-banks.
    /// This is the rejection contract the runtime format
    /// ([`crate::rfc::CompressedTensor::validate`]) mirrors.
    pub fn validate(&self) -> Result<()> {
        let nnz = self.nnz();
        if nnz != self.packed.len() {
            bail!(
                "hot code names {nnz} values but {} are packed",
                self.packed.len()
            );
        }
        if self.mbhot != Self::mbhot_for(nnz) {
            bail!(
                "mbhot {:#06b} inconsistent with nnz {nnz} (expected {:#06b})",
                self.mbhot,
                Self::mbhot_for(nnz)
            );
        }
        Ok(())
    }
}

/// Encode one bank of `BANK_WIDTH` post-ReLU values.
pub fn encode_bank(values: &[f32]) -> Result<EncodedBank> {
    if values.len() != BANK_WIDTH {
        bail!("bank must have {BANK_WIDTH} values, got {}", values.len());
    }
    let mut hot = 0u16;
    let mut packed = Vec::with_capacity(BANK_WIDTH);
    for (i, &v) in values.iter().enumerate() {
        if v != 0.0 {
            hot |= 1 << i;
            packed.push(v);
        }
    }
    let mbhot = EncodedBank::mbhot_for(packed.len());
    Ok(EncodedBank { packed, hot, mbhot })
}

/// Checked decode: rejects hot-code/packed-length (or mbhot) mismatches
/// instead of panicking on a short `packed` or silently ignoring a long
/// one.
pub fn decode_bank_checked(e: &EncodedBank) -> Result<[f32; BANK_WIDTH]> {
    e.validate()?;
    Ok(decode_bank(e))
}

/// Decode an encoded bank back to its sparse form.
pub fn decode_bank(e: &EncodedBank) -> [f32; BANK_WIDTH] {
    let mut out = [0f32; BANK_WIDTH];
    let mut next = 0;
    for (i, slot) in out.iter_mut().enumerate() {
        if e.hot & (1 << i) != 0 {
            *slot = e.packed[next];
            next += 1;
        }
    }
    out
}

/// One bank's physical storage: mini-banks with independent depths and
/// write pointers.
#[derive(Debug, Clone)]
pub struct BankStorage {
    /// depth (in bank-lines) of each mini-bank, head to tail --
    /// depth-variable per the offline sparsity distribution
    pub depths: [usize; MINI_PER_BANK],
    /// mini-bank memories: `mem[m][pt]` holds `MINI_WIDTH` elements
    mem: Vec<Vec<[f32; MINI_WIDTH]>>,
    /// per-mini-bank write pointers (`pt` in the paper)
    pts: [usize; MINI_PER_BANK],
    /// per-line hot codes (data-hot storage)
    hots: Vec<u16>,
    /// per-line mbhot codes (mini-bank-hot storage)
    mbhots: Vec<u8>,
}

/// Write/read outcome including cycle cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Access {
    pub cycles: u64,
    /// lines that could not fit their tail mini-banks (truncation events)
    pub truncated: bool,
}

impl BankStorage {
    pub fn new(depths: [usize; MINI_PER_BANK]) -> Self {
        BankStorage {
            depths,
            mem: depths.iter().map(|&d| Vec::with_capacity(d)).collect(),
            pts: [0; MINI_PER_BANK],
            hots: Vec::new(),
            mbhots: Vec::new(),
        }
    }

    /// Size the mini-bank depths from a sparsity-bucket distribution:
    /// `buckets[0]` = fraction of vectors with sparsity in [0.75, 1]
    /// (need 1 mini-bank), ... `buckets[3]` = [0, 0.25) (need all 4).
    /// `lines` is the number of bank-lines the layer must hold.
    pub fn depths_from_buckets(buckets: [f64; 4], lines: usize) -> [usize; MINI_PER_BANK] {
        // mini-bank m is used by vectors needing > m mini-banks
        let mut depths = [0usize; MINI_PER_BANK];
        for (m, d) in depths.iter_mut().enumerate() {
            let frac: f64 = buckets[m..].iter().sum();
            // headroom: sizing exactly at the expectation truncates ~half
            // the denser-than-average lines; the paper leaves slack via
            // "variable grains" -- we provision 12.5% extra.
            *d = ((frac * lines as f64 * 1.125).ceil() as usize).min(lines);
        }
        depths[0] = lines; // head mini-bank always holds every line
        depths
    }

    /// Store one encoded line in a single cycle (all enabled mini-banks
    /// written in parallel).  A line whose tail mini-bank is full is
    /// truncated (its overflow elements dropped) -- tracked, and sized to
    /// be rare by `depths_from_buckets`.
    pub fn store(&mut self, e: &EncodedBank) -> Access {
        let mut truncated = false;
        for m in 0..MINI_PER_BANK {
            if e.mbhot & (1 << m) != 0 {
                if self.pts[m] < self.depths[m] {
                    let mut chunk = [0f32; MINI_WIDTH];
                    for (i, c) in chunk.iter_mut().enumerate() {
                        *c = *e
                            .packed
                            .get(m * MINI_WIDTH + i)
                            .unwrap_or(&0.0);
                    }
                    self.mem[m].push(chunk);
                    self.pts[m] += 1;
                } else {
                    truncated = true;
                }
            }
        }
        self.hots.push(e.hot);
        self.mbhots.push(e.mbhot);
        Access {
            cycles: 1,
            truncated,
        }
    }

    /// Load line `idx` in one cycle: mbhot enables the right mini-banks;
    /// disabled mini-banks output zero.
    pub fn load(&self, idx: usize) -> Option<(EncodedBank, Access)> {
        let hot = *self.hots.get(idx)?;
        let mbhot = *self.mbhots.get(idx)?;
        // reconstruct each mini-bank's pt at line idx: number of earlier
        // lines that enabled it (pointer arithmetic the pt register does
        // incrementally in hardware)
        let mut packed = Vec::new();
        let nnz = hot.count_ones() as usize;
        for m in 0..MINI_PER_BANK {
            if mbhot & (1 << m) != 0 {
                let pt = self.mbhots[..idx]
                    .iter()
                    .filter(|&&mb| mb & (1 << m) != 0)
                    .count();
                if let Some(chunk) = self.mem[m].get(pt) {
                    packed.extend_from_slice(chunk);
                } else {
                    packed.extend_from_slice(&[0.0; MINI_WIDTH]);
                }
            }
        }
        packed.truncate(nnz);
        Some((
            EncodedBank {
                packed,
                hot,
                mbhot,
            },
            Access {
                cycles: 1,
                truncated: false,
            },
        ))
    }

    /// Bits of storage provisioned (mini-banks + hot-code sidecars).
    pub fn provisioned_bits(&self, lines: usize) -> u64 {
        let data: u64 = self
            .depths
            .iter()
            .map(|&d| (d * MINI_WIDTH) as u64 * ELEM_BITS as u64)
            .sum();
        let hot = lines as u64 * BANK_WIDTH as u64; // 16-bit hot per line
        let mbhot = lines as u64 * MINI_PER_BANK as u64;
        data + hot + mbhot
    }
}

/// Encode an entire feature vector (multiple banks across channels).
/// Returns the encoded banks and the pipeline cycles: the paper's encoder
/// handles one bank per stage, 4 pipeline stages, so a vector of B banks
/// streams through in `B + 3` cycles.
pub fn encode_vector(values: &[f32]) -> Result<(Vec<EncodedBank>, u64)> {
    if values.len() % BANK_WIDTH != 0 {
        bail!(
            "vector length {} not a multiple of bank width {BANK_WIDTH}",
            values.len()
        );
    }
    let banks: Vec<EncodedBank> = values
        .chunks(BANK_WIDTH)
        .map(encode_bank)
        .collect::<Result<_>>()?;
    let cycles = banks.len() as u64 + 3;
    Ok((banks, cycles))
}

/// Wire-format v1 magic -- duplicated from the runtime implementation
/// (`crate::rfc::wire`) on purpose: this mirror re-implements the
/// normative spec (`docs/wire-format.md`) independently, so the
/// equivalence test in `tests/rfc_equivalence.rs` catches either side
/// drifting from the format.
pub const WIRE_MAGIC: [u8; 4] = *b"RFCW";
/// Wire-format version this mirror emits.
pub const WIRE_VERSION: u16 = 1;

/// Serialize a dense tensor into the v1 wire byte stream through the
/// bit-exact sim encoder ([`encode_vector`]), bank by bank.  Unaligned
/// rows are zero-padded to the bank grid before encoding (padding lanes
/// are never hot), mirroring the runtime tail-bank rule.  The output
/// must be byte-identical to `rfc::wire::to_bytes` of the runtime
/// encoding of the same tensor, for every encoder shard count.
pub fn wire_bytes(shape: &[usize], data: &[f32]) -> Result<Vec<u8>> {
    // the same bounds the runtime writer enforces (8 is the wire MAX_RANK,
    // restated here rather than imported to keep the mirror independent)
    ensure!(shape.len() <= 8, "rank {} exceeds wire max 8", shape.len());
    for &d in shape {
        ensure!(d as u64 <= u32::MAX as u64, "dim {d} exceeds u32");
    }
    let (rows, row_len) = match shape.len() {
        0 => (1usize, 1usize),
        1 => (1, shape[0]),
        _ => (shape[0], shape[1..].iter().product()),
    };
    ensure!(
        rows * row_len == data.len(),
        "shape {shape:?} wants {} elements, got {}",
        rows * row_len,
        data.len()
    );
    let row_banks = row_len.div_ceil(BANK_WIDTH);
    let mut banks: Vec<EncodedBank> = Vec::with_capacity(rows * row_banks);
    let mut row_offsets = Vec::with_capacity(rows + 1);
    let mut nnz = 0usize;
    row_offsets.push(0u32);
    for r in 0..rows {
        let mut padded = data[r * row_len..(r + 1) * row_len].to_vec();
        padded.resize(row_banks * BANK_WIDTH, 0.0);
        let (encoded, _cycles) = encode_vector(&padded)?;
        nnz += encoded.iter().map(|b| b.packed.len()).sum::<usize>();
        banks.extend(encoded);
        row_offsets.push(nnz as u32);
    }
    // header: magic | version | rank | total_len | dims | row_banks |
    // bank_count | packed_len, then hots, mbhots, row_offsets, packed
    let total =
        12 + 4 * shape.len() + 12 + banks.len() * 3 + (rows + 1) * 4 + nnz * 4;
    ensure!(
        total as u64 <= u32::MAX as u64,
        "frame length {total} exceeds u32"
    );
    let mut w = Vec::with_capacity(total);
    w.extend_from_slice(&WIRE_MAGIC);
    w.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    w.extend_from_slice(&(shape.len() as u16).to_le_bytes());
    w.extend_from_slice(&(total as u32).to_le_bytes());
    for &d in shape {
        w.extend_from_slice(&(d as u32).to_le_bytes());
    }
    w.extend_from_slice(&(row_banks as u32).to_le_bytes());
    w.extend_from_slice(&((rows * row_banks) as u32).to_le_bytes());
    w.extend_from_slice(&(nnz as u32).to_le_bytes());
    for b in &banks {
        w.extend_from_slice(&b.hot.to_le_bytes());
    }
    for b in &banks {
        w.push(b.mbhot);
    }
    for &o in &row_offsets {
        w.extend_from_slice(&o.to_le_bytes());
    }
    for b in &banks {
        for &v in &b.packed {
            w.extend_from_slice(&v.to_le_bytes());
        }
    }
    debug_assert_eq!(w.len(), total);
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vec16(pairs: &[(usize, f32)]) -> Vec<f32> {
        let mut v = vec![0f32; BANK_WIDTH];
        for &(i, x) in pairs {
            v[i] = x;
        }
        v
    }

    #[test]
    fn encode_packs_high_positions() {
        let v = vec16(&[(0, 1.0), (5, 2.0), (15, 3.0)]);
        let e = encode_bank(&v).unwrap();
        assert_eq!(e.packed, vec![1.0, 2.0, 3.0]);
        assert_eq!(e.nnz(), 3);
        assert_eq!(e.mbhot, 0b0001); // 3 values -> 1 mini-bank
    }

    #[test]
    fn paper_worked_example() {
        // paper: data-hot 0001_1100_0000_0111 -> five nonzero, mbhot 2
        // mini-banks (their figure writes mbhot as "1100"; in our
        // head-first bit order that is 0b0011)
        let mut v = vec![0f32; BANK_WIDTH];
        // bits set in 0001_1100_0000_0111 reading MSB-first positions:
        for i in [3, 4, 5, 13, 14, 15] {
            v[i] = 1.0;
        }
        // that's six bits; the paper says five -- use exactly five:
        v[3] = 0.0;
        let e = encode_bank(&v).unwrap();
        assert_eq!(e.nnz(), 5);
        assert_eq!(e.mbhot.count_ones(), 2); // 5 values -> 2 mini-banks
    }

    #[test]
    fn decode_roundtrip() {
        let v = vec16(&[(1, 0.5), (2, -1.5), (7, 3.0), (8, 4.0), (14, 9.0)]);
        let e = encode_bank(&v).unwrap();
        assert_eq!(decode_bank(&e).to_vec(), v);
    }

    #[test]
    fn dense_bank_uses_all_minibanks() {
        let v: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let e = encode_bank(&v).unwrap();
        assert_eq!(e.mbhot, 0b1111);
        assert_eq!(e.packed.len(), 16);
    }

    #[test]
    fn all_zero_bank() {
        let e = encode_bank(&vec![0f32; 16]).unwrap();
        assert_eq!(e.nnz(), 0);
        assert_eq!(e.mbhot, 0);
        assert_eq!(decode_bank(&e), [0f32; 16]);
    }

    #[test]
    fn all_zero_bank_stores_and_loads() {
        // mbhot = 0: no mini-bank is written, yet the line must load
        // back as zeros (only the hot-code sidecars advance)
        let mut st = BankStorage::new([4, 4, 4, 4]);
        let e = encode_bank(&vec![0f32; 16]).unwrap();
        let a = st.store(&e);
        assert_eq!(a.cycles, 1);
        assert!(!a.truncated);
        let (back, _) = st.load(0).unwrap();
        assert_eq!(back.mbhot, 0);
        assert!(back.packed.is_empty());
        assert_eq!(decode_bank(&back), [0f32; 16]);
    }

    #[test]
    fn fully_dense_bank_roundtrips_through_storage() {
        // all 4 mini-banks enabled: mbhot 0b1111, 16 packed values
        let dense: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let e = encode_bank(&dense).unwrap();
        assert_eq!(e.mbhot, 0b1111);
        assert_eq!(e.packed.len(), BANK_WIDTH);
        e.validate().unwrap();
        let mut st = BankStorage::new([2, 2, 2, 2]);
        st.store(&e);
        let (back, _) = st.load(0).unwrap();
        assert_eq!(decode_bank(&back).to_vec(), dense);
    }

    #[test]
    fn mismatched_packed_length_rejected() {
        let v = vec16(&[(0, 1.0), (4, 2.0), (9, 3.0)]);
        let mut e = encode_bank(&v).unwrap();
        e.validate().unwrap();
        assert_eq!(decode_bank_checked(&e).unwrap().to_vec(), v);
        // drop one packed value: hot names 3, packed holds 2
        e.packed.pop();
        assert!(e.validate().is_err());
        assert!(decode_bank_checked(&e).is_err());
        // extra packed value: hot names 3, packed holds 4
        e.packed.push(9.0);
        e.packed.push(9.0);
        assert!(decode_bank_checked(&e).is_err());
    }

    #[test]
    fn inconsistent_mbhot_rejected() {
        let v = vec16(&[(1, 1.0), (2, 2.0)]);
        let mut e = encode_bank(&v).unwrap();
        // 2 values need 1 mini-bank; claim all 4
        e.mbhot = 0b1111;
        assert!(e.validate().is_err());
        assert!(decode_bank_checked(&e).is_err());
        e.mbhot = EncodedBank::mbhot_for(2);
        assert!(decode_bank_checked(&e).is_ok());
    }

    #[test]
    fn storage_roundtrip_many_lines() {
        let mut st = BankStorage::new([8, 8, 8, 8]);
        let lines: Vec<Vec<f32>> = (0..8)
            .map(|l| {
                vec16(&[(l % 16, l as f32 + 1.0), ((l + 3) % 16, 2.0)])
            })
            .collect();
        for l in &lines {
            let a = st.store(&encode_bank(l).unwrap());
            assert_eq!(a.cycles, 1);
            assert!(!a.truncated);
        }
        for (i, l) in lines.iter().enumerate() {
            let (e, a) = st.load(i).unwrap();
            assert_eq!(a.cycles, 1);
            assert_eq!(decode_bank(&e).to_vec(), *l);
        }
    }

    #[test]
    fn shallow_tail_minibank_truncates_dense_lines() {
        // tail mini-banks sized for sparse traffic; a dense burst truncates
        let mut st = BankStorage::new([4, 1, 1, 1]);
        let dense: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let a1 = st.store(&encode_bank(&dense).unwrap());
        let a2 = st.store(&encode_bank(&dense).unwrap());
        assert!(!a1.truncated);
        assert!(a2.truncated);
    }

    #[test]
    fn depths_from_buckets_monotone() {
        let d = BankStorage::depths_from_buckets([0.25, 0.25, 0.25, 0.25], 64);
        assert_eq!(d[0], 64);
        assert!(d[0] >= d[1] && d[1] >= d[2] && d[2] >= d[3]);
        // all-sparse traffic needs almost no tail storage
        let d2 = BankStorage::depths_from_buckets([1.0, 0.0, 0.0, 0.0], 64);
        assert_eq!(d2[1], 0);
    }

    #[test]
    fn paper_example_storage_reduction() {
        // paper SSV-C: with sparsity quartiles evenly spread, the
        // arrangement saves 37.5% vs full sparse-form storage.
        let lines = 64usize;
        let d = BankStorage::depths_from_buckets([0.25, 0.25, 0.25, 0.25],
                                                 lines);
        let mini_lines: usize = d.iter().sum();
        let full_lines = lines * MINI_PER_BANK;
        let saving = 1.0 - mini_lines as f64 / full_lines as f64;
        // 37.5% nominal minus our 12.5% headroom
        assert!(
            (0.25..0.45).contains(&saving),
            "saving {saving}"
        );
    }

    #[test]
    fn wire_bytes_layout_sanity() {
        // 2 rows of 20 elements: 2 banks per row, tail bank padded
        let mut data = vec![0f32; 40];
        data[0] = 1.0; // row 0, bank 0
        data[17] = 2.0; // row 0, bank 1 (live lane 1)
        data[20] = 3.0; // row 1, bank 0
        let w = wire_bytes(&[2, 20], &data).unwrap();
        assert_eq!(&w[..4], &WIRE_MAGIC);
        assert_eq!(u16::from_le_bytes([w[4], w[5]]), WIRE_VERSION);
        assert_eq!(u16::from_le_bytes([w[6], w[7]]), 2); // rank
        // header 32 + 4 banks * 3 + 3 row offsets * 4 + 3 values * 4
        assert_eq!(w.len(), 32 + 12 + 12 + 12);
        assert_eq!(u32::from_le_bytes([w[8], w[9], w[10], w[11]]), w.len() as u32);
        // bad element count is rejected
        assert!(wire_bytes(&[2, 20], &data[..39]).is_err());
    }

    #[test]
    fn encode_vector_pipeline_cycles() {
        let v = vec![1.0f32; 64]; // 4 banks
        let (banks, cycles) = encode_vector(&v).unwrap();
        assert_eq!(banks.len(), 4);
        assert_eq!(cycles, 7); // B + 3 pipeline fill
        assert!(encode_vector(&vec![0f32; 10]).is_err());
    }
}
