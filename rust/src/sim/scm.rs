//! SCM -- Spatial Conv Module cycle model (paper SSV-A, Fig. 5).
//!
//! The SCM performs the *reorganized* graph + spatial convolution: the
//! feature buffer holds 25-wide lines x kept-channel depth; each line is
//! broadcast to the Mult-PE array (4 DSPs each) against one graph column,
//! producing output channel-first.  Dropped channels never enter the
//! buffer (the dataflow-reorganization skip), so the workload is exactly
//! the pruned MAC count.

use crate::model::{BlockSpec, K_V, NUM_JOINTS};

/// One SCM instance's static configuration.
#[derive(Debug, Clone, Copy)]
pub struct ScmConfig {
    /// Mult-PE count (each contributes 4 DSP MACs/cycle)
    pub pes: usize,
    /// DSPs per Mult-PE (fixed 4 in the paper)
    pub dsp_per_pe: usize,
}

impl Default for ScmConfig {
    fn default() -> Self {
        ScmConfig {
            pes: 8,
            dsp_per_pe: 4,
        }
    }
}

/// Cycle cost of one block's SCM work for one input sample.
#[derive(Debug, Clone, Copy)]
pub struct ScmCycles {
    pub macs: u64,
    pub cycles: u64,
    pub dsp: u32,
}

/// Graph + spatial MACs for one sample (pruned).
pub fn scm_macs(spec: &BlockSpec, t_in: usize, kept_in: usize) -> u64 {
    let v = NUM_JOINTS as u64;
    let graph = (K_V * t_in * kept_in) as u64 * v * v;
    let spatial = (K_V * t_in * kept_in * spec.out_channels) as u64 * v;
    graph + spatial
}

/// Simulate (analytically) the SCM: the dataflow of Fig. 5 keeps every
/// DSP busy on dense compacted work, so cycles = MACs / (PEs x 4), plus a
/// per-row pipeline refill of one cycle per feature-buffer swap.
pub fn scm_cycles(spec: &BlockSpec, t_in: usize, kept_in: usize, cfg: &ScmConfig) -> ScmCycles {
    let macs = scm_macs(spec, t_in, kept_in);
    let lanes = (cfg.pes * cfg.dsp_per_pe) as u64;
    let refill = t_in as u64; // one bubble per tensor row (buffer swap)
    ScmCycles {
        macs,
        cycles: macs.div_ceil(lanes) + refill,
        dsp: (cfg.pes * cfg.dsp_per_pe) as u32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: BlockSpec = BlockSpec {
        in_channels: 64,
        out_channels: 64,
        stride: 1,
    };

    #[test]
    fn macs_scale_with_kept_channels() {
        let dense = scm_macs(&SPEC, 64, 64);
        let half = scm_macs(&SPEC, 64, 32);
        assert_eq!(half * 2, dense);
    }

    #[test]
    fn more_pes_fewer_cycles() {
        let a = scm_cycles(&SPEC, 64, 32, &ScmConfig { pes: 4, dsp_per_pe: 4 });
        let b = scm_cycles(&SPEC, 64, 32, &ScmConfig { pes: 16, dsp_per_pe: 4 });
        assert!(b.cycles < a.cycles);
        assert_eq!(a.macs, b.macs);
    }

    #[test]
    fn utilization_near_one_for_large_work() {
        let cfg = ScmConfig { pes: 8, dsp_per_pe: 4 };
        let c = scm_cycles(&SPEC, 64, 48, &cfg);
        let ideal = c.macs.div_ceil(32);
        let util = ideal as f64 / c.cycles as f64;
        assert!(util > 0.95, "util {util}");
    }
}
