//! TCM -- Temporal Conv Module cycle model (paper SSV-B, Fig. 6).
//!
//! Dyn-Mult-PEs parallelize across temporal filters; each PE owns one
//! sub-filter row (its cavity pattern fixes the kept-weight queue count:
//! 2 or 3 per row of cav-70-1), and dynamic scheduling shares `d < q`
//! DSPs among the queues, exploiting runtime feature sparsity (zero
//! features never enqueue).

use crate::meta::CavityMeta;
use crate::model::BlockSpec;
use crate::model::NUM_JOINTS;
use crate::util::rng::Rng;

use super::dyn_pe::{self, PeStats};

/// TCM configuration for one block.
#[derive(Debug, Clone)]
pub struct TcmConfig {
    /// Dyn-Mult-PE count (filters processed in parallel)
    pub pes: usize,
    /// feature sparsity entering the TCM (from the layer trace)
    pub sparsity: f64,
    /// waiting-queue depth
    pub queue_cap: usize,
}

/// Aggregated TCM simulation result for one block.
#[derive(Debug, Clone)]
pub struct TcmStats {
    /// per-pattern-group PE stats (one Dyn-Mult-PE flavour per row)
    pub per_group: Vec<PeStats>,
    pub total_dsp: u32,
    pub static_dsp: u32,
    pub cycles: u64,
    pub macs: u64,
}

impl TcmStats {
    pub fn efficiency(&self) -> f64 {
        let num: f64 = self.per_group.iter().map(|p| p.macs as f64).sum();
        let den: f64 = self
            .per_group
            .iter()
            .map(|p| (p.cycles * p.dsps as u64) as f64)
            .sum();
        if den > 0.0 {
            num / den
        } else {
            0.0
        }
    }

    pub fn static_efficiency(&self) -> f64 {
        let num: f64 = self.per_group.iter().map(|p| p.macs as f64).sum();
        let den: f64 = self
            .per_group
            .iter()
            .map(|p| (p.static_cycles * p.queues as u64) as f64)
            .sum();
        if den > 0.0 {
            num / den
        } else {
            0.0
        }
    }

    pub fn max_delay(&self) -> f64 {
        self.per_group
            .iter()
            .map(|p| p.delay())
            .fold(0.0, f64::max)
    }
}

/// Total temporal MACs for one sample: `t_out * V * IC * sum_f taps(f)`
/// where IC is the temporal conv's input width (= spatial out channels)
/// and f ranges over the surviving (coarse-kept) filters.
pub fn tcm_macs(
    spec: &BlockSpec,
    t_out: usize,
    kept_filters: usize,
    cavity: &CavityMeta,
) -> u64 {
    let taps: u64 = (0..kept_filters)
        .map(|f| cavity.kept_taps(f).len() as u64)
        .sum();
    (t_out * NUM_JOINTS) as u64 * spec.out_channels as u64 * taps
}

/// Simulate one block's TCM: one Dyn-Mult-PE per distinct cavity row
/// (8 pattern groups), each fed `steps` feature vectors.
pub fn simulate_tcm(
    spec: &BlockSpec,
    t_out: usize,
    kept_filters: usize,
    cavity: &CavityMeta,
    cfg: &TcmConfig,
    rng: &mut Rng,
) -> TcmStats {
    // input positions each filter processes per sample
    let steps = (t_out * NUM_JOINTS) as u64 * spec.out_channels as u64
        / (cfg.pes.max(1) as u64 * 64).max(1); // scaled sample for speed
    let steps = steps.clamp(256, 4096);
    let mut per_group = Vec::new();
    let mut total_dsp = 0u32;
    let mut static_dsp = 0u32;
    let mut macs = 0u64;
    let mut cycles = 0u64;
    for g in 0..8usize.min(kept_filters.max(1)) {
        let q = cavity.kept_taps(g).len().max(1);
        let d = dyn_pe::dsp_allocation(q, cfg.sparsity).min(q);
        let stats = dyn_pe::simulate(q, d, steps, cfg.sparsity,
                                     cfg.queue_cap, rng);
        total_dsp += d as u32;
        static_dsp += q as u32;
        macs += stats.macs;
        cycles = cycles.max(stats.cycles);
        per_group.push(stats);
    }
    // scale DSP totals by the PE count mapped to this block (groups
    // replicate across PEs)
    let reps = (cfg.pes as u32).div_ceil(8).max(1);
    TcmStats {
        per_group,
        total_dsp: total_dsp * reps,
        static_dsp: static_dsp * reps,
        cycles,
        macs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cav70() -> CavityMeta {
        let rows = [
            "100100100", "010010010", "001001001", "111000000",
            "000111000", "100000100", "010100010", "001000001",
        ];
        let mut masks = [[false; 9]; 8];
        for (i, r) in rows.iter().enumerate() {
            for (t, c) in r.chars().enumerate() {
                masks[i][t] = c == '1';
            }
        }
        CavityMeta {
            name: "cav-70-1".into(),
            masks,
        }
    }

    const SPEC: BlockSpec = BlockSpec {
        in_channels: 64,
        out_channels: 64,
        stride: 1,
    };

    #[test]
    fn dynamic_saves_dsps() {
        let mut rng = Rng::new(0);
        let cfg = TcmConfig {
            pes: 8,
            sparsity: 0.5,
            queue_cap: 8,
        };
        let st = simulate_tcm(&SPEC, 64, 48, &cav70(), &cfg, &mut rng);
        assert!(
            st.total_dsp < st.static_dsp,
            "dyn {} vs static {}",
            st.total_dsp,
            st.static_dsp
        );
    }

    #[test]
    fn efficiency_above_static() {
        let mut rng = Rng::new(1);
        let cfg = TcmConfig {
            pes: 8,
            sparsity: 0.5,
            queue_cap: 8,
        };
        let st = simulate_tcm(&SPEC, 64, 48, &cav70(), &cfg, &mut rng);
        assert!(st.efficiency() > st.static_efficiency());
    }

    #[test]
    fn paper_band_efficiency_and_delay() {
        // Table II: total efficiency 75.38%, max delay 6.48%, static 57.86%
        let mut rng = Rng::new(2);
        let cfg = TcmConfig {
            pes: 8,
            sparsity: 0.45,
            queue_cap: 8,
        };
        let st = simulate_tcm(&SPEC, 64, 48, &cav70(), &cfg, &mut rng);
        assert!(
            (0.5..1.0).contains(&st.efficiency()),
            "eff {:.3}",
            st.efficiency()
        );
        assert!(st.max_delay() < 0.3, "delay {:.3}", st.max_delay());
    }

    #[test]
    fn macs_reflect_cavity_keep_ratio() {
        // 64 filters = 8 full loops of the 8-row pattern, 22 taps per loop
        let m = tcm_macs(&SPEC, 64, 64, &cav70());
        assert_eq!(m, (64u64 * 25) * 64 * (22 * 8));
    }
}
