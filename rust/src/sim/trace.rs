//! Trace-driven RFC simulation: stream *real* activation tensors from
//! the runtime through encode -> mini-bank store -> load -> decode and
//! measure occupancy, truncation and cycle costs.  This closes the loop
//! between the functional runtime (Layer 3 executing the AOT model) and
//! the storage architecture (paper SSV-C): the mini-bank sizing derived
//! from offline sparsity must hold up on live tensors.

use anyhow::Result;

use crate::runtime::Tensor;

use super::csc::CscStore;
use super::rfc::{
    decode_bank, encode_bank, BankStorage, BANK_WIDTH, MINI_PER_BANK,
};

/// Outcome of replaying one activation tensor through the RFC path.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub vectors: usize,
    pub banks_per_vector: usize,
    /// lines whose tail mini-bank overflowed (should be ~0 when sized well)
    pub truncated_lines: usize,
    /// all decoded values matched the source exactly
    pub lossless: bool,
    /// provisioned RFC bits vs dense bits
    pub rfc_bits: u64,
    pub dense_bits: u64,
    /// total store+load cycles, RFC vs CSC serial
    pub rfc_cycles: u64,
    pub csc_cycles: u64,
    /// observed mean sparsity of the trace
    pub sparsity: f64,
}

impl TraceReport {
    pub fn saving_vs_dense(&self) -> f64 {
        1.0 - self.rfc_bits as f64 / self.dense_bits.max(1) as f64
    }
}

/// Replay a `(N, T, V, C)` activation tensor: each `(n,t,v)` feature
/// vector is split into 16-wide banks, stored into per-bank mini-bank
/// storage sized from `buckets`, then read back and compared.
pub fn replay(x: &Tensor, buckets: [f64; 4]) -> Result<TraceReport> {
    anyhow::ensure!(
        x.shape.len() >= 2,
        "expected an activation tensor, got {:?}",
        x.shape
    );
    let channels = *x.shape.last().unwrap();
    let banks = channels.div_ceil(BANK_WIDTH);
    let vectors = x.data.len() / channels;

    let depths = BankStorage::depths_from_buckets(buckets, vectors);
    let mut stores: Vec<BankStorage> =
        (0..banks).map(|_| BankStorage::new(depths)).collect();
    let mut csc = CscStore::new(banks * BANK_WIDTH);

    let mut truncated = 0usize;
    let mut rfc_cycles = 0u64;
    let mut csc_cycles = 0u64;
    let mut zeros = 0usize;

    // store pass
    for vec_i in 0..vectors {
        let row = &x.data[vec_i * channels..(vec_i + 1) * channels];
        let mut padded = row.to_vec();
        padded.resize(banks * BANK_WIDTH, 0.0);
        zeros += row.iter().filter(|&&v| v == 0.0).count();
        let mut line_truncated = false;
        for (b, store) in stores.iter_mut().enumerate() {
            let bank = &padded[b * BANK_WIDTH..(b + 1) * BANK_WIDTH];
            let e = encode_bank(bank)?;
            let a = store.store(&e);
            line_truncated |= a.truncated;
        }
        rfc_cycles += banks as u64 + 3; // pipelined encoder, 1-cycle store
        csc_cycles += csc.store(&padded).cycles;
        truncated += usize::from(line_truncated);
    }

    // load + verify pass
    let mut lossless = true;
    for vec_i in 0..vectors {
        let row = &x.data[vec_i * channels..(vec_i + 1) * channels];
        let mut padded = row.to_vec();
        padded.resize(banks * BANK_WIDTH, 0.0);
        let mut decoded = Vec::with_capacity(banks * BANK_WIDTH);
        for store in &stores {
            let (e, _) = store
                .load(vec_i)
                .ok_or_else(|| anyhow::anyhow!("missing line {vec_i}"))?;
            decoded.extend_from_slice(&decode_bank(&e));
        }
        rfc_cycles += 1 + 4; // 1-cycle parallel load + 4-stage decode
        csc_cycles += csc.load(vec_i).unwrap().1.cycles;
        if decoded != padded {
            lossless = false;
        }
    }

    let rfc_bits: u64 = stores
        .iter()
        .map(|s| s.provisioned_bits(vectors))
        .sum();
    let dense_bits =
        (vectors * banks * BANK_WIDTH) as u64 * super::rfc::ELEM_BITS as u64;
    Ok(TraceReport {
        vectors,
        banks_per_vector: banks,
        truncated_lines: truncated,
        lossless,
        rfc_bits,
        dense_bits,
        rfc_cycles,
        csc_cycles,
        sparsity: zeros as f64 / (vectors * channels) as f64,
    })
}

/// Measure the *bank-level* mini-bank-need distribution: fraction of
/// 16-wide banks needing 1, 2, 3, 4 mini-banks (ceil(nnz/4)).  This is
/// the correct sizing input for `replay` -- per-bank nnz fluctuates more
/// than vector-level sparsity (binomial n = 16), so sizing from the
/// vector-level Table III buckets truncates the dense tail.
pub fn measure_bank_buckets(x: &Tensor) -> [f64; 4] {
    let channels = *x.shape.last().unwrap();
    let banks = channels.div_ceil(BANK_WIDTH);
    let vectors = x.data.len() / channels;
    let mut counts = [0usize; 4];
    for i in 0..vectors {
        let row = &x.data[i * channels..(i + 1) * channels];
        let mut padded = row.to_vec();
        padded.resize(banks * BANK_WIDTH, 0.0);
        for b in 0..banks {
            let nnz = padded[b * BANK_WIDTH..(b + 1) * BANK_WIDTH]
                .iter()
                .filter(|&&v| v != 0.0)
                .count();
            let need = nnz.div_ceil(4).max(1); // 1..=4 mini-banks
            counts[need - 1] += 1;
        }
    }
    let n = (vectors * banks).max(1) as f64;
    [
        counts[0] as f64 / n,
        counts[1] as f64 / n,
        counts[2] as f64 / n,
        counts[3] as f64 / n,
    ]
}

/// Measure a tensor's sparsity-bucket distribution (the Table III stat),
/// usable as `replay` sizing input for self-consistent runs.
pub fn measure_buckets(x: &Tensor) -> [f64; 4] {
    let channels = *x.shape.last().unwrap();
    let vectors = x.data.len() / channels;
    let mut counts = [0usize; 4];
    for i in 0..vectors {
        let row = &x.data[i * channels..(i + 1) * channels];
        let s = row.iter().filter(|&&v| v == 0.0).count() as f64
            / channels as f64;
        let b = if s >= 0.75 {
            0
        } else if s >= 0.5 {
            1
        } else if s >= 0.25 {
            2
        } else {
            3
        };
        counts[b] += 1;
    }
    let n = vectors.max(1) as f64;
    [
        counts[0] as f64 / n,
        counts[1] as f64 / n,
        counts[2] as f64 / n,
        counts[3] as f64 / n,
    ]
}

/// Sanity bound used by callers: with `MINI_PER_BANK` mini-banks a line
/// can never need more than all of them.
pub const MAX_MINIBANKS: usize = MINI_PER_BANK;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sparse_tensor(n: usize, c: usize, sparsity: f64, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * c)
            .map(|_| {
                if rng.chance(sparsity) {
                    0.0
                } else {
                    rng.f32() + 0.01
                }
            })
            .collect();
        Tensor::new(vec![n, c], data).unwrap()
    }

    #[test]
    fn replay_is_lossless_with_self_measured_buckets() {
        let x = sparse_tensor(128, 32, 0.55, 1);
        let buckets = measure_bank_buckets(&x);
        let r = replay(&x, buckets).unwrap();
        assert!(r.lossless);
        assert_eq!(r.vectors, 128);
        assert!(r.truncated_lines <= 3, "{} truncations", r.truncated_lines);
    }

    #[test]
    fn rfc_saves_storage_on_sparse_trace() {
        let x = sparse_tensor(256, 64, 0.6, 2);
        let r = replay(&x, measure_bank_buckets(&x)).unwrap();
        assert!(
            r.saving_vs_dense() > 0.15,
            "saving {:.3}",
            r.saving_vs_dense()
        );
    }

    #[test]
    fn rfc_access_cycles_beat_csc() {
        let x = sparse_tensor(128, 64, 0.4, 3);
        let r = replay(&x, measure_bank_buckets(&x)).unwrap();
        assert!(
            r.rfc_cycles < r.csc_cycles,
            "rfc {} vs csc {}",
            r.rfc_cycles,
            r.csc_cycles
        );
    }

    #[test]
    fn measured_buckets_sum_to_one() {
        let x = sparse_tensor(64, 16, 0.5, 4);
        let b = measure_buckets(&x);
        assert!((b.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn undersized_buckets_truncate_but_report() {
        // lie to the sizer: claim everything is ultra-sparse
        let x = sparse_tensor(64, 16, 0.1, 5);
        let r = replay(&x, [1.0, 0.0, 0.0, 0.0]).unwrap();
        assert!(r.truncated_lines > 0);
        assert!(!r.lossless);
    }

    #[test]
    fn sparsity_measured_matches_generator() {
        let x = sparse_tensor(512, 64, 0.5, 6);
        let r = replay(&x, measure_bank_buckets(&x)).unwrap();
        assert!((r.sparsity - 0.5).abs() < 0.05);
    }
}
