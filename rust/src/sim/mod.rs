//! Cycle-level model of the RFC-HyPGCN accelerator (paper SSV):
//!
//! * [`resource`] -- XCKU-115 budgets, BRAM/DSP/LUT accounting;
//! * [`scm`]      -- spatial conv module (Mult-PE array) cycle model;
//! * [`dyn_pe`]   -- Dyn-Mult-PE waiting queues + dynamic DSP scheduling
//!   (eq. 6, Table II);
//! * [`tcm`]      -- temporal conv module built from Dyn-Mult-PEs;
//! * [`rfc`]      -- runtime sparse feature compress: bank encoding,
//!   mini-bank storage, decoding (Fig. 7);
//! * [`formats`]  -- dense/CSC/RFC storage cost models (Fig. 11);
//! * [`pipeline`] -- whole-chip mapping with balanced stage IIs
//!   (Tables IV/V);
//! * [`reports`]  -- text renderers for the paper tables.

pub mod csc;
pub mod dyn_pe;
pub mod formats;
pub mod pipeline;
pub mod reports;
pub mod resource;
pub mod rfc;
pub mod scm;
pub mod tcm;
pub mod trace;
