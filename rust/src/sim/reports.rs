//! Human-readable renderers for the simulator-backed paper artifacts:
//! Table II (Dyn-MultPE), Table IV (resource/perf vs [10]), Fig. 11
//! (storage formats).  Each takes the artifact manifest when available
//! (for measured sparsity distributions) and falls back to the paper's
//! own operating point otherwise.

use crate::baseline::DING;
use crate::meta::{CavityMeta, Manifest};
use crate::model::ModelConfig;
use crate::util::rng::Rng;

use super::dyn_pe;
use super::formats::{compare, LayerTraffic};
use super::pipeline::{map_chip, workloads};
use super::resource::XCKU115;

/// The paper's chosen cavity scheme, used when no manifest is present.
pub fn default_cavity() -> CavityMeta {
    let rows = [
        "100100100", "010010010", "001001001", "111000000",
        "000111000", "100000100", "010100010", "001000001",
    ];
    let mut masks = [[false; 9]; 8];
    for (i, r) in rows.iter().enumerate() {
        for (t, c) in r.chars().enumerate() {
            masks[i][t] = c == '1';
        }
    }
    CavityMeta {
        name: "cav-70-1".into(),
        masks,
    }
}

/// Mean sparsity per block from the manifest trace (tconv outputs feed
/// the next block), or the paper's ~0.5 default.
pub fn block_sparsities(manifest: Option<&Manifest>, n: usize) -> Vec<f64> {
    match manifest {
        Some(m) => (0..n)
            .map(|l| {
                m.sparsity
                    .iter()
                    .find(|s| s.name == format!("b{}.sconv", l + 1))
                    .map(|s| s.mean_sparsity)
                    .unwrap_or(0.5)
            })
            .collect(),
        None => vec![0.5; n],
    }
}

/// Table II: Dyn-MultPE utilization / efficiency / max delay per layer
/// group, with the static (one-DSP-per-queue) comparison row.
pub fn table2(manifest: Option<&Manifest>) -> String {
    let cavity = manifest
        .map(|m| m.cavity.clone())
        .unwrap_or_else(default_cavity);
    let sparsities = block_sparsities(manifest, 10);
    let mut rng = Rng::new(2024);
    let mut out = String::new();
    out.push_str(
        "Table II -- Dyn-MultPE utilization, efficiency, max delay\n",
    );
    out.push_str(
        "layer  queues/PE  dsp/PE  total_dsp  static_dsp  efficiency  static_eff  max_delay\n",
    );
    // group layers like the paper's 4 representative rows: blocks
    // (1..=2), (3..=4), (5..=7), (8..=10)
    let groups: [(usize, std::ops::RangeInclusive<usize>); 4] = [
        (1, 1..=2),
        (2, 3..=4),
        (3, 5..=7),
        (4, 8..=10),
    ];
    let mut tot_macs = 0u64;
    let mut tot_dyn_cost = 0f64;
    let mut tot_static_cost = 0f64;
    let mut tot_dsp = 0u32;
    let mut tot_static_dsp = 0u32;
    let mut worst_delay = 0f64;
    for (gi, range) in groups {
        let s: f64 = range.clone().map(|l| sparsities[l - 1]).sum::<f64>()
            / range.clone().count() as f64;
        // queue counts present in the cavity loop (e.g. 2 and 3 for
        // cav-70-1), simulated per distinct depth
        let mut qs: Vec<usize> =
            (0..8).map(|g| cavity.kept_taps(g).len().max(1)).collect();
        qs.sort_unstable();
        qs.dedup();
        let mut g_macs = 0u64;
        let mut g_dyn_cost = 0f64;
        let mut g_static_cost = 0f64;
        let mut g_dsp = 0u32;
        let mut g_static = 0u32;
        let mut g_delay = 0f64;
        for &q in &qs {
            let d = dyn_pe::dsp_allocation(q, s).min(q);
            let st = dyn_pe::simulate(q, d, 4096, s, 8, &mut rng);
            g_macs += st.macs;
            g_dyn_cost += (st.cycles * st.dsps as u64) as f64;
            g_static_cost += (st.static_cycles * st.queues as u64) as f64;
            g_dsp += d as u32;
            g_static += q as u32;
            g_delay = g_delay.max(st.delay());
        }
        // scale PE counts to the paper's per-layer magnitudes (range sum)
        let reps = range.clone().count() as u32 * 21;
        let dq: Vec<String> = qs
            .iter()
            .map(|&q| {
                format!("{}/{}", dyn_pe::dsp_allocation(q, s).min(q), q)
            })
            .collect();
        out.push_str(&format!(
            "{:5}  {:9?}  {:>6}  {:9}  {:10}  {:9.2}%  {:9.2}%  {:8.2}%\n",
            gi,
            qs,
            dq.join(","),
            g_dsp * reps,
            g_static * reps,
            100.0 * g_macs as f64 / g_dyn_cost,
            100.0 * g_macs as f64 / g_static_cost,
            100.0 * g_delay,
        ));
        tot_macs += g_macs;
        tot_dyn_cost += g_dyn_cost;
        tot_static_cost += g_static_cost;
        tot_dsp += g_dsp * reps;
        tot_static_dsp += g_static * reps;
        worst_delay = worst_delay.max(g_delay);
    }
    out.push_str(&format!(
        "total  ------------------  {:9}  {:10}  {:9.2}%  {:9.2}%  {:8.2}%\n",
        tot_dsp,
        tot_static_dsp,
        100.0 * tot_macs as f64 / tot_dyn_cost,
        100.0 * tot_macs as f64 / tot_static_cost,
        100.0 * worst_delay,
    ));
    out.push_str(&format!(
        "DSP reduction vs static: {:.2}%  (paper: 23.24%)\n",
        100.0 * (1.0 - tot_dsp as f64 / tot_static_dsp as f64)
    ));
    out
}

/// Fig. 11: storage cost of dense / CSC / RFC per traced layer.
pub fn fig11(manifest: Option<&Manifest>) -> String {
    let mut out = String::new();
    out.push_str("Fig. 11 -- storage cost of three data formats\n");
    out.push_str(
        "layer        lines  ch   dense(bits)   csc(bits)    rfc(bits)   rfc_save  dense_br  csc_br  rfc_br\n",
    );
    let traffics: Vec<LayerTraffic> = match manifest {
        Some(m) => m
            .sparsity
            .iter()
            .map(|s| {
                // lines per layer: time * joints of the traced testbed
                let lines = m.seq_len * m.num_joints;
                LayerTraffic {
                    name: s.name.clone(),
                    lines,
                    channels: s.channels,
                    mean_sparsity: s.mean_sparsity,
                    buckets: s.buckets,
                }
            })
            .collect(),
        None => {
            // paper-scale defaults: Table III's quartile mixes
            vec![
                LayerTraffic {
                    name: "11.sconv".into(),
                    lines: 75 * 25,
                    channels: 256,
                    mean_sparsity: 0.55,
                    buckets: [0.0, 0.2935, 0.7064, 0.0001],
                },
                LayerTraffic {
                    name: "11.tconv".into(),
                    lines: 75 * 25,
                    channels: 256,
                    mean_sparsity: 0.62,
                    buckets: [0.0002, 0.9473, 0.0525, 0.0],
                },
                LayerTraffic {
                    name: "12.sconv".into(),
                    lines: 75 * 25,
                    channels: 256,
                    mean_sparsity: 0.42,
                    buckets: [0.0, 0.0073, 0.7579, 0.2348],
                },
                LayerTraffic {
                    name: "12.tconv".into(),
                    lines: 75 * 25,
                    channels: 256,
                    mean_sparsity: 0.52,
                    buckets: [0.0001, 0.3424, 0.6576, 0.0],
                },
            ]
        }
    };
    let mut dense_total = 0u64;
    let mut csc_total = 0u64;
    let mut rfc_total = 0u64;
    let mut dense_br = 0u32;
    let mut csc_br = 0u32;
    let mut rfc_br = 0u32;
    for t in &traffics {
        let row = compare(t);
        dense_total += row.dense.bits;
        csc_total += row.csc.bits;
        rfc_total += row.rfc.bits;
        dense_br += row.dense.bram36;
        csc_br += row.csc.bram36;
        rfc_br += row.rfc.bram36;
        out.push_str(&format!(
            "{:<12} {:5} {:4}  {:12} {:12} {:12}  {:7.2}%  {:8} {:7} {:7}\n",
            row.layer,
            t.lines,
            t.channels,
            row.dense.bits,
            row.csc.bits,
            row.rfc.bits,
            100.0 * (1.0 - row.rfc.bits as f64 / row.dense.bits as f64),
            row.dense.bram36,
            row.csc.bram36,
            row.rfc.bram36,
        ));
    }
    out.push_str(&format!(
        "total: dense={dense_total}b ({dense_br} BRAM)  csc={csc_total}b ({csc_br})  rfc={rfc_total}b ({rfc_br})\n",
    ));
    out.push_str(&format!(
        "RFC reduction vs dense: {:.2}%  (paper: 35.93%); \
         access: RFC load 1 cyc / codec 4 cyc vs CSC serial ~64 cyc\n",
        100.0 * (1.0 - rfc_total as f64 / dense_total as f64)
    ));
    out
}

/// Table IV: our mapped design vs Ding et al. [10].
pub fn table4(manifest: Option<&Manifest>) -> String {
    let cavity = manifest
        .map(|m| m.cavity.clone())
        .unwrap_or_else(default_cavity);
    let cfg = ModelConfig::paper_full();
    let specs = cfg.block_specs();
    // paper-scale pruning summary: drop-1-like ~50% channel drop
    let kept_in: Vec<usize> = specs
        .iter()
        .enumerate()
        .map(|(l, s)| if l == 0 { 3 } else { s.in_channels / 2 })
        .collect();
    let kept_f: Vec<usize> = (0..specs.len())
        .map(|l| {
            if l + 1 < specs.len() {
                kept_in[l + 1]
            } else {
                specs[l].out_channels
            }
        })
        .collect();
    let sparsities = block_sparsities(manifest, specs.len());
    let works = workloads(&cfg, &kept_in, &kept_f, &sparsities);
    let mut rng = Rng::new(7);
    let mut plan = map_chip(&works, &cavity, &XCKU115, 3500, &mut rng);

    // BRAM: RFC inter-layer storage + weight ROMs
    let mut bram = 0u32;
    for (l, s) in specs.iter().enumerate() {
        let t = LayerTraffic {
            name: format!("b{}", l + 1),
            lines: cfg.seq_len_at(l).div_ceil(s.stride) * 25,
            channels: s.out_channels,
            mean_sparsity: sparsities[l],
            buckets: [0.25, 0.25, 0.25, 0.25],
        };
        bram += super::formats::rfc_cost(&t).bram36;
        // weight ROM: pruned parameters at 16 bit
        let params = 3 * kept_in[l] * s.out_channels
            + kept_f[l] * s.out_channels * 3; // avg kept taps ~2.75
        bram += super::resource::bram36_for(params as u64 * 16, 36);
    }
    plan.usage.bram36 = bram;
    plan.usage.lut =
        super::resource::Usage::estimate_lut(plan.usage.dsp, bram);

    let eff = plan.dsp_efficiency();
    let mut out = String::new();
    out.push_str("Table IV -- utilization & performance vs Ding [10]\n");
    out.push_str(
        "design  dsp   bram  lut      dsp_eff(GOP/s/DSP)  peak(GOP/s)  freq    fps\n",
    );
    out.push_str(&format!(
        "ours    {:<5} {:<5} {:<8} {:<19.3} {:<12.1} {:.0}MHz {:.2}\n",
        plan.usage.dsp,
        plan.usage.bram36,
        plan.usage.lut,
        eff,
        plan.effective_gops(),
        plan.clock_hz / 1e6,
        plan.fps(),
    ));
    out.push_str(&format!(
        "[10]    {:<5} {:<5} {:<8} {:<19.3} {:<12.1} {:.0}MHz {:.2}\n",
        DING.dsp,
        DING.bram,
        DING.lut,
        DING.dsp_efficiency(),
        DING.peak_gops,
        DING.frequency_mhz,
        DING.fps,
    ));
    out.push_str(&format!(
        "speedup vs [10]: {:.1}x; dsp-eff improvement: {:.2}%  (paper: 22.9x, 28.93%+)\n",
        plan.fps() / DING.fps,
        100.0 * (eff - DING.dsp_efficiency()) / DING.dsp_efficiency(),
    ));
    out.push_str(&format!(
        "paper's own row: dsp 3544, bram 1806, lut 176776, 0.322 GOP/s/DSP, 1142 GOP/s, 172MHz, 271.25 fps\n",
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_renders_and_reduces_dsps() {
        let s = table2(None);
        assert!(s.contains("total"));
        assert!(s.contains("DSP reduction"));
    }

    #[test]
    fn fig11_renders_with_defaults() {
        let s = fig11(None);
        assert!(s.contains("RFC reduction"));
        assert!(s.contains("11.sconv"));
    }

    #[test]
    fn table4_renders() {
        let s = table4(None);
        assert!(s.contains("ours"));
        assert!(s.contains("[10]"));
    }
}
