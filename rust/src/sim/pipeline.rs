//! Whole-accelerator mapping: ten conv blocks resident on chip, PE counts
//! balanced so every pipeline stage takes similar cycles (paper: "we also
//! adjust the number of temporal convolutional PE to keep balance between
//! pipeline stages"), then fps / GOP/s / resource totals (Table IV/V).

use crate::meta::CavityMeta;
use crate::model::{BlockSpec, ModelConfig};
use crate::util::rng::Rng;

use super::dyn_pe;
use super::resource::{self, Budget, Usage};
use super::scm::{self, ScmConfig};
use super::tcm;

/// Per-block mapping decision + simulated cost.
#[derive(Debug, Clone)]
pub struct StagePlan {
    pub block: usize,
    pub scm_pes: usize,
    pub tcm_pes: usize,
    pub scm_cycles: u64,
    pub tcm_cycles: u64,
    pub dsp: u32,
    pub macs: u64,
}

impl StagePlan {
    /// The stage's initiation interval: SCM and TCM of one block overlap
    /// (Fig. 4), so the block's II is their max.
    pub fn ii(&self) -> u64 {
        self.scm_cycles.max(self.tcm_cycles)
    }
}

/// Full-chip mapping result.
#[derive(Debug, Clone)]
pub struct ChipPlan {
    pub stages: Vec<StagePlan>,
    pub usage: Usage,
    pub clock_hz: f64,
    /// dense-equivalent ops per sample (for effective GOP/s)
    pub dense_flops: f64,
    /// actually-executed (pruned) ops per sample
    pub pruned_flops: f64,
}

impl ChipPlan {
    /// Pipeline initiation interval = slowest stage.
    pub fn ii_cycles(&self) -> u64 {
        self.stages.iter().map(|s| s.ii()).max().unwrap_or(1)
    }

    /// Sustained samples/second once the pipeline is full.
    pub fn fps(&self) -> f64 {
        self.clock_hz / self.ii_cycles() as f64
    }

    /// Executed GOP/s (pruned work actually performed).
    pub fn gops(&self) -> f64 {
        self.fps() * self.pruned_flops / 1e9
    }

    /// Dense-equivalent GOP/s (credit for skipped work, the way
    /// sparse-accelerator papers report "effective" throughput).
    pub fn effective_gops(&self) -> f64 {
        self.fps() * self.dense_flops / 1e9
    }

    pub fn dsp_efficiency(&self) -> f64 {
        resource::dsp_efficiency(self.effective_gops(), self.usage.dsp)
    }
}

/// Inputs the mapper needs per block.
#[derive(Debug, Clone)]
pub struct BlockWorkload {
    pub spec: BlockSpec,
    pub t_in: usize,
    pub kept_in: usize,
    pub kept_filters: usize,
    pub sparsity: f64,
}

/// Derive the per-block workloads from a model config + pruning summary.
pub fn workloads(
    cfg: &ModelConfig,
    kept_in: &[usize],
    kept_filters: &[usize],
    sparsity: &[f64],
) -> Vec<BlockWorkload> {
    cfg.block_specs()
        .iter()
        .enumerate()
        .map(|(l, spec)| BlockWorkload {
            spec: *spec,
            t_in: cfg.seq_len_at(l),
            kept_in: kept_in.get(l).copied().unwrap_or(spec.in_channels),
            kept_filters: kept_filters
                .get(l)
                .copied()
                .unwrap_or(spec.out_channels),
            sparsity: sparsity.get(l).copied().unwrap_or(0.5),
        })
        .collect()
}

/// Map the network onto the chip: allocate PEs per block so stage IIs are
/// balanced under the DSP budget, then simulate.
pub fn map_chip(
    works: &[BlockWorkload],
    cavity: &CavityMeta,
    budget: &Budget,
    dsp_target: u32,
    rng: &mut Rng,
) -> ChipPlan {
    // 1) per-block MAC loads
    let scm_loads: Vec<u64> = works
        .iter()
        .map(|w| scm::scm_macs(&w.spec, w.t_in, w.kept_in))
        .collect();
    let tcm_loads: Vec<u64> = works
        .iter()
        .map(|w| {
            tcm::tcm_macs(
                &w.spec,
                w.t_in.div_ceil(w.spec.stride),
                w.kept_filters,
                cavity,
            )
        })
        .collect();
    let total_load: u64 =
        scm_loads.iter().sum::<u64>() + tcm_loads.iter().sum::<u64>();

    // 2) allocate DSPs proportional to load (balanced II), min 1 PE each
    let mut stages = Vec::new();
    let mut usage = Usage::default();
    let mut dense_flops = 0f64;
    let mut pruned_flops = 0f64;
    for (l, w) in works.iter().enumerate() {
        let share =
            (scm_loads[l] + tcm_loads[l]) as f64 / total_load.max(1) as f64;
        let dsp_block = (share * dsp_target as f64).round() as u32;
        // split block DSPs between SCM and TCM by their loads
        let scm_share = scm_loads[l] as f64
            / (scm_loads[l] + tcm_loads[l]).max(1) as f64;
        let scm_dsp = ((dsp_block as f64 * scm_share) as u32).max(4);
        let scm_pes = (scm_dsp / 4).max(1) as usize;

        // TCM: Dyn-Mult-PEs come in groups of 8 pattern rows; DSPs per
        // group follow eq. 6 for this block's sparsity
        let dsp_per_group: u32 = (0..8)
            .map(|g| {
                let q = cavity.kept_taps(g).len().max(1);
                dyn_pe::dsp_allocation(q, w.sparsity).min(q) as u32
            })
            .sum();
        let tcm_dsp_budget = dsp_block.saturating_sub(scm_pes as u32 * 4);
        let groups = (tcm_dsp_budget / dsp_per_group.max(1)).max(1);
        let tcm_pes = groups as usize * 8;

        let scfg = ScmConfig {
            pes: scm_pes,
            dsp_per_pe: 4,
        };
        let sc = scm::scm_cycles(&w.spec, w.t_in, w.kept_in, &scfg);
        let tcfg = tcm::TcmConfig {
            pes: tcm_pes,
            sparsity: w.sparsity,
            queue_cap: 8,
        };
        let t_out = w.t_in.div_ceil(w.spec.stride);
        let ts = tcm::simulate_tcm(
            &w.spec,
            t_out,
            w.kept_filters,
            cavity,
            &tcfg,
            rng,
        );
        // analytic TCM cycles at this PE count: MACs / (PEs * eff * 1 MAC)
        let eff = ts.efficiency().max(0.05);
        let tcm_lanes =
            (groups * dsp_per_group) as f64 * eff;
        let tcm_cycles =
            (tcm_loads[l] as f64 / tcm_lanes.max(1.0)).ceil() as u64;

        let dsp = scm_pes as u32 * 4 + groups * dsp_per_group;
        usage.add(Usage {
            dsp,
            bram36: 0,
            lut: 0,
        });
        dense_flops += 2.0
            * (scm::scm_macs(&w.spec, w.t_in, w.spec.in_channels) as f64
                + (t_out * 25) as f64
                    * w.spec.out_channels as f64
                    * w.spec.out_channels as f64
                    * 9.0);
        pruned_flops += 2.0 * (scm_loads[l] + tcm_loads[l]) as f64;
        stages.push(StagePlan {
            block: l + 1,
            scm_pes,
            tcm_pes,
            scm_cycles: sc.cycles,
            tcm_cycles,
            dsp,
            macs: scm_loads[l] + tcm_loads[l],
        });
    }
    usage.lut = Usage::estimate_lut(usage.dsp, usage.bram36);
    ChipPlan {
        stages,
        usage,
        clock_hz: budget.clock_hz,
        dense_flops,
        pruned_flops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::resource::XCKU115;

    fn cav70() -> CavityMeta {
        let rows = [
            "100100100", "010010010", "001001001", "111000000",
            "000111000", "100000100", "010100010", "001000001",
        ];
        let mut masks = [[false; 9]; 8];
        for (i, r) in rows.iter().enumerate() {
            for (t, c) in r.chars().enumerate() {
                masks[i][t] = c == '1';
            }
        }
        CavityMeta {
            name: "cav-70-1".into(),
            masks,
        }
    }

    fn paper_works() -> Vec<BlockWorkload> {
        let cfg = ModelConfig::paper_full();
        let specs = cfg.block_specs();
        let kept_in: Vec<usize> = specs
            .iter()
            .enumerate()
            .map(|(l, s)| {
                if l == 0 {
                    3
                } else {
                    s.in_channels / 2
                }
            })
            .collect();
        let kept_f: Vec<usize> = specs
            .iter()
            .enumerate()
            .map(|(l, s)| {
                if l + 1 < specs.len() {
                    specs[l + 1].in_channels / 2
                } else {
                    s.out_channels
                }
            })
            .collect();
        workloads(&cfg, &kept_in, &kept_f, &vec![0.5; 10])
    }

    #[test]
    fn chip_fits_budget() {
        let mut rng = Rng::new(0);
        let plan = map_chip(&paper_works(), &cav70(), &XCKU115, 3500,
                            &mut rng);
        assert!(plan.usage.dsp <= XCKU115.dsp, "dsp {}", plan.usage.dsp);
        assert!(plan.stages.len() == 10);
    }

    #[test]
    fn stages_roughly_balanced() {
        let mut rng = Rng::new(1);
        let plan = map_chip(&paper_works(), &cav70(), &XCKU115, 3500,
                            &mut rng);
        let iis: Vec<u64> = plan.stages.iter().map(|s| s.ii()).collect();
        let max = *iis.iter().max().unwrap() as f64;
        let min = *iis.iter().min().unwrap() as f64;
        assert!(
            max / min < 8.0,
            "stage imbalance {min}..{max}: {iis:?}"
        );
    }

    #[test]
    fn fps_positive_and_finite() {
        let mut rng = Rng::new(2);
        let plan = map_chip(&paper_works(), &cav70(), &XCKU115, 3500,
                            &mut rng);
        assert!(plan.fps() > 1.0);
        assert!(plan.effective_gops() > plan.gops());
    }

    #[test]
    fn more_dsps_more_fps() {
        let mut rng = Rng::new(3);
        let small = map_chip(&paper_works(), &cav70(), &XCKU115, 1000,
                             &mut rng);
        let large = map_chip(&paper_works(), &cav70(), &XCKU115, 3500,
                             &mut rng);
        assert!(large.fps() > small.fps());
    }
}
