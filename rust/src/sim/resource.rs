//! FPGA resource model: XCKU-115 budgets, usage accounting, DSP
//! efficiency -- the accounting behind Table IV.

/// Xilinx Kintex UltraScale XCKU-115 budgets (DSP48E2 slices, BRAM36
/// blocks, LUTs) and the paper's clock.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub dsp: u32,
    pub bram36: u32,
    pub lut: u32,
    pub clock_hz: f64,
}

pub const XCKU115: Budget = Budget {
    dsp: 5520,
    bram36: 2160,
    lut: 663_360,
    clock_hz: 172e6, // the paper's achieved frequency
};

/// Aggregated resource usage of a mapped design.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Usage {
    pub dsp: u32,
    pub bram36: u32,
    pub lut: u32,
}

impl Usage {
    pub fn add(&mut self, other: Usage) {
        self.dsp += other.dsp;
        self.bram36 += other.bram36;
        self.lut += other.lut;
    }

    pub fn fits(&self, budget: &Budget) -> bool {
        self.dsp <= budget.dsp
            && self.bram36 <= budget.bram36
            && self.lut <= budget.lut
    }

    /// Rough LUT estimate from datapath counts: control + muxing per DSP
    /// and per BRAM port, calibrated to the paper's 176,776 LUTs for
    /// 3,544 DSPs + 1,806 BRAMs (~45 LUT/DSP + ~8 LUT/BRAM + fixed).
    pub fn estimate_lut(dsp: u32, bram36: u32) -> u32 {
        10_000 + 45 * dsp + 8 * bram36
    }
}

/// Peak performance of a design: 1 MAC = 2 ops per DSP per cycle.
pub fn peak_gops(dsp_used: u32, clock_hz: f64) -> f64 {
    2.0 * dsp_used as f64 * clock_hz / 1e9
}

/// DSP efficiency in GOP/s/DSP (the paper's comparison metric vs [10]).
pub fn dsp_efficiency(gops: f64, dsp_used: u32) -> f64 {
    if dsp_used == 0 {
        0.0
    } else {
        gops / dsp_used as f64
    }
}

/// BRAM36 blocks needed to hold `bits` with `width`-bit ports.
/// A BRAM36 is 36 kbit; width > 36 requires parallel blocks; depth beyond
/// 1024 x 36 cascades.  This mirrors the "variable grains" the paper
/// exploits in mini-bank sizing.
pub fn bram36_for(bits: u64, width_bits: u32) -> u32 {
    if bits == 0 {
        return 0;
    }
    let width_blocks = width_bits.div_ceil(36).max(1);
    let depth = bits.div_ceil(width_bits as u64); // entries
    let depth_per_block = 36 * 1024 / width_bits.min(36).max(1) as u64;
    let depth_blocks = depth.div_ceil(depth_per_block).max(1) as u32;
    width_blocks * depth_blocks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_matches_paper_headline() {
        // paper: 3544 DSPs @172 MHz -> 1219 GOP/s theoretical; its 1142
        // peak is 93.7% of that.
        let gops = peak_gops(3544, 172e6);
        assert!((gops - 1219.1).abs() < 1.0, "got {gops}");
    }

    #[test]
    fn ding_efficiency_close_to_published() {
        // [10]: 46 GOP/s on 228 DSPs -> 0.202 GOP/s/DSP
        let e = dsp_efficiency(46.0, 228);
        assert!((e - 0.2017).abs() < 1e-3);
    }

    #[test]
    fn usage_fits() {
        let u = Usage {
            dsp: 3544,
            bram36: 1806,
            lut: 176_776,
        };
        assert!(u.fits(&XCKU115));
        let over = Usage {
            dsp: 6000,
            ..u
        };
        assert!(!over.fits(&XCKU115));
    }

    #[test]
    fn bram_accounting() {
        assert_eq!(bram36_for(0, 16), 0);
        // 36 kbit at 16-bit width: one block
        assert_eq!(bram36_for(36 * 1024, 16), 1);
        // 10x that: 10 blocks
        assert_eq!(bram36_for(10 * 36 * 1024, 16), 10);
        // wide port: 64-bit needs 2 width blocks even for small depth
        assert_eq!(bram36_for(1024, 64), 2);
    }

    #[test]
    fn lut_estimate_calibration() {
        let lut = Usage::estimate_lut(3544, 1806);
        // within ~15% of the paper's 176,776
        assert!((lut as f64 - 176_776.0).abs() / 176_776.0 < 0.15,
                "lut estimate {lut}");
    }
}
