//! Serving-side synthetic skeleton stream (mirrors `python/compile/data.py`).
//!
//! The coordinator needs realistic request payloads without touching
//! Python: class-conditioned sinusoidal limb motion over the NTU 25-joint
//! skeleton, shaped `(C=3, T, V=25)` per sample, flattened to the
//! `(N, 3, T, V)` batches the AOT full-model artifacts expect.

use crate::model::NUM_JOINTS;
use crate::runtime::Tensor;
use crate::util::rng::Rng;

/// Five coarse limb groups (0-based NTU joints), matching data.py.
const LIMBS: [&[usize]; 5] = [
    &[4, 5, 6, 7, 21, 22],     // left arm
    &[8, 9, 10, 11, 23, 24],   // right arm
    &[12, 13, 14, 15],         // left leg
    &[16, 17, 18, 19],         // right leg
    &[0, 1, 2, 3, 20],         // torso
];

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct GenConfig {
    pub num_classes: usize,
    pub seq_len: usize,
    pub noise: f64,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            num_classes: 12,
            seq_len: 64,
            noise: 0.02,
        }
    }
}

/// Streaming skeleton-sample generator.
pub struct SkeletonGen {
    cfg: GenConfig,
    rng: Rng,
}

impl SkeletonGen {
    pub fn new(cfg: GenConfig, seed: u64) -> Self {
        SkeletonGen {
            cfg,
            rng: Rng::new(seed),
        }
    }

    /// One sample `(3, T, V)` with its class label.
    pub fn sample(&mut self) -> (Vec<f32>, usize) {
        let t_len = self.cfg.seq_len;
        let label = self.rng.below(self.cfg.num_classes);
        // deterministic per-class program (mirrors data.py's structure)
        let mut prng = Rng::new(1234 + label as u64);
        let limb_a = label % LIMBS.len();
        let limb_b = (label / LIMBS.len() + 1) % LIMBS.len();
        let freq = 0.5 + 0.35 * (label % 5) as f64 + prng.f64() * 0.1;
        let amp = 0.10 + 0.04 * (label % 3) as f64;
        let phase = prng.f64() * std::f64::consts::TAU;
        let axis = [prng.f64(), prng.f64(), prng.f64()];
        let axis_sum: f64 = axis.iter().sum();
        let axis = [axis[0] / axis_sum, axis[1] / axis_sum, axis[2] / axis_sum];

        let mut x = vec![0f32; 3 * t_len * NUM_JOINTS];
        let theta = self.rng.range_f64(-0.4, 0.4);
        let scale = self.rng.range_f64(0.9, 1.1);
        let (cos_t, sin_t) = (theta.cos(), theta.sin());
        for step in 0..t_len {
            let tt = step as f64 / t_len as f64 * std::f64::consts::TAU;
            for j in 0..NUM_JOINTS {
                let mut pos = [0.02 * (j as f64), 0.01 * (j % 7) as f64, 0.0];
                for (li, limb) in [limb_a, limb_b].iter().enumerate() {
                    if let Some(depth) =
                        LIMBS[*limb].iter().position(|&q| q == j)
                    {
                        let a = amp * (1.0 + 0.35 * depth as f64);
                        let wave = a
                            * (freq * tt * t_len as f64 / 16.0
                                + phase
                                + 0.3 * depth as f64
                                + li as f64)
                                .sin();
                        for ax in 0..3 {
                            pos[ax] += axis[ax] * wave;
                        }
                    }
                }
                // global y-rotation + scale + sensor noise
                let rx = scale * (cos_t * pos[0] + sin_t * pos[2]);
                let rz = scale * (-sin_t * pos[0] + cos_t * pos[2]);
                let ry = scale * pos[1];
                let out = [rx, ry, rz];
                for ax in 0..3 {
                    let noisy =
                        out[ax] + self.rng.normal() * self.cfg.noise;
                    x[ax * t_len * NUM_JOINTS + step * NUM_JOINTS + j] =
                        noisy as f32;
                }
            }
        }
        (x, label)
    }

    /// A batch tensor `(n, 3, T, V)` plus labels.
    pub fn batch(&mut self, n: usize) -> (Tensor, Vec<usize>) {
        let t_len = self.cfg.seq_len;
        let mut data = Vec::with_capacity(n * 3 * t_len * NUM_JOINTS);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let (x, y) = self.sample();
            data.extend_from_slice(&x);
            labels.push(y);
        }
        (
            Tensor::new(vec![n, 3, t_len, NUM_JOINTS], data).unwrap(),
            labels,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_shape() {
        let mut g = SkeletonGen::new(GenConfig::default(), 0);
        let (x, y) = g.sample();
        assert_eq!(x.len(), 3 * 64 * 25);
        assert!(y < 12);
    }

    #[test]
    fn batch_shape() {
        let mut g = SkeletonGen::new(GenConfig::default(), 0);
        let (t, labels) = g.batch(4);
        assert_eq!(t.shape, vec![4, 3, 64, 25]);
        assert_eq!(labels.len(), 4);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SkeletonGen::new(GenConfig::default(), 7);
        let mut b = SkeletonGen::new(GenConfig::default(), 7);
        assert_eq!(a.sample().0, b.sample().0);
    }

    #[test]
    fn motion_nontrivial() {
        let mut g = SkeletonGen::new(GenConfig::default(), 1);
        let (x, _) = g.sample();
        let spread = x.iter().fold((f32::MAX, f32::MIN), |(lo, hi), &v| {
            (lo.min(v), hi.max(v))
        });
        assert!(spread.1 - spread.0 > 0.05);
    }
}
