//! Layer-3 coordinator: the serving-side contribution.
//!
//! * [`request`] -- request/response/batch types;
//! * [`batcher`] -- size-or-timeout dynamic batching to the artifacts'
//!   fixed batch shape;
//! * [`pipeline`] -- the layer-pipelined executor over the ten AOT conv
//!   blocks + head (the software mirror of the paper's on-chip pipeline);
//! * [`server`] -- intake/delivery threads wiring it together;
//! * [`metrics`] -- throughput/latency accounting.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use pipeline::{Pipeline, PipelineHandle};
pub use request::{Batch, Request, Response};
pub use router::{RouteInfo, Router, RouterConfig, Variant};
pub use server::Server;
