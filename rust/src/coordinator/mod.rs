//! Layer-3 coordinator: the serving-side contribution.
//!
//! * [`request`] -- request/response/batch types;
//! * [`admission`] -- the bounded front door: load shedding with
//!   retry-after answers, deadline stamping, never-blocking intake
//!   (see `docs/serving-front-door.md`);
//! * [`batcher`] -- size-or-timeout dynamic batching to the artifacts'
//!   fixed batch shape, reaping expired requests at formation;
//! * [`pipeline`] -- the layer-pipelined executor over the ten AOT conv
//!   blocks + head (the software mirror of the paper's on-chip pipeline);
//! * [`server`] -- intake/delivery threads wiring it together;
//! * [`shard`] -- multi-node layer: batches split by row shard, shipped
//!   as RFC wire bytes over [`shard::NodeLink`]s (in-process loopback or
//!   TCP sockets) to per-node stage workers, results reassembled in the
//!   coordinator; links live in supervised slots that route around,
//!   reconnect, and eventually standby-promote dead nodes, and a shard
//!   lost to a link failure is retried on survivors within the batch's
//!   deadline (see `docs/cluster-resilience.md`);
//! * [`node`] -- the worker-node agent serving the far end of a
//!   [`shard::TcpLink`]: handshake, frame-service loop, error-frame
//!   replies;
//! * [`metrics`] -- throughput/latency accounting, including per-node
//!   shard link traffic.

// Defense-in-depth behind `tools/contract_lint`'s `panic` rule: no
// non-test code in this module tree may call `unwrap()`. Test modules are
// exempt (the `not(test)` gate), matching the lint's test-region carve-out.
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

use std::sync::{Mutex, MutexGuard, PoisonError};

pub mod admission;
pub mod batcher;
pub mod metrics;
pub mod node;
pub mod pipeline;
pub mod request;
pub mod router;
pub mod server;
pub mod shard;

pub use admission::{AdmissionGate, AdmissionPolicy};
pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{Metrics, NodeHealth, NodeTransport};
pub use node::{serve_node, spawn_local_agents, NodeAgent};
pub use pipeline::{Pipeline, PipelineHandle};
pub use request::{Batch, Request, Response};
pub use router::{RouteInfo, Router, RouterConfig, Variant};
pub use server::Server;
pub use shard::{
    backoff_delay, dense_entry, LoopbackLink, NodeLink, NodeSpec,
    PayloadShardFn, ReconnectPolicy, RetryPolicy, ShardCluster, ShardFn,
    SlotState, TcpLink,
};

/// Lock a mutex on the serving path, recovering from poisoning instead of
/// propagating the panic. Every coordinator mutex guards state that stays
/// internally consistent under a mid-update panic (counter maps, connection
/// lists -- each update is a single insert/remove/increment), so the data in
/// a poisoned lock is still valid; answering callers beats wedging the
/// server because some *other* thread died while holding the lock.
pub(crate) fn lock_recovered<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}
