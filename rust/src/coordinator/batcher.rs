//! Dynamic batcher: groups incoming requests into fixed-shape batches.
//!
//! The AOT artifacts are compiled for a fixed batch size `n`, so the
//! batcher's policy is: release a batch as soon as `n` requests are
//! waiting, or when the oldest waiting request has been queued for
//! `max_wait` (zero-padding the tail) -- the same size-or-timeout policy
//! vLLM-style routers use, adapted to static shapes.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::NUM_JOINTS;
use crate::runtime::Tensor;

use super::request::{Batch, Request};

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// artifact batch size (rows per executable invocation)
    pub batch_size: usize,
    /// max time the oldest request may wait before a partial batch ships
    pub max_wait: Duration,
    pub seq_len: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            batch_size: 8,
            max_wait: Duration::from_millis(20),
            seq_len: 64,
        }
    }
}

/// Pulls requests off `rx` and forms batches; runs on its own thread.
pub struct Batcher {
    policy: BatchPolicy,
    pending: Vec<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            pending: Vec::new(),
        }
    }

    /// Blocking: returns the next batch, or `None` when the channel closed
    /// and no pending requests remain.
    pub fn next_batch(&mut self, rx: &Receiver<Request>) -> Option<Batch> {
        loop {
            if self.pending.len() >= self.policy.batch_size {
                return Some(self.form());
            }
            let wait = if self.pending.is_empty() {
                // nothing pending: block until a request shows up
                match rx.recv() {
                    Ok(r) => {
                        self.validate(&r);
                        self.pending.push(r);
                        continue;
                    }
                    Err(_) => return None,
                }
            } else {
                let oldest = self.pending[0].arrived;
                let deadline = oldest + self.policy.max_wait;
                deadline.saturating_duration_since(Instant::now())
            };
            if wait.is_zero() {
                return Some(self.form());
            }
            match rx.recv_timeout(wait) {
                Ok(r) => {
                    self.validate(&r);
                    self.pending.push(r);
                }
                Err(RecvTimeoutError::Timeout) => return Some(self.form()),
                Err(RecvTimeoutError::Disconnected) => {
                    return if self.pending.is_empty() {
                        None
                    } else {
                        Some(self.form())
                    };
                }
            }
        }
    }

    fn validate(&self, r: &Request) {
        debug_assert_eq!(
            r.clip.len(),
            3 * self.policy.seq_len * NUM_JOINTS,
            "request {} clip length mismatch",
            r.id
        );
    }

    fn form(&mut self) -> Batch {
        let n = self.policy.batch_size;
        let take = self.pending.len().min(n);
        let requests: Vec<Request> = self.pending.drain(..take).collect();
        let row = 3 * self.policy.seq_len * NUM_JOINTS;
        let mut data = vec![0f32; n * row];
        for (i, r) in requests.iter().enumerate() {
            data[i * row..(i + 1) * row].copy_from_slice(&r.clip);
        }
        Batch {
            real: requests.len(),
            requests,
            input: Tensor::new(
                vec![n, 3, self.policy.seq_len, NUM_JOINTS],
                data,
            )
            .expect("batch shape"),
            formed: Instant::now(),
        }
    }

    /// Build one batch directly from requests (test/bench path).
    pub fn form_from(policy: &BatchPolicy, requests: Vec<Request>) -> Result<Batch> {
        anyhow::ensure!(
            requests.len() <= policy.batch_size,
            "too many requests for one batch"
        );
        let mut b = Batcher::new(policy.clone());
        b.pending = requests;
        Ok(b.form())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, seq_len: usize) -> (Request, Receiver<super::super::request::Response>) {
        let (tx, rx) = channel();
        (
            Request {
                id,
                clip: vec![id as f32; 3 * seq_len * NUM_JOINTS],
                seq_len,
                arrived: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn full_batch_released_immediately() {
        let policy = BatchPolicy {
            batch_size: 2,
            max_wait: Duration::from_secs(10),
            seq_len: 8,
        };
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for i in 0..2 {
            let (r, rr) = req(i, 8);
            keep.push(rr);
            tx.send(r).unwrap();
        }
        let mut b = Batcher::new(policy);
        let start = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert!(start.elapsed() < Duration::from_secs(1));
        assert_eq!(batch.real, 2);
        assert_eq!(batch.input.shape, vec![2, 3, 8, NUM_JOINTS]);
    }

    #[test]
    fn timeout_ships_partial_batch_padded() {
        let policy = BatchPolicy {
            batch_size: 4,
            max_wait: Duration::from_millis(10),
            seq_len: 8,
        };
        let (tx, rx) = channel();
        let (r, _rr) = req(7, 8);
        tx.send(r).unwrap();
        let mut b = Batcher::new(policy);
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.real, 1);
        assert_eq!(batch.input.shape[0], 4); // padded to artifact batch
        let row = 3 * 8 * NUM_JOINTS;
        assert!(batch.input.data[row..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn closed_channel_flushes_then_ends() {
        let policy = BatchPolicy {
            batch_size: 4,
            max_wait: Duration::from_secs(10),
            seq_len: 8,
        };
        let (tx, rx) = channel();
        let (r, _rr) = req(1, 8);
        tx.send(r).unwrap();
        drop(tx);
        let mut b = Batcher::new(policy);
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.real, 1);
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn rows_preserve_request_payloads() {
        let policy = BatchPolicy {
            batch_size: 3,
            max_wait: Duration::from_millis(1),
            seq_len: 4,
        };
        let reqs: Vec<Request> = (0..3)
            .map(|i| {
                let (r, _rx) = req(i, 4);
                r
            })
            .collect();
        let batch = Batcher::form_from(&policy, reqs).unwrap();
        let row = 3 * 4 * NUM_JOINTS;
        for i in 0..3 {
            assert!(batch.input.data[i * row..(i + 1) * row]
                .iter()
                .all(|&v| v == i as f32));
        }
    }
}
