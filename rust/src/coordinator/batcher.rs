//! Dynamic batcher: groups incoming requests into fixed-shape batches.
//!
//! The AOT artifacts are compiled for a fixed batch size `n`, so the
//! batcher's policy is: release a batch as soon as `n` requests are
//! waiting, or when the oldest waiting request has been queued for
//! `max_wait` (zero-padding the tail) -- the same size-or-timeout policy
//! vLLM-style routers use, adapted to static shapes.
//!
//! Batches form **in compressed form** whenever the batch-level gate
//! says it pays: each request row is bank-encoded once straight from
//! its clip buffer (no copy), rows are spliced by zero-copy segment
//! concatenation, and padding rows are sidecar-only
//! [`CompressedTensor::zeros`] -- a short batch never materializes its
//! padding densely.  A full batch of dense clips fails the gate (the
//! sidecars would cost more than they save) and ships dense.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::NUM_JOINTS;
use crate::rfc::{CompressedTensor, Payload, BANK_SIDECAR_BITS};
use crate::runtime::Tensor;
use crate::sim::rfc::{BANK_WIDTH, ELEM_BITS};

use super::admission::respond;
use super::metrics::Metrics;
use super::request::{Batch, Request, Response};

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// artifact batch size (rows per executable invocation)
    pub batch_size: usize,
    /// max time the oldest request may wait before a partial batch ships
    pub max_wait: Duration,
    pub seq_len: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            batch_size: 8,
            max_wait: Duration::from_millis(20),
            seq_len: 64,
        }
    }
}

/// Pulls requests off `rx` and forms batches; runs on its own thread.
pub struct Batcher {
    policy: BatchPolicy,
    encoder: crate::rfc::EncoderConfig,
    pending: Vec<Request>,
    /// serving-path sink for expiry/queue accounting (`None`: the
    /// standalone test/bench batcher records nothing)
    metrics: Option<Arc<Metrics>>,
    /// set by `Server::shutdown` *before* the intake disconnects: drain
    /// everything still queued with shutdown errors instead of serving
    /// (or silently dropping) it
    shutting_down: Option<Arc<AtomicBool>>,
    /// admission queue-residency bound ([`super::admission::AdmissionPolicy::max_queue_wait`]):
    /// a request that waited longer than this is reaped as expired even
    /// if it carries no deadline of its own
    max_queue_wait: Option<Duration>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            encoder: crate::rfc::EncoderConfig::default(),
            pending: Vec::new(),
            metrics: None,
            shutting_down: None,
            max_queue_wait: None,
        }
    }

    /// Use the same RFC transport configuration as the pipeline, so the
    /// `min_sparsity` gate means one thing everywhere
    /// (see [`crate::coordinator::Server::start_with`]).
    pub fn with_encoder(mut self, encoder: crate::rfc::EncoderConfig) -> Self {
        self.encoder = encoder;
        self
    }

    /// Record expiry/failure/queue-depth events against the serving
    /// metrics.
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Observe the server's shutdown flag (see [`Batcher::next_batch`]'s
    /// drain semantics).
    pub fn with_shutdown_flag(mut self, flag: Arc<AtomicBool>) -> Self {
        self.shutting_down = Some(flag);
        self
    }

    /// Enforce the admission queue-residency bound at formation time.
    pub fn with_queue_bound(mut self, max_queue_wait: Duration) -> Self {
        self.max_queue_wait = Some(max_queue_wait);
        self
    }

    /// Blocking: returns the next batch, or `None` when the channel closed
    /// and no pending requests remain.
    ///
    /// Expired requests (absolute deadline passed, or queued longer
    /// than the admission residency bound) are reaped before every
    /// formation and answered with deadline-exceeded responses -- a
    /// formed batch never carries an expired request.  Once the
    /// shutdown flag is up, everything pending or still queued is
    /// answered with shutdown errors and `None` is returned.
    pub fn next_batch(&mut self, rx: &Receiver<Request>) -> Option<Batch> {
        loop {
            if self.draining() {
                return self.drain_shutdown(rx);
            }
            self.reap_expired();
            if self.pending.len() >= self.policy.batch_size {
                return Some(self.form());
            }
            let wait = if self.pending.is_empty() {
                // nothing pending: block until a request shows up
                match rx.recv() {
                    Ok(r) => {
                        self.dequeued();
                        self.admit(r);
                        continue;
                    }
                    Err(_) => return None,
                }
            } else {
                let oldest = self.pending[0].arrived;
                let deadline = oldest + self.policy.max_wait;
                deadline.saturating_duration_since(Instant::now())
            };
            if wait.is_zero() {
                if let Some(b) = self.try_form() {
                    return Some(b);
                }
                continue;
            }
            match rx.recv_timeout(wait) {
                Ok(r) => {
                    self.dequeued();
                    self.admit(r);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if let Some(b) = self.try_form() {
                        return Some(b);
                    }
                    // everything pending expired while we waited: back
                    // to blocking on fresh intake
                }
                Err(RecvTimeoutError::Disconnected) => return self.try_form(),
            }
        }
    }

    /// Reap, then form whatever survived (`None` when expiry emptied
    /// the pending set -- never an all-padding batch).
    fn try_form(&mut self) -> Option<Batch> {
        self.reap_expired();
        if self.pending.is_empty() {
            None
        } else {
            Some(self.form())
        }
    }

    fn draining(&self) -> bool {
        self.shutting_down
            .as_ref()
            .is_some_and(|f| f.load(Ordering::SeqCst))
    }

    /// Shutdown drain: answer everything pending, then everything still
    /// in the intake queue, with shutdown errors.  The server sets the
    /// flag before dropping the gate, so the trailing `recv` loop
    /// terminates on disconnect; requests racing the drain get answered
    /// here or by the gate's disconnected-intake path -- never silently
    /// dropped.
    fn drain_shutdown(&mut self, rx: &Receiver<Request>) -> Option<Batch> {
        for r in std::mem::take(&mut self.pending) {
            self.answer_shutdown(r);
        }
        while let Ok(r) = rx.recv() {
            self.dequeued();
            self.answer_shutdown(r);
        }
        None
    }

    fn answer_shutdown(&self, r: Request) {
        if let Some(m) = &self.metrics {
            m.record_failure();
        }
        respond(
            &r.reply,
            Response::failure(
                r.id,
                "server shutting down: request not served".into(),
                r.arrived,
            ),
            self.metrics.as_deref(),
        );
    }

    /// One request left the bounded intake queue.
    fn dequeued(&self) {
        if let Some(m) = &self.metrics {
            m.record_queue_pop();
        }
    }

    fn is_expired(&self, r: &Request, now: Instant) -> bool {
        if r.deadline.is_some_and(|d| d <= now) {
            return true;
        }
        self.max_queue_wait
            .is_some_and(|w| now.duration_since(r.arrived) > w)
    }

    /// Answer and drop every pending request whose deadline (or queue
    /// residency bound) has passed: an expired request must never
    /// occupy a batch slot.
    fn reap_expired(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let now = Instant::now();
        let mut i = 0;
        while i < self.pending.len() {
            if self.is_expired(&self.pending[i], now) {
                let r = self.pending.remove(i);
                self.answer_expired(r);
            } else {
                i += 1;
            }
        }
    }

    fn answer_expired(&self, r: Request) {
        if let Some(m) = &self.metrics {
            m.record_expired();
            m.record_failure();
        }
        respond(
            &r.reply,
            Response::deadline_exceeded(r.id, r.arrived),
            self.metrics.as_deref(),
        );
    }

    /// Intake gate: a clip that does not match the batch's fixed row
    /// shape is answered with an error [`Response`] and dropped -- it
    /// must never reach [`Batcher::form`], where a wrong-length clip
    /// would panic the batcher thread (`copy_from_slice` on the dense
    /// path, `encode_slice(..).expect(..)` on the compressed path) and
    /// silently wedge the server.  `Server::submit` rejects these
    /// up-front too; this gate keeps the batcher safe against any
    /// direct-intake producer.
    fn admit(&mut self, r: Request) {
        let want = 3 * self.policy.seq_len * NUM_JOINTS;
        if r.clip.len() != want {
            if let Some(m) = &self.metrics {
                m.record_failure();
            }
            respond(
                &r.reply,
                Response::failure(
                    r.id,
                    format!(
                        "malformed clip: {} values, batch row wants {want} \
                         (3 x {} x {NUM_JOINTS})",
                        r.clip.len(),
                        self.policy.seq_len
                    ),
                    r.arrived,
                ),
                self.metrics.as_deref(),
            );
            return;
        }
        // a request that expired while queued is answered here, before
        // it can occupy pending space
        if self.is_expired(&r, Instant::now()) {
            self.answer_expired(r);
            return;
        }
        self.pending.push(r);
    }

    fn form(&mut self) -> Batch {
        let n = self.policy.batch_size;
        let take = self.pending.len().min(n);
        let requests: Vec<Request> = self.pending.drain(..take).collect();
        let row = 3 * self.policy.seq_len * NUM_JOINTS;
        let pad_rows = n - requests.len();
        // cheap pre-gate: under saturating load batches are full of
        // dense coordinate clips, where encoding just to discard it
        // would be pure waste -- a padded batch always goes the
        // compressed route, a full batch only if a strided sample of
        // each clip suggests enough zeros.  The sample is the same
        // rotating-offset sampler `Payload::from_tensor`'s pre-gate
        // uses: clips are (3, T, V) coordinate-major, so a prefix probe
        // would see only x-coordinates of early frames and misjudge
        // sparsity concentrated in later frames or other axes
        let worth_encoding = pad_rows > 0 || {
            let (zeros, sampled) = requests
                .iter()
                .map(|r| crate::rfc::sampled_zeros(&r.clip))
                .fold((0usize, 0usize), |(az, an), (z, n)| (az + z, an + n));
            sampled > 0
                && !crate::rfc::sampled_sparsity_below(
                    zeros,
                    sampled,
                    requests.len() * row,
                    self.encoder.min_sparsity,
                )
        };
        let mut input = None;
        if worth_encoding {
            // encode each request row straight from its clip, one pass
            // per clip and no copy: the encoder counts nonzeros as it
            // packs, so the exact gate below reads wire costs off the
            // parts instead of re-scanning the clips
            let row_shape = vec![1, 3, self.policy.seq_len, NUM_JOINTS];
            let mut parts: Vec<CompressedTensor> =
                Vec::with_capacity(requests.len() + 1);
            for r in &requests {
                parts.push(
                    CompressedTensor::encode_slice(&r.clip, row_shape.clone())
                        // lint: allow(panic): clip.len() == 3*T*V is
                        // enforced at intake (admit) and by form_from's
                        // ensure -- exactly encode_slice's requirement
                        .expect("request clip shape"),
                );
            }
            let compressed_bits: u64 = parts
                .iter()
                .map(|p| p.compressed_bits())
                .sum::<u64>()
                + (pad_rows * row.div_ceil(BANK_WIDTH)) as u64
                    * BANK_SIDECAR_BITS;
            let dense_bits = (n * row) as u64 * ELEM_BITS as u64;
            let nnz: usize = parts.iter().map(|p| p.nnz()).sum();
            let sparsity = 1.0 - nnz as f64 / (n * row) as f64;
            // exact gate, same two-condition rule as Payload::from_tensor
            if sparsity >= self.encoder.min_sparsity
                && compressed_bits < dense_bits
            {
                if pad_rows > 0 {
                    let mut pad_shape = row_shape.clone();
                    pad_shape[0] = pad_rows;
                    parts.push(CompressedTensor::zeros(pad_shape));
                }
                input = Some(Payload::Compressed(
                    CompressedTensor::concat_batch(parts)
                        // lint: allow(panic): every part was encoded with
                        // the identical row_shape above, the only
                        // precondition concat_batch checks
                        .expect("batch concat"),
                ));
            }
        }
        let input = input.unwrap_or_else(|| {
            let mut data = vec![0f32; n * row];
            for (i, r) in requests.iter().enumerate() {
                // lint: allow(index): i < requests.len() <= n and data
                // holds exactly n * row elements, so (i + 1) * row <= len
                data[i * row..(i + 1) * row].copy_from_slice(&r.clip);
            }
            Payload::Dense(
                Tensor::new(vec![n, 3, self.policy.seq_len, NUM_JOINTS], data)
                    // lint: allow(panic): data.len() == n * 3 * T * V by
                    // the vec! above -- Tensor::new's only failure mode
                    .expect("batch shape"),
            )
        });
        Batch {
            real: requests.len(),
            requests,
            input,
            formed: Instant::now(),
        }
    }

    /// Build one batch directly from requests (test/bench path).
    pub fn form_from(policy: &BatchPolicy, requests: Vec<Request>) -> Result<Batch> {
        anyhow::ensure!(
            requests.len() <= policy.batch_size,
            "too many requests for one batch"
        );
        // this path bypasses the intake gate, so enforce its contract
        // here -- form() is allowed to assume exact-length clips
        let want = 3 * policy.seq_len * NUM_JOINTS;
        for r in &requests {
            anyhow::ensure!(
                r.clip.len() == want,
                "request {}: clip has {} values, batch row wants {want}",
                r.id,
                r.clip.len()
            );
        }
        let mut b = Batcher::new(policy.clone());
        b.pending = requests;
        Ok(b.form())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, seq_len: usize) -> (Request, Receiver<super::super::request::Response>) {
        let (tx, rx) = channel();
        (
            Request {
                id,
                clip: vec![id as f32; 3 * seq_len * NUM_JOINTS],
                seq_len,
                arrived: Instant::now(),
                deadline: None,
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn full_batch_released_immediately() {
        let policy = BatchPolicy {
            batch_size: 2,
            max_wait: Duration::from_secs(10),
            seq_len: 8,
        };
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for i in 0..2 {
            let (r, rr) = req(i, 8);
            keep.push(rr);
            tx.send(r).unwrap();
        }
        let mut b = Batcher::new(policy);
        let start = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert!(start.elapsed() < Duration::from_secs(1));
        assert_eq!(batch.real, 2);
        assert_eq!(batch.input.shape().to_vec(), vec![2, 3, 8, NUM_JOINTS]);
    }

    #[test]
    fn timeout_ships_partial_batch_padded() {
        let policy = BatchPolicy {
            batch_size: 4,
            max_wait: Duration::from_millis(10),
            seq_len: 8,
        };
        let (tx, rx) = channel();
        let (r, _rr) = req(7, 8);
        tx.send(r).unwrap();
        let mut b = Batcher::new(policy);
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.real, 1);
        assert_eq!(batch.input.shape()[0], 4); // padded to artifact batch
        let ct = batch
            .input
            .as_compressed()
            .expect("padded batch ships compressed");
        ct.validate().unwrap();
        let dense = ct.to_tensor();
        let row = 3 * 8 * NUM_JOINTS;
        assert!(dense.data[row..].iter().all(|&v| v == 0.0));
        // padding rows are sidecar-only: exactly the one real (all-7.0)
        // row's values are stored, nothing for the 3 padding rows
        assert_eq!(ct.nnz(), row);
    }

    #[test]
    fn closed_channel_flushes_then_ends() {
        let policy = BatchPolicy {
            batch_size: 4,
            max_wait: Duration::from_secs(10),
            seq_len: 8,
        };
        let (tx, rx) = channel();
        let (r, _rr) = req(1, 8);
        tx.send(r).unwrap();
        drop(tx);
        let mut b = Batcher::new(policy);
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.real, 1);
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn rows_preserve_request_payloads() {
        let policy = BatchPolicy {
            batch_size: 3,
            max_wait: Duration::from_millis(1),
            seq_len: 4,
        };
        let reqs: Vec<Request> = (0..3)
            .map(|i| {
                let (r, _rx) = req(i, 4);
                r
            })
            .collect();
        let batch = Batcher::form_from(&policy, reqs).unwrap();
        let dense = batch
            .input
            .to_dense(&crate::rfc::EncoderConfig::default());
        let row = 3 * 4 * NUM_JOINTS;
        for i in 0..3 {
            assert!(dense.data[i * row..(i + 1) * row]
                .iter()
                .all(|&v| v == i as f32));
        }
    }

    #[test]
    fn compressed_batch_beats_dense_transport_when_padded() {
        // one real request in a batch of 8: dense transport would ship
        // 7 rows of zeros; compressed padding is sidecar-only
        let policy = BatchPolicy {
            batch_size: 8,
            max_wait: Duration::from_millis(1),
            seq_len: 8,
        };
        let (r, _rr) = req(1, 8);
        let batch = Batcher::form_from(&policy, vec![r]).unwrap();
        let ct = batch.input.as_compressed().expect("compressed");
        assert!(ct.compression_ratio() > 4.0);
        assert_eq!(ct.shape, vec![8, 3, 8, NUM_JOINTS]);
    }

    #[test]
    fn take_after_dense_batch_leaves_no_padding_sidecar() {
        // Regression: a full dense batch skips encoding and ships dense,
        // but `Payload::take` used to leave a *compressed* placeholder
        // (`CompressedTensor::default()`, the padding-sidecar
        // constructor) in `Batch::input` -- so after the server moved the
        // payload out, the batch read as still carrying a compressed
        // padding sidecar with a phantom row.  The placeholder must be
        // an empty dense tensor instead.
        let policy = BatchPolicy {
            batch_size: 2,
            max_wait: Duration::from_millis(1),
            seq_len: 8,
        };
        let reqs: Vec<Request> = (1..=2)
            .map(|i| {
                let (r, _rx) = req(i, 8);
                std::mem::forget(_rx);
                r
            })
            .collect();
        let mut batch = Batcher::form_from(&policy, reqs).unwrap();
        assert!(!batch.input.is_compressed(), "dense batch skipped encoding");
        let taken = batch.input.take();
        assert_eq!(taken.shape()[0], 2, "the real payload moved out");
        assert!(
            !batch.input.is_compressed(),
            "placeholder resurrects a compressed padding sidecar"
        );
        assert_eq!(batch.input.shape(), &[0]);
        assert_eq!(batch.input.transport_bits(), 0);
    }

    #[test]
    fn malformed_clip_gets_error_response_and_batcher_survives() {
        // Regression: a wrong-length clip used to reach form(), where
        // the dense path's copy_from_slice (or the compressed path's
        // encode_slice().expect()) panicked the batcher thread in
        // release builds -- after which every subsequent request was
        // silently dropped forever.  The intake gate must answer the
        // bad request with an error Response and keep batching.
        let policy = BatchPolicy {
            batch_size: 2,
            max_wait: Duration::from_millis(1),
            seq_len: 8,
        };
        let (tx, rx) = channel();
        let (bad_tx, bad_rx) = channel();
        tx.send(Request {
            id: 99,
            clip: vec![1.0; 17], // nowhere near 3 * 8 * NUM_JOINTS
            seq_len: 8,
            arrived: Instant::now(),
            deadline: None,
            reply: bad_tx,
        })
        .unwrap();
        let (good, good_rx) = req(1, 8);
        tx.send(good).unwrap();
        let mut b = Batcher::new(policy);
        let batch = b.next_batch(&rx).unwrap();
        // the bad clip was answered, not batched
        let resp = bad_rx.try_recv().expect("error response delivered");
        assert!(!resp.is_ok());
        assert!(resp.error.as_deref().unwrap().contains("malformed clip"));
        assert_eq!(resp.id, 99);
        // the good clip still made it into a (padded) batch
        assert_eq!(batch.real, 1);
        assert_eq!(batch.requests[0].id, 1);
        drop(good_rx);
    }

    #[test]
    fn pre_gate_sees_sparsity_beyond_the_clip_prefix() {
        // Regression: the old pre-gate probed only the first
        // min(row, 256) elements of each clip.  Clips are (3, T, V)
        // coordinate-major, so that prefix is x-coordinates of early
        // frames -- a clip that is dense there but sparse elsewhere was
        // wrongly shipped dense.  The strided sampler must see the
        // zeros and let the exact gate compress the batch.
        let policy = BatchPolicy {
            batch_size: 2,
            max_wait: Duration::from_millis(1),
            seq_len: 8,
        };
        let row = 3 * 8 * NUM_JOINTS; // 600 > the old 256-element probe
        let clip: Vec<f32> = (0..row)
            .map(|i| if i < 256 { 1.0 } else { 0.0 })
            .collect();
        assert!(
            (row - 256) as f64 / row as f64 > 0.5,
            "fixture must be mostly sparse overall"
        );
        let reqs: Vec<Request> = (1..=2)
            .map(|i| {
                let (tx, _rx) = channel();
                std::mem::forget(_rx);
                Request {
                    id: i,
                    clip: clip.clone(),
                    seq_len: 8,
                    arrived: Instant::now(),
                    deadline: None,
                    reply: tx,
                }
            })
            .collect();
        // full batch (no padding): the pre-gate alone decides whether
        // the encode is even attempted
        let batch = Batcher::form_from(&policy, reqs).unwrap();
        let ct = batch
            .input
            .as_compressed()
            .expect("prefix-dense clip must still compress");
        ct.validate().unwrap();
        assert_eq!(ct.nnz(), 2 * 256, "exactly the dense prefixes stored");
    }

    #[test]
    fn form_from_rejects_wrong_length_clips() {
        let policy = BatchPolicy {
            batch_size: 2,
            max_wait: Duration::from_millis(1),
            seq_len: 8,
        };
        let (tx, _rx) = channel();
        let bad = Request {
            id: 1,
            clip: vec![0.0; 5],
            seq_len: 8,
            arrived: Instant::now(),
            deadline: None,
            reply: tx,
        };
        assert!(Batcher::form_from(&policy, vec![bad]).is_err());
    }

    #[test]
    fn expired_requests_are_reaped_at_formation_not_batched() {
        // two requests, one with a deadline already in the past: the
        // formed batch must carry only the live one, and the expired
        // one must be answered deadline-exceeded -- never padded into a
        // batch slot
        let policy = BatchPolicy {
            batch_size: 2,
            max_wait: Duration::from_millis(5),
            seq_len: 8,
        };
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = channel();
        let (mut dead, dead_rx) = req(1, 8);
        dead.deadline = Some(Instant::now() - Duration::from_millis(1));
        tx.send(dead).unwrap();
        let (live, _live_rx) = req(2, 8);
        tx.send(live).unwrap();
        let mut b = Batcher::new(policy).with_metrics(metrics.clone());
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.real, 1, "only the live request forms");
        assert_eq!(batch.requests[0].id, 2);
        let resp = dead_rx.try_recv().expect("expired answered at formation");
        assert!(!resp.is_ok());
        assert!(resp.error.as_deref().unwrap().contains("deadline exceeded"));
        assert_eq!(
            metrics.expired.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(
            metrics.failures.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn queue_residency_bound_expires_deadlineless_requests() {
        // no per-request deadline, but the admission residency bound is
        // tiny: a request that sat longer than the bound is reaped even
        // though it never asked for a deadline
        let policy = BatchPolicy {
            batch_size: 4,
            max_wait: Duration::from_millis(1),
            seq_len: 8,
        };
        let (tx, rx) = channel();
        let (mut stale, stale_rx) = req(1, 8);
        stale.arrived = Instant::now() - Duration::from_millis(50);
        tx.send(stale).unwrap();
        let mut b = Batcher::new(policy)
            .with_queue_bound(Duration::from_millis(10));
        // the only pending request expires, so next_batch must not form
        // an all-padding batch from it; close the channel so the call
        // returns None instead of blocking for fresh intake
        drop(tx);
        assert!(b.next_batch(&rx).is_none());
        let resp = stale_rx.try_recv().expect("stale request answered");
        assert!(resp.error.as_deref().unwrap().contains("deadline exceeded"));
    }

    #[test]
    fn shutdown_flag_drains_pending_and_queued_with_errors() {
        let policy = BatchPolicy {
            batch_size: 8,
            max_wait: Duration::from_secs(10),
            seq_len: 8,
        };
        let metrics = Arc::new(Metrics::default());
        let flag = Arc::new(AtomicBool::new(false));
        let (tx, rx) = channel();
        let mut reply_rxs = Vec::new();
        for i in 0..3u64 {
            let (r, rr) = req(i, 8);
            reply_rxs.push(rr);
            tx.send(r).unwrap();
        }
        let mut b = Batcher::new(policy)
            .with_metrics(metrics.clone())
            .with_shutdown_flag(flag.clone());
        // shutdown ordering contract: flag up, then intake disconnects
        flag.store(true, Ordering::SeqCst);
        drop(tx);
        assert!(
            b.next_batch(&rx).is_none(),
            "a draining batcher forms no more batches"
        );
        for rr in &reply_rxs {
            let resp = rr
                .try_recv()
                .expect("every queued request answered, none dropped");
            assert!(!resp.is_ok());
            assert!(
                resp.error.as_deref().unwrap().contains("shutting down"),
                "{:?}",
                resp.error
            );
        }
        assert_eq!(
            metrics.failures.load(std::sync::atomic::Ordering::Relaxed),
            3
        );
    }

    #[test]
    fn full_dense_batch_fails_the_gate_and_ships_dense() {
        // every row nonzero and no padding: sidecars would cost more
        // than they save, so the batch-level gate keeps it dense
        let policy = BatchPolicy {
            batch_size: 2,
            max_wait: Duration::from_millis(1),
            seq_len: 8,
        };
        let reqs: Vec<Request> = (1..=2)
            .map(|i| {
                let (r, _rx) = req(i, 8);
                std::mem::forget(_rx);
                r
            })
            .collect();
        let batch = Batcher::form_from(&policy, reqs).unwrap();
        assert!(batch.input.as_compressed().is_none());
        assert_eq!(batch.input.transport_bits(), batch.input.dense_bits());
        let dense = batch
            .input
            .to_dense(&crate::rfc::EncoderConfig::default());
        let row = 3 * 8 * NUM_JOINTS;
        for i in 0..2 {
            assert!(dense.data[i * row..(i + 1) * row]
                .iter()
                .all(|&v| v == (i + 1) as f32));
        }
    }
}
