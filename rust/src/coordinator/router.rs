//! Variant router: picks which compiled model variant serves a request.
//!
//! The accelerated system ships several executables (pruned, pruned +
//! input-skip, dense fallback); a vLLM-style front door routes each
//! request by its latency budget and clip length.  Policy:
//!
//! * a request whose deadline is tight routes to `Skip` (half the work,
//!   paper SSVI-A: skip keeps accuracy >= the original's);
//! * clips already at half temporal resolution route to `Skip` directly
//!   (the skip artifact's input shape matches them);
//! * requests demanding reference accuracy route to `Dense`;
//! * everything else takes the default `Pruned` path.

use std::time::Duration;

/// Routable model variants (mirrors the AOT artifact set).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Pruned,
    Skip,
    Dense,
}

/// Routing-relevant request attributes.
#[derive(Debug, Clone, Copy)]
pub struct RouteInfo {
    /// frames in the clip
    pub seq_len: usize,
    /// client latency budget, if any.  Beyond variant choice, this
    /// propagates end-to-end: `Server::submit_routed` stamps it on the
    /// request as an absolute deadline, the batcher reaps it once
    /// expired, and delivery refuses to answer past it (see
    /// `docs/serving-front-door.md`).
    pub deadline: Option<Duration>,
    /// client requests reference (unpruned) accuracy
    pub reference_accuracy: bool,
}

impl RouteInfo {
    /// The absolute deadline this request carries through the serving
    /// path, anchored at its arrival instant.
    pub fn absolute_deadline(&self, arrived: std::time::Instant) -> Option<std::time::Instant> {
        self.deadline.map(|d| arrived + d)
    }
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// the full-rate artifact's expected frames
    pub full_seq_len: usize,
    /// deadline below which the skip variant is preferred
    pub tight_deadline: Duration,
    /// shard fan-out floor: a worker shard smaller than this many batch
    /// rows costs more in framing + hand-off than it wins in
    /// parallelism, so [`Router::shards_for`] stops adding nodes below it
    pub min_shard_rows: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            full_seq_len: 64,
            tight_deadline: Duration::from_millis(50),
            min_shard_rows: 2,
        }
    }
}

/// Stateless routing decision + running distribution stats.
#[derive(Debug)]
pub struct Router {
    pub cfg: RouterConfig,
    pub routed: [u64; 3],
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Router {
        Router {
            cfg,
            routed: [0; 3],
        }
    }

    pub fn route(&mut self, info: &RouteInfo) -> Variant {
        let v = self.decide(info);
        self.routed[match v {
            Variant::Pruned => 0,
            Variant::Skip => 1,
            Variant::Dense => 2,
        }] += 1;
        v
    }

    fn decide(&self, info: &RouteInfo) -> Variant {
        if info.reference_accuracy {
            return Variant::Dense;
        }
        if info.seq_len <= self.cfg.full_seq_len / 2 {
            return Variant::Skip;
        }
        if let Some(d) = info.deadline {
            if d <= self.cfg.tight_deadline {
                return Variant::Skip;
            }
        }
        Variant::Pruned
    }

    /// How many of `nodes` worker nodes to fan a `rows`-row batch over
    /// (see [`crate::coordinator::shard::ShardCluster`]): every shard
    /// keeps at least `min_shard_rows` rows, and a batch too small to
    /// split stays on one node.  The serving path passes the cluster's
    /// **live** slot count (`ShardCluster::heal`'s return), so the plan
    /// never budgets shards for nodes that are Down.
    pub fn shards_for(&self, rows: usize, nodes: usize) -> usize {
        (rows / self.cfg.min_shard_rows.max(1)).clamp(1, nodes.max(1))
    }

    /// [`Router::shards_for`] for a possibly-degraded cluster.  When
    /// any slot is Down, shard-level retry is in play (see
    /// `ShardCluster::infer_deadline`): a further link failure
    /// re-dispatches its shard onto a survivor that already has its own
    /// shard in flight, so a retry effectively **halves** the capacity
    /// of whoever absorbs it.  Planning over `ceil(live / 2)` nodes
    /// while degraded leaves the survivors that headroom -- a retried
    /// shard lands on an idle slot instead of serializing behind every
    /// survivor's own work -- at the cost of coarser (cheaper) shards.
    pub fn shards_for_resilient(&self, rows: usize, live: usize, degraded: bool) -> usize {
        let effective = if degraded { live.div_ceil(2).max(1) } else { live };
        self.shards_for(rows, effective)
    }

    /// Fraction routed to each variant (pruned, skip, dense).
    pub fn distribution(&self) -> [f64; 3] {
        let total: u64 = self.routed.iter().sum();
        if total == 0 {
            return [0.0; 3];
        }
        [
            self.routed[0] as f64 / total as f64,
            self.routed[1] as f64 / total as f64,
            self.routed[2] as f64 / total as f64,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(seq: usize, ms: Option<u64>, reference: bool) -> RouteInfo {
        RouteInfo {
            seq_len: seq,
            deadline: ms.map(Duration::from_millis),
            reference_accuracy: reference,
        }
    }

    #[test]
    fn default_path_is_pruned() {
        let mut r = Router::new(RouterConfig::default());
        assert_eq!(r.route(&info(64, None, false)), Variant::Pruned);
    }

    #[test]
    fn tight_deadline_takes_skip() {
        let mut r = Router::new(RouterConfig::default());
        assert_eq!(r.route(&info(64, Some(10), false)), Variant::Skip);
        assert_eq!(r.route(&info(64, Some(500), false)), Variant::Pruned);
    }

    #[test]
    fn half_rate_clips_take_skip() {
        let mut r = Router::new(RouterConfig::default());
        assert_eq!(r.route(&info(32, None, false)), Variant::Skip);
    }

    #[test]
    fn reference_accuracy_wins_over_everything() {
        let mut r = Router::new(RouterConfig::default());
        assert_eq!(r.route(&info(32, Some(1), true)), Variant::Dense);
    }

    #[test]
    fn shard_fanout_respects_row_floor() {
        let r = Router::new(RouterConfig::default()); // min_shard_rows: 2
        assert_eq!(r.shards_for(8, 4), 4);
        assert_eq!(r.shards_for(8, 16), 4, "shards capped by the row floor");
        assert_eq!(r.shards_for(3, 4), 1, "too small to split");
        assert_eq!(r.shards_for(4, 4), 2);
        assert_eq!(r.shards_for(1, 4), 1);
        assert_eq!(r.shards_for(0, 4), 1, "degenerate batch still routes");
        assert_eq!(r.shards_for(100, 0), 1, "no nodes: serve locally");
    }

    #[test]
    fn degraded_fanout_leaves_retry_headroom() {
        let r = Router::new(RouterConfig::default()); // min_shard_rows: 2
        // healthy: identical to shards_for
        assert_eq!(r.shards_for_resilient(16, 4, false), 4);
        // degraded: plan over ceil(live/2) so a retried shard finds an
        // idle survivor
        assert_eq!(r.shards_for_resilient(16, 4, true), 2);
        assert_eq!(r.shards_for_resilient(16, 3, true), 2);
        assert_eq!(r.shards_for_resilient(16, 1, true), 1);
        // row floor still wins over headroom math
        assert_eq!(r.shards_for_resilient(2, 4, true), 1);
        assert_eq!(r.shards_for_resilient(0, 0, true), 1);
    }

    #[test]
    fn absolute_deadline_anchors_at_arrival() {
        let arrived = std::time::Instant::now();
        let with = info(64, Some(30), false);
        assert_eq!(
            with.absolute_deadline(arrived),
            Some(arrived + Duration::from_millis(30))
        );
        assert_eq!(info(64, None, false).absolute_deadline(arrived), None);
    }

    #[test]
    fn distribution_tracks() {
        let mut r = Router::new(RouterConfig::default());
        r.route(&info(64, None, false));
        r.route(&info(64, Some(10), false));
        r.route(&info(64, None, true));
        r.route(&info(64, None, false));
        let d = r.distribution();
        assert!((d[0] - 0.5).abs() < 1e-9);
        assert!((d[1] - 0.25).abs() < 1e-9);
        assert!((d[2] - 0.25).abs() < 1e-9);
    }
}
