//! The layer-pipelined executor: ten conv-block executables + head,
//! chained stage-to-stage -- the software analog of the paper's
//! "all convolutional layers mapped on chip" design.
//!
//! Two execution modes:
//! * [`Pipeline::run_sync`] -- one batch through all stages in the caller's
//!   thread (equivalence tests, simple CLI inference);
//! * [`Pipeline::spawn`]    -- one OS thread per stage connected by
//!   channels, so consecutive batches overlap exactly like the FPGA's
//!   block pipeline; throughput is set by the slowest stage.
//!
//! Between spawned stages a [`Job`] carries an [`rfc::Payload`]: stage
//! outputs are re-encoded into the bank-compressed form when their
//! post-ReLU sparsity clears the gate, and each stage decodes lazily on
//! entry (`Executable::run_payload`) -- the software mirror of the
//! paper's RFC storage sitting between on-chip layers.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::meta::Manifest;
use crate::rfc::{EncoderConfig, Payload};
use crate::runtime::{Engine, Executable, StageEntry, StagePlan, Tensor};

use super::metrics::Metrics;

/// Compiled pipeline stages (10 blocks + head).
pub struct Pipeline {
    pub stages: Vec<Arc<Executable>>,
    pub head: Arc<Executable>,
    pub batch: usize,
    pub seq_len: usize,
    pub num_classes: usize,
    /// Per-stage leading-GEMM plans (indexed like `stages`; the head is
    /// never planned).  A planned stage consumes compressed payloads
    /// through the compressed-domain kernel instead of decoding -- see
    /// [`crate::runtime::StagePlan`] for the contract.
    plans: Vec<Option<Arc<StagePlan>>>,
}

/// A unit of work travelling the pipeline with its provenance.
pub struct Job<Ctx: Send> {
    pub ctx: Ctx,
    pub payload: Payload,
    pub entered: Instant,
}

impl<Ctx: Send> Job<Ctx> {
    /// A job over a dense tensor (tests, direct submission).
    pub fn dense(ctx: Ctx, tensor: Tensor) -> Job<Ctx> {
        Job {
            ctx,
            payload: Payload::Dense(tensor),
            entered: Instant::now(),
        }
    }
}

/// Handle to a spawned pipeline.
pub struct PipelineHandle<Ctx: Send + 'static> {
    pub input: SyncSender<Job<Ctx>>,
    pub output: Receiver<Job<Ctx>>,
    pub threads: Vec<JoinHandle<()>>,
}

impl Pipeline {
    /// Compile every block + the head from the manifest.
    pub fn load(engine: &Engine, manifest: &Manifest) -> Result<Pipeline> {
        let mut stages = Vec::with_capacity(manifest.blocks.len());
        for b in &manifest.blocks {
            stages.push(
                engine
                    .load_hlo(&manifest.hlo_path(&b.hlo))
                    .with_context(|| format!("loading stage {}", b.hlo))?,
            );
        }
        let head = engine.load_hlo(&manifest.hlo_path(&manifest.head.hlo))?;
        Ok(Pipeline {
            stages,
            head,
            batch: manifest.batch,
            seq_len: manifest.seq_len,
            num_classes: manifest.num_classes,
            plans: Vec::new(),
        })
    }

    /// Attach leading-GEMM plans, one slot per stage (missing / `None`
    /// slots keep the decode path).  Stage 1 can never be planned (it
    /// always runs its full executable: it owns the request-layout
    /// transpose), and a plan beyond the stage count has no stage to
    /// run its remainder -- both would leave a remainder executable
    /// running without its GEMM, so they are rejected here instead of
    /// being silently ignored by the execution paths.
    pub fn with_plans(mut self, plans: Vec<Option<StagePlan>>) -> Result<Pipeline> {
        anyhow::ensure!(
            plans.first().map_or(true, Option::is_none),
            "stage 1 cannot take a plan: it always runs its full executable"
        );
        anyhow::ensure!(
            plans
                .iter()
                .enumerate()
                .all(|(i, p)| p.is_none() || i < self.stages.len()),
            "plan attached beyond the {}-stage pipeline",
            self.stages.len()
        );
        self.plans = plans.into_iter().map(|p| p.map(Arc::new)).collect();
        Ok(self)
    }

    /// Attach one stage's plan in place (same index rules as
    /// [`Pipeline::with_plans`]).
    pub fn set_plan(&mut self, stage: usize, plan: StagePlan) -> Result<()> {
        anyhow::ensure!(
            stage > 0,
            "stage 1 cannot take a plan: it always runs its full executable"
        );
        anyhow::ensure!(
            stage < self.stages.len(),
            "stage index {stage} is beyond the {}-stage pipeline",
            self.stages.len()
        );
        if self.plans.len() <= stage {
            self.plans.resize(stage + 1, None);
        }
        self.plans[stage] = Some(Arc::new(plan));
        Ok(())
    }

    pub fn has_plans(&self) -> bool {
        self.plans.iter().any(Option::is_some)
    }

    fn plan(&self, stage: usize) -> Option<Arc<StagePlan>> {
        self.plans.get(stage).cloned().flatten()
    }

    /// Run one `(N, 3, T, V)` batch through all stages synchronously and
    /// return `(N, num_classes)` logits.
    ///
    /// Block artifacts take `(N, T, V, C)` activations; the first stage's
    /// input is produced here by transposing the NCHW-ish request layout
    /// (the full-model artifacts do this inside their HLO instead).
    pub fn run_sync(&self, input: &Tensor) -> Result<Tensor> {
        // a planned pipeline's stage executables are remainders compiled
        // without their leading GEMMs; running them as-is would silently
        // skip those GEMMs (the payload-aware entries apply them)
        anyhow::ensure!(
            !self.has_plans(),
            "pipeline has stage plans (remainder executables): \
             use run_payload_sync, which runs the planned GEMMs"
        );
        // chain XLA literals stage-to-stage: no host Vec materialization
        // between blocks (SSPerf L3: two copies saved per boundary)
        let mut lit = nctv_to_ntvc(input)?.to_literal()?;
        for (i, stage) in self.stages.iter().enumerate() {
            lit = stage
                .run_literal1(&lit)
                .with_context(|| format!("stage {} failed", i + 1))?;
        }
        let out = self.head.run_literal1(&lit).context("head failed")?;
        Tensor::from_literal(&out)
    }

    /// The full stage chain as a row-local shard function: what one
    /// worker node runs on its row shard when the coordinator fans a
    /// batch over a [`super::shard::ShardCluster`].  The hand-off to and
    /// from the worker goes through a [`super::shard::NodeLink`] as
    /// wire-format bytes; *inside* the node the stages chain exactly
    /// like [`Pipeline::run_sync`].
    pub fn shard_fn(self: &Arc<Self>) -> super::shard::ShardFn {
        let pipeline = self.clone();
        Arc::new(move |t: Tensor| pipeline.run_sync(&t))
    }

    /// Payload-consuming variant of [`Pipeline::shard_fn`] for pipelines
    /// with stage plans: the node's stage workers route compressed
    /// payloads through the compressed-domain kernel
    /// ([`Executable::run_payload_planned`]) instead of decoding on
    /// every stage entry.  Stage-entry and gate decisions are recorded
    /// into `metrics` when given.
    pub fn payload_shard_fn(
        self: &Arc<Self>,
        enc: EncoderConfig,
        metrics: Option<Arc<Metrics>>,
    ) -> super::shard::PayloadShardFn {
        let pipeline = self.clone();
        Arc::new(move |p: Payload| {
            pipeline.run_payload_sync(p, &enc, metrics.as_deref())
        })
    }

    /// One transported batch through all stages synchronously, claiming
    /// planned leading-GEMM stages in compressed form.  Stage 1 always
    /// takes the dense entry (it owns the request-layout transpose);
    /// between in-process stages the output is re-encoded only when the
    /// *next* stage has a plan that could consume it -- an encode whose
    /// only consumer is an immediate decode would be pure overhead.
    pub fn run_payload_sync(
        &self,
        payload: Payload,
        enc: &EncoderConfig,
        metrics: Option<&Metrics>,
    ) -> Result<Tensor> {
        let first = self.stages.first().context("pipeline has no stages")?;
        let x = nctv_to_ntvc(&payload.into_dense(enc))?;
        let mut h = first.run1(&[x]).context("stage 1 failed")?;
        for (j, stage) in self.stages.iter().enumerate().skip(1) {
            h = match self.plan(j) {
                // the shape-level claim check runs before the encode:
                // a plan whose geometry can never line up must not cost
                // an encode whose only consumer is an immediate decode
                Some(plan) if plan.claims_dims(&h.shape) => {
                    let p = Payload::from_tensor_metered(
                        h,
                        enc,
                        metrics.map(|m| &m.gate),
                    );
                    let (out, entry) = stage
                        .run_payload_planned(p, enc, Some(&plan))
                        .with_context(|| format!("stage {} failed", j + 1))?;
                    if let Some(m) = metrics {
                        m.record_stage_entry(&entry);
                    }
                    out
                }
                // dense entry: a planned stage still runs its leading
                // GEMM (run_payload_planned applies it densely; a plan
                // that can never match this stage errors there), an
                // unplanned stage runs as compiled -- and the entry is
                // recorded either way, so this path's stage-entry
                // counts line up with the spawned pipeline's
                plan => {
                    let (out, entry) = stage
                        .run_payload_planned(Payload::Dense(h), enc, plan.as_deref())
                        .with_context(|| format!("stage {} failed", j + 1))?;
                    if let Some(m) = metrics {
                        m.record_stage_entry(&entry);
                    }
                    out
                }
            };
        }
        // the spawned pipeline records a head entry too (it receives a
        // payload); count it here so both serving paths report the same
        // decode-elision denominator
        if let Some(m) = metrics {
            m.record_stage_entry(&StageEntry::default());
        }
        self.head.run1(&[h]).context("head failed")
    }

    /// Per-stage wall times for one batch (profiling / Table V shape).
    pub fn time_stages(&self, input: &Tensor) -> Result<Vec<f64>> {
        anyhow::ensure!(
            !self.has_plans(),
            "pipeline has stage plans (remainder executables): \
             stage timings without their leading GEMMs would be wrong"
        );
        let mut times = Vec::with_capacity(self.stages.len() + 1);
        let mut h = nctv_to_ntvc(input)?;
        for stage in &self.stages {
            let t0 = Instant::now();
            h = stage.run1(&[h])?;
            times.push(t0.elapsed().as_secs_f64());
        }
        let t0 = Instant::now();
        let _ = self.head.run1(&[h])?;
        times.push(t0.elapsed().as_secs_f64());
        Ok(times)
    }

    /// Spawn one thread per stage (10 blocks + head = 11 compute stages);
    /// returns the input sender and output receiver.  `depth` bounds
    /// in-flight batches per stage edge (backpressure, mirroring the
    /// bounded inter-layer buffers the RFC storage provides on chip).
    pub fn spawn<Ctx: Send + 'static>(
        self: &Arc<Self>,
        depth: usize,
    ) -> PipelineHandle<Ctx> {
        self.spawn_with(depth, EncoderConfig::default())
    }

    /// [`Pipeline::spawn`] with an explicit RFC transport configuration
    /// (shard count, compression gate).
    pub fn spawn_with<Ctx: Send + 'static>(
        self: &Arc<Self>,
        depth: usize,
        enc: EncoderConfig,
    ) -> PipelineHandle<Ctx> {
        self.spawn_metered(depth, enc, None)
    }

    /// [`Pipeline::spawn_with`] recording stage-entry decisions (decode
    /// elisions, kernel input-skipping, gate rejects) into `metrics` --
    /// what [`super::Server`] passes so its report shows the kernel
    /// counters live.
    pub fn spawn_metered<Ctx: Send + 'static>(
        self: &Arc<Self>,
        depth: usize,
        enc: EncoderConfig,
        metrics: Option<Arc<Metrics>>,
    ) -> PipelineHandle<Ctx> {
        let n_compute = self.stages.len() + 1; // blocks + head
        // channel j feeds compute stage j; stage j writes channel j+1.
        let mut txs: Vec<SyncSender<Job<Ctx>>> = Vec::new();
        let mut rxs: Vec<Option<Receiver<Job<Ctx>>>> = Vec::new();
        for _ in 0..=n_compute {
            let (tx, rx) = sync_channel::<Job<Ctx>>(depth.max(1));
            txs.push(tx);
            rxs.push(Some(rx));
        }
        let input = txs[0].clone();
        // lint: allow(panic): rxs holds exactly n_compute + 1 fresh
        // Some(rx) slots built by the loop above, and each index below is
        // taken exactly once -- spawn-time setup, no request in flight yet
        let output = rxs[n_compute].take().expect("output channel slot");
        let mut threads = Vec::new();
        for j in 0..n_compute {
            // lint: allow(panic): slot j is taken only by iteration j
            let rx = rxs[j].take().expect("stage channel slot");
            // lint: allow(index): txs.len() == n_compute + 1 and
            // j < n_compute, so j + 1 is in bounds
            let tx = txs[j + 1].clone();
            let is_first = j == 0;
            let is_head = j == n_compute - 1;
            let exe = if is_head {
                self.head.clone()
            } else {
                self.stages[j].clone()
            };
            let label = if is_head {
                "head".to_string()
            } else {
                format!("stage {}", j + 1)
            };
            let plan = if is_first || is_head {
                None
            } else {
                self.plan(j)
            };
            let metrics = metrics.clone();
            threads.push(std::thread::spawn(move || {
                for mut job in rx.iter() {
                    // stage entry: planned stages consume the compressed
                    // transport directly (input-skipping GEMM, decode
                    // elided); everything else decodes lazily here
                    let payload = job.payload.take();
                    let result = if is_first {
                        // stage 1 also performs the layout transpose
                        nctv_to_ntvc(&payload.into_dense(&enc))
                            .and_then(|h| exe.run1(&[h]))
                    } else {
                        exe.run_payload_planned(payload, &enc, plan.as_deref())
                            .map(|(h, entry)| {
                                if let Some(m) = &metrics {
                                    m.record_stage_entry(&entry);
                                }
                                h
                            })
                    };
                    match result {
                        Ok(h) => {
                            // stage exit: re-compress for transport; the
                            // head's logits are tiny and stay dense
                            job.payload = if is_head {
                                Payload::Dense(h)
                            } else {
                                Payload::from_tensor_metered(
                                    h,
                                    &enc,
                                    metrics.as_deref().map(|m| &m.gate),
                                )
                            };
                            if tx.send(job).is_err() {
                                break; // downstream gone
                            }
                        }
                        // the job (and its ctx) drops here: on the
                        // serving path that disconnects the batch's
                        // per-request reply channels, so submitters see
                        // the failure instead of hanging (mirrors the
                        // shard-cluster error path).  Raw handle users
                        // counting outputs must not assume one output
                        // per input on error.
                        Err(e) => eprintln!("{label} error: {e:#}"),
                    }
                }
                // rx closed: dropping tx propagates shutdown downstream
            }));
        }
        drop(txs); // keep only the cloned handles owned by threads/input
        PipelineHandle {
            input,
            output,
            threads,
        }
    }
}

/// `(N, 3, T, V)` -> `(N, T, V, 3)` layout change for the block pipeline.
pub fn nctv_to_ntvc(x: &Tensor) -> Result<Tensor> {
    anyhow::ensure!(x.shape.len() == 4, "expected rank-4, got {:?}", x.shape);
    let (n, c, t, v) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = vec![0f32; x.data.len()];
    for ni in 0..n {
        for ci in 0..c {
            for ti in 0..t {
                let src = ((ni * c + ci) * t + ti) * v;
                for vi in 0..v {
                    let dst = ((ni * t + ti) * v + vi) * c + ci;
                    // lint: allow(index): src + vi and dst are mixed-radix
                    // encodings of (ni, ci, ti, vi) over n*c*t*v, each
                    // component strictly below its radix, and out/x.data
                    // both hold exactly n*c*t*v elements
                    out[dst] = x.data[src + vi];
                }
            }
        }
    }
    Tensor::new(vec![n, t, v, c], out)
}

impl<Ctx: Send + 'static> PipelineHandle<Ctx> {
    /// Close the input and join all stage threads.
    pub fn shutdown(self) {
        drop(self.input);
        for t in self.threads {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    use crate::rfc::kernel::{gemm_dense_f32, GemmF32};

    /// Stage 1 of the toy planned pipeline: reshape the transposed
    /// request layout into GEMM rows (what the real stage-1 transpose +
    /// feature flatten amounts to for the plan machinery).
    const STAGE1_HLO: &str = r#"
HloModule pipe_stage1, entry_computation_layout={(f32[1,4,4,4]{3,2,1,0})->(f32[4,16]{1,0})}

ENTRY main {
  x = f32[1,4,4,4]{3,2,1,0} parameter(0)
  r = f32[4,16]{1,0} reshape(x)
  ROOT out = (f32[4,16]{1,0}) tuple(r)
}
"#;

    /// Stage 2 *remainder* (ReLU): per the [`StagePlan`] contract it is
    /// compiled without the leading 16x16 GEMM the plan owns.
    const REMAINDER_HLO: &str = r#"
HloModule pipe_remainder, entry_computation_layout={(f32[4,16]{1,0})->(f32[4,16]{1,0})}

ENTRY main {
  x = f32[4,16]{1,0} parameter(0)
  zero = f32[] constant(0)
  zb = f32[4,16]{1,0} broadcast(zero), dimensions={}
  relu = f32[4,16]{1,0} maximum(x, zb)
  ROOT out = (f32[4,16]{1,0}) tuple(relu)
}
"#;

    /// Head: identity (add 0), so logits equal the stage-2 output.
    const HEAD_HLO: &str = r#"
HloModule pipe_head, entry_computation_layout={(f32[4,16]{1,0})->(f32[4,16]{1,0})}

ENTRY main {
  x = f32[4,16]{1,0} parameter(0)
  zero = f32[] constant(0)
  zb = f32[4,16]{1,0} broadcast(zero), dimensions={}
  s = f32[4,16]{1,0} add(x, zb)
  ROOT out = (f32[4,16]{1,0}) tuple(s)
}
"#;

    /// A two-stage + head pipeline whose stage 2 is a remainder behind a
    /// 16x16 leading-GEMM plan.
    fn planned_pipeline(tag: &str, k: usize) -> (Pipeline, GemmF32) {
        let engine = Engine::cpu().unwrap();
        let load = |name: &str, hlo: &str| {
            let path = std::env::temp_dir().join(format!("rfc_pipe_{tag}_{name}.txt"));
            std::fs::write(&path, hlo).unwrap();
            engine.load_hlo(&path).unwrap()
        };
        let stages = vec![load("s1", STAGE1_HLO), load("s2", REMAINDER_HLO)];
        let head = load("head", HEAD_HLO);
        let w: Vec<f32> = (0..k * 16)
            .map(|i| ((i % 9) as f32 - 4.0) / 4.0)
            .collect();
        let gemm = GemmF32::new(w, k, 16).unwrap();
        let mut p = Pipeline {
            stages,
            head,
            batch: 1,
            seq_len: 4,
            num_classes: 16,
            plans: Vec::new(),
        };
        p.set_plan(1, StagePlan::new(gemm.clone())).unwrap();
        (p, gemm)
    }

    fn enc() -> EncoderConfig {
        EncoderConfig {
            shards: 1,
            min_sparsity: 0.10,
            parallel_threshold: usize::MAX,
        }
    }

    /// relu(x_t . w) for the toy pipeline, computed by hand.
    fn expected_logits(x: &Tensor, gemm: &GemmF32) -> Vec<f32> {
        let x_t = nctv_to_ntvc(x).unwrap();
        gemm_dense_f32(&x_t.data, 4, gemm)
            .into_iter()
            .map(|v| v.max(0.0))
            .collect()
    }

    #[test]
    fn planned_stage_runs_its_gemm_on_dense_gate_rejects() {
        // every element nonzero: the compression gate rejects, so the
        // planned stage sees a *dense* payload -- its leading GEMM must
        // still run before the remainder executable
        let (pipeline, gemm) = planned_pipeline("dense", 16);
        let data: Vec<f32> = (0..64).map(|i| ((i % 7) + 1) as f32).collect();
        let x = Tensor::new(vec![1, 4, 4, 4], data).unwrap();
        let m = Metrics::default();
        let out = pipeline
            .run_payload_sync(Payload::Dense(x.clone()), &enc(), Some(&m))
            .unwrap();
        let expect = expected_logits(&x, &gemm);
        assert_eq!(out.shape, vec![4, 16]);
        for (a, b) in out.data.iter().zip(&expect) {
            assert_eq!(a.to_bits(), b.to_bits(), "dense fallback skipped the GEMM");
        }
        assert_eq!(m.gate.pre_rejects.load(Ordering::Relaxed), 1);
        assert_eq!(m.decodes_elided.load(Ordering::Relaxed), 0);
        // stage 2 (dense entry) + head: both serving paths count these
        assert_eq!(m.decodes.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn planned_stage_claims_compressed_payloads_and_matches_dense() {
        let (pipeline, gemm) = planned_pipeline("sparse", 16);
        let data: Vec<f32> = (0..64)
            .map(|i| if i % 5 == 0 { (i + 1) as f32 } else { 0.0 })
            .collect();
        let x = Tensor::new(vec![1, 4, 4, 4], data).unwrap();
        let m = Metrics::default();
        let out = pipeline
            .run_payload_sync(Payload::Dense(x.clone()), &enc(), Some(&m))
            .unwrap();
        let expect = expected_logits(&x, &gemm);
        for (a, b) in out.data.iter().zip(&expect) {
            assert_eq!(a.to_bits(), b.to_bits(), "kernel path diverged");
        }
        assert_eq!(m.decodes_elided.load(Ordering::Relaxed), 1);
        assert_eq!(m.decodes.load(Ordering::Relaxed), 1, "head entry only");
        assert!(m.kernel_skipped_lanes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn misplaced_plans_are_rejected() {
        // stage 1 never consults a plan and out-of-range slots have no
        // stage: attaching either would silently skip a GEMM, so the
        // attach points refuse them up front
        let (mut p1, gemm) = planned_pipeline("validate", 16);
        assert!(p1.set_plan(0, StagePlan::new(gemm.clone())).is_err());
        assert!(p1.set_plan(5, StagePlan::new(gemm.clone())).is_err());
        assert!(p1.set_plan(1, StagePlan::new(gemm.clone())).is_ok());
        let (p2, _) = planned_pipeline("validate2", 16);
        assert!(p2
            .with_plans(vec![Some(StagePlan::new(gemm.clone())), None])
            .is_err());
        let (p3, _) = planned_pipeline("validate3", 16);
        assert!(p3
            .with_plans(vec![None, None, Some(StagePlan::new(gemm))])
            .is_err());
    }

    #[test]
    fn run_sync_refuses_planned_pipelines() {
        // a planned pipeline's stage executables are remainders: running
        // them through the plan-unaware entries would skip every leading
        // GEMM, so those entries refuse instead
        let (pipeline, _) = planned_pipeline("guard", 16);
        let x = Tensor::zeros(vec![1, 4, 4, 4]);
        assert!(pipeline.run_sync(&x).is_err());
        assert!(pipeline.time_stages(&x).is_err());
    }

    #[test]
    fn plan_that_can_never_match_its_stage_errors_loudly() {
        // k = 8 against a 16-wide stage input: the GEMM cannot apply, and
        // running the remainder without it would be silently wrong
        let (pipeline, _) = planned_pipeline("mismatch", 8);
        let data: Vec<f32> = (0..64).map(|i| (i + 1) as f32).collect();
        let x = Tensor::new(vec![1, 4, 4, 4], data).unwrap();
        let err = pipeline
            .run_payload_sync(Payload::Dense(x), &enc(), None)
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("contraction axis"),
            "expected a configuration error, got: {err:#}"
        );
    }

    #[test]
    fn transpose_layout() {
        // (1, 2, 2, 3): c-major input
        let x = Tensor::new(
            vec![1, 2, 2, 3],
            (0..12).map(|i| i as f32).collect(),
        )
        .unwrap();
        let y = nctv_to_ntvc(&x).unwrap();
        assert_eq!(y.shape, vec![1, 2, 3, 2]);
        // x[n=0, c, t, v] = ((0*2 + c)*2 + t)*3 + v
        // y[n=0, t, v, c] must equal x[0, c, t, v]
        for c in 0..2 {
            for t in 0..2 {
                for v in 0..3 {
                    let xi = (c * 2 + t) * 3 + v;
                    let yi = (t * 3 + v) * 2 + c;
                    assert_eq!(y.data[yi], x.data[xi]);
                }
            }
        }
    }

    #[test]
    fn transpose_rejects_bad_rank() {
        let x = Tensor::zeros(vec![2, 3]);
        assert!(nctv_to_ntvc(&x).is_err());
    }
}
