//! The serving coordinator: request intake -> dynamic batcher -> layer
//! pipeline -> response delivery, all on std threads (no Python, no async
//! runtime dependency).

use std::net::ToSocketAddrs;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::meta::Manifest;
use crate::model::NUM_JOINTS;
use crate::rfc::EncoderConfig;
use crate::runtime::{Engine, Tensor};

use super::admission::{respond, AdmissionGate, AdmissionPolicy};
use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::pipeline::{Job, Pipeline};
use super::request::{Batch, Request, Response};
use super::router::{RouteInfo, Router, RouterConfig};
use super::shard::ShardCluster;

/// Release-mode delivery contract: the logits a batch is sliced from
/// must actually be `(rows >= requests, num_classes)` -- a mis-sized
/// node or stage reply would otherwise slice the wrong rows (or panic)
/// in release builds, where the old `debug_assert` was compiled out.
fn check_logits(logits: &Tensor, requests: usize, num_classes: usize) -> Result<()> {
    ensure!(
        logits.shape.len() == 2 && logits.shape[1] == num_classes,
        "delivery expects (batch, {num_classes}) logits, got {:?}",
        logits.shape
    );
    ensure!(
        logits.shape[0] >= requests,
        "logits carry {} rows for a batch of {requests} requests",
        logits.shape[0]
    );
    Ok(())
}

/// Deliver one batch outcome to its requesters: per-request logits rows
/// on success, an error [`Response`] to every requester on failure --
/// submitters get an answer either way instead of a silently
/// disconnected reply channel.
///
/// A request whose deadline passed while its batch was in flight is
/// recorded expired and answered deadline-exceeded instead of getting a
/// result it stopped waiting for.  Every send goes through
/// [`respond`], so a caller that dropped its receiver lands in the
/// `abandoned` counter instead of passing for a delivery.
fn deliver(batch: Batch, result: Result<Tensor>, num_classes: usize, metrics: &Metrics) {
    let checked = result.and_then(|logits| {
        check_logits(&logits, batch.requests.len(), num_classes)?;
        Ok(logits)
    });
    match checked {
        Ok(logits) => {
            let now = Instant::now();
            for (i, req) in batch.requests.into_iter().enumerate() {
                if req.deadline.is_some_and(|d| d <= now) {
                    metrics.record_expired();
                    metrics.record_failure();
                    respond(
                        &req.reply,
                        Response::deadline_exceeded(req.id, req.arrived),
                        Some(metrics),
                    );
                    continue;
                }
                // lint: allow(index): check_logits above proved shape ==
                // (rows >= requests, num_classes) and Tensor data length
                // is shape product, so (i + 1) * num_classes <= len
                let row = logits.data[i * num_classes..(i + 1) * num_classes]
                    .to_vec();
                let resp = Response::from_logits(req.id, row, req.arrived);
                let latency_s = resp.latency_s;
                if respond(&req.reply, resp, Some(metrics)) {
                    metrics.record_response(latency_s);
                }
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            eprintln!("batch delivery failed: {msg}");
            let now = Instant::now();
            for req in batch.requests {
                metrics.record_failure();
                // a request already past its deadline when the batch
                // failed answers deadline-exceeded -- the truthful
                // outcome its caller is handling (and the reason the
                // cluster refused to keep retrying) -- instead of the
                // batch error
                let resp = if req.deadline.is_some_and(|d| d <= now) {
                    metrics.record_expired();
                    Response::deadline_exceeded(req.id, req.arrived)
                } else {
                    Response::failure(req.id, msg.clone(), req.arrived)
                };
                respond(&req.reply, resp, Some(metrics));
            }
        }
    }
}

/// Handle to a running server.
pub struct Server {
    /// bounded front door: sheds when full, never blocks `submit`
    gate: AdmissionGate,
    pub metrics: Arc<Metrics>,
    pub num_classes: usize,
    seq_len: usize,
    next_id: AtomicU64,
    /// raised by [`Server::shutdown`] *before* the gate drops, so the
    /// batcher drains the intake with shutdown errors instead of
    /// serving (or dropping) what's still queued
    shutting_down: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start the full coordinator over compiled pipeline stages.
    pub fn start(
        engine: &Engine,
        manifest: &Manifest,
        policy: BatchPolicy,
    ) -> Result<Server> {
        Self::start_with(engine, manifest, policy, EncoderConfig::default())
    }

    /// [`Server::start`] with an explicit RFC transport configuration,
    /// applied uniformly to the batcher's gate and every pipeline stage.
    pub fn start_with(
        engine: &Engine,
        manifest: &Manifest,
        policy: BatchPolicy,
        enc: EncoderConfig,
    ) -> Result<Server> {
        Self::start_planned(engine, manifest, policy, enc, Vec::new())
    }

    /// [`Server::start_with`] with per-stage leading-GEMM plans: planned
    /// stages consume compressed payloads through the compressed-domain
    /// kernel (decode elided; see [`crate::runtime::StagePlan`]), and
    /// the kernel / gate counters land in [`Server::metrics`].
    /// Admission runs with [`AdmissionPolicy::default`] (deep queue, no
    /// implicit deadline); use [`Server::start_planned_admitted`] to set
    /// an explicit front-door policy.
    pub fn start_planned(
        engine: &Engine,
        manifest: &Manifest,
        policy: BatchPolicy,
        enc: EncoderConfig,
        plans: Vec<Option<crate::runtime::StagePlan>>,
    ) -> Result<Server> {
        Self::start_planned_admitted(
            engine,
            manifest,
            policy,
            AdmissionPolicy::default(),
            enc,
            plans,
        )
    }

    /// [`Server::start_planned`] behind an explicit admission policy:
    /// the bounded front door (shed/deadline semantics in
    /// `docs/serving-front-door.md`) guards the local pipeline path.
    pub fn start_planned_admitted(
        engine: &Engine,
        manifest: &Manifest,
        policy: BatchPolicy,
        admission: AdmissionPolicy,
        enc: EncoderConfig,
        plans: Vec<Option<crate::runtime::StagePlan>>,
    ) -> Result<Server> {
        let pipeline =
            Arc::new(Pipeline::load(engine, manifest)?.with_plans(plans)?);
        let metrics = Arc::new(Metrics::default());
        let (gate, submit_rx, shutting_down) =
            AdmissionGate::new(admission, metrics.clone());
        let max_queue_wait = gate.max_queue_wait();
        let handle = pipeline.spawn_metered::<Batch>(2, enc, Some(metrics.clone()));
        let mut threads = Vec::new();

        // batcher thread: requests -> padded fixed-shape batches formed
        // in compressed form; the payload moves out of the batch (no
        // dense materialization, no copy)
        {
            let metrics = metrics.clone();
            let pipe_in = handle.input.clone();
            let policy = policy.clone();
            let flag = shutting_down.clone();
            let num_classes = manifest.num_classes;
            threads.push(std::thread::spawn(move || {
                let mut batcher = Batcher::new(policy)
                    .with_encoder(enc)
                    .with_metrics(metrics.clone())
                    .with_shutdown_flag(flag)
                    .with_queue_bound(max_queue_wait);
                while let Some(mut batch) = batcher.next_batch(&submit_rx) {
                    metrics.record_batch(batch.real, batch.input.shape()[0]);
                    metrics.record_transport(
                        batch.input.transport_bits(),
                        batch.input.dense_bits(),
                    );
                    let payload = batch.input.take();
                    let job = Job {
                        ctx: batch,
                        payload,
                        entered: Instant::now(),
                    };
                    if let Err(send_failed) = pipe_in.send(job) {
                        // the pipeline input closed under us (stage
                        // thread died): the send gives the job back --
                        // answer its batch with error responses instead
                        // of silently dropping every reply channel
                        let job = send_failed.0;
                        deliver(
                            job.ctx,
                            Err(anyhow::anyhow!(
                                "pipeline input closed: stage threads gone"
                            )),
                            num_classes,
                            &metrics,
                        );
                        break;
                    }
                }
                // dropping pipe_in shuts the pipeline down
            }));
        }

        // delivery thread: pipeline output -> per-request responses
        // (a mis-shaped stage output fails the batch with error
        // responses instead of slicing wrong rows)
        {
            let metrics = metrics.clone();
            let out = handle.output;
            let num_classes = manifest.num_classes;
            threads.push(std::thread::spawn(move || {
                for job in out.iter() {
                    let batch: Batch = job.ctx;
                    let logits = job.payload.into_dense(&enc);
                    deliver(batch, Ok(logits), num_classes, &metrics);
                }
            }));
        }

        // keep the stage threads joinable through the server handle
        threads.extend(handle.threads);
        let _ = handle.input; // dropped here; batcher holds its own clone

        Ok(Server {
            gate,
            metrics,
            num_classes: manifest.num_classes,
            seq_len: manifest.seq_len,
            next_id: AtomicU64::new(0),
            shutting_down,
            threads,
        })
    }

    /// Start the coordinator with the stage chain sharded over `nodes`
    /// loopback worker nodes instead of the in-process stage pipeline:
    /// each batch is split by rows, every shard ships its RFC wire bytes
    /// over a [`super::shard::NodeLink`], the workers run the full stage
    /// chain on their shard, and the coordinator reassembles the logits
    /// before delivery.  Per-batch fan-out follows
    /// [`Router::shards_for`] (tiny padded batches stay on one node);
    /// per-node link traffic lands in [`Metrics::node_transport`].
    pub fn start_sharded(
        engine: &Engine,
        manifest: &Manifest,
        policy: BatchPolicy,
        enc: EncoderConfig,
        nodes: usize,
    ) -> Result<Server> {
        Self::start_sharded_planned(engine, manifest, policy, enc, nodes, Vec::new())
    }

    /// [`Server::start_sharded`] with per-stage leading-GEMM plans: the
    /// node workers route through
    /// [`Pipeline::payload_shard_fn`], so planned stages consume their
    /// compressed shards without the node-boundary decode.
    pub fn start_sharded_planned(
        engine: &Engine,
        manifest: &Manifest,
        policy: BatchPolicy,
        enc: EncoderConfig,
        nodes: usize,
        plans: Vec<Option<crate::runtime::StagePlan>>,
    ) -> Result<Server> {
        let pipeline =
            Arc::new(Pipeline::load(engine, manifest)?.with_plans(plans)?);
        let metrics = Arc::new(Metrics::default());
        let compute = if pipeline.has_plans() {
            pipeline.payload_shard_fn(enc, Some(metrics.clone()))
        } else {
            super::shard::dense_entry(pipeline.shard_fn(), enc)
        };
        let cluster = ShardCluster::loopback_payload(nodes, compute, enc);
        Ok(Self::start_cluster_with_metrics(
            policy,
            AdmissionPolicy::default(),
            enc,
            cluster,
            manifest.num_classes,
            manifest.seq_len,
            metrics,
        ))
    }

    /// Start the coordinator over a **pre-built** shard cluster --
    /// loopback workers, TCP links to remote node agents
    /// ([`ShardCluster::connect`]), or any mix.  The nodes own the
    /// model, so the coordinator needs no engine or artifacts here;
    /// `num_classes` is the delivery contract the node replies are
    /// checked against, and the batch shape follows `policy`.
    pub fn start_cluster(
        policy: BatchPolicy,
        enc: EncoderConfig,
        cluster: ShardCluster,
        num_classes: usize,
    ) -> Server {
        Self::start_cluster_admitted(
            policy,
            AdmissionPolicy::default(),
            enc,
            cluster,
            num_classes,
        )
    }

    /// [`Server::start_cluster`] behind an explicit admission policy:
    /// the bounded front door guards the sharded-cluster path exactly
    /// like the local pipeline path.
    pub fn start_cluster_admitted(
        policy: BatchPolicy,
        admission: AdmissionPolicy,
        enc: EncoderConfig,
        cluster: ShardCluster,
        num_classes: usize,
    ) -> Server {
        let seq_len = policy.seq_len;
        Self::start_cluster_with_metrics(
            policy,
            admission,
            enc,
            cluster,
            num_classes,
            seq_len,
            Arc::new(Metrics::default()),
        )
    }

    /// [`Server::start_cluster`] over TCP node agents at `addrs`
    /// (connects one [`super::shard::TcpLink`] per address, with the
    /// version handshake): the shard cluster spans real machines.
    /// Links carry [`super::shard::DEFAULT_NODE_IO_TIMEOUT`], so a
    /// silently-partitioned peer fails its batch instead of wedging the
    /// coordinator thread forever.
    pub fn connect_sharded<A: ToSocketAddrs>(
        addrs: &[A],
        policy: BatchPolicy,
        enc: EncoderConfig,
        num_classes: usize,
    ) -> Result<Server> {
        Self::connect_sharded_admitted(
            addrs,
            policy,
            AdmissionPolicy::default(),
            enc,
            num_classes,
        )
    }

    /// [`Server::connect_sharded`] behind an explicit admission policy.
    pub fn connect_sharded_admitted<A: ToSocketAddrs>(
        addrs: &[A],
        policy: BatchPolicy,
        admission: AdmissionPolicy,
        enc: EncoderConfig,
        num_classes: usize,
    ) -> Result<Server> {
        let cluster = ShardCluster::connect_timeout(
            addrs,
            enc,
            Some(super::shard::DEFAULT_NODE_IO_TIMEOUT),
        )?;
        Ok(Self::start_cluster_admitted(
            policy, admission, enc, cluster, num_classes,
        ))
    }

    #[allow(clippy::too_many_arguments)]
    fn start_cluster_with_metrics(
        policy: BatchPolicy,
        admission: AdmissionPolicy,
        enc: EncoderConfig,
        mut cluster: ShardCluster,
        num_classes: usize,
        seq_len: usize,
        metrics: Arc<Metrics>,
    ) -> Server {
        let (gate, submit_rx, shutting_down) =
            AdmissionGate::new(admission, metrics.clone());
        let max_queue_wait = gate.max_queue_wait();
        let mut threads = Vec::new();

        // one coordinator thread: batches form, fan out over the node
        // links (the links themselves run concurrently), reassemble,
        // deliver.  Within-batch parallelism comes from the nodes.
        {
            let metrics = metrics.clone();
            let policy = policy.clone();
            let flag = shutting_down.clone();
            threads.push(std::thread::spawn(move || {
                let mut batcher = Batcher::new(policy)
                    .with_encoder(enc)
                    .with_metrics(metrics.clone())
                    .with_shutdown_flag(flag)
                    .with_queue_bound(max_queue_wait);
                let router = Router::new(RouterConfig::default());
                cluster.publish_health(&metrics);
                while let Some(mut batch) = batcher.next_batch(&submit_rx) {
                    metrics.record_batch(batch.real, batch.input.shape()[0]);
                    metrics.record_transport(
                        batch.input.transport_bits(),
                        batch.input.dense_bits(),
                    );
                    let payload = batch.input.take();
                    // reconnect pass first (bounded; backoff-gated), so
                    // the fan-out is planned over the slots that are
                    // actually live -- a Down node costs shards, not
                    // failed batches
                    let live = cluster.heal(Some(&metrics));
                    // real rows drive the fan-out: padding rows are
                    // sidecar-only and not worth extra shard frames.  A
                    // degraded cluster plans coarser shards so a
                    // retried one lands on an idle survivor
                    let fan = router.shards_for_resilient(
                        batch.real,
                        live,
                        cluster.is_degraded(),
                    );
                    // the batch's earliest request deadline bounds the
                    // per-shard recv waits and every retry dispatch
                    let deadline =
                        batch.requests.iter().filter_map(|r| r.deadline).min();
                    let result = cluster.infer_deadline(
                        fan,
                        &payload,
                        deadline,
                        Some(&metrics),
                    );
                    // a shard lost to a node death was re-dispatched
                    // onto survivors inside infer_deadline; a batch
                    // that still failed (no survivors, deadline, app
                    // error) answers every requester with an error
                    // response.  The cluster drained its live links
                    // after every attempt, so the next batch starts
                    // clean either way.
                    deliver(batch, result, num_classes, &metrics);
                }
                cluster.shutdown();
            }));
        }

        Server {
            gate,
            metrics,
            num_classes,
            seq_len,
            next_id: AtomicU64::new(0),
            shutting_down,
            threads,
        }
    }

    /// Submit one clip `(3, T, V)`; returns a receiver for the response.
    ///
    /// Never blocks: the bounded admission gate answers immediately
    /// with a shed [`Response`] (carrying `retry_after`) when the
    /// intake queue is full.  A clip whose length does not match the
    /// model's `3 * T * V` frame contract is answered immediately with
    /// an error [`Response`] -- it never reaches the batcher, so one
    /// malformed submission cannot poison a batch or (as it once did,
    /// via a release-mode `copy_from_slice` panic) wedge the whole
    /// server.  The request carries the admission policy's default
    /// deadline, if any; use [`Server::submit_routed`] for a
    /// per-request budget.
    pub fn submit(&self, clip: Vec<f32>) -> Receiver<Response> {
        self.submit_with_deadline(clip, None)
    }

    /// [`Server::submit`] with routing attributes: the caller's latency
    /// budget ([`RouteInfo::deadline`]) becomes the request's absolute
    /// deadline, enforced at batch formation and delivery.
    pub fn submit_routed(&self, clip: Vec<f32>, info: &RouteInfo) -> Receiver<Response> {
        self.submit_with_deadline(clip, info.deadline)
    }

    /// Submit with an explicit relative deadline (`None`: the admission
    /// policy's default applies).
    pub fn submit_with_deadline(
        &self,
        clip: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Receiver<Response> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.record_request();
        let arrived = Instant::now();
        let want = 3 * self.seq_len * NUM_JOINTS;
        if clip.len() != want {
            self.metrics.record_failure();
            // respond(), not a discarded send: the caller holds `rx`
            // right here so the send cannot fail today, but routing it
            // through respond() keeps the abandoned-caller accounting
            // uniform if this path ever answers asynchronously
            respond(
                &tx,
                Response::failure(
                    id,
                    format!(
                        "malformed clip: {} values, model wants {want} \
                         (3 x {} x {NUM_JOINTS})",
                        clip.len(),
                        self.seq_len
                    ),
                    arrived,
                ),
                Some(&*self.metrics),
            );
            return rx;
        }
        let req = Request {
            id,
            clip,
            seq_len: self.seq_len,
            arrived,
            deadline: deadline.map(|d| arrived + d),
            reply: tx,
        };
        // the gate answers every non-admitted request itself (shed with
        // retry_after on a full queue, intake-closed on a dead batcher
        // racing shutdown) -- a submit never blocks and never leaves
        // the caller hanging on `rx.recv()`
        self.gate.offer(req);
        rx
    }

    /// Stop accepting requests, drain in-flight work, join all threads.
    ///
    /// Ordering contract: the shutdown flag goes up *before* the gate
    /// drops, so the batcher sees the flag and answers everything still
    /// queued with shutdown errors (then the disconnect ends its drain
    /// loop) -- an overloaded server shuts down without silently
    /// dropping a single queued reply channel.
    pub fn shutdown(self) {
        self.shutting_down.store(true, Ordering::SeqCst);
        drop(self.gate);
        for t in self.threads {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn bare_server(seq_len: usize) -> (Server, Receiver<Request>) {
        let metrics = Arc::new(Metrics::default());
        let (gate, submit_rx, shutting_down) =
            AdmissionGate::new(AdmissionPolicy::default(), metrics.clone());
        (
            Server {
                gate,
                metrics,
                num_classes: 4,
                seq_len,
                next_id: AtomicU64::new(0),
                shutting_down,
                threads: Vec::new(),
            },
            submit_rx,
        )
    }

    #[test]
    fn submit_racing_a_closed_intake_answers_instead_of_hanging() {
        // a server whose intake receiver is already gone -- exactly the
        // state a dead batcher thread leaves behind for a racing submit
        let seq_len = 8;
        let (server, submit_rx) = bare_server(seq_len);
        drop(submit_rx);
        let clip = vec![0.0f32; 3 * seq_len * NUM_JOINTS];
        let resp = server
            .submit(clip)
            .recv_timeout(Duration::from_secs(5))
            .expect("an error response must arrive; pre-fix the reply channel just hung");
        assert!(!resp.is_ok());
        assert!(
            resp.error.as_deref().unwrap_or("").contains("intake closed"),
            "{:?}",
            resp.error
        );
        assert_eq!(server.metrics.failures.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn submit_routed_stamps_the_absolute_deadline() {
        let seq_len = 8;
        let (server, submit_rx) = bare_server(seq_len);
        let clip = vec![0.0f32; 3 * seq_len * NUM_JOINTS];
        let info = RouteInfo {
            seq_len,
            deadline: Some(Duration::from_millis(40)),
            reference_accuracy: false,
        };
        let _rx = server.submit_routed(clip, &info);
        let req = submit_rx.try_recv().expect("admitted");
        let d = req.deadline.expect("deadline propagated");
        assert_eq!(d, req.arrived + Duration::from_millis(40));
        // no budget and no policy default: the request carries none
        let _rx = server.submit(vec![0.0f32; 3 * seq_len * NUM_JOINTS]);
        assert!(submit_rx.try_recv().unwrap().deadline.is_none());
    }
}
