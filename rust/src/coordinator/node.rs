//! The worker-node agent: the far end of a [`super::shard::TcpLink`].
//!
//! A node process binds a `TcpListener`, builds its shard compute (for
//! real serving, [`super::pipeline::Pipeline::payload_shard_fn`] over
//! its own copy of the artifacts), and parks in [`serve_node`].  Each
//! coordinator connection gets the one-shot version handshake, then the
//! same frame-service loop the loopback workers run
//! ([`super::shard::spawn_worker`]): read a shard frame, run the
//! compute, reply with the re-gated result -- or with an error frame,
//! so a compute failure travels the same channel as a result instead of
//! killing the node.
//!
//! Failure containment per connection:
//!
//! * a compute error replies with a [`crate::rfc::wire::error_frame`]
//!   and the connection keeps serving;
//! * a *framing* error (garbage or oversized outer length prefix,
//!   truncated frame, handshake skew) drops that connection only --
//!   framing is a stream-level contract, there is no way to resync
//!   mid-stream -- and the listener keeps accepting;
//! * the coordinator hanging up ends the connection loop normally.

use std::io::{BufRead, BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::rfc::{wire, EncoderConfig};

use super::lock_recovered;
use super::shard::{run_frame, PayloadShardFn};

/// Serve coordinator connections on `listener` forever (the blocking
/// node-process entry point).  Every accepted connection is serviced on
/// its own thread ([`handle_conn`] -> [`serve_conn`]); accept errors
/// are transient-logged and the loop continues.  For an in-process,
/// stoppable agent (tests, benches, embedded nodes) use
/// [`NodeAgent::spawn`].
pub fn serve_node(
    listener: TcpListener,
    compute: PayloadShardFn,
    enc: EncoderConfig,
) -> Result<()> {
    accept_loop(
        listener,
        compute,
        enc,
        Arc::new(AtomicBool::new(false)),
        Arc::new(Mutex::new(Vec::new())),
    );
    Ok(())
}

/// Severing handles for the live connections, keyed by connection id.
/// `TcpStream::shutdown` acts on the socket across every duplicated
/// descriptor, which is what lets [`NodeAgent::shutdown`] unblock
/// handler threads parked in `read`.
type ConnRegistry = Arc<Mutex<Vec<(u64, TcpStream)>>>;

fn accept_loop(
    listener: TcpListener,
    compute: PayloadShardFn,
    enc: EncoderConfig,
    stop: Arc<AtomicBool>,
    conns: ConnRegistry,
) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    let mut next_id = 0u64;
    loop {
        // reap finished handlers so a long-lived node does not grow a
        // JoinHandle per connection forever
        handlers.retain(|h| !h.is_finished());
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(e) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                eprintln!("node accept error: {e}");
                // transient accept failures (fd pressure) should not
                // spin the loop hot
                std::thread::sleep(std::time::Duration::from_millis(50));
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            break; // the shutdown nudge connection; drop it
        }
        let id = next_id;
        next_id += 1;
        let compute = compute.clone();
        let (stop, conns) = (stop.clone(), conns.clone());
        handlers.push(std::thread::spawn(move || {
            handle_conn(id, stream, &compute, &enc, &stop, &conns)
        }));
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// One connection's lifecycle: register a severing handle, serve, then
/// shut the socket down across all descriptors and deregister -- the
/// peer sees EOF/RST the moment service ends, and the registry never
/// accumulates dead entries.
fn handle_conn(
    id: u64,
    stream: TcpStream,
    compute: &PayloadShardFn,
    enc: &EncoderConfig,
    stop: &AtomicBool,
    conns: &ConnRegistry,
) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown peer>".into());
    // no severing handle, no service: a connection the registry cannot
    // sever would leave its handler parked in a blocking read with
    // nothing able to unblock it, wedging `NodeAgent::shutdown` on the
    // join forever
    if !register_severing(id, stream.try_clone(), conns) {
        eprintln!(
            "node connection {peer}: cannot register severing handle \
             (try_clone failed); refusing connection"
        );
        let _ = stream.shutdown(std::net::Shutdown::Both);
        return;
    }
    // re-check AFTER registering: a shutdown that raced past this
    // connection's registration has already drained the registry, so
    // the stop flag (stored before the drain) is the fallback signal
    if !stop.load(Ordering::SeqCst) {
        if let Err(e) = serve_conn(&stream, &peer, compute, enc) {
            eprintln!("node connection {peer}: {e:#}");
        }
    }
    // close the socket across every dup (the registry holds one), so
    // the coordinator actually observes the drop instead of blocking
    let _ = stream.shutdown(std::net::Shutdown::Both);
    lock_recovered(conns).retain(|(cid, _)| *cid != id);
}

/// Register `clone` as connection `id`'s severing handle.  Returns
/// whether registration succeeded; a failed `try_clone` means the
/// connection must be refused (see [`handle_conn`]).
fn register_severing(
    id: u64,
    clone: std::io::Result<TcpStream>,
    conns: &ConnRegistry,
) -> bool {
    match clone {
        Ok(c) => {
            lock_recovered(conns).push((id, c));
            true
        }
        Err(_) => false,
    }
}

/// Read the next shard frame, classifying a clean hangup: EOF exactly
/// at a frame boundary (the buffered reader's `fill_buf` comes back
/// empty before any length byte arrives) is the peer hanging up
/// normally and returns `Ok(None)`.  Anything else that fails --
/// death mid-length-prefix, oversized prefix, mid-frame truncation --
/// is broken framing and surfaces as the error it is.  This replaces
/// matching on the frame reader's context string, which misclassified
/// a peer dying 2 bytes into the length prefix as a clean hangup (both
/// fail the same 4-byte read) and silently broke if the wording
/// changed.
fn next_frame<R: BufRead>(reader: &mut R) -> Result<Option<Vec<u8>>> {
    let at_frame_start_eof = reader
        .fill_buf()
        .context("polling for next frame")?
        .is_empty();
    if at_frame_start_eof {
        return Ok(None);
    }
    wire::read_frame(reader).map(Some)
}

/// Service one coordinator connection: handshake, then frames until the
/// peer hangs up or the stream framing breaks.
fn serve_conn(
    stream: &TcpStream,
    peer: &str,
    compute: &PayloadShardFn,
    enc: &EncoderConfig,
) -> Result<()> {
    let _ = stream.set_nodelay(true);
    let mut writer = BufWriter::new(stream);
    let mut reader = BufReader::new(stream);
    // symmetric exchange, ours first: a version-skewed coordinator still
    // learns what this node speaks before the connection drops
    wire::write_handshake(&mut writer)?;
    wire::expect_handshake(&mut reader).context("coordinator handshake")?;
    loop {
        // EOF at a frame boundary is the coordinator hanging up
        // (normal); any other read failure -- death mid-prefix,
        // oversized prefix, mid-frame truncation -- is broken or
        // hostile framing: drop the connection (new connects still
        // work) and leave a diagnosable log line
        let frame = match next_frame(&mut reader) {
            Ok(Some(f)) => f,
            Ok(None) => return Ok(()),
            Err(e) => {
                eprintln!("node connection {peer}: framing error: {e:#}");
                return Ok(());
            }
        };
        let reply = run_frame(&frame, compute, enc)
            .unwrap_or_else(|e| wire::error_frame(&format!("node {peer}: {e:#}")));
        wire::write_frame(&mut writer, &reply)
            .context("replying to coordinator")?;
    }
}

/// Spawn `n` ephemeral-port localhost agents all running `compute`:
/// the scaffold every TCP conformance test and bench builds its cluster
/// from (connect the returned addresses with
/// [`super::shard::ShardCluster::connect`], and shut the agents down
/// after the cluster).  Production nodes run [`serve_node`] standalone
/// instead.
pub fn spawn_local_agents(
    n: usize,
    compute: PayloadShardFn,
    enc: EncoderConfig,
) -> Result<(Vec<NodeAgent>, Vec<SocketAddr>)> {
    let mut agents = Vec::with_capacity(n.max(1));
    let mut addrs = Vec::with_capacity(n.max(1));
    for _ in 0..n.max(1) {
        let listener = TcpListener::bind("127.0.0.1:0")
            .context("binding ephemeral agent listener")?;
        addrs.push(listener.local_addr().context("agent local addr")?);
        agents.push(NodeAgent::spawn(listener, compute.clone(), enc)?);
    }
    Ok((agents, addrs))
}

/// An in-process node agent: [`serve_node`] on a background thread with
/// a deterministic [`NodeAgent::shutdown`].  This is what the TCP
/// conformance tests and benches run; a real deployment calls
/// [`serve_node`] from its own main.
pub struct NodeAgent {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: ConnRegistry,
    accept: Option<JoinHandle<()>>,
}

impl NodeAgent {
    /// Bind-and-go: spawn the accept loop for `listener` (bind to port 0
    /// for an ephemeral localhost agent).
    pub fn spawn(
        listener: TcpListener,
        compute: PayloadShardFn,
        enc: EncoderConfig,
    ) -> Result<NodeAgent> {
        let addr = listener.local_addr().context("agent local addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let (stop, conns) = (stop.clone(), conns.clone());
            std::thread::spawn(move || {
                accept_loop(listener, compute, enc, stop, conns)
            })
        };
        Ok(NodeAgent {
            addr,
            stop,
            conns,
            accept: Some(accept),
        })
    }

    /// The address coordinators connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, sever every live connection (a coordinator
    /// mid-batch sees the peer-death error path), and join the agent
    /// threads.
    pub fn shutdown(mut self) {
        // order matters: the stop flag is stored before the registry
        // drain, so a handler whose registration raced past the drain
        // still observes it (see `handle_conn`)
        self.stop.store(true, Ordering::SeqCst);
        for (_, c) in lock_recovered(&self.conns).drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        // nudge the blocking accept so it observes the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn eof_at_frame_start_is_a_clean_hangup() {
        let mut hung_up = Cursor::new(Vec::<u8>::new());
        assert!(next_frame(&mut hung_up).unwrap().is_none());
    }

    #[test]
    fn death_mid_length_prefix_is_a_framing_error_not_a_clean_hangup() {
        // 2 of the 4 length bytes arrived before the peer died: the old
        // error-string classification called this a clean hangup
        // because the same 4-byte read fails either way
        let mut partial_prefix = Cursor::new(vec![0x03, 0x00]);
        assert!(next_frame(&mut partial_prefix).is_err());
        // mid-body truncation is equally a framing error
        let mut framed = Vec::new();
        wire::write_frame(&mut framed, &wire::error_frame("x")).unwrap();
        framed.truncate(framed.len() - 1);
        let mut truncated_body = Cursor::new(framed);
        assert!(next_frame(&mut truncated_body).is_err());
    }

    #[test]
    fn whole_frames_round_trip_then_clean_eof() {
        let frame = wire::error_frame("ping");
        let mut framed = Vec::new();
        wire::write_frame(&mut framed, &frame).unwrap();
        let mut reader = Cursor::new(framed);
        assert_eq!(
            next_frame(&mut reader).unwrap().as_deref(),
            Some(frame.as_slice())
        );
        assert!(next_frame(&mut reader).unwrap().is_none());
    }

    #[test]
    fn failed_severing_registration_refuses_the_connection() {
        let conns: ConnRegistry = Arc::new(Mutex::new(Vec::new()));
        // try_clone failed (fd exhaustion): registration must refuse
        // and leave no registry entry behind
        let denied = std::io::Error::new(
            std::io::ErrorKind::Other,
            "too many open files",
        );
        assert!(!register_severing(7, Err(denied), &conns));
        assert!(conns.lock().unwrap().is_empty());
        // the success path registers the handle under the given id
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        assert!(register_severing(8, stream.try_clone(), &conns));
        let registry = conns.lock().unwrap();
        assert_eq!(registry.len(), 1);
        assert_eq!(registry[0].0, 8);
    }
}
