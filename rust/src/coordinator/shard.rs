//! Multi-node sharding: split a compressed batch by row shard, ship each
//! shard's wire bytes over a [`NodeLink`], run the per-node stage
//! workers, and reassemble the results in the coordinator.
//!
//! This is the serving-side continuation of the paper's bank-partitioned
//! storage: the batch axis is already segmented into row-aligned bank
//! runs (see [`crate::rfc`]), so a shard split is a row slice of the
//! compressed form -- the bytes that leave the coordinator are the same
//! `(hot, mbhot, packed)` data the RFC storage holds, serialized by
//! [`crate::rfc::wire`] with **no decode/re-encode round trip**.
//!
//! Topology: one [`NodeLink`] per worker node.  Two links ship here:
//! the in-process [`LoopbackLink`] (byte channels between threads) and
//! the socket-backed [`TcpLink`] (u32-length outer framing + one-shot
//! version handshake over `std::net::TcpStream`, speaking to a
//! [`super::node`] agent).  Both carry identical frames -- the loopback
//! cluster tests double as the TCP conformance suite.

use std::io::{BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, ensure, Context, Result};

use crate::rfc::{wire, EncoderConfig, Payload};
use crate::runtime::Tensor;

use super::metrics::Metrics;

/// Byte-frame transport between the coordinator and one worker node.
/// Frames are [`crate::rfc::wire`] payload frames: self-describing,
/// length-prefixed, validated on decode.
pub trait NodeLink: Send {
    /// Ship one frame to the node.
    fn send(&mut self, frame: Vec<u8>) -> Result<()>;
    /// Block until the node's next reply frame.
    fn recv(&mut self) -> Result<Vec<u8>>;
}

/// In-process loopback link: a pair of byte channels.  The production
/// socket link replaces this without touching the coordinator -- the
/// frames on the channel are exactly the bytes a socket would carry.
pub struct LoopbackLink {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl NodeLink for LoopbackLink {
    fn send(&mut self, frame: Vec<u8>) -> Result<()> {
        self.tx.send(frame).map_err(|_| anyhow!("node link closed"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx.recv().map_err(|_| anyhow!("node link closed"))
    }
}

/// A connected (coordinator-side, node-side) pair of loopback links.
pub fn loopback_pair() -> (LoopbackLink, LoopbackLink) {
    let (coord_tx, node_rx) = channel();
    let (node_tx, coord_rx) = channel();
    (
        LoopbackLink {
            tx: coord_tx,
            rx: coord_rx,
        },
        LoopbackLink {
            tx: node_tx,
            rx: node_rx,
        },
    )
}

/// Default per-I/O activity timeout [`Server::connect_sharded`] applies
/// to its node links: generous enough for any real shard compute, small
/// enough that a silently-partitioned peer (no RST/FIN ever arrives)
/// cannot wedge the coordinator thread forever.
///
/// [`Server::connect_sharded`]: super::server::Server::connect_sharded
pub const DEFAULT_NODE_IO_TIMEOUT: std::time::Duration =
    std::time::Duration::from_secs(120);

/// Socket-backed [`NodeLink`]: the same payload frames the loopback
/// link carries, delimited on the byte stream by the
/// [`wire::write_frame`] u32-length outer framing, with a one-shot
/// [`wire::write_handshake`] version exchange on connect.  A peer that
/// dies mid-batch surfaces as a `recv` error on the coordinator, which
/// [`ShardCluster::infer_on`] treats exactly like a failed compute --
/// the other nodes still drain.
///
/// Any send/recv failure (peer death, framing break, I/O timeout)
/// **poisons the link**: the socket is shut down so a reply that
/// arrives late can never be misread as a *later* batch's reply.  A
/// timed-out link is dead, not one-batch-desynchronized.
pub struct TcpLink {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    peer: String,
}

impl TcpLink {
    /// Connect to a node agent (see [`super::node::serve_node`]) and run
    /// the handshake: both ends send magic + wire version, then verify
    /// the peer's.  Version skew or a non-RFC peer fails here, before
    /// any shard frame is in flight.  No I/O timeout: a hung peer
    /// blocks `recv` indefinitely -- serving paths should prefer
    /// [`TcpLink::connect_timeout`].
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<TcpLink> {
        Self::connect_timeout(addr, None)
    }

    /// [`TcpLink::connect`] with a per-I/O activity timeout: a read or
    /// write that makes no progress for `io_timeout` fails (and
    /// poisons) the link instead of blocking forever.  This is the
    /// hung-peer guard -- a network partition with no RST/FIN would
    /// otherwise park the coordinator in `recv` permanently.
    pub fn connect_timeout<A: ToSocketAddrs>(
        addr: A,
        io_timeout: Option<std::time::Duration>,
    ) -> Result<TcpLink> {
        let stream = TcpStream::connect(addr).context("connecting node link")?;
        stream
            .set_read_timeout(io_timeout)
            .context("setting link read timeout")?;
        stream
            .set_write_timeout(io_timeout)
            .context("setting link write timeout")?;
        Self::from_stream(stream)
    }

    /// Wrap an already-connected stream (either side: the exchange is
    /// symmetric -- write ours, read theirs).
    pub fn from_stream(stream: TcpStream) -> Result<TcpLink> {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown peer>".into());
        // shard frames are one write / one reply: latency, not batching
        let _ = stream.set_nodelay(true);
        let mut writer = BufWriter::new(
            stream.try_clone().context("cloning node stream")?,
        );
        let mut reader = BufReader::new(stream);
        wire::write_handshake(&mut writer)
            .with_context(|| format!("handshake to {peer}"))?;
        wire::expect_handshake(&mut reader)
            .with_context(|| format!("handshake from {peer}"))?;
        Ok(TcpLink {
            reader,
            writer,
            peer,
        })
    }

    /// The peer address this link talks to (diagnostics).
    pub fn peer(&self) -> &str {
        &self.peer
    }
}

impl TcpLink {
    /// Sever the socket after an I/O failure so the link can never
    /// deliver a stale (previous-batch) reply: a timed-out or
    /// half-written stream has lost framing sync permanently.
    fn poison(&self) {
        let _ = self
            .reader
            .get_ref()
            .shutdown(std::net::Shutdown::Both);
    }
}

impl NodeLink for TcpLink {
    fn send(&mut self, frame: Vec<u8>) -> Result<()> {
        let r = wire::write_frame(&mut self.writer, &frame)
            .with_context(|| format!("sending to node {}", self.peer));
        if r.is_err() {
            self.poison();
        }
        r
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let r = wire::read_frame(&mut self.reader)
            .with_context(|| format!("receiving from node {}", self.peer));
        if r.is_err() {
            self.poison();
        }
        r
    }
}

/// The row-local compute one worker node runs on its shard -- for the
/// serving pipeline this is the full stage chain
/// ([`super::pipeline::Pipeline::shard_fn`]); tests substitute synthetic
/// models.
pub type ShardFn = Arc<dyn Fn(Tensor) -> Result<Tensor> + Send + Sync>;

/// Payload-consuming worker compute: the shard arrives still in its
/// transported form, so a pipeline with stage plans can feed the
/// compressed banks straight into the compressed-domain kernel
/// ([`super::pipeline::Pipeline::payload_shard_fn`]) instead of paying a
/// decode at the node boundary.
pub type PayloadShardFn = Arc<dyn Fn(Payload) -> Result<Tensor> + Send + Sync>;

/// Adapt a dense-entry [`ShardFn`] to the payload-consuming worker
/// interface: the payload is decoded lazily at the node, exactly the
/// pre-plan behavior.
pub fn dense_entry(compute: ShardFn, enc: EncoderConfig) -> PayloadShardFn {
    Arc::new(move |p: Payload| compute(p.into_dense(&enc)))
}

/// Spawn a worker thread servicing `link` until the coordinator hangs
/// up.  Each frame's payload is handed to `compute` in transported form
/// (dense-entry models decode via [`dense_entry`]), and the result is
/// re-gated and framed for the reply; failures reply with an error frame
/// instead of killing the node.  Generic over the link, so the same
/// worker loop backs loopback clusters here and socket connections in
/// [`super::node`].
pub fn spawn_worker<L: NodeLink + 'static>(
    mut link: L,
    compute: PayloadShardFn,
    enc: EncoderConfig,
    label: String,
) -> JoinHandle<()> {
    std::thread::spawn(move || loop {
        let frame = match link.recv() {
            Ok(f) => f,
            Err(_) => break, // coordinator gone: shut down
        };
        let reply = run_frame(&frame, &compute, &enc)
            .unwrap_or_else(|e| wire::error_frame(&format!("{label}: {e:#}")));
        if link.send(reply).is_err() {
            break;
        }
    })
}

/// Service one shard frame: decode, compute, re-gate, frame the reply.
/// Shared by [`spawn_worker`] and the node agent's connection loop.
pub(crate) fn run_frame(
    frame: &[u8],
    compute: &PayloadShardFn,
    enc: &EncoderConfig,
) -> Result<Vec<u8>> {
    let payload = wire::payload_from_bytes(frame)?;
    let out = compute(payload)?;
    wire::payload_to_bytes(&Payload::from_tensor(out, enc))
}

/// Contiguous near-equal row ranges over `nodes` workers; nodes beyond
/// the row count get no range.  Shards are in row order, so per-shard
/// results concatenate back in batch order.
pub fn shard_ranges(rows: usize, nodes: usize) -> Vec<(usize, usize)> {
    let nodes = nodes.max(1);
    let per = rows.div_ceil(nodes).max(1);
    (0..nodes)
        .map(|i| (i * per, rows.min((i + 1) * per)))
        .filter(|&(lo, hi)| lo < hi)
        .collect()
}

fn slice_payload(p: &Payload, lo: usize, hi: usize) -> Result<Payload> {
    match p {
        Payload::Compressed(ct) => Ok(Payload::Compressed(ct.slice_rows(lo, hi)?)),
        Payload::Dense(t) => {
            ensure!(
                t.shape.len() >= 2,
                "row slice needs a batch axis, got {:?}",
                t.shape
            );
            let row: usize = t.shape[1..].iter().product();
            let mut shape = t.shape.clone();
            shape[0] = hi - lo;
            Ok(Payload::Dense(Tensor::new(
                shape,
                t.data[lo * row..hi * row].to_vec(),
            )?))
        }
    }
}

/// A cluster of worker nodes behind [`NodeLink`]s, plus the split /
/// reassemble logic the coordinator runs around them.
pub struct ShardCluster {
    links: Vec<Box<dyn NodeLink>>,
    workers: Vec<JoinHandle<()>>,
    enc: EncoderConfig,
}

impl ShardCluster {
    /// Spawn `nodes` loopback workers, all running the dense-entry
    /// `compute` on their row shards (shards decode at the node).
    pub fn loopback(nodes: usize, compute: ShardFn, enc: EncoderConfig) -> ShardCluster {
        Self::loopback_payload(nodes, dense_entry(compute, enc), enc)
    }

    /// Spawn `nodes` loopback workers running a payload-consuming
    /// compute -- the entry point for planned pipelines whose stage
    /// workers claim compressed shards without decoding.
    pub fn loopback_payload(
        nodes: usize,
        compute: PayloadShardFn,
        enc: EncoderConfig,
    ) -> ShardCluster {
        let mut links: Vec<Box<dyn NodeLink>> = Vec::new();
        let mut workers = Vec::new();
        for i in 0..nodes.max(1) {
            let (coord, node) = loopback_pair();
            workers.push(spawn_worker(node, compute.clone(), enc, format!("node {i}")));
            links.push(Box::new(coord));
        }
        ShardCluster {
            links,
            workers,
            enc,
        }
    }

    /// Drive remote node agents over localhost/network TCP: one
    /// [`TcpLink`] per address, handshake on connect.  The coordinator
    /// treats the resulting cluster exactly like a loopback one -- same
    /// split/reassemble, same drain-after-failure invariant when a peer
    /// dies mid-batch.
    pub fn connect<A: ToSocketAddrs>(
        addrs: &[A],
        enc: EncoderConfig,
    ) -> Result<ShardCluster> {
        Self::connect_timeout(addrs, enc, None)
    }

    /// [`ShardCluster::connect`] with a per-I/O activity timeout on
    /// every link (see [`TcpLink::connect_timeout`]): the serving
    /// path's guard against a hung-but-not-dead peer.
    pub fn connect_timeout<A: ToSocketAddrs>(
        addrs: &[A],
        enc: EncoderConfig,
        io_timeout: Option<std::time::Duration>,
    ) -> Result<ShardCluster> {
        ensure!(!addrs.is_empty(), "cluster needs at least one node address");
        let mut links: Vec<Box<dyn NodeLink>> = Vec::with_capacity(addrs.len());
        for (i, a) in addrs.iter().enumerate() {
            links.push(Box::new(
                TcpLink::connect_timeout(a, io_timeout)
                    .with_context(|| format!("node {i}"))?,
            ));
        }
        Ok(Self::from_links(links, enc))
    }

    /// A cluster over caller-built links (mixed transports, tests).  The
    /// cluster owns no worker threads for these; whatever serves the far
    /// end of each link outlives it.
    pub fn from_links(
        links: Vec<Box<dyn NodeLink>>,
        enc: EncoderConfig,
    ) -> ShardCluster {
        ShardCluster {
            links,
            workers: Vec::new(),
            enc,
        }
    }

    pub fn nodes(&self) -> usize {
        self.links.len()
    }

    /// Run one batch over every node: split by rows, ship every shard's
    /// wire frame before collecting any reply (the nodes run
    /// concurrently), then reassemble the per-node results in batch
    /// order.  Per-node wire traffic is recorded into `metrics` when
    /// given.
    pub fn infer(&mut self, input: &Payload, metrics: Option<&Metrics>) -> Result<Tensor> {
        self.infer_on(self.links.len(), input, metrics)
    }

    /// [`ShardCluster::infer`] with an explicit fan-out (clamped to the
    /// node count): the serving path picks it per batch via
    /// [`super::router::Router::shards_for`], so tiny batches stay on
    /// one node instead of paying per-shard framing for nothing.
    ///
    /// Failure handling: the cluster is long-lived, so every node that
    /// was sent a shard is drained even after an error -- a reply left
    /// queued on a link would be collected by the *next* batch and
    /// silently deliver stale results one batch off, forever.
    pub fn infer_on(
        &mut self,
        fan_out: usize,
        input: &Payload,
        metrics: Option<&Metrics>,
    ) -> Result<Tensor> {
        let shape = input.shape();
        ensure!(
            shape.len() >= 2,
            "cluster input needs a batch axis, got {shape:?}"
        );
        let plan = shard_ranges(shape[0], fan_out.clamp(1, self.links.len()));
        ensure!(!plan.is_empty(), "empty batch (0 rows)");
        let mut failure: Option<anyhow::Error> = None;
        let mut sent = vec![false; plan.len()];
        for (node, &(lo, hi)) in plan.iter().enumerate() {
            let result = slice_payload(input, lo, hi).and_then(|part| {
                let frame = wire::payload_to_bytes(&part)?;
                let wire_bytes = frame.len() as u64;
                self.links[node]
                    .send(frame)
                    .with_context(|| format!("sending shard to node {node}"))?;
                // recorded only after the link accepted the frame, so a
                // dead node cannot inflate its transport stats
                if let Some(m) = metrics {
                    m.record_node_tx(node, wire_bytes, part.dense_bits() / 8);
                }
                Ok(())
            });
            match result {
                Ok(()) => sent[node] = true,
                Err(e) => {
                    failure.get_or_insert(e);
                }
            }
        }
        let mut parts = Vec::with_capacity(plan.len());
        for (node, &(lo, hi)) in plan.iter().enumerate() {
            if !sent[node] {
                continue; // nothing in flight on this link
            }
            let result = self.collect_reply(node, hi - lo, metrics);
            match result {
                Ok(t) => parts.push(t),
                Err(e) => {
                    failure.get_or_insert(e);
                }
            }
        }
        if let Some(e) = failure {
            return Err(e);
        }
        Tensor::concat_batch(&parts)
    }

    /// Receive + decode one node's reply for a `rows`-row shard.
    fn collect_reply(
        &mut self,
        node: usize,
        rows: usize,
        metrics: Option<&Metrics>,
    ) -> Result<Tensor> {
        let frame = self.links[node]
            .recv()
            .with_context(|| format!("collecting node {node}"))?;
        let reply = wire::payload_from_bytes(&frame)
            .with_context(|| format!("node {node} reply"))?;
        ensure!(
            reply.shape().first() == Some(&rows),
            "node {node} returned shape {:?} for a {rows}-row shard",
            reply.shape()
        );
        if let Some(m) = metrics {
            m.record_node_rx(node, frame.len() as u64, reply.dense_bits() / 8);
        }
        Ok(reply.into_dense(&self.enc))
    }

    /// Hang up every link and join the workers.
    pub fn shutdown(self) {
        drop(self.links);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::coordinator::node::{spawn_local_agents, NodeAgent};

    /// Every cluster test below runs against both transports: the
    /// in-process loopback link and real localhost TCP sockets served
    /// by [`NodeAgent`]s.  This is the conformance contract -- above
    /// the link layer the two are indistinguishable.
    const TRANSPORTS: [&str; 2] = ["loopback", "tcp"];

    /// Build a cluster over the named transport; the returned agents
    /// (TCP only) must outlive the cluster and be shut down after it.
    fn cluster_on(
        transport: &str,
        nodes: usize,
        compute: PayloadShardFn,
        enc: EncoderConfig,
    ) -> (ShardCluster, Vec<NodeAgent>) {
        match transport {
            "loopback" => (
                ShardCluster::loopback_payload(nodes, compute, enc),
                Vec::new(),
            ),
            "tcp" => {
                let (agents, addrs) =
                    spawn_local_agents(nodes, compute, enc).unwrap();
                (ShardCluster::connect(&addrs, enc).unwrap(), agents)
            }
            t => panic!("unknown transport {t}"),
        }
    }

    fn dense_cluster_on(
        transport: &str,
        nodes: usize,
        compute: ShardFn,
        enc: EncoderConfig,
    ) -> (ShardCluster, Vec<NodeAgent>) {
        cluster_on(transport, nodes, dense_entry(compute, enc), enc)
    }

    fn teardown(cluster: ShardCluster, agents: Vec<NodeAgent>) {
        cluster.shutdown();
        for a in agents {
            a.shutdown();
        }
    }

    /// Row-local toy model (deliberately simpler than the synthetic
    /// classifier the integration tests use): out[r][c] = (c+1) * sum(row).
    /// Row-locality is what makes shard + concat equal single-node.
    fn synth(classes: usize) -> ShardFn {
        Arc::new(move |t: Tensor| {
            ensure!(t.shape.len() >= 2, "need a batch axis");
            let rows = t.shape[0];
            let row: usize = t.shape[1..].iter().product();
            let mut out = vec![0f32; rows * classes];
            for r in 0..rows {
                let s: f32 = t.data[r * row..(r + 1) * row].iter().sum();
                for (c, slot) in out[r * classes..(r + 1) * classes]
                    .iter_mut()
                    .enumerate()
                {
                    *slot = s * (c + 1) as f32;
                }
            }
            Tensor::new(vec![rows, classes], out)
        })
    }

    fn enc() -> EncoderConfig {
        EncoderConfig {
            shards: 1,
            min_sparsity: 0.10,
            parallel_threshold: usize::MAX,
        }
    }

    #[test]
    fn shard_ranges_cover_and_order() {
        for (rows, nodes) in [(8, 2), (8, 3), (3, 4), (1, 4), (16, 1), (5, 5)] {
            let plan = shard_ranges(rows, nodes);
            assert!(plan.len() <= nodes.max(1));
            assert_eq!(plan[0].0, 0);
            assert_eq!(plan.last().unwrap().1, rows);
            for w in plan.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous ({rows}, {nodes})");
            }
        }
        assert!(shard_ranges(0, 3).is_empty());
    }

    #[test]
    fn cluster_matches_single_node_for_all_shard_counts() {
        let t = Tensor::random_sparse(vec![8, 3, 4, 25], 0.6, 31);
        let expect = synth(10)(t.clone()).unwrap();
        for transport in TRANSPORTS {
            for nodes in [1usize, 2, 3, 4, 8] {
                let (mut cluster, agents) =
                    dense_cluster_on(transport, nodes, synth(10), enc());
                let out = cluster
                    .infer(&Payload::Dense(t.clone()), None)
                    .unwrap();
                assert_eq!(out, expect, "{transport}: {nodes} nodes");
                teardown(cluster, agents);
            }
        }
    }

    #[test]
    fn compressed_input_stays_compressed_on_the_wire() {
        let t = Tensor::random_sparse(vec![4, 3, 4, 25], 0.8, 32);
        let e = enc();
        let p = Payload::from_tensor(t.clone(), &e);
        assert!(p.is_compressed());
        for transport in TRANSPORTS {
            let m = Metrics::default();
            let (mut cluster, agents) =
                dense_cluster_on(transport, 2, synth(6), e);
            let out = cluster.infer(&p, Some(&m)).unwrap();
            assert_eq!(out, synth(6)(t.clone()).unwrap(), "{transport}");
            teardown(cluster, agents);
            let nodes = m.node_transport();
            assert_eq!(nodes.len(), 2, "{transport}");
            for (i, n) in nodes.iter().enumerate() {
                assert_eq!(n.shards, 1, "{transport}: node {i}");
                // a 80%-sparse shard's frame is far smaller than dense
                assert!(
                    n.tx_wire_bytes < n.tx_dense_bytes / 2,
                    "{transport}: node {i}: {} vs {}",
                    n.tx_wire_bytes,
                    n.tx_dense_bytes
                );
                assert!(n.saving() > 0.0);
            }
        }
    }

    #[test]
    fn more_nodes_than_rows_leaves_tail_nodes_idle() {
        let t = Tensor::random_sparse(vec![2, 3, 4, 25], 0.5, 33);
        let expect = synth(4)(t.clone()).unwrap();
        for transport in TRANSPORTS {
            let m = Metrics::default();
            let (mut cluster, agents) =
                dense_cluster_on(transport, 4, synth(4), enc());
            let out = cluster
                .infer(&Payload::Dense(t.clone()), Some(&m))
                .unwrap();
            assert_eq!(out, expect, "{transport}");
            teardown(cluster, agents);
            let nodes = m.node_transport();
            assert_eq!(
                nodes.len(),
                2,
                "{transport}: only the first two nodes saw work"
            );
        }
    }

    #[test]
    fn payload_workers_consume_compressed_shards_without_decode() {
        use crate::rfc::kernel::{self, GemmF32, KernelConfig};
        use std::sync::atomic::{AtomicU64, Ordering};
        let (k, n) = (64usize, 6usize);
        let w: Vec<f32> = (0..k * n).map(|i| ((i % 13) as f32 - 6.0) / 8.0).collect();
        let gemm = Arc::new(GemmF32::new(w, k, n).unwrap());
        let elided = Arc::new(AtomicU64::new(0));
        // a worker compute that never decodes a compressed shard: the
        // banks go straight through the compressed-domain kernel
        let compute: PayloadShardFn = {
            let gemm = gemm.clone();
            let elided = elided.clone();
            Arc::new(move |p: Payload| match p {
                Payload::Compressed(ct) => {
                    elided.fetch_add(1, Ordering::Relaxed);
                    let (y, _) =
                        kernel::spmm_f32(&ct, &gemm, &KernelConfig::serial())?;
                    Ok(y)
                }
                Payload::Dense(t) => {
                    let m = t.shape[0];
                    let out = kernel::gemm_dense_f32(&t.data, m, &gemm);
                    Tensor::new(vec![m, n], out)
                }
            })
        };
        let t = Tensor::random_sparse(vec![8, k], 0.8, 51);
        let e = enc();
        let p = Payload::from_tensor(t.clone(), &e);
        assert!(p.is_compressed());
        let expect = kernel::gemm_dense_f32(&t.data, 8, &gemm);
        for transport in TRANSPORTS {
            elided.store(0, Ordering::Relaxed);
            let (mut cluster, agents) =
                cluster_on(transport, 2, compute.clone(), e);
            let out = cluster.infer(&p, None).unwrap();
            teardown(cluster, agents);
            assert_eq!(out.shape, vec![8, n], "{transport}");
            assert_eq!(out.data, expect, "{transport}");
            assert_eq!(
                elided.load(Ordering::Relaxed),
                2,
                "{transport}: both shards arrived compressed and skipped \
                 the decode"
            );
        }
    }

    #[test]
    fn worker_errors_surface_without_hanging() {
        let failing: ShardFn =
            Arc::new(|_t| Err(anyhow!("synthetic stage failure")));
        let t = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 34);
        for transport in TRANSPORTS {
            let (mut cluster, agents) =
                dense_cluster_on(transport, 2, failing.clone(), enc());
            let err = cluster
                .infer(&Payload::Dense(t.clone()), None)
                .unwrap_err();
            assert!(
                format!("{err:#}").contains("synthetic stage failure"),
                "{transport}: {err:#}"
            );
            teardown(cluster, agents);
        }
    }

    #[test]
    fn cluster_stays_synchronized_after_a_failed_batch() {
        // one worker fails on exactly one shard; the coordinator must
        // drain every in-flight reply so the *next* batch gets its own
        // results, not the failed batch's leftovers shifted by one
        use std::sync::atomic::{AtomicUsize, Ordering};
        let reference = synth(4);
        let t1 = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 41);
        let t2 = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 42);
        for transport in TRANSPORTS {
            let inner = synth(4);
            let calls = Arc::new(AtomicUsize::new(0));
            let counter = calls.clone();
            let flaky: ShardFn = Arc::new(move |t: Tensor| {
                if counter.fetch_add(1, Ordering::SeqCst) == 0 {
                    Err(anyhow!("transient stage failure"))
                } else {
                    inner(t)
                }
            });
            let (mut cluster, agents) =
                dense_cluster_on(transport, 2, flaky, enc());
            let err = cluster
                .infer(&Payload::Dense(t1.clone()), None)
                .unwrap_err();
            assert!(
                format!("{err:#}").contains("transient"),
                "{transport}: {err:#}"
            );
            // the very next batch on the same cluster must be correct
            let out = cluster
                .infer(&Payload::Dense(t2.clone()), None)
                .unwrap();
            assert_eq!(out, reference(t2.clone()).unwrap(), "{transport}");
            assert_eq!(
                calls.load(Ordering::SeqCst),
                4,
                "{transport}: 2 shards x 2 batches"
            );
            teardown(cluster, agents);
        }
    }

    #[test]
    fn fan_out_keeps_small_batches_on_fewer_nodes() {
        let t = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 43);
        let expect = synth(5)(t.clone()).unwrap();
        for transport in TRANSPORTS {
            let m = Metrics::default();
            let (mut cluster, agents) =
                dense_cluster_on(transport, 4, synth(5), enc());
            let out = cluster
                .infer_on(2, &Payload::Dense(t.clone()), Some(&m))
                .unwrap();
            assert_eq!(out, expect, "{transport}");
            teardown(cluster, agents);
            // only the first 2 nodes saw frames despite 4 available
            assert_eq!(m.node_transport().len(), 2, "{transport}");
            // degenerate fan-outs clamp instead of panicking
            let (mut one, one_agents) =
                dense_cluster_on(transport, 1, synth(5), enc());
            let t = Tensor::random_sparse(vec![2, 3, 4, 25], 0.5, 44);
            assert!(one.infer_on(0, &Payload::Dense(t.clone()), None).is_ok());
            assert!(one.infer_on(9, &Payload::Dense(t), None).is_ok());
            teardown(one, one_agents);
        }
    }

    #[test]
    fn wrong_row_count_from_a_node_is_rejected() {
        // a "model" that drops the batch axis contract
        let bad: ShardFn = Arc::new(|t| {
            let rows = t.shape[0] + 1;
            Ok(Tensor::zeros(vec![rows, 2]))
        });
        let t = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 35);
        for transport in TRANSPORTS {
            let (mut cluster, agents) =
                dense_cluster_on(transport, 2, bad.clone(), enc());
            assert!(
                cluster.infer(&Payload::Dense(t.clone()), None).is_err(),
                "{transport}"
            );
            teardown(cluster, agents);
        }
    }

    #[test]
    fn tcp_peer_death_mid_batch_drains_the_live_nodes() {
        // kill node 1's agent while the cluster is connected: the next
        // batch fails (link error, not a hang), but node 0's in-flight
        // reply must still be drained -- a stale reply left queued
        // would be collected by the next batch and deliver wrong rows
        let (mut cluster, mut agents) =
            dense_cluster_on("tcp", 2, synth(4), enc());
        agents.remove(1).shutdown();
        let t1 = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 45);
        let err = cluster
            .infer(&Payload::Dense(t1), None)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("node 1"), "{msg}");
        // fan-out 1 hits only the (live, drained) node 0: the reply it
        // gets must be for *this* batch, which the row-count check and
        // the value assert both verify
        let t2 = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 46);
        let out = cluster
            .infer_on(1, &Payload::Dense(t2.clone()), None)
            .unwrap();
        assert_eq!(out, synth(4)(t2).unwrap());
        teardown(cluster, agents);
    }
}
