//! Multi-node sharding: split a compressed batch by row shard, ship each
//! shard's wire bytes over a [`NodeLink`], run the per-node stage
//! workers, and reassemble the results in the coordinator.
//!
//! This is the serving-side continuation of the paper's bank-partitioned
//! storage: the batch axis is already segmented into row-aligned bank
//! runs (see [`crate::rfc`]), so a shard split is a row slice of the
//! compressed form -- the bytes that leave the coordinator are the same
//! `(hot, mbhot, packed)` data the RFC storage holds, serialized by
//! [`crate::rfc::wire`] with **no decode/re-encode round trip**.
//!
//! Topology: one supervised [`NodeSlot`] per worker node.  Two links
//! ship here: the in-process [`LoopbackLink`] (byte channels between
//! threads) and the socket-backed [`TcpLink`] (u32-length outer framing
//! + one-shot version handshake over `std::net::TcpStream`, speaking to
//! a [`super::node`] agent).  Both carry identical frames -- the
//! loopback cluster tests double as the TCP conformance suite.
//!
//! Supervision: a link-level send/recv failure takes its slot
//! [`SlotState::Down`] instead of leaving a poisoned link in the
//! rotation forever.  [`ShardCluster::infer_on`] plans shards over the
//! **live** slots only, and a shard lost to a link failure mid-batch is
//! **re-dispatched onto the survivors** (bounded by [`RetryPolicy`] and
//! the batch deadline) so a node death is masked from callers instead
//! of failing every request in the batch.  [`ShardCluster::heal`]
//! re-dials Down TCP slots on a bounded exponential backoff (see
//! [`ReconnectPolicy`]), rotating its per-pass budget across Down slots,
//! and promotes a slot to its standby address once it has been Down
//! past [`ReconnectPolicy::promote_after`] -- so both a restarted node
//! agent and a permanently lost machine rejoin the cluster without a
//! coordinator restart.  Full policy write-up:
//! `docs/cluster-resilience.md`.

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::rfc::{wire, EncoderConfig, Payload};
use crate::runtime::Tensor;

use super::metrics::Metrics;

/// Byte-frame transport between the coordinator and one worker node.
/// Frames are [`crate::rfc::wire`] payload frames: self-describing,
/// length-prefixed, validated on decode.
pub trait NodeLink: Send {
    /// Ship one frame to the node.
    fn send(&mut self, frame: Vec<u8>) -> Result<()>;
    /// Block until the node's next reply frame.
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// [`NodeLink::recv`] bounded by an absolute deadline, when one is
    /// given: a reply that misses the deadline is a **link-level**
    /// failure, and the link must arrange that the late frame can never
    /// surface as a later batch's reply (the TCP impl poisons the
    /// socket; the loopback impl's channel is dropped by the caller's
    /// `mark_down`).  This is what converts a hung-but-alive straggler
    /// node into a retryable shard failure instead of a batch-wide
    /// stall.  The default ignores the deadline, preserving plain
    /// blocking-recv semantics for custom links.
    fn recv_deadline(&mut self, deadline: Option<Instant>) -> Result<Vec<u8>> {
        let _ = deadline;
        self.recv()
    }
}

/// In-process loopback link: a pair of byte channels.  The production
/// socket link replaces this without touching the coordinator -- the
/// frames on the channel are exactly the bytes a socket would carry.
pub struct LoopbackLink {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl NodeLink for LoopbackLink {
    fn send(&mut self, frame: Vec<u8>) -> Result<()> {
        self.tx.send(frame).map_err(|_| anyhow!("node link closed"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx.recv().map_err(|_| anyhow!("node link closed"))
    }

    fn recv_deadline(&mut self, deadline: Option<Instant>) -> Result<Vec<u8>> {
        let Some(d) = deadline else {
            return self.recv();
        };
        let remaining = d.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(anyhow!(
                "node link: shard deadline passed before the reply"
            ));
        }
        self.rx.recv_timeout(remaining).map_err(|e| match e {
            RecvTimeoutError::Timeout => {
                anyhow!("node link: no reply within the shard deadline")
            }
            RecvTimeoutError::Disconnected => anyhow!("node link closed"),
        })
    }
}

/// A connected (coordinator-side, node-side) pair of loopback links.
pub fn loopback_pair() -> (LoopbackLink, LoopbackLink) {
    let (coord_tx, node_rx) = channel();
    let (node_tx, coord_rx) = channel();
    (
        LoopbackLink {
            tx: coord_tx,
            rx: coord_rx,
        },
        LoopbackLink {
            tx: node_tx,
            rx: node_rx,
        },
    )
}

/// Default per-I/O activity timeout [`Server::connect_sharded`] applies
/// to its node links: generous enough for any real shard compute, small
/// enough that a silently-partitioned peer (no RST/FIN ever arrives)
/// cannot wedge the coordinator thread forever.
///
/// [`Server::connect_sharded`]: super::server::Server::connect_sharded
pub const DEFAULT_NODE_IO_TIMEOUT: Duration = Duration::from_secs(120);

/// Resolve a node address once, up front: reconnects re-dial the
/// resolved set instead of re-resolving (a DNS outage during recovery
/// should not keep a slot Down that the network would accept).
fn resolve<A: ToSocketAddrs>(addr: &A) -> Result<Vec<SocketAddr>> {
    let addrs: Vec<SocketAddr> = addr
        .to_socket_addrs()
        .context("resolving node address")?
        .collect();
    ensure!(!addrs.is_empty(), "node address resolved to no addresses");
    Ok(addrs)
}

/// Socket-backed [`NodeLink`]: the same payload frames the loopback
/// link carries, delimited on the byte stream by the
/// [`wire::write_frame`] u32-length outer framing, with a one-shot
/// [`wire::write_handshake`] version exchange on connect.  A peer that
/// dies mid-batch surfaces as a `recv` error on the coordinator, which
/// [`ShardCluster::infer_on`] treats exactly like a failed compute --
/// the other nodes still drain.
///
/// Any send/recv failure (peer death, framing break, I/O timeout)
/// **poisons the link**: the socket is shut down so a reply that
/// arrives late can never be misread as a *later* batch's reply.  A
/// timed-out link is dead, not one-batch-desynchronized.
pub struct TcpLink {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    peer: String,
    /// the configured per-I/O activity timeout, remembered so a
    /// deadline-bounded recv can tighten the socket read timeout for
    /// one frame and then restore it
    io_timeout: Option<Duration>,
}

impl TcpLink {
    /// Connect to a node agent (see [`super::node::serve_node`]) and run
    /// the handshake: both ends send magic + wire version, then verify
    /// the peer's.  Version skew or a non-RFC peer fails here, before
    /// any shard frame is in flight.  No I/O timeout: a hung peer
    /// blocks `recv` indefinitely -- serving paths should prefer
    /// [`TcpLink::connect_timeout`].
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<TcpLink> {
        Self::connect_timeout(addr, None)
    }

    /// [`TcpLink::connect`] with a per-I/O activity timeout: a read or
    /// write that makes no progress for `io_timeout` fails (and
    /// poisons) the link instead of blocking forever.  The **dial
    /// itself** is bounded by the same budget: a blackholed peer (SYN
    /// swallowed, no RST ever) used to hang the plain `connect` for the
    /// OS default -- minutes -- before the read/write timeouts applied
    /// at all.
    pub fn connect_timeout<A: ToSocketAddrs>(
        addr: A,
        io_timeout: Option<Duration>,
    ) -> Result<TcpLink> {
        let addrs = resolve(&addr)?;
        Self::dial(&addrs, io_timeout, io_timeout)
    }

    /// Dial the resolved addresses in order (first reachable wins, like
    /// `TcpStream::connect` over a multi-address resolution), bounding
    /// each attempt by `connect_timeout` when given, then apply the
    /// per-I/O timeout and run the handshake.
    fn dial(
        addrs: &[SocketAddr],
        connect_timeout: Option<Duration>,
        io_timeout: Option<Duration>,
    ) -> Result<TcpLink> {
        let mut last: Option<std::io::Error> = None;
        for a in addrs {
            let connected = match connect_timeout {
                Some(bound) => TcpStream::connect_timeout(a, bound),
                None => TcpStream::connect(a),
            };
            match connected {
                Ok(stream) => {
                    stream
                        .set_read_timeout(io_timeout)
                        .context("setting link read timeout")?;
                    stream
                        .set_write_timeout(io_timeout)
                        .context("setting link write timeout")?;
                    return Self::from_stream(stream);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(match last {
            Some(e) => anyhow::Error::from(e).context("connecting node link"),
            None => anyhow!("connecting node link: no addresses to dial"),
        })
    }

    /// Wrap an already-connected stream (either side: the exchange is
    /// symmetric -- write ours, read theirs).
    pub fn from_stream(stream: TcpStream) -> Result<TcpLink> {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown peer>".into());
        // whatever activity timeout the dialer applied is the one
        // deadline-bounded recvs restore afterwards
        let io_timeout = stream.read_timeout().unwrap_or(None);
        // shard frames are one write / one reply: latency, not batching
        let _ = stream.set_nodelay(true);
        let mut writer = BufWriter::new(
            stream.try_clone().context("cloning node stream")?,
        );
        let mut reader = BufReader::new(stream);
        wire::write_handshake(&mut writer)
            .with_context(|| format!("handshake to {peer}"))?;
        wire::expect_handshake(&mut reader)
            .with_context(|| format!("handshake from {peer}"))?;
        Ok(TcpLink {
            reader,
            writer,
            peer,
            io_timeout,
        })
    }

    /// The peer address this link talks to (diagnostics).
    pub fn peer(&self) -> &str {
        &self.peer
    }
}

impl TcpLink {
    /// Sever the socket after an I/O failure so the link can never
    /// deliver a stale (previous-batch) reply: a timed-out or
    /// half-written stream has lost framing sync permanently.
    fn poison(&self) {
        let _ = self
            .reader
            .get_ref()
            .shutdown(std::net::Shutdown::Both);
    }
}

impl NodeLink for TcpLink {
    fn send(&mut self, frame: Vec<u8>) -> Result<()> {
        let r = wire::write_frame(&mut self.writer, &frame)
            .with_context(|| format!("sending to node {}", self.peer));
        if r.is_err() {
            self.poison();
        }
        r
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let r = wire::read_frame(&mut self.reader)
            .with_context(|| format!("receiving from node {}", self.peer));
        if r.is_err() {
            self.poison();
        }
        r
    }

    fn recv_deadline(&mut self, deadline: Option<Instant>) -> Result<Vec<u8>> {
        let Some(d) = deadline else {
            return self.recv();
        };
        let remaining = d.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            // a frame may already be in flight toward us; abandoning it
            // would desynchronize the stream, so the link dies with the
            // deadline (same contract as any other recv failure)
            self.poison();
            return Err(anyhow!(
                "receiving from node {}: shard deadline passed before the reply",
                self.peer
            ));
        }
        let effective = match self.io_timeout {
            Some(t) => t.min(remaining),
            None => remaining,
        };
        // if the socket refuses the tightened timeout, fall back to the
        // plain recv rather than losing a frame that may still arrive
        if self
            .reader
            .get_ref()
            .set_read_timeout(Some(effective))
            .is_err()
        {
            return self.recv();
        }
        let r = self.recv();
        let _ = self.reader.get_ref().set_read_timeout(self.io_timeout);
        r
    }
}

/// Backoff and budget policy for reviving Down TCP slots
/// ([`ShardCluster::heal`]).
#[derive(Debug, Clone)]
pub struct ReconnectPolicy {
    /// delay before the first reconnect attempt after a failure
    pub base: Duration,
    /// backoff ceiling: each consecutive failure doubles the delay,
    /// saturating here
    pub cap: Duration,
    /// bound on each re-dial, so a still-dead peer costs a heal pass
    /// milliseconds, never a serving stall
    pub connect_timeout: Duration,
    /// most re-dial attempts one heal pass pays for (reconnect work is
    /// amortized across batches instead of front-loaded onto one)
    pub attempts_per_heal: usize,
    /// how long a slot may stay Down before [`ShardCluster::heal`]
    /// gives up waiting for the primary and dials the slot's standby
    /// address instead, promoting it into the slot on success -- the
    /// self-repair path for a *permanently* lost machine
    pub promote_after: Duration,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        ReconnectPolicy {
            base: Duration::from_millis(50),
            cap: Duration::from_secs(5),
            connect_timeout: Duration::from_millis(250),
            attempts_per_heal: 2,
            promote_after: Duration::from_secs(10),
        }
    }
}

/// Bounds on re-dispatching a failed shard onto surviving slots
/// ([`ShardCluster::infer_deadline`]).  Retry applies **only** to
/// link-level losses (send/recv failure, slot Down mid-batch, recv
/// deadline missed); an application failure -- error frame, mis-shaped
/// reply -- is deterministic and is never retried.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// total dispatch attempts per shard, first try included; 1 means
    /// fail-the-batch on any shard loss (the pre-retry behavior)
    pub max_attempts: usize,
    /// per-shard recv budget, independent of the batch deadline: a node
    /// that holds a shard longer than this is treated as a straggler
    /// (link failure, shard retried elsewhere) even on deadline-less
    /// batches.  `None` leaves only the batch deadline and the link's
    /// own I/O timeout in force.
    pub per_shard_timeout: Option<Duration>,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            per_shard_timeout: None,
        }
    }
}

impl RetryPolicy {
    /// Fail-the-batch on the first shard loss: the pre-retry semantics,
    /// for tests that prove routing-around / drain behavior in
    /// isolation.
    pub fn disabled() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            per_shard_timeout: None,
        }
    }
}

/// The backoff schedule as a pure function of the failure count: delay
/// before attempt N+1 after N consecutive failures.  Doubles from
/// `base`, saturates at `cap`; no wall clock involved, so the cap is
/// unit-testable without sleeping.
pub fn backoff_delay(consecutive_failures: u32, policy: &ReconnectPolicy) -> Duration {
    // 2^20 * base already dwarfs any sane cap; clamping the exponent
    // keeps the shift defined for arbitrarily large failure counts
    let exp = consecutive_failures.saturating_sub(1).min(20);
    policy.base.saturating_mul(1u32 << exp).min(policy.cap)
}

/// Supervision state of one cluster slot.
#[derive(Debug, Clone, Copy)]
pub enum SlotState {
    /// the link is believed healthy and is in the shard rotation
    Up,
    /// the link failed; a TCP slot re-dials on the backoff schedule,
    /// a static (loopback / caller-built) slot stays Down
    Down {
        /// when the slot left the rotation
        since: Instant,
        /// link failures since it last served (drives the backoff)
        consecutive_failures: u32,
    },
}

impl SlotState {
    pub fn is_up(&self) -> bool {
        matches!(self, SlotState::Up)
    }
}

/// How a slot's link came to be -- and whether it can be rebuilt.
enum SlotOrigin {
    /// dialed by the cluster: remembers the resolved addresses and the
    /// per-I/O timeout so [`ShardCluster::heal`] can re-dial after a
    /// failure, plus any standby addresses the slot may be promoted to
    /// when the primary stays dead past
    /// [`ReconnectPolicy::promote_after`]
    Tcp {
        addrs: Vec<SocketAddr>,
        standbys: Vec<SocketAddr>,
        io_timeout: Option<Duration>,
    },
    /// loopback or caller-built link: nothing to re-dial, Down is final
    Static,
}

/// One supervised cluster slot: the link (present while Up), its
/// origin, and the failure/backoff bookkeeping.
pub struct NodeSlot {
    link: Option<Box<dyn NodeLink>>,
    origin: SlotOrigin,
    state: SlotState,
    /// earliest instant the next reconnect attempt may run
    next_attempt: Instant,
    /// lifetime successful revivals
    reconnects: u64,
    /// lifetime standby promotions (each one also counts a reconnect)
    promotions: u64,
}

impl NodeSlot {
    fn up(link: Box<dyn NodeLink>, origin: SlotOrigin) -> NodeSlot {
        NodeSlot {
            link: Some(link),
            origin,
            state: SlotState::Up,
            next_attempt: Instant::now(),
            reconnects: 0,
            promotions: 0,
        }
    }

    pub fn state(&self) -> SlotState {
        self.state
    }

    /// Lifetime successful reconnects of this slot.
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// Lifetime standby promotions of this slot.
    pub fn promotions(&self) -> u64 {
        self.promotions
    }

    fn consecutive_failures(&self) -> u32 {
        match self.state {
            SlotState::Up => 0,
            SlotState::Down {
                consecutive_failures,
                ..
            } => consecutive_failures,
        }
    }

    /// Diagnostic label: the dialed address, or "static" for slots the
    /// cluster cannot rebuild.
    pub fn label(&self) -> String {
        match &self.origin {
            SlotOrigin::Tcp { addrs, .. } => addrs
                .first()
                .map(|a| a.to_string())
                .unwrap_or_else(|| "tcp:<unresolved>".into()),
            SlotOrigin::Static => "static".into(),
        }
    }
}

/// One node's dial plan: the primary address set plus optional standby
/// addresses [`ShardCluster::heal`] may promote into the slot when the
/// primary stays Down past [`ReconnectPolicy::promote_after`].  CLI
/// syntax (`serve --nodes`): `host:port|standby_host:port[|...]` --
/// everything after the first `|` is a standby.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    /// resolved primary addresses (first reachable wins on dial)
    pub primary: Vec<SocketAddr>,
    /// resolved standby addresses, in promotion preference order
    pub standbys: Vec<SocketAddr>,
}

impl NodeSpec {
    /// Parse `host:port[|standby_host:port[|...]]`, resolving every
    /// address up front (reconnects and promotions re-dial the resolved
    /// set; a DNS outage during recovery must not keep a slot Down).
    pub fn parse(spec: &str) -> Result<NodeSpec> {
        let mut parts = spec.split('|').map(str::trim);
        let first = parts
            .next()
            .filter(|s| !s.is_empty())
            .ok_or_else(|| anyhow!("node spec {spec:?} has no primary address"))?;
        let primary = resolve(&first)?;
        let mut standbys = Vec::new();
        for p in parts {
            ensure!(!p.is_empty(), "node spec {spec:?} has an empty standby address");
            standbys.extend(resolve(&p)?);
        }
        Ok(NodeSpec { primary, standbys })
    }

    /// A spec from already-resolved addresses (tests, embedding).
    pub fn with_standbys(
        primary: Vec<SocketAddr>,
        standbys: Vec<SocketAddr>,
    ) -> NodeSpec {
        NodeSpec { primary, standbys }
    }
}

/// The row-local compute one worker node runs on its shard -- for the
/// serving pipeline this is the full stage chain
/// ([`super::pipeline::Pipeline::shard_fn`]); tests substitute synthetic
/// models.
pub type ShardFn = Arc<dyn Fn(Tensor) -> Result<Tensor> + Send + Sync>;

/// Payload-consuming worker compute: the shard arrives still in its
/// transported form, so a pipeline with stage plans can feed the
/// compressed banks straight into the compressed-domain kernel
/// ([`super::pipeline::Pipeline::payload_shard_fn`]) instead of paying a
/// decode at the node boundary.
pub type PayloadShardFn = Arc<dyn Fn(Payload) -> Result<Tensor> + Send + Sync>;

/// Adapt a dense-entry [`ShardFn`] to the payload-consuming worker
/// interface: the payload is decoded lazily at the node, exactly the
/// pre-plan behavior.
pub fn dense_entry(compute: ShardFn, enc: EncoderConfig) -> PayloadShardFn {
    Arc::new(move |p: Payload| compute(p.into_dense(&enc)))
}

/// Spawn a worker thread servicing `link` until the coordinator hangs
/// up.  Each frame's payload is handed to `compute` in transported form
/// (dense-entry models decode via [`dense_entry`]), and the result is
/// re-gated and framed for the reply; failures reply with an error frame
/// instead of killing the node.  Generic over the link, so the same
/// worker loop backs loopback clusters here and socket connections in
/// [`super::node`].
pub fn spawn_worker<L: NodeLink + 'static>(
    mut link: L,
    compute: PayloadShardFn,
    enc: EncoderConfig,
    label: String,
) -> JoinHandle<()> {
    std::thread::spawn(move || loop {
        let frame = match link.recv() {
            Ok(f) => f,
            Err(_) => break, // coordinator gone: shut down
        };
        let reply = run_frame(&frame, &compute, &enc)
            .unwrap_or_else(|e| wire::error_frame(&format!("{label}: {e:#}")));
        if link.send(reply).is_err() {
            break;
        }
    })
}

/// Service one shard frame: decode, compute, re-gate, frame the reply.
/// Shared by [`spawn_worker`] and the node agent's connection loop.
pub(crate) fn run_frame(
    frame: &[u8],
    compute: &PayloadShardFn,
    enc: &EncoderConfig,
) -> Result<Vec<u8>> {
    let payload = wire::payload_from_bytes(frame)?;
    let out = compute(payload)?;
    wire::payload_to_bytes(&Payload::from_tensor(out, enc))
}

/// Contiguous near-equal row ranges over `nodes` workers; nodes beyond
/// the row count get no range.  Shards are in row order, so per-shard
/// results concatenate back in batch order.
pub fn shard_ranges(rows: usize, nodes: usize) -> Vec<(usize, usize)> {
    let nodes = nodes.max(1);
    let per = rows.div_ceil(nodes).max(1);
    (0..nodes)
        .map(|i| (i * per, rows.min((i + 1) * per)))
        .filter(|&(lo, hi)| lo < hi)
        .collect()
}

fn slice_payload(p: &Payload, lo: usize, hi: usize) -> Result<Payload> {
    match p {
        Payload::Compressed(ct) => Ok(Payload::Compressed(ct.slice_rows(lo, hi)?)),
        Payload::Dense(t) => {
            ensure!(
                t.shape.len() >= 2,
                "row slice needs a batch axis, got {:?}",
                t.shape
            );
            let row: usize = t.shape[1..].iter().product();
            let mut shape = t.shape.clone();
            shape[0] = hi - lo;
            Ok(Payload::Dense(Tensor::new(
                shape,
                // lint: allow(index): callers pass lo <= hi <= shape[0]
                // (Router::shards_for geometry) and data.len() is the
                // shape product, so hi * row <= len
                t.data[lo * row..hi * row].to_vec(),
            )?))
        }
    }
}

/// A cluster of worker nodes behind supervised [`NodeSlot`]s, plus the
/// split / reassemble logic the coordinator runs around them.
///
/// Failure semantics: a link-level send/recv failure takes the slot
/// Down and the lost shard is **re-dispatched onto surviving slots**
/// (bounded by [`RetryPolicy`] and the batch deadline), so a node death
/// is masked from callers while at least one slot survives and
/// deadlines permit.  The drain invariant is unchanged and holds per
/// attempt: every link sent a frame is drained before the batch
/// resolves.  Subsequent batches plan over the live slots only, and
/// [`ShardCluster::heal`] re-dials Down TCP slots on the
/// [`ReconnectPolicy`] backoff (promoting to a standby address past
/// [`ReconnectPolicy::promote_after`]).  An *application* failure
/// (error frame, mis-shaped reply) fails the batch, leaves the slot Up
/// (the link itself held), and is never retried -- recomputing a
/// deterministic failure elsewhere buys nothing.
pub struct ShardCluster {
    slots: Vec<NodeSlot>,
    workers: Vec<JoinHandle<()>>,
    enc: EncoderConfig,
    reconnect: ReconnectPolicy,
    retry: RetryPolicy,
    /// where the next [`ShardCluster::heal`] pass starts scanning: the
    /// slot after the one that spent the last budget unit, so re-dial
    /// attempts rotate across Down slots instead of starving the
    /// highest-indexed ones
    heal_cursor: usize,
}

impl ShardCluster {
    /// Spawn `nodes` loopback workers, all running the dense-entry
    /// `compute` on their row shards (shards decode at the node).
    pub fn loopback(nodes: usize, compute: ShardFn, enc: EncoderConfig) -> ShardCluster {
        Self::loopback_payload(nodes, dense_entry(compute, enc), enc)
    }

    /// Spawn `nodes` loopback workers running a payload-consuming
    /// compute -- the entry point for planned pipelines whose stage
    /// workers claim compressed shards without decoding.
    pub fn loopback_payload(
        nodes: usize,
        compute: PayloadShardFn,
        enc: EncoderConfig,
    ) -> ShardCluster {
        let mut slots = Vec::new();
        let mut workers = Vec::new();
        for i in 0..nodes.max(1) {
            let (coord, node) = loopback_pair();
            workers.push(spawn_worker(node, compute.clone(), enc, format!("node {i}")));
            slots.push(NodeSlot::up(Box::new(coord), SlotOrigin::Static));
        }
        ShardCluster {
            slots,
            workers,
            enc,
            reconnect: ReconnectPolicy::default(),
            retry: RetryPolicy::default(),
            heal_cursor: 0,
        }
    }

    /// Drive remote node agents over localhost/network TCP: one
    /// [`TcpLink`] per address, handshake on connect.  The coordinator
    /// treats the resulting cluster exactly like a loopback one -- same
    /// split/reassemble, same drain-after-failure invariant when a peer
    /// dies mid-batch -- and remembers each address, so a slot whose
    /// peer dies is re-dialed by [`ShardCluster::heal`] instead of
    /// staying dead for the cluster's lifetime.
    pub fn connect<A: ToSocketAddrs>(
        addrs: &[A],
        enc: EncoderConfig,
    ) -> Result<ShardCluster> {
        Self::connect_timeout(addrs, enc, None)
    }

    /// [`ShardCluster::connect`] with a per-I/O activity timeout on
    /// every link (see [`TcpLink::connect_timeout`]): the serving
    /// path's guard against a hung-but-not-dead peer.
    pub fn connect_timeout<A: ToSocketAddrs>(
        addrs: &[A],
        enc: EncoderConfig,
        io_timeout: Option<Duration>,
    ) -> Result<ShardCluster> {
        let specs = addrs
            .iter()
            .enumerate()
            .map(|(i, a)| {
                Ok(NodeSpec {
                    primary: resolve(a).with_context(|| format!("node {i}"))?,
                    standbys: Vec::new(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Self::connect_specs(&specs, enc, io_timeout)
    }

    /// [`ShardCluster::connect_timeout`] over full [`NodeSpec`]s: each
    /// slot dials its primary addresses now and remembers its standbys
    /// for [`ShardCluster::heal`]'s promotion path.
    pub fn connect_specs(
        specs: &[NodeSpec],
        enc: EncoderConfig,
        io_timeout: Option<Duration>,
    ) -> Result<ShardCluster> {
        ensure!(!specs.is_empty(), "cluster needs at least one node address");
        let mut slots = Vec::with_capacity(specs.len());
        for (i, spec) in specs.iter().enumerate() {
            let link = TcpLink::dial(&spec.primary, io_timeout, io_timeout)
                .with_context(|| format!("node {i}"))?;
            slots.push(NodeSlot::up(
                Box::new(link),
                SlotOrigin::Tcp {
                    addrs: spec.primary.clone(),
                    standbys: spec.standbys.clone(),
                    io_timeout,
                },
            ));
        }
        Ok(ShardCluster {
            slots,
            workers: Vec::new(),
            enc,
            reconnect: ReconnectPolicy::default(),
            retry: RetryPolicy::default(),
            heal_cursor: 0,
        })
    }

    /// A cluster over caller-built links (mixed transports, tests).  The
    /// cluster owns no worker threads for these; whatever serves the far
    /// end of each link outlives it.  Caller-built slots are static: the
    /// cluster has no recipe to rebuild them, so a failed one stays Down.
    pub fn from_links(
        links: Vec<Box<dyn NodeLink>>,
        enc: EncoderConfig,
    ) -> ShardCluster {
        ShardCluster {
            slots: links
                .into_iter()
                .map(|l| NodeSlot::up(l, SlotOrigin::Static))
                .collect(),
            workers: Vec::new(),
            enc,
            reconnect: ReconnectPolicy::default(),
            retry: RetryPolicy::default(),
            heal_cursor: 0,
        }
    }

    /// Override the reconnect/backoff policy (chaos tests tighten it;
    /// the default suits serving).
    pub fn set_reconnect_policy(&mut self, policy: ReconnectPolicy) {
        self.reconnect = policy;
    }

    /// Override the shard-retry policy ([`RetryPolicy::disabled`]
    /// restores fail-the-batch semantics; the default suits serving).
    pub fn set_retry_policy(&mut self, policy: RetryPolicy) {
        self.retry = policy;
    }

    /// True when any slot is Down: the router plans degraded batches
    /// with retry headroom (see
    /// [`super::router::Router::shards_for_resilient`]).
    pub fn is_degraded(&self) -> bool {
        self.slots.iter().any(|s| !s.state.is_up())
    }

    /// Total slots, live or not.
    pub fn nodes(&self) -> usize {
        self.slots.len()
    }

    /// Slots currently Up: the plannable fan-out ceiling.
    pub fn live_nodes(&self) -> usize {
        self.slots.iter().filter(|s| s.state.is_up()).count()
    }

    /// Supervision snapshot, indexed by slot.
    pub fn slot_states(&self) -> Vec<SlotState> {
        self.slots.iter().map(|s| s.state).collect()
    }

    /// Push every slot's current health into `metrics` (the server does
    /// this once at startup; transitions update incrementally).
    pub fn publish_health(&self, metrics: &Metrics) {
        for (i, s) in self.slots.iter().enumerate() {
            metrics.set_node_health(
                i,
                &s.label(),
                s.state.is_up(),
                s.reconnects,
                s.consecutive_failures() as u64,
                s.promotions,
            );
        }
    }

    /// Take slot `node` Down after a link-level failure: the link is
    /// dropped (a poisoned socket is dead anyway) and a TCP slot is
    /// scheduled for its first re-dial one backoff step from now.
    fn mark_down(&mut self, node: usize, metrics: Option<&Metrics>) {
        let failures = self.slots[node].consecutive_failures().saturating_add(1);
        let slot = &mut self.slots[node];
        slot.link = None;
        slot.state = match slot.state {
            SlotState::Down { since, .. } => SlotState::Down {
                since,
                consecutive_failures: failures,
            },
            SlotState::Up => SlotState::Down {
                since: Instant::now(),
                consecutive_failures: failures,
            },
        };
        slot.next_attempt = Instant::now() + backoff_delay(failures, &self.reconnect);
        if let Some(m) = metrics {
            let label = slot.label();
            m.set_node_health(
                node,
                &label,
                false,
                slot.reconnects,
                failures as u64,
                slot.promotions,
            );
        }
    }

    /// Bounded reconnect pass: every Down TCP slot whose backoff delay
    /// has elapsed gets one re-dial (connect + handshake), up to
    /// [`ReconnectPolicy::attempts_per_heal`] attempts total, each dial
    /// bounded by [`ReconnectPolicy::connect_timeout`] -- reconnect
    /// work amortizes across batches and never stalls serving on a
    /// still-dead peer.  The scan starts at a **persisted cursor** (the
    /// slot after the one that spent the last budget unit), so with
    /// more Down slots than budget the attempts rotate round-robin
    /// instead of starving the highest-indexed slots.  A slot Down past
    /// [`ReconnectPolicy::promote_after`] with standby addresses dials
    /// the standby first and **promotes** it into the slot on success
    /// (the old primary becomes the standby, so a later death falls
    /// back the other way); the primary is still tried in the same
    /// attempt when the standby is unreachable.  Static slots have
    /// nothing to re-dial and stay Down.  Returns the live-slot count.
    ///
    /// Called automatically at the top of [`ShardCluster::infer_on`];
    /// callers that need the live count *before* planning fan-out (the
    /// server does) call it directly -- attempts are gated on the
    /// backoff clock, so back-to-back passes are near-free.
    pub fn heal(&mut self, metrics: Option<&Metrics>) -> usize {
        let len = self.slots.len();
        if len == 0 {
            return 0;
        }
        let mut budget = self.reconnect.attempts_per_heal;
        let start = self.heal_cursor % len;
        for off in 0..len {
            if budget == 0 {
                break;
            }
            let i = (start + off) % len;
            let due = {
                let s = &self.slots[i];
                !s.state.is_up()
                    && matches!(s.origin, SlotOrigin::Tcp { .. })
                    && Instant::now() >= s.next_attempt
            };
            if !due {
                continue;
            }
            budget -= 1;
            self.heal_cursor = (i + 1) % len;
            let (primary, standbys, io_timeout) = {
                let SlotOrigin::Tcp {
                    ref addrs,
                    ref standbys,
                    io_timeout,
                } = self.slots[i].origin
                else {
                    // lint: allow(panic): the `due` guard above matched
                    // SlotOrigin::Tcp on this same slot, with &mut self
                    // held across both reads -- no other origin can appear
                    unreachable!("non-TCP slots are never due for re-dial");
                };
                (addrs.clone(), standbys.clone(), io_timeout)
            };
            let try_promote = !standbys.is_empty()
                && matches!(
                    self.slots[i].state,
                    SlotState::Down { since, .. }
                        if since.elapsed() >= self.reconnect.promote_after
                );
            let connect = Some(self.reconnect.connect_timeout);
            let mut promoted = false;
            let dialed = if try_promote {
                match TcpLink::dial(&standbys, connect, io_timeout) {
                    Ok(link) => {
                        promoted = true;
                        Ok(link)
                    }
                    // unreachable standby: the primary still gets its
                    // shot this attempt (a restart on the original
                    // address wins over a dead standby)
                    Err(_) => TcpLink::dial(&primary, connect, io_timeout),
                }
            } else {
                TcpLink::dial(&primary, connect, io_timeout)
            };
            match dialed {
                Ok(link) => {
                    let slot = &mut self.slots[i];
                    if promoted {
                        // the standby becomes the slot's primary and
                        // the old primary its standby
                        if let SlotOrigin::Tcp {
                            addrs, standbys, ..
                        } = &mut slot.origin
                        {
                            std::mem::swap(addrs, standbys);
                        }
                        slot.promotions += 1;
                        if let Some(m) = metrics {
                            m.record_standby_promotion();
                        }
                    }
                    slot.link = Some(Box::new(link));
                    slot.state = SlotState::Up;
                    slot.reconnects += 1;
                    slot.next_attempt = Instant::now();
                    if let Some(m) = metrics {
                        let label = slot.label();
                        m.set_node_health(
                            i,
                            &label,
                            true,
                            slot.reconnects,
                            0,
                            slot.promotions,
                        );
                    }
                }
                Err(_) => {
                    let failures =
                        self.slots[i].consecutive_failures().saturating_add(1);
                    let slot = &mut self.slots[i];
                    if let SlotState::Down { since, .. } = slot.state {
                        slot.state = SlotState::Down {
                            since,
                            consecutive_failures: failures,
                        };
                    }
                    slot.next_attempt =
                        Instant::now() + backoff_delay(failures, &self.reconnect);
                    if let Some(m) = metrics {
                        let label = slot.label();
                        m.set_node_health(
                            i,
                            &label,
                            false,
                            slot.reconnects,
                            failures as u64,
                            slot.promotions,
                        );
                    }
                }
            }
        }
        self.live_nodes()
    }

    /// Run one batch over every live node: split by rows, ship every
    /// shard's wire frame before collecting any reply (the nodes run
    /// concurrently), then reassemble the per-node results in batch
    /// order.  Per-node wire traffic is recorded into `metrics` when
    /// given.
    pub fn infer(&mut self, input: &Payload, metrics: Option<&Metrics>) -> Result<Tensor> {
        self.infer_on(self.slots.len(), input, metrics)
    }

    /// [`ShardCluster::infer`] with an explicit fan-out (clamped to the
    /// **live** slot count): the serving path picks it per batch via
    /// [`super::router::Router::shards_for`], so tiny batches stay on
    /// one node instead of paying per-shard framing for nothing.
    /// Equivalent to [`ShardCluster::infer_deadline`] with no deadline.
    pub fn infer_on(
        &mut self,
        fan_out: usize,
        input: &Payload,
        metrics: Option<&Metrics>,
    ) -> Result<Tensor> {
        self.infer_deadline(fan_out, input, None, metrics)
    }

    /// The fault-masking batch run: split by rows over the live slots,
    /// ship every shard before collecting any reply, and **re-dispatch
    /// shards lost to link-level failures onto the survivors** in
    /// further rounds, bounded by [`RetryPolicy::max_attempts`] and by
    /// `deadline` (the batch's earliest request deadline -- an expired
    /// batch is never retried, and an already-expired one never ships a
    /// frame at all).  A node death mid-batch therefore *delays* the
    /// batch instead of erroring it, for as long as at least one slot
    /// survives and deadlines permit.
    ///
    /// Per-shard recvs are bounded by `deadline` and by
    /// [`RetryPolicy::per_shard_timeout`] (via
    /// [`NodeLink::recv_deadline`]): a hung-but-alive straggler node is
    /// reclassified as a retryable link failure, not a batch-wide
    /// stall.
    ///
    /// The drain invariant holds **per attempt**: the cluster is
    /// long-lived, so every link sent a frame in a round is drained
    /// before the round resolves -- a reply left queued on a link would
    /// be collected by the *next* batch and silently deliver stale
    /// results one batch off, forever.  A link-level failure takes the
    /// slot Down (see [`ShardCluster::heal`]); an application failure
    /// (error frame, mis-shaped reply) is terminal for the batch and is
    /// never re-dispatched -- the compute is deterministic, so a retry
    /// would only recompute the same failure elsewhere.
    ///
    /// On failure the error names **every** failed shard with its node
    /// index and cause, not just the first.
    pub fn infer_deadline(
        &mut self,
        fan_out: usize,
        input: &Payload,
        deadline: Option<Instant>,
        metrics: Option<&Metrics>,
    ) -> Result<Tensor> {
        let shape = input.shape();
        ensure!(
            shape.len() >= 2,
            "cluster input needs a batch axis, got {shape:?}"
        );
        self.heal(metrics);
        let live: Vec<usize> = self.live_ids();
        ensure!(
            !live.is_empty(),
            "no live node slots ({} of {} down)",
            self.slots.len(),
            self.slots.len()
        );
        // an already-expired batch is refused before a single frame
        // ships: its recv deadlines are all in the past, so dispatching
        // would poison every healthy link for nothing
        if let Some(d) = deadline {
            ensure!(
                Instant::now() < d,
                "batch deadline expired before dispatch ({} rows never shipped)",
                shape[0]
            );
        }
        let plan = shard_ranges(shape[0], fan_out.clamp(1, live.len()));
        ensure!(!plan.is_empty(), "empty batch (0 rows)");

        struct ShardRun {
            lo: usize,
            hi: usize,
            attempts: usize,
            result: Option<Tensor>,
            /// per-attempt failure trail: (node, cause), oldest first
            failures: Vec<(usize, anyhow::Error)>,
            /// an application failure: no retry can help
            terminal: bool,
        }
        let mut shards: Vec<ShardRun> = plan
            .iter()
            .map(|&(lo, hi)| ShardRun {
                lo,
                hi,
                attempts: 0,
                result: None,
                failures: Vec::new(),
                terminal: false,
            })
            .collect();
        let max_attempts = self.retry.max_attempts.max(1);

        let mut round = 0usize;
        loop {
            let live = self.live_ids();
            let pending: Vec<usize> = shards
                .iter()
                .enumerate()
                .filter(|(_, s)| {
                    s.result.is_none() && !s.terminal && s.attempts < max_attempts
                })
                .map(|(i, _)| i)
                .collect();
            if pending.is_empty() || live.is_empty() {
                break;
            }
            // every round past the first is a retry: an expired batch
            // is never retried (its callers already count as failed)
            if round > 0 && deadline.is_some_and(|d| Instant::now() >= d) {
                break;
            }

            // round 0 assigns shard i to the i-th live slot (the plan
            // geometry the router chose); retry rounds spread the lost
            // shards round-robin over whoever is still live
            let mut sent: Vec<(usize, usize)> = Vec::new(); // (shard, node)
            for (j, &si) in pending.iter().enumerate() {
                // lint: allow(index): live is non-empty (checked at the
                // top of the round) and j % len is always in bounds
                let node = live[j % live.len()];
                let (lo, hi) = (shards[si].lo, shards[si].hi);
                // slicing/encoding failures are the batch's problem,
                // not the link's: terminal, and no slot changes state
                let framed = slice_payload(input, lo, hi).and_then(|part| {
                    let bytes = wire::payload_to_bytes(&part)?;
                    Ok((bytes, part.dense_bits() / 8))
                });
                let (bytes, dense_bytes) = match framed {
                    Ok(f) => f,
                    Err(e) => {
                        shards[si].terminal = true;
                        shards[si].failures.push((node, e));
                        continue;
                    }
                };
                let wire_bytes = bytes.len() as u64;
                shards[si].attempts += 1;
                let Some(link) = self.slots[node].link.as_mut() else {
                    // the slot was lost earlier in this same round (a
                    // send for another shard failed): a link-level
                    // loss, retryable next round
                    shards[si]
                        .failures
                        .push((node, anyhow!("node {node} went down mid-round")));
                    continue;
                };
                match link.send(bytes) {
                    Ok(()) => {
                        sent.push((si, node));
                        // recorded only after the link accepted the
                        // frame, so a dead node cannot inflate its
                        // transport stats
                        if let Some(m) = metrics {
                            m.record_node_tx(node, wire_bytes, dense_bytes);
                            if round > 0 {
                                m.record_shard_retry(node);
                            }
                        }
                    }
                    Err(e) => {
                        self.mark_down(node, metrics);
                        shards[si].failures.push((
                            node,
                            e.context(format!("sending shard to node {node}")),
                        ));
                    }
                }
            }

            // drain: every link sent a frame this round gives back
            // exactly one reply (or dies trying), even after earlier
            // failures -- the invariant that keeps long-lived links
            // batch-synchronized.  Per node, recvs run in send order.
            for (si, node) in sent {
                // the link can be gone already: a send to this node for
                // a LATER shard in the same round failed and downed it
                let Some(link) = self.slots[node].link.as_mut() else {
                    shards[si].failures.push((
                        node,
                        anyhow!("node {node} link lost before its reply"),
                    ));
                    continue;
                };
                let recv_by = match (deadline, self.retry.per_shard_timeout) {
                    (Some(d), Some(t)) => Some(d.min(Instant::now() + t)),
                    (Some(d), None) => Some(d),
                    (None, Some(t)) => Some(Instant::now() + t),
                    (None, None) => None,
                };
                let frame = match link.recv_deadline(recv_by) {
                    Ok(f) => f,
                    Err(e) => {
                        // straggler conversion lands here too: a recv
                        // deadline miss is a link failure, and the
                        // shard is retryable on a survivor
                        self.mark_down(node, metrics);
                        shards[si].failures.push((
                            node,
                            e.context(format!("collecting node {node}")),
                        ));
                        continue;
                    }
                };
                let rows = shards[si].hi - shards[si].lo;
                // a decode error or row mismatch is an application
                // failure on a link that held: the slot stays in the
                // rotation, the shard is not retried
                let decoded = (|| -> Result<Tensor> {
                    let reply = wire::payload_from_bytes(&frame)
                        .with_context(|| format!("node {node} reply"))?;
                    ensure!(
                        reply.shape().first() == Some(&rows),
                        "node {node} returned shape {:?} for a {rows}-row shard",
                        reply.shape()
                    );
                    if let Some(m) = metrics {
                        m.record_node_rx(
                            node,
                            frame.len() as u64,
                            reply.dense_bits() / 8,
                        );
                    }
                    Ok(reply.into_dense(&self.enc))
                })();
                match decoded {
                    Ok(t) => shards[si].result = Some(t),
                    Err(e) => {
                        shards[si].terminal = true;
                        shards[si].failures.push((node, e));
                    }
                }
            }
            round += 1;
        }

        let failed = shards.iter().filter(|s| s.result.is_none()).count();
        if failed > 0 {
            let mut causes = Vec::new();
            for (i, s) in shards.iter().enumerate() {
                if s.result.is_some() {
                    continue;
                }
                for (node, e) in &s.failures {
                    causes.push(format!(
                        "shard {i} (rows {}..{}) node {node}: {e:#}",
                        s.lo, s.hi
                    ));
                }
            }
            let expired = deadline.is_some_and(|d| Instant::now() >= d);
            let note = if expired {
                " (batch deadline expired; retries refused)"
            } else {
                ""
            };
            return Err(anyhow!(
                "{failed} of {} shards failed{note}: [{}]",
                shards.len(),
                causes.join("; ")
            ));
        }
        let parts: Vec<Tensor> = shards
            .into_iter()
            .map(|s| {
                s.result.ok_or_else(|| {
                    anyhow!("internal: unfailed shard lost its result")
                })
            })
            .collect::<Result<_>>()?;
        Tensor::concat_batch(&parts)
    }

    /// Indices of the slots currently Up, in slot order.
    fn live_ids(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.state.is_up())
            .map(|(i, _)| i)
            .collect()
    }

    /// Hang up every link and join the workers.
    pub fn shutdown(self) {
        drop(self.slots);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::coordinator::node::{spawn_local_agents, NodeAgent};

    /// Every cluster test below runs against both transports: the
    /// in-process loopback link and real localhost TCP sockets served
    /// by [`NodeAgent`]s.  This is the conformance contract -- above
    /// the link layer the two are indistinguishable.
    const TRANSPORTS: [&str; 2] = ["loopback", "tcp"];

    /// Build a cluster over the named transport; the returned agents
    /// (TCP only) must outlive the cluster and be shut down after it.
    fn cluster_on(
        transport: &str,
        nodes: usize,
        compute: PayloadShardFn,
        enc: EncoderConfig,
    ) -> (ShardCluster, Vec<NodeAgent>) {
        match transport {
            "loopback" => (
                ShardCluster::loopback_payload(nodes, compute, enc),
                Vec::new(),
            ),
            "tcp" => {
                let (agents, addrs) =
                    spawn_local_agents(nodes, compute, enc).unwrap();
                (ShardCluster::connect(&addrs, enc).unwrap(), agents)
            }
            t => panic!("unknown transport {t}"),
        }
    }

    fn dense_cluster_on(
        transport: &str,
        nodes: usize,
        compute: ShardFn,
        enc: EncoderConfig,
    ) -> (ShardCluster, Vec<NodeAgent>) {
        cluster_on(transport, nodes, dense_entry(compute, enc), enc)
    }

    fn teardown(cluster: ShardCluster, agents: Vec<NodeAgent>) {
        cluster.shutdown();
        for a in agents {
            a.shutdown();
        }
    }

    /// Row-local toy model (deliberately simpler than the synthetic
    /// classifier the integration tests use): out[r][c] = (c+1) * sum(row).
    /// Row-locality is what makes shard + concat equal single-node.
    fn synth(classes: usize) -> ShardFn {
        Arc::new(move |t: Tensor| {
            ensure!(t.shape.len() >= 2, "need a batch axis");
            let rows = t.shape[0];
            let row: usize = t.shape[1..].iter().product();
            let mut out = vec![0f32; rows * classes];
            for r in 0..rows {
                let s: f32 = t.data[r * row..(r + 1) * row].iter().sum();
                for (c, slot) in out[r * classes..(r + 1) * classes]
                    .iter_mut()
                    .enumerate()
                {
                    *slot = s * (c + 1) as f32;
                }
            }
            Tensor::new(vec![rows, classes], out)
        })
    }

    fn enc() -> EncoderConfig {
        EncoderConfig {
            shards: 1,
            min_sparsity: 0.10,
            parallel_threshold: usize::MAX,
        }
    }

    #[test]
    fn shard_ranges_cover_and_order() {
        for (rows, nodes) in [(8, 2), (8, 3), (3, 4), (1, 4), (16, 1), (5, 5)] {
            let plan = shard_ranges(rows, nodes);
            assert!(plan.len() <= nodes.max(1));
            assert_eq!(plan[0].0, 0);
            assert_eq!(plan.last().unwrap().1, rows);
            for w in plan.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous ({rows}, {nodes})");
            }
        }
        assert!(shard_ranges(0, 3).is_empty());
    }

    #[test]
    fn reconnect_backoff_doubles_and_caps_without_wall_clock() {
        let p = ReconnectPolicy {
            base: Duration::from_millis(100),
            cap: Duration::from_secs(1),
            connect_timeout: Duration::from_millis(250),
            attempts_per_heal: 2,
            promote_after: Duration::from_secs(10),
        };
        // failure counts 0 and 1 both wait one base step
        assert_eq!(backoff_delay(0, &p), Duration::from_millis(100));
        assert_eq!(backoff_delay(1, &p), Duration::from_millis(100));
        assert_eq!(backoff_delay(2, &p), Duration::from_millis(200));
        assert_eq!(backoff_delay(3, &p), Duration::from_millis(400));
        assert_eq!(backoff_delay(4, &p), Duration::from_millis(800));
        assert_eq!(backoff_delay(5, &p), Duration::from_secs(1), "capped");
        assert_eq!(
            backoff_delay(u32::MAX, &p),
            Duration::from_secs(1),
            "arbitrarily large failure counts saturate instead of overflowing"
        );
    }

    #[test]
    fn cluster_matches_single_node_for_all_shard_counts() {
        let t = Tensor::random_sparse(vec![8, 3, 4, 25], 0.6, 31);
        let expect = synth(10)(t.clone()).unwrap();
        for transport in TRANSPORTS {
            for nodes in [1usize, 2, 3, 4, 8] {
                let (mut cluster, agents) =
                    dense_cluster_on(transport, nodes, synth(10), enc());
                let out = cluster
                    .infer(&Payload::Dense(t.clone()), None)
                    .unwrap();
                assert_eq!(out, expect, "{transport}: {nodes} nodes");
                teardown(cluster, agents);
            }
        }
    }

    #[test]
    fn compressed_input_stays_compressed_on_the_wire() {
        let t = Tensor::random_sparse(vec![4, 3, 4, 25], 0.8, 32);
        let e = enc();
        let p = Payload::from_tensor(t.clone(), &e);
        assert!(p.is_compressed());
        for transport in TRANSPORTS {
            let m = Metrics::default();
            let (mut cluster, agents) =
                dense_cluster_on(transport, 2, synth(6), e);
            let out = cluster.infer(&p, Some(&m)).unwrap();
            assert_eq!(out, synth(6)(t.clone()).unwrap(), "{transport}");
            teardown(cluster, agents);
            let nodes = m.node_transport();
            assert_eq!(nodes.len(), 2, "{transport}");
            for (i, n) in nodes.iter().enumerate() {
                assert_eq!(n.shards, 1, "{transport}: node {i}");
                // a 80%-sparse shard's frame is far smaller than dense
                assert!(
                    n.tx_wire_bytes < n.tx_dense_bytes / 2,
                    "{transport}: node {i}: {} vs {}",
                    n.tx_wire_bytes,
                    n.tx_dense_bytes
                );
                assert!(n.saving() > 0.0);
            }
        }
    }

    #[test]
    fn more_nodes_than_rows_leaves_tail_nodes_idle() {
        let t = Tensor::random_sparse(vec![2, 3, 4, 25], 0.5, 33);
        let expect = synth(4)(t.clone()).unwrap();
        for transport in TRANSPORTS {
            let m = Metrics::default();
            let (mut cluster, agents) =
                dense_cluster_on(transport, 4, synth(4), enc());
            let out = cluster
                .infer(&Payload::Dense(t.clone()), Some(&m))
                .unwrap();
            assert_eq!(out, expect, "{transport}");
            teardown(cluster, agents);
            let nodes = m.node_transport();
            assert_eq!(
                nodes.len(),
                2,
                "{transport}: only the first two nodes saw work"
            );
        }
    }

    #[test]
    fn payload_workers_consume_compressed_shards_without_decode() {
        use crate::rfc::kernel::{self, GemmF32, KernelConfig};
        use std::sync::atomic::{AtomicU64, Ordering};
        let (k, n) = (64usize, 6usize);
        let w: Vec<f32> = (0..k * n).map(|i| ((i % 13) as f32 - 6.0) / 8.0).collect();
        let gemm = Arc::new(GemmF32::new(w, k, n).unwrap());
        let elided = Arc::new(AtomicU64::new(0));
        // a worker compute that never decodes a compressed shard: the
        // banks go straight through the compressed-domain kernel
        let compute: PayloadShardFn = {
            let gemm = gemm.clone();
            let elided = elided.clone();
            Arc::new(move |p: Payload| match p {
                Payload::Compressed(ct) => {
                    elided.fetch_add(1, Ordering::Relaxed);
                    let (y, _) =
                        kernel::spmm_f32(&ct, &gemm, &KernelConfig::serial())?;
                    Ok(y)
                }
                Payload::Dense(t) => {
                    let m = t.shape[0];
                    let out = kernel::gemm_dense_f32(&t.data, m, &gemm);
                    Tensor::new(vec![m, n], out)
                }
            })
        };
        let t = Tensor::random_sparse(vec![8, k], 0.8, 51);
        let e = enc();
        let p = Payload::from_tensor(t.clone(), &e);
        assert!(p.is_compressed());
        let expect = kernel::gemm_dense_f32(&t.data, 8, &gemm);
        for transport in TRANSPORTS {
            elided.store(0, Ordering::Relaxed);
            let (mut cluster, agents) =
                cluster_on(transport, 2, compute.clone(), e);
            let out = cluster.infer(&p, None).unwrap();
            teardown(cluster, agents);
            assert_eq!(out.shape, vec![8, n], "{transport}");
            assert_eq!(out.data, expect, "{transport}");
            assert_eq!(
                elided.load(Ordering::Relaxed),
                2,
                "{transport}: both shards arrived compressed and skipped \
                 the decode"
            );
        }
    }

    #[test]
    fn worker_errors_surface_without_hanging() {
        use std::sync::atomic::Ordering;
        let failing: ShardFn =
            Arc::new(|_t| Err(anyhow!("synthetic stage failure")));
        let t = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 34);
        for transport in TRANSPORTS {
            let m = Metrics::default();
            let (mut cluster, agents) =
                dense_cluster_on(transport, 2, failing.clone(), enc());
            let err = cluster
                .infer(&Payload::Dense(t.clone()), Some(&m))
                .unwrap_err();
            assert!(
                format!("{err:#}").contains("synthetic stage failure"),
                "{transport}: {err:#}"
            );
            // an error *frame* is an application failure on a healthy
            // link: the slots must all still be in the rotation, and --
            // even with retry on by default -- the deterministic
            // failure must never have been re-dispatched
            assert_eq!(cluster.live_nodes(), 2, "{transport}");
            assert_eq!(
                m.shard_retries.load(Ordering::Relaxed),
                0,
                "{transport}: an application error frame was retried"
            );
            teardown(cluster, agents);
        }
    }

    #[test]
    fn cluster_stays_synchronized_after_a_failed_batch() {
        // one worker fails on exactly one shard; the coordinator must
        // drain every in-flight reply so the *next* batch gets its own
        // results, not the failed batch's leftovers shifted by one
        use std::sync::atomic::{AtomicUsize, Ordering};
        let reference = synth(4);
        let t1 = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 41);
        let t2 = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 42);
        for transport in TRANSPORTS {
            let inner = synth(4);
            let calls = Arc::new(AtomicUsize::new(0));
            let counter = calls.clone();
            let flaky: ShardFn = Arc::new(move |t: Tensor| {
                if counter.fetch_add(1, Ordering::SeqCst) == 0 {
                    Err(anyhow!("transient stage failure"))
                } else {
                    inner(t)
                }
            });
            let (mut cluster, agents) =
                dense_cluster_on(transport, 2, flaky, enc());
            let err = cluster
                .infer(&Payload::Dense(t1.clone()), None)
                .unwrap_err();
            assert!(
                format!("{err:#}").contains("transient"),
                "{transport}: {err:#}"
            );
            // the very next batch on the same cluster must be correct
            let out = cluster
                .infer(&Payload::Dense(t2.clone()), None)
                .unwrap();
            assert_eq!(out, reference(t2.clone()).unwrap(), "{transport}");
            assert_eq!(
                calls.load(Ordering::SeqCst),
                4,
                "{transport}: 2 shards x 2 batches"
            );
            teardown(cluster, agents);
        }
    }

    #[test]
    fn fan_out_keeps_small_batches_on_fewer_nodes() {
        let t = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 43);
        let expect = synth(5)(t.clone()).unwrap();
        for transport in TRANSPORTS {
            let m = Metrics::default();
            let (mut cluster, agents) =
                dense_cluster_on(transport, 4, synth(5), enc());
            let out = cluster
                .infer_on(2, &Payload::Dense(t.clone()), Some(&m))
                .unwrap();
            assert_eq!(out, expect, "{transport}");
            teardown(cluster, agents);
            // only the first 2 nodes saw frames despite 4 available
            assert_eq!(m.node_transport().len(), 2, "{transport}");
            // degenerate fan-outs clamp instead of panicking
            let (mut one, one_agents) =
                dense_cluster_on(transport, 1, synth(5), enc());
            let t = Tensor::random_sparse(vec![2, 3, 4, 25], 0.5, 44);
            assert!(one.infer_on(0, &Payload::Dense(t.clone()), None).is_ok());
            assert!(one.infer_on(9, &Payload::Dense(t), None).is_ok());
            teardown(one, one_agents);
        }
    }

    #[test]
    fn wrong_row_count_from_a_node_is_rejected() {
        // a "model" that drops the batch axis contract
        let bad: ShardFn = Arc::new(|t| {
            let rows = t.shape[0] + 1;
            Ok(Tensor::zeros(vec![rows, 2]))
        });
        let t = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 35);
        for transport in TRANSPORTS {
            let (mut cluster, agents) =
                dense_cluster_on(transport, 2, bad.clone(), enc());
            assert!(
                cluster.infer(&Payload::Dense(t.clone()), None).is_err(),
                "{transport}"
            );
            // a mis-shaped reply arrived over a healthy link: the slot
            // stays Up (the model is broken, not the transport)
            assert_eq!(cluster.live_nodes(), 2, "{transport}");
            teardown(cluster, agents);
        }
    }

    #[test]
    fn down_slot_is_skipped_not_fatal() {
        // kill node 1 out of 3, then run another batch: the in-flight
        // batch fails (link error), the slot goes Down, and the NEXT
        // batch routes around it over the live slots -- full result,
        // correct rows, no error.  Loopback kills the worker thread via
        // a sentinel-triggered panic (its channels close); TCP shuts the
        // whole agent down.
        const SENTINEL: f32 = 1.0e9;
        let reference = synth(4);
        let t2 = Tensor::random_sparse(vec![6, 3, 4, 25], 0.5, 62);
        for transport in TRANSPORTS {
            let inner = synth(4);
            let killer: ShardFn = Arc::new(move |t: Tensor| {
                if t.data.contains(&SENTINEL) {
                    panic!("chaos: worker killed by sentinel shard");
                }
                inner(t)
            });
            let (mut cluster, mut agents) =
                dense_cluster_on(transport, 3, killer, enc());
            // reconnects stay out of this test: a dead TCP agent's port
            // could be re-dialed, which is the *heal* path -- here we
            // prove routing-around alone.  Retry is off too: the
            // sentinel shard would cascade-kill every worker it was
            // re-dispatched to, and this test is about the Down slot
            // leaving the rotation, not about masking.
            cluster.set_reconnect_policy(ReconnectPolicy {
                base: Duration::from_secs(3600),
                ..ReconnectPolicy::default()
            });
            cluster.set_retry_policy(RetryPolicy::disabled());
            let m = Metrics::default();
            // 6 rows over 3 nodes: rows 2..4 are node 1's shard
            let mut t1 = Tensor::random_sparse(vec![6, 3, 4, 25], 0.5, 61);
            match transport {
                "loopback" => {
                    let row: usize = t1.shape[1..].iter().product();
                    t1.data[2 * row] = SENTINEL;
                }
                _ => agents.remove(1).shutdown(),
            }
            let err = cluster
                .infer(&Payload::Dense(t1), Some(&m))
                .unwrap_err();
            assert!(
                format!("{err:#}").contains("node 1"),
                "{transport}: {err:#}"
            );
            assert_eq!(cluster.live_nodes(), 2, "{transport}");
            let health = m.node_health();
            assert!(!health[1].up, "{transport}");
            assert_eq!(health[1].consecutive_failures, 1, "{transport}");
            // the next batch must route around the Down slot
            let out = cluster
                .infer(&Payload::Dense(t2.clone()), Some(&m))
                .unwrap();
            assert_eq!(out, reference(t2.clone()).unwrap(), "{transport}");
            // and the Down slot saw no new shard frames (whether the
            // killed batch's own send was accepted or refused, nothing
            // beyond that single frame may have been shipped to it)
            let transport_stats = m.node_transport();
            assert!(
                transport_stats[1].shards <= 1,
                "{transport}: a routed-around slot got a new frame \
                 ({} shards)",
                transport_stats[1].shards
            );
            teardown(cluster, agents);
        }
    }

    #[test]
    fn tcp_peer_death_mid_batch_drains_the_live_nodes() {
        // kill node 1's agent while the cluster is connected: the next
        // batch fails (link error, not a hang), but node 0's in-flight
        // reply must still be drained -- a stale reply left queued
        // would be collected by the next batch and deliver wrong rows
        let (mut cluster, mut agents) =
            dense_cluster_on("tcp", 2, synth(4), enc());
        // retry off: this test proves the drain invariant in isolation
        // (with masking on, the batch would simply succeed)
        cluster.set_retry_policy(RetryPolicy::disabled());
        agents.remove(1).shutdown();
        let t1 = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 45);
        let err = cluster
            .infer(&Payload::Dense(t1), None)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("node 1"), "{msg}");
        // fan-out 1 hits only the (live, drained) node 0: the reply it
        // gets must be for *this* batch, which the row-count check and
        // the value assert both verify
        let t2 = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 46);
        let out = cluster
            .infer_on(1, &Payload::Dense(t2.clone()), None)
            .unwrap();
        assert_eq!(out, synth(4)(t2).unwrap());
        teardown(cluster, agents);
    }

    #[test]
    fn retry_masks_a_dead_node_within_one_batch() {
        // kill node 1 of 3 with no warning, then run a batch: the lost
        // shard re-dispatches onto a survivor and the caller sees the
        // full bit-exact result instead of an error
        use std::sync::atomic::Ordering;
        let m = Metrics::default();
        let (mut cluster, mut agents) =
            dense_cluster_on("tcp", 3, synth(4), enc());
        cluster.set_reconnect_policy(ReconnectPolicy {
            base: Duration::from_secs(3600),
            ..ReconnectPolicy::default()
        });
        agents.remove(1).shutdown();
        let t = Tensor::random_sparse(vec![6, 3, 4, 25], 0.5, 72);
        let out = cluster
            .infer(&Payload::Dense(t.clone()), Some(&m))
            .unwrap();
        assert_eq!(out, synth(4)(t).unwrap());
        assert_eq!(cluster.live_nodes(), 2);
        assert!(m.shard_retries.load(Ordering::Relaxed) >= 1);
        // the re-dispatch landed on a survivor, visible per slot
        let nt = m.node_transport();
        assert!(
            nt[0].retries + nt[2].retries >= 1,
            "no survivor recorded the retried shard: {nt:?}"
        );
        teardown(cluster, agents);
    }

    #[test]
    fn two_dead_nodes_both_appear_in_the_error() {
        // regression: the old get_or_insert error path silently dropped
        // every failure after the first -- the aggregated error must
        // name each failed shard's node and cause
        let (mut cluster, mut agents) =
            dense_cluster_on("tcp", 3, synth(4), enc());
        cluster.set_retry_policy(RetryPolicy::disabled());
        cluster.set_reconnect_policy(ReconnectPolicy {
            base: Duration::from_secs(3600),
            ..ReconnectPolicy::default()
        });
        agents.remove(2).shutdown();
        agents.remove(1).shutdown();
        let t1 = Tensor::random_sparse(vec![6, 3, 4, 25], 0.5, 74);
        let err = cluster.infer(&Payload::Dense(t1), None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("node 1") && msg.contains("node 2"),
            "one failure hid the other: {msg}"
        );
        assert_eq!(cluster.live_nodes(), 1);
        // node 0 drained: the next batch on the survivor is correct
        let t2 = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 75);
        let out = cluster
            .infer_on(1, &Payload::Dense(t2.clone()), None)
            .unwrap();
        assert_eq!(out, synth(4)(t2).unwrap());
        teardown(cluster, agents);
    }

    #[test]
    fn expired_deadline_is_refused_before_dispatch() {
        // an already-expired batch never ships a frame and never
        // retries: its recv deadlines are all in the past, so
        // dispatching would poison every healthy link for nothing
        use std::sync::atomic::Ordering;
        for transport in TRANSPORTS {
            let m = Metrics::default();
            let (mut cluster, agents) =
                dense_cluster_on(transport, 2, synth(4), enc());
            let t = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 71);
            let past = Instant::now() - Duration::from_millis(1);
            let err = cluster
                .infer_deadline(2, &Payload::Dense(t.clone()), Some(past), Some(&m))
                .unwrap_err();
            assert!(
                format!("{err:#}").contains("deadline"),
                "{transport}: {err:#}"
            );
            assert_eq!(
                m.shard_retries.load(Ordering::Relaxed),
                0,
                "{transport}: an expired batch dispatched a retry"
            );
            assert!(
                m.node_transport().is_empty(),
                "{transport}: an expired batch shipped a frame"
            );
            assert_eq!(cluster.live_nodes(), 2, "{transport}: links poisoned");
            // the cluster is fully usable for the next, unexpired batch
            let out = cluster
                .infer(&Payload::Dense(t.clone()), Some(&m))
                .unwrap();
            assert_eq!(out, synth(4)(t).unwrap(), "{transport}");
            teardown(cluster, agents);
        }
    }

    #[test]
    fn straggler_conversion_retries_a_hung_node_on_a_survivor() {
        // node 1's worker hangs far past the per-shard budget on its
        // first shard: the recv deadline reclassifies the straggler as
        // a link failure, the shard retries on node 0, and the caller
        // still gets the bit-exact batch -- no batch-wide stall
        use std::sync::atomic::{AtomicUsize, Ordering};
        const SLOW: f32 = 7.0e8;
        let reference = synth(3);
        for transport in TRANSPORTS {
            let m = Metrics::default();
            let inner = synth(3);
            let slept = Arc::new(AtomicUsize::new(0));
            let gate = slept.clone();
            // only the FIRST worker to see the sentinel hangs; the
            // retried dispatch computes promptly
            let sleepy: ShardFn = Arc::new(move |t: Tensor| {
                if t.data.contains(&SLOW)
                    && gate.fetch_add(1, Ordering::SeqCst) == 0
                {
                    std::thread::sleep(Duration::from_millis(800));
                }
                inner(t)
            });
            let (mut cluster, agents) =
                dense_cluster_on(transport, 2, sleepy, enc());
            cluster.set_reconnect_policy(ReconnectPolicy {
                base: Duration::from_secs(3600),
                ..ReconnectPolicy::default()
            });
            cluster.set_retry_policy(RetryPolicy {
                max_attempts: 3,
                per_shard_timeout: Some(Duration::from_millis(150)),
            });
            // 4 rows over 2 nodes: rows 2..4 are node 1's shard
            let mut t = Tensor::random_sparse(vec![4, 3, 4, 25], 0.5, 73);
            let row: usize = t.shape[1..].iter().product();
            t.data[2 * row] = SLOW;
            let expect = reference(t.clone()).unwrap();
            let out = cluster.infer(&Payload::Dense(t), Some(&m)).unwrap();
            assert_eq!(out, expect, "{transport}");
            // the hung node was converted to a Down slot, not waited on
            assert_eq!(cluster.live_nodes(), 1, "{transport}");
            assert_eq!(
                m.shard_retries.load(Ordering::Relaxed),
                1,
                "{transport}"
            );
            teardown(cluster, agents);
        }
    }

    #[test]
    fn heal_budget_rotates_across_down_slots() {
        // 3 Down TCP slots all pointing at a closed port, heal budget
        // 1, zero backoff (every slot is always due again).  Three heal
        // passes must spread three attempts one per slot -- pre-fix the
        // scan always started at slot 0 and slots 1/2 starved forever.
        let closed = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let a = l.local_addr().unwrap();
            drop(l);
            a
        };
        let down_slot = || NodeSlot {
            link: None,
            origin: SlotOrigin::Tcp {
                addrs: vec![closed],
                standbys: Vec::new(),
                io_timeout: None,
            },
            state: SlotState::Down {
                since: Instant::now(),
                consecutive_failures: 1,
            },
            next_attempt: Instant::now(),
            reconnects: 0,
            promotions: 0,
        };
        let mut cluster = ShardCluster {
            slots: vec![down_slot(), down_slot(), down_slot()],
            workers: Vec::new(),
            enc: enc(),
            reconnect: ReconnectPolicy {
                base: Duration::ZERO,
                cap: Duration::ZERO,
                connect_timeout: Duration::from_millis(100),
                attempts_per_heal: 1,
                promote_after: Duration::from_secs(3600),
            },
            retry: RetryPolicy::default(),
            heal_cursor: 0,
        };
        for pass in 0..3 {
            assert_eq!(cluster.heal(None), 0, "pass {pass}: nothing revives");
        }
        let failures: Vec<u32> = cluster
            .slots
            .iter()
            .map(|s| s.consecutive_failures())
            .collect();
        assert_eq!(
            failures,
            vec![2, 2, 2],
            "budget 1 x 3 passes must spend one attempt per slot \
             (pre-fix slot 0 ate all three)"
        );
    }

    #[test]
    fn node_spec_parses_primary_and_standbys() {
        let spec =
            NodeSpec::parse("127.0.0.1:7000|127.0.0.1:7001|127.0.0.1:7002")
                .unwrap();
        assert_eq!(spec.primary, vec!["127.0.0.1:7000".parse().unwrap()]);
        assert_eq!(
            spec.standbys,
            vec![
                "127.0.0.1:7001".parse().unwrap(),
                "127.0.0.1:7002".parse().unwrap()
            ]
        );
        let bare = NodeSpec::parse(" 127.0.0.1:7000 ").unwrap();
        assert_eq!(bare.primary.len(), 1);
        assert!(bare.standbys.is_empty());
        assert!(NodeSpec::parse("").is_err());
        assert!(NodeSpec::parse("127.0.0.1:7000|").is_err());
        assert!(NodeSpec::parse("|127.0.0.1:7000").is_err());
    }
}
