//! Request/response types flowing through the coordinator.

use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use crate::rfc::Payload;

/// A single inference request: one skeleton clip `(3, T, V)`.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// flattened `(3, T, V)` clip
    pub clip: Vec<f32>,
    pub seq_len: usize,
    pub arrived: Instant,
    /// absolute deadline this request is judged by everywhere
    /// downstream: stamped from the caller's latency budget
    /// ([`super::router::RouteInfo::deadline`]) or the admission
    /// policy's default, anchored at arrival.  The batcher reaps an
    /// expired request at formation time instead of padding a batch
    /// slot with it; delivery answers one that expired in flight with a
    /// deadline-exceeded failure instead of a stale result.
    pub deadline: Option<Instant>,
    /// where to deliver the response
    pub reply: Sender<Response>,
}

/// The answer for one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub predicted: usize,
    /// end-to-end latency (queue + batch + pipeline), seconds
    pub latency_s: f64,
    /// why serving failed for this request, when it did (`logits` is
    /// empty then).  A malformed request or a failed batch delivers one
    /// of these instead of silently disconnecting the reply channel.
    pub error: Option<String>,
    /// machine-readable backoff hint, set **only** on load-shed
    /// responses (the admission queue was full): retry after this long
    /// and the queue is guaranteed to have turned over or expired (see
    /// `docs/serving-front-door.md`).  `None` on every other failure --
    /// a malformed clip or a dead intake will not get better by
    /// retrying.
    pub retry_after: Option<Duration>,
}

impl Response {
    pub fn from_logits(id: u64, logits: Vec<f32>, arrived: Instant) -> Self {
        // total_cmp, not partial_cmp().unwrap(): a NaN logit (a bug
        // upstream, but one that must not take the delivery thread down
        // with it) orders deterministically instead of panicking -- see
        // `nan_logits_answer_instead_of_panicking`
        let predicted = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        Response {
            id,
            logits,
            predicted,
            latency_s: arrived.elapsed().as_secs_f64(),
            error: None,
            retry_after: None,
        }
    }

    /// A failure answer: no logits, an explanation instead.
    pub fn failure(id: u64, error: String, arrived: Instant) -> Self {
        Response {
            id,
            logits: Vec::new(),
            predicted: 0,
            latency_s: arrived.elapsed().as_secs_f64(),
            error: Some(error),
            retry_after: None,
        }
    }

    /// A load-shed answer: the bounded admission queue was full, the
    /// caller should back off `retry_after` before resubmitting.
    pub fn shed(id: u64, retry_after: Duration, arrived: Instant) -> Self {
        Response {
            retry_after: Some(retry_after),
            ..Self::failure(
                id,
                format!(
                    "overloaded: admission queue full, retry after {}ms",
                    retry_after.as_millis()
                ),
                arrived,
            )
        }
    }

    /// A deadline-exceeded answer: the request's absolute deadline (or
    /// the admission queue-residency bound) passed before a result
    /// could be delivered.
    pub fn deadline_exceeded(id: u64, arrived: Instant) -> Self {
        Self::failure(
            id,
            format!(
                "deadline exceeded: request waited {:.0}ms unserved",
                arrived.elapsed().as_secs_f64() * 1e3
            ),
            arrived,
        )
    }

    /// Whether this response carries logits rather than an error.
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    /// Whether this is a load-shed rejection (retryable per the
    /// `retry_after` hint), as opposed to a terminal failure.
    pub fn is_shed(&self) -> bool {
        self.retry_after.is_some()
    }
}

/// A formed batch heading into the pipeline.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    /// `(n, 3, T, V)` stacked input (n == artifact batch): compressed
    /// whenever the batch's zero content (sparse clips and/or padding
    /// rows, which are sidecar-only) beats dense transport, dense for a
    /// full batch of dense clips; padding rows are discarded on reply
    pub input: Payload,
    /// number of real (non-padding) rows
    pub real: usize,
    pub formed: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_argmax() {
        let r = Response::from_logits(
            3,
            vec![0.1, 2.0, -1.0],
            Instant::now(),
        );
        assert_eq!(r.predicted, 1);
        assert_eq!(r.id, 3);
        assert!(r.latency_s >= 0.0);
        assert!(r.is_ok());
    }

    // Regression (PR 10 lint sweep): `from_logits` used
    // `partial_cmp(..).unwrap()`, so a single NaN logit -- producible by
    // a buggy model artifact -- panicked the delivery thread and wedged
    // the server exactly like PR 5's debug_assert incident.  The caller
    // must always get an answer.
    #[test]
    fn nan_logits_answer_instead_of_panicking() {
        let r = Response::from_logits(
            7,
            vec![0.5, f32::NAN, 2.0],
            Instant::now(),
        );
        assert!(r.is_ok());
        assert_eq!(r.id, 7);
        // total_cmp orders NaN above every finite value, so the NaN slot
        // itself is the deterministic argmax -- the caller can see the
        // corrupt logit rather than a silently "plausible" class
        assert_eq!(r.predicted, 1);

        // all-NaN still answers deterministically
        let r = Response::from_logits(
            8,
            vec![f32::NAN, f32::NAN],
            Instant::now(),
        );
        assert!(r.is_ok());
        assert_eq!(r.predicted, 1);
    }

    #[test]
    fn failure_response_carries_the_error() {
        let r = Response::failure(9, "bad clip".into(), Instant::now());
        assert!(!r.is_ok());
        assert!(!r.is_shed());
        assert_eq!(r.error.as_deref(), Some("bad clip"));
        assert!(r.logits.is_empty());
        assert_eq!(r.id, 9);
    }

    #[test]
    fn shed_response_is_a_retryable_failure() {
        let r = Response::shed(4, Duration::from_millis(250), Instant::now());
        assert!(!r.is_ok());
        assert!(r.is_shed());
        assert_eq!(r.retry_after, Some(Duration::from_millis(250)));
        assert!(r.error.as_deref().unwrap().contains("retry after 250ms"));
    }

    #[test]
    fn deadline_exceeded_is_terminal_not_retryable() {
        let r = Response::deadline_exceeded(5, Instant::now());
        assert!(!r.is_ok());
        assert!(!r.is_shed());
        assert!(r.error.as_deref().unwrap().contains("deadline exceeded"));
    }
}
