//! Request/response types flowing through the coordinator.

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::rfc::Payload;

/// A single inference request: one skeleton clip `(3, T, V)`.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// flattened `(3, T, V)` clip
    pub clip: Vec<f32>,
    pub seq_len: usize,
    pub arrived: Instant,
    /// where to deliver the response
    pub reply: Sender<Response>,
}

/// The answer for one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub predicted: usize,
    /// end-to-end latency (queue + batch + pipeline), seconds
    pub latency_s: f64,
    /// why serving failed for this request, when it did (`logits` is
    /// empty then).  A malformed request or a failed batch delivers one
    /// of these instead of silently disconnecting the reply channel.
    pub error: Option<String>,
}

impl Response {
    pub fn from_logits(id: u64, logits: Vec<f32>, arrived: Instant) -> Self {
        let predicted = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        Response {
            id,
            logits,
            predicted,
            latency_s: arrived.elapsed().as_secs_f64(),
            error: None,
        }
    }

    /// A failure answer: no logits, an explanation instead.
    pub fn failure(id: u64, error: String, arrived: Instant) -> Self {
        Response {
            id,
            logits: Vec::new(),
            predicted: 0,
            latency_s: arrived.elapsed().as_secs_f64(),
            error: Some(error),
        }
    }

    /// Whether this response carries logits rather than an error.
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// A formed batch heading into the pipeline.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    /// `(n, 3, T, V)` stacked input (n == artifact batch): compressed
    /// whenever the batch's zero content (sparse clips and/or padding
    /// rows, which are sidecar-only) beats dense transport, dense for a
    /// full batch of dense clips; padding rows are discarded on reply
    pub input: Payload,
    /// number of real (non-padding) rows
    pub real: usize,
    pub formed: Instant,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_argmax() {
        let r = Response::from_logits(
            3,
            vec![0.1, 2.0, -1.0],
            Instant::now(),
        );
        assert_eq!(r.predicted, 1);
        assert_eq!(r.id, 3);
        assert!(r.latency_s >= 0.0);
        assert!(r.is_ok());
    }

    #[test]
    fn failure_response_carries_the_error() {
        let r = Response::failure(9, "bad clip".into(), Instant::now());
        assert!(!r.is_ok());
        assert_eq!(r.error.as_deref(), Some("bad clip"));
        assert!(r.logits.is_empty());
        assert_eq!(r.id, 9);
    }
}
