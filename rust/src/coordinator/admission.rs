//! Bounded admission control: the front door between [`super::server::Server::submit`]
//! and the [`super::batcher::Batcher`].
//!
//! The intake used to be an unbounded `mpsc::channel`: overload meant
//! unbounded queue growth, blown latencies, and batches burned on
//! requests whose callers had long given up.  The gate replaces it with
//! a `sync_channel(capacity)` offered via `try_send`, so the policy is:
//!
//! * **never block the caller** -- `offer` returns immediately, always;
//! * **shed before the batcher** -- a full queue answers right away with
//!   a [`Response`] carrying a machine-readable `retry_after` hint
//!   (see `docs/serving-front-door.md` for the contract);
//! * **deadlines propagate** -- a request with no caller deadline
//!   inherits `default_deadline` here, anchored at arrival, so the
//!   batcher and delivery can drop expired work instead of serving it.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Duration;

use super::metrics::Metrics;
use super::request::{Request, Response};

/// Front-door policy knobs.
#[derive(Debug, Clone)]
pub struct AdmissionPolicy {
    /// bounded intake queue depth; a submit arriving when `capacity`
    /// requests are already queued is shed, not enqueued
    pub capacity: usize,
    /// longest a request may sit in the intake queue before the batcher
    /// reaps it as expired (an implicit deadline every request carries);
    /// doubles as the `retry_after` hint on shed responses -- the time
    /// scale on which a full queue is guaranteed to have turned over
    pub max_queue_wait: Duration,
    /// end-to-end deadline stamped on requests that carry none of their
    /// own (`None`: only `max_queue_wait` bounds them)
    pub default_deadline: Option<Duration>,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        // deliberately permissive: deep queue, a residency bound that
        // only trips when the pipeline is genuinely wedged, no implicit
        // e2e deadline.  Production front doors set explicit values
        // (`serve --admission-capacity/--default-deadline-ms`).
        AdmissionPolicy {
            capacity: 1024,
            max_queue_wait: Duration::from_secs(30),
            default_deadline: None,
        }
    }
}

/// The bounded intake gate.  Owns the sending half of the intake
/// channel; the batcher drains the receiving half.  Dropping the gate
/// disconnects the intake (how [`super::server::Server::shutdown`] stops
/// the batcher).
pub struct AdmissionGate {
    tx: SyncSender<Request>,
    policy: AdmissionPolicy,
    metrics: Arc<Metrics>,
}

impl AdmissionGate {
    /// Build a gate over a fresh bounded intake queue.  Returns the
    /// batcher-side receiver and the shared shutdown flag: the server
    /// sets the flag *before* dropping the gate so the batcher can tell
    /// "drain with shutdown errors" from "intake idle".
    pub fn new(
        policy: AdmissionPolicy,
        metrics: Arc<Metrics>,
    ) -> (AdmissionGate, Receiver<Request>, Arc<AtomicBool>) {
        let (tx, rx) = sync_channel(policy.capacity.max(1));
        let shutting_down = Arc::new(AtomicBool::new(false));
        (
            AdmissionGate {
                tx,
                policy,
                metrics,
            },
            rx,
            shutting_down,
        )
    }

    /// The queue-residency bound the batcher must enforce.
    pub fn max_queue_wait(&self) -> Duration {
        self.policy.max_queue_wait
    }

    /// Admit or immediately answer one request.  Never blocks: a full
    /// queue sheds (failure `Response` with `retry_after`), a
    /// disconnected queue answers with an intake-closed error.  The
    /// request's deadline is defaulted from the policy first, so every
    /// admitted request downstream carries whatever deadline it will be
    /// judged by.
    pub fn offer(&self, mut req: Request) {
        if req.deadline.is_none() {
            req.deadline = self
                .policy
                .default_deadline
                .map(|d| req.arrived + d);
        }
        match self.tx.try_send(req) {
            Ok(()) => self.metrics.record_queue_push(),
            Err(TrySendError::Full(req)) => {
                self.metrics.record_shed();
                self.metrics.record_failure();
                respond(
                    &req.reply,
                    Response::shed(req.id, self.policy.max_queue_wait, req.arrived),
                    Some(&self.metrics),
                );
            }
            Err(TrySendError::Disconnected(req)) => {
                self.metrics.record_failure();
                respond(
                    &req.reply,
                    Response::failure(
                        req.id,
                        "server intake closed: request not accepted".into(),
                        req.arrived,
                    ),
                    Some(&self.metrics),
                );
            }
        }
    }
}

/// Deliver one response, counting an abandoned caller (receiver already
/// dropped) instead of silently swallowing the send error -- before
/// this, `let _ = reply.send(..)` made "caller gave up" indistinguishable
/// from success in the metrics.  Returns whether the response landed.
pub fn respond(
    reply: &Sender<Response>,
    resp: Response,
    metrics: Option<&Metrics>,
) -> bool {
    match reply.send(resp) {
        Ok(()) => true,
        Err(_) => {
            if let Some(m) = metrics {
                m.record_abandoned();
            }
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn req(id: u64, reply: Sender<Response>) -> Request {
        Request {
            id,
            clip: vec![0.0; 4],
            seq_len: 1,
            arrived: Instant::now(),
            deadline: None,
            reply,
        }
    }

    #[test]
    fn full_queue_sheds_with_retry_after_and_never_blocks() {
        let metrics = Arc::new(Metrics::default());
        let policy = AdmissionPolicy {
            capacity: 2,
            max_queue_wait: Duration::from_millis(125),
            default_deadline: None,
        };
        let (gate, _rx, _flag) = AdmissionGate::new(policy, metrics.clone());
        let start = Instant::now();
        let mut reply_rxs = Vec::new();
        for i in 0..5u64 {
            let (tx, rx) = channel();
            reply_rxs.push(rx);
            gate.offer(req(i, tx));
        }
        // try_send semantics: offering 5 into capacity 2 returns
        // immediately every time, even with nothing draining the queue
        assert!(start.elapsed() < Duration::from_secs(1));
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 2);
        // the first two were admitted (no response yet)...
        assert!(reply_rxs[0].try_recv().is_err());
        assert!(reply_rxs[1].try_recv().is_err());
        // ...the rest were answered immediately with the retry hint
        for rx in &reply_rxs[2..] {
            let resp = rx.try_recv().expect("shed answer is immediate");
            assert!(!resp.is_ok());
            assert!(resp.is_shed());
            assert_eq!(resp.retry_after, Some(Duration::from_millis(125)));
            assert!(
                resp.error.as_deref().unwrap().contains("overloaded"),
                "{:?}",
                resp.error
            );
        }
    }

    #[test]
    fn default_deadline_is_stamped_on_admission() {
        let metrics = Arc::new(Metrics::default());
        let policy = AdmissionPolicy {
            capacity: 4,
            max_queue_wait: Duration::from_secs(1),
            default_deadline: Some(Duration::from_millis(80)),
        };
        let (gate, rx, _flag) = AdmissionGate::new(policy, metrics);
        let (tx, _reply) = channel();
        let r = req(1, tx);
        let arrived = r.arrived;
        gate.offer(r);
        let admitted = rx.try_recv().unwrap();
        assert_eq!(
            admitted.deadline,
            Some(arrived + Duration::from_millis(80))
        );
        // an explicit deadline wins over the default
        let (tx, _reply) = channel();
        let mut r = req(2, tx);
        r.deadline = Some(arrived + Duration::from_millis(7));
        gate.offer(r);
        let admitted = rx.try_recv().unwrap();
        assert_eq!(admitted.deadline, Some(arrived + Duration::from_millis(7)));
    }

    #[test]
    fn disconnected_intake_answers_instead_of_dropping() {
        let metrics = Arc::new(Metrics::default());
        let (gate, rx, _flag) =
            AdmissionGate::new(AdmissionPolicy::default(), metrics.clone());
        drop(rx);
        let (tx, reply) = channel();
        gate.offer(req(9, tx));
        let resp = reply.try_recv().expect("answered");
        assert!(!resp.is_ok());
        assert!(!resp.is_shed(), "a dead intake is not overload");
        assert!(resp.error.as_deref().unwrap().contains("intake closed"));
        assert_eq!(metrics.failures.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn respond_counts_abandoned_callers() {
        let metrics = Metrics::default();
        let (tx, rx) = channel();
        let resp = Response::failure(1, "x".into(), Instant::now());
        assert!(respond(&tx, resp.clone(), Some(&metrics)));
        drop(rx);
        assert!(!respond(&tx, resp, Some(&metrics)));
        assert_eq!(metrics.abandoned.load(Ordering::Relaxed), 1);
    }
}
