//! Serving metrics: counters + latency reservoir.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::lock_recovered;
use crate::rfc::GateStats;
use crate::runtime::StageEntry;
use crate::util::stats::{percentile, Summary};

/// Per-node wire-transport counters for the shard coordinator
/// ([`crate::coordinator::shard::ShardCluster`]): actual frame bytes
/// shipped each way vs the dense-transport bytes of the same tensors.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct NodeTransport {
    /// shard frames shipped to this node
    pub shards: u64,
    /// of those, frames that were **re-dispatches** of a shard another
    /// slot lost to a link failure (fault-masking retry) -- the
    /// per-slot attempt accounting behind `shard_retries`
    pub retries: u64,
    /// wire bytes coordinator -> node
    pub tx_wire_bytes: u64,
    /// dense bytes the same shards would have cost
    pub tx_dense_bytes: u64,
    /// wire bytes node -> coordinator
    pub rx_wire_bytes: u64,
    /// dense bytes the same replies would have cost
    pub rx_dense_bytes: u64,
}

impl NodeTransport {
    /// Fraction of dense-transport bytes the wire encoding saved on this
    /// node's link, both directions (negative when framing overhead on
    /// dense payloads outweighs compression).
    pub fn saving(&self) -> f64 {
        let dense = self.tx_dense_bytes + self.rx_dense_bytes;
        if dense == 0 {
            return 0.0;
        }
        1.0 - (self.tx_wire_bytes + self.rx_wire_bytes) as f64 / dense as f64
    }
}

/// Supervision snapshot of one cluster slot, published by
/// [`crate::coordinator::shard::ShardCluster`] (full state on startup
/// via `publish_health`, then incrementally on every Down/reconnect
/// transition) so link degradation is observable from the coordinator.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct NodeHealth {
    /// where the slot points: the dialed address, or "static" for
    /// loopback / caller-built links the cluster cannot rebuild
    pub label: String,
    /// whether the slot is in the shard rotation
    pub up: bool,
    /// lifetime successful reconnects of this slot
    pub reconnects: u64,
    /// link failures since the slot last served (0 while up)
    pub consecutive_failures: u64,
    /// lifetime standby promotions of this slot (each also counts as a
    /// reconnect)
    pub promotions: u64,
}

/// Shared metrics sink (cheap atomics on the hot path, a mutex-guarded
/// latency reservoir sampled per response).
#[derive(Debug)]
pub struct Metrics {
    pub requests_in: AtomicU64,
    pub responses_out: AtomicU64,
    /// requests answered with an error [`super::request::Response`]
    /// (malformed submission, failed batch, shed, expired) instead of
    /// logits
    pub failures: AtomicU64,
    /// requests shed at the admission gate: the bounded intake queue
    /// was full, the caller got an immediate retry-after answer and the
    /// batcher never saw the request
    pub shed: AtomicU64,
    /// requests whose deadline (or the admission queue-residency bound)
    /// passed before delivery: reaped at batch formation or answered
    /// deadline-exceeded in flight
    pub expired: AtomicU64,
    /// responses that could not be delivered because the caller dropped
    /// its receiver (gave up after shed/timeout) -- counted so an
    /// abandoned caller is distinguishable from a served one
    pub abandoned: AtomicU64,
    /// current depth of the bounded admission queue (gauge: pushed at
    /// the gate, popped as the batcher dequeues)
    pub queue_depth: AtomicU64,
    pub batches: AtomicU64,
    pub padded_rows: AtomicU64,
    /// real (non-padding) rows, recorded at batch-formation time --
    /// the padding-fraction denominator.  `responses_out` is recorded
    /// at *delivery* time, so using it would skew the fraction while
    /// batches are in flight and permanently over-count padding after
    /// a failed batch (whose rows are never delivered)
    pub real_rows: AtomicU64,
    /// bits shipped on the batcher -> stage-1 edge (RFC compressed form).
    /// Scope note: inter-stage payload boundaries re-encode inside the
    /// pipeline threads and are not recorded here, so this understates
    /// the system-wide RFC saving
    pub transport_bits: AtomicU64,
    /// bits dense transport of the same input batches would have shipped
    pub transport_dense_bits: AtomicU64,
    /// payload compression-gate decisions (sampled pre-gate rejects,
    /// discarded encodes, compressed ships)
    pub gate: GateStats,
    /// stage entries that consumed the compressed payload directly
    /// through the compressed-domain kernel (no decode)
    pub decodes_elided: AtomicU64,
    /// stage entries that materialized a dense tensor on entry
    pub decodes: AtomicU64,
    /// nonzero input lanes the kernel multiplied
    pub kernel_hot_lanes: AtomicU64,
    /// zero input lanes the kernel skipped (dense-path MAC rows avoided)
    pub kernel_skipped_lanes: AtomicU64,
    /// kernel jobs that finished on a stealing worker
    pub kernel_jobs_stolen: AtomicU64,
    /// shards re-dispatched onto a surviving slot after a link-level
    /// loss (fault-masking retry; an expired batch never retries, so
    /// this stays 0 under pure deadline pressure)
    pub shard_retries: AtomicU64,
    /// Down slots promoted to their standby address by `heal`
    pub standby_promotions: AtomicU64,
    /// per-node shard link traffic (indexed by node id)
    nodes: Mutex<Vec<NodeTransport>>,
    /// per-node link supervision state (indexed by node id)
    health: Mutex<Vec<NodeHealth>>,
    latencies_s: Mutex<Vec<f64>>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests_in: AtomicU64::new(0),
            responses_out: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            abandoned: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_rows: AtomicU64::new(0),
            real_rows: AtomicU64::new(0),
            transport_bits: AtomicU64::new(0),
            transport_dense_bits: AtomicU64::new(0),
            gate: GateStats::default(),
            decodes_elided: AtomicU64::new(0),
            decodes: AtomicU64::new(0),
            kernel_hot_lanes: AtomicU64::new(0),
            kernel_skipped_lanes: AtomicU64::new(0),
            kernel_jobs_stolen: AtomicU64::new(0),
            shard_retries: AtomicU64::new(0),
            standby_promotions: AtomicU64::new(0),
            nodes: Mutex::new(Vec::new()),
            health: Mutex::new(Vec::new()),
            latencies_s: Mutex::new(Vec::new()),
            started: Instant::now(),
        }
    }
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests_in.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, real: usize, padded_to: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.real_rows.fetch_add(real as u64, Ordering::Relaxed);
        self.padded_rows
            .fetch_add((padded_to - real) as u64, Ordering::Relaxed);
    }

    /// Record one batch's wire cost vs its dense-transport baseline.
    pub fn record_transport(&self, compressed_bits: u64, dense_bits: u64) {
        self.transport_bits
            .fetch_add(compressed_bits, Ordering::Relaxed);
        self.transport_dense_bits
            .fetch_add(dense_bits, Ordering::Relaxed);
    }

    /// Fraction of dense-transport bits saved by RFC compression on the
    /// recorded (batcher -> stage-1) edge.
    pub fn transport_saving(&self) -> f64 {
        let dense = self.transport_dense_bits.load(Ordering::Relaxed);
        if dense == 0 {
            return 0.0;
        }
        1.0 - self.transport_bits.load(Ordering::Relaxed) as f64 / dense as f64
    }

    /// Record what one pipeline-stage entry did with its payload: a
    /// decode elided by the compressed-domain kernel (plus that call's
    /// input-skipping accounting), or a dense decode.
    pub fn record_stage_entry(&self, entry: &StageEntry) {
        if entry.decode_elided {
            self.decodes_elided.fetch_add(1, Ordering::Relaxed);
        } else {
            self.decodes.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(k) = entry.kernel {
            self.kernel_hot_lanes.fetch_add(k.hot_lanes, Ordering::Relaxed);
            self.kernel_skipped_lanes
                .fetch_add(k.skipped_lanes, Ordering::Relaxed);
            self.kernel_jobs_stolen
                .fetch_add(k.stolen_jobs, Ordering::Relaxed);
        }
    }

    /// Fraction of stage entries that never decoded their payload.
    pub fn decode_elision_fraction(&self) -> f64 {
        let elided = self.decodes_elided.load(Ordering::Relaxed);
        let total = elided + self.decodes.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        elided as f64 / total as f64
    }

    /// Fraction of logical input lanes the kernel skipped (the runtime
    /// mirror of the paper's input-skipping MAC saving).
    pub fn kernel_skip_fraction(&self) -> f64 {
        let skipped = self.kernel_skipped_lanes.load(Ordering::Relaxed);
        let total = skipped + self.kernel_hot_lanes.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        skipped as f64 / total as f64
    }

    /// Record one shard frame shipped coordinator -> `node`.
    pub fn record_node_tx(&self, node: usize, wire_bytes: u64, dense_bytes: u64) {
        let mut nodes = lock_recovered(&self.nodes);
        if nodes.len() <= node {
            nodes.resize(node + 1, NodeTransport::default());
        }
        let n = &mut nodes[node];
        n.shards += 1;
        n.tx_wire_bytes += wire_bytes;
        n.tx_dense_bytes += dense_bytes;
    }

    /// Record one reply frame collected from `node`.
    pub fn record_node_rx(&self, node: usize, wire_bytes: u64, dense_bytes: u64) {
        let mut nodes = lock_recovered(&self.nodes);
        if nodes.len() <= node {
            nodes.resize(node + 1, NodeTransport::default());
        }
        let n = &mut nodes[node];
        n.rx_wire_bytes += wire_bytes;
        n.rx_dense_bytes += dense_bytes;
    }

    /// Record one shard re-dispatched onto `node` after another slot's
    /// link-level failure: the global retry counter plus the receiving
    /// node's per-slot attempt count.
    pub fn record_shard_retry(&self, node: usize) {
        self.shard_retries.fetch_add(1, Ordering::Relaxed);
        let mut nodes = lock_recovered(&self.nodes);
        if nodes.len() <= node {
            nodes.resize(node + 1, NodeTransport::default());
        }
        nodes[node].retries += 1;
    }

    /// Record one Down slot promoted to its standby address.
    pub fn record_standby_promotion(&self) {
        self.standby_promotions.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of per-node shard link traffic (index = node id).
    pub fn node_transport(&self) -> Vec<NodeTransport> {
        lock_recovered(&self.nodes).clone()
    }

    /// [`NodeTransport::saving`] for one node (0.0 if it never saw work).
    pub fn node_transport_saving(&self, node: usize) -> f64 {
        lock_recovered(&self.nodes)
            .get(node)
            .map(NodeTransport::saving)
            .unwrap_or(0.0)
    }

    /// Publish slot `node`'s supervision state (the cluster calls this
    /// on every Down/reconnect transition and once at startup).
    pub fn set_node_health(
        &self,
        node: usize,
        label: &str,
        up: bool,
        reconnects: u64,
        consecutive_failures: u64,
        promotions: u64,
    ) {
        let mut health = lock_recovered(&self.health);
        if health.len() <= node {
            health.resize(node + 1, NodeHealth::default());
        }
        health[node] = NodeHealth {
            label: label.to_string(),
            up,
            reconnects,
            consecutive_failures,
            promotions,
        };
    }

    /// Snapshot of per-node link supervision state (index = node id;
    /// empty until a cluster publishes).
    pub fn node_health(&self) -> Vec<NodeHealth> {
        lock_recovered(&self.health).clone()
    }

    pub fn record_response(&self, latency_s: f64) {
        self.responses_out.fetch_add(1, Ordering::Relaxed);
        lock_recovered(&self.latencies_s).push(latency_s);
    }

    /// Record one request answered with an error response (malformed
    /// submission or a failed batch).  Kept out of the latency
    /// reservoir: an instant rejection would drag the percentiles away
    /// from what served traffic actually experienced.
    pub fn record_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request shed at the admission gate (queue full).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request reaped or answered past its deadline.
    pub fn record_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one undeliverable response: the caller's receiver was
    /// already dropped when delivery tried to answer.
    pub fn record_abandoned(&self) {
        self.abandoned.fetch_add(1, Ordering::Relaxed);
    }

    /// One request entered the bounded admission queue.
    pub fn record_queue_push(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// One request left the admission queue (dequeued by the batcher).
    /// Saturating at zero: a batcher fed outside a gate (tests, direct
    /// producers) must not wrap the gauge.
    pub fn record_queue_pop(&self) {
        let _ = self.queue_depth.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |d| d.checked_sub(1),
        );
    }

    /// Completed responses per second since start.
    pub fn throughput_fps(&self) -> f64 {
        let n = self.responses_out.load(Ordering::Relaxed) as f64;
        let dt = self.started.elapsed().as_secs_f64();
        if dt > 0.0 {
            n / dt
        } else {
            0.0
        }
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::of(&lock_recovered(&self.latencies_s))
    }

    pub fn latency_p99_s(&self) -> f64 {
        percentile(&lock_recovered(&self.latencies_s), 99.0)
    }

    /// Fraction of executed rows that were padding (batching
    /// efficiency).  Both counters are recorded together in
    /// [`Metrics::record_batch`], so the fraction is exact even while
    /// batches are in flight or after a failed batch -- the old
    /// `responses_out` denominator was recorded at delivery time and
    /// went stale in both cases.
    pub fn padding_fraction(&self) -> f64 {
        let pads = self.padded_rows.load(Ordering::Relaxed) as f64;
        let real = self.real_rows.load(Ordering::Relaxed) as f64;
        if pads + real > 0.0 {
            pads / (pads + real)
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} responses={} batches={} fps={:.2} pad={:.1}% \
             rfc_in_save={:.1}% lat[{}]",
            self.requests_in.load(Ordering::Relaxed),
            self.responses_out.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.throughput_fps(),
            self.padding_fraction() * 100.0,
            self.transport_saving() * 100.0,
            self.latency_summary(),
        );
        if self.decodes_elided.load(Ordering::Relaxed)
            + self.decodes.load(Ordering::Relaxed)
            > 0
        {
            s.push_str(&format!(
                " decode_elide={:.1}% mac_skip={:.1}%",
                self.decode_elision_fraction() * 100.0,
                self.kernel_skip_fraction() * 100.0,
            ));
        }
        let failures = self.failures.load(Ordering::Relaxed);
        if failures > 0 {
            s.push_str(&format!(" failures={failures}"));
        }
        let shed = self.shed.load(Ordering::Relaxed);
        if shed > 0 {
            s.push_str(&format!(" shed={shed}"));
        }
        let expired = self.expired.load(Ordering::Relaxed);
        if expired > 0 {
            s.push_str(&format!(" expired={expired}"));
        }
        let abandoned = self.abandoned.load(Ordering::Relaxed);
        if abandoned > 0 {
            s.push_str(&format!(" abandoned={abandoned}"));
        }
        let queued = self.queue_depth.load(Ordering::Relaxed);
        if queued > 0 {
            s.push_str(&format!(" queue_depth={queued}"));
        }
        let pre = self.gate.pre_rejects.load(Ordering::Relaxed);
        if pre > 0 {
            s.push_str(&format!(" gate_pre_rejects={pre}"));
        }
        let retries = self.shard_retries.load(Ordering::Relaxed);
        if retries > 0 {
            s.push_str(&format!(" shard_retries={retries}"));
        }
        let promotions = self.standby_promotions.load(Ordering::Relaxed);
        if promotions > 0 {
            s.push_str(&format!(" standby_promotions={promotions}"));
        }
        let nodes = lock_recovered(&self.nodes);
        if !nodes.is_empty() {
            let saves: Vec<String> = nodes
                .iter()
                .map(|n| format!("{:.1}%", n.saving() * 100.0))
                .collect();
            s.push_str(&format!(" node_save=[{}]", saves.join(", ")));
        }
        // per-slot attempt counts, shown only once a retry happened: a
        // slot that absorbed re-dispatched shards reads `N(+Kr)`
        if retries > 0 && !nodes.is_empty() {
            let attempts: Vec<String> = nodes
                .iter()
                .map(|n| {
                    if n.retries > 0 {
                        format!("{}(+{}r)", n.shards, n.retries)
                    } else {
                        format!("{}", n.shards)
                    }
                })
                .collect();
            s.push_str(&format!(" node_attempts=[{}]", attempts.join(", ")));
        }
        let health = lock_recovered(&self.health);
        // an all-up, never-failed cluster stays out of the report line
        if health.iter().any(|h| !h.up || h.reconnects > 0) {
            let states: Vec<String> = health
                .iter()
                .map(|h| {
                    if h.up {
                        match (h.reconnects, h.promotions) {
                            (0, _) => "up".into(),
                            (r, 0) => format!("up(r{r})"),
                            (r, p) => format!("up(r{r},p{p})"),
                        }
                    } else {
                        format!("down(f{})", h.consecutive_failures)
                    }
                })
                .collect();
            s.push_str(&format!(" node_state=[{}]", states.join(", ")));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let m = Metrics::default();
        m.record_request();
        m.record_request();
        m.record_batch(2, 4);
        // the batch is still in flight (no responses yet): the padding
        // fraction must already be exact -- the old responses_out
        // denominator read 2/(2+0) = 1.0 here
        assert_eq!(m.padded_rows.load(Ordering::Relaxed), 2);
        assert_eq!(m.real_rows.load(Ordering::Relaxed), 2);
        assert!((m.padding_fraction() - 0.5).abs() < 1e-12);
        m.record_response(0.010);
        m.record_response(0.020);
        assert_eq!(m.requests_in.load(Ordering::Relaxed), 2);
        assert!((m.padding_fraction() - 0.5).abs() < 1e-12);
        let s = m.latency_summary();
        assert_eq!(s.n, 2);
        assert!((s.mean_s - 0.015).abs() < 1e-12);
        // a failed batch's rows never deliver: the fraction must not
        // drift when a later batch errors out after formation
        m.record_batch(2, 4);
        assert!((m.padding_fraction() - 0.5).abs() < 1e-12);
        m.record_failure();
        m.record_failure();
        assert_eq!(m.failures.load(Ordering::Relaxed), 2);
        assert!(m.report().contains("failures=2"));
        assert!((m.padding_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn admission_counters_and_queue_gauge() {
        let m = Metrics::default();
        // the report stays quiet while the front door is idle
        let quiet = m.report();
        assert!(!quiet.contains("shed="));
        assert!(!quiet.contains("expired="));
        assert!(!quiet.contains("abandoned="));
        assert!(!quiet.contains("queue_depth="));
        m.record_queue_push();
        m.record_queue_push();
        m.record_queue_pop();
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 1);
        // popping below zero saturates instead of wrapping the gauge
        m.record_queue_pop();
        m.record_queue_pop();
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 0);
        m.record_shed();
        m.record_shed();
        m.record_expired();
        m.record_abandoned();
        m.record_queue_push();
        let s = m.report();
        assert!(s.contains("shed=2"), "{s}");
        assert!(s.contains("expired=1"), "{s}");
        assert!(s.contains("abandoned=1"), "{s}");
        assert!(s.contains("queue_depth=1"), "{s}");
    }

    #[test]
    fn transport_saving_tracks() {
        let m = Metrics::default();
        assert_eq!(m.transport_saving(), 0.0);
        m.record_transport(250, 1000);
        m.record_transport(250, 1000);
        assert!((m.transport_saving() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn report_smoke() {
        let m = Metrics::default();
        m.record_response(0.005);
        assert!(m.report().contains("responses=1"));
        assert!(!m.report().contains("node_save"));
    }

    #[test]
    fn stage_entry_counters_track_elision_and_skipping() {
        use crate::rfc::SpmmStats;
        let m = Metrics::default();
        assert_eq!(m.decode_elision_fraction(), 0.0);
        assert_eq!(m.kernel_skip_fraction(), 0.0);
        m.record_stage_entry(&StageEntry {
            decode_elided: true,
            kernel: Some(SpmmStats {
                gemm_rows: 4,
                hot_lanes: 30,
                skipped_lanes: 70,
                jobs: 4,
                stolen_jobs: 1,
            }),
        });
        m.record_stage_entry(&StageEntry::default());
        assert!((m.decode_elision_fraction() - 0.5).abs() < 1e-12);
        assert!((m.kernel_skip_fraction() - 0.7).abs() < 1e-12);
        assert_eq!(m.kernel_jobs_stolen.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("decode_elide=50.0%"));
        assert!(m.report().contains("mac_skip=70.0%"));
    }

    #[test]
    fn node_transport_tracks_per_node() {
        let m = Metrics::default();
        assert!(m.node_transport().is_empty());
        assert_eq!(m.node_transport_saving(0), 0.0);
        // node 1 recorded before node 0 ever shows up: vec grows
        m.record_node_tx(1, 100, 400);
        m.record_node_rx(1, 50, 100);
        m.record_node_tx(0, 300, 300);
        let nodes = m.node_transport();
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[1].shards, 1);
        assert_eq!(nodes[0].shards, 1);
        assert!((nodes[1].saving() - 0.7).abs() < 1e-12);
        assert!((m.node_transport_saving(1) - 0.7).abs() < 1e-12);
        // dense payload framing can cost more than it saves: negative
        m.record_node_rx(0, 400, 300);
        assert!(m.node_transport_saving(0) < 0.0);
        assert!(m.report().contains("node_save=["));
    }

    #[test]
    fn node_health_tracks_transitions_and_reports_degradation() {
        let m = Metrics::default();
        assert!(m.node_health().is_empty());
        m.set_node_health(0, "127.0.0.1:7000", true, 0, 0, 0);
        m.set_node_health(1, "127.0.0.1:7001", true, 0, 0, 0);
        // a fully-healthy cluster stays out of the report line
        assert!(!m.report().contains("node_state"));
        // node 1 fails twice, then heals
        m.set_node_health(1, "127.0.0.1:7001", false, 0, 2, 0);
        let h = m.node_health();
        assert_eq!(h.len(), 2);
        assert!(h[0].up && !h[1].up);
        assert_eq!(h[1].consecutive_failures, 2);
        assert!(m.report().contains("node_state=[up, down(f2)]"));
        m.set_node_health(1, "127.0.0.1:7001", true, 1, 0, 0);
        let h = m.node_health();
        assert!(h[1].up);
        assert_eq!(h[1].reconnects, 1);
        // a healed slot keeps its reconnect count visible
        assert!(m.report().contains("node_state=[up, up(r1)]"));
        // a promotion shows up alongside the reconnect it implies
        m.set_node_health(1, "127.0.0.1:7002", true, 2, 0, 1);
        assert!(m.report().contains("node_state=[up, up(r2,p1)]"));
    }

    #[test]
    fn retry_and_promotion_counters_report_per_slot_attempts() {
        let m = Metrics::default();
        // quiet while nothing failed over
        let quiet = m.report();
        assert!(!quiet.contains("shard_retries="));
        assert!(!quiet.contains("standby_promotions="));
        assert!(!quiet.contains("node_attempts="));
        // node 0 served 2 shards, one of them a re-dispatch of node 1's
        m.record_node_tx(0, 100, 400);
        m.record_node_tx(1, 100, 400);
        m.record_node_tx(0, 100, 400);
        m.record_shard_retry(0);
        m.record_standby_promotion();
        assert_eq!(m.shard_retries.load(Ordering::Relaxed), 1);
        assert_eq!(m.standby_promotions.load(Ordering::Relaxed), 1);
        let nodes = m.node_transport();
        assert_eq!(nodes[0].retries, 1);
        assert_eq!(nodes[1].retries, 0);
        let s = m.report();
        assert!(s.contains("shard_retries=1"), "{s}");
        assert!(s.contains("standby_promotions=1"), "{s}");
        assert!(s.contains("node_attempts=[2(+1r), 1]"), "{s}");
    }
}
