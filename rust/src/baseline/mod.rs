//! Comparator baselines: GPU rooflines (Table V) and the Ding et al. [10]
//! accelerator (Table IV).

pub mod ding;
pub mod gpu;

pub use ding::{DingPublished, DING};
pub use gpu::{paper_gpus, Gpu, VariantFlops};
