//! Ding et al. [10] comparator (ASICON'19 ST-GCN FPGA accelerator) --
//! the published Table IV row, plus a single-PE analytical model used for
//! the speedup sanity check.

/// The published implementation numbers used in the paper's Table IV.
#[derive(Debug, Clone, Copy)]
pub struct DingPublished {
    pub dsp: u32,
    pub bram: u32,
    pub lut: u32,
    pub peak_gops: f64,
    pub frequency_mhz: f64,
    pub fps: f64,
}

pub const DING: DingPublished = DingPublished {
    dsp: 228,
    bram: 151,
    lut: 44_457,
    peak_gops: 46.0,
    frequency_mhz: 188.0,
    fps: 11.99,
};

impl DingPublished {
    pub fn dsp_efficiency(&self) -> f64 {
        self.peak_gops / self.dsp as f64
    }
}

/// Single-PE throughput model: one processing element computing the
/// whole network serially (the design point the paper criticises) --
/// fps = clock * dsp * 1 MAC / macs_per_sample.
pub fn single_pe_fps(clock_hz: f64, dsp: u32, macs_per_sample: f64) -> f64 {
    clock_hz * dsp as f64 / macs_per_sample
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_efficiency() {
        assert!((DING.dsp_efficiency() - 0.2017).abs() < 1e-3);
    }

    #[test]
    fn single_pe_is_slow() {
        // ST-GCN ~ 4 GMAC/sample: 228 DSPs at 188 MHz serial => ~10 fps,
        // same magnitude as the published 11.99 fps
        let fps = single_pe_fps(188e6, 228, 4.0e9);
        assert!((5.0..25.0).contains(&fps), "fps {fps}");
    }
}
