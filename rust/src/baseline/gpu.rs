//! GPU roofline baselines for Table V (substitution: no physical
//! 2080Ti/V100 in this environment; see DESIGN.md SSSubstitutions).
//!
//! Model: `fps = peak_flops * utilization(model) / flops_per_sample`.
//! GCN inference utilizes GPUs poorly (small 25-node graph matmuls,
//! kernel-launch bound): the paper measured 29.53 fps (2080Ti) / 69.38
//! fps (V100) on the ~8.6 GFLOP original model (w/ C_k).  We fit one
//! utilization constant per card to the *original* row and predict the
//! other variants from their FLOP counts -- so "who wins, by what factor"
//! is derived, not copied.

/// A GPU card's roofline parameters.
#[derive(Debug, Clone)]
pub struct Gpu {
    pub name: &'static str,
    pub peak_tflops: f64,
    /// fitted effective utilization for this workload class
    pub utilization: f64,
    /// TDP-class power draw in watts (for fps/W columns)
    pub power_w: f64,
}

/// FLOPs per sample of the paper-scale model variants (w/ C_k includes
/// the self-similarity graph; "skip" halves the input frames).
#[derive(Debug, Clone, Copy)]
pub struct VariantFlops {
    pub with_ck: f64,
    pub without_ck: f64,
    pub skip: f64,
}

impl VariantFlops {
    /// Derive from a dense per-sample FLOP count: the paper's Table I
    /// shows C_k costs ~30% extra wall time (69.38 -> 98.87 fps), and
    /// input-skip halves the work.
    pub fn from_dense(dense_flops: f64) -> VariantFlops {
        VariantFlops {
            with_ck: dense_flops * 98.87 / 69.38,
            without_ck: dense_flops,
            skip: dense_flops * 0.5,
        }
    }
}

/// Fit a card's utilization so that its predicted w/C fps matches a
/// measured reference (the paper's "original" row), then predict all
/// variants.
pub fn fit_gpu(
    name: &'static str,
    peak_tflops: f64,
    power_w: f64,
    measured_original_fps: f64,
    flops: &VariantFlops,
) -> Gpu {
    let utilization =
        measured_original_fps * flops.with_ck / (peak_tflops * 1e12);
    Gpu {
        name,
        peak_tflops,
        utilization,
        power_w,
    }
}

impl Gpu {
    pub fn fps(&self, flops_per_sample: f64) -> f64 {
        self.peak_tflops * 1e12 * self.utilization / flops_per_sample
    }

    pub fn fps_per_watt(&self, flops_per_sample: f64) -> f64 {
        self.fps(flops_per_sample) / self.power_w
    }
}

/// The two comparison cards with the paper's measured original-model fps.
pub fn paper_gpus(flops: &VariantFlops) -> (Gpu, Gpu) {
    (
        fit_gpu("2080Ti", 13.45, 250.0, 29.53, flops),
        fit_gpu("V100", 14.0, 300.0, 69.38, flops),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flops() -> VariantFlops {
        VariantFlops::from_dense(3.9e9)
    }

    #[test]
    fn fit_reproduces_reference_point() {
        let f = flops();
        let (g2080, v100) = paper_gpus(&f);
        assert!((g2080.fps(f.with_ck) - 29.53).abs() < 0.01);
        assert!((v100.fps(f.with_ck) - 69.38).abs() < 0.01);
    }

    #[test]
    fn variant_ordering_matches_paper() {
        // paper Table V: original < w/o C < skip for both cards
        let f = flops();
        let (g, v) = paper_gpus(&f);
        for card in [g, v] {
            assert!(card.fps(f.with_ck) < card.fps(f.without_ck));
            assert!(card.fps(f.without_ck) < card.fps(f.skip));
        }
    }

    #[test]
    fn predicted_wo_ck_near_paper_measured() {
        // paper measured 45.42 (2080Ti) / 98.87 (V100) for w/o C; the
        // roofline prediction should land within ~35% (utilization is
        // workload-dependent; the *ratio* structure is what must hold)
        let f = flops();
        let (g, v) = paper_gpus(&f);
        let rel =
            |pred: f64, meas: f64| (pred - meas).abs() / meas;
        assert!(rel(g.fps(f.without_ck), 45.42) < 0.35,
                "2080Ti {}", g.fps(f.without_ck));
        assert!(rel(v.fps(f.without_ck), 98.87) < 0.35,
                "V100 {}", v.fps(f.without_ck));
    }

    #[test]
    fn utilization_is_tiny_like_real_gcn_serving() {
        let f = flops();
        let (_, v100) = paper_gpus(&f);
        assert!(v100.utilization < 0.05, "util {}", v100.utilization);
    }
}
