//! Typed view of `artifacts/meta.json` -- the contract between the
//! build-time Python (Layer 1/2) and the Rust runtime/simulator (Layer 3).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One conv block's artifact entry.
#[derive(Debug, Clone)]
pub struct BlockMeta {
    pub hlo: String,
    pub in_shape: Vec<usize>,  // (N, T, V, C_in)
    pub out_shape: Vec<usize>, // (N, T', V, C_out)
    pub in_channels: usize,
    pub out_channels: usize,
    pub stride: usize,
    pub kept_in: Vec<usize>,
    pub kept_t_out: Vec<usize>,
}

/// A whole-model artifact entry (dense / ck / pruned / skip / head / quant).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub hlo: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
}

/// FLOP breakdown per block (per sample).
#[derive(Debug, Clone, Copy, Default)]
pub struct BlockFlops {
    pub graph: f64,
    pub spatial: f64,
    pub temporal: f64,
    pub shortcut: f64,
    pub total: f64,
}

/// Per-layer activation sparsity stats (Table III / RFC sizing).
#[derive(Debug, Clone)]
pub struct LayerSparsity {
    pub name: String,
    pub mean_sparsity: f64,
    /// fraction of feature vectors in sparsity buckets
    /// I: [0.75, 1], II: [0.5, 0.75), III: [0.25, 0.5), IV: [0, 0.25)
    pub buckets: [f64; 4],
    pub channels: usize,
}

/// The recurrent cavity scheme (8 masks x 9 taps).
#[derive(Debug, Clone)]
pub struct CavityMeta {
    pub name: String,
    pub masks: [[bool; 9]; 8],
}

impl CavityMeta {
    pub fn kept_taps(&self, filter: usize) -> Vec<usize> {
        (0..9).filter(|&t| self.masks[filter % 8][t]).collect()
    }

    pub fn keep_ratio(&self) -> f64 {
        let kept: usize = self
            .masks
            .iter()
            .map(|m| m.iter().filter(|&&b| b).count())
            .sum();
        kept as f64 / 72.0
    }
}

/// Everything in meta.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub seq_len: usize,
    pub num_classes: usize,
    pub num_joints: usize,
    pub schedule: String,
    pub cavity: CavityMeta,
    pub blocks: Vec<BlockMeta>,
    pub head: ArtifactMeta,
    pub model_dense: ArtifactMeta,
    pub model_ck: ArtifactMeta,
    pub model_pruned: ArtifactMeta,
    pub model_skip: ArtifactMeta,
    pub quant_demo: ArtifactMeta,
    pub flops_dense: Vec<BlockFlops>,
    pub flops_pruned: Vec<BlockFlops>,
    pub graph_skip_ratio: f64,
    pub compression_ratio: f64,
    pub sparsity: Vec<LayerSparsity>,
}

fn parse_flops(v: &Json) -> Result<Vec<BlockFlops>> {
    v.as_arr()?
        .iter()
        .map(|row| {
            Ok(BlockFlops {
                graph: row.get("graph")?.as_f64()?,
                spatial: row.get("spatial")?.as_f64()?,
                temporal: row.get("temporal")?.as_f64()?,
                shortcut: row.get("shortcut")?.as_f64()?,
                total: row.get("total")?.as_f64()?,
            })
        })
        .collect()
}

fn parse_artifact(v: &Json) -> Result<ArtifactMeta> {
    Ok(ArtifactMeta {
        hlo: v.get("hlo")?.as_str()?.to_string(),
        in_shape: v.get("in_shape")?.usize_vec()?,
        out_shape: v
            .opt("out_shape")
            .map(|s| s.usize_vec())
            .transpose()?
            .unwrap_or_default(),
    })
}

impl Manifest {
    /// Load `<dir>/meta.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let v = Json::from_file(&dir.join("meta.json"))
            .context("loading manifest")?;

        let cav = v.get("cavity")?;
        let mask_strs = cav.get("masks")?.as_arr()?;
        if mask_strs.len() != 8 {
            bail!("expected 8 cavity masks, got {}", mask_strs.len());
        }
        let mut masks = [[false; 9]; 8];
        for (i, row) in mask_strs.iter().enumerate() {
            let s = row.as_str()?;
            if s.len() != 9 {
                bail!("cavity mask {i} has length {}", s.len());
            }
            for (t, c) in s.chars().enumerate() {
                masks[i][t] = c == '1';
            }
        }

        let blocks = v
            .get("blocks")?
            .as_arr()?
            .iter()
            .map(|b| {
                Ok(BlockMeta {
                    hlo: b.get("hlo")?.as_str()?.to_string(),
                    in_shape: b.get("in_shape")?.usize_vec()?,
                    out_shape: b.get("out_shape")?.usize_vec()?,
                    in_channels: b.get("in_channels")?.as_usize()?,
                    out_channels: b.get("out_channels")?.as_usize()?,
                    stride: b.get("stride")?.as_usize()?,
                    kept_in: b.get("kept_in")?.usize_vec()?,
                    kept_t_out: b.get("kept_t_out")?.usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let arts = v.get("artifacts")?;
        let sparsity = v
            .get("sparsity")?
            .as_obj()?
            .iter()
            .map(|(name, s)| {
                let b = s.get("buckets_I_II_III_IV")?.f64_vec()?;
                if b.len() != 4 {
                    bail!("expected 4 sparsity buckets for {name}");
                }
                Ok(LayerSparsity {
                    name: name.clone(),
                    mean_sparsity: s.get("mean_sparsity")?.as_f64()?,
                    buckets: [b[0], b[1], b[2], b[3]],
                    channels: s.get("channels")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            dir: dir.to_path_buf(),
            batch: v.get("batch")?.as_usize()?,
            seq_len: v.get("seq_len")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            num_joints: v.get("num_joints")?.as_usize()?,
            schedule: v.get("schedule")?.as_str()?.to_string(),
            cavity: CavityMeta {
                name: cav.get("name")?.as_str()?.to_string(),
                masks,
            },
            blocks,
            head: parse_artifact(arts.get("head")?)?,
            model_dense: parse_artifact(arts.get("model_dense")?)?,
            model_ck: parse_artifact(arts.get("model_ck")?)?,
            model_pruned: parse_artifact(arts.get("model_pruned")?)?,
            model_skip: parse_artifact(arts.get("model_skip")?)?,
            quant_demo: parse_artifact(arts.get("quant_demo")?)?,
            flops_dense: parse_flops(v.get("flops")?.get("dense_per_sample")?)?,
            flops_pruned: parse_flops(
                v.get("flops")?.get("pruned_per_sample")?,
            )?,
            graph_skip_ratio: v.get("graph_skip_ratio")?.as_f64()?,
            compression_ratio: v.get("compression_ratio")?.as_f64()?,
            sparsity,
        })
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn hlo_path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }

    /// Total dense / pruned GFLOPs per sample.
    pub fn total_flops(&self, pruned: bool) -> f64 {
        let t = if pruned {
            &self.flops_pruned
        } else {
            &self.flops_dense
        };
        t.iter().map(|b| b.total).sum()
    }

    /// Default artifacts directory: `$RFC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("RFC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cavity_kept_taps() {
        let mut masks = [[false; 9]; 8];
        masks[0][0] = true;
        masks[0][4] = true;
        masks[1][2] = true;
        let c = CavityMeta { name: "t".into(), masks };
        assert_eq!(c.kept_taps(0), vec![0, 4]);
        assert_eq!(c.kept_taps(8), vec![0, 4]); // wraps mod 8
        assert_eq!(c.kept_taps(1), vec![2]);
        assert!((c.keep_ratio() - 3.0 / 72.0).abs() < 1e-12);
    }

    #[test]
    fn manifest_load_if_built() {
        // integration-level check; unit tests must pass without artifacts
        let dir = Manifest::default_dir();
        if dir.join("meta.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.blocks.len(), 10);
            assert_eq!(m.num_joints, 25);
            for (a, b) in m.blocks.iter().zip(m.blocks.iter().skip(1)) {
                assert_eq!(a.out_shape, b.in_shape);
                assert_eq!(a.kept_t_out, b.kept_in);
            }
        }
    }
}
