//! Runtime configuration: layered `key = value` config files (TOML-like
//! scalars; the offline vendor set has no toml crate), environment
//! overrides (`RFC_*`), and CLI overrides -- the launcher-grade config
//! system the serving binary uses.
//!
//! Precedence: defaults < config file < environment < CLI flags.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Raw parsed key/value view of a config source.
#[derive(Debug, Clone, Default)]
pub struct KvConfig {
    values: BTreeMap<String, String>,
}

impl KvConfig {
    /// Parse a `key = value` file: comments (`#`, `;`), blank lines and
    /// `[section]` headers (flattened to `section.key`) are supported.
    pub fn parse(text: &str) -> Result<KvConfig> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value, got {line:?}", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = v.trim().trim_matches('"').to_string();
            values.insert(key, val);
        }
        Ok(KvConfig { values })
    }

    pub fn from_file(path: &Path) -> Result<KvConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Merge `other` on top of `self` (other wins).
    pub fn overlay(&mut self, other: &KvConfig) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }

    /// Pull `RFC_SECTION_KEY=value` environment overrides: the variable
    /// `RFC_SERVE_BATCH_WAIT_MS` maps to key `serve.batch_wait_ms`.
    pub fn overlay_env(&mut self) {
        for (k, v) in std::env::vars() {
            if let Some(rest) = k.strip_prefix("RFC_") {
                let parts: Vec<&str> =
                    rest.splitn(2, '_').collect();
                if parts.len() == 2 {
                    let key = format!(
                        "{}.{}",
                        parts[0].to_lowercase(),
                        parts[1].to_lowercase()
                    );
                    self.values.insert(key, v);
                }
            }
        }
    }

    fn typed<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("config {key} = {v:?}: {e}")),
        }
    }
}

/// Fully-resolved serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts: PathBuf,
    pub batch_wait: Duration,
    pub pipeline_depth: usize,
    pub variant: String,
    pub request_noise: f64,
    pub seed: u64,
    /// bounded admission queue depth
    /// ([`crate::coordinator::AdmissionPolicy::capacity`])
    pub admission_capacity: usize,
    /// admission queue-residency bound; doubles as the shed responses'
    /// `retry_after` hint
    pub max_queue_wait: Duration,
    /// default end-to-end deadline stamped on requests without one
    /// (config key `serve.default_deadline_ms`; `0` = no deadline)
    pub default_deadline: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let admission = crate::coordinator::AdmissionPolicy::default();
        ServeConfig {
            artifacts: PathBuf::from("artifacts"),
            batch_wait: Duration::from_millis(20),
            pipeline_depth: 2,
            variant: "pruned".into(),
            request_noise: 0.02,
            seed: 7,
            admission_capacity: admission.capacity,
            max_queue_wait: admission.max_queue_wait,
            default_deadline: admission.default_deadline,
        }
    }
}

impl ServeConfig {
    /// Resolve from an optional config file + environment.
    pub fn resolve(path: Option<&Path>) -> Result<ServeConfig> {
        let mut kv = KvConfig::default();
        if let Some(p) = path {
            kv.overlay(&KvConfig::from_file(p)?);
        }
        kv.overlay_env();
        let d = ServeConfig::default();
        let default_deadline_ms = kv.typed(
            "serve.default_deadline_ms",
            d.default_deadline.map(|d| d.as_millis() as u64).unwrap_or(0),
        )?;
        Ok(ServeConfig {
            artifacts: kv
                .get("serve.artifacts")
                .map(PathBuf::from)
                .unwrap_or(d.artifacts),
            batch_wait: Duration::from_millis(
                kv.typed("serve.batch_wait_ms", 20u64)?,
            ),
            pipeline_depth: kv.typed("serve.pipeline_depth", d.pipeline_depth)?,
            variant: kv
                .get("serve.variant")
                .unwrap_or(&d.variant)
                .to_string(),
            request_noise: kv.typed("serve.request_noise", d.request_noise)?,
            seed: kv.typed("serve.seed", d.seed)?,
            admission_capacity: kv
                .typed("serve.admission_capacity", d.admission_capacity)?,
            max_queue_wait: Duration::from_millis(kv.typed(
                "serve.max_queue_wait_ms",
                d.max_queue_wait.as_millis() as u64,
            )?),
            default_deadline: (default_deadline_ms > 0)
                .then(|| Duration::from_millis(default_deadline_ms)),
        })
    }

    /// The admission policy this configuration resolves to.
    pub fn admission(&self) -> crate::coordinator::AdmissionPolicy {
        crate::coordinator::AdmissionPolicy {
            capacity: self.admission_capacity,
            max_queue_wait: self.max_queue_wait,
            default_deadline: self.default_deadline,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_comments() {
        let kv = KvConfig::parse(
            "# top\nname = base\n[serve]\nbatch_wait_ms = 35\n; c\nvariant = \"skip\"\n",
        )
        .unwrap();
        assert_eq!(kv.get("name"), Some("base"));
        assert_eq!(kv.get("serve.batch_wait_ms"), Some("35"));
        assert_eq!(kv.get("serve.variant"), Some("skip"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(KvConfig::parse("no equals here").is_err());
        assert!(KvConfig::parse("[unterminated").is_err());
    }

    #[test]
    fn overlay_precedence() {
        let mut base = KvConfig::parse("a = 1\nb = 2").unwrap();
        let over = KvConfig::parse("b = 3\nc = 4").unwrap();
        base.overlay(&over);
        assert_eq!(base.get("a"), Some("1"));
        assert_eq!(base.get("b"), Some("3"));
        assert_eq!(base.get("c"), Some("4"));
    }

    #[test]
    fn typed_parsing_and_errors() {
        let kv = KvConfig::parse("x = 12\ny = oops").unwrap();
        assert_eq!(kv.typed("x", 0usize).unwrap(), 12);
        assert_eq!(kv.typed("missing", 7usize).unwrap(), 7);
        assert!(kv.typed::<usize>("y", 0).is_err());
    }

    #[test]
    fn serve_config_resolution() {
        let dir = std::env::temp_dir().join("rfc_cfg_test.conf");
        std::fs::write(
            &dir,
            "[serve]\nbatch_wait_ms = 50\nvariant = skip\nseed = 99\n",
        )
        .unwrap();
        let c = ServeConfig::resolve(Some(&dir)).unwrap();
        assert_eq!(c.batch_wait, Duration::from_millis(50));
        assert_eq!(c.variant, "skip");
        assert_eq!(c.seed, 99);
        assert_eq!(c.pipeline_depth, 2); // default preserved
    }

    #[test]
    fn defaults_without_file() {
        let c = ServeConfig::resolve(None).unwrap();
        assert_eq!(c.variant, "pruned");
        // admission defaults mirror AdmissionPolicy::default()
        let d = crate::coordinator::AdmissionPolicy::default();
        assert_eq!(c.admission_capacity, d.capacity);
        assert_eq!(c.max_queue_wait, d.max_queue_wait);
        assert_eq!(c.default_deadline, d.default_deadline);
    }

    #[test]
    fn admission_keys_resolve_and_zero_deadline_means_none() {
        let path = std::env::temp_dir().join("rfc_cfg_admission_test.conf");
        std::fs::write(
            &path,
            "[serve]\nadmission_capacity = 16\nmax_queue_wait_ms = 75\n\
             default_deadline_ms = 200\n",
        )
        .unwrap();
        let c = ServeConfig::resolve(Some(&path)).unwrap();
        let a = c.admission();
        assert_eq!(a.capacity, 16);
        assert_eq!(a.max_queue_wait, Duration::from_millis(75));
        assert_eq!(a.default_deadline, Some(Duration::from_millis(200)));
        std::fs::write(&path, "[serve]\ndefault_deadline_ms = 0\n").unwrap();
        let c = ServeConfig::resolve(Some(&path)).unwrap();
        assert_eq!(c.default_deadline, None, "0 disables the default deadline");
    }
}
