//! Host-side Q8.8 fixed-point helpers (mirror of `python/compile/
//! quantize.py`), used by the quantized-inference example and benches.

/// Fractional bits of the paper's format (8 integer + 8 fractional).
pub const FRAC_BITS: u32 = 8;
pub const SCALE: f32 = 256.0;

/// float -> Q8.8 with round-to-nearest and int16 saturation.
pub fn quantize(x: f32) -> i16 {
    let q = (x * SCALE).round();
    q.clamp(i16::MIN as f32, i16::MAX as f32) as i16
}

/// Q8.8 -> float.
pub fn dequantize(q: i16) -> f32 {
    q as f32 / SCALE
}

pub fn quantize_slice(xs: &[f32]) -> Vec<i16> {
    xs.iter().copied().map(quantize).collect()
}

pub fn dequantize_slice(qs: &[i16]) -> Vec<f32> {
    qs.iter().copied().map(dequantize).collect()
}

/// int32 accumulator -> Q8.8 output: arithmetic shift then int16
/// saturation.  The single definition of the requantization rule, shared
/// by [`quant_matmul_ref`] and the compressed-domain kernel
/// (`crate::rfc::kernel::spmm_q88`) so the two stay bit-identical by
/// construction.
pub fn requantize(acc: i32) -> i16 {
    (acc >> FRAC_BITS).clamp(-32768, 32767) as i16
}

/// [`requantize`] over a whole accumulator row: `out[j] =
/// requantize(acc[j])`.  The compressed-domain kernel's output step
/// (shared by its scalar and SIMD paths, so the rule keeps its single
/// definition no matter which lanes accumulated).
pub fn requantize_slice(acc: &[i32], out: &mut [i16]) {
    debug_assert_eq!(acc.len(), out.len());
    for (o, &a) in out.iter_mut().zip(acc) {
        *o = requantize(a);
    }
}

/// Reference Q8.8 matmul semantics (int32 accumulate, arithmetic shift,
/// saturate) -- must agree with the AOT `quant_demo` kernel bit-for-bit.
pub fn quant_matmul_ref(
    xq: &[i16],
    wq: &[i16],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<i16> {
    let mut out = vec![0i16; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc: i32 = 0;
            for l in 0..k {
                acc = acc
                    .wrapping_add(xq[i * k + l] as i32 * wq[l * n + j] as i32);
            }
            out[i * n + j] = requantize(acc);
        }
    }
    out
}

/// Max |x - dequantize(quantize(x))| bound inside the representable range.
pub const MAX_QUANT_ERROR: f32 = 0.5 / SCALE;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_on_grid() {
        for v in [-128.0f32, -1.5, 0.0, 0.00390625, 1.0, 127.99609375] {
            assert_eq!(dequantize(quantize(v)), v);
        }
    }

    #[test]
    fn error_bound() {
        for i in -1000..1000 {
            let x = i as f32 * 0.017;
            let err = (x - dequantize(quantize(x))).abs();
            assert!(err <= MAX_QUANT_ERROR + 1e-7, "x={x} err={err}");
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(quantize(1e9), i16::MAX);
        assert_eq!(quantize(-1e9), i16::MIN);
    }

    #[test]
    fn matmul_ref_basic() {
        // [1.0, 2.0] . [0.5, 0.25]^T in Q8.8
        let x = quantize_slice(&[1.0, 2.0]);
        let w = quantize_slice(&[0.5, 0.25]);
        let out = quant_matmul_ref(&x, &w, 1, 2, 1);
        assert_eq!(dequantize(out[0]), 1.0);
    }

    #[test]
    fn matmul_ref_arithmetic_shift() {
        // -1 (raw) * 1 (raw) >> 8 must be -1, not 0
        let out = quant_matmul_ref(&[-1], &[1], 1, 1, 1);
        assert_eq!(out[0], -1);
    }

    #[test]
    fn requantize_slice_matches_scalar_rule() {
        let acc = [0i32, -1, 256, -257, i32::MAX, i32::MIN];
        let mut out = [0i16; 6];
        requantize_slice(&acc, &mut out);
        for (o, a) in out.iter().zip(acc) {
            assert_eq!(*o, requantize(a));
        }
        assert_eq!(out[4], i16::MAX);
        assert_eq!(out[5], i16::MIN);
    }
}
