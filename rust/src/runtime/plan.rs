//! Stage plans: the per-stage contract that lets
//! [`super::Executable::run_payload_planned`] consume a compressed
//! payload directly instead of decoding it on stage entry.
//!
//! A [`StagePlan`] names the stage's **leading GEMM** -- the first op the
//! stage applies to its input.  When a plan is attached, the stage's
//! executable is the *remainder* of the stage (compiled without that
//! GEMM); the plan owns the GEMM weights and runs it through the
//! compressed-domain kernel ([`crate::rfc::kernel`]), so the decode on
//! stage entry disappears entirely for compressed payloads.  Payloads the
//! plan cannot claim (dense, or bank geometry that does not line up)
//! decode and run the GEMM densely ([`StagePlan::apply_dense`]) before
//! the remainder -- attaching a plan never changes results, only where
//! the GEMM runs.  An input the GEMM can never apply to (trailing axis
//! != contraction axis) is a configuration error and fails loudly.

use anyhow::{ensure, Result};

use crate::meta::BlockMeta;
use crate::rfc::{kernel, CompressedTensor, GemmF32, KernelConfig, SpmmStats};
use crate::runtime::Tensor;

/// A claimable leading-GEMM description for one pipeline stage.
#[derive(Debug, Clone)]
pub struct StagePlan {
    gemm: GemmF32,
    kernel: KernelConfig,
}

impl StagePlan {
    pub fn new(gemm: GemmF32) -> StagePlan {
        StagePlan {
            gemm,
            kernel: KernelConfig::default(),
        }
    }

    /// Override the kernel scheduling knobs (worker count, job grain).
    pub fn with_kernel(mut self, cfg: KernelConfig) -> StagePlan {
        self.kernel = cfg;
        self
    }

    /// Plan a conv block's leading per-joint feature transform:
    /// `(N, T, V, C_in) x (C_in, C_out)`.  `weights` must be the block's
    /// `[in_channels, out_channels]` GEMM operand (exported alongside the
    /// remainder HLO by the AOT pipeline).
    pub fn from_block(block: &BlockMeta, weights: &Tensor) -> Result<StagePlan> {
        ensure!(
            weights.shape == [block.in_channels, block.out_channels],
            "block wants a [{}, {}] GEMM operand, weights are {:?}",
            block.in_channels,
            block.out_channels,
            weights.shape
        );
        Ok(StagePlan::new(GemmF32::from_tensor(weights)?))
    }

    pub fn gemm(&self) -> &GemmF32 {
        &self.gemm
    }

    /// Whether this plan can consume `ct` in compressed form: the
    /// tensor's trailing axis must be the GEMM contraction axis and the
    /// bank geometry must line up (see [`kernel::claimable`]).
    pub fn claims(&self, ct: &CompressedTensor) -> bool {
        self.claims_dims(&ct.shape) && kernel::claimable(ct, self.gemm.k())
    }

    /// Shape-level claim check, answerable *before* any encode: would a
    /// tensor of this dense shape be claimable once compressed?  Lets
    /// callers skip the encode entirely for a plan whose geometry can
    /// never line up (an encode whose only consumer would be an
    /// immediate decode is pure overhead).
    pub fn claims_dims(&self, shape: &[usize]) -> bool {
        let k = self.gemm.k();
        if shape.last() != Some(&k) {
            return false;
        }
        let (_, row_len) = CompressedTensor::layout(shape);
        kernel::claimable_row(row_len, k)
    }

    /// Run the leading GEMM over the compressed payload.
    pub fn apply(&self, ct: &CompressedTensor) -> Result<(Tensor, SpmmStats)> {
        kernel::spmm_f32(ct, &self.gemm, &self.kernel)
    }

    /// Run the leading GEMM densely over a stage input the compressed
    /// path could not claim (dense gate reject, or bank geometry that
    /// does not line up).  The executable behind a plan is the stage
    /// *remainder*, so the GEMM must still run on every fallback --
    /// skipping it would feed pre-GEMM data into the remainder and
    /// produce silently wrong results.  An input whose trailing axis is
    /// not the contraction axis is a configuration error: that plan can
    /// never match this stage, and it is surfaced here rather than
    /// papered over.
    pub fn apply_dense(&self, x: &Tensor) -> Result<Tensor> {
        let (k, n) = (self.gemm.k(), self.gemm.n());
        ensure!(
            x.shape.last() == Some(&k),
            "planned stage input {:?} does not end in the GEMM \
             contraction axis {k}: the plan cannot apply to this stage",
            x.shape
        );
        let m = x.len() / k;
        let data = kernel::gemm_dense_f32(&x.data, m, &self.gemm);
        let mut shape = x.shape.clone();
        *shape.last_mut().unwrap() = n;
        Tensor::new(shape, data)
    }
}

/// What one stage entry did with its payload -- the per-entry record
/// `crate::coordinator::Metrics::record_stage_entry` aggregates.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageEntry {
    /// the stage consumed the compressed payload directly (no decode)
    pub decode_elided: bool,
    /// kernel accounting when the fast path ran
    pub kernel: Option<SpmmStats>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfc::{encode, EncoderConfig};

    fn plan(k: usize, n: usize) -> StagePlan {
        let w: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32 - 3.0).collect();
        StagePlan::new(GemmF32::new(w, k, n).unwrap())
    }

    #[test]
    fn claims_only_matching_trailing_axis() {
        let cfg = EncoderConfig {
            shards: 1,
            min_sparsity: 0.0,
            parallel_threshold: usize::MAX,
        };
        let t = Tensor::random_sparse(vec![2, 5, 32], 0.5, 1);
        let ct = encode(&t, &cfg);
        assert!(plan(32, 8).claims(&ct));
        assert!(!plan(16, 8).claims(&ct), "16 != trailing axis 32");
        assert!(!plan(160, 8).claims(&ct), "whole-row k is not the trailing axis");
        // the shape-level pre-check agrees with the compressed-form claim
        assert!(plan(32, 8).claims_dims(&[2, 5, 32]));
        assert!(!plan(16, 8).claims_dims(&[2, 5, 32]));
        assert!(!plan(160, 8).claims_dims(&[2, 5, 32]));
        // unaligned trailing axis claims only when it spans the row
        assert!(plan(52, 4).claims_dims(&[3, 52]));
        assert!(!plan(52, 4).claims_dims(&[3, 2, 52]), "52 is not bank-aligned");
        let (y, stats) = plan(32, 8).apply(&ct).unwrap();
        assert_eq!(y.shape, vec![2, 5, 8]);
        assert_eq!(stats.gemm_rows, 10);
    }

    #[test]
    fn apply_dense_runs_the_gemm_and_rejects_mismatched_axes() {
        let p = plan(32, 8);
        let t = Tensor::random_sparse(vec![2, 5, 32], 0.3, 9);
        let y = p.apply_dense(&t).unwrap();
        assert_eq!(y.shape, vec![2, 5, 8]);
        let reference = kernel::gemm_dense_f32(&t.data, 10, p.gemm());
        assert_eq!(y.data, reference);
        // geometry the compressed path cannot claim (52 is not
        // bank-aligned within a multi-row tensor) still applies densely
        let u = Tensor::random_sparse(vec![3, 2, 52], 0.3, 10);
        let pu = plan(52, 4);
        assert!(!pu.claims_dims(&u.shape));
        let yu = pu.apply_dense(&u).unwrap();
        assert_eq!(yu.shape, vec![3, 2, 4]);
        assert_eq!(yu.data, kernel::gemm_dense_f32(&u.data, 6, pu.gemm()));
        // trailing-axis mismatch is a loud configuration error, never a
        // silent GEMM skip
        assert!(p.apply_dense(&Tensor::zeros(vec![2, 16])).is_err());
    }

    #[test]
    fn from_block_checks_weight_shape() {
        let block = BlockMeta {
            hlo: "block.hlo".into(),
            in_shape: vec![8, 64, 25, 64],
            out_shape: vec![8, 64, 25, 128],
            in_channels: 64,
            out_channels: 128,
            stride: 1,
            kept_in: Vec::new(),
            kept_t_out: Vec::new(),
        };
        let good = Tensor::zeros(vec![64, 128]);
        assert!(StagePlan::from_block(&block, &good).is_ok());
        let bad = Tensor::zeros(vec![128, 64]);
        assert!(StagePlan::from_block(&block, &bad).is_err());
    }
}
