//! PJRT runtime: load AOT-compiled HLO text, compile once, execute from
//! the request path.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`).
//! Python never runs here: the HLO artifacts under `artifacts/` are the
//! entire model.  One compiled executable per model variant / pipeline
//! stage, cached for the process lifetime.

pub mod plan;
pub mod tensor;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

pub use plan::{StageEntry, StagePlan};
pub use tensor::Tensor;

/// Process-wide PJRT engine with an executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

/// A compiled HLO module ready to run.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

// SAFETY: `Engine` only holds a PJRT CPU client handle (plus `Mutex`-guarded
// caches); the PJRT CPU client is internally synchronized and safe to move
// across threads. The raw pointers inside the xla crate wrappers are what
// block the auto-derive, not any real thread-affinity.
unsafe impl Send for Engine {}
// SAFETY: all mutable state in `Engine` sits behind `Mutex`es and the PJRT
// client itself is internally synchronized, so `&Engine` is safe to share.
unsafe impl Sync for Engine {}
// SAFETY: a loaded PJRT executable is immutable after compilation; execution
// is re-entrant on the CPU client, so moving the handle between threads is
// sound.
unsafe impl Send for Executable {}
// SAFETY: `Executable` exposes only `&self` execution over an immutable
// compiled module; concurrent `run*` calls are serialized inside PJRT.
unsafe impl Sync for Executable {}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PJRT cpu client: {e}"))?,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO **text** module (cached by path).
    pub fn load_hlo(&self, path: &Path) -> Result<Arc<Executable>> {
        let key = path.display().to_string();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        let exe = Arc::new(Executable {
            exe,
            name: key.clone(),
        });
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of compiled modules held in the cache.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl Executable {
    /// Execute with f32 host tensors; returns the tuple elements as host
    /// tensors (jax modules are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {}: {e}", self.name))?;
        parts
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<Vec<_>>>()
            .context("reading result tensors")
    }

    /// Single-output convenience wrapper.
    pub fn run1(&self, inputs: &[Tensor]) -> Result<Tensor> {
        let mut out = self.run(inputs)?;
        if out.len() != 1 {
            anyhow::bail!(
                "{} returned {} outputs, expected 1",
                self.name,
                out.len()
            );
        }
        Ok(out.pop().unwrap())
    }

    /// Stage-entry execution over the compressed transport: the payload
    /// is decoded lazily *here*, at the moment the stage needs dense
    /// data, so upstream queues and channels only ever carry the
    /// bank-encoded form (see [`crate::rfc`]).
    pub fn run_payload(
        &self,
        payload: crate::rfc::Payload,
        cfg: &crate::rfc::EncoderConfig,
    ) -> Result<Tensor> {
        self.run1(&[payload.into_dense(cfg)])
    }

    /// Planned stage entry: when `plan` names this stage's leading GEMM
    /// and the compressed payload's bank geometry lines up, the GEMM is
    /// computed directly over the bank segments (input-skipping, no
    /// decode) and only the result is handed to the executable -- which,
    /// per the [`StagePlan`] contract, is the stage *remainder* compiled
    /// without that GEMM.  A payload the plan cannot claim in compressed
    /// form (dense after a compression-gate reject, or bank geometry
    /// that does not line up) is decoded and the GEMM runs densely
    /// ([`StagePlan::apply_dense`]) before the remainder -- the GEMM is
    /// part of the stage and must run on *every* path through it.
    /// Unplanned stages keep [`Executable::run_payload`]'s lazy decode.
    /// The returned [`StageEntry`] says which path ran (fed to
    /// `coordinator::Metrics::record_stage_entry` on the serving path).
    pub fn run_payload_planned(
        &self,
        payload: crate::rfc::Payload,
        cfg: &crate::rfc::EncoderConfig,
        plan: Option<&StagePlan>,
    ) -> Result<(Tensor, StageEntry)> {
        let Some(plan) = plan else {
            return Ok((self.run_payload(payload, cfg)?, StageEntry::default()));
        };
        if let crate::rfc::Payload::Compressed(ct) = &payload {
            if plan.claims(ct) {
                let (y, stats) = plan.apply(ct)?;
                let out = self.run1(&[y])?;
                return Ok((
                    out,
                    StageEntry {
                        decode_elided: true,
                        kernel: Some(stats),
                    },
                ));
            }
        }
        let y = plan.apply_dense(&payload.into_dense(cfg))?;
        Ok((self.run1(&[y])?, StageEntry::default()))
    }

    /// Execute literal -> literal without any host `Vec` round-trip:
    /// the hot path for chaining pipeline stages (perf: saves two host
    /// copies per stage boundary vs `run`).
    pub fn run_literal1(&self, input: &xla::Literal) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(std::slice::from_ref(input))
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", self.name))?;
        lit.to_tuple1()
            .map_err(|e| anyhow!("untupling result of {}: {e}", self.name))
    }

    /// Execute with raw literals (e.g. the int16 quant demo).
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", self.name))?;
        lit.to_tuple()
            .map_err(|e| anyhow!("untupling result of {}: {e}", self.name))
    }
}
