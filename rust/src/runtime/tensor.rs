//! Host tensors and conversion to/from XLA `Literal`s.

use anyhow::{bail, Result};

/// A dense row-major host tensor of f32 (the serving datapath dtype).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, data has {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    /// Deterministic random tensor with the given fraction of exact
    /// zeros and strictly positive (post-ReLU-style) nonzeros -- the
    /// shared generator for the RFC tests and benches.
    pub fn random_sparse(shape: Vec<usize>, sparsity: f64, seed: u64) -> Tensor {
        let mut rng = crate::util::rng::Rng::new(seed);
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n)
            .map(|_| {
                if rng.chance(sparsity) {
                    0.0
                } else {
                    rng.f32() + 1e-3
                }
            })
            .collect();
        Tensor { shape, data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Fraction of exactly-zero elements (activation sparsity).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f64
            / self.data.len() as f64
    }

    /// Convert to an XLA literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Tensor::new(dims, data)
    }

    /// Split the leading (batch) axis into chunks of at most `chunk`.
    pub fn split_batch(&self, chunk: usize) -> Vec<Tensor> {
        let n = self.shape[0];
        let row: usize = self.shape[1..].iter().product();
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let take = chunk.min(n - i);
            let mut shape = self.shape.clone();
            shape[0] = take;
            out.push(Tensor {
                shape,
                data: self.data[i * row..(i + take) * row].to_vec(),
            });
            i += take;
        }
        out
    }

    /// Concatenate along the leading axis (shapes must match elsewhere).
    pub fn concat_batch(parts: &[Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("concat of zero tensors");
        }
        let tail = &parts[0].shape[1..];
        let mut data = Vec::new();
        let mut n = 0;
        for p in parts {
            if &p.shape[1..] != tail {
                bail!("ragged concat: {:?} vs {:?}", p.shape, parts[0].shape);
            }
            n += p.shape[0];
            data.extend_from_slice(&p.data);
        }
        let mut shape = parts[0].shape.clone();
        shape[0] = n;
        Tensor::new(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_len() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn sparsity() {
        let t = Tensor::new(vec![4], vec![0.0, 1.0, 0.0, 2.0]).unwrap();
        assert_eq!(t.sparsity(), 0.5);
    }

    #[test]
    fn split_and_concat_roundtrip() {
        let t = Tensor::new(vec![5, 2], (0..10).map(|i| i as f32).collect())
            .unwrap();
        let parts = t.split_batch(2);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].shape, vec![2, 2]);
        assert_eq!(parts[2].shape, vec![1, 2]);
        let back = Tensor::concat_batch(&parts).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn concat_rejects_ragged() {
        let a = Tensor::zeros(vec![1, 2]);
        let b = Tensor::zeros(vec![1, 3]);
        assert!(Tensor::concat_batch(&[a, b]).is_err());
    }
}
