//! Minimal JSON parser/serializer (offline build: no serde_json).
//!
//! Supports the full JSON grammar needed by `artifacts/meta.json` and the
//! experiment result files: objects, arrays, strings (with escapes),
//! numbers, booleans, null.  Numbers are held as `f64`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    // ---- typed accessors ----

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---- serialization ----

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    e.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, e)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    e.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder: `obj([("k", Json::Num(1.0))])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(items: I) -> Json {
    Json::Obj(
        items
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated utf-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#)
            .unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize().unwrap(), 1);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse(r#""A""#).unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x": [1.5, -2, true, null, "s\"q"], "y": {}}"#;
        let v = Json::parse(src).unwrap();
        let text = v.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn rejects_truncated() {
        assert!(Json::parse(r#"{"a": "#).is_err());
        assert!(Json::parse(r#"[1, 2"#).is_err());
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(Json::parse("[1.5]").unwrap().usize_vec().is_err());
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }
}
