//! Small deterministic RNG (SplitMix64) -- no external `rand` crate in the
//! offline build.  Used by the workload generators, the simulator's
//! sparsity sampling and the property tests.

/// SplitMix64: tiny, fast, well-distributed; perfectly adequate for
/// workload synthesis and property-test case generation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(3);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
