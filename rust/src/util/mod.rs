//! Shared utilities: offline JSON, deterministic RNG, summary stats.

pub mod json;
pub mod rng;
pub mod stats;
