//! Summary statistics for benches and serving metrics.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Percentile with linear interpolation, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// Timing summary for a set of repeated measurements (seconds).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let mut mn = f64::INFINITY;
        let mut mx = f64::NEG_INFINITY;
        for &x in xs {
            mn = mn.min(x);
            mx = mx.max(x);
        }
        Summary {
            n: xs.len(),
            mean_s: mean(xs),
            std_s: stddev(xs),
            min_s: if xs.is_empty() { 0.0 } else { mn },
            p50_s: percentile(xs, 50.0),
            p99_s: percentile(xs, 99.0),
            max_s: if xs.is_empty() { 0.0 } else { mx },
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3}ms ±{:.3} p50={:.3} p99={:.3} [{:.3}..{:.3}]",
            self.n,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.p50_s * 1e3,
            self.p99_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[0.001, 0.002, 0.003]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min_s, 0.001);
        assert_eq!(s.max_s, 0.003);
        assert!((s.mean_s - 0.002).abs() < 1e-12);
    }
}
