//! # rfc-hypgcn
//!
//! Production-grade reproduction of **RFC-HyPGCN** (Wen et al., 2021): a
//! runtime sparse-feature-compress accelerator for skeleton-based GCN
//! action recognition with hybrid pruning.
//!
//! Three layers (see `DESIGN.md`):
//!
//! 1. **Pallas kernels** (`python/compile/kernels/`, build-time) -- the
//!    reorganized graph+spatial convolution (paper eq. 5), cavity temporal
//!    convolution and Q8.8 matmul.
//! 2. **JAX model** (`python/compile/`, build-time) -- the full 2s-AGCN
//!    and its pruned/quantized variants, AOT-lowered to HLO text.
//! 3. **This crate** (request path, no Python) --
//!    * [`runtime`]: PJRT engine loading the AOT artifacts;
//!    * [`rfc`]: the production runtime sparse-feature-compress
//!      subsystem (paper SSV-C): bank-sharded [`rfc::CompressedTensor`]
//!      transport with a multi-threaded encoder, carried between
//!      pipeline stages and decoded lazily on stage entry.  The sim
//!      model below stays the bit-exact reference; the equivalence
//!      contract is enforced by `tests/rfc_equivalence.rs`;
//!    * [`coordinator`]: request router, dynamic batcher (batching in
//!      compressed form), the layer-pipelined block executor, and the
//!      multi-node shard layer ([`coordinator::shard`]) that ships
//!      compressed batches across process boundaries as
//!      [`rfc::wire`]-format bytes -- over in-process loopback links or
//!      real TCP sockets to [`coordinator::node`] worker agents;
//!    * [`sim`]: cycle-level model of the paper's FPGA architecture
//!      (Mult-PE, Dyn-Mult-PE, RFC compressed storage, resource model)
//!      regenerating Tables II-IV and Fig. 11;
//!    * [`baseline`]: GPU roofline + Ding et al. comparators.

pub mod baseline;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod meta;
pub mod model;
pub mod quant;
pub mod rfc;
pub mod runtime;
pub mod sim;
pub mod util;
