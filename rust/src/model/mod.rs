//! Topology mirror of the 2s-AGCN network: block widths, strides, and
//! workload (MAC) accounting used by the simulator and benches.
//!
//! This intentionally duplicates the Python-side `ModelConfig` maths: the
//! Rust binary must be able to reason about the network (pipeline
//! balancing, FLOP accounting, resource mapping) without Python, and the
//! two sides are cross-checked through `artifacts/meta.json`.

/// Full-size 2s-AGCN output channels per block.
pub const FULL_CHANNELS: [usize; 10] = [64, 64, 64, 64, 128, 128, 128, 256, 256, 256];
/// Temporal strides per block.
pub const FULL_STRIDES: [usize; 10] = [1, 1, 1, 1, 2, 1, 1, 2, 1, 1];
/// NTU-RGB+D joint count.
pub const NUM_JOINTS: usize = 25;
/// Graph partition subsets (k_v).
pub const K_V: usize = 3;
/// Temporal kernel size.
pub const TEMPORAL_K: usize = 9;

/// One block's static hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockSpec {
    pub in_channels: usize,
    pub out_channels: usize,
    pub stride: usize,
}

impl BlockSpec {
    pub fn has_projection(&self) -> bool {
        self.in_channels != self.out_channels || self.stride != 1
    }
}

/// Network-level configuration (mirrors Python `ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub num_classes: usize,
    pub seq_len: usize,
    pub width_mult: f64,
    pub in_channels: usize,
    pub num_blocks: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            num_classes: 12,
            seq_len: 64,
            width_mult: 0.25,
            in_channels: 3,
            num_blocks: 10,
        }
    }
}

impl ModelConfig {
    /// The paper's full-size model (T = 300 input frames).
    pub fn paper_full() -> Self {
        ModelConfig {
            num_classes: 60,
            seq_len: 300,
            width_mult: 1.0,
            in_channels: 3,
            num_blocks: 10,
        }
    }

    pub fn block_specs(&self) -> Vec<BlockSpec> {
        let mut specs = Vec::with_capacity(self.num_blocks);
        let mut ic = self.in_channels;
        for i in 0..self.num_blocks {
            let w = ((FULL_CHANNELS[i] as f64 * self.width_mult) as usize / 8
                * 8)
            .max(8);
            specs.push(BlockSpec {
                in_channels: ic,
                out_channels: w,
                stride: FULL_STRIDES[i],
            });
            ic = w;
        }
        specs
    }

    /// Time length entering block `l` (0-based).
    pub fn seq_len_at(&self, l: usize) -> usize {
        let mut t = self.seq_len;
        for s in FULL_STRIDES.iter().take(l) {
            t = t.div_ceil(*s);
        }
        t
    }
}

/// MAC counts for one block under optional pruning.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BlockMacs {
    pub graph: u64,
    pub spatial: u64,
    pub temporal: u64,
    pub shortcut: u64,
}

impl BlockMacs {
    pub fn total(&self) -> u64 {
        self.graph + self.spatial + self.temporal + self.shortcut
    }

    /// FLOPs = 2 x MACs.
    pub fn flops(&self) -> u64 {
        2 * self.total()
    }
}

/// MACs for one block per input sample.
///
/// * `kept_in`: surviving spatial input channels (dataflow reorg);
/// * `tap_counts`: kept taps per surviving temporal filter (cavity).
pub fn block_macs(
    spec: &BlockSpec,
    t_in: usize,
    kept_in: usize,
    tap_counts: &[usize],
) -> BlockMacs {
    let t_out = t_in.div_ceil(spec.stride);
    let v = NUM_JOINTS as u64;
    let graph = (K_V * t_in * kept_in) as u64 * v * v;
    let spatial = (K_V * t_in * kept_in * spec.out_channels) as u64 * v;
    let temporal = (t_out * spec.out_channels) as u64
        * v
        * tap_counts.iter().sum::<usize>() as u64;
    let shortcut = if spec.has_projection() {
        (t_out * spec.in_channels * spec.out_channels) as u64 * v
    } else {
        0
    };
    BlockMacs {
        graph,
        spatial,
        temporal,
        shortcut,
    }
}

/// Dense (unpruned) MACs for a whole model, per sample.
pub fn dense_macs(cfg: &ModelConfig) -> Vec<BlockMacs> {
    cfg.block_specs()
        .iter()
        .enumerate()
        .map(|(l, spec)| {
            block_macs(
                spec,
                cfg.seq_len_at(l),
                spec.in_channels,
                &vec![TEMPORAL_K; spec.out_channels],
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_chain() {
        let cfg = ModelConfig::default();
        let specs = cfg.block_specs();
        assert_eq!(specs.len(), 10);
        assert_eq!(specs[0].in_channels, 3);
        for w in specs.windows(2) {
            assert_eq!(w[0].out_channels, w[1].in_channels);
        }
    }

    #[test]
    fn paper_full_widths() {
        let cfg = ModelConfig::paper_full();
        let specs = cfg.block_specs();
        assert_eq!(specs[0].out_channels, 64);
        assert_eq!(specs[9].out_channels, 256);
        assert_eq!(cfg.seq_len_at(9), 75); // 300 / 2 / 2
    }

    #[test]
    fn paper_dense_gflops_magnitude() {
        // One AGCN stream at T=300 is ~16-17 GFLOPs/sample (ST-GCN is
        // published at ~16.3; "2s" doubles it across the two streams).
        let cfg = ModelConfig::paper_full();
        let total: u64 = dense_macs(&cfg).iter().map(|m| m.flops()).sum();
        let gflops = total as f64 / 1e9;
        assert!(
            (10.0..25.0).contains(&gflops),
            "unexpected workload {gflops} GFLOP"
        );
    }

    #[test]
    fn graph_share_of_eq3() {
        // Paper SSIV-A: graph computation ~49.83% of the graph+spatial
        // workload at full width (V=25 ~ between 64 and 256 channels).
        let cfg = ModelConfig::paper_full();
        let macs = dense_macs(&cfg);
        let g: u64 = macs.iter().map(|m| m.graph).sum();
        let s: u64 = macs.iter().map(|m| m.spatial).sum();
        let share = g as f64 / (g + s) as f64;
        assert!(
            (0.1..0.5).contains(&share),
            "graph share {share} out of expected band"
        );
    }

    #[test]
    fn pruning_reduces_macs() {
        let spec = BlockSpec {
            in_channels: 64,
            out_channels: 64,
            stride: 1,
        };
        let dense = block_macs(&spec, 64, 64, &vec![9; 64]);
        let pruned = block_macs(&spec, 64, 32, &vec![3; 32]);
        assert!(pruned.total() < dense.total() / 2);
        // graph work scales exactly with kept input channels
        assert_eq!(pruned.graph * 2, dense.graph);
    }

    #[test]
    fn projection_blocks_have_shortcut_macs() {
        let cfg = ModelConfig::paper_full();
        let macs = dense_macs(&cfg);
        assert!(macs[4].shortcut > 0); // 64 -> 128 stride 2
        assert_eq!(macs[1].shortcut, 0); // identity block
    }
}
