//! RFC wire format v1: a versioned, length-prefixed binary encoding of
//! [`CompressedTensor`] for process-boundary transport (multi-node
//! sharding, and the socket links that follow).
//!
//! Normative spec: `docs/wire-format.md`.  Layout (little-endian):
//!
//! ```text
//! header:  magic "RFCW" | version u16 | rank u16 | total_len u32
//!          dims rank*u32 | row_banks u32 | bank_count u32 | packed_len u32
//! body:    hots   bank_count * u16     (row-major bank order)
//!          mbhots bank_count * u8
//!          row_offsets (rows + 1) * u32 (packed index at each row boundary)
//!          packed packed_len * f32      (IEEE-754 bit pattern)
//! ```
//!
//! Two properties the rest of the system leans on:
//!
//! * **Canonical**: the stream depends only on the logical tensor, never
//!   on how many encoder shards produced it -- segments are flattened in
//!   row order, so the sim reference ([`crate::sim::rfc::wire_bytes`])
//!   can produce byte-identical output with no segment concept at all.
//! * **Row-aligned offsets**: the `row_offsets` table lets a receiver
//!   slice whole rows out of the packed data without decoding, which is
//!   exactly the unit the shard coordinator splits batches on.
//!
//! [`from_bytes`] never panics on malformed input: every length is
//! checked before use (overflow-checked arithmetic), redundant header
//! fields must agree, and the decoded tensor passes the existing
//! [`CompressedTensor::validate`] rejection API before it is returned.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::runtime::Tensor;
use crate::sim::rfc::BANK_WIDTH;

use super::compressed::{BankSegment, CompressedTensor};
use super::Payload;

/// Frame magic for a serialized [`CompressedTensor`].
pub const WIRE_MAGIC: [u8; 4] = *b"RFCW";
/// Frame magic for a serialized [`Payload`] (dense or compressed).
pub const PAYLOAD_MAGIC: [u8; 4] = *b"RFCP";
/// Magic opening the one-shot stream handshake (see [`write_handshake`]).
pub const HANDSHAKE_MAGIC: [u8; 4] = *b"RFCH";
/// The one and only wire version this build reads and writes.
pub const WIRE_VERSION: u16 = 1;
/// Sanity bound on tensor rank (serving shapes are rank <= 4).
pub const MAX_RANK: usize = 8;
/// Upper bound a stream receiver accepts for one outer frame.  Wire v1
/// caps inner frames at u32 anyway; this tighter bound means a hostile
/// or corrupted length prefix can never provoke a multi-gigabyte
/// allocation before the inner validation gets a chance to reject it.
pub const MAX_FRAME_LEN: u32 = 1 << 30;

const KIND_DENSE: u8 = 0;
const KIND_COMPRESSED: u8 = 1;
const KIND_ERROR: u8 = 2;

/// Header bytes for a tensor frame of the given rank.
fn header_len(rank: usize) -> usize {
    // magic + version + rank + total_len, dims, row_banks + bank_count
    // + packed_len
    12 + 4 * rank + 12
}

fn put_u16(w: &mut Vec<u8>, v: u16) {
    w.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(w: &mut Vec<u8>, v: u32) {
    w.extend_from_slice(&v.to_le_bytes());
}

/// Serialize to the v1 wire stream.  Fails only on tensors that are
/// structurally invalid or too large for the u32 length fields.
pub fn to_bytes(ct: &CompressedTensor) -> Result<Vec<u8>> {
    ct.validate().context("serializing invalid tensor")?;
    let (rows, _row_len) = CompressedTensor::layout(&ct.shape);
    let rank = ct.shape.len();
    let banks = ct.banks();
    let nnz = ct.nnz();
    ensure!(rank <= MAX_RANK, "rank {rank} exceeds wire max {MAX_RANK}");
    for &d in &ct.shape {
        ensure!(d as u64 <= u32::MAX as u64, "dim {d} exceeds u32");
    }
    ensure!(
        banks as u64 <= u32::MAX as u64
            && nnz as u64 <= u32::MAX as u64
            && ct.row_banks() as u64 <= u32::MAX as u64,
        "tensor too large for wire v1 ({banks} banks, {nnz} values)"
    );
    let total = header_len(rank) as u64
        + banks as u64 * 3
        + (rows as u64 + 1) * 4
        + nnz as u64 * 4;
    ensure!(total <= u32::MAX as u64, "frame length {total} exceeds u32");

    let mut w = Vec::with_capacity(total as usize);
    w.extend_from_slice(&WIRE_MAGIC);
    put_u16(&mut w, WIRE_VERSION);
    put_u16(&mut w, rank as u16);
    put_u32(&mut w, total as u32);
    for &d in &ct.shape {
        put_u32(&mut w, d as u32);
    }
    put_u32(&mut w, ct.row_banks() as u32);
    put_u32(&mut w, banks as u32);
    put_u32(&mut w, nnz as u32);
    // body: segments are whole-row runs in batch order, so walking them
    // sequentially yields the canonical row-major bank order
    for seg in ct.segments() {
        for &h in &seg.hots {
            put_u16(&mut w, h);
        }
    }
    for seg in ct.segments() {
        w.extend_from_slice(&seg.mbhots);
    }
    let mut base = 0u64;
    put_u32(&mut w, 0);
    for seg in ct.segments() {
        for r in 1..=seg.rows {
            // lint: allow(index): segment invariant (property-tested):
            // offsets.len() == rows * row_banks + 1, so r * row_banks is
            // in bounds for every r <= rows
            put_u32(&mut w, (base + seg.offsets[r * seg.row_banks] as u64) as u32);
        }
        base += seg.packed.len() as u64;
    }
    for seg in ct.segments() {
        for &v in &seg.packed {
            w.extend_from_slice(&v.to_le_bytes());
        }
    }
    // a real check, not a debug_assert: a size-accounting bug here would
    // ship a frame whose header length lies, and release builds (the PR 5
    // incident class) must refuse it too
    ensure!(
        w.len() as u64 == total,
        "encoder wrote {} bytes, header promised {total}",
        w.len()
    );
    Ok(w)
}

/// Bounds-checked little-endian reader over a byte buffer.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .context("frame offset overflow")?;
        ensure!(
            end <= self.buf.len(),
            "truncated frame: need {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn rest(&self) -> &'a [u8] {
        &self.buf[self.pos..]
    }
}

/// Decode a v1 wire stream, rejecting (never panicking on) anything
/// malformed: short buffers, wrong magic, version skew, disagreeing
/// counts, hot/packed mismatches, oversized shapes.
pub fn from_bytes(buf: &[u8]) -> Result<CompressedTensor> {
    let mut r = Reader::new(buf);
    let magic = r.take(4)?;
    ensure!(magic == WIRE_MAGIC, "bad magic {magic:02x?}");
    let version = r.u16()?;
    ensure!(
        version == WIRE_VERSION,
        "wire version {version} not supported (this build reads v{WIRE_VERSION})"
    );
    let rank = r.u16()? as usize;
    ensure!(rank <= MAX_RANK, "rank {rank} exceeds wire max {MAX_RANK}");
    let total_len = r.u32()? as usize;
    ensure!(
        total_len == buf.len(),
        "frame says {total_len} bytes, buffer has {}",
        buf.len()
    );
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(r.u32()? as usize);
    }
    // (rows, row_len) with overflow-checked products -- a hostile header
    // can name dims whose product exceeds usize
    let (rows, row_len) = match shape.len() {
        0 => (1usize, 1usize),
        1 => (1, shape[0]),
        _ => (
            shape[0],
            shape[1..]
                .iter()
                .try_fold(1usize, |a, &d| a.checked_mul(d))
                .context("shape element count overflows")?,
        ),
    };
    let row_banks = row_len.div_ceil(BANK_WIDTH);
    let row_banks_field = r.u32()? as usize;
    ensure!(
        row_banks_field == row_banks,
        "header row_banks {row_banks_field}, shape implies {row_banks}"
    );
    let bank_count = r.u32()? as usize;
    let expect_banks = rows
        .checked_mul(row_banks)
        .context("bank count overflows")?;
    ensure!(
        bank_count == expect_banks,
        "header bank_count {bank_count}, shape implies {expect_banks}"
    );
    let packed_len = r.u32()? as usize;
    // exact-size check before any array read: truncation and trailing
    // garbage both fail here
    let expect_total = header_len(rank) as u64
        + bank_count as u64 * 3
        + (rows as u64 + 1) * 4
        + packed_len as u64 * 4;
    ensure!(
        expect_total == buf.len() as u64,
        "counts imply a {expect_total}-byte frame, buffer has {}",
        buf.len()
    );

    // the exact-size check above bounds every count by the buffer
    // length, so these bulk reads cannot overflow; chunked decodes keep
    // the hot-path cost to one pass per section
    let hots: Vec<u16> = r
        .take(bank_count * 2)?
        .chunks_exact(2)
        .map(|b| u16::from_le_bytes([b[0], b[1]]))
        .collect();
    let mbhots = r.take(bank_count)?.to_vec();
    let row_offsets: Vec<u32> = r
        .take((rows + 1) * 4)?
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let packed: Vec<f32> = r
        .take(packed_len * 4)?
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    ensure!(r.rest().is_empty(), "trailing bytes after frame");

    // per-bank offsets are redundant on the wire: rebuild them from the
    // hot-code popcounts and require they land exactly on packed_len
    let mut offsets = Vec::with_capacity(bank_count + 1);
    let mut at = 0u64;
    offsets.push(0u32);
    for &h in &hots {
        at += h.count_ones() as u64;
        ensure!(
            at <= packed_len as u64,
            "hot codes name more than the {packed_len} packed values"
        );
        offsets.push(at as u32);
    }
    ensure!(
        at == packed_len as u64,
        "hot codes name {at} values but {packed_len} are packed"
    );
    for (row, &off) in row_offsets.iter().enumerate() {
        // lint: allow(index): offsets was built above with exactly
        // rows * row_banks + 1 entries and row < rows (row_offsets has
        // `rows` entries, validated against the header), so in bounds
        let expect = offsets[row * row_banks];
        ensure!(
            off == expect,
            "row {row} offset {off} does not match hot codes ({expect})"
        );
    }

    let ct = CompressedTensor::from_parts(
        shape,
        row_len,
        row_banks,
        vec![BankSegment {
            rows,
            row_banks,
            packed,
            hots,
            mbhots,
            offsets,
        }],
    );
    ct.validate().context("decoded frame fails validation")?;
    Ok(ct)
}

/// Frame a [`Payload`] for a [`crate::coordinator::shard::NodeLink`]:
/// magic, version, a u32 total-length prefix (so a stream transport can
/// delimit frames without understanding the body), a kind byte, then the
/// body.  Compressed payloads embed their [`to_bytes`] stream untouched
/// (no decode/re-encode round trip); dense payloads ship shape + raw
/// values.
pub fn payload_to_bytes(p: &Payload) -> Result<Vec<u8>> {
    let mut w = Vec::new();
    w.extend_from_slice(&PAYLOAD_MAGIC);
    put_u16(&mut w, WIRE_VERSION);
    put_u32(&mut w, 0); // total_len, patched below
    match p {
        Payload::Compressed(ct) => {
            w.push(KIND_COMPRESSED);
            w.extend_from_slice(&to_bytes(ct)?);
        }
        Payload::Dense(t) => {
            let rank = t.shape.len();
            ensure!(rank <= MAX_RANK, "rank {rank} exceeds wire max {MAX_RANK}");
            for &d in &t.shape {
                ensure!(d as u64 <= u32::MAX as u64, "dim {d} exceeds u32");
            }
            w.push(KIND_DENSE);
            put_u16(&mut w, rank as u16);
            for &d in &t.shape {
                put_u32(&mut w, d as u32);
            }
            for &v in &t.data {
                w.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    ensure!(
        w.len() as u64 <= u32::MAX as u64,
        "payload frame length {} exceeds u32",
        w.len()
    );
    let total = (w.len() as u32).to_le_bytes();
    w[6..10].copy_from_slice(&total);
    Ok(w)
}

/// An error reply frame: a worker that failed sends this instead of a
/// payload, and [`payload_from_bytes`] surfaces it as `Err` on the
/// coordinator side.
pub fn error_frame(msg: &str) -> Vec<u8> {
    let msg = &msg.as_bytes()[..msg.len().min(u16::MAX as usize)];
    let mut w = Vec::with_capacity(11 + msg.len());
    w.extend_from_slice(&PAYLOAD_MAGIC);
    put_u16(&mut w, WIRE_VERSION);
    put_u32(&mut w, (11 + msg.len()) as u32);
    w.push(KIND_ERROR);
    w.extend_from_slice(msg);
    w
}

/// Decode a payload frame (the inverse of [`payload_to_bytes`] /
/// [`error_frame`]).
pub fn payload_from_bytes(buf: &[u8]) -> Result<Payload> {
    let mut r = Reader::new(buf);
    let magic = r.take(4)?;
    ensure!(magic == PAYLOAD_MAGIC, "bad payload magic {magic:02x?}");
    let version = r.u16()?;
    ensure!(
        version == WIRE_VERSION,
        "payload version {version} not supported (this build reads v{WIRE_VERSION})"
    );
    let total_len = r.u32()? as usize;
    ensure!(
        total_len == buf.len(),
        "payload frame says {total_len} bytes, buffer has {}",
        buf.len()
    );
    match r.u8()? {
        KIND_COMPRESSED => Ok(Payload::Compressed(from_bytes(r.rest())?)),
        KIND_DENSE => {
            let rank = r.u16()? as usize;
            ensure!(rank <= MAX_RANK, "rank {rank} exceeds wire max {MAX_RANK}");
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(r.u32()? as usize);
            }
            let n = shape
                .iter()
                .try_fold(1usize, |a, &d| a.checked_mul(d))
                .context("dense element count overflows")?;
            let want = n.checked_mul(4).context("dense byte count overflows")?;
            ensure!(
                r.rest().len() == want,
                "dense body has {} bytes, shape {shape:?} wants {want}",
                r.rest().len()
            );
            let data = r
                .rest()
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            Ok(Payload::Dense(Tensor::new(shape, data)?))
        }
        KIND_ERROR => bail!(
            "remote node error: {}",
            String::from_utf8_lossy(r.rest())
        ),
        k => bail!("unknown payload kind {k}"),
    }
}

/// Ship one frame over a byte stream: a u32 little-endian length prefix,
/// then the frame bytes.  This is the *outer* framing socket transports
/// use to delimit the self-describing payload frames above -- the inner
/// `total_len` stays, so a receiver can still validate the body against
/// what the stream promised.
pub fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    ensure!(
        frame.len() as u64 <= MAX_FRAME_LEN as u64,
        "frame of {} bytes exceeds the {MAX_FRAME_LEN}-byte stream bound",
        frame.len()
    );
    w.write_all(&(frame.len() as u32).to_le_bytes())
        .context("writing frame length")?;
    w.write_all(frame).context("writing frame body")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one length-prefixed frame off a byte stream (inverse of
/// [`write_frame`]).  The length is bounds-checked before any
/// allocation, and the buffer then grows only as bytes actually arrive
/// (`read_to_end` over a `Take`), so a hostile in-bound length prefix
/// costs the attacker the bytes, not this process an up-front
/// `MAX_FRAME_LEN` allocation.  A short read (peer died mid-frame)
/// surfaces as `Err`, never a partial frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4).context("reading frame length")?;
    let len = u32::from_le_bytes(len4) as u64;
    ensure!(
        len <= MAX_FRAME_LEN as u64,
        "stream names a {len}-byte frame, bound is {MAX_FRAME_LEN}"
    );
    let mut buf = Vec::with_capacity(len.min(64 * 1024) as usize);
    let got = r
        .by_ref()
        .take(len)
        .read_to_end(&mut buf)
        .with_context(|| format!("reading {len}-byte frame body"))?;
    ensure!(
        got as u64 == len,
        "stream ended after {got} of {len} frame bytes"
    );
    Ok(buf)
}

/// Send this build's one-shot stream handshake: magic + wire version.
/// Both ends of a socket link write theirs immediately on connect, then
/// read the peer's -- six bytes each way, so the symmetric exchange
/// cannot deadlock.
pub fn write_handshake<W: Write>(w: &mut W) -> Result<()> {
    w.write_all(&HANDSHAKE_MAGIC).context("writing handshake")?;
    w.write_all(&WIRE_VERSION.to_le_bytes())
        .context("writing handshake version")?;
    w.flush().context("flushing handshake")?;
    Ok(())
}

/// Read the peer's handshake and return the wire version it speaks.
/// Bad magic (the peer is not an RFC node at all) is an error here;
/// version *skew* is returned to the caller, which decides how loudly
/// to fail -- see [`expect_handshake`] for the common strict form.
pub fn read_handshake<R: Read>(r: &mut R) -> Result<u16> {
    let mut buf = [0u8; 6];
    r.read_exact(&mut buf).context("reading handshake")?;
    ensure!(
        buf[..4] == HANDSHAKE_MAGIC,
        "bad handshake magic {:02x?} (not an RFC node link)",
        &buf[..4]
    );
    Ok(u16::from_le_bytes([buf[4], buf[5]]))
}

/// [`read_handshake`] that also rejects version skew: the one check
/// every socket link runs right after connect.
pub fn expect_handshake<R: Read>(r: &mut R) -> Result<()> {
    let version = read_handshake(r)?;
    ensure!(
        version == WIRE_VERSION,
        "peer speaks wire v{version}, this build speaks v{WIRE_VERSION}"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rfc::{encode, EncoderConfig};

    fn cfg(shards: usize) -> EncoderConfig {
        EncoderConfig {
            shards,
            min_sparsity: 0.0,
            parallel_threshold: 0,
        }
    }

    fn sample(shape: Vec<usize>, sparsity: f64, seed: u64) -> CompressedTensor {
        encode(&Tensor::random_sparse(shape, sparsity, seed), &cfg(3))
    }

    #[test]
    fn roundtrip_bit_exact() {
        for (shape, s) in [
            (vec![5, 64], 0.5),
            (vec![3, 3, 20], 0.9),
            (vec![1, 17], 0.0),
            (vec![8, 600], 0.7),
        ] {
            let ct = sample(shape.clone(), s, 42);
            let bytes = to_bytes(&ct).unwrap();
            let back = from_bytes(&bytes).unwrap();
            assert_eq!(back.shape, ct.shape);
            assert_eq!(back.to_tensor(), ct.to_tensor(), "{shape:?}");
            // and the stream re-serializes identically
            assert_eq!(to_bytes(&back).unwrap(), bytes);
        }
    }

    #[test]
    fn stream_is_canonical_across_shard_counts() {
        let t = Tensor::random_sparse(vec![9, 320], 0.6, 7);
        let reference = to_bytes(&encode(&t, &cfg(1))).unwrap();
        for shards in [2usize, 3, 5, 8] {
            let bytes = to_bytes(&encode(&t, &cfg(shards))).unwrap();
            assert_eq!(bytes, reference, "shards {shards}");
        }
    }

    #[test]
    fn zeros_frame_is_sidecar_only() {
        let z = CompressedTensor::zeros(vec![4, 32]);
        let bytes = to_bytes(&z).unwrap();
        // header(rank 2) + 8 banks * 3 + 5 row offsets * 4, no packed data
        assert_eq!(bytes.len(), header_len(2) + 8 * 3 + 5 * 4);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(back.nnz(), 0);
        assert_eq!(back.to_tensor(), Tensor::zeros(vec![4, 32]));
    }

    #[test]
    fn every_truncation_is_rejected() {
        let bytes = to_bytes(&sample(vec![3, 48], 0.5, 9)).unwrap();
        for n in 0..bytes.len() {
            assert!(from_bytes(&bytes[..n]).is_err(), "prefix of {n} bytes");
        }
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut bytes = to_bytes(&sample(vec![2, 32], 0.5, 10)).unwrap();
        bytes.push(0);
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn payload_roundtrip_both_kinds() {
        let t = Tensor::random_sparse(vec![4, 96], 0.6, 11);
        for p in [
            Payload::Dense(t.clone()),
            Payload::Compressed(encode(&t, &cfg(2))),
        ] {
            let bytes = payload_to_bytes(&p).unwrap();
            let back = payload_from_bytes(&bytes).unwrap();
            assert_eq!(back.is_compressed(), p.is_compressed());
            assert_eq!(
                back.into_dense(&EncoderConfig::default()),
                t,
                "kind {}",
                p.is_compressed()
            );
        }
    }

    #[test]
    fn error_frame_surfaces_as_err() {
        let e = payload_from_bytes(&error_frame("stage 3 exploded")).unwrap_err();
        assert!(format!("{e:#}").contains("stage 3 exploded"));
    }

    #[test]
    fn payload_frame_rejects_wrong_magic_and_kind() {
        let t = Tensor::zeros(vec![1, 16]);
        let mut bytes = payload_to_bytes(&Payload::Dense(t)).unwrap();
        let good = bytes.clone();
        bytes[0] = b'X';
        assert!(payload_from_bytes(&bytes).is_err());
        let mut skew = good.clone();
        skew[10] = 99; // unknown kind
        assert!(payload_from_bytes(&skew).is_err());
        // total-length prefix must match the buffer exactly
        let mut long = good.clone();
        long.push(0);
        assert!(payload_from_bytes(&long).is_err());
        assert!(payload_from_bytes(&good).is_ok());
    }

    #[test]
    fn stream_framing_roundtrips_back_to_back_frames() {
        let t = Tensor::random_sparse(vec![3, 48], 0.6, 12);
        let frames = [
            payload_to_bytes(&Payload::Compressed(encode(&t, &cfg(1)))).unwrap(),
            payload_to_bytes(&Payload::Dense(t)).unwrap(),
            error_frame("node fell over"),
        ];
        let mut stream = Vec::new();
        for f in &frames {
            write_frame(&mut stream, f).unwrap();
        }
        let mut r = std::io::Cursor::new(stream);
        for f in &frames {
            assert_eq!(&read_frame(&mut r).unwrap(), f);
        }
        // the stream is exactly consumed: one more read hits EOF
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn truncated_outer_frame_is_rejected() {
        let inner = error_frame("short");
        let mut stream = Vec::new();
        write_frame(&mut stream, &inner).unwrap();
        for n in 0..stream.len() {
            let mut r = std::io::Cursor::new(&stream[..n]);
            assert!(read_frame(&mut r).is_err(), "prefix of {n} bytes");
        }
    }

    #[test]
    fn oversized_length_prefix_fails_before_allocating() {
        // a hostile length prefix (u32::MAX) with no body behind it must
        // be rejected by the bound check, not by an allocation attempt
        let mut stream = Vec::from(u32::MAX.to_le_bytes());
        stream.extend_from_slice(b"garbage");
        let e = read_frame(&mut std::io::Cursor::new(stream)).unwrap_err();
        assert!(format!("{e:#}").contains("bound"), "{e:#}");
    }

    #[test]
    fn handshake_roundtrip_and_skew() {
        let mut stream = Vec::new();
        write_handshake(&mut stream).unwrap();
        assert_eq!(stream.len(), 6);
        let mut r = std::io::Cursor::new(stream.clone());
        assert_eq!(read_handshake(&mut r).unwrap(), WIRE_VERSION);
        let mut r = std::io::Cursor::new(stream.clone());
        assert!(expect_handshake(&mut r).is_ok());
        // version skew: readable, but the strict form rejects it loudly
        let mut skew = stream.clone();
        skew[4] = 9;
        let mut r = std::io::Cursor::new(skew.clone());
        assert_eq!(read_handshake(&mut r).unwrap(), 9);
        let e = expect_handshake(&mut std::io::Cursor::new(skew)).unwrap_err();
        assert!(format!("{e:#}").contains("v9"), "{e:#}");
        // wrong magic: not an RFC peer at all
        let mut junk = stream;
        junk[0] = b'X';
        assert!(read_handshake(&mut std::io::Cursor::new(junk)).is_err());
        // truncation
        assert!(read_handshake(&mut std::io::Cursor::new(vec![0u8; 3])).is_err());
    }
}
