//! Multi-threaded bank-shard encoder/decoder for [`CompressedTensor`].
//!
//! The paper's storage writes every bank through its own write port in
//! parallel; the software analog is one worker per *bank shard*: the
//! batch rows are split into contiguous shards and each worker encodes
//! its shard into an independent [`super::compressed::BankSegment`].
//! Segments are kept separate in the result (no stitch copy), which is
//! also what makes batch concatenation zero-copy.  Decoding scatters
//! each segment into its disjoint slice of the dense output, so it
//! parallelizes the same way.

use std::num::NonZeroUsize;
use std::thread;

use crate::runtime::Tensor;

use super::compressed::{BankSegment, CompressedTensor};

/// Encoder/decoder policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct EncoderConfig {
    /// worker shards; rows are split into this many contiguous ranges
    pub shards: usize,
    /// minimum activation sparsity for compressed transport to pay off
    /// (the 16+4 sidecar bits per 16x16-bit bank break even near 8%
    /// zeros); below it payloads stay dense -- see [`super::Payload`]
    pub min_sparsity: f64,
    /// tensors smaller than this many elements encode on the calling
    /// thread.  The workers are scoped threads spawned per call (std
    /// has no pool), so the threshold is set high enough that typical
    /// per-stage activations stay serial -- the pipeline's 11 stage
    /// threads already saturate the cores, and per-payload spawns there
    /// would only add churn.  Sharding kicks in for genuinely large
    /// tensors (big batches / long clips) where the spawn cost
    /// amortizes; a persistent worker pool is a ROADMAP item.
    pub parallel_threshold: usize,
}

impl Default for EncoderConfig {
    fn default() -> Self {
        EncoderConfig {
            shards: thread::available_parallelism()
                .map(NonZeroUsize::get)
                .unwrap_or(4)
                .min(8),
            min_sparsity: 0.10,
            parallel_threshold: 1 << 20,
        }
    }
}

/// Encode a dense tensor into bank-sharded compressed form.  The
/// logical encoding (per-bank hot/mbhot/packed values) is identical for
/// every shard count; only the internal segment boundaries differ.
pub fn encode(t: &Tensor, cfg: &EncoderConfig) -> CompressedTensor {
    let (rows, row_len) = CompressedTensor::layout(&t.shape);
    let row_banks = row_len.div_ceil(crate::sim::rfc::BANK_WIDTH);
    let shards = cfg.shards.clamp(1, rows.max(1));
    let segments = if shards <= 1 || t.data.len() < cfg.parallel_threshold {
        vec![BankSegment::encode(&t.data, rows, row_len)]
    } else {
        let per = rows.div_ceil(shards);
        let ranges: Vec<(usize, usize)> = (0..shards)
            .map(|s| (s * per, rows.min((s + 1) * per)))
            .filter(|&(lo, hi)| lo < hi)
            .collect();
        thread::scope(|scope| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|&(lo, hi)| {
                    let slice = &t.data[lo * row_len..hi * row_len];
                    scope.spawn(move || BankSegment::encode(slice, hi - lo, row_len))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("encoder shard panicked"))
                .collect()
        })
    };
    CompressedTensor {
        shape: t.shape.clone(),
        row_len,
        row_banks,
        segments,
    }
}

/// Decode back to dense form, one worker per segment when the tensor is
/// large enough to pay for the spawns.
pub fn decode(ct: &CompressedTensor, cfg: &EncoderConfig) -> Tensor {
    if ct.segments.len() <= 1 || ct.len() < cfg.parallel_threshold || ct.row_len == 0 {
        return ct.to_tensor();
    }
    let row_len = ct.row_len;
    let mut data = vec![0f32; ct.len()];
    thread::scope(|scope| {
        let mut rest: &mut [f32] = &mut data;
        for seg in &ct.segments {
            let taken = std::mem::take(&mut rest);
            let (head, tail) = taken.split_at_mut(seg.rows * row_len);
            scope.spawn(move || seg.decode_into(head, row_len));
            rest = tail;
        }
    });
    Tensor {
        shape: ct.shape.clone(),
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparse(shape: Vec<usize>, sparsity: f64, seed: u64) -> Tensor {
        Tensor::random_sparse(shape, sparsity, seed)
    }

    fn cfg(shards: usize, threshold: usize) -> EncoderConfig {
        EncoderConfig {
            shards,
            min_sparsity: 0.10,
            parallel_threshold: threshold,
        }
    }

    #[test]
    fn parallel_encode_matches_serial_logically() {
        let t = sparse(vec![13, 4, 40], 0.55, 42);
        let serial = encode(&t, &cfg(1, usize::MAX));
        for shards in [2usize, 3, 5, 8] {
            let par = encode(&t, &cfg(shards, 0));
            par.validate().unwrap();
            assert_eq!(par.nnz(), serial.nnz(), "shards {shards}");
            assert_eq!(par.to_tensor(), t, "shards {shards}");
            for r in 0..13 {
                for b in 0..par.row_banks {
                    assert_eq!(par.bank(r, b), serial.bank(r, b));
                }
            }
        }
    }

    #[test]
    fn parallel_decode_matches_dense() {
        let t = sparse(vec![16, 512], 0.7, 7);
        let ct = encode(&t, &cfg(4, 0));
        assert!(ct.segments.len() > 1);
        assert_eq!(decode(&ct, &cfg(4, 0)), t);
        assert_eq!(decode(&ct, &cfg(4, usize::MAX)), t);
    }

    #[test]
    fn more_shards_than_rows_is_fine() {
        let t = sparse(vec![2, 64], 0.5, 8);
        let ct = encode(&t, &cfg(16, 0));
        ct.validate().unwrap();
        assert_eq!(ct.to_tensor(), t);
        assert!(ct.segments.len() <= 2);
    }

    #[test]
    fn small_tensors_stay_on_calling_thread() {
        let t = sparse(vec![4, 32], 0.5, 9);
        let ct = encode(&t, &EncoderConfig::default());
        assert_eq!(ct.segments.len(), 1);
        assert_eq!(ct.to_tensor(), t);
    }
}
