//! RFC runtime subsystem: production sparse-feature compression for the
//! serving hot path (paper SSV-C, Fig. 7 / Fig. 11).
//!
//! [`crate::sim::rfc`] stays the bit-exact functional + cost *reference*
//! for the paper's bank/mini-bank scheme; this module is what the
//! coordinator actually ships between pipeline stages:
//!
//! * [`CompressedTensor`] -- a bank-sharded encoded tensor whose
//!   batch-axis concatenation is zero-copy (segments move, packed
//!   values don't);
//! * [`encode`] / [`decode`] -- the multi-threaded codec, one worker per
//!   bank shard (the software analog of the paper's per-bank parallel
//!   write ports);
//! * [`Payload`] -- the stage-to-stage transport: compressed when the
//!   post-ReLU sparsity clears the break-even gate, dense otherwise,
//!   decoded lazily on stage entry.
//!
//! * [`wire`] -- wire format v1: the versioned, length-prefixed byte
//!   encoding of [`CompressedTensor`] that leaves the process (multi-node
//!   shard links, see [`crate::coordinator::shard`]).
//!
//! Equivalence contract (enforced by `tests/rfc_equivalence.rs`): for
//! every 16-aligned bank, the runtime encoder's `(hot, mbhot, packed)`
//! triple is bit-for-bit identical to `sim::rfc::encode_bank`, decode
//! reproduces the dense tensor exactly, and the serialized wire stream
//! is byte-identical to the sim mirror `sim::rfc::wire_bytes`.

pub mod compressed;
pub mod encoder;
pub mod wire;

pub use compressed::{BankSegment, CompressedTensor, BANK_SIDECAR_BITS};
pub use encoder::{decode, encode, EncoderConfig};

use crate::runtime::Tensor;

/// A tensor travelling between pipeline stages: dense, or bank-encoded
/// when compression pays for itself.
#[derive(Debug, Clone)]
pub enum Payload {
    Dense(Tensor),
    Compressed(CompressedTensor),
}

impl Payload {
    /// Wrap a stage output for transport: compress when the sparsity
    /// gate says the wire format wins (ReLU outputs usually do), keep
    /// dense otherwise.  This is the runtime decision the paper makes
    /// structurally by placing the encoder after every ReLU.
    ///
    /// Single pass: encoding counts the nonzeros as it packs, so the
    /// gate reads the exact wire costs off the result instead of
    /// pre-scanning the tensor; a tensor that fails the gate costs one
    /// discarded encode, which post-ReLU traffic rarely does.
    pub fn from_tensor(t: Tensor, cfg: &EncoderConfig) -> Payload {
        let ct = encode(&t, cfg);
        if ct.sparsity() >= cfg.min_sparsity && ct.compressed_bits() < ct.dense_bits() {
            Payload::Compressed(ct)
        } else {
            Payload::Dense(t)
        }
    }

    /// Logical dense shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            Payload::Dense(t) => &t.shape,
            Payload::Compressed(c) => &c.shape,
        }
    }

    pub fn is_compressed(&self) -> bool {
        matches!(self, Payload::Compressed(_))
    }

    /// The compressed view, if this payload is compressed.
    pub fn as_compressed(&self) -> Option<&CompressedTensor> {
        match self {
            Payload::Compressed(c) => Some(c),
            Payload::Dense(_) => None,
        }
    }

    /// Bits the dense transport of this payload would occupy.
    pub fn dense_bits(&self) -> u64 {
        self.shape().iter().product::<usize>() as u64
            * crate::sim::rfc::ELEM_BITS as u64
    }

    /// Bits this payload occupies on the wire.
    pub fn transport_bits(&self) -> u64 {
        match self {
            Payload::Dense(t) => {
                t.len() as u64 * crate::sim::rfc::ELEM_BITS as u64
            }
            Payload::Compressed(c) => c.compressed_bits(),
        }
    }

    /// Materialize the dense tensor -- the lazy decode point, called at
    /// stage entry by [`crate::runtime::Executable::run_payload`].
    pub fn into_dense(self, cfg: &EncoderConfig) -> Tensor {
        match self {
            Payload::Dense(t) => t,
            Payload::Compressed(c) => decode(&c, cfg),
        }
    }

    /// Borrowing variant of [`Payload::into_dense`].
    pub fn to_dense(&self, cfg: &EncoderConfig) -> Tensor {
        match self {
            Payload::Dense(t) => t.clone(),
            Payload::Compressed(c) => decode(c, cfg),
        }
    }

    /// Move the payload out, leaving an empty placeholder behind.
    ///
    /// The placeholder is a zero-element *dense* tensor, not a
    /// compressed one: the old `CompressedTensor::default()` placeholder
    /// made a batch that had shipped dense read as still carrying a
    /// compressed padding sidecar (`is_compressed()` true, a phantom
    /// segment row) after the server moved its payload out -- see the
    /// `take_after_dense_batch_leaves_no_padding_sidecar` regression
    /// test in [`crate::coordinator::batcher`].
    pub fn take(&mut self) -> Payload {
        std::mem::replace(self, Payload::Dense(Tensor::zeros(vec![0])))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor_with_sparsity(sparsity: f64, seed: u64) -> Tensor {
        Tensor::random_sparse(vec![4, 256], sparsity, seed)
    }

    #[test]
    fn gate_compresses_sparse_keeps_dense() {
        let cfg = EncoderConfig::default();
        let sparse = Payload::from_tensor(tensor_with_sparsity(0.6, 1), &cfg);
        assert!(sparse.is_compressed());
        let dense = Payload::from_tensor(tensor_with_sparsity(0.0, 2), &cfg);
        assert!(!dense.is_compressed());
    }

    #[test]
    fn into_dense_roundtrips() {
        let cfg = EncoderConfig::default();
        let t = tensor_with_sparsity(0.5, 3);
        let p = Payload::from_tensor(t.clone(), &cfg);
        assert_eq!(p.shape(), &[4, 256]);
        assert_eq!(p.into_dense(&cfg), t);
    }

    #[test]
    fn compressed_transport_is_smaller_when_sparse() {
        let cfg = EncoderConfig::default();
        let t = tensor_with_sparsity(0.7, 4);
        let dense_bits = t.len() as u64 * 16;
        let p = Payload::from_tensor(t, &cfg);
        assert!(p.transport_bits() < dense_bits / 2);
    }

    #[test]
    fn take_leaves_empty_placeholder() {
        let cfg = EncoderConfig::default();
        let mut p = Payload::from_tensor(tensor_with_sparsity(0.5, 5), &cfg);
        let taken = p.take();
        assert_eq!(taken.shape(), &[4, 256]);
        assert_eq!(p.shape(), &[0]);
        // the placeholder must not read as a compressed sidecar
        assert!(!p.is_compressed());
        assert_eq!(p.transport_bits(), 0);
    }
}
