//! RFC runtime subsystem: production sparse-feature compression for the
//! serving hot path (paper SSV-C, Fig. 7 / Fig. 11).
//!
//! [`crate::sim::rfc`] stays the bit-exact functional + cost *reference*
//! for the paper's bank/mini-bank scheme; this module is what the
//! coordinator actually ships between pipeline stages:
//!
//! * [`CompressedTensor`] -- a bank-sharded encoded tensor whose
//!   batch-axis concatenation is zero-copy (segments move, packed
//!   values don't);
//! * [`encode`] / [`decode`] -- the multi-threaded codec, one worker per
//!   bank shard (the software analog of the paper's per-bank parallel
//!   write ports);
//! * [`Payload`] -- the stage-to-stage transport: compressed when the
//!   post-ReLU sparsity clears the break-even gate, dense otherwise,
//!   decoded lazily on stage entry.
//!
//! * [`wire`] -- wire format v1: the versioned, length-prefixed byte
//!   encoding of [`CompressedTensor`] that leaves the process (multi-node
//!   shard links, see [`crate::coordinator::shard`]);
//!
//! * [`kernel`] -- compressed-domain compute: input-skipping GEMM that
//!   consumes the bank segments directly, so a stage whose leading op is
//!   a GEMM never decodes at all (see `docs/compressed-compute.md`).
//!
//! Equivalence contract (enforced by `tests/rfc_equivalence.rs`): for
//! every 16-aligned bank, the runtime encoder's `(hot, mbhot, packed)`
//! triple is bit-for-bit identical to `sim::rfc::encode_bank`, decode
//! reproduces the dense tensor exactly, and the serialized wire stream
//! is byte-identical to the sim mirror `sim::rfc::wire_bytes`.

pub mod compressed;
pub mod encoder;
pub mod kernel;
pub mod wire;

pub use compressed::{BankRef, BankSegment, CompressedTensor, BANK_SIDECAR_BITS};
pub use encoder::{decode, encode, EncoderConfig};
pub use kernel::{
    cpu_features, GemmF32, GemmQ88, IsaPath, KernelConfig, LaneDispatch,
    SpmmStats,
};

use std::sync::atomic::{AtomicU64, Ordering};

use crate::runtime::Tensor;

/// Counters for the [`Payload::from_tensor`] compression gate (embedded
/// in `crate::coordinator::Metrics` for the serving path).
#[derive(Debug, Default)]
pub struct GateStats {
    /// tensors the sampled pre-gate rejected before any encode work
    pub pre_rejects: AtomicU64,
    /// tensors that were fully encoded and then failed the exact gate
    /// (the encode was discarded)
    pub encode_discards: AtomicU64,
    /// tensors that cleared the gate and shipped compressed
    pub compressed: AtomicU64,
}

impl GateStats {
    /// Fraction of gate decisions that avoided a discarded encode thanks
    /// to the sampled pre-gate.
    pub fn pre_reject_fraction(&self) -> f64 {
        let pre = self.pre_rejects.load(Ordering::Relaxed);
        let total = pre
            + self.encode_discards.load(Ordering::Relaxed)
            + self.compressed.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        pre as f64 / total as f64
    }
}

/// Elements the pre-gate samples (evenly strided) before committing to a
/// full encode.
const GATE_SAMPLES: usize = 512;

/// Rotating-offset strided zero count over `data`: the shared sampler
/// behind every cheap sparsity pre-gate ([`Payload::from_tensor`] and
/// the batcher's batch-level gate, which sums it across request clips).
/// Returns `(zeros, sampled)`.
///
/// The intra-stride offset rotates as the scan walks: a fixed-stride
/// scan of a tensor whose trailing (channel) axis divides the stride
/// would sample a single channel lane forever, and post-ReLU sparsity
/// is strongly channel-structured -- the offset cycles through every
/// residue class of the stride, so no axis can alias the sample.
pub fn sampled_zeros(data: &[f32]) -> (usize, usize) {
    if data.is_empty() {
        return (0, 0);
    }
    let stride = (data.len() / GATE_SAMPLES).max(1);
    let mut sampled = 0usize;
    let mut zeros = 0usize;
    let mut j = 0usize;
    loop {
        let i = j * stride + j % stride;
        if i >= data.len() {
            break;
        }
        sampled += 1;
        if data[i] == 0.0 {
            zeros += 1;
        }
        j += 1;
    }
    (zeros, sampled)
}

/// Shared pre-gate decision rule: does a `(zeros, sampled)` estimate of
/// a `total`-element population fall clearly below `min_sparsity`?
/// Sampling error is covered by a three-sigma margin (zero for an
/// exhaustive scan, where the estimate is exact), so a compressible
/// tensor is practically never pre-rejected; a dense tensor that slips
/// through just pays the encode it would have paid without the gate.
pub fn sampled_sparsity_below(
    zeros: usize,
    sampled: usize,
    total: usize,
    min_sparsity: f64,
) -> bool {
    if sampled == 0 || min_sparsity <= 0.0 {
        return false;
    }
    let s = zeros as f64 / sampled as f64;
    let margin = if sampled >= total {
        0.0 // exhaustive scan: the estimate is exact
    } else {
        3.0 * (s * (1.0 - s) / sampled as f64).sqrt()
    };
    s + margin < min_sparsity
}

/// Cheap sampled-sparsity check: `true` when the tensor is clearly too
/// dense for the `min_sparsity` gate, so [`Payload::from_tensor`] can
/// skip the full (discarded) encode.
fn pre_gate_rejects(data: &[f32], min_sparsity: f64) -> bool {
    let (zeros, sampled) = sampled_zeros(data);
    sampled_sparsity_below(zeros, sampled, data.len(), min_sparsity)
}

/// A tensor travelling between pipeline stages: dense, or bank-encoded
/// when compression pays for itself.
#[derive(Debug, Clone)]
pub enum Payload {
    Dense(Tensor),
    Compressed(CompressedTensor),
}

impl Payload {
    /// Wrap a stage output for transport: compress when the sparsity
    /// gate says the wire format wins (ReLU outputs usually do), keep
    /// dense otherwise.  This is the runtime decision the paper makes
    /// structurally by placing the encoder after every ReLU.
    ///
    /// Two-stage gate: a strided-sample sparsity estimate first (so a
    /// clearly-dense tensor never pays a full discarded encode), then
    /// the exact gate read off the encode result for everything that
    /// survives.  Post-ReLU traffic almost always clears both.
    pub fn from_tensor(t: Tensor, cfg: &EncoderConfig) -> Payload {
        Self::from_tensor_metered(t, cfg, None)
    }

    /// [`Payload::from_tensor`] recording gate decisions into `stats`
    /// (the serving path passes `Metrics::gate`).
    pub fn from_tensor_metered(
        t: Tensor,
        cfg: &EncoderConfig,
        stats: Option<&GateStats>,
    ) -> Payload {
        if pre_gate_rejects(&t.data, cfg.min_sparsity) {
            if let Some(s) = stats {
                s.pre_rejects.fetch_add(1, Ordering::Relaxed);
            }
            return Payload::Dense(t);
        }
        let ct = encode(&t, cfg);
        if ct.sparsity() >= cfg.min_sparsity && ct.compressed_bits() < ct.dense_bits() {
            if let Some(s) = stats {
                s.compressed.fetch_add(1, Ordering::Relaxed);
            }
            Payload::Compressed(ct)
        } else {
            if let Some(s) = stats {
                s.encode_discards.fetch_add(1, Ordering::Relaxed);
            }
            Payload::Dense(t)
        }
    }

    /// Logical dense shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            Payload::Dense(t) => &t.shape,
            Payload::Compressed(c) => &c.shape,
        }
    }

    pub fn is_compressed(&self) -> bool {
        matches!(self, Payload::Compressed(_))
    }

    /// The compressed view, if this payload is compressed.
    pub fn as_compressed(&self) -> Option<&CompressedTensor> {
        match self {
            Payload::Compressed(c) => Some(c),
            Payload::Dense(_) => None,
        }
    }

    /// Bits the dense transport of this payload would occupy.
    pub fn dense_bits(&self) -> u64 {
        self.shape().iter().product::<usize>() as u64
            * crate::sim::rfc::ELEM_BITS as u64
    }

    /// Bits this payload occupies on the wire.
    pub fn transport_bits(&self) -> u64 {
        match self {
            Payload::Dense(t) => {
                t.len() as u64 * crate::sim::rfc::ELEM_BITS as u64
            }
            Payload::Compressed(c) => c.compressed_bits(),
        }
    }

    /// Materialize the dense tensor -- the lazy decode point, called at
    /// stage entry by [`crate::runtime::Executable::run_payload`].
    pub fn into_dense(self, cfg: &EncoderConfig) -> Tensor {
        match self {
            Payload::Dense(t) => t,
            Payload::Compressed(c) => decode(&c, cfg),
        }
    }

    /// Borrowing variant of [`Payload::into_dense`].
    pub fn to_dense(&self, cfg: &EncoderConfig) -> Tensor {
        match self {
            Payload::Dense(t) => t.clone(),
            Payload::Compressed(c) => decode(c, cfg),
        }
    }

    /// Move the payload out, leaving an empty placeholder behind.
    ///
    /// The placeholder is a zero-element *dense* tensor, not a
    /// compressed one: the old `CompressedTensor::default()` placeholder
    /// made a batch that had shipped dense read as still carrying a
    /// compressed padding sidecar (`is_compressed()` true, a phantom
    /// segment row) after the server moved its payload out -- see the
    /// `take_after_dense_batch_leaves_no_padding_sidecar` regression
    /// test in [`crate::coordinator::batcher`].
    pub fn take(&mut self) -> Payload {
        std::mem::replace(self, Payload::Dense(Tensor::zeros(vec![0])))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor_with_sparsity(sparsity: f64, seed: u64) -> Tensor {
        Tensor::random_sparse(vec![4, 256], sparsity, seed)
    }

    #[test]
    fn gate_compresses_sparse_keeps_dense() {
        let cfg = EncoderConfig::default();
        let sparse = Payload::from_tensor(tensor_with_sparsity(0.6, 1), &cfg);
        assert!(sparse.is_compressed());
        let dense = Payload::from_tensor(tensor_with_sparsity(0.0, 2), &cfg);
        assert!(!dense.is_compressed());
    }

    #[test]
    fn pre_gate_skips_encode_for_dense_and_counts_it() {
        let cfg = EncoderConfig::default();
        let stats = GateStats::default();
        // clearly dense: rejected by the sampled pre-gate, no encode
        let p = Payload::from_tensor_metered(
            tensor_with_sparsity(0.0, 10),
            &cfg,
            Some(&stats),
        );
        assert!(!p.is_compressed());
        assert_eq!(stats.pre_rejects.load(Ordering::Relaxed), 1);
        assert_eq!(stats.encode_discards.load(Ordering::Relaxed), 0);
        // clearly sparse: clears both gates
        let p = Payload::from_tensor_metered(
            tensor_with_sparsity(0.6, 11),
            &cfg,
            Some(&stats),
        );
        assert!(p.is_compressed());
        assert_eq!(stats.compressed.load(Ordering::Relaxed), 1);
        assert!(stats.pre_reject_fraction() > 0.4);
    }

    #[test]
    fn pre_gate_survives_channel_aligned_sparsity() {
        // regression: len 65536 gives stride 128, a multiple of the
        // 64-wide channel axis.  A fixed-stride scan would only ever
        // sample channel 0 (the dense one) and wrongly pre-reject a
        // 98%-sparse tensor; the rotating offset must see the zeros.
        let data: Vec<f32> = (0..64 * 1024)
            .map(|i| if i % 64 == 0 { 1.0 } else { 0.0 })
            .collect();
        let t = Tensor::new(vec![1024, 64], data).unwrap();
        let cfg = EncoderConfig::default();
        assert!(!pre_gate_rejects(&t.data, cfg.min_sparsity));
        let p = Payload::from_tensor(t, &cfg);
        assert!(p.is_compressed(), "channel-structured sparsity must compress");
    }

    #[test]
    fn pre_gate_never_rejects_compressible_traffic() {
        // every sparsity that clears the exact gate must also clear the
        // sampled pre-gate (the three-sigma margin absorbs sampling
        // error); borderline-dense tensors ship dense either way
        let cfg = EncoderConfig::default();
        for s10 in [20u64, 40, 60, 80, 95] {
            let t = tensor_with_sparsity(s10 as f64 / 100.0, 100 + s10);
            let exact_gate = {
                let ct = encode(&t, &cfg);
                ct.sparsity() >= cfg.min_sparsity
                    && ct.compressed_bits() < ct.dense_bits()
            };
            let p = Payload::from_tensor(t, &cfg);
            assert_eq!(
                p.is_compressed(),
                exact_gate,
                "sparsity {}%: pre-gate changed the gate decision",
                s10
            );
        }
        // right at the gate threshold the sampled estimate may land on
        // either side; the invariant is one-sided -- a compressed ship
        // always means the exact gate passed
        for s10 in [8u64, 10, 12, 15] {
            let t = tensor_with_sparsity(s10 as f64 / 100.0, 200 + s10);
            let exact_gate = {
                let ct = encode(&t, &cfg);
                ct.sparsity() >= cfg.min_sparsity
                    && ct.compressed_bits() < ct.dense_bits()
            };
            let p = Payload::from_tensor(t, &cfg);
            assert!(
                !p.is_compressed() || exact_gate,
                "sparsity {}%: compressed despite failing the exact gate",
                s10
            );
        }
    }

    #[test]
    fn into_dense_roundtrips() {
        let cfg = EncoderConfig::default();
        let t = tensor_with_sparsity(0.5, 3);
        let p = Payload::from_tensor(t.clone(), &cfg);
        assert_eq!(p.shape(), &[4, 256]);
        assert_eq!(p.into_dense(&cfg), t);
    }

    #[test]
    fn compressed_transport_is_smaller_when_sparse() {
        let cfg = EncoderConfig::default();
        let t = tensor_with_sparsity(0.7, 4);
        let dense_bits = t.len() as u64 * 16;
        let p = Payload::from_tensor(t, &cfg);
        assert!(p.transport_bits() < dense_bits / 2);
    }

    #[test]
    fn take_leaves_empty_placeholder() {
        let cfg = EncoderConfig::default();
        let mut p = Payload::from_tensor(tensor_with_sparsity(0.5, 5), &cfg);
        let taken = p.take();
        assert_eq!(taken.shape(), &[4, 256]);
        assert_eq!(p.shape(), &[0]);
        // the placeholder must not read as a compressed sidecar
        assert!(!p.is_compressed());
        assert_eq!(p.transport_bits(), 0);
    }
}
