//! Compressed-domain GEMM: input-skipping matrix multiply computed
//! directly over [`CompressedTensor`] bank segments, so a stage whose
//! leading op is a GEMM never pays the decode on stage entry.
//!
//! This is the software realization of the paper's compute side (SSV-B):
//! the Dyn-Mult-PE consumes RFC-encoded features as they are stored --
//! a Logic-AND of the weight mask and the feature hot code drops zero
//! features before any multiplier sees them.  Here the per-bank
//! `(hot, mbhot)` bitmaps play the same role: `mbhot == 0` skips a whole
//! bank, and the hot code walks only the packed nonzeros, each selecting
//! the weight row it multiplies (input-skipping).  Work is scheduled
//! dynamically: segment row-chunks are dealt to a worker pool and idle
//! workers steal from loaded ones, the software analog of the intra-PE
//! dynamic DSP scheduling that keeps sparsity-imbalanced banks from
//! serializing the batch.
//!
//! ## GEMM geometry
//!
//! A [`CompressedTensor`] stores `rows` rows of `row_len` elements.  A
//! `k x n` GEMM spec claims the tensor when either
//!
//! * `k == row_len` -- each tensor row is one GEMM row (any alignment:
//!   tail-bank padding lanes are never hot), or
//! * `k % 16 == 0 && row_len % k == 0` -- each tensor row splits into
//!   `row_len / k` GEMM rows on exact bank boundaries (the per-joint
//!   feature transform of a GCN block: `(N, T, V, C) x (C, C')`).
//!
//! ## Exactness contract (enforced by `tests/prop_invariants.rs`)
//!
//! * **f32**: bit-identical to [`gemm_dense_f32`] over the decoded
//!   tensor.  Both accumulate lane-ascending per output element, and a
//!   skipped zero lane contributes `+-0.0` to a finite accumulation,
//!   which never changes the bits (a `NaN`/`inf` weight against a zero
//!   activation would poison the dense path but be skipped here, so
//!   [`GemmF32::new`] rejects non-finite weights).
//! * **Q8.8**: bit-identical to [`crate::quant::quant_matmul_ref`] over
//!   the quantized decoded tensor.  Packed values are quantized on the
//!   fly; zero lanes quantize to 0 and wrapping integer accumulation is
//!   order-independent, so skipping them is exact by construction.
//!
//! ## SIMD lanes
//!
//! The hot loops execute many MACs per cycle -- the CPU analog of the
//! paper's Dyn-Mult-PE DSP array -- by vectorizing over the *output
//! column* axis with `std::arch` intrinsics: AVX2 8-wide f32 / 8-wide
//! i32 on x86_64 (runtime-detected), NEON 4-wide on aarch64 (baseline).
//! The scalar loops stay compiled on every target as the always-available
//! fallback and the single source of truth for bit-exactness:
//!
//! * the f32 lanes use separate multiply-then-add (never FMA), so each
//!   element performs the identical IEEE operations in the identical
//!   order as the scalar loop -- vectorizing across columns never
//!   reorders any single output element's accumulation;
//! * the Q8.8 lanes widen int16 weights to int32 and use wrapping
//!   vector multiply/add, exact by integer arithmetic;
//! * ragged tails (`n` not a multiple of the lane width) fall through to
//!   the scalar loop for the remaining columns.
//!
//! Weight rows are processed in [`PANEL_COLS`]-column panels so a hot
//! bitmap's packed nonzeros stream against weight columns resident in
//! L1/L2 instead of walking whole cache-busting rows; panels change only
//! *when* columns are touched, never per-element accumulation order.
//! The next bank's packed values are software-prefetched while the
//! current bank drains (see [`BankSegment::packed_values`] for the
//! stride contract that makes the hint meaningful).
//!
//! Selection is per call via [`KernelConfig::dispatch`]:
//! [`LaneDispatch::Auto`] resolves to the widest ISA the host supports,
//! [`LaneDispatch::ForceScalar`] pins the reference loops (every CI leg
//! exercises forced-scalar vs auto equivalence, so the fallback cannot
//! rot on SIMD-capable runners).  Result bits are identical either way.

use std::num::NonZeroUsize;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;

use anyhow::{ensure, Result};

use crate::quant::{quantize, quantize_slice, requantize_slice};
use crate::runtime::Tensor;
use crate::sim::rfc::BANK_WIDTH;

use super::compressed::{BankSegment, CompressedTensor};

/// A dense `k x n` f32 weight operand (row-major: `w[l * n + j]`).
#[derive(Debug, Clone)]
pub struct GemmF32 {
    k: usize,
    n: usize,
    w: Vec<f32>,
}

impl GemmF32 {
    pub fn new(weights: Vec<f32>, k: usize, n: usize) -> Result<GemmF32> {
        ensure!(k > 0 && n > 0, "GEMM dims must be positive, got {k}x{n}");
        ensure!(
            weights.len() == k * n,
            "weight buffer holds {} values for a {k}x{n} GEMM",
            weights.len()
        );
        // the bit-exactness contract rests on skipped zero lanes being
        // no-ops, which a NaN/inf weight would break (NaN * 0 != 0)
        ensure!(
            weights.iter().all(|w| w.is_finite()),
            "GEMM weights must be finite for input-skipping to be exact"
        );
        Ok(GemmF32 { k, n, w: weights })
    }

    /// Build from a rank-2 `[k, n]` tensor.
    pub fn from_tensor(w: &Tensor) -> Result<GemmF32> {
        ensure!(
            w.shape.len() == 2,
            "weights must be rank-2 [k, n], got {:?}",
            w.shape
        );
        GemmF32::new(w.data.clone(), w.shape[0], w.shape[1])
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    /// Q8.8-quantize the weights once, ahead of serving.
    pub fn quantize(&self) -> GemmQ88 {
        GemmQ88 {
            k: self.k,
            n: self.n,
            wq: quantize_slice(&self.w),
        }
    }
}

/// A Q8.8 `k x n` weight operand (row-major int16 raws).
#[derive(Debug, Clone)]
pub struct GemmQ88 {
    k: usize,
    n: usize,
    wq: Vec<i16>,
}

impl GemmQ88 {
    pub fn new(wq: Vec<i16>, k: usize, n: usize) -> Result<GemmQ88> {
        ensure!(k > 0 && n > 0, "GEMM dims must be positive, got {k}x{n}");
        ensure!(
            wq.len() == k * n,
            "weight buffer holds {} values for a {k}x{n} GEMM",
            wq.len()
        );
        Ok(GemmQ88 { k, n, wq })
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn raw_weights(&self) -> &[i16] {
        &self.wq
    }
}

/// Lane-selection knob: which inner-loop implementation a kernel call
/// may use.  Purely a scheduling choice -- the output bits are identical
/// for every value (enforced by `tests/prop_invariants.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LaneDispatch {
    /// Runtime feature detection picks the widest ISA path the host
    /// supports (AVX2 on x86_64, NEON on aarch64, scalar otherwise).
    #[default]
    Auto,
    /// Pin the scalar reference loops -- the testing knob that keeps the
    /// fallback exercised on SIMD-capable machines, and an escape hatch
    /// should a platform's vector unit ever misbehave.
    ForceScalar,
}

impl LaneDispatch {
    /// The ISA path this dispatch setting resolves to on this host.
    pub fn resolve(self) -> IsaPath {
        match self {
            LaneDispatch::Auto => IsaPath::detect(),
            LaneDispatch::ForceScalar => IsaPath::Scalar,
        }
    }
}

/// A concrete inner-loop implementation (what [`LaneDispatch::resolve`]
/// picked).  `Avx2`/`Neon` are only ever produced on hosts where the
/// corresponding intrinsics are safe to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsaPath {
    /// Portable scalar loops: always available, the bit-exactness
    /// reference every vector path must match.
    Scalar,
    /// AVX2 256-bit lanes (8 x f32 / 8 x i32), x86_64 runtime-detected.
    Avx2,
    /// NEON 128-bit lanes (4 x f32 / 4 x i32), aarch64 baseline.
    Neon,
}

impl IsaPath {
    /// Detect the widest path the running CPU supports.
    #[cfg(target_arch = "x86_64")]
    pub fn detect() -> IsaPath {
        if std::arch::is_x86_feature_detected!("avx2") {
            IsaPath::Avx2
        } else {
            IsaPath::Scalar
        }
    }

    /// Detect the widest path the running CPU supports (NEON is
    /// architecturally mandatory on aarch64 -- no runtime probe needed).
    #[cfg(target_arch = "aarch64")]
    pub fn detect() -> IsaPath {
        IsaPath::Neon
    }

    /// Detect the widest path the running CPU supports.
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    pub fn detect() -> IsaPath {
        IsaPath::Scalar
    }

    /// Stable name for bench output / `BENCH_rfc.json` (the ratchet uses
    /// it to tell AVX2 runners from scalar ones).
    pub fn name(self) -> &'static str {
        match self {
            IsaPath::Scalar => "scalar",
            IsaPath::Avx2 => "avx2",
            IsaPath::Neon => "neon",
        }
    }

    /// f32 elements per vector lane operation.
    pub fn f32_lanes(self) -> usize {
        match self {
            IsaPath::Scalar => 1,
            IsaPath::Avx2 => 8,
            IsaPath::Neon => 4,
        }
    }

    /// f32 axpy over one weight-row panel: `out[j] += x * w[j]`.
    /// Every path performs the identical per-element IEEE multiply and
    /// add (no FMA), so the bits match the scalar loop exactly.
    #[inline]
    fn axpy_f32(self, out: &mut [f32], x: f32, w: &[f32]) {
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Avx2 is only produced by detect() after the
            // runtime avx2 probe succeeded
            IsaPath::Avx2 => unsafe { axpy_f32_avx2(out, x, w) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is baseline on aarch64
            IsaPath::Neon => unsafe { axpy_f32_neon(out, x, w) },
            _ => axpy_f32_scalar(out, x, w),
        }
    }

    /// Q8.8 accumulate over one weight-row panel:
    /// `acc[j] = acc[j].wrapping_add(xq * wq[j] as i32)`.
    #[inline]
    fn acc_q88(self, acc: &mut [i32], xq: i32, wq: &[i16]) {
        match self {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: see axpy_f32
            IsaPath::Avx2 => unsafe { acc_q88_avx2(acc, xq, wq) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: see axpy_f32
            IsaPath::Neon => unsafe { acc_q88_neon(acc, xq, wq) },
            _ => acc_q88_scalar(acc, xq, wq),
        }
    }
}

/// Runtime-detected CPU features relevant to the kernel, stamped into
/// `BENCH_rfc.json` so ratchet comparisons are self-describing.
#[cfg(target_arch = "x86_64")]
pub fn cpu_features() -> Vec<&'static str> {
    let mut f = Vec::new();
    for (name, have) in [
        ("sse4.2", std::arch::is_x86_feature_detected!("sse4.2")),
        ("avx", std::arch::is_x86_feature_detected!("avx")),
        ("avx2", std::arch::is_x86_feature_detected!("avx2")),
        ("fma", std::arch::is_x86_feature_detected!("fma")),
        ("avx512f", std::arch::is_x86_feature_detected!("avx512f")),
    ] {
        if have {
            f.push(name);
        }
    }
    f
}

/// Runtime-detected CPU features relevant to the kernel, stamped into
/// `BENCH_rfc.json` so ratchet comparisons are self-describing.
#[cfg(target_arch = "aarch64")]
pub fn cpu_features() -> Vec<&'static str> {
    vec!["neon"]
}

/// Runtime-detected CPU features relevant to the kernel, stamped into
/// `BENCH_rfc.json` so ratchet comparisons are self-describing.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn cpu_features() -> Vec<&'static str> {
    Vec::new()
}

/// Scheduling knobs for the kernel's worker pool.
#[derive(Debug, Clone, Copy)]
pub struct KernelConfig {
    /// worker threads (1 = run on the calling thread)
    pub workers: usize,
    /// tensor rows per schedulable job (granularity of stealing)
    pub rows_per_job: usize,
    /// estimated MACs (`nnz * n`) below which the call stays serial --
    /// the workers are scoped threads spawned per call, so tiny GEMMs
    /// must not pay the spawn cost
    pub par_threshold_macs: u64,
    /// inner-loop lane selection (never changes output bits)
    pub dispatch: LaneDispatch,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            workers: thread::available_parallelism()
                .map(NonZeroUsize::get)
                .unwrap_or(4)
                .min(8),
            rows_per_job: 1,
            par_threshold_macs: 1 << 21,
            dispatch: LaneDispatch::Auto,
        }
    }
}

impl KernelConfig {
    /// Single-threaded configuration (deterministic scheduling, zero
    /// spawn cost -- the result bits are identical either way).
    pub fn serial() -> KernelConfig {
        KernelConfig {
            workers: 1,
            rows_per_job: usize::MAX,
            par_threshold_macs: u64::MAX,
            dispatch: LaneDispatch::Auto,
        }
    }

    /// Same scheduling, different lane selection.
    pub fn with_dispatch(mut self, dispatch: LaneDispatch) -> KernelConfig {
        self.dispatch = dispatch;
        self
    }
}

/// What one spmm call did: the runtime mirror of the sim cost model's
/// valid/skipped MAC admission accounting (`crate::sim::dyn_pe`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpmmStats {
    /// GEMM output rows produced
    pub gemm_rows: u64,
    /// nonzero input lanes multiplied (each costs `n` MACs)
    pub hot_lanes: u64,
    /// zero input lanes skipped by the hot bitmaps (each would have cost
    /// `n` MACs in the dense path; padding lanes are not counted)
    pub skipped_lanes: u64,
    /// jobs scheduled
    pub jobs: u64,
    /// jobs a worker stole from another worker's queue
    pub stolen_jobs: u64,
}

impl SpmmStats {
    /// Fraction of logical input lanes the kernel skipped.
    pub fn skip_fraction(&self) -> f64 {
        let total = self.hot_lanes + self.skipped_lanes;
        if total == 0 {
            return 0.0;
        }
        self.skipped_lanes as f64 / total as f64
    }
}

/// How tensor rows map onto GEMM rows for a claimed `(tensor, k)` pair.
#[derive(Debug, Clone, Copy)]
struct Geometry {
    /// GEMM rows per tensor row
    g: usize,
    /// banks per GEMM row
    bpg: usize,
    /// total GEMM rows
    m: usize,
    /// output columns
    n: usize,
    /// dense elements per tensor row (for live-lane accounting)
    row_len: usize,
}

/// The claim-geometry rule, single-sourced for the kernel
/// ([`geometry`]) and the shape-level pre-checks
/// (`StagePlan::claims_dims`): a `k`-row GEMM consumes `row_len`-element
/// rows when `k` spans the whole row or splits it on exact bank
/// boundaries (see module docs).
pub fn claimable_row(row_len: usize, k: usize) -> bool {
    row_len > 0 && (row_len == k || (k % BANK_WIDTH == 0 && row_len % k == 0))
}

fn geometry(ct: &CompressedTensor, k: usize, n: usize) -> Result<Geometry> {
    let (rows, row_len) = CompressedTensor::layout(&ct.shape);
    ensure!(row_len > 0, "cannot GEMM a zero-length row");
    if row_len == k {
        return Ok(Geometry {
            g: 1,
            bpg: ct.row_banks(),
            m: rows,
            n,
            row_len,
        });
    }
    ensure!(
        claimable_row(row_len, k),
        "cannot claim row_len {row_len} with k {k}: k must equal row_len \
         or be a bank-aligned divisor of it"
    );
    Ok(Geometry {
        g: row_len / k,
        bpg: k / BANK_WIDTH,
        m: rows * (row_len / k),
        n,
        row_len,
    })
}

/// Whether a `k`-row GEMM can consume this tensor in compressed form
/// (the fast-path claim check -- see module docs for the geometry rule).
pub fn claimable(ct: &CompressedTensor, k: usize) -> bool {
    if ct.is_empty() {
        return false;
    }
    geometry(ct, k, 1).is_ok()
}

/// Logical output shape: the input shape with its last axis replaced by
/// `n` when the GEMM ran per-last-axis, else a flat `[m, n]`.
fn out_shape(in_shape: &[usize], k: usize, n: usize, m: usize) -> Vec<usize> {
    if in_shape.last() == Some(&k) {
        let mut s = in_shape.to_vec();
        *s.last_mut().unwrap() = n;
        s
    } else {
        vec![m, n]
    }
}

/// Compressed-domain f32 GEMM: `out[m, n] = decode(ct)[m, k] . w[k, n]`,
/// computed without decoding.  Bit-identical to [`gemm_dense_f32`] over
/// the decoded tensor for finite weights, for every worker count.
pub fn spmm_f32(
    ct: &CompressedTensor,
    gemm: &GemmF32,
    cfg: &KernelConfig,
) -> Result<(Tensor, SpmmStats)> {
    let geo = geometry(ct, gemm.k, gemm.n)?;
    let mut out = vec![0f32; geo.m * geo.n];
    let w = gemm.w.as_slice();
    let isa = cfg.dispatch.resolve();
    let mut stats = dispatch(ct, &mut out, geo, cfg, &|job, _scratch, local| {
        run_job_f32(job, w, geo, isa, local)
    });
    stats.gemm_rows = geo.m as u64;
    let shape = out_shape(&ct.shape, gemm.k, gemm.n, geo.m);
    Ok((Tensor { shape, data: out }, stats))
}

/// Compressed-domain Q8.8 GEMM: packed values are quantized on the fly,
/// accumulated in int32 per output row (per-worker scratch, reused
/// across jobs), then requantized.  Bit-identical to
/// [`crate::quant::quant_matmul_ref`] over the quantized decoded tensor.
pub fn spmm_q88(
    ct: &CompressedTensor,
    gemm: &GemmQ88,
    cfg: &KernelConfig,
) -> Result<(Vec<i16>, SpmmStats)> {
    let geo = geometry(ct, gemm.k, gemm.n)?;
    let mut out = vec![0i16; geo.m * geo.n];
    let wq = gemm.wq.as_slice();
    let isa = cfg.dispatch.resolve();
    let mut stats = dispatch(ct, &mut out, geo, cfg, &|job, scratch, local| {
        run_job_q88(job, wq, geo, isa, scratch, local)
    });
    stats.gemm_rows = geo.m as u64;
    Ok((out, stats))
}

/// The decode-then-dense f32 reference: plain GEMM over a dense `[m, k]`
/// buffer in the exact accumulation order the compressed kernel uses
/// (lanes ascending per output element).  This is both the bit-exactness
/// reference and the dense baseline the benches time.
pub fn gemm_dense_f32(x: &[f32], m: usize, gemm: &GemmF32) -> Vec<f32> {
    let (k, n) = (gemm.k, gemm.n);
    debug_assert_eq!(x.len(), m * k);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let out_row = &mut out[i * n..(i + 1) * n];
        for (l, &xv) in x[i * k..(i + 1) * k].iter().enumerate() {
            let wrow = &gemm.w[l * n..(l + 1) * n];
            for (o, &wv) in out_row.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    out
}

// ------------------------------------------------------------ scheduling

/// One schedulable unit: a run of whole tensor rows within one segment,
/// owning the disjoint output slice those rows produce.
struct Job<'a, T> {
    seg: &'a BankSegment,
    row_lo: usize,
    row_hi: usize,
    out: &'a mut [T],
}

#[derive(Default)]
struct LocalStats {
    hot: u64,
    skipped: u64,
    stolen: u64,
}

/// A worker's job queue: jobs are claimed by a unique `fetch_add` ticket,
/// so any worker (owner or thief) can pop concurrently without blocking.
struct JobQueue<'a, T> {
    slots: Vec<Mutex<Option<Job<'a, T>>>>,
    next: AtomicUsize,
}

impl<'a, T> JobQueue<'a, T> {
    fn pop(&self) -> Option<Job<'a, T>> {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.slots.len() {
                return None;
            }
            // the ticket is unique, so the slot still holds its job
            if let Some(job) = self.slots[i].lock().unwrap().take() {
                return Some(job);
            }
        }
    }
}

/// Chop the tensor into jobs: contiguous row chunks per segment, each
/// paired with its disjoint slice of `out`.
fn build_jobs<'a, T>(
    ct: &'a CompressedTensor,
    out: &'a mut [T],
    geo: Geometry,
    rows_per_job: usize,
) -> Vec<Job<'a, T>> {
    let rpj = rows_per_job.max(1);
    let per_row = geo.g * geo.n;
    let mut jobs = Vec::new();
    let mut rest = out;
    for seg in ct.segments() {
        let mut r = 0;
        while r < seg.rows() {
            let hi = seg.rows().min(r.saturating_add(rpj));
            let (head, tail) =
                std::mem::take(&mut rest).split_at_mut((hi - r) * per_row);
            rest = tail;
            jobs.push(Job {
                seg,
                row_lo: r,
                row_hi: hi,
                out: head,
            });
            r = hi;
        }
    }
    jobs
}

/// Run every job through `run`: serially when the work is too small to
/// pay for thread spawns, otherwise on a worker pool with work-stealing.
/// Jobs are dealt to the workers in contiguous blocks (cache-adjacent
/// rows); a worker that drains its own queue steals from the others, so
/// a sparsity-imbalanced segment never serializes the batch.
fn dispatch<T, F>(
    ct: &CompressedTensor,
    out: &mut [T],
    geo: Geometry,
    cfg: &KernelConfig,
    run: &F,
) -> SpmmStats
where
    T: Send,
    F: Fn(Job<'_, T>, &mut Vec<i32>, &mut LocalStats) + Sync,
{
    let est_macs = ct.nnz() as u64 * geo.n as u64;
    let workers = if est_macs < cfg.par_threshold_macs {
        1
    } else {
        cfg.workers.max(1)
    };
    let jobs = build_jobs(ct, out, geo, cfg.rows_per_job);
    let n_jobs = jobs.len() as u64;

    if workers <= 1 || jobs.len() <= 1 {
        let mut local = LocalStats::default();
        let mut scratch = Vec::new();
        let mut it = jobs.into_iter().peekable();
        while let Some(job) = it.next() {
            // warm the next job's segment head (often the next
            // BankSegment) while the current one drains
            if let Some(next) = it.peek() {
                if let Some(p) = next.seg.packed_values().first() {
                    prefetch_read(p);
                }
            }
            run(job, &mut scratch, &mut local);
        }
        return SpmmStats {
            gemm_rows: 0, // filled by the caller
            hot_lanes: local.hot,
            skipped_lanes: local.skipped,
            jobs: n_jobs,
            stolen_jobs: 0,
        };
    }

    let w = workers.min(jobs.len());
    let per = jobs.len().div_ceil(w);
    let mut queues: Vec<JobQueue<T>> = Vec::with_capacity(w);
    let mut it = jobs.into_iter();
    for _ in 0..w {
        queues.push(JobQueue {
            slots: it.by_ref().take(per).map(|j| Mutex::new(Some(j))).collect(),
            next: AtomicUsize::new(0),
        });
    }
    let queues = &queues;
    let totals = Mutex::new(LocalStats::default());
    thread::scope(|scope| {
        for me in 0..w {
            let totals = &totals;
            scope.spawn(move || {
                let mut local = LocalStats::default();
                let mut scratch = Vec::new();
                loop {
                    // own queue first, then sweep the victims round-robin
                    let mut taken = queues[me].pop().map(|j| (j, false));
                    if taken.is_none() {
                        for off in 1..queues.len() {
                            let victim = (me + off) % queues.len();
                            if let Some(j) = queues[victim].pop() {
                                taken = Some((j, true));
                                break;
                            }
                        }
                    }
                    let Some((job, stolen)) = taken else { break };
                    if stolen {
                        local.stolen += 1;
                    }
                    run(job, &mut scratch, &mut local);
                }
                let mut t = totals.lock().unwrap();
                t.hot += local.hot;
                t.skipped += local.skipped;
                t.stolen += local.stolen;
            });
        }
    });
    let t = totals.into_inner().unwrap();
    SpmmStats {
        gemm_rows: 0,
        hot_lanes: t.hot,
        skipped_lanes: t.skipped,
        jobs: n_jobs,
        stolen_jobs: t.stolen,
    }
}

// ---------------------------------------------------------- job kernels

/// Output columns per weight-row panel.  One bank selects at most 16
/// weight rows; a 512-column f32 panel of those rows is 16 x 512 x 4 B =
/// 32 KiB -- resident in L1d (or at worst hot L2) while the bank's
/// packed nonzeros stream against it.  Panels partition the column axis
/// *outside* the lane walk, so each output element still accumulates its
/// lanes in exactly the scalar reference order (bit-exactness is
/// untouched); lane/skip accounting runs on the first panel only, so a
/// bank's lanes are counted exactly once however many panels replay it.
pub const PANEL_COLS: usize = 512;

/// Best-effort software prefetch of the cache line holding `p` (the
/// upcoming bank's packed values, or the next job's segment head).
/// No-op off x86_64: stable Rust exposes no aarch64 prefetch intrinsic,
/// and the NEON path's strictly-forward packed stream is a pattern
/// hardware prefetchers already handle.
#[inline(always)]
fn prefetch_read<T>(p: *const T) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: PREFETCHT0 is architecturally a hint and cannot fault, so
    // `p` may be any address -- including one just past the end of a
    // bank's packed run. Callers derive `p` from
    // `BankSegment::packed_values` / `BankIter::upcoming_packed`, whose
    // stride contract (property-tested in prop_invariants.rs) keeps the
    // pointer inside or one-past the segment's packed buffer.
    unsafe {
        use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<_MM_HINT_T0>(p.cast::<i8>());
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = p;
    }
}

/// f32 job body: stream the job's banks, axpy each hot lane's weight row
/// into the owning output row.  Lane order is ascending (lowest set bit
/// first), matching [`gemm_dense_f32`] bit for bit; columns are covered
/// in [`PANEL_COLS`] panels (see the constant's docs).
fn run_job_f32(
    job: Job<'_, f32>,
    w: &[f32],
    geo: Geometry,
    isa: IsaPath,
    local: &mut LocalStats,
) {
    let Job {
        seg,
        row_lo,
        row_hi,
        out,
    } = job;
    let n = geo.n;
    let mut j0 = 0usize;
    let mut first_panel = true;
    while j0 < n {
        let j1 = n.min(j0 + PANEL_COLS);
        let mut banks = seg.banks_in(row_lo, row_hi);
        while let Some(bank) = banks.next() {
            if first_panel {
                let live = BANK_WIDTH.min(geo.row_len - bank.index * BANK_WIDTH);
                let nnz = bank.packed.len();
                local.hot += nnz as u64;
                local.skipped += (live - nnz) as u64;
            }
            // warm the next bank's packed head while this one drains
            if let Some(p) = banks.upcoming_packed() {
                prefetch_read(p);
            }
            if bank.mbhot == 0 {
                continue; // mini-bank gate: whole bank empty
            }
            let gr = (bank.row - row_lo) * geo.g + bank.index / geo.bpg;
            let out_row = &mut out[gr * n + j0..gr * n + j1];
            let base = (bank.index % geo.bpg) * BANK_WIDTH;
            let mut bits = bank.hot;
            let mut next = 0usize;
            while bits != 0 {
                let lane = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let x = bank.packed[next];
                next += 1;
                let row0 = (base + lane) * n;
                isa.axpy_f32(out_row, x, &w[row0 + j0..row0 + j1]);
            }
        }
        first_panel = false;
        j0 = j1;
    }
}

/// Q8.8 job body: per GEMM row, accumulate `quantize(x) * wq` into the
/// worker's int32 scratch (panel by panel, like the f32 path), then
/// requantize into the output row via the shared
/// [`crate::quant::requantize_slice`] rule.
fn run_job_q88(
    job: Job<'_, i16>,
    wq: &[i16],
    geo: Geometry,
    isa: IsaPath,
    scratch: &mut Vec<i32>,
    local: &mut LocalStats,
) {
    let Job {
        seg,
        row_lo,
        row_hi: _,
        out,
    } = job;
    let rb = seg.banks_per_row();
    for (gr, out_row) in out.chunks_mut(geo.n).enumerate() {
        let r = row_lo + gr / geo.g;
        let gi = gr % geo.g;
        scratch.clear();
        scratch.resize(geo.n, 0);
        let b0 = r * rb + gi * geo.bpg;
        let mut j0 = 0usize;
        let mut first_panel = true;
        while j0 < geo.n {
            let j1 = geo.n.min(j0 + PANEL_COLS);
            let mut banks = seg.bank_span(b0, b0 + geo.bpg);
            while let Some(bank) = banks.next() {
                if first_panel {
                    let live =
                        BANK_WIDTH.min(geo.row_len - bank.index * BANK_WIDTH);
                    let nnz = bank.packed.len();
                    local.hot += nnz as u64;
                    local.skipped += (live - nnz) as u64;
                }
                if let Some(p) = banks.upcoming_packed() {
                    prefetch_read(p);
                }
                if bank.mbhot == 0 {
                    continue;
                }
                let base = (bank.index % geo.bpg) * BANK_WIDTH;
                let mut bits = bank.hot;
                let mut next = 0usize;
                while bits != 0 {
                    let lane = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let xq = quantize(bank.packed[next]) as i32;
                    next += 1;
                    let row0 = (base + lane) * geo.n;
                    isa.acc_q88(
                        &mut scratch[j0..j1],
                        xq,
                        &wq[row0 + j0..row0 + j1],
                    );
                }
            }
            first_panel = false;
            j0 = j1;
        }
        requantize_slice(scratch, out_row);
    }
}

// ----------------------------------------------------------- lane loops
//
// The scalar loops below are the bit-exactness reference; each vector
// path performs the identical per-element operations (IEEE f32 multiply
// then add -- never FMA, whose unrounded intermediate would change bits;
// wrapping i32 multiply/add, exact by integer arithmetic) over the same
// column order, then falls through to the scalar loop for the ragged
// tail.  `out`/`acc` and `w`/`wq` panels always have equal lengths.

#[inline(always)]
fn axpy_f32_scalar(out: &mut [f32], x: f32, w: &[f32]) {
    for (o, &wv) in out.iter_mut().zip(w) {
        *o += x * wv;
    }
}

#[inline(always)]
fn acc_q88_scalar(acc: &mut [i32], xq: i32, wq: &[i16]) {
    for (a, &wv) in acc.iter_mut().zip(wq) {
        // |xq|, |wq| <= 2^15, so the i32 product is exact (no overflow
        // before the wrapping accumulate)
        *a = a.wrapping_add(xq * wv as i32);
    }
}

/// # Safety
/// ISA: caller must have verified AVX2 support ([`IsaPath::detect`] is the
/// only producer of [`IsaPath::Avx2`]).
/// Alignment: none required -- every vector access is `_mm256_loadu_*`/
/// `_mm256_storeu_*` (unaligned), so `out`/`w` may start anywhere; the
/// `j + 8 <= n` guard keeps each 32-byte access inside the slices.
/// Stride: `x` streams from `BankSegment::packed_values`, whose contiguous
/// stride contract is property-tested in `prop_invariants.rs`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_f32_avx2(out: &mut [f32], x: f32, w: &[f32]) {
    use core::arch::x86_64::*;
    debug_assert_eq!(out.len(), w.len());
    let n = out.len();
    let xs = _mm256_set1_ps(x);
    let mut j = 0usize;
    while j + 8 <= n {
        let wv = _mm256_loadu_ps(w.as_ptr().add(j));
        let ov = _mm256_loadu_ps(out.as_ptr().add(j));
        let r = _mm256_add_ps(ov, _mm256_mul_ps(xs, wv));
        _mm256_storeu_ps(out.as_mut_ptr().add(j), r);
        j += 8;
    }
    axpy_f32_scalar(&mut out[j..], x, &w[j..]);
}

/// # Safety
/// ISA: caller must have verified AVX2 support ([`IsaPath::detect`]).
/// Alignment: none required -- `_mm_loadu_si128`/`_mm256_loadu_si256`/
/// `_mm256_storeu_si256` are the unaligned forms; the `j + 8 <= n` guard
/// bounds the 16-byte `wq` read and 32-byte `acc` accesses (`wq` is i16,
/// so 8 lanes span 16 bytes) inside the slices.
/// Stride: `xq` streams from `BankSegment::packed_values` (contract
/// property-tested in `prop_invariants.rs`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn acc_q88_avx2(acc: &mut [i32], xq: i32, wq: &[i16]) {
    use core::arch::x86_64::*;
    debug_assert_eq!(acc.len(), wq.len());
    let n = acc.len();
    let xs = _mm256_set1_epi32(xq);
    let mut j = 0usize;
    while j + 8 <= n {
        let w128 = _mm_loadu_si128(wq.as_ptr().add(j).cast());
        let wv = _mm256_cvtepi16_epi32(w128);
        let prod = _mm256_mullo_epi32(xs, wv);
        let av = _mm256_loadu_si256(acc.as_ptr().add(j).cast());
        _mm256_storeu_si256(
            acc.as_mut_ptr().add(j).cast(),
            _mm256_add_epi32(av, prod),
        );
        j += 8;
    }
    acc_q88_scalar(&mut acc[j..], xq, &wq[j..]);
}

/// # Safety
/// ISA: NEON is baseline on aarch64, so this is callable from any aarch64
/// context ([`IsaPath::detect`] still gates dispatch for symmetry).
/// Alignment: none required -- `vld1q_f32`/`vst1q_f32` tolerate unaligned
/// addresses; the `j + 4 <= n` guard keeps each 16-byte access in-bounds.
/// Stride: `x` streams from `BankSegment::packed_values` (contract
/// property-tested in `prop_invariants.rs`).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_f32_neon(out: &mut [f32], x: f32, w: &[f32]) {
    use core::arch::aarch64::*;
    debug_assert_eq!(out.len(), w.len());
    let n = out.len();
    let xs = vdupq_n_f32(x);
    let mut j = 0usize;
    while j + 4 <= n {
        let wv = vld1q_f32(w.as_ptr().add(j));
        let ov = vld1q_f32(out.as_ptr().add(j));
        // separate mul + add (not vfmaq) to keep the scalar rounding
        let r = vaddq_f32(ov, vmulq_f32(xs, wv));
        vst1q_f32(out.as_mut_ptr().add(j), r);
        j += 4;
    }
    axpy_f32_scalar(&mut out[j..], x, &w[j..]);
}

/// # Safety
/// ISA: NEON is baseline on aarch64; callable from any aarch64 context.
/// Alignment: none required -- `vld1_s16`/`vld1q_s32`/`vst1q_s32` tolerate
/// unaligned addresses; the `j + 4 <= n` guard bounds the 8-byte `wq` read
/// and 16-byte `acc` accesses inside the slices.
/// Stride: `xq` streams from `BankSegment::packed_values` (contract
/// property-tested in `prop_invariants.rs`).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn acc_q88_neon(acc: &mut [i32], xq: i32, wq: &[i16]) {
    use core::arch::aarch64::*;
    debug_assert_eq!(acc.len(), wq.len());
    let n = acc.len();
    let xs = vdupq_n_s32(xq);
    let mut j = 0usize;
    while j + 4 <= n {
        let wv = vmovl_s16(vld1_s16(wq.as_ptr().add(j)));
        let av = vld1q_s32(acc.as_ptr().add(j));
        // integer multiply-accumulate wraps, matching wrapping_add
        vst1q_s32(acc.as_mut_ptr().add(j), vmlaq_s32(av, xs, wv));
        j += 4;
    }
    acc_q88_scalar(&mut acc[j..], xq, &wq[j..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_matmul_ref;
    use crate::rfc::{encode, EncoderConfig};
    use crate::util::rng::Rng;

    fn enc(shards: usize) -> EncoderConfig {
        EncoderConfig {
            shards,
            min_sparsity: 0.0,
            parallel_threshold: 0,
        }
    }

    fn weights(k: usize, n: usize, seed: u64) -> GemmF32 {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..k * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        GemmF32::new(w, k, n).unwrap()
    }

    #[test]
    fn matches_dense_reference_bit_for_bit() {
        // k == row_len (incl. bank-unaligned) and k | row_len geometries
        for (shape, k) in [
            (vec![5usize, 48], 48),
            (vec![3, 52], 52), // tail-bank padding lanes
            (vec![4, 2, 64], 64),
            (vec![2, 6, 32], 32),
        ] {
            let t = Tensor::random_sparse(shape.clone(), 0.6, k as u64);
            let ct = encode(&t, &enc(2));
            let gemm = weights(k, 9, 7);
            let (y, stats) = spmm_f32(&ct, &gemm, &KernelConfig::serial()).unwrap();
            let m = t.len() / k;
            let reference = gemm_dense_f32(&t.data, m, &gemm);
            assert_eq!(y.data.len(), reference.len());
            for (a, b) in y.data.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits(), "shape {shape:?} k {k}");
            }
            assert_eq!(stats.gemm_rows, m as u64);
            assert_eq!(
                stats.hot_lanes + stats.skipped_lanes,
                t.len() as u64,
                "lane accounting covers every logical element"
            );
            assert_eq!(
                stats.hot_lanes as usize,
                t.data.iter().filter(|&&v| v != 0.0).count()
            );
        }
    }

    #[test]
    fn worker_count_never_changes_the_bits() {
        let t = Tensor::random_sparse(vec![13, 64], 0.5, 99);
        let ct = encode(&t, &enc(3));
        let gemm = weights(64, 17, 3);
        let (reference, _) = spmm_f32(&ct, &gemm, &KernelConfig::serial()).unwrap();
        for workers in [2usize, 4, 8] {
            let cfg = KernelConfig {
                workers,
                rows_per_job: 1,
                par_threshold_macs: 0,
                dispatch: LaneDispatch::Auto,
            };
            let (y, stats) = spmm_f32(&ct, &gemm, &cfg).unwrap();
            for (a, b) in y.data.iter().zip(&reference.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers {workers}");
            }
            assert_eq!(stats.jobs, 13);
        }
    }

    #[test]
    fn q88_matches_quant_matmul_ref() {
        let t = Tensor::random_sparse(vec![6, 32], 0.55, 21);
        let ct = encode(&t, &enc(2));
        let gemm = weights(32, 11, 5).quantize();
        let (yq, stats) = spmm_q88(&ct, &gemm, &KernelConfig::serial()).unwrap();
        let xq = quantize_slice(&t.data);
        let reference = quant_matmul_ref(&xq, gemm.raw_weights(), 6, 32, 11);
        assert_eq!(yq, reference);
        assert_eq!(stats.gemm_rows, 6);
    }

    #[test]
    fn all_zero_and_fully_dense_banks() {
        let z = CompressedTensor::zeros(vec![4, 32]);
        let gemm = weights(32, 5, 1);
        let (y, stats) = spmm_f32(&z, &gemm, &KernelConfig::serial()).unwrap();
        assert!(y.data.iter().all(|&v| v == 0.0));
        assert_eq!(stats.hot_lanes, 0);
        assert_eq!(stats.skipped_lanes, 4 * 32);

        let d = Tensor::random_sparse(vec![4, 32], 0.0, 2);
        let cd = encode(&d, &enc(1));
        let (yd, sd) = spmm_f32(&cd, &gemm, &KernelConfig::serial()).unwrap();
        assert_eq!(sd.skipped_lanes, 0);
        let reference = gemm_dense_f32(&d.data, 4, &gemm);
        for (a, b) in yd.data.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn non_finite_weights_are_rejected() {
        assert!(GemmF32::new(vec![1.0, f32::NAN], 2, 1).is_err());
        assert!(GemmF32::new(vec![f32::INFINITY, 0.0], 1, 2).is_err());
        let w = Tensor::new(vec![1, 2], vec![0.0, f32::NEG_INFINITY]).unwrap();
        assert!(GemmF32::from_tensor(&w).is_err());
        assert!(GemmF32::new(vec![0.0; 4], 2, 2).is_ok());
    }

    #[test]
    fn claim_rules() {
        let t = Tensor::random_sparse(vec![2, 96], 0.5, 4);
        let ct = encode(&t, &enc(1));
        assert!(claimable(&ct, 96)); // whole row
        assert!(claimable(&ct, 32)); // bank-aligned divisor
        assert!(claimable(&ct, 48));
        assert!(!claimable(&ct, 24)); // not bank-aligned
        assert!(!claimable(&ct, 40)); // does not divide row_len
        let gemm = weights(24, 4, 6);
        assert!(spmm_f32(&ct, &gemm, &KernelConfig::serial()).is_err());
        // unaligned k is fine only when it covers the whole row
        let u = encode(&Tensor::random_sparse(vec![2, 52], 0.5, 8), &enc(1));
        assert!(claimable(&u, 52));
        assert!(!claimable(&u, 26));
    }

    #[test]
    fn sub_row_gemm_reshapes_trailing_axis() {
        // (N, T, C) x (C, n): output keeps the leading axes
        let t = Tensor::random_sparse(vec![3, 4, 16], 0.5, 11);
        let ct = encode(&t, &enc(1));
        let gemm = weights(16, 6, 12);
        let (y, _) = spmm_f32(&ct, &gemm, &KernelConfig::serial()).unwrap();
        assert_eq!(y.shape, vec![3, 4, 6]);
        let reference = gemm_dense_f32(&t.data, 12, &gemm);
        assert_eq!(y.data, reference);
    }

    #[test]
    fn dispatch_resolution_is_sane() {
        // ForceScalar always pins the reference loops
        assert_eq!(LaneDispatch::ForceScalar.resolve(), IsaPath::Scalar);
        // Auto resolves to *some* path this binary can execute; its lane
        // width and name are consistent
        let auto = LaneDispatch::Auto.resolve();
        assert!(auto.f32_lanes() >= 1);
        match auto {
            IsaPath::Scalar => assert_eq!(auto.name(), "scalar"),
            IsaPath::Avx2 => {
                assert_eq!(auto.name(), "avx2");
                assert_eq!(auto.f32_lanes(), 8);
                assert!(cpu_features().contains(&"avx2"));
            }
            IsaPath::Neon => {
                assert_eq!(auto.name(), "neon");
                assert_eq!(auto.f32_lanes(), 4);
            }
        }
        assert_eq!(KernelConfig::default().dispatch, LaneDispatch::Auto);
        let forced =
            KernelConfig::serial().with_dispatch(LaneDispatch::ForceScalar);
        assert_eq!(forced.dispatch, LaneDispatch::ForceScalar);
    }

    #[test]
    fn forced_scalar_matches_auto_dispatch_bit_for_bit() {
        // n = 21 exercises the ragged tail of both the 8-wide and 4-wide
        // paths; run serial and parallel schedules under both dispatches
        let t = Tensor::random_sparse(vec![9, 64], 0.55, 41);
        let ct = encode(&t, &enc(2));
        let gemm = weights(64, 21, 43);
        let gq = gemm.quantize();
        let scalar_cfg =
            KernelConfig::serial().with_dispatch(LaneDispatch::ForceScalar);
        let (y_s, st_s) = spmm_f32(&ct, &gemm, &scalar_cfg).unwrap();
        let (q_s, _) = spmm_q88(&ct, &gq, &scalar_cfg).unwrap();
        for cfg in [
            KernelConfig::serial(),
            KernelConfig {
                workers: 4,
                rows_per_job: 1,
                par_threshold_macs: 0,
                dispatch: LaneDispatch::Auto,
            },
        ] {
            let (y, st) = spmm_f32(&ct, &gemm, &cfg).unwrap();
            for (a, b) in y.data.iter().zip(&y_s.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "auto vs forced-scalar");
            }
            assert_eq!(st.hot_lanes, st_s.hot_lanes);
            assert_eq!(st.skipped_lanes, st_s.skipped_lanes);
            let (q, _) = spmm_q88(&ct, &gq, &cfg).unwrap();
            assert_eq!(q, q_s);
        }
    }

    #[test]
    fn column_panels_count_lanes_once_and_stay_bit_exact() {
        // n = PANEL_COLS + 3 forces a second (ragged) panel; the banks
        // replay once per panel but lane accounting must not double-count
        let t = Tensor::random_sparse(vec![3, 32], 0.5, 51);
        let ct = encode(&t, &enc(1));
        let n = PANEL_COLS + 3;
        let gemm = weights(32, n, 53);
        for dispatch in [LaneDispatch::Auto, LaneDispatch::ForceScalar] {
            let cfg = KernelConfig::serial().with_dispatch(dispatch);
            let (y, stats) = spmm_f32(&ct, &gemm, &cfg).unwrap();
            let reference = gemm_dense_f32(&t.data, 3, &gemm);
            for (a, b) in y.data.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits(), "{dispatch:?}");
            }
            assert_eq!(
                stats.hot_lanes + stats.skipped_lanes,
                t.len() as u64,
                "multi-panel lane accounting must count each bank once"
            );
            let gq = gemm.quantize();
            let (yq, qstats) = spmm_q88(&ct, &gq, &cfg).unwrap();
            let xq = quantize_slice(&t.data);
            let qref = quant_matmul_ref(&xq, gq.raw_weights(), 3, 32, n);
            assert_eq!(yq, qref);
            assert_eq!(qstats.hot_lanes + qstats.skipped_lanes, t.len() as u64);
        }
    }

    #[test]
    fn lane_loops_match_scalar_on_all_tail_lengths() {
        // drive the lane primitives directly through every residue of the
        // widest lane width (plus empty), on whichever path Auto picked
        let isa = LaneDispatch::Auto.resolve();
        let mut rng = Rng::new(61);
        for len in 0..=17usize {
            let w: Vec<f32> = (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let x = rng.f32() * 2.0 - 1.0;
            let mut out_v: Vec<f32> =
                (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let mut out_s = out_v.clone();
            isa.axpy_f32(&mut out_v, x, &w);
            axpy_f32_scalar(&mut out_s, x, &w);
            for (a, b) in out_v.iter().zip(&out_s) {
                assert_eq!(a.to_bits(), b.to_bits(), "f32 len {len}");
            }

            let wq: Vec<i16> =
                (0..len).map(|_| (rng.f32() * 60000.0 - 30000.0) as i16).collect();
            let xq = (rng.f32() * 60000.0 - 30000.0) as i32;
            let mut acc_v: Vec<i32> =
                (0..len).map(|_| (rng.f32() * 1e6) as i32).collect();
            let mut acc_s = acc_v.clone();
            isa.acc_q88(&mut acc_v, xq, &wq);
            acc_q88_scalar(&mut acc_s, xq, &wq);
            assert_eq!(acc_v, acc_s, "q88 len {len}");
        }
    }

    #[test]
    fn stealing_engages_on_imbalanced_segments() {
        // one dense segment, one nearly-empty one: with one job per row
        // and 2 workers dealt contiguous halves, the worker that gets
        // the empty half must steal from the loaded one
        let dense = Tensor::random_sparse(vec![8, 256], 0.0, 31);
        let sparse = Tensor::random_sparse(vec![8, 256], 0.99, 32);
        let mut data = dense.data.clone();
        data.extend_from_slice(&sparse.data);
        let ct = CompressedTensor::concat_batch(vec![
            encode(&dense, &enc(1)),
            encode(&sparse, &enc(1)),
        ])
        .unwrap();
        let gemm = weights(256, 32, 33);
        let cfg = KernelConfig {
            workers: 2,
            rows_per_job: 1,
            par_threshold_macs: 0,
            dispatch: LaneDispatch::Auto,
        };
        let (y, stats) = spmm_f32(&ct, &gemm, &cfg).unwrap();
        let reference = gemm_dense_f32(&data, 16, &gemm);
        for (a, b) in y.data.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(stats.jobs, 16);
        // scheduling is timing-dependent; correctness above is the hard
        // guarantee, stolen_jobs just has to be consistent
        assert!(stats.stolen_jobs <= stats.jobs);
    }
}
