//! Compressed-domain GEMM: input-skipping matrix multiply computed
//! directly over [`CompressedTensor`] bank segments, so a stage whose
//! leading op is a GEMM never pays the decode on stage entry.
//!
//! This is the software realization of the paper's compute side (SSV-B):
//! the Dyn-Mult-PE consumes RFC-encoded features as they are stored --
//! a Logic-AND of the weight mask and the feature hot code drops zero
//! features before any multiplier sees them.  Here the per-bank
//! `(hot, mbhot)` bitmaps play the same role: `mbhot == 0` skips a whole
//! bank, and the hot code walks only the packed nonzeros, each selecting
//! the weight row it multiplies (input-skipping).  Work is scheduled
//! dynamically: segment row-chunks are dealt to a worker pool and idle
//! workers steal from loaded ones, the software analog of the intra-PE
//! dynamic DSP scheduling that keeps sparsity-imbalanced banks from
//! serializing the batch.
//!
//! ## GEMM geometry
//!
//! A [`CompressedTensor`] stores `rows` rows of `row_len` elements.  A
//! `k x n` GEMM spec claims the tensor when either
//!
//! * `k == row_len` -- each tensor row is one GEMM row (any alignment:
//!   tail-bank padding lanes are never hot), or
//! * `k % 16 == 0 && row_len % k == 0` -- each tensor row splits into
//!   `row_len / k` GEMM rows on exact bank boundaries (the per-joint
//!   feature transform of a GCN block: `(N, T, V, C) x (C, C')`).
//!
//! ## Exactness contract (enforced by `tests/prop_invariants.rs`)
//!
//! * **f32**: bit-identical to [`gemm_dense_f32`] over the decoded
//!   tensor.  Both accumulate lane-ascending per output element, and a
//!   skipped zero lane contributes `+-0.0` to a finite accumulation,
//!   which never changes the bits (a `NaN`/`inf` weight against a zero
//!   activation would poison the dense path but be skipped here, so
//!   [`GemmF32::new`] rejects non-finite weights).
//! * **Q8.8**: bit-identical to [`crate::quant::quant_matmul_ref`] over
//!   the quantized decoded tensor.  Packed values are quantized on the
//!   fly; zero lanes quantize to 0 and wrapping integer accumulation is
//!   order-independent, so skipping them is exact by construction.

use std::num::NonZeroUsize;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;

use anyhow::{ensure, Result};

use crate::quant::{quantize, quantize_slice, requantize};
use crate::runtime::Tensor;
use crate::sim::rfc::BANK_WIDTH;

use super::compressed::{BankSegment, CompressedTensor};

/// A dense `k x n` f32 weight operand (row-major: `w[l * n + j]`).
#[derive(Debug, Clone)]
pub struct GemmF32 {
    k: usize,
    n: usize,
    w: Vec<f32>,
}

impl GemmF32 {
    pub fn new(weights: Vec<f32>, k: usize, n: usize) -> Result<GemmF32> {
        ensure!(k > 0 && n > 0, "GEMM dims must be positive, got {k}x{n}");
        ensure!(
            weights.len() == k * n,
            "weight buffer holds {} values for a {k}x{n} GEMM",
            weights.len()
        );
        // the bit-exactness contract rests on skipped zero lanes being
        // no-ops, which a NaN/inf weight would break (NaN * 0 != 0)
        ensure!(
            weights.iter().all(|w| w.is_finite()),
            "GEMM weights must be finite for input-skipping to be exact"
        );
        Ok(GemmF32 { k, n, w: weights })
    }

    /// Build from a rank-2 `[k, n]` tensor.
    pub fn from_tensor(w: &Tensor) -> Result<GemmF32> {
        ensure!(
            w.shape.len() == 2,
            "weights must be rank-2 [k, n], got {:?}",
            w.shape
        );
        GemmF32::new(w.data.clone(), w.shape[0], w.shape[1])
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    /// Q8.8-quantize the weights once, ahead of serving.
    pub fn quantize(&self) -> GemmQ88 {
        GemmQ88 {
            k: self.k,
            n: self.n,
            wq: quantize_slice(&self.w),
        }
    }
}

/// A Q8.8 `k x n` weight operand (row-major int16 raws).
#[derive(Debug, Clone)]
pub struct GemmQ88 {
    k: usize,
    n: usize,
    wq: Vec<i16>,
}

impl GemmQ88 {
    pub fn new(wq: Vec<i16>, k: usize, n: usize) -> Result<GemmQ88> {
        ensure!(k > 0 && n > 0, "GEMM dims must be positive, got {k}x{n}");
        ensure!(
            wq.len() == k * n,
            "weight buffer holds {} values for a {k}x{n} GEMM",
            wq.len()
        );
        Ok(GemmQ88 { k, n, wq })
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn raw_weights(&self) -> &[i16] {
        &self.wq
    }
}

/// Scheduling knobs for the kernel's worker pool.
#[derive(Debug, Clone, Copy)]
pub struct KernelConfig {
    /// worker threads (1 = run on the calling thread)
    pub workers: usize,
    /// tensor rows per schedulable job (granularity of stealing)
    pub rows_per_job: usize,
    /// estimated MACs (`nnz * n`) below which the call stays serial --
    /// the workers are scoped threads spawned per call, so tiny GEMMs
    /// must not pay the spawn cost
    pub par_threshold_macs: u64,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            workers: thread::available_parallelism()
                .map(NonZeroUsize::get)
                .unwrap_or(4)
                .min(8),
            rows_per_job: 1,
            par_threshold_macs: 1 << 21,
        }
    }
}

impl KernelConfig {
    /// Single-threaded configuration (deterministic scheduling, zero
    /// spawn cost -- the result bits are identical either way).
    pub fn serial() -> KernelConfig {
        KernelConfig {
            workers: 1,
            rows_per_job: usize::MAX,
            par_threshold_macs: u64::MAX,
        }
    }
}

/// What one spmm call did: the runtime mirror of the sim cost model's
/// valid/skipped MAC admission accounting (`crate::sim::dyn_pe`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpmmStats {
    /// GEMM output rows produced
    pub gemm_rows: u64,
    /// nonzero input lanes multiplied (each costs `n` MACs)
    pub hot_lanes: u64,
    /// zero input lanes skipped by the hot bitmaps (each would have cost
    /// `n` MACs in the dense path; padding lanes are not counted)
    pub skipped_lanes: u64,
    /// jobs scheduled
    pub jobs: u64,
    /// jobs a worker stole from another worker's queue
    pub stolen_jobs: u64,
}

impl SpmmStats {
    /// Fraction of logical input lanes the kernel skipped.
    pub fn skip_fraction(&self) -> f64 {
        let total = self.hot_lanes + self.skipped_lanes;
        if total == 0 {
            return 0.0;
        }
        self.skipped_lanes as f64 / total as f64
    }
}

/// How tensor rows map onto GEMM rows for a claimed `(tensor, k)` pair.
#[derive(Debug, Clone, Copy)]
struct Geometry {
    /// GEMM rows per tensor row
    g: usize,
    /// banks per GEMM row
    bpg: usize,
    /// total GEMM rows
    m: usize,
    /// output columns
    n: usize,
    /// dense elements per tensor row (for live-lane accounting)
    row_len: usize,
}

/// The claim-geometry rule, single-sourced for the kernel
/// ([`geometry`]) and the shape-level pre-checks
/// (`StagePlan::claims_dims`): a `k`-row GEMM consumes `row_len`-element
/// rows when `k` spans the whole row or splits it on exact bank
/// boundaries (see module docs).
pub fn claimable_row(row_len: usize, k: usize) -> bool {
    row_len > 0 && (row_len == k || (k % BANK_WIDTH == 0 && row_len % k == 0))
}

fn geometry(ct: &CompressedTensor, k: usize, n: usize) -> Result<Geometry> {
    let (rows, row_len) = CompressedTensor::layout(&ct.shape);
    ensure!(row_len > 0, "cannot GEMM a zero-length row");
    if row_len == k {
        return Ok(Geometry {
            g: 1,
            bpg: ct.row_banks(),
            m: rows,
            n,
            row_len,
        });
    }
    ensure!(
        claimable_row(row_len, k),
        "cannot claim row_len {row_len} with k {k}: k must equal row_len \
         or be a bank-aligned divisor of it"
    );
    Ok(Geometry {
        g: row_len / k,
        bpg: k / BANK_WIDTH,
        m: rows * (row_len / k),
        n,
        row_len,
    })
}

/// Whether a `k`-row GEMM can consume this tensor in compressed form
/// (the fast-path claim check -- see module docs for the geometry rule).
pub fn claimable(ct: &CompressedTensor, k: usize) -> bool {
    if ct.is_empty() {
        return false;
    }
    geometry(ct, k, 1).is_ok()
}

/// Logical output shape: the input shape with its last axis replaced by
/// `n` when the GEMM ran per-last-axis, else a flat `[m, n]`.
fn out_shape(in_shape: &[usize], k: usize, n: usize, m: usize) -> Vec<usize> {
    if in_shape.last() == Some(&k) {
        let mut s = in_shape.to_vec();
        *s.last_mut().unwrap() = n;
        s
    } else {
        vec![m, n]
    }
}

/// Compressed-domain f32 GEMM: `out[m, n] = decode(ct)[m, k] . w[k, n]`,
/// computed without decoding.  Bit-identical to [`gemm_dense_f32`] over
/// the decoded tensor for finite weights, for every worker count.
pub fn spmm_f32(
    ct: &CompressedTensor,
    gemm: &GemmF32,
    cfg: &KernelConfig,
) -> Result<(Tensor, SpmmStats)> {
    let geo = geometry(ct, gemm.k, gemm.n)?;
    let mut out = vec![0f32; geo.m * geo.n];
    let w = gemm.w.as_slice();
    let mut stats = dispatch(ct, &mut out, geo, cfg, &|job, _scratch, local| {
        run_job_f32(job, w, geo, local)
    });
    stats.gemm_rows = geo.m as u64;
    let shape = out_shape(&ct.shape, gemm.k, gemm.n, geo.m);
    Ok((Tensor { shape, data: out }, stats))
}

/// Compressed-domain Q8.8 GEMM: packed values are quantized on the fly,
/// accumulated in int32 per output row (per-worker scratch, reused
/// across jobs), then requantized.  Bit-identical to
/// [`crate::quant::quant_matmul_ref`] over the quantized decoded tensor.
pub fn spmm_q88(
    ct: &CompressedTensor,
    gemm: &GemmQ88,
    cfg: &KernelConfig,
) -> Result<(Vec<i16>, SpmmStats)> {
    let geo = geometry(ct, gemm.k, gemm.n)?;
    let mut out = vec![0i16; geo.m * geo.n];
    let wq = gemm.wq.as_slice();
    let mut stats = dispatch(ct, &mut out, geo, cfg, &|job, scratch, local| {
        run_job_q88(job, wq, geo, scratch, local)
    });
    stats.gemm_rows = geo.m as u64;
    Ok((out, stats))
}

/// The decode-then-dense f32 reference: plain GEMM over a dense `[m, k]`
/// buffer in the exact accumulation order the compressed kernel uses
/// (lanes ascending per output element).  This is both the bit-exactness
/// reference and the dense baseline the benches time.
pub fn gemm_dense_f32(x: &[f32], m: usize, gemm: &GemmF32) -> Vec<f32> {
    let (k, n) = (gemm.k, gemm.n);
    debug_assert_eq!(x.len(), m * k);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let out_row = &mut out[i * n..(i + 1) * n];
        for (l, &xv) in x[i * k..(i + 1) * k].iter().enumerate() {
            let wrow = &gemm.w[l * n..(l + 1) * n];
            for (o, &wv) in out_row.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    out
}

// ------------------------------------------------------------ scheduling

/// One schedulable unit: a run of whole tensor rows within one segment,
/// owning the disjoint output slice those rows produce.
struct Job<'a, T> {
    seg: &'a BankSegment,
    row_lo: usize,
    row_hi: usize,
    out: &'a mut [T],
}

#[derive(Default)]
struct LocalStats {
    hot: u64,
    skipped: u64,
    stolen: u64,
}

/// A worker's job queue: jobs are claimed by a unique `fetch_add` ticket,
/// so any worker (owner or thief) can pop concurrently without blocking.
struct JobQueue<'a, T> {
    slots: Vec<Mutex<Option<Job<'a, T>>>>,
    next: AtomicUsize,
}

impl<'a, T> JobQueue<'a, T> {
    fn pop(&self) -> Option<Job<'a, T>> {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.slots.len() {
                return None;
            }
            // the ticket is unique, so the slot still holds its job
            if let Some(job) = self.slots[i].lock().unwrap().take() {
                return Some(job);
            }
        }
    }
}

/// Chop the tensor into jobs: contiguous row chunks per segment, each
/// paired with its disjoint slice of `out`.
fn build_jobs<'a, T>(
    ct: &'a CompressedTensor,
    out: &'a mut [T],
    geo: Geometry,
    rows_per_job: usize,
) -> Vec<Job<'a, T>> {
    let rpj = rows_per_job.max(1);
    let per_row = geo.g * geo.n;
    let mut jobs = Vec::new();
    let mut rest = out;
    for seg in ct.segments() {
        let mut r = 0;
        while r < seg.rows() {
            let hi = seg.rows().min(r.saturating_add(rpj));
            let (head, tail) =
                std::mem::take(&mut rest).split_at_mut((hi - r) * per_row);
            rest = tail;
            jobs.push(Job {
                seg,
                row_lo: r,
                row_hi: hi,
                out: head,
            });
            r = hi;
        }
    }
    jobs
}

/// Run every job through `run`: serially when the work is too small to
/// pay for thread spawns, otherwise on a worker pool with work-stealing.
/// Jobs are dealt to the workers in contiguous blocks (cache-adjacent
/// rows); a worker that drains its own queue steals from the others, so
/// a sparsity-imbalanced segment never serializes the batch.
fn dispatch<T, F>(
    ct: &CompressedTensor,
    out: &mut [T],
    geo: Geometry,
    cfg: &KernelConfig,
    run: &F,
) -> SpmmStats
where
    T: Send,
    F: Fn(Job<'_, T>, &mut Vec<i32>, &mut LocalStats) + Sync,
{
    let est_macs = ct.nnz() as u64 * geo.n as u64;
    let workers = if est_macs < cfg.par_threshold_macs {
        1
    } else {
        cfg.workers.max(1)
    };
    let jobs = build_jobs(ct, out, geo, cfg.rows_per_job);
    let n_jobs = jobs.len() as u64;

    if workers <= 1 || jobs.len() <= 1 {
        let mut local = LocalStats::default();
        let mut scratch = Vec::new();
        for job in jobs {
            run(job, &mut scratch, &mut local);
        }
        return SpmmStats {
            gemm_rows: 0, // filled by the caller
            hot_lanes: local.hot,
            skipped_lanes: local.skipped,
            jobs: n_jobs,
            stolen_jobs: 0,
        };
    }

    let w = workers.min(jobs.len());
    let per = jobs.len().div_ceil(w);
    let mut queues: Vec<JobQueue<T>> = Vec::with_capacity(w);
    let mut it = jobs.into_iter();
    for _ in 0..w {
        queues.push(JobQueue {
            slots: it.by_ref().take(per).map(|j| Mutex::new(Some(j))).collect(),
            next: AtomicUsize::new(0),
        });
    }
    let queues = &queues;
    let totals = Mutex::new(LocalStats::default());
    thread::scope(|scope| {
        for me in 0..w {
            let totals = &totals;
            scope.spawn(move || {
                let mut local = LocalStats::default();
                let mut scratch = Vec::new();
                loop {
                    // own queue first, then sweep the victims round-robin
                    let mut taken = queues[me].pop().map(|j| (j, false));
                    if taken.is_none() {
                        for off in 1..queues.len() {
                            let victim = (me + off) % queues.len();
                            if let Some(j) = queues[victim].pop() {
                                taken = Some((j, true));
                                break;
                            }
                        }
                    }
                    let Some((job, stolen)) = taken else { break };
                    if stolen {
                        local.stolen += 1;
                    }
                    run(job, &mut scratch, &mut local);
                }
                let mut t = totals.lock().unwrap();
                t.hot += local.hot;
                t.skipped += local.skipped;
                t.stolen += local.stolen;
            });
        }
    });
    let t = totals.into_inner().unwrap();
    SpmmStats {
        gemm_rows: 0,
        hot_lanes: t.hot,
        skipped_lanes: t.skipped,
        jobs: n_jobs,
        stolen_jobs: t.stolen,
    }
}

// ---------------------------------------------------------- job kernels

/// f32 job body: stream the job's banks, axpy each hot lane's weight row
/// into the owning output row.  Lane order is ascending (lowest set bit
/// first), matching [`gemm_dense_f32`] bit for bit.
fn run_job_f32(job: Job<'_, f32>, w: &[f32], geo: Geometry, local: &mut LocalStats) {
    let Job {
        seg,
        row_lo,
        row_hi,
        out,
    } = job;
    for bank in seg.banks_in(row_lo, row_hi) {
        let live = BANK_WIDTH.min(geo.row_len - bank.index * BANK_WIDTH);
        let nnz = bank.packed.len();
        local.hot += nnz as u64;
        local.skipped += (live - nnz) as u64;
        if bank.mbhot == 0 {
            continue; // mini-bank gate: whole bank empty
        }
        let gr = (bank.row - row_lo) * geo.g + bank.index / geo.bpg;
        let out_row = &mut out[gr * geo.n..(gr + 1) * geo.n];
        let base = (bank.index % geo.bpg) * BANK_WIDTH;
        let mut bits = bank.hot;
        let mut next = 0usize;
        while bits != 0 {
            let lane = bits.trailing_zeros() as usize;
            bits &= bits - 1;
            let x = bank.packed[next];
            next += 1;
            let wrow = &w[(base + lane) * geo.n..(base + lane + 1) * geo.n];
            for (o, &wv) in out_row.iter_mut().zip(wrow) {
                *o += x * wv;
            }
        }
    }
}

/// Q8.8 job body: per GEMM row, accumulate `quantize(x) * wq` into the
/// worker's int32 scratch, then requantize into the output row.
fn run_job_q88(
    job: Job<'_, i16>,
    wq: &[i16],
    geo: Geometry,
    scratch: &mut Vec<i32>,
    local: &mut LocalStats,
) {
    let Job {
        seg,
        row_lo,
        row_hi,
        out,
    } = job;
    let rb = seg.banks_per_row();
    for (gr, out_row) in out.chunks_mut(geo.n).enumerate() {
        let r = row_lo + gr / geo.g;
        let gi = gr % geo.g;
        scratch.clear();
        scratch.resize(geo.n, 0);
        let b0 = r * rb + gi * geo.bpg;
        for bank in seg.bank_span(b0, b0 + geo.bpg) {
            let live = BANK_WIDTH.min(geo.row_len - bank.index * BANK_WIDTH);
            let nnz = bank.packed.len();
            local.hot += nnz as u64;
            local.skipped += (live - nnz) as u64;
            if bank.mbhot == 0 {
                continue;
            }
            let base = (bank.index % geo.bpg) * BANK_WIDTH;
            let mut bits = bank.hot;
            let mut next = 0usize;
            while bits != 0 {
                let lane = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let xq = quantize(bank.packed[next]) as i32;
                next += 1;
                let wrow = &wq[(base + lane) * geo.n..(base + lane + 1) * geo.n];
                for (acc, &wv) in scratch.iter_mut().zip(wrow) {
                    *acc = acc.wrapping_add(xq * wv as i32);
                }
            }
        }
        for (o, &acc) in out_row.iter_mut().zip(scratch.iter()) {
            *o = requantize(acc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_matmul_ref;
    use crate::rfc::{encode, EncoderConfig};
    use crate::util::rng::Rng;

    fn enc(shards: usize) -> EncoderConfig {
        EncoderConfig {
            shards,
            min_sparsity: 0.0,
            parallel_threshold: 0,
        }
    }

    fn weights(k: usize, n: usize, seed: u64) -> GemmF32 {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..k * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        GemmF32::new(w, k, n).unwrap()
    }

    #[test]
    fn matches_dense_reference_bit_for_bit() {
        // k == row_len (incl. bank-unaligned) and k | row_len geometries
        for (shape, k) in [
            (vec![5usize, 48], 48),
            (vec![3, 52], 52), // tail-bank padding lanes
            (vec![4, 2, 64], 64),
            (vec![2, 6, 32], 32),
        ] {
            let t = Tensor::random_sparse(shape.clone(), 0.6, k as u64);
            let ct = encode(&t, &enc(2));
            let gemm = weights(k, 9, 7);
            let (y, stats) = spmm_f32(&ct, &gemm, &KernelConfig::serial()).unwrap();
            let m = t.len() / k;
            let reference = gemm_dense_f32(&t.data, m, &gemm);
            assert_eq!(y.data.len(), reference.len());
            for (a, b) in y.data.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits(), "shape {shape:?} k {k}");
            }
            assert_eq!(stats.gemm_rows, m as u64);
            assert_eq!(
                stats.hot_lanes + stats.skipped_lanes,
                t.len() as u64,
                "lane accounting covers every logical element"
            );
            assert_eq!(
                stats.hot_lanes as usize,
                t.data.iter().filter(|&&v| v != 0.0).count()
            );
        }
    }

    #[test]
    fn worker_count_never_changes_the_bits() {
        let t = Tensor::random_sparse(vec![13, 64], 0.5, 99);
        let ct = encode(&t, &enc(3));
        let gemm = weights(64, 17, 3);
        let (reference, _) = spmm_f32(&ct, &gemm, &KernelConfig::serial()).unwrap();
        for workers in [2usize, 4, 8] {
            let cfg = KernelConfig {
                workers,
                rows_per_job: 1,
                par_threshold_macs: 0,
            };
            let (y, stats) = spmm_f32(&ct, &gemm, &cfg).unwrap();
            for (a, b) in y.data.iter().zip(&reference.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers {workers}");
            }
            assert_eq!(stats.jobs, 13);
        }
    }

    #[test]
    fn q88_matches_quant_matmul_ref() {
        let t = Tensor::random_sparse(vec![6, 32], 0.55, 21);
        let ct = encode(&t, &enc(2));
        let gemm = weights(32, 11, 5).quantize();
        let (yq, stats) = spmm_q88(&ct, &gemm, &KernelConfig::serial()).unwrap();
        let xq = quantize_slice(&t.data);
        let reference = quant_matmul_ref(&xq, gemm.raw_weights(), 6, 32, 11);
        assert_eq!(yq, reference);
        assert_eq!(stats.gemm_rows, 6);
    }

    #[test]
    fn all_zero_and_fully_dense_banks() {
        let z = CompressedTensor::zeros(vec![4, 32]);
        let gemm = weights(32, 5, 1);
        let (y, stats) = spmm_f32(&z, &gemm, &KernelConfig::serial()).unwrap();
        assert!(y.data.iter().all(|&v| v == 0.0));
        assert_eq!(stats.hot_lanes, 0);
        assert_eq!(stats.skipped_lanes, 4 * 32);

        let d = Tensor::random_sparse(vec![4, 32], 0.0, 2);
        let cd = encode(&d, &enc(1));
        let (yd, sd) = spmm_f32(&cd, &gemm, &KernelConfig::serial()).unwrap();
        assert_eq!(sd.skipped_lanes, 0);
        let reference = gemm_dense_f32(&d.data, 4, &gemm);
        for (a, b) in yd.data.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn non_finite_weights_are_rejected() {
        assert!(GemmF32::new(vec![1.0, f32::NAN], 2, 1).is_err());
        assert!(GemmF32::new(vec![f32::INFINITY, 0.0], 1, 2).is_err());
        let w = Tensor::new(vec![1, 2], vec![0.0, f32::NEG_INFINITY]).unwrap();
        assert!(GemmF32::from_tensor(&w).is_err());
        assert!(GemmF32::new(vec![0.0; 4], 2, 2).is_ok());
    }

    #[test]
    fn claim_rules() {
        let t = Tensor::random_sparse(vec![2, 96], 0.5, 4);
        let ct = encode(&t, &enc(1));
        assert!(claimable(&ct, 96)); // whole row
        assert!(claimable(&ct, 32)); // bank-aligned divisor
        assert!(claimable(&ct, 48));
        assert!(!claimable(&ct, 24)); // not bank-aligned
        assert!(!claimable(&ct, 40)); // does not divide row_len
        let gemm = weights(24, 4, 6);
        assert!(spmm_f32(&ct, &gemm, &KernelConfig::serial()).is_err());
        // unaligned k is fine only when it covers the whole row
        let u = encode(&Tensor::random_sparse(vec![2, 52], 0.5, 8), &enc(1));
        assert!(claimable(&u, 52));
        assert!(!claimable(&u, 26));
    }

    #[test]
    fn sub_row_gemm_reshapes_trailing_axis() {
        // (N, T, C) x (C, n): output keeps the leading axes
        let t = Tensor::random_sparse(vec![3, 4, 16], 0.5, 11);
        let ct = encode(&t, &enc(1));
        let gemm = weights(16, 6, 12);
        let (y, _) = spmm_f32(&ct, &gemm, &KernelConfig::serial()).unwrap();
        assert_eq!(y.shape, vec![3, 4, 6]);
        let reference = gemm_dense_f32(&t.data, 12, &gemm);
        assert_eq!(y.data, reference);
    }

    #[test]
    fn stealing_engages_on_imbalanced_segments() {
        // one dense segment, one nearly-empty one: with one job per row
        // and 2 workers dealt contiguous halves, the worker that gets
        // the empty half must steal from the loaded one
        let dense = Tensor::random_sparse(vec![8, 256], 0.0, 31);
        let sparse = Tensor::random_sparse(vec![8, 256], 0.99, 32);
        let mut data = dense.data.clone();
        data.extend_from_slice(&sparse.data);
        let ct = CompressedTensor::concat_batch(vec![
            encode(&dense, &enc(1)),
            encode(&sparse, &enc(1)),
        ])
        .unwrap();
        let gemm = weights(256, 32, 33);
        let cfg = KernelConfig {
            workers: 2,
            rows_per_job: 1,
            par_threshold_macs: 0,
        };
        let (y, stats) = spmm_f32(&ct, &gemm, &cfg).unwrap();
        let reference = gemm_dense_f32(&data, 16, &gemm);
        for (a, b) in y.data.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(stats.jobs, 16);
        // scheduling is timing-dependent; correctness above is the hard
        // guarantee, stolen_jobs just has to be consistent
        assert!(stats.stolen_jobs <= stats.jobs);
    }
}
