//! Bank-sharded compressed tensor: the serving-path realization of the
//! paper's SSV-C runtime feature compression.
//!
//! The bit-exact reference for the per-bank encoding is
//! [`crate::sim::rfc`]; this module is the production format the
//! coordinator actually transports.  Layout:
//!
//! * the tensor's leading axis is the batch ("row") axis; each row's
//!   `row_len` elements are chunked into 16-wide banks, the tail bank
//!   logically zero-padded (padding lanes are never hot);
//! * each bank stores exactly what the sim model stores: a 16-bit
//!   element hot code, a mini-bank hot code (`mbhot`), and the nonzero
//!   values packed head-first;
//! * banks live in row-aligned [`BankSegment`]s -- one segment per
//!   encoder shard (see [`super::encoder`]), mirroring the paper's
//!   per-bank parallel write ports.  Because segments own their packed
//!   storage, batch-axis concatenation moves segments without copying a
//!   single value: the zero-copy transport property the pipeline and
//!   batcher rely on.

use anyhow::{bail, ensure, Result};

use crate::runtime::Tensor;
use crate::sim::rfc::{EncodedBank, BANK_WIDTH, ELEM_BITS, MINI_PER_BANK};

/// Sidecar bits per bank (16-bit hot code + 4-bit mini-bank hot code),
/// matching the sim cost model's data-hot + mbhot accounting.
pub const BANK_SIDECAR_BITS: u64 = (BANK_WIDTH + MINI_PER_BANK) as u64;

/// A contiguous run of whole rows, encoded bank-by-bank.  One segment is
/// one encoder shard's output.
#[derive(Debug, Clone, PartialEq)]
pub struct BankSegment {
    /// rows covered by this segment
    pub(crate) rows: usize,
    /// banks per row (shared with the owning tensor)
    pub(crate) row_banks: usize,
    /// nonzero values, packed head-first per bank, banks in row-major order
    pub(crate) packed: Vec<f32>,
    /// per-bank 16-bit element hot codes
    pub(crate) hots: Vec<u16>,
    /// per-bank mini-bank hot codes
    pub(crate) mbhots: Vec<u8>,
    /// per-bank start offsets into `packed`; length `rows * row_banks + 1`
    pub(crate) offsets: Vec<u32>,
}

impl BankSegment {
    /// Encode `rows` dense rows of `row_len` elements each
    /// (`data.len() == rows * row_len`).  Bit-exact with
    /// [`crate::sim::rfc::encode_bank`] on every 16-aligned bank; the
    /// tail bank of an unaligned row behaves as if zero-padded.
    pub fn encode(data: &[f32], rows: usize, row_len: usize) -> BankSegment {
        debug_assert_eq!(data.len(), rows * row_len);
        let row_banks = row_len.div_ceil(BANK_WIDTH);
        let n_banks = rows * row_banks;
        let mut packed = Vec::new();
        let mut hots = Vec::with_capacity(n_banks);
        let mut mbhots = Vec::with_capacity(n_banks);
        let mut offsets = Vec::with_capacity(n_banks + 1);
        offsets.push(0u32);
        for r in 0..rows {
            let row = &data[r * row_len..(r + 1) * row_len];
            for b in 0..row_banks {
                let start = b * BANK_WIDTH;
                let end = row_len.min(start + BANK_WIDTH);
                let mut hot: u16 = 0;
                for (lane, &v) in row[start..end].iter().enumerate() {
                    if v != 0.0 {
                        hot |= 1 << lane;
                        packed.push(v);
                    }
                }
                let nnz = hot.count_ones() as usize;
                hots.push(hot);
                mbhots.push(mbhot_for(nnz));
                offsets.push(packed.len() as u32);
            }
        }
        BankSegment {
            rows,
            row_banks,
            packed,
            hots,
            mbhots,
            offsets,
        }
    }

    /// Scatter this segment's rows into `out`
    /// (`out.len() == rows * row_len`, pre-zeroed by the caller).
    pub(crate) fn decode_into(&self, out: &mut [f32], row_len: usize) {
        for r in 0..self.rows {
            let row = &mut out[r * row_len..(r + 1) * row_len];
            for b in 0..self.row_banks {
                let bank_i = r * self.row_banks + b;
                let hot = self.hots[bank_i];
                if hot == 0 {
                    continue;
                }
                let mut next = self.offsets[bank_i] as usize;
                let base = b * BANK_WIDTH;
                for lane in 0..BANK_WIDTH {
                    if hot & (1 << lane) != 0 {
                        row[base + lane] = self.packed[next];
                        next += 1;
                    }
                }
            }
        }
    }

    /// Rows covered by this segment.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Banks per row (shared with the owning tensor).
    pub fn banks_per_row(&self) -> usize {
        self.row_banks
    }

    /// Nonzero values packed in this segment.
    pub fn nnz(&self) -> usize {
        self.packed.len()
    }

    /// The segment's packed nonzeros as one contiguous slice.
    ///
    /// **Stride/alignment contract** (relied on by the SIMD lanes in
    /// [`super::kernel`]): banks are stored back-to-back in row-major
    /// iteration order, so the `BankRef::packed` slices yielded by
    /// [`BankSegment::banks_in`] are *adjacent* subslices of this one
    /// buffer -- a kernel walking banks in order streams this memory
    /// strictly forward with no gaps, which is what makes the software
    /// prefetch of the upcoming bank ([`BankIter::upcoming_packed`])
    /// effective.  Values are naturally 4-byte aligned (`Vec<f32>`);
    /// no wider alignment is promised, so lane code must use unaligned
    /// loads.  The per-bank extents are the validated monotone
    /// `offsets` table (`offsets[i + 1] - offsets[i]` equals the bank's
    /// hot-code popcount -- see [`BankSegment::validate`]).
    pub fn packed_values(&self) -> &[f32] {
        &self.packed
    }

    /// Iterate the encoded banks of rows `[lo, hi)` in row-major order,
    /// in place (no decode, no copy).  This is the iteration surface the
    /// compressed-domain kernel ([`super::kernel`]) computes over: each
    /// [`BankRef`] carries the `(hot, mbhot)` bitmaps and the packed
    /// nonzeros of one bank.
    pub fn banks_in(&self, lo: usize, hi: usize) -> BankIter<'_> {
        debug_assert!(lo <= hi && hi <= self.rows);
        self.bank_span(lo * self.row_banks, hi * self.row_banks)
    }

    /// All banks of the segment, row-major.
    pub fn iter_banks(&self) -> BankIter<'_> {
        self.banks_in(0, self.rows)
    }

    /// Iterate an arbitrary span of bank indices (row-major numbering).
    pub(crate) fn bank_span(&self, lo: usize, hi: usize) -> BankIter<'_> {
        debug_assert!(lo <= hi && hi <= self.hots.len());
        BankIter {
            seg: self,
            i: lo,
            end: hi,
        }
    }

    /// Structural validation against `row_len` (the runtime counterpart
    /// of the sim model's hot-code/packed-length mismatch rejection).
    pub(crate) fn validate(&self, row_len: usize) -> Result<()> {
        let n_banks = self.rows * self.row_banks;
        ensure!(
            self.hots.len() == n_banks && self.mbhots.len() == n_banks,
            "segment holds {} hot / {} mbhot codes for {n_banks} banks",
            self.hots.len(),
            self.mbhots.len()
        );
        ensure!(
            self.offsets.len() == n_banks + 1,
            "segment has {} offsets for {n_banks} banks",
            self.offsets.len()
        );
        ensure!(
            self.offsets.first() == Some(&0)
                && *self.offsets.last().unwrap_or(&0) as usize == self.packed.len(),
            "offset table does not span the packed data"
        );
        for i in 0..n_banks {
            let hot = self.hots[i];
            let nnz = hot.count_ones() as usize;
            ensure!(
                self.offsets[i] <= self.offsets[i + 1],
                "bank {i}: offset table not monotonic"
            );
            let span = (self.offsets[i + 1] - self.offsets[i]) as usize;
            ensure!(
                span == nnz,
                "bank {i}: hot code names {nnz} values but {span} are packed"
            );
            ensure!(
                self.mbhots[i] == mbhot_for(nnz),
                "bank {i}: mbhot {:#06b} inconsistent with nnz {nnz}",
                self.mbhots[i]
            );
            let b = i % self.row_banks.max(1);
            let live = row_len.saturating_sub(b * BANK_WIDTH).min(BANK_WIDTH);
            ensure!(
                live == BANK_WIDTH || hot >> live == 0,
                "bank {i}: hot bits set in padding lanes"
            );
        }
        Ok(())
    }
}

/// Mini-bank hot code for `nnz` packed values -- delegated to the sim
/// reference so the rule has exactly one definition.
pub(crate) fn mbhot_for(nnz: usize) -> u8 {
    EncodedBank::mbhot_for(nnz)
}

/// One encoded bank viewed in place, yielded by [`BankSegment::banks_in`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BankRef<'a> {
    /// row within the segment
    pub row: usize,
    /// bank index within the row
    pub index: usize,
    /// 16-bit element hot code (bit `l` set == lane `l` nonzero)
    pub hot: u16,
    /// mini-bank hot code (zero == the whole bank is empty)
    pub mbhot: u8,
    /// the bank's packed nonzeros, head-first
    pub packed: &'a [f32],
}

/// Row-major in-place iterator over a segment's encoded banks.
pub struct BankIter<'a> {
    seg: &'a BankSegment,
    i: usize,
    end: usize,
}

impl<'a> BankIter<'a> {
    /// First packed value of the bank the next `next()` call will
    /// yield, if any -- the kernel's software-prefetch hint.  Because a
    /// segment's banks pack back-to-back ([`BankSegment::packed_values`]
    /// documents the stride contract), touching this address pulls the
    /// upcoming bank's head cache line while the current bank drains.
    ///
    /// `None` at the end of the span or when no packed data follows
    /// (trailing banks all empty); an empty *upcoming* bank may still
    /// return `Some` -- the address is then the first value of the next
    /// non-empty bank, which is exactly what should be warmed.
    pub fn upcoming_packed(&self) -> Option<&'a f32> {
        if self.i >= self.end {
            return None;
        }
        self.seg.packed.get(self.seg.offsets[self.i] as usize)
    }
}

impl<'a> Iterator for BankIter<'a> {
    type Item = BankRef<'a>;

    fn next(&mut self) -> Option<BankRef<'a>> {
        if self.i >= self.end {
            return None;
        }
        let i = self.i;
        self.i += 1;
        let seg = self.seg;
        let lo = seg.offsets[i] as usize;
        let hi = seg.offsets[i + 1] as usize;
        Some(BankRef {
            row: i / seg.row_banks,
            index: i % seg.row_banks,
            hot: seg.hots[i],
            mbhot: seg.mbhots[i],
            packed: &seg.packed[lo..hi],
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.end - self.i;
        (n, Some(n))
    }
}

impl ExactSizeIterator for BankIter<'_> {}

/// A tensor in bank-encoded compressed form.
#[derive(Debug, Clone)]
pub struct CompressedTensor {
    /// logical dense shape
    pub shape: Vec<usize>,
    pub(crate) row_len: usize,
    pub(crate) row_banks: usize,
    pub(crate) segments: Vec<BankSegment>,
}

impl CompressedTensor {
    /// (rows, row_len) factorization of a shape: leading axis is the
    /// batch axis, everything else is the per-row feature extent.
    pub(crate) fn layout(shape: &[usize]) -> (usize, usize) {
        match shape.len() {
            0 => (1, 1),
            1 => (1, shape[0]),
            _ => (shape[0], shape[1..].iter().product()),
        }
    }

    /// All-zero tensor in compressed form (used for batch padding rows):
    /// costs only the per-bank sidecar entries, no packed values.
    pub fn zeros(shape: Vec<usize>) -> CompressedTensor {
        let (rows, row_len) = Self::layout(&shape);
        let row_banks = row_len.div_ceil(BANK_WIDTH);
        let n_banks = rows * row_banks;
        let segment = BankSegment {
            rows,
            row_banks,
            packed: Vec::new(),
            hots: vec![0; n_banks],
            mbhots: vec![0; n_banks],
            offsets: vec![0; n_banks + 1],
        };
        CompressedTensor {
            shape,
            row_len,
            row_banks,
            segments: vec![segment],
        }
    }

    /// Encode borrowed dense data with the given logical shape on the
    /// calling thread (single segment; [`super::encoder::encode`] is the
    /// multi-threaded entry point over a [`Tensor`]).  Lets callers that
    /// keep ownership of a flat buffer (e.g. a request clip) encode
    /// without first copying into a `Tensor`.
    pub fn encode_slice(data: &[f32], shape: Vec<usize>) -> Result<CompressedTensor> {
        let (rows, row_len) = Self::layout(&shape);
        ensure!(
            rows * row_len == data.len(),
            "shape {shape:?} wants {} elements, got {}",
            rows * row_len,
            data.len()
        );
        let row_banks = row_len.div_ceil(BANK_WIDTH);
        Ok(CompressedTensor {
            shape,
            row_len,
            row_banks,
            segments: vec![BankSegment::encode(data, rows, row_len)],
        })
    }

    /// Logical (dense) element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rows along the batch axis covered by the segments.
    pub fn rows(&self) -> usize {
        self.segments.iter().map(|s| s.rows).sum()
    }

    /// Banks per row: the row-aligned unit that wire serialization and
    /// shard slicing work in.
    pub fn row_banks(&self) -> usize {
        self.row_banks
    }

    /// The row-aligned encoder segments, in batch order (each segment is
    /// a contiguous run of whole rows -- see [`super::encoder`]).
    pub fn segments(&self) -> &[BankSegment] {
        &self.segments
    }

    /// Assemble a tensor from already-validated parts (wire decode).
    pub(crate) fn from_parts(
        shape: Vec<usize>,
        row_len: usize,
        row_banks: usize,
        segments: Vec<BankSegment>,
    ) -> CompressedTensor {
        CompressedTensor {
            shape,
            row_len,
            row_banks,
            segments,
        }
    }

    /// Copy out rows `[lo, hi)` as a standalone tensor: the shard split.
    /// Only the banks in range are copied -- the packed data is sliced by
    /// the row-aligned offsets, never decoded.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Result<CompressedTensor> {
        let (rows, _) = Self::layout(&self.shape);
        ensure!(
            self.shape.len() >= 2,
            "row slice needs a batch axis, got {:?}",
            self.shape
        );
        ensure!(lo <= hi && hi <= rows, "row slice {lo}..{hi} of {rows} rows");
        let rb = self.row_banks;
        let mut segments = Vec::new();
        let mut seg_start = 0usize;
        for seg in &self.segments {
            let seg_end = seg_start + seg.rows;
            let a = lo.max(seg_start);
            let b = hi.min(seg_end);
            if a < b {
                let (la, lb) = (a - seg_start, b - seg_start);
                let off_lo = seg.offsets[la * rb] as usize;
                let off_hi = seg.offsets[lb * rb] as usize;
                segments.push(BankSegment {
                    rows: b - a,
                    row_banks: rb,
                    packed: seg.packed[off_lo..off_hi].to_vec(),
                    hots: seg.hots[la * rb..lb * rb].to_vec(),
                    mbhots: seg.mbhots[la * rb..lb * rb].to_vec(),
                    offsets: seg.offsets[la * rb..=lb * rb]
                        .iter()
                        .map(|&o| o - off_lo as u32)
                        .collect(),
                });
            }
            seg_start = seg_end;
        }
        if segments.is_empty() {
            // empty slice: keep one zero-row segment so validate() holds
            segments.push(BankSegment {
                rows: 0,
                row_banks: rb,
                packed: Vec::new(),
                hots: Vec::new(),
                mbhots: Vec::new(),
                offsets: vec![0],
            });
        }
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Ok(CompressedTensor {
            shape,
            row_len: self.row_len,
            row_banks: rb,
            segments,
        })
    }

    /// Stored nonzero values.
    pub fn nnz(&self) -> usize {
        self.segments.iter().map(|s| s.packed.len()).sum()
    }

    /// Total encoded banks.
    pub fn banks(&self) -> usize {
        self.segments.iter().map(|s| s.hots.len()).sum()
    }

    /// Fraction of logical elements that are exactly zero.
    pub fn sparsity(&self) -> f64 {
        let n = self.len();
        if n == 0 {
            return 0.0;
        }
        1.0 - self.nnz() as f64 / n as f64
    }

    /// Bits this tensor occupies on the wire: packed values plus the
    /// per-bank hot/mbhot sidecars.
    pub fn compressed_bits(&self) -> u64 {
        self.nnz() as u64 * ELEM_BITS as u64 + self.banks() as u64 * BANK_SIDECAR_BITS
    }

    /// Bits the dense transport of the same tensor would occupy.
    pub fn dense_bits(&self) -> u64 {
        self.len() as u64 * ELEM_BITS as u64
    }

    /// Dense bits over compressed bits (> 1 means compression wins).
    pub fn compression_ratio(&self) -> f64 {
        let c = self.compressed_bits();
        if c == 0 {
            return 1.0;
        }
        self.dense_bits() as f64 / c as f64
    }

    /// Decode to a dense tensor (single-threaded; the encoder module's
    /// [`super::encoder::decode`] parallelizes over segments).
    pub fn to_tensor(&self) -> Tensor {
        let mut data = vec![0f32; self.len()];
        if self.row_len > 0 {
            let mut row0 = 0usize;
            for seg in &self.segments {
                let span = &mut data[row0 * self.row_len..(row0 + seg.rows) * self.row_len];
                seg.decode_into(span, self.row_len);
                row0 += seg.rows;
            }
        }
        Tensor {
            shape: self.shape.clone(),
            data,
        }
    }

    /// Zero-copy batch concatenation: segments move into the result,
    /// packed data is never copied (shapes past the batch axis must
    /// match).
    pub fn concat_batch(parts: Vec<CompressedTensor>) -> Result<CompressedTensor> {
        let Some(first) = parts.first() else {
            bail!("concat of zero tensors");
        };
        ensure!(
            first.shape.len() >= 2,
            "concat needs a batch axis, got {:?}",
            first.shape
        );
        let tail: Vec<usize> = first.shape[1..].to_vec();
        let row_len = first.row_len;
        let row_banks = first.row_banks;
        let mut rows = 0usize;
        let mut segments = Vec::new();
        for p in parts {
            ensure!(
                p.shape[1..] == tail[..],
                "ragged concat: {:?} vs tail {:?}",
                p.shape,
                tail
            );
            rows += p.shape[0];
            segments.extend(p.segments);
        }
        let mut shape = vec![rows];
        shape.extend_from_slice(&tail);
        Ok(CompressedTensor {
            shape,
            row_len,
            row_banks,
            segments,
        })
    }

    /// Random access to one encoded bank (`row` on the batch axis, `b`
    /// the bank within the row): the layout-independent view the
    /// sim-equivalence tests compare against [`crate::sim::rfc`].
    pub fn bank(&self, row: usize, b: usize) -> Option<(u16, u8, &[f32])> {
        if b >= self.row_banks {
            return None;
        }
        let mut r = row;
        for seg in &self.segments {
            if r < seg.rows {
                let i = r * seg.row_banks + b;
                let lo = seg.offsets[i] as usize;
                let hi = seg.offsets[i + 1] as usize;
                return Some((seg.hots[i], seg.mbhots[i], &seg.packed[lo..hi]));
            }
            r -= seg.rows;
        }
        None
    }

    /// Full structural validation: shape/segment agreement plus every
    /// bank's hot-code/packed-length and mbhot consistency.
    pub fn validate(&self) -> Result<()> {
        let (rows, row_len) = Self::layout(&self.shape);
        ensure!(
            row_len == self.row_len,
            "shape {:?} implies row_len {row_len}, tensor says {}",
            self.shape,
            self.row_len
        );
        ensure!(
            self.row_banks == row_len.div_ceil(BANK_WIDTH),
            "row_banks {} inconsistent with row_len {row_len}",
            self.row_banks
        );
        let seg_rows: usize = self.segments.iter().map(|s| s.rows).sum();
        ensure!(
            seg_rows == rows,
            "segments cover {seg_rows} rows, shape has {rows}"
        );
        for seg in &self.segments {
            ensure!(
                seg.row_banks == self.row_banks,
                "segment row_banks {} vs tensor {}",
                seg.row_banks,
                self.row_banks
            );
            seg.validate(self.row_len)?;
        }
        Ok(())
    }
}

impl Default for CompressedTensor {
    /// An empty zero-element tensor.  NOT the [`super::Payload::take`]
    /// placeholder: that is a dense empty tensor, because a compressed
    /// default here reads as a leftover padding sidecar to anyone
    /// inspecting a moved-out payload.
    fn default() -> CompressedTensor {
        CompressedTensor::zeros(vec![0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rfc as sim_rfc;

    fn sparse(shape: Vec<usize>, sparsity: f64, seed: u64) -> Tensor {
        Tensor::random_sparse(shape, sparsity, seed)
    }

    #[test]
    fn roundtrip_aligned_and_unaligned_rows() {
        for row_len in [16usize, 64, 600, 75, 1] {
            let t = sparse(vec![5, row_len], 0.5, row_len as u64);
            let ct = CompressedTensor {
                shape: t.shape.clone(),
                row_len,
                row_banks: row_len.div_ceil(BANK_WIDTH),
                segments: vec![BankSegment::encode(&t.data, 5, row_len)],
            };
            ct.validate().unwrap();
            assert_eq!(ct.to_tensor(), t, "row_len {row_len}");
        }
    }

    #[test]
    fn banks_match_sim_encoder() {
        let row_len = 4 * BANK_WIDTH;
        let t = sparse(vec![3, row_len], 0.6, 9);
        let seg = BankSegment::encode(&t.data, 3, row_len);
        let ct = CompressedTensor {
            shape: t.shape.clone(),
            row_len,
            row_banks: 4,
            segments: vec![seg],
        };
        for r in 0..3 {
            let row = &t.data[r * row_len..(r + 1) * row_len];
            let (sim_banks, _) = sim_rfc::encode_vector(row).unwrap();
            for (b, sb) in sim_banks.iter().enumerate() {
                let (hot, mbhot, packed) = ct.bank(r, b).unwrap();
                assert_eq!(hot, sb.hot);
                assert_eq!(mbhot, sb.mbhot);
                assert_eq!(packed, &sb.packed[..]);
            }
        }
    }

    #[test]
    fn bank_iteration_matches_random_access() {
        let t = sparse(vec![4, 52], 0.5, 17);
        let ct = CompressedTensor::encode_slice(&t.data, t.shape.clone()).unwrap();
        let seg = &ct.segments[0];
        let mut seen = 0usize;
        for bank in seg.iter_banks() {
            let (hot, mbhot, packed) = ct.bank(bank.row, bank.index).unwrap();
            assert_eq!(bank.hot, hot);
            assert_eq!(bank.mbhot, mbhot);
            assert_eq!(bank.packed, packed);
            assert_eq!(bank.hot.count_ones() as usize, bank.packed.len());
            seen += 1;
        }
        assert_eq!(seen, 4 * ct.row_banks);
        // row-range iteration covers exactly the requested rows
        let mid: Vec<_> = seg.banks_in(1, 3).collect();
        assert_eq!(mid.len(), 2 * ct.row_banks);
        assert_eq!(mid.first().unwrap().row, 1);
        assert_eq!(mid.last().unwrap().row, 2);
        assert_eq!(seg.banks_in(2, 2).count(), 0);
    }

    #[test]
    fn packed_banks_are_adjacent_subslices_in_iteration_order() {
        // the SIMD lanes' stride contract: concatenating the yielded
        // banks' packed slices reproduces packed_values() exactly, and
        // upcoming_packed() always points at the next value the stream
        // will touch
        let t = sparse(vec![5, 52], 0.6, 77);
        let ct = CompressedTensor::encode_slice(&t.data, t.shape.clone()).unwrap();
        let seg = &ct.segments[0];
        let mut streamed: Vec<f32> = Vec::new();
        let mut iter = seg.iter_banks();
        while let Some(bank) = iter.next() {
            if let Some(hint) = iter.upcoming_packed() {
                // the hint is the next packed value after this bank's
                // slice in the one contiguous buffer
                let consumed = streamed.len() + bank.packed.len();
                assert_eq!(
                    *hint,
                    seg.packed_values()[consumed],
                    "prefetch hint must point into the forward stream"
                );
            }
            streamed.extend_from_slice(bank.packed);
        }
        assert_eq!(streamed, seg.packed_values());
        assert!(iter.upcoming_packed().is_none());
    }

    #[test]
    fn zeros_cost_only_sidecars() {
        let z = CompressedTensor::zeros(vec![4, 32]);
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.banks(), 8);
        assert_eq!(z.compressed_bits(), 8 * BANK_SIDECAR_BITS);
        assert_eq!(z.to_tensor(), Tensor::zeros(vec![4, 32]));
        z.validate().unwrap();
    }

    #[test]
    fn concat_is_zero_copy_and_correct() {
        let a = sparse(vec![2, 48], 0.5, 1);
        let b = sparse(vec![3, 48], 0.8, 2);
        let ca = CompressedTensor {
            shape: a.shape.clone(),
            row_len: 48,
            row_banks: 3,
            segments: vec![BankSegment::encode(&a.data, 2, 48)],
        };
        let cb = CompressedTensor {
            shape: b.shape.clone(),
            row_len: 48,
            row_banks: 3,
            segments: vec![BankSegment::encode(&b.data, 3, 48)],
        };
        let bits = ca.compressed_bits() + cb.compressed_bits();
        let cat = CompressedTensor::concat_batch(vec![ca, cb]).unwrap();
        cat.validate().unwrap();
        assert_eq!(cat.shape, vec![5, 48]);
        assert_eq!(cat.compressed_bits(), bits);
        let dense = Tensor::concat_batch(&[a, b]).unwrap();
        assert_eq!(cat.to_tensor(), dense);
    }

    #[test]
    fn concat_rejects_ragged() {
        let a = CompressedTensor::zeros(vec![1, 32]);
        let b = CompressedTensor::zeros(vec![1, 48]);
        assert!(CompressedTensor::concat_batch(vec![a, b]).is_err());
        assert!(CompressedTensor::concat_batch(Vec::new()).is_err());
    }

    #[test]
    fn validate_rejects_hot_packed_mismatch() {
        let t = sparse(vec![2, 32], 0.5, 3);
        let mut seg = BankSegment::encode(&t.data, 2, 32);
        // flip one hot bit: packed length no longer matches the hot code
        seg.hots[0] ^= 1 << 15;
        seg.mbhots[0] = mbhot_for(seg.hots[0].count_ones() as usize);
        let ct = CompressedTensor {
            shape: vec![2, 32],
            row_len: 32,
            row_banks: 2,
            segments: vec![seg],
        };
        assert!(ct.validate().is_err());
    }

    #[test]
    fn validate_rejects_inconsistent_mbhot() {
        let t = sparse(vec![1, 16], 0.3, 4);
        let mut seg = BankSegment::encode(&t.data, 1, 16);
        seg.mbhots[0] = 0b1111;
        let nnz = seg.hots[0].count_ones() as usize;
        if mbhot_for(nnz) != 0b1111 {
            let ct = CompressedTensor {
                shape: vec![1, 16],
                row_len: 16,
                row_banks: 1,
                segments: vec![seg],
            };
            assert!(ct.validate().is_err());
        }
    }

    #[test]
    fn validate_rejects_padding_lane_hot_bits() {
        // row_len 20: bank 1 has 4 live lanes, 12 padding lanes
        let t = sparse(vec![1, 20], 0.0, 5);
        let mut seg = BankSegment::encode(&t.data, 1, 20);
        seg.hots[1] |= 1 << 10;
        seg.packed.push(1.0);
        for o in seg.offsets.iter_mut().skip(2) {
            *o += 1;
        }
        seg.mbhots[1] = mbhot_for(seg.hots[1].count_ones() as usize);
        let ct = CompressedTensor {
            shape: vec![1, 20],
            row_len: 20,
            row_banks: 2,
            segments: vec![seg],
        };
        assert!(ct.validate().is_err());
    }

    #[test]
    fn slice_rows_matches_dense_slice() {
        let t = sparse(vec![7, 52], 0.6, 21);
        let ct = CompressedTensor {
            shape: t.shape.clone(),
            row_len: 52,
            row_banks: 52usize.div_ceil(BANK_WIDTH),
            segments: vec![
                BankSegment::encode(&t.data[..3 * 52], 3, 52),
                BankSegment::encode(&t.data[3 * 52..], 4, 52),
            ],
        };
        ct.validate().unwrap();
        // slices within one segment, across the boundary, and empty
        for (lo, hi) in [(0, 2), (2, 5), (0, 7), (3, 3), (6, 7)] {
            let s = ct.slice_rows(lo, hi).unwrap();
            s.validate().unwrap();
            assert_eq!(s.shape, vec![hi - lo, 52]);
            let dense = s.to_tensor();
            assert_eq!(
                dense.data,
                t.data[lo * 52..hi * 52].to_vec(),
                "slice {lo}..{hi}"
            );
        }
        assert!(ct.slice_rows(5, 3).is_err());
        assert!(ct.slice_rows(0, 8).is_err());
    }

    #[test]
    fn sliced_shards_reconcat_to_the_whole() {
        let t = sparse(vec![8, 48], 0.5, 22);
        let ct = CompressedTensor {
            shape: t.shape.clone(),
            row_len: 48,
            row_banks: 3,
            segments: vec![BankSegment::encode(&t.data, 8, 48)],
        };
        let parts: Vec<CompressedTensor> = [(0, 3), (3, 6), (6, 8)]
            .iter()
            .map(|&(lo, hi)| ct.slice_rows(lo, hi).unwrap())
            .collect();
        let back = CompressedTensor::concat_batch(parts).unwrap();
        back.validate().unwrap();
        assert_eq!(back.to_tensor(), t);
    }

    #[test]
    fn ratio_reflects_sparsity() {
        let sparse_t = sparse(vec![8, 256], 0.9, 6);
        let dense_t = sparse(vec![8, 256], 0.0, 7);
        let cs = CompressedTensor {
            shape: sparse_t.shape.clone(),
            row_len: 256,
            row_banks: 16,
            segments: vec![BankSegment::encode(&sparse_t.data, 8, 256)],
        };
        let cd = CompressedTensor {
            shape: dense_t.shape.clone(),
            row_len: 256,
            row_banks: 16,
            segments: vec![BankSegment::encode(&dense_t.data, 8, 256)],
        };
        assert!(cs.compression_ratio() > 3.0, "{}", cs.compression_ratio());
        // fully dense pays the sidecar overhead (20 bits per 256-bit bank)
        assert!(cd.compression_ratio() < 1.0);
        assert!(cd.compression_ratio() > 0.85);
    }
}
