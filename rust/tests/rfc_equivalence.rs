//! The equivalence contract between the serving-path RFC subsystem
//! (`rfc::CompressedTensor`, multi-threaded encoder) and the bit-exact
//! sim reference (`sim::rfc`): every 16-aligned bank's
//! `(hot, mbhot, packed)` triple must be identical bit-for-bit, decode
//! must reproduce the dense tensor exactly, and the answer must not
//! depend on how many encoder shards produced it.  Runs without AOT
//! artifacts.

use rfc_hypgcn::rfc::{self, CompressedTensor, EncoderConfig, Payload};
use rfc_hypgcn::runtime::Tensor;
use rfc_hypgcn::sim::rfc as sim_rfc;
use rfc_hypgcn::util::rng::Rng;

fn sparse_tensor(shape: Vec<usize>, sparsity: f64, seed: u64) -> Tensor {
    Tensor::random_sparse(shape, sparsity, seed)
}

fn cfg(shards: usize) -> EncoderConfig {
    EncoderConfig {
        shards,
        min_sparsity: 0.0,
        parallel_threshold: 0,
    }
}

#[test]
fn runtime_banks_match_sim_encoder_bit_exact() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..50u64 {
        let rows = 1 + rng.below(6);
        let banks_per_row = 1 + rng.below(5);
        let row_len = banks_per_row * sim_rfc::BANK_WIDTH;
        let sparsity = rng.f64();
        let t = sparse_tensor(vec![rows, row_len], sparsity, 1000 + case);
        let ct = rfc::encode(&t, &cfg(1 + (case as usize % 4)));
        ct.validate().unwrap();
        for r in 0..rows {
            let row = &t.data[r * row_len..(r + 1) * row_len];
            let (sim_banks, _cycles) = sim_rfc::encode_vector(row).unwrap();
            for (b, sb) in sim_banks.iter().enumerate() {
                let (hot, mbhot, packed) =
                    ct.bank(r, b).expect("bank present");
                assert_eq!(hot, sb.hot, "case {case} row {r} bank {b}");
                assert_eq!(mbhot, sb.mbhot, "case {case} row {r} bank {b}");
                assert_eq!(packed.len(), sb.packed.len());
                for (x, y) in packed.iter().zip(&sb.packed) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "case {case} row {r} bank {b}: value bits differ"
                    );
                }
                sb.validate().unwrap();
            }
        }
    }
}

#[test]
fn runtime_decode_matches_sim_decode_and_source() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..30u64 {
        let rows = 1 + rng.below(5);
        let row_len = (1 + rng.below(4)) * sim_rfc::BANK_WIDTH;
        let t = sparse_tensor(vec![rows, row_len], rng.f64(), 2000 + case);
        let ct = rfc::encode(&t, &cfg(2));
        // runtime decode == source, bit for bit
        let back = rfc::decode(&ct, &cfg(2));
        assert_eq!(back.shape, t.shape);
        for (x, y) in back.data.iter().zip(&t.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "case {case}");
        }
        // sim decode of the runtime banks == source as well
        for r in 0..rows {
            for b in 0..row_len / sim_rfc::BANK_WIDTH {
                let (hot, mbhot, packed) = ct.bank(r, b).unwrap();
                let e = sim_rfc::EncodedBank {
                    packed: packed.to_vec(),
                    hot,
                    mbhot,
                };
                let decoded = sim_rfc::decode_bank_checked(&e).unwrap();
                let lo = r * row_len + b * sim_rfc::BANK_WIDTH;
                assert_eq!(
                    decoded.to_vec(),
                    t.data[lo..lo + sim_rfc::BANK_WIDTH].to_vec()
                );
            }
        }
    }
}

#[test]
fn unaligned_rows_roundtrip_with_cold_padding_lanes() {
    // the serving batch row (3 * T * 25 joints) is not a bank multiple
    for row_len in [600usize, 75, 17, 15, 1] {
        let t = sparse_tensor(vec![4, row_len], 0.5, row_len as u64);
        let ct = rfc::encode(&t, &cfg(3));
        ct.validate().unwrap();
        assert_eq!(ct.to_tensor(), t, "row_len {row_len}");
        // tail bank padding lanes must never be hot
        let last_bank = ct.shape[1].div_ceil(sim_rfc::BANK_WIDTH) - 1;
        let live = row_len - last_bank * sim_rfc::BANK_WIDTH;
        for r in 0..4 {
            let (hot, _, _) = ct.bank(r, last_bank).unwrap();
            if live < sim_rfc::BANK_WIDTH {
                assert_eq!(hot >> live, 0, "padding lanes hot");
            }
        }
    }
}

#[test]
fn shard_count_never_changes_the_encoding() {
    let t = sparse_tensor(vec![11, 640], 0.6, 77);
    let reference = rfc::encode(&t, &cfg(1));
    for shards in [2usize, 3, 4, 7, 16] {
        let ct = rfc::encode(&t, &cfg(shards));
        assert_eq!(ct.nnz(), reference.nnz());
        assert_eq!(ct.compressed_bits(), reference.compressed_bits());
        for r in 0..11 {
            for b in 0..ct.shape[1].div_ceil(sim_rfc::BANK_WIDTH) {
                assert_eq!(
                    ct.bank(r, b),
                    reference.bank(r, b),
                    "shards {shards} row {r} bank {b}"
                );
            }
        }
    }
}

#[test]
fn compressed_concat_equals_dense_concat() {
    let a = sparse_tensor(vec![3, 320], 0.7, 5);
    let b = sparse_tensor(vec![2, 320], 0.2, 6);
    let ca = rfc::encode(&a, &cfg(2));
    let cb = rfc::encode(&b, &cfg(3));
    let bits = ca.compressed_bits() + cb.compressed_bits();
    let cat = CompressedTensor::concat_batch(vec![ca, cb]).unwrap();
    cat.validate().unwrap();
    // zero-copy: concat adds no bits and loses none
    assert_eq!(cat.compressed_bits(), bits);
    let dense = Tensor::concat_batch(&[a, b]).unwrap();
    assert_eq!(cat.to_tensor(), dense);
}

#[test]
fn payload_roundtrip_preserves_logits_semantics() {
    let enc = EncoderConfig::default();
    let t = sparse_tensor(vec![4, 16, 25, 64], 0.55, 11);
    let p = Payload::from_tensor(t.clone(), &enc);
    assert!(p.is_compressed());
    assert!(p.transport_bits() < t.len() as u64 * 16);
    assert_eq!(p.into_dense(&enc), t);
}

#[test]
fn wire_stream_identical_between_sim_and_runtime() {
    // the serialized byte stream -- not just the decoded values -- must
    // agree between the runtime writer (rfc::wire::to_bytes over the
    // sharded CompressedTensor) and the sim mirror (sim::rfc::wire_bytes
    // straight from the reference encoder), locking wire v1 against
    // drift on either side
    let mut rng = Rng::new(0xCAFE);
    for case in 0..40u64 {
        let rows = 1 + rng.below(6);
        let cols = 1 + rng.below(100); // includes bank-unaligned rows
        let t = sparse_tensor(vec![rows, cols], rng.f64(), 3000 + case);
        let shards = 1 + (case as usize % 5);
        let ct = rfc::encode(&t, &cfg(shards));
        let runtime = rfc::wire::to_bytes(&ct).unwrap();
        let sim = sim_rfc::wire_bytes(&t.shape, &t.data).unwrap();
        assert_eq!(runtime, sim, "case {case} shards {shards}");
        // and the stream decodes back to the source, bit for bit
        let back = rfc::wire::from_bytes(&runtime).unwrap().to_tensor();
        for (x, y) in back.data.iter().zip(&t.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "case {case}");
        }
    }
    // a rank-3 mid-pipeline activation shape serializes identically too
    let t = sparse_tensor(vec![4, 16, 25], 0.55, 777);
    let runtime = rfc::wire::to_bytes(&rfc::encode(&t, &cfg(3))).unwrap();
    assert_eq!(runtime, sim_rfc::wire_bytes(&t.shape, &t.data).unwrap());
}

#[test]
fn kernel_skipped_lanes_match_sim_dyn_pe_admission() {
    // the sim cost model and the runtime kernel must agree on how many
    // MAC candidates sparsity eliminates: feed the same tensor's zero
    // pattern to both.  One Dyn-Mult-PE queue per bank lane (q = 16),
    // one input step per bank -- the Logic-AND admission then drops
    // exactly the lanes the kernel's hot bitmaps skip.
    use rfc_hypgcn::rfc::kernel::{spmm_f32, GemmF32, KernelConfig};
    use rfc_hypgcn::sim::dyn_pe;
    let mut rng = Rng::new(0x51AB);
    for case in 0..20u64 {
        let rows = 1 + rng.below(5);
        let k = (1 + rng.below(4)) * sim_rfc::BANK_WIDTH;
        let t = sparse_tensor(vec![rows, k], rng.f64(), 4000 + case);
        let ct = rfc::encode(&t, &cfg(1 + (case as usize % 3)));

        let n = 1 + rng.below(8);
        let w: Vec<f32> = (0..k * n).map(|_| rng.f32()).collect();
        let gemm = GemmF32::new(w, k, n).unwrap();
        let (_, stats) = spmm_f32(&ct, &gemm, &KernelConfig::serial()).unwrap();

        // bank-aligned rows: the row-major zero pattern is also the
        // bank-major admission stream
        let hot: Vec<bool> = t.data.iter().map(|&v| v != 0.0).collect();
        let pe = dyn_pe::simulate_stream(
            sim_rfc::BANK_WIDTH,
            sim_rfc::BANK_WIDTH,
            &hot,
            4,
        );
        assert_eq!(pe.macs, stats.hot_lanes, "case {case}: admitted MACs");
        assert_eq!(
            pe.skipped_macs(),
            stats.skipped_lanes,
            "case {case}: sim admission drop vs kernel skipped lanes"
        );
    }
}

#[test]
fn compression_ratio_tracks_sim_cost_model_accounting() {
    // per-bank wire cost must match the sim model's accounting:
    // 16 bits per packed value + (16 + 4) sidecar bits per bank
    let t = sparse_tensor(vec![8, 512], 0.5, 13);
    let ct = rfc::encode(&t, &cfg(2));
    let nnz = t.data.iter().filter(|&&v| v != 0.0).count() as u64;
    let banks = (8 * 512 / sim_rfc::BANK_WIDTH) as u64;
    assert_eq!(ct.compressed_bits(), nnz * 16 + banks * 20);
    assert_eq!(ct.dense_bits(), 8 * 512 * 16);
}
