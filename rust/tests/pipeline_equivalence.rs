//! The decisive composition check: chaining the ten per-block AOT
//! executables + head through the Rust pipeline must reproduce the
//! single-module `model_pruned` artifact's logits on identical input.
//! (Blocks run the Pallas-kernel path, the full module the jnp path, so
//! this also cross-validates Layer 1 vs Layer 2 *through* Layer 3.)

//! Quarantine note: every test here needs the AOT artifacts, so they are
//! `#[ignore]`d unless the `aot-artifacts` feature is on (tracking: the
//! gates go away once artifact export runs in CI).

use std::sync::Arc;

use rfc_hypgcn::coordinator::pipeline::{Job, Pipeline};
use rfc_hypgcn::data::{GenConfig, SkeletonGen};
use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::rfc::EncoderConfig;
use rfc_hypgcn::runtime::{Engine, Tensor};

fn setup() -> Option<(Manifest, Engine)> {
    let dir = Manifest::default_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some((Manifest::load(&dir).unwrap(), Engine::cpu().unwrap()))
}

fn input_batch(m: &Manifest, seed: u64) -> Tensor {
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: m.num_classes,
            seq_len: m.seq_len,
            noise: 0.02,
        },
        seed,
    );
    gen.batch(m.batch).0
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn block_chain_matches_full_model() {
    let Some((m, engine)) = setup() else { return };
    let pipeline = Pipeline::load(&engine, &m).unwrap();
    let full = engine
        .load_hlo(&m.hlo_path(&m.model_pruned.hlo))
        .unwrap();
    let x = input_batch(&m, 11);
    let chained = pipeline.run_sync(&x).unwrap();
    let reference = full.run1(&[x]).unwrap();
    assert_eq!(chained.shape, reference.shape);
    let max_err = chained
        .data
        .iter()
        .zip(&reference.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    let scale = reference
        .data
        .iter()
        .map(|v| v.abs())
        .fold(0f32, f32::max)
        .max(1.0);
    assert!(
        max_err / scale < 2e-3,
        "pipeline vs full model: max_err {max_err} (scale {scale})"
    );
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn block_shapes_chain() {
    let Some((m, engine)) = setup() else { return };
    let pipeline = Pipeline::load(&engine, &m).unwrap();
    let x = input_batch(&m, 3);
    let mut h = rfc_hypgcn::coordinator::pipeline::nctv_to_ntvc(&x).unwrap();
    for (i, stage) in pipeline.stages.iter().enumerate() {
        h = stage.run1(&[h]).unwrap();
        assert_eq!(
            h.shape, m.blocks[i].out_shape,
            "block {} output shape",
            i + 1
        );
        assert!(h.data.iter().all(|v| v.is_finite()));
    }
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn threaded_pipeline_matches_sync_and_preserves_order() {
    let Some((m, engine)) = setup() else { return };
    let pipeline = Arc::new(Pipeline::load(&engine, &m).unwrap());
    let handle = pipeline.spawn::<usize>(2);
    let enc = EncoderConfig::default();
    let inputs: Vec<Tensor> =
        (0..4).map(|i| input_batch(&m, 100 + i)).collect();
    let expected: Vec<Tensor> = inputs
        .iter()
        .map(|x| pipeline.run_sync(x).unwrap())
        .collect();
    for (i, x) in inputs.iter().enumerate() {
        handle.input.send(Job::dense(i, x.clone())).unwrap();
    }
    let mut got = 0;
    for job in handle.output.iter() {
        let exp = &expected[job.ctx];
        let out = job.payload.into_dense(&enc);
        assert_eq!(out.shape, exp.shape);
        let max_err = out
            .data
            .iter()
            .zip(&exp.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-4, "job {} differs by {max_err}", job.ctx);
        got += 1;
        if got == 4 {
            break;
        }
    }
    handle.shutdown();
    assert_eq!(got, 4);
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn skip_variant_runs_on_half_frames() {
    let Some((m, engine)) = setup() else { return };
    let exe = engine.load_hlo(&m.hlo_path(&m.model_skip.hlo)).unwrap();
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: m.num_classes,
            seq_len: m.seq_len / 2,
            noise: 0.02,
        },
        5,
    );
    let (x, _) = gen.batch(m.batch);
    let y = exe.run1(&[x]).unwrap();
    assert_eq!(y.shape, vec![m.batch, m.num_classes]);
    assert!(y.data.iter().all(|v| v.is_finite()));
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn ck_variant_differs_from_dense() {
    let Some((m, engine)) = setup() else { return };
    let dense = engine.load_hlo(&m.hlo_path(&m.model_dense.hlo)).unwrap();
    let ck = engine.load_hlo(&m.hlo_path(&m.model_ck.hlo)).unwrap();
    let x = input_batch(&m, 17);
    let a = dense.run1(&[x.clone()]).unwrap();
    let b = ck.run1(&[x]).unwrap();
    let diff: f32 = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(p, q)| (p - q).abs())
        .sum();
    assert!(diff > 1e-6, "C_k graph had no effect");
}
