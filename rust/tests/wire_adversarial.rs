//! Adversarial decode coverage for the v1 wire format: truncated
//! buffers, wrong magic, version skew, count mismatches and overflowed
//! shapes must all come back as `Err` from `rfc::wire::from_bytes` --
//! never a panic.  The checked-in corpus (`tests/wire_corpus/`) pins the
//! byte-level cases; the programmatic sweeps below mutate freshly
//! serialized frames so they track the format as it evolves.

use std::path::Path;

use rfc_hypgcn::rfc::{self, wire, EncoderConfig};
use rfc_hypgcn::runtime::Tensor;

fn cfg() -> EncoderConfig {
    EncoderConfig {
        shards: 2,
        min_sparsity: 0.0,
        parallel_threshold: 0,
    }
}

fn valid_frame() -> Vec<u8> {
    let t = Tensor::random_sparse(vec![3, 40], 0.5, 99);
    wire::to_bytes(&rfc::encode(&t, &cfg())).unwrap()
}

#[test]
fn corpus_files_all_rejected() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/wire_corpus");
    let mut checked = 0;
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .expect("wire corpus dir")
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    for path in entries {
        if path.extension().is_some_and(|e| e == "bin") {
            let bytes = std::fs::read(&path).unwrap();
            let res = wire::from_bytes(&bytes);
            assert!(res.is_err(), "{} decoded successfully", path.display());
            checked += 1;
        }
    }
    assert!(checked >= 13, "corpus shrank: only {checked} files");
}

#[test]
fn every_prefix_of_a_valid_frame_is_rejected() {
    let bytes = valid_frame();
    for n in 0..bytes.len() {
        assert!(wire::from_bytes(&bytes[..n]).is_err(), "prefix {n}");
    }
}

#[test]
fn wrong_magic_and_version_skew_rejected() {
    let good = valid_frame();
    let mut bad = good.clone();
    bad[0] ^= 0xFF;
    assert!(wire::from_bytes(&bad).is_err());
    let mut skew = good.clone();
    skew[4] = 2; // version 2
    let e = wire::from_bytes(&skew).unwrap_err();
    assert!(format!("{e:#}").contains("version"), "{e:#}");
    assert!(wire::from_bytes(&good).is_ok());
}

#[test]
fn corrupt_counts_rejected() {
    let good = valid_frame();
    // header u32 fields (rank 2): total_len @8, dims[0] @12,
    // row_banks @20, bank_count @24, packed_len @28.  (dims[1] is not in
    // the list: nudging 40 -> 41 keeps every derived count consistent
    // and legitimately decodes to a wider tensor.)
    for at in [8usize, 12, 20, 24, 28] {
        let mut bad = good.clone();
        bad[at] ^= 0x01;
        assert!(wire::from_bytes(&bad).is_err(), "field at {at}");
    }
    // a dims[1] flip that changes the bank grid must be caught, though
    let mut bad = good.clone();
    bad[16] ^= 0x10; // 40 -> 56: row_banks 3 -> 4 disagrees with header
    assert!(wire::from_bytes(&bad).is_err());
}

#[test]
fn flipped_bytes_never_panic() {
    // fuzz-ish sweep: every single-byte corruption must either decode
    // to Err or to a structurally valid tensor (a flip inside packed
    // values, or a popcount-preserving hot flip) -- never panic, and
    // never to a tensor that fails validation or re-serialization
    let good = valid_frame();
    for at in 0..good.len() {
        let mut bad = good.clone();
        bad[at] ^= 0xFF;
        if let Ok(ct) = wire::from_bytes(&bad) {
            ct.validate()
                .unwrap_or_else(|e| panic!("byte {at}: invalid decode: {e:#}"));
            wire::to_bytes(&ct)
                .unwrap_or_else(|e| panic!("byte {at}: unserializable: {e:#}"));
        }
    }
}

#[test]
fn oversized_rank_and_dims_rejected() {
    // hand-built header: rank 9 exceeds MAX_RANK
    let mut w = Vec::new();
    w.extend_from_slice(&wire::WIRE_MAGIC);
    w.extend_from_slice(&wire::WIRE_VERSION.to_le_bytes());
    w.extend_from_slice(&9u16.to_le_bytes());
    w.extend_from_slice(&12u32.to_le_bytes());
    assert!(wire::from_bytes(&w).is_err());
    // rank 8 with u32::MAX dims: element count must overflow-check
    let mut w = Vec::new();
    w.extend_from_slice(&wire::WIRE_MAGIC);
    w.extend_from_slice(&wire::WIRE_VERSION.to_le_bytes());
    w.extend_from_slice(&8u16.to_le_bytes());
    w.extend_from_slice(&56u32.to_le_bytes());
    for _ in 0..8 {
        w.extend_from_slice(&u32::MAX.to_le_bytes());
    }
    w.extend_from_slice(&1u32.to_le_bytes()); // row_banks
    w.extend_from_slice(&1u32.to_le_bytes()); // bank_count
    w.extend_from_slice(&0u32.to_le_bytes()); // packed_len
    assert_eq!(w.len(), 56);
    let e = wire::from_bytes(&w).unwrap_err();
    assert!(format!("{e:#}").contains("overflow"), "{e:#}");
}

#[test]
fn corpus_truncated_outer_frame_rejected_by_stream_reader() {
    // the socket transport's outer framing: a u32 length prefix that
    // promises more bytes than the stream carries must come back as
    // `Err` from `wire::read_frame` (peer died / corrupted stream),
    // never a partial frame
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/wire_corpus/truncated_outer_frame.bin");
    let bytes = std::fs::read(&path).unwrap();
    let mut r = std::io::Cursor::new(bytes.clone());
    assert!(wire::read_frame(&mut r).is_err(), "truncated outer frame");
    // every shorter prefix of the stream is just as dead
    for n in 0..bytes.len() {
        let mut r = std::io::Cursor::new(&bytes[..n]);
        assert!(wire::read_frame(&mut r).is_err(), "stream prefix {n}");
    }
}

#[test]
fn oversized_outer_length_prefix_rejected_before_allocation() {
    let mut stream = Vec::from(u32::MAX.to_le_bytes());
    stream.extend_from_slice(b"junk");
    let e = wire::read_frame(&mut std::io::Cursor::new(stream)).unwrap_err();
    assert!(format!("{e:#}").contains("bound"), "{e:#}");
}

#[test]
fn outer_framing_roundtrips_payload_frames() {
    // write_frame/read_frame must hand back exactly the payload frame
    // bytes, so the inner validation chain is unchanged by the stream
    let t = Tensor::random_sparse(vec![2, 3, 8, 25], 0.6, 101);
    let p = rfc::Payload::from_tensor(t, &cfg());
    let inner = wire::payload_to_bytes(&p).unwrap();
    let mut stream = Vec::new();
    wire::write_frame(&mut stream, &inner).unwrap();
    let back = wire::read_frame(&mut std::io::Cursor::new(stream)).unwrap();
    assert_eq!(back, inner);
    assert!(wire::payload_from_bytes(&back).is_ok());
}

#[test]
fn payload_frames_reject_corruption() {
    let t = Tensor::random_sparse(vec![2, 3, 8, 25], 0.6, 100);
    let p = rfc::Payload::from_tensor(t, &cfg());
    let good = wire::payload_to_bytes(&p).unwrap();
    for n in 0..good.len() {
        assert!(
            wire::payload_from_bytes(&good[..n]).is_err(),
            "payload prefix {n}"
        );
    }
    let mut bad = good.clone();
    bad[10] = 99; // unknown kind
    assert!(wire::payload_from_bytes(&bad).is_err());
    assert!(wire::payload_from_bytes(&good).is_ok());
}
