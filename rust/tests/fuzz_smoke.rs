//! Deterministic structure-aware fuzz smoke for the wire decoders and
//! the node agent's frame-service loop: no cargo-fuzz in the offline
//! build, so a seeded SplitMix64 ([`rfc_hypgcn::util::rng::Rng`])
//! drives reproducible mutation sweeps over the checked-in corpus
//! (`tests/wire_corpus/`) plus freshly serialized frames that track the
//! format as it evolves.
//!
//! Contract under fuzz: every decoder call returns `Ok` (of a
//! structurally valid value) or a clean `Err`; a hostile byte stream at
//! a node agent costs at most its own connection -- the listener keeps
//! serving.  A panic anywhere is the bug these tests exist to catch.

use std::io::{BufReader, BufWriter, Cursor, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use rfc_hypgcn::coordinator::{dense_entry, spawn_local_agents, ShardFn};
use rfc_hypgcn::rfc::{self, wire, EncoderConfig};
use rfc_hypgcn::runtime::Tensor;
use rfc_hypgcn::util::rng::Rng;

fn cfg() -> EncoderConfig {
    EncoderConfig {
        shards: 2,
        min_sparsity: 0.0,
        parallel_threshold: 0,
    }
}

/// Mutation seeds: every corpus file (byte-level pins) plus freshly
/// serialized tensor / payload / error / outer-framed frames, so the
/// sweep keeps biting as the format evolves.
fn seed_frames() -> Vec<Vec<u8>> {
    let mut seeds = Vec::new();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/wire_corpus");
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .expect("wire corpus dir")
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    for path in entries {
        if path.extension().is_some_and(|e| e == "bin") {
            seeds.push(std::fs::read(&path).unwrap());
        }
    }
    assert!(seeds.len() >= 13, "corpus shrank: only {} seeds", seeds.len());
    for (shape, sparsity, seed) in [
        (vec![3, 40], 0.5, 7011u64),
        (vec![2, 3, 8, 25], 0.7, 7012),
        (vec![1, 60], 0.0, 7013),
    ] {
        let t = Tensor::random_sparse(shape, sparsity, seed);
        seeds.push(wire::to_bytes(&rfc::encode(&t, &cfg())).unwrap());
        let p = rfc::Payload::from_tensor(t, &cfg());
        let inner = wire::payload_to_bytes(&p).unwrap();
        let mut framed = Vec::new();
        wire::write_frame(&mut framed, &inner).unwrap();
        seeds.push(inner);
        seeds.push(framed);
    }
    seeds.push(wire::error_frame("fuzz seed"));
    seeds
}

/// One structure-aware mutant: a random seed put through 1-4 of byte
/// stomp, bit flip, truncate, random extend, aligned-u32 header-field
/// stomp (with boundary-interesting values), or cross-seed splice.
fn mutate(rng: &mut Rng, seeds: &[Vec<u8>]) -> Vec<u8> {
    let mut buf = seeds[rng.below(seeds.len())].clone();
    for _ in 0..(1 + rng.below(4)) {
        match rng.below(6) {
            0 if !buf.is_empty() => {
                let at = rng.below(buf.len());
                buf[at] = rng.next_u64() as u8;
            }
            1 if !buf.is_empty() => {
                let at = rng.below(buf.len());
                buf[at] ^= 1 << rng.below(8);
            }
            2 => {
                let keep = rng.below(buf.len() + 1);
                buf.truncate(keep);
            }
            3 => {
                for _ in 0..rng.below(9) {
                    buf.push(rng.next_u64() as u8);
                }
            }
            4 if buf.len() >= 8 => {
                // header fields are 4-aligned u32s up front: stomp one
                // with a value that probes the bounds checks
                let fields = (buf.len() / 4).min(16);
                let at = rng.below(fields) * 4;
                let v: u32 = match rng.below(5) {
                    0 => 0,
                    1 => 1,
                    2 => u32::MAX,
                    3 => wire::MAX_FRAME_LEN + 1,
                    _ => rng.next_u64() as u32,
                };
                buf[at..at + 4].copy_from_slice(&v.to_le_bytes());
            }
            5 if !buf.is_empty() => {
                let other = &seeds[rng.below(seeds.len())];
                if !other.is_empty() {
                    let cut = rng.below(buf.len());
                    let from = rng.below(other.len());
                    buf.truncate(cut);
                    buf.extend_from_slice(&other[from..]);
                }
            }
            _ => {}
        }
    }
    buf
}

#[test]
fn fuzz_wire_decoders_never_panic() {
    let seeds = seed_frames();
    let mut rng = Rng::new(0xDEC0DE);
    for round in 0..2500u32 {
        let buf = mutate(&mut rng, &seeds);
        // tensor frames: Ok must be structurally valid and reserializable
        if let Ok(ct) = wire::from_bytes(&buf) {
            ct.validate().unwrap_or_else(|e| {
                panic!("round {round}: invalid decode accepted: {e:#}")
            });
            wire::to_bytes(&ct).unwrap_or_else(|e| {
                panic!("round {round}: unserializable decode: {e:#}")
            });
        }
        // payload frames: Err or a payload -- never a panic
        let _ = wire::payload_from_bytes(&buf);
        // the stream transport's outer framing over the same bytes
        // (hostile length prefixes, truncated bodies)
        let _ = wire::read_frame(&mut Cursor::new(&buf));
    }
}

#[test]
fn fuzz_handshake_reader_never_panics() {
    let mut hs = Vec::new();
    wire::write_handshake(&mut hs).unwrap();
    let seeds = vec![hs];
    let mut rng = Rng::new(0x45C0A7);
    for _ in 0..500u32 {
        let buf = mutate(&mut rng, &seeds);
        let _ = wire::read_handshake(&mut Cursor::new(&buf));
        let _ = wire::expect_handshake(&mut Cursor::new(&buf));
    }
}

#[test]
fn fuzz_node_agent_frame_loop_survives_hostile_streams() {
    // a real TCP node agent under three connection-level attack shapes,
    // round-robined so the fixed seed exercises all of them:
    //   0: valid handshake, then mutated *inner* frames in honest outer
    //      framing -- the agent must answer each (error frame or
    //      result) and keep the connection;
    //   1: valid handshake, then raw bytes with no honest framing --
    //      the agent drops that connection only;
    //   2: garbage instead of a handshake -- dropped at the door.
    // After the sweep the same listener must still serve a clean
    // request end-to-end.
    let enc = cfg();
    let double: ShardFn = Arc::new(|t: Tensor| {
        let mut t = t;
        for v in &mut t.data {
            *v *= 2.0;
        }
        Ok(t)
    });
    let (agents, addrs) =
        spawn_local_agents(1, dense_entry(double, enc), enc).unwrap();
    let addr = addrs[0];
    let seeds = seed_frames();
    let mut rng = Rng::new(0xA6E47);

    for conn in 0..18u32 {
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut writer = BufWriter::new(stream.try_clone().unwrap());
        let mut reader = BufReader::new(stream);
        match conn % 3 {
            0 => {
                let _ = wire::read_handshake(&mut reader);
                wire::write_handshake(&mut writer).unwrap();
                for _ in 0..(1 + rng.below(5)) {
                    let frame = mutate(&mut rng, &seeds);
                    if wire::write_frame(&mut writer, &frame).is_err() {
                        break; // mutant outgrew the stream bound
                    }
                    // honest framing: the agent always answers (a
                    // result or an error frame) -- a dropped
                    // connection here would be the bug
                    let reply = wire::read_frame(&mut reader)
                        .expect("agent answers every honestly-framed mutant");
                    let _ = wire::payload_from_bytes(&reply);
                }
            }
            1 => {
                let _ = wire::read_handshake(&mut reader);
                wire::write_handshake(&mut writer).unwrap();
                let garbage = mutate(&mut rng, &seeds);
                let _ = writer.write_all(&garbage);
                let _ = writer.flush();
            }
            _ => {
                let garbage: Vec<u8> =
                    (0..8).map(|_| rng.next_u64() as u8).collect();
                let _ = writer.write_all(&garbage);
                let _ = writer.flush();
            }
        }
        // hang up (drops sever the socket; the agent reaps the handler)
    }

    // liveness: the listener survived the sweep and still serves
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = BufWriter::new(stream.try_clone().unwrap());
    let mut reader = BufReader::new(stream);
    wire::expect_handshake(&mut reader).expect("agent still handshakes");
    wire::write_handshake(&mut writer).unwrap();
    let t = Tensor::random_sparse(vec![2, 3, 8, 25], 0.6, 7099);
    let inner =
        wire::payload_to_bytes(&rfc::Payload::from_tensor(t.clone(), &cfg()))
            .unwrap();
    wire::write_frame(&mut writer, &inner).unwrap();
    let reply = wire::read_frame(&mut reader).expect("agent still serves");
    let out = wire::payload_from_bytes(&reply)
        .expect("clean request gets a clean payload back")
        .into_dense(&enc);
    assert_eq!(out.shape, t.shape);
    for (got, want) in out.data.iter().zip(&t.data) {
        assert_eq!(*got, want * 2.0, "compute ran on the surviving agent");
    }
    drop((writer, reader));
    for a in agents {
        a.shutdown();
    }
}
