//! Simulator integration: chip mapping at the paper's scale reproduces
//! the *shape* of the paper's headline results (who wins, by roughly what
//! factor) -- Tables II/IV and Fig. 11.

use rfc_hypgcn::baseline::{paper_gpus, VariantFlops, DING};
use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::model::{dense_macs, ModelConfig};
use rfc_hypgcn::sim::pipeline::{map_chip, workloads};
use rfc_hypgcn::sim::reports;
use rfc_hypgcn::sim::resource::XCKU115;
use rfc_hypgcn::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    dir.join("meta.json")
        .exists()
        .then(|| Manifest::load(&dir).unwrap())
}

fn paper_plan(dsp_target: u32) -> rfc_hypgcn::sim::pipeline::ChipPlan {
    let cfg = ModelConfig::paper_full();
    let specs = cfg.block_specs();
    let kept_in: Vec<usize> = specs
        .iter()
        .enumerate()
        .map(|(l, s)| if l == 0 { 3 } else { s.in_channels / 2 })
        .collect();
    let kept_f: Vec<usize> = (0..specs.len())
        .map(|l| {
            if l + 1 < specs.len() {
                kept_in[l + 1]
            } else {
                specs[l].out_channels
            }
        })
        .collect();
    let works = workloads(&cfg, &kept_in, &kept_f, &vec![0.5; 10]);
    let mut rng = Rng::new(99);
    map_chip(
        &works,
        &reports::default_cavity(),
        &XCKU115,
        dsp_target,
        &mut rng,
    )
}

#[test]
fn accelerator_beats_both_gpus_on_fps() {
    // Table V's headline: ours > V100 > 2080Ti on the original model
    let plan = paper_plan(3500);
    let dense: u64 = dense_macs(&ModelConfig::paper_full())
        .iter()
        .map(|m| m.flops())
        .sum();
    let flops = VariantFlops::from_dense(dense as f64);
    let (g2080, v100) = paper_gpus(&flops);
    let ours = plan.fps();
    assert!(
        ours > v100.fps(flops.with_ck),
        "ours {ours} vs V100 {}",
        v100.fps(flops.with_ck)
    );
    assert!(v100.fps(flops.with_ck) > g2080.fps(flops.with_ck));
    // speedup factor band: paper reports 9.19x over 2080Ti-original
    let speedup = ours / g2080.fps(flops.with_ck);
    assert!(
        (2.0..40.0).contains(&speedup),
        "speedup {speedup} out of plausible band"
    );
}

#[test]
fn accelerator_beats_ding_on_dsp_efficiency() {
    // Table IV: our DSP efficiency must exceed [10]'s 0.202 GOP/s/DSP
    let plan = paper_plan(3500);
    assert!(
        plan.dsp_efficiency() > DING.dsp_efficiency(),
        "ours {} vs ding {}",
        plan.dsp_efficiency(),
        DING.dsp_efficiency()
    );
    // and the fps gap is the paper's ~22x headline (band check)
    let speedup = plan.fps() / DING.fps;
    assert!(speedup > 5.0, "speedup over [10] only {speedup}");
}

#[test]
fn fps_in_paper_band() {
    // paper: 271.25 fps at T=300 full width; the band allows for model
    // differences but must be the same order of magnitude
    let plan = paper_plan(3500);
    assert!(
        (50.0..2000.0).contains(&plan.fps()),
        "fps {}",
        plan.fps()
    );
}

#[test]
fn reports_render_with_manifest() {
    let m = manifest();
    let t2 = reports::table2(m.as_ref());
    assert!(t2.contains("DSP reduction"));
    let f11 = reports::fig11(m.as_ref());
    assert!(f11.contains("RFC reduction"));
    let t4 = reports::table4(m.as_ref());
    assert!(t4.contains("speedup vs [10]"));
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn rfc_reduction_in_paper_band_on_traced_sparsity() {
    // with the traced (manifest) sparsity distributions, RFC must cut
    // storage vs dense by a two-digit percentage (paper: 35.93%)
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    use rfc_hypgcn::sim::formats::{compare, LayerTraffic};
    let mut dense = 0u64;
    let mut rfc = 0u64;
    for s in &m.sparsity {
        let row = compare(&LayerTraffic {
            name: s.name.clone(),
            lines: m.seq_len * m.num_joints,
            channels: s.channels,
            mean_sparsity: s.mean_sparsity,
            buckets: s.buckets,
        });
        dense += row.dense.bits;
        rfc += row.rfc.bits;
    }
    let saving = 1.0 - rfc as f64 / dense as f64;
    assert!(
        saving > 0.10,
        "RFC saving only {saving:.3} on traced sparsity"
    );
}
