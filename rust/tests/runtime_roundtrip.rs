//! Runtime integration: the PJRT engine must load AOT HLO-text artifacts,
//! execute them, and hand back numerically-correct host tensors.
//!
//! Quarantine note: tests touching the AOT model artifacts are
//! `#[ignore]`d unless the `aot-artifacts` feature is on (tracking: the
//! gates go away once artifact export runs in CI).  The inline-HLO tests
//! below run everywhere -- they only need the engine backend.

use std::path::Path;

use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::rfc::kernel::{GemmF32, KernelConfig};
use rfc_hypgcn::rfc::{EncoderConfig, Payload};
use rfc_hypgcn::runtime::{Engine, StagePlan, Tensor};

fn artifacts() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    if dir.join("meta.json").exists() {
        Some(Manifest::load(&dir).expect("manifest parses"))
    } else {
        None
    }
}

/// A self-contained HLO module (written inline so this test runs without
/// artifacts): y = x * 2 + 1 elementwise over f32[4], tuple-wrapped like
/// the jax exports.
const TINY_HLO: &str = r#"
HloModule tiny, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  two = f32[] constant(2)
  twob = f32[4]{0} broadcast(two), dimensions={}
  one = f32[] constant(1)
  oneb = f32[4]{0} broadcast(one), dimensions={}
  mul = f32[4]{0} multiply(x, twob)
  add = f32[4]{0} add(mul, oneb)
  ROOT out = (f32[4]{0}) tuple(add)
}
"#;

#[test]
fn engine_runs_inline_hlo() {
    let dir = std::env::temp_dir().join("rfc_tiny_hlo.txt");
    std::fs::write(&dir, TINY_HLO).unwrap();
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_hlo(&dir).unwrap();
    let x = Tensor::new(vec![4], vec![0.0, 1.0, 2.0, -3.0]).unwrap();
    let y = exe.run1(&[x]).unwrap();
    assert_eq!(y.shape, vec![4]);
    assert_eq!(y.data, vec![1.0, 3.0, 5.0, -5.0]);
}

#[test]
fn executable_cache_dedupes() {
    let dir = std::env::temp_dir().join("rfc_tiny_hlo2.txt");
    std::fs::write(&dir, TINY_HLO).unwrap();
    let engine = Engine::cpu().unwrap();
    let a = engine.load_hlo(&dir).unwrap();
    let b = engine.load_hlo(&dir).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert_eq!(engine.cached(), 1);
}

/// A stage *remainder* the stub interpreter can run (ReLU over the
/// leading GEMM's `[8, 16]` output): what an AOT stage compiled without
/// its leading GEMM looks like to [`StagePlan`]'s fast path.
const RELU_REMAINDER_HLO: &str = r#"
HloModule relu_remainder, entry_computation_layout={(f32[8,16]{1,0})->(f32[8,16]{1,0})}

ENTRY main {
  x = f32[8,16]{1,0} parameter(0)
  zero = f32[] constant(0)
  zb = f32[8,16]{1,0} broadcast(zero), dimensions={}
  relu = f32[8,16]{1,0} maximum(x, zb)
  ROOT out = (f32[8,16]{1,0}) tuple(relu)
}
"#;

#[test]
fn planned_stage_entry_elides_decode_and_matches_decode_path() {
    let path = std::env::temp_dir().join("rfc_relu_remainder.txt");
    std::fs::write(&path, RELU_REMAINDER_HLO).unwrap();
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_hlo(&path).unwrap();
    let enc = EncoderConfig {
        shards: 1,
        min_sparsity: 0.10,
        parallel_threshold: usize::MAX,
    };
    let t = Tensor::random_sparse(vec![8, 64], 0.7, 61);
    let w: Vec<f32> = (0..64 * 16)
        .map(|i| ((i % 11) as f32 - 5.0) / 4.0)
        .collect();
    let gemm = GemmF32::new(w, 64, 16).unwrap();
    let plan = StagePlan::new(gemm.clone()).with_kernel(KernelConfig::serial());

    let p = Payload::from_tensor(t.clone(), &enc);
    assert!(p.is_compressed());
    let (fast, entry) = exe
        .run_payload_planned(p, &enc, Some(&plan))
        .unwrap();
    assert!(entry.decode_elided, "compressed payload must take the kernel path");
    let stats = entry.kernel.unwrap();
    assert_eq!(stats.hot_lanes + stats.skipped_lanes, 8 * 64);
    assert!(stats.skipped_lanes > 0);

    // decode-then-dense-GEMM through the same remainder: bit-identical
    let y = Tensor::new(
        vec![8, 16],
        rfc_hypgcn::rfc::kernel::gemm_dense_f32(&t.data, 8, &gemm),
    )
    .unwrap();
    let reference = exe.run1(&[y]).unwrap();
    assert_eq!(fast.shape, reference.shape);
    for (a, b) in fast.data.iter().zip(&reference.data) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // a dense stage *input* (what a compression-gate reject delivers)
    // must still go through the plan's GEMM before the remainder: the
    // executable is the stage remainder, so skipping the GEMM on the
    // fallback would silently feed it pre-GEMM data
    let (slow, entry) = exe
        .run_payload_planned(Payload::Dense(t.clone()), &enc, Some(&plan))
        .unwrap();
    assert!(!entry.decode_elided);
    assert_eq!(slow.shape, reference.shape);
    for (a, b) in slow.data.iter().zip(&reference.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "dense fallback skipped the GEMM");
    }

    // an input the plan can never match (wrong trailing axis) is a
    // configuration error, not a silent remainder-only run
    let bad = Tensor::zeros(vec![8, 16]);
    assert!(exe
        .run_payload_planned(Payload::Dense(bad), &enc, Some(&plan))
        .is_err());
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn block01_artifact_executes_finite() {
    let Some(m) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Engine::cpu().unwrap();
    let b = &m.blocks[0];
    let exe = engine.load_hlo(&m.hlo_path(&b.hlo)).unwrap();
    let n: usize = b.in_shape.iter().product();
    // deterministic pseudo-input in a sane activation range
    let data: Vec<f32> =
        (0..n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
    let x = Tensor::new(b.in_shape.clone(), data).unwrap();
    let y = exe.run1(&[x]).unwrap();
    assert_eq!(y.shape, b.out_shape);
    assert!(
        y.data.iter().all(|v| v.is_finite()),
        "block 1 produced non-finite values"
    );
    // ReLU output: non-negative
    assert!(y.data.iter().all(|&v| v >= 0.0));
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn quant_demo_executes() {
    let Some(m) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_hlo(&m.hlo_path(&m.quant_demo.hlo)).unwrap();
    let xq: Vec<i16> = (0..64 * 32).map(|i| (i % 251) as i16 - 125).collect();
    let wq: Vec<i16> = (0..32 * 32).map(|i| (i % 127) as i16 - 63).collect();
    // i16 is ArrayElement but not NativeType: build via raw copy
    let mut xl =
        xla::Literal::create_from_shape(xla::PrimitiveType::S16, &[64, 32]);
    xl.copy_raw_from(&xq).unwrap();
    let mut wl =
        xla::Literal::create_from_shape(xla::PrimitiveType::S16, &[32, 32]);
    wl.copy_raw_from(&wq).unwrap();
    let out = exe.run_literals(&[xl, wl]).unwrap();
    assert_eq!(out.len(), 1);
    let v = out[0].to_vec::<i16>().unwrap();
    assert_eq!(v.len(), 64 * 32);
    // spot-check one element against the Q8.8 reference semantics
    let mut acc: i32 = 0;
    for k in 0..32 {
        acc += xq[k] as i32 * wq[k * 32] as i32;
    }
    let expect = (acc >> 8).clamp(-32768, 32767) as i16;
    assert_eq!(v[0], expect);
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn full_model_variants_execute_finite() {
    let Some(m) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Engine::cpu().unwrap();
    for art in [&m.model_dense, &m.model_pruned] {
        let exe = engine.load_hlo(&m.hlo_path(&art.hlo)).unwrap();
        let n: usize = art.in_shape.iter().product();
        let data: Vec<f32> =
            (0..n).map(|i| ((i % 23) as f32 - 11.0) / 11.0).collect();
        let x = Tensor::new(art.in_shape.clone(), data).unwrap();
        let y = exe.run1(&[x]).unwrap();
        assert_eq!(y.shape, art.out_shape);
        assert!(
            y.data.iter().all(|v| v.is_finite()),
            "{} produced non-finite logits: {:?}",
            art.hlo,
            &y.data[..8.min(y.data.len())]
        );
    }
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn hlo_is_text_not_proto() {
    // guardrail for the aot_recipe gotcha: artifacts must be HLO text
    let Some(m) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let head = std::fs::read_to_string(m.hlo_path(&m.blocks[0].hlo)).unwrap();
    assert!(head.starts_with("HloModule"), "artifact is not HLO text");
    assert!(Path::new(&m.hlo_path(&m.head.hlo)).exists());
}
