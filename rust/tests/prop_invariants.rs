//! Property-based tests (hand-rolled generator loops over the offline
//! SplitMix64 RNG -- no proptest in the vendor set) on coordinator and
//! simulator invariants: routing, batching, state, compression.

use rfc_hypgcn::sim::dyn_pe;
use rfc_hypgcn::sim::rfc::{
    decode_bank, encode_bank, encode_vector, BankStorage, BANK_WIDTH,
};
use rfc_hypgcn::runtime::Tensor;
use rfc_hypgcn::util::rng::Rng;

const CASES: usize = 200;

fn random_bank(rng: &mut Rng, sparsity: f64) -> Vec<f32> {
    (0..BANK_WIDTH)
        .map(|_| {
            if rng.chance(sparsity) {
                0.0
            } else {
                // strictly positive (post-ReLU) values
                (rng.f32() + 1e-3).abs()
            }
        })
        .collect()
}

#[test]
fn prop_rfc_encode_decode_roundtrip() {
    let mut rng = Rng::new(0xDECAF);
    for case in 0..CASES {
        let s = rng.f64();
        let mut rng2 = Rng::new(rng.next_u64());
        let bank = random_bank(&mut rng2, s);
        let e = encode_bank(&bank).unwrap();
        assert_eq!(
            decode_bank(&e).to_vec(),
            bank,
            "case {case} sparsity {s:.2}"
        );
    }
}

#[test]
fn prop_rfc_nnz_consistency() {
    // hot-code popcount == packed length; mbhot covers ceil(nnz/4)
    let mut rng = Rng::new(1);
    for _ in 0..CASES {
        let s = rng.f64();
        let bank = random_bank(&mut rng, s);
        let e = encode_bank(&bank).unwrap();
        assert_eq!(e.hot.count_ones() as usize, e.packed.len());
        assert_eq!(
            e.mbhot.count_ones() as usize,
            e.packed.len().div_ceil(4)
        );
        // mbhot is contiguous from the head (paper: head mini-banks first)
        let used = e.mbhot.count_ones();
        assert_eq!(e.mbhot, ((1u16 << used) - 1) as u8);
    }
}

#[test]
fn prop_storage_loads_what_it_stored() {
    let mut rng = Rng::new(2);
    for case in 0..40 {
        let lines = 4 + rng.below(28);
        let mut st = BankStorage::new([lines, lines, lines, lines]);
        let banks: Vec<Vec<f32>> = (0..lines)
            .map(|_| {
                let s = rng.f64();
                random_bank(&mut rng, s)
            })
            .collect();
        for b in &banks {
            let a = st.store(&encode_bank(b).unwrap());
            assert!(!a.truncated, "case {case}: full-depth bank truncated");
        }
        // random access order must still decode correctly (pt recompute)
        let mut order: Vec<usize> = (0..lines).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            let (e, _) = st.load(i).unwrap();
            assert_eq!(decode_bank(&e).to_vec(), banks[i], "line {i}");
        }
    }
}

#[test]
fn prop_encode_vector_preserves_total_nnz() {
    let mut rng = Rng::new(3);
    for _ in 0..CASES {
        let banks = 1 + rng.below(8);
        let v: Vec<f32> = (0..banks * BANK_WIDTH)
            .map(|_| if rng.chance(0.5) { 0.0 } else { rng.f32() + 0.01 })
            .collect();
        let nnz = v.iter().filter(|&&x| x != 0.0).count();
        let (encoded, cycles) = encode_vector(&v).unwrap();
        let packed: usize = encoded.iter().map(|e| e.packed.len()).sum();
        assert_eq!(packed, nnz);
        assert_eq!(cycles, banks as u64 + 3);
    }
}

#[test]
fn prop_dyn_pe_conservation_and_bounds() {
    // MACs executed == MACs admitted; efficiency in [0, 1]; delay >= 0
    let mut rng = Rng::new(4);
    for case in 0..60 {
        let q = 1 + rng.below(6);
        let d = 1 + rng.below(q);
        let s = rng.f64() * 0.9;
        let st = dyn_pe::simulate(q, d, 400, s, 4 + rng.below(12), &mut rng);
        assert!(st.efficiency() <= 1.0 + 1e-9, "case {case}");
        assert!(st.efficiency() >= 0.0);
        assert!(st.delay() >= 0.0);
        assert!(st.cycles >= st.static_cycles.min(st.cycles));
        // admitted macs bounded by q per input step
        assert!(st.macs <= 400 * q as u64);
    }
}

#[test]
fn prop_dyn_pe_monotone_in_dsps() {
    // more DSPs never increases cycles (same seed workload statistics)
    let mut rng = Rng::new(5);
    for _ in 0..30 {
        let q = 2 + rng.below(5);
        let s = rng.f64() * 0.8;
        let mut r1 = Rng::new(777);
        let mut r2 = Rng::new(777);
        let small = dyn_pe::simulate(q, 1, 300, s, 8, &mut r1);
        let large = dyn_pe::simulate(q, q, 300, s, 8, &mut r2);
        assert!(
            large.cycles <= small.cycles,
            "q={q} s={s:.2}: {} vs {}",
            large.cycles,
            small.cycles
        );
    }
}

#[test]
fn prop_tensor_split_concat_identity() {
    let mut rng = Rng::new(6);
    for _ in 0..CASES {
        let n = 1 + rng.below(12);
        let d = 1 + rng.below(6);
        let data: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
        let t = Tensor::new(vec![n, d], data).unwrap();
        let chunk = 1 + rng.below(n + 2);
        let parts = t.split_batch(chunk);
        assert!(parts.iter().all(|p| p.shape[0] <= chunk));
        assert_eq!(
            parts.iter().map(|p| p.shape[0]).sum::<usize>(),
            n
        );
        assert_eq!(Tensor::concat_batch(&parts).unwrap(), t);
    }
}

#[test]
fn prop_batch_padding_rows_zero() {
    use rfc_hypgcn::coordinator::{BatchPolicy, Batcher};
    use rfc_hypgcn::coordinator::Request;
    use std::time::Instant;
    let mut rng = Rng::new(7);
    for _ in 0..40 {
        let batch_size = 2 + rng.below(6);
        let seq_len = 4 + rng.below(4);
        let real = 1 + rng.below(batch_size);
        let policy = BatchPolicy {
            batch_size,
            max_wait: std::time::Duration::from_millis(1),
            seq_len,
        };
        let reqs: Vec<Request> = (0..real)
            .map(|i| {
                let (tx, _rx) = std::sync::mpsc::channel();
                std::mem::forget(_rx);
                Request {
                    id: i as u64,
                    clip: vec![1.0; 3 * seq_len * 25],
                    seq_len,
                    arrived: Instant::now(),
                    deadline: None,
                    reply: tx,
                }
            })
            .collect();
        let b = Batcher::form_from(&policy, reqs).unwrap();
        assert_eq!(b.real, real);
        assert_eq!(b.input.shape()[0], batch_size);
        let dense = b
            .input
            .to_dense(&rfc_hypgcn::rfc::EncoderConfig::default());
        let row = 3 * seq_len * 25;
        for r in real..batch_size {
            assert!(
                dense.data[r * row..(r + 1) * row]
                    .iter()
                    .all(|&v| v == 0.0),
                "padding row {r} not zero"
            );
        }
        match b.input.as_compressed() {
            Some(ct) => {
                ct.validate().unwrap();
                // compressed-form batching: only the real (all-ones)
                // clips' values are stored, padding is sidecar-only
                assert_eq!(ct.nnz(), real * row);
            }
            // the batch-level gate ships dense only when every row is a
            // dense clip (no padding at these policy sizes)
            None => assert_eq!(real, batch_size),
        }
    }
}

#[test]
fn prop_wire_roundtrip_bit_exact() {
    // arbitrary sparsity/shape tensors round-trip through
    // to_bytes/from_bytes bit-exactly, for any encoder shard count
    use rfc_hypgcn::rfc::{self, wire, EncoderConfig};
    let mut rng = Rng::new(9);
    for case in 0..60 {
        let rows = 1 + rng.below(8);
        let cols = 1 + rng.below(120);
        let shape = if case % 3 == 0 {
            vec![rows, 4, cols.div_ceil(4)]
        } else {
            vec![rows, cols]
        };
        let t = Tensor::random_sparse(shape, rng.f64(), rng.next_u64());
        let cfg = EncoderConfig {
            shards: 1 + rng.below(5),
            min_sparsity: 0.0,
            parallel_threshold: 0,
        };
        let ct = rfc::encode(&t, &cfg);
        let bytes = wire::to_bytes(&ct).unwrap();
        let back = wire::from_bytes(&bytes).unwrap();
        let dense = back.to_tensor();
        assert_eq!(dense.shape, t.shape, "case {case}");
        for (x, y) in dense.data.iter().zip(&t.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "case {case}");
        }
        // decoded tensors re-serialize to the identical stream
        assert_eq!(wire::to_bytes(&back).unwrap(), bytes, "case {case}");
    }
}

/// Little-endian field reads for the stitch helper below.
fn rd_u16(b: &[u8], at: usize) -> usize {
    u16::from_le_bytes([b[at], b[at + 1]]) as usize
}

fn rd_u32(b: &[u8], at: usize) -> usize {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]]) as usize
}

/// Reassemble a whole-batch wire frame from per-part frames by the
/// header rules: dims[0] and the count fields sum, hot/mbhot/packed
/// sections concatenate, row offsets rebase by the running packed count.
fn stitch_wire(parts: &[Vec<u8>]) -> Vec<u8> {
    let rank = rd_u16(&parts[0], 6);
    let hdr = 24 + 4 * rank;
    let mut rows = 0usize;
    let mut banks = 0usize;
    let mut packed = 0usize;
    for p in parts {
        rows += rd_u32(p, 12);
        banks += rd_u32(p, 16 + 4 * rank);
        packed += rd_u32(p, 20 + 4 * rank);
    }
    let total = hdr + banks * 3 + (rows + 1) * 4 + packed * 4;
    let mut w = Vec::with_capacity(total);
    w.extend_from_slice(&parts[0][..6]); // magic + version
    w.extend_from_slice(&(rank as u16).to_le_bytes());
    w.extend_from_slice(&(total as u32).to_le_bytes());
    w.extend_from_slice(&(rows as u32).to_le_bytes());
    w.extend_from_slice(&parts[0][16..12 + 4 * rank]); // tail dims
    w.extend_from_slice(&parts[0][12 + 4 * rank..16 + 4 * rank]); // row_banks
    w.extend_from_slice(&(banks as u32).to_le_bytes());
    w.extend_from_slice(&(packed as u32).to_le_bytes());
    for p in parts {
        let b = rd_u32(p, 16 + 4 * rank);
        w.extend_from_slice(&p[hdr..hdr + 2 * b]); // hots
    }
    for p in parts {
        let b = rd_u32(p, 16 + 4 * rank);
        w.extend_from_slice(&p[hdr + 2 * b..hdr + 3 * b]); // mbhots
    }
    w.extend_from_slice(&0u32.to_le_bytes());
    let mut base = 0usize;
    for p in parts {
        let r = rd_u32(p, 12);
        let b = rd_u32(p, 16 + 4 * rank);
        let offs = hdr + 3 * b;
        for i in 1..=r {
            let o = rd_u32(p, offs + 4 * i) + base;
            w.extend_from_slice(&(o as u32).to_le_bytes());
        }
        base += rd_u32(p, 20 + 4 * rank);
    }
    for p in parts {
        let r = rd_u32(p, 12);
        let b = rd_u32(p, 16 + 4 * rank);
        let pk = rd_u32(p, 20 + 4 * rank);
        let at = hdr + 3 * b + 4 * (r + 1);
        w.extend_from_slice(&p[at..at + 4 * pk]); // packed values
    }
    assert_eq!(w.len(), total);
    w
}

#[test]
fn prop_wire_concat_equals_stitched_segments() {
    // concat_batch(parts).to_bytes() == concatenating the parts' wire
    // segments under the header rules
    use rfc_hypgcn::rfc::{self, wire, CompressedTensor, EncoderConfig};
    let mut rng = Rng::new(10);
    for case in 0..40 {
        let cols = 1 + rng.below(80);
        let n_parts = 1 + rng.below(4);
        let cfg = EncoderConfig {
            shards: 1 + rng.below(3),
            min_sparsity: 0.0,
            parallel_threshold: 0,
        };
        let mut parts = Vec::new();
        let mut part_bytes = Vec::new();
        for _ in 0..n_parts {
            let rows = 1 + rng.below(5);
            let t = Tensor::random_sparse(
                vec![rows, cols],
                rng.f64(),
                rng.next_u64(),
            );
            let ct = rfc::encode(&t, &cfg);
            part_bytes.push(wire::to_bytes(&ct).unwrap());
            parts.push(ct);
        }
        let whole = CompressedTensor::concat_batch(parts).unwrap();
        assert_eq!(
            wire::to_bytes(&whole).unwrap(),
            stitch_wire(&part_bytes),
            "case {case}"
        );
    }
}

#[test]
fn prop_kernel_f32_bit_identical_to_decode_then_dense() {
    // compressed-domain GEMM == dense GEMM over the decoded tensor, bit
    // for bit, across random shapes/sparsities (incl. all-zero and
    // fully-dense banks), both claim geometries, any shard count, any
    // worker count / job grain
    use rfc_hypgcn::rfc::kernel::{
        gemm_dense_f32, spmm_f32, GemmF32, KernelConfig, LaneDispatch,
    };
    use rfc_hypgcn::rfc::{self, EncoderConfig};
    let mut rng = Rng::new(0x6E33);
    for case in 0..60 {
        let aligned = case % 2 == 0;
        let (rows, k, g) = if aligned {
            // bank-aligned k, 1..3 GEMM rows per tensor row
            (1 + rng.below(6), (1 + rng.below(4)) * BANK_WIDTH, 1 + rng.below(3))
        } else {
            // k covers the whole (possibly unaligned) row
            (1 + rng.below(6), 1 + rng.below(70), 1)
        };
        let n = 1 + rng.below(20);
        let sparsity = match case % 5 {
            0 => 0.0, // fully dense banks
            1 => 1.0, // all-zero banks
            _ => rng.f64(),
        };
        let t = Tensor::random_sparse(vec![rows, g * k], sparsity, 5000 + case);
        let cfg = EncoderConfig {
            shards: 1 + rng.below(4),
            min_sparsity: 0.0,
            parallel_threshold: 0,
        };
        let ct = rfc::encode(&t, &cfg);
        let w: Vec<f32> = (0..k * n).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let gemm = GemmF32::new(w, k, n).unwrap();
        let m = rows * g;
        let reference = gemm_dense_f32(&ct.to_tensor().data, m, &gemm);
        for kcfg in [
            KernelConfig::serial(),
            KernelConfig::serial().with_dispatch(LaneDispatch::ForceScalar),
            KernelConfig {
                workers: 1 + rng.below(6),
                rows_per_job: 1 + rng.below(3),
                par_threshold_macs: 0,
                dispatch: LaneDispatch::Auto,
            },
            KernelConfig {
                workers: 1 + rng.below(6),
                rows_per_job: 1 + rng.below(3),
                par_threshold_macs: 0,
                dispatch: LaneDispatch::ForceScalar,
            },
        ] {
            let (y, stats) = spmm_f32(&ct, &gemm, &kcfg).unwrap();
            assert_eq!(y.data.len(), reference.len(), "case {case}");
            for (a, b) in y.data.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits(), "case {case}");
            }
            assert_eq!(
                stats.hot_lanes + stats.skipped_lanes,
                t.len() as u64,
                "case {case}: lane accounting"
            );
            assert_eq!(
                stats.hot_lanes as usize,
                t.data.iter().filter(|&&v| v != 0.0).count(),
                "case {case}"
            );
        }
    }
}

#[test]
fn prop_kernel_q88_bit_identical_to_quant_matmul_ref() {
    use rfc_hypgcn::quant::{quant_matmul_ref, quantize_slice};
    use rfc_hypgcn::rfc::kernel::{spmm_q88, GemmF32, KernelConfig, LaneDispatch};
    use rfc_hypgcn::rfc::{self, EncoderConfig};
    let mut rng = Rng::new(0xABBA);
    for case in 0..40 {
        let rows = 1 + rng.below(5);
        let k = if case % 2 == 0 {
            (1 + rng.below(3)) * BANK_WIDTH
        } else {
            1 + rng.below(50)
        };
        let n = 1 + rng.below(12);
        let sparsity = match case % 5 {
            0 => 0.0,
            1 => 1.0,
            _ => rng.f64(),
        };
        let t = Tensor::random_sparse(vec![rows, k], sparsity, 7000 + case);
        let cfg = EncoderConfig {
            shards: 1 + rng.below(3),
            min_sparsity: 0.0,
            parallel_threshold: 0,
        };
        let ct = rfc::encode(&t, &cfg);
        let w: Vec<f32> = (0..k * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let gemm = GemmF32::new(w, k, n).unwrap().quantize();
        let xq = quantize_slice(&ct.to_tensor().data);
        let reference = quant_matmul_ref(&xq, gemm.raw_weights(), rows, k, n);
        for workers in [1usize, 3] {
            for dispatch in [LaneDispatch::Auto, LaneDispatch::ForceScalar] {
                let kcfg = KernelConfig {
                    workers,
                    rows_per_job: 1,
                    par_threshold_macs: 0,
                    dispatch,
                };
                let (yq, stats) = spmm_q88(&ct, &gemm, &kcfg).unwrap();
                assert_eq!(
                    yq, reference,
                    "case {case} workers {workers} {dispatch:?}"
                );
                assert_eq!(stats.gemm_rows, rows as u64, "case {case}");
            }
        }
    }
}

#[test]
fn prop_kernel_simd_tail_geometries_match_scalar() {
    // the SIMD-specific hazard zone: output widths sweeping every
    // residue of the widest lane width (ragged tails), single-row
    // banks, and rows forced all-zero (empty mbhot banks mid-stream).
    // Forced-scalar and auto dispatch must agree bit for bit with each
    // other and with the dense reference, f32 and Q8.8 alike.
    use rfc_hypgcn::quant::{quant_matmul_ref, quantize_slice};
    use rfc_hypgcn::rfc::kernel::{
        gemm_dense_f32, spmm_f32, spmm_q88, GemmF32, KernelConfig,
        LaneDispatch,
    };
    use rfc_hypgcn::rfc::{self, EncoderConfig};
    let mut rng = Rng::new(0x51D3);
    for case in 0..40 {
        // n = 1..=18 covers every residue mod 8 (AVX2) and mod 4 (NEON),
        // including n smaller than one vector lane
        let n = 1 + (case as usize % 18);
        let single_row_banks = case % 3 == 0;
        let (rows, k) = if single_row_banks {
            // one bank per GEMM row: k == BANK_WIDTH
            (1 + rng.below(4), BANK_WIDTH)
        } else {
            (1 + rng.below(4), 1 + rng.below(70))
        };
        let mut t =
            Tensor::random_sparse(vec![rows, k], rng.f64(), 9000 + case);
        // force a row all-zero so the kernel crosses empty mbhot banks
        // between live ones
        if rows > 1 {
            let dead = rng.below(rows);
            for v in &mut t.data[dead * k..(dead + 1) * k] {
                *v = 0.0;
            }
        }
        let cfg = EncoderConfig {
            shards: 1 + rng.below(3),
            min_sparsity: 0.0,
            parallel_threshold: 0,
        };
        let ct = rfc::encode(&t, &cfg);
        let w: Vec<f32> = (0..k * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let gemm = GemmF32::new(w, k, n).unwrap();
        let reference = gemm_dense_f32(&ct.to_tensor().data, rows, &gemm);
        let auto = KernelConfig::serial();
        let scalar =
            KernelConfig::serial().with_dispatch(LaneDispatch::ForceScalar);
        let (y_a, st_a) = spmm_f32(&ct, &gemm, &auto).unwrap();
        let (y_s, st_s) = spmm_f32(&ct, &gemm, &scalar).unwrap();
        for ((a, s), r) in y_a.data.iter().zip(&y_s.data).zip(&reference) {
            assert_eq!(a.to_bits(), s.to_bits(), "case {case} n {n}");
            assert_eq!(a.to_bits(), r.to_bits(), "case {case} n {n}");
        }
        assert_eq!(st_a, st_s, "case {case}: stats must not depend on ISA");

        let gq = gemm.quantize();
        let xq = quantize_slice(&ct.to_tensor().data);
        let qref = quant_matmul_ref(&xq, gq.raw_weights(), rows, k, n);
        let (q_a, _) = spmm_q88(&ct, &gq, &auto).unwrap();
        let (q_s, _) = spmm_q88(&ct, &gq, &scalar).unwrap();
        assert_eq!(q_a, qref, "case {case} n {n}: q88 auto vs ref");
        assert_eq!(q_s, qref, "case {case} n {n}: q88 scalar vs ref");
    }
}

#[test]
fn prop_runtime_compress_roundtrip_any_shard_count() {
    use rfc_hypgcn::rfc::{self, EncoderConfig};
    let mut rng = Rng::new(8);
    for case in 0..40 {
        let rows = 1 + rng.below(9);
        let cols = 1 + rng.below(90);
        let s = rng.f64();
        let t = Tensor::random_sparse(vec![rows, cols], s, rng.next_u64());
        let cfg = EncoderConfig {
            shards: 1 + rng.below(6),
            min_sparsity: 0.0,
            parallel_threshold: 0,
        };
        let ct = rfc::encode(&t, &cfg);
        ct.validate().unwrap();
        assert_eq!(rfc::decode(&ct, &cfg), t, "case {case}");
        assert_eq!(
            ct.nnz(),
            t.data.iter().filter(|&&v| v != 0.0).count(),
            "case {case}"
        );
    }
}
