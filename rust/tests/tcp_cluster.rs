//! Socket-backed shard cluster, end to end: a coordinator `Server` over
//! TCP node agents must serve exactly like the loopback cluster, and
//! the failure surface (peer death, version skew, garbage frames,
//! malformed requests, mis-sized node replies) must come back as error
//! responses / rejected connections -- never hangs, panics, or a
//! silently wedged server.
//!
//! Runs entirely on localhost ephemeral ports; no artifacts and no
//! external network needed.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use rfc_hypgcn::coordinator::{
    dense_entry, spawn_local_agents, AdmissionPolicy, BatchPolicy, Metrics,
    NodeAgent, NodeSpec, ReconnectPolicy, Response, RetryPolicy, Server,
    ShardCluster, ShardFn, TcpLink,
};
use rfc_hypgcn::model::NUM_JOINTS;
use rfc_hypgcn::rfc::{wire, EncoderConfig, Payload};
use rfc_hypgcn::runtime::Tensor;

/// Deterministic row-local synthetic classifier (same contract as the
/// real stage chain on the batch axis).
fn synth_model(classes: usize) -> ShardFn {
    Arc::new(move |t: Tensor| {
        anyhow::ensure!(t.shape.len() >= 2, "need a batch axis");
        let rows = t.shape[0];
        let row: usize = t.shape[1..].iter().product();
        let mut out = vec![0f32; rows * classes];
        for r in 0..rows {
            let src = &t.data[r * row..(r + 1) * row];
            for (c, slot) in
                out[r * classes..(r + 1) * classes].iter_mut().enumerate()
            {
                *slot = src
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| v * (((i + c) % 7) as f32))
                    .sum();
            }
        }
        Tensor::new(vec![rows, classes], out)
    })
}

fn enc() -> EncoderConfig {
    EncoderConfig {
        shards: 1,
        min_sparsity: 0.10,
        parallel_threshold: usize::MAX,
    }
}

fn policy(seq_len: usize) -> BatchPolicy {
    BatchPolicy {
        batch_size: 4,
        max_wait: Duration::from_millis(1),
        seq_len,
    }
}

/// Spawn `n` localhost node agents running `model`; returns them with
/// their addresses.
fn spawn_agents(
    n: usize,
    model: ShardFn,
    enc: EncoderConfig,
) -> (Vec<NodeAgent>, Vec<SocketAddr>) {
    spawn_local_agents(n, dense_entry(model, enc), enc).unwrap()
}

/// Rebind a just-freed listener address, retrying briefly: the restart
/// half of the chaos tests needs the *same* port back, and the old
/// listener's teardown can race the rebind.
fn bind_retry(addr: SocketAddr) -> TcpListener {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match TcpListener::bind(addr) {
            Ok(l) => return l,
            Err(e) => {
                assert!(Instant::now() < deadline, "rebinding {addr}: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Submit `n` random clips and collect every response (each paired with
/// its clip so callers can check the answers against the model).
fn submit_batch(
    server: &Server,
    seq_len: usize,
    n: usize,
    seed: u64,
) -> Vec<(Vec<f32>, Response)> {
    let row = 3 * seq_len * NUM_JOINTS;
    let clips: Vec<Vec<f32>> = (0..n)
        .map(|i| Tensor::random_sparse(vec![row], 0.5, seed + i as u64).data)
        .collect();
    let rxs: Vec<_> = clips.iter().map(|c| server.submit(c.clone())).collect();
    clips
        .into_iter()
        .zip(rxs)
        .map(|(c, rx)| {
            let resp = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("response must arrive");
            (c, resp)
        })
        .collect()
}

/// Every response in `batch` carries the model's logits for its clip.
fn assert_all_served(
    batch: &[(Vec<f32>, Response)],
    model: &ShardFn,
    seq_len: usize,
    ctx: &str,
) {
    for (i, (clip, resp)) in batch.iter().enumerate() {
        assert!(resp.is_ok(), "{ctx}: clip {i}: {:?}", resp.error);
        let t = Tensor::new(vec![1, 3, seq_len, NUM_JOINTS], clip.clone())
            .unwrap();
        assert_eq!(resp.logits, model(t).unwrap().data, "{ctx}: clip {i}");
    }
}

#[test]
fn sharded_server_over_tcp_matches_loopback_cluster_server() {
    const CLASSES: usize = 6;
    let seq_len = 8;
    let row = 3 * seq_len * NUM_JOINTS;
    let model = synth_model(CLASSES);
    let clips: Vec<Vec<f32>> = (0..9)
        .map(|i| Tensor::random_sparse(vec![row], 0.7, 6000 + i).data)
        .collect();

    let loopback = Server::start_cluster(
        policy(seq_len),
        enc(),
        ShardCluster::loopback(2, model.clone(), enc()),
        CLASSES,
    );
    let (agents, addrs) = spawn_agents(2, model.clone(), enc());
    let tcp =
        Server::connect_sharded(&addrs, policy(seq_len), enc(), CLASSES)
            .unwrap();

    let a: Vec<_> = clips.iter().map(|c| loopback.submit(c.clone())).collect();
    let b: Vec<_> = clips.iter().map(|c| tcp.submit(c.clone())).collect();
    for (i, (ra, rb)) in a.into_iter().zip(b).enumerate() {
        let ra = ra.recv_timeout(Duration::from_secs(30)).unwrap();
        let rb = rb.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(ra.is_ok() && rb.is_ok(), "clip {i}");
        assert_eq!(
            ra.logits, rb.logits,
            "clip {i}: TCP serving diverged from loopback"
        );
        // and both match the model applied to the clip directly
        let t = Tensor::new(
            vec![1, 3, seq_len, NUM_JOINTS],
            clips[i].clone(),
        )
        .unwrap();
        assert_eq!(ra.logits, model(t).unwrap().data, "clip {i}");
    }
    // the TCP links recorded per-node wire traffic
    let nodes = tcp.metrics.node_transport();
    assert!(!nodes.is_empty());
    assert!(nodes.iter().any(|n| n.shards > 0));
    tcp.shutdown();
    loopback.shutdown();
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn malformed_clip_gets_error_response_and_good_clip_still_served() {
    // Regression: a wrong-length clip used to panic the batcher thread
    // in release builds, after which every request was dropped forever.
    const CLASSES: usize = 5;
    let seq_len = 8;
    let model = synth_model(CLASSES);
    let server = Server::start_cluster(
        policy(seq_len),
        enc(),
        ShardCluster::loopback(2, model.clone(), enc()),
        CLASSES,
    );
    // bad clip first: must be answered with an error response
    let bad_rx = server.submit(vec![1.0; 17]);
    let bad = bad_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert!(!bad.is_ok());
    assert!(
        bad.error.as_deref().unwrap().contains("malformed clip"),
        "{:?}",
        bad.error
    );
    assert!(bad.logits.is_empty());
    // the good clip right behind it must still be served
    let row = 3 * seq_len * NUM_JOINTS;
    let clip = Tensor::random_sparse(vec![row], 0.6, 7000).data;
    let good_rx = server.submit(clip.clone());
    let good = good_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert!(good.is_ok(), "{:?}", good.error);
    let t = Tensor::new(vec![1, 3, seq_len, NUM_JOINTS], clip).unwrap();
    assert_eq!(good.logits, model(t).unwrap().data);
    assert!(
        server
            .metrics
            .failures
            .load(std::sync::atomic::Ordering::Relaxed)
            >= 1
    );
    server.shutdown();
}

#[test]
fn wrong_width_node_reply_fails_the_batch_with_error_responses() {
    // a shard compute that answers 3-wide logits for a server expecting
    // 10: release builds used to debug_assert (i.e. not at all) and
    // slice wrong rows; now the batch fails loudly with error responses
    const WRONG: usize = 3;
    const EXPECTED: usize = 10;
    let seq_len = 8;
    let server = Server::start_cluster(
        policy(seq_len),
        enc(),
        ShardCluster::loopback(2, synth_model(WRONG), enc()),
        EXPECTED,
    );
    let row = 3 * seq_len * NUM_JOINTS;
    let rxs: Vec<_> = (0..2)
        .map(|i| {
            server.submit(Tensor::random_sparse(vec![row], 0.5, 7100 + i).data)
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(!resp.is_ok(), "mis-sized reply must fail the batch");
        assert!(
            resp.error.as_deref().unwrap().contains("delivery expects"),
            "{:?}",
            resp.error
        );
    }
    server.shutdown();
}

#[test]
fn tcp_peer_death_fails_the_batch_then_single_shard_batches_recover() {
    const CLASSES: usize = 4;
    let seq_len = 8;
    let row = 3 * seq_len * NUM_JOINTS;
    let model = synth_model(CLASSES);
    let (mut agents, addrs) = spawn_agents(2, model.clone(), enc());
    // a generous max_wait so the 4 submits below land in ONE full batch
    // (a split batch could route a lone shard to the live node and pass
    // without exercising the dead peer at all)
    let batch_policy = BatchPolicy {
        batch_size: 4,
        max_wait: Duration::from_millis(250),
        seq_len,
    };
    // retry DISABLED: this test pins the fail-the-batch substrate the
    // fault-masking path is built on (error responses, drain, route
    // around) -- the masked behavior is proven separately in
    // chaos_retry_kill_mid_batch_is_masked_from_callers
    let mut cluster = ShardCluster::connect_timeout(
        &addrs,
        enc(),
        Some(Duration::from_secs(5)),
    )
    .unwrap();
    cluster.set_retry_policy(RetryPolicy::disabled());
    let server =
        Server::start_cluster(batch_policy, enc(), cluster, CLASSES);
    // kill node 1 while the server holds live links to both
    agents.remove(1).shutdown();
    // a full batch fans out over both nodes: it must fail with error
    // responses (node 1 is gone), not hang and not panic
    let rxs: Vec<_> = (0..4)
        .map(|i| {
            server.submit(Tensor::random_sparse(vec![row], 0.5, 7200 + i).data)
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(!resp.is_ok(), "dead peer must fail the batch");
    }
    // a lone request pads out and routes to a single shard on node 0,
    // which the failed batch drained: it must serve correctly -- a
    // stale queued reply would have shifted its results by one batch
    let clip = Tensor::random_sparse(vec![row], 0.5, 7300).data;
    let rx = server.submit(clip.clone());
    let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert!(resp.is_ok(), "{:?}", resp.error);
    let t = Tensor::new(vec![1, 3, seq_len, NUM_JOINTS], clip).unwrap();
    assert_eq!(resp.logits, model(t).unwrap().data);
    // the failed batch took node 1's slot Down, so a FULL batch -- the
    // very shape that failed above -- now routes around it and succeeds
    // on the survivor, no coordinator restart involved
    let full = submit_batch(&server, seq_len, 4, 7350);
    assert_all_served(&full, &model, seq_len, "routed-around full batch");
    assert!(
        !server.metrics.node_health()[1].up,
        "the dead slot must be reported Down"
    );
    server.shutdown();
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn chaos_kill_under_load_then_restart_heals_without_coordinator_restart() {
    // 3 TCP agents under sustained full batches.  Killing one is masked
    // by shard retry (the in-flight batch is re-dispatched onto the
    // survivors, so its callers still get correct answers); every later
    // batch succeeds on the survivors; restarting the agent on the SAME
    // address heals the cluster (its slot serves shards again) with no
    // coordinator restart.
    const CLASSES: usize = 4;
    let seq_len = 8;
    let model = synth_model(CLASSES);
    let (mut agents, addrs) = spawn_agents(3, model.clone(), enc());
    // 6-row batches so the router fans over all 3 nodes (2 rows each)
    let batch_policy = BatchPolicy {
        batch_size: 6,
        max_wait: Duration::from_millis(250),
        seq_len,
    };
    let mut cluster = ShardCluster::connect_timeout(
        &addrs,
        enc(),
        Some(Duration::from_secs(5)),
    )
    .unwrap();
    // a tight backoff so the heal lands within the polling budget below
    // (no standbys here, so promote_after is inert however it is set)
    cluster.set_reconnect_policy(ReconnectPolicy {
        base: Duration::from_millis(10),
        cap: Duration::from_millis(100),
        connect_timeout: Duration::from_millis(250),
        attempts_per_heal: 3,
        promote_after: Duration::from_secs(3600),
    });
    let server = Server::start_cluster(batch_policy, enc(), cluster, CLASSES);

    // healthy baseline: a full batch lands shards on every node
    let healthy = submit_batch(&server, seq_len, 6, 9000);
    assert_all_served(&healthy, &model, seq_len, "healthy baseline");
    assert_eq!(server.metrics.node_transport().len(), 3);

    let dead_addr = addrs[1];
    agents.remove(1).shutdown();

    // the batch in flight across the kill is MASKED: the lost shard is
    // re-dispatched onto the survivors, so every caller still gets its
    // bit-exact answer
    let in_flight = submit_batch(&server, seq_len, 6, 9010);
    assert_all_served(&in_flight, &model, seq_len, "kill-spanning batch");
    assert!(
        server
            .metrics
            .shard_retries
            .load(std::sync::atomic::Ordering::Relaxed)
            > 0,
        "masking must go through the retry path, not dumb luck"
    );
    // ...and nothing else is lost either: sustained batches keep
    // succeeding on the 2 survivors, correct to the model
    for round in 0..4u64 {
        let survived = submit_batch(&server, seq_len, 6, 9020 + round * 10);
        assert_all_served(
            &survived,
            &model,
            seq_len,
            &format!("survivor round {round}"),
        );
    }
    let health = server.metrics.node_health();
    assert!(!health[1].up, "killed slot reported Down: {health:?}");
    assert!(health[0].up && health[2].up, "{health:?}");
    let shards_at_kill = server.metrics.node_transport()[1].shards;

    // restart on the same address; the coordinator's backoff-gated heal
    // must re-dial and put the slot back in the rotation
    let revived = NodeAgent::spawn(
        bind_retry(dead_addr),
        dense_entry(model.clone(), enc()),
        enc(),
    )
    .unwrap();
    let heal_deadline = Instant::now() + Duration::from_secs(20);
    let mut seed = 9200;
    loop {
        // serving never pauses while the heal converges
        let served = submit_batch(&server, seq_len, 6, seed);
        assert_all_served(&served, &model, seq_len, "during heal");
        seed += 10;
        if server.metrics.node_health()[1].up {
            break;
        }
        assert!(
            Instant::now() < heal_deadline,
            "cluster did not heal within 20s of the agent restart: {:?}",
            server.metrics.node_health()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    // the healed slot serves shards again
    let healed = submit_batch(&server, seq_len, 6, seed);
    assert_all_served(&healed, &model, seq_len, "after heal");
    let health = server.metrics.node_health();
    assert!(health[1].reconnects >= 1, "{health:?}");
    assert!(
        server.metrics.node_transport()[1].shards > shards_at_kill,
        "the revived node's slot must carry new shard frames"
    );
    server.shutdown();
    revived.shutdown();
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn chaos_flapping_agent_heals_after_every_flap() {
    // kill/restart the same agent repeatedly at the cluster level: each
    // flap is masked by retry on the survivor, routes around, heals,
    // and the reconnect counter grows -- the drain invariant (correct
    // values right after every failure) holds through all of it.
    const CLASSES: usize = 3;
    let model = synth_model(CLASSES);
    let (mut agents, addrs) = spawn_agents(2, model.clone(), enc());
    let mut cluster = ShardCluster::connect_timeout(
        &addrs,
        enc(),
        Some(Duration::from_secs(5)),
    )
    .unwrap();
    cluster.set_reconnect_policy(ReconnectPolicy {
        base: Duration::from_millis(10),
        cap: Duration::from_millis(100),
        connect_timeout: Duration::from_millis(250),
        attempts_per_heal: 4,
        promote_after: Duration::from_secs(3600),
    });
    let m = Metrics::default();
    let mut agent1 = Some(agents.remove(1));
    for cycle in 0..3u64 {
        let seed = 9500 + cycle * 10;
        let t_ok = Tensor::random_sparse(vec![4, 3, 8, 25], 0.5, seed);
        let out = cluster
            .infer(&Payload::Dense(t_ok.clone()), Some(&m))
            .unwrap();
        assert_eq!(out, model(t_ok).unwrap(), "cycle {cycle}: healthy");
        // kill: the in-flight batch is retried on the survivor and
        // masked -- its caller sees correct logits, not an error
        agent1.take().unwrap().shutdown();
        let t_kill = Tensor::random_sparse(vec![4, 3, 8, 25], 0.5, seed + 1);
        let out = cluster
            .infer(&Payload::Dense(t_kill.clone()), Some(&m))
            .unwrap();
        assert_eq!(
            out,
            model(t_kill).unwrap(),
            "cycle {cycle}: kill-spanning batch masked"
        );
        assert_eq!(cluster.live_nodes(), 1, "cycle {cycle}");
        // ...and the next one is also correct on the survivor (the
        // masked batch drained; nothing stale shifts into this one)
        let t_survive =
            Tensor::random_sparse(vec![4, 3, 8, 25], 0.5, seed + 2);
        let out = cluster
            .infer(&Payload::Dense(t_survive.clone()), Some(&m))
            .unwrap();
        assert_eq!(
            out,
            model(t_survive).unwrap(),
            "cycle {cycle}: survivor"
        );
        // restart on the same address and wait for the heal
        agent1 = Some(
            NodeAgent::spawn(
                bind_retry(addrs[1]),
                dense_entry(model.clone(), enc()),
                enc(),
            )
            .unwrap(),
        );
        let deadline = Instant::now() + Duration::from_secs(10);
        while cluster.heal(Some(&m)) < 2 {
            assert!(
                Instant::now() < deadline,
                "cycle {cycle}: no heal within 10s"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        let t_healed =
            Tensor::random_sparse(vec![4, 3, 8, 25], 0.5, seed + 3);
        let out = cluster
            .infer(&Payload::Dense(t_healed.clone()), Some(&m))
            .unwrap();
        assert_eq!(out, model(t_healed).unwrap(), "cycle {cycle}: healed");
    }
    let health = m.node_health();
    assert!(
        health[1].reconnects >= 3,
        "one reconnect per flap: {health:?}"
    );
    assert!(
        m.shard_retries.load(std::sync::atomic::Ordering::Relaxed) >= 3,
        "one masking retry per flap"
    );
    cluster.shutdown();
    agent1.unwrap().shutdown();
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn chaos_overload_flood_over_tcp_sheds_then_serving_recovers() {
    // the bounded front door on the REAL socket path: TCP node agents
    // running a deliberately slow model, a flood far past admission
    // capacity.  Submits stay non-blocking, every caller is answered
    // (served / shed-with-retry_after / deadline-exceeded), and once
    // the flood drains the same server serves normally again.
    const CLASSES: usize = 4;
    let seq_len = 8;
    let row = 3 * seq_len * NUM_JOINTS;
    let model = synth_model(CLASSES);
    let slow: ShardFn = {
        let inner = model.clone();
        Arc::new(move |t: Tensor| {
            std::thread::sleep(Duration::from_millis(120));
            inner(t)
        })
    };
    let (agents, addrs) = spawn_agents(2, slow, enc());
    let admission = AdmissionPolicy {
        capacity: 4,
        max_queue_wait: Duration::from_millis(100),
        default_deadline: None,
    };
    let server = Server::connect_sharded_admitted(
        &addrs,
        policy(seq_len),
        admission,
        enc(),
        CLASSES,
    )
    .unwrap();

    let n = 40; // 10x capacity
    let clip = Tensor::random_sparse(vec![row], 0.5, 7700).data;
    let flood_started = Instant::now();
    let rxs: Vec<_> = (0..n).map(|_| server.submit(clip.clone())).collect();
    assert!(
        flood_started.elapsed() < Duration::from_secs(2),
        "submit blocked under TCP overload: {:?}",
        flood_started.elapsed()
    );
    let (mut ok, mut shed, mut expired) = (0usize, 0usize, 0usize);
    for rx in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("every flooded caller answered");
        if resp.is_ok() {
            ok += 1;
        } else if resp.is_shed() {
            assert_eq!(resp.retry_after, Some(Duration::from_millis(100)));
            shed += 1;
        } else {
            assert!(
                resp.error
                    .as_deref()
                    .unwrap_or("")
                    .contains("deadline exceeded"),
                "{:?}",
                resp.error
            );
            expired += 1;
        }
    }
    assert_eq!(ok + shed + expired, n, "answers partition the flood");
    assert!(shed > 0, "a 10x-capacity flood must shed");
    assert!(
        server
            .metrics
            .shed
            .load(std::sync::atomic::Ordering::Relaxed)
            >= shed as u64
    );
    // the overload is over: the same server serves correctly again
    let recovered = submit_batch(&server, seq_len, 2, 7710);
    for (i, (clip, resp)) in recovered.iter().enumerate() {
        assert!(resp.is_ok(), "post-flood clip {i}: {:?}", resp.error);
        let t = Tensor::new(vec![1, 3, seq_len, NUM_JOINTS], clip.clone())
            .unwrap();
        assert_eq!(resp.logits, model(t).unwrap().data, "post-flood clip {i}");
    }
    server.shutdown();
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn blackholed_peer_connect_is_bounded_by_the_timeout() {
    // 240.0.0.1 (class E, never routed): a SYN into the void, no RST
    // ever.  The old plain `TcpStream::connect` dial hung for the OS
    // default -- minutes -- before the I/O timeouts even applied; the
    // dial itself must be bounded now.  (Some sandboxes answer with an
    // immediate network-unreachable error instead of blackholing; the
    // bound holds either way.)
    let start = Instant::now();
    let result =
        TcpLink::connect_timeout("240.0.0.1:9", Some(Duration::from_millis(500)));
    assert!(result.is_err(), "a blackholed peer must not connect");
    assert!(
        start.elapsed() < Duration::from_secs(30),
        "dial took {:?}: connect is not bounded by the io timeout",
        start.elapsed()
    );
}

#[test]
fn hung_peer_trips_the_io_timeout_and_poisons_the_link() {
    use rfc_hypgcn::coordinator::NodeLink;
    // a peer that handshakes, swallows our frame, and then goes silent
    // forever -- no RST, no FIN, just nothing.  Without an I/O timeout
    // the coordinator would block in recv permanently.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let hung = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut hs = Vec::new();
        hs.extend_from_slice(&wire::HANDSHAKE_MAGIC);
        hs.extend_from_slice(&wire::WIRE_VERSION.to_le_bytes());
        s.write_all(&hs).unwrap();
        let mut theirs = [0u8; 6];
        s.read_exact(&mut theirs).unwrap();
        // drain whatever arrives, reply with nothing; exits when the
        // poisoned link severs the socket
        let mut sink = [0u8; 1024];
        loop {
            match s.read(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    });
    // generous enough that the handshake never trips it on a loaded
    // machine; the silent peer still deterministically times out recv
    let mut link =
        TcpLink::connect_timeout(addr, Some(Duration::from_millis(500)))
            .unwrap();
    link.send(wire::error_frame("ping")).unwrap();
    let err = link.recv().unwrap_err();
    assert!(format!("{err:#}").contains("receiving from node"), "{err:#}");
    // the failure poisoned the link: it is dead, not desynchronized --
    // a late reply can never be read as the next batch's answer
    assert!(
        link.send(wire::error_frame("again")).is_err(),
        "poisoned link must refuse further traffic"
    );
    hung.join().unwrap();
}

#[test]
fn version_skew_on_handshake_is_rejected() {
    // a fake "node" speaking wire v2: the coordinator link must refuse
    // it at connect, naming both versions
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut hs = Vec::new();
        hs.extend_from_slice(&wire::HANDSHAKE_MAGIC);
        hs.extend_from_slice(&2u16.to_le_bytes());
        s.write_all(&hs).unwrap();
        let mut theirs = [0u8; 6];
        let _ = s.read_exact(&mut theirs);
    });
    let err = TcpLink::connect(addr).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("v2") && msg.contains("v1"), "{msg}");
    fake.join().unwrap();
}

#[test]
fn non_rfc_peer_is_rejected_at_handshake() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        s.write_all(b"HTTP/1.1 200 OK\r\n\r\n").unwrap();
    });
    let err = TcpLink::connect(addr).unwrap_err();
    assert!(format!("{err:#}").contains("handshake"), "{err:#}");
    fake.join().unwrap();
}

#[test]
fn node_agent_rejects_skewed_coordinators_but_keeps_accepting() {
    let (agents, addrs) = spawn_agents(1, synth_model(3), enc());
    // a skewed "coordinator": handshake names wire v9
    {
        let mut s = TcpStream::connect(addrs[0]).unwrap();
        let mut hs = Vec::new();
        hs.extend_from_slice(&wire::HANDSHAKE_MAGIC);
        hs.extend_from_slice(&9u16.to_le_bytes());
        s.write_all(&hs).unwrap();
        // the node sends its own handshake, then drops the connection
        // instead of serving frames
        let mut theirs = [0u8; 6];
        s.read_exact(&mut theirs).unwrap();
        assert_eq!(&theirs[..4], &wire::HANDSHAKE_MAGIC);
        let mut probe = [0u8; 1];
        let n = s.read(&mut probe);
        assert!(
            matches!(n, Ok(0) | Err(_)),
            "connection must close, got {n:?}"
        );
    }
    // the agent still serves well-behaved coordinators afterwards
    let mut cluster = ShardCluster::connect(&addrs, enc()).unwrap();
    let t = Tensor::random_sparse(vec![2, 3, 8, 25], 0.5, 7400);
    let out = cluster.infer(&Payload::Dense(t.clone()), None).unwrap();
    assert_eq!(out, synth_model(3)(t).unwrap());
    cluster.shutdown();
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn oversized_outer_frame_drops_the_connection_not_the_agent() {
    let (agents, addrs) = spawn_agents(1, synth_model(3), enc());
    {
        let mut s = TcpStream::connect(addrs[0]).unwrap();
        let mut hs = Vec::new();
        hs.extend_from_slice(&wire::HANDSHAKE_MAGIC);
        hs.extend_from_slice(&wire::WIRE_VERSION.to_le_bytes());
        s.write_all(&hs).unwrap();
        let mut theirs = [0u8; 6];
        s.read_exact(&mut theirs).unwrap();
        // a hostile length prefix: 4 GiB frame announcement
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        s.write_all(b"junk").unwrap();
        // the node must sever this connection (and must not try to
        // allocate the announced 4 GiB).  The unread junk in the node's
        // receive buffer makes the close an RST on most stacks, so both
        // EOF and a reset error count as "closed"
        let mut probe = [0u8; 1];
        let n = s.read(&mut probe);
        assert!(
            matches!(n, Ok(0) | Err(_)),
            "connection must close, got {n:?}"
        );
    }
    // fresh connections still serve
    let mut cluster = ShardCluster::connect(&addrs, enc()).unwrap();
    let t = Tensor::random_sparse(vec![2, 3, 8, 25], 0.5, 7500);
    let out = cluster.infer(&Payload::Dense(t.clone()), None).unwrap();
    assert_eq!(out, synth_model(3)(t).unwrap());
    cluster.shutdown();
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn garbage_inner_frame_gets_an_error_reply_and_the_connection_survives() {
    // broken *framing* kills a connection; a broken *payload* inside a
    // well-formed outer frame is an application error -- the node
    // replies with an error frame and keeps serving the same link
    let (agents, addrs) = spawn_agents(1, synth_model(3), enc());
    let mut link = TcpLink::connect(addrs[0]).unwrap();
    use rfc_hypgcn::coordinator::NodeLink;
    link.send(b"definitely not a payload frame".to_vec()).unwrap();
    let reply = link.recv().unwrap();
    let err = wire::payload_from_bytes(&reply).unwrap_err();
    assert!(format!("{err:#}").contains("remote node error"), "{err:#}");
    // same connection, now a valid shard frame: served normally
    let t = Tensor::random_sparse(vec![2, 3, 8, 25], 0.6, 7600);
    let frame = wire::payload_to_bytes(&Payload::Dense(t.clone())).unwrap();
    link.send(frame).unwrap();
    let reply = link.recv().unwrap();
    let payload = wire::payload_from_bytes(&reply).unwrap();
    assert_eq!(payload.into_dense(&enc()), synth_model(3)(t).unwrap());
    drop(link);
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn chaos_retry_kill_mid_batch_is_masked_from_callers() {
    // the fault-masking acceptance scenario: 3 TCP agents under
    // sustained full batches, one killed mid-stream.  The batch in
    // flight across the kill is retried on the survivors -- every
    // caller gets a bit-exact ok answer, `shard_retries` counts the
    // re-dispatches, and the drain invariant holds through every
    // later batch.  The killed slot stays Down the whole time (long
    // reconnect backoff), so nothing below is a lucky heal.
    const CLASSES: usize = 4;
    let seq_len = 8;
    let model = synth_model(CLASSES);
    let (mut agents, addrs) = spawn_agents(3, model.clone(), enc());
    let batch_policy = BatchPolicy {
        batch_size: 6,
        max_wait: Duration::from_millis(250),
        seq_len,
    };
    let mut cluster = ShardCluster::connect_timeout(
        &addrs,
        enc(),
        Some(Duration::from_secs(5)),
    )
    .unwrap();
    cluster.set_reconnect_policy(ReconnectPolicy {
        base: Duration::from_secs(3600),
        cap: Duration::from_secs(3600),
        connect_timeout: Duration::from_millis(250),
        attempts_per_heal: 2,
        promote_after: Duration::from_secs(3600),
    });
    let server = Server::start_cluster(batch_policy, enc(), cluster, CLASSES);

    // sustained load before the kill
    for round in 0..2u64 {
        let served = submit_batch(&server, seq_len, 6, 9600 + round * 10);
        assert_all_served(
            &served,
            &model,
            seq_len,
            &format!("pre-kill round {round}"),
        );
    }
    agents.remove(1).shutdown();
    // the kill-spanning batch: masked, not failed
    let masked = submit_batch(&server, seq_len, 6, 9650);
    assert_all_served(&masked, &model, seq_len, "kill-spanning batch");
    use std::sync::atomic::Ordering;
    assert!(
        server.metrics.shard_retries.load(Ordering::Relaxed) > 0,
        "masking must go through the retry path"
    );
    assert!(
        !server.metrics.node_health()[1].up,
        "the killed slot is Down: {:?}",
        server.metrics.node_health()
    );
    // sustained load after the kill: the drain invariant held across
    // every retry attempt, so nothing stale shifts into these batches
    for round in 0..3u64 {
        let served = submit_batch(&server, seq_len, 6, 9700 + round * 10);
        assert_all_served(
            &served,
            &model,
            seq_len,
            &format!("post-kill round {round}"),
        );
    }
    // the survivors absorbed the lost shard: per-slot attempt accounting
    let nt = server.metrics.node_transport();
    assert!(
        nt[0].retries + nt[2].retries >= 1,
        "a survivor carried the re-dispatched shard: {nt:?}"
    );
    server.shutdown();
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn chaos_retry_expired_batch_gets_deadline_answers_with_zero_retries() {
    // deadline-bounded recovery, server level: a batch whose requests
    // expire before the cluster can serve it gets honest
    // deadline-exceeded answers with ZERO shard dispatches or retries
    // -- late work for a caller that already gave up is never bought.
    const CLASSES: usize = 4;
    let seq_len = 8;
    let row = 3 * seq_len * NUM_JOINTS;
    let model = synth_model(CLASSES);
    // every shard takes 500ms, far past the 150ms request deadlines
    let slow: ShardFn = {
        let inner = model.clone();
        Arc::new(move |t: Tensor| {
            std::thread::sleep(Duration::from_millis(500));
            inner(t)
        })
    };
    let (agents, addrs) = spawn_agents(2, slow, enc());
    let admission = AdmissionPolicy {
        capacity: 16,
        max_queue_wait: Duration::from_millis(100),
        default_deadline: None,
    };
    let server = Server::connect_sharded_admitted(
        &addrs,
        policy(seq_len),
        admission,
        enc(),
        CLASSES,
    )
    .unwrap();
    // a deadline-less warm request occupies the cluster for 500ms...
    let warm_clip = Tensor::random_sparse(vec![row], 0.5, 9790).data;
    let warm_rx = server.submit(warm_clip);
    // (let it form its own batch before the deadlined ones arrive)
    std::thread::sleep(Duration::from_millis(25));
    // ...so these 150ms-deadline requests are long expired by the time
    // their batch could dispatch: whether the batcher reaps them at
    // formation or the cluster refuses the expired batch at dispatch,
    // no shard is ever shipped or retried for them
    let rxs: Vec<_> = (0..4)
        .map(|i| {
            server.submit_with_deadline(
                Tensor::random_sparse(vec![row], 0.5, 9800 + i).data,
                Some(Duration::from_millis(150)),
            )
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(!resp.is_ok(), "an expired request must not be answered ok");
        assert!(
            resp.error
                .as_deref()
                .unwrap_or("")
                .contains("deadline exceeded"),
            "{:?}",
            resp.error
        );
    }
    use std::sync::atomic::Ordering;
    assert_eq!(
        server.metrics.shard_retries.load(Ordering::Relaxed),
        0,
        "an expired batch must never be retried"
    );
    assert!(
        server.metrics.expired.load(Ordering::Relaxed) >= 4,
        "every expired caller counted"
    );
    // the warm request was never at risk
    let warm = warm_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert!(warm.is_ok(), "{:?}", warm.error);
    server.shutdown();
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn chaos_retry_in_flight_expiry_refuses_the_retry() {
    // deadline-bounded recovery, cluster level: a shard lost to a node
    // death mid-batch is NOT re-dispatched when the batch deadline has
    // already passed by the time the round resolves -- the error names
    // the refusal and `shard_retries` stays at zero.
    const CLASSES: usize = 4;
    let model = synth_model(CLASSES);
    // the survivor holds its shard for 300ms, so the 100ms batch
    // deadline is always spent before the lost shard could be retried
    let slow: ShardFn = {
        let inner = model.clone();
        Arc::new(move |t: Tensor| {
            std::thread::sleep(Duration::from_millis(300));
            inner(t)
        })
    };
    let (mut agents, addrs) = spawn_agents(2, slow, enc());
    let mut cluster = ShardCluster::connect_timeout(
        &addrs,
        enc(),
        Some(Duration::from_secs(5)),
    )
    .unwrap();
    let m = Metrics::default();
    agents.remove(1).shutdown();
    let t = Tensor::random_sparse(vec![4, 3, 8, 25], 0.5, 9850);
    let deadline = Instant::now() + Duration::from_millis(100);
    let err = cluster
        .infer_deadline(2, &Payload::Dense(t), Some(deadline), Some(&m))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("retries refused"), "{msg}");
    use std::sync::atomic::Ordering;
    assert_eq!(
        m.shard_retries.load(Ordering::Relaxed),
        0,
        "no retry may dispatch past the deadline"
    );
    cluster.shutdown();
    for a in agents {
        a.shutdown();
    }
}

#[test]
fn chaos_standby_down_slot_promotes_to_standby_and_serves() {
    // ROADMAP (d): a slot whose primary stays Down past promote_after
    // is promoted to its standby address by heal -- no coordinator
    // restart -- and the promoted node serves subsequent shards.
    const CLASSES: usize = 4;
    let model = synth_model(CLASSES);
    let (mut agents, addrs) = spawn_agents(3, model.clone(), enc());
    // slot 0: plain primary; slot 1: primary with agent 2 standing by
    let specs = vec![
        NodeSpec::with_standbys(vec![addrs[0]], Vec::new()),
        NodeSpec::with_standbys(vec![addrs[1]], vec![addrs[2]]),
    ];
    let mut cluster = ShardCluster::connect_specs(
        &specs,
        enc(),
        Some(Duration::from_secs(5)),
    )
    .unwrap();
    cluster.set_reconnect_policy(ReconnectPolicy {
        base: Duration::from_millis(10),
        cap: Duration::from_millis(50),
        connect_timeout: Duration::from_millis(250),
        attempts_per_heal: 2,
        promote_after: Duration::from_millis(100),
    });
    let m = Metrics::default();
    cluster.publish_health(&m);
    // kill slot 1's PRIMARY for good (the standby agent stays up)
    agents.remove(1).shutdown();
    // the kill-spanning batch is masked by retry on slot 0
    let t = Tensor::random_sparse(vec![4, 3, 8, 25], 0.5, 9900);
    let out = cluster.infer(&Payload::Dense(t.clone()), Some(&m)).unwrap();
    assert_eq!(out, model(t).unwrap(), "kill-spanning batch masked");
    assert_eq!(cluster.live_nodes(), 1);
    // past promote_after, heal dials the standby and promotes it into
    // the slot; serving keeps working while the promotion converges
    let deadline = Instant::now() + Duration::from_secs(10);
    while cluster.live_nodes() < 2 {
        assert!(
            Instant::now() < deadline,
            "no standby promotion within 10s: {:?}",
            m.node_health()
        );
        let t_during =
            Tensor::random_sparse(vec![4, 3, 8, 25], 0.5, 9905);
        let out = cluster
            .infer(&Payload::Dense(t_during.clone()), Some(&m))
            .unwrap();
        assert_eq!(out, model(t_during).unwrap(), "serving during promotion");
        std::thread::sleep(Duration::from_millis(20));
    }
    use std::sync::atomic::Ordering;
    assert_eq!(
        m.standby_promotions.load(Ordering::Relaxed),
        1,
        "exactly one promotion"
    );
    let health = m.node_health();
    assert!(health[1].up, "{health:?}");
    assert_eq!(health[1].promotions, 1, "{health:?}");
    assert_eq!(
        health[1].label,
        addrs[2].to_string(),
        "slot 1 now points at the standby: {health:?}"
    );
    // the promoted slot serves shards: a fresh batch fans over both
    let shards_before =
        m.node_transport().get(1).map(|t| t.shards).unwrap_or(0);
    let t2 = Tensor::random_sparse(vec![4, 3, 8, 25], 0.5, 9910);
    let out = cluster.infer(&Payload::Dense(t2.clone()), Some(&m)).unwrap();
    assert_eq!(out, model(t2).unwrap(), "promoted slot serving");
    assert!(
        m.node_transport()[1].shards > shards_before,
        "the promoted slot must carry shard frames"
    );
    cluster.shutdown();
    for a in agents {
        a.shutdown();
    }
}
