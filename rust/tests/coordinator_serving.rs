//! Coordinator end-to-end: submit clips, get classified responses, with
//! batching and latency accounting intact -- on the in-process stage
//! pipeline and on multi-node loopback shard clusters.
//!
//! Quarantine note: the tests that need the AOT artifacts are
//! `#[ignore]`d unless the `aot-artifacts` feature is on (tracking: the
//! gates go away once artifact export runs in CI).  The shard-cluster
//! stream tests run a synthetic row-local model and need no artifacts.

use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use rfc_hypgcn::coordinator::{
    dense_entry, spawn_local_agents, AdmissionPolicy, BatchPolicy, Batcher,
    Metrics, NodeAgent, Request, Response, Server, ShardCluster, ShardFn,
};
use rfc_hypgcn::data::{GenConfig, SkeletonGen};
use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::model::NUM_JOINTS;
use rfc_hypgcn::rfc::EncoderConfig;
use rfc_hypgcn::runtime::{Engine, Tensor};

fn setup() -> Option<(Manifest, Engine)> {
    let dir = Manifest::default_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some((Manifest::load(&dir).unwrap(), Engine::cpu().unwrap()))
}

/// The cluster conformance axis: every shard-cluster test runs against
/// both the in-process loopback link and localhost TCP node agents.
const TRANSPORTS: [&str; 2] = ["loopback", "tcp"];

fn cluster_on(
    transport: &str,
    nodes: usize,
    model: ShardFn,
    enc: EncoderConfig,
) -> (ShardCluster, Vec<NodeAgent>) {
    match transport {
        "loopback" => (ShardCluster::loopback(nodes, model, enc), Vec::new()),
        "tcp" => {
            let (agents, addrs) =
                spawn_local_agents(nodes, dense_entry(model, enc), enc)
                    .unwrap();
            (ShardCluster::connect(&addrs, enc).unwrap(), agents)
        }
        t => panic!("unknown transport {t}"),
    }
}

/// Deterministic row-local synthetic classifier (stands in for the full
/// stage chain; row-locality is the same contract the real pipeline has
/// on the batch axis): logits[r][c] = sum_i row[i] * ((i + c) % 7).
fn synth_model(classes: usize) -> ShardFn {
    Arc::new(move |t: Tensor| {
        anyhow::ensure!(t.shape.len() >= 2, "need a batch axis");
        let rows = t.shape[0];
        let row: usize = t.shape[1..].iter().product();
        let mut out = vec![0f32; rows * classes];
        for r in 0..rows {
            let src = &t.data[r * row..(r + 1) * row];
            for (c, slot) in
                out[r * classes..(r + 1) * classes].iter_mut().enumerate()
            {
                *slot = src
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| v * (((i + c) % 7) as f32))
                    .sum();
            }
        }
        Tensor::new(vec![rows, classes], out)
    })
}

#[test]
fn loopback_cluster_serves_stream_identical_to_single_node() {
    // a stream of sparse skeleton clips through the real batcher, served
    // by 2- and 4-shard loopback clusters: responses must be identical
    // to the single-node path (the model applied to each clip directly),
    // and Metrics must report per-node transport savings.
    const CLASSES: usize = 10;
    let seq_len = 8;
    let row = 3 * seq_len * NUM_JOINTS;
    let policy = BatchPolicy {
        batch_size: 4,
        max_wait: Duration::from_millis(1),
        seq_len,
    };
    let enc = EncoderConfig {
        shards: 1,
        min_sparsity: 0.10,
        parallel_threshold: usize::MAX,
    };
    let model = synth_model(CLASSES);
    let clips: Vec<Vec<f32>> = (0..13)
        .map(|i| Tensor::random_sparse(vec![row], 0.7, 4000 + i).data)
        .collect();
    // the single-node path: the model applied to each clip on its own
    let expected: Vec<Vec<f32>> = clips
        .iter()
        .map(|c| {
            let t =
                Tensor::new(vec![1, 3, seq_len, NUM_JOINTS], c.clone()).unwrap();
            model(t).unwrap().data
        })
        .collect();

    for transport in TRANSPORTS {
        for nodes in [2usize, 4] {
            let metrics = Metrics::default();
            let (mut cluster, agents) =
                cluster_on(transport, nodes, model.clone(), enc);
            let mut rxs = Vec::new();
            let mut pending: Vec<Request> = clips
                .iter()
                .enumerate()
                .map(|(i, clip)| {
                    let (tx, rx) = channel::<Response>();
                    rxs.push(rx);
                    Request {
                        id: i as u64,
                        clip: clip.clone(),
                        seq_len,
                        arrived: Instant::now(),
                        deadline: None,
                        reply: tx,
                    }
                })
                .collect();
            // drain the stream in batcher-formed batches (the last one
            // is 1 real row + 3 padding rows), like the sharded server
            while !pending.is_empty() {
                let take = pending.len().min(policy.batch_size);
                let reqs: Vec<Request> = pending.drain(..take).collect();
                let mut batch = Batcher::form_from(&policy, reqs).unwrap();
                metrics.record_batch(batch.real, batch.input.shape()[0]);
                let payload = batch.input.take();
                let logits = cluster.infer(&payload, Some(&metrics)).unwrap();
                assert_eq!(logits.shape, vec![policy.batch_size, CLASSES]);
                for (i, req) in batch.requests.into_iter().enumerate() {
                    let rowv =
                        logits.data[i * CLASSES..(i + 1) * CLASSES].to_vec();
                    let resp =
                        Response::from_logits(req.id, rowv, req.arrived);
                    metrics.record_response(resp.latency_s);
                    req.reply.send(resp).unwrap();
                }
            }
            cluster.shutdown();
            for a in agents {
                a.shutdown();
            }
            for (i, rx) in rxs.iter().enumerate() {
                let resp = rx.try_recv().expect("response delivered");
                assert_eq!(resp.id, i as u64, "{transport}: {nodes} nodes");
                assert_eq!(
                    resp.logits, expected[i],
                    "{transport}: {nodes} nodes: clip {i} diverged from \
                     single-node"
                );
            }
            // every node that saw work must report transport savings:
            // the 70%-sparse shards ship far below their dense cost
            let per_node = metrics.node_transport();
            assert_eq!(
                per_node.len(),
                nodes,
                "{transport}: {nodes} nodes all saw work"
            );
            for (n, t) in per_node.iter().enumerate() {
                assert!(t.shards > 0, "{transport}: node {n} idle");
                assert!(
                    metrics.node_transport_saving(n) > 0.1,
                    "{transport}: node {n} saving {}",
                    metrics.node_transport_saving(n)
                );
            }
            assert!(metrics.report().contains("node_save=["));
        }
    }
}

#[test]
fn cluster_output_independent_of_node_count() {
    // 1-, 2-, 3- and 4-node clusters agree bit-for-bit on a batch that
    // does not divide evenly, over both transports
    let t = Tensor::random_sparse(vec![6, 3, 8, 25], 0.5, 4100);
    let enc = EncoderConfig {
        shards: 1,
        min_sparsity: 0.0,
        parallel_threshold: usize::MAX,
    };
    let model = synth_model(7);
    let reference = model(t.clone()).unwrap();
    for transport in TRANSPORTS {
        for nodes in [1usize, 2, 3, 4] {
            let (mut cluster, agents) =
                cluster_on(transport, nodes, model.clone(), enc);
            let out = cluster
                .infer(&rfc_hypgcn::rfc::Payload::Dense(t.clone()), None)
                .unwrap();
            assert_eq!(out, reference, "{transport}: {nodes} nodes");
            cluster.shutdown();
            for a in agents {
                a.shutdown();
            }
        }
    }
}

/// [`synth_model`] slowed down per batch call: the deterministic way to
/// pin the pipeline while the admission queue backs up.
fn slow_model(classes: usize, delay: Duration) -> ShardFn {
    let inner = synth_model(classes);
    Arc::new(move |t: Tensor| {
        std::thread::sleep(delay);
        inner(t)
    })
}

#[test]
fn overload_flood_sheds_expires_and_answers_every_caller() {
    // the front-door acceptance scenario: capacity C, a pipeline slower
    // than the arrival rate, a 10xC flood.  Submits never block, every
    // reply channel gets exactly one answer (served, shed-with-
    // retry_after, or deadline-exceeded), no batch slot carries an
    // expired request, and the overload is visible in Metrics.
    const CLASSES: usize = 6;
    let seq_len = 8;
    let row = 3 * seq_len * NUM_JOINTS;
    let policy = BatchPolicy {
        batch_size: 4,
        max_wait: Duration::from_millis(1),
        seq_len,
    };
    let enc = EncoderConfig {
        shards: 1,
        min_sparsity: 0.10,
        parallel_threshold: usize::MAX,
    };
    let admission = AdmissionPolicy {
        capacity: 8,
        max_queue_wait: Duration::from_millis(100),
        default_deadline: None,
    };
    let cluster = ShardCluster::loopback(
        2,
        slow_model(CLASSES, Duration::from_millis(150)),
        enc,
    );
    let server =
        Server::start_cluster_admitted(policy, admission, enc, cluster, CLASSES);

    let n = 80; // 10x admission capacity
    let clip = vec![0.25f32; row];
    let flood_started = Instant::now();
    let rxs: Vec<_> = (0..n).map(|_| server.submit(clip.clone())).collect();
    let flood = flood_started.elapsed();
    assert!(
        flood < Duration::from_secs(2),
        "submit must never block under overload: flood took {flood:?}"
    );

    let (mut ok, mut shed, mut expired) = (0usize, 0usize, 0usize);
    for rx in &rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("every flooded caller gets an answer");
        if resp.is_ok() {
            assert_eq!(resp.logits.len(), CLASSES);
            ok += 1;
        } else if resp.is_shed() {
            assert_eq!(
                resp.retry_after,
                Some(Duration::from_millis(100)),
                "shed answers carry the queue-residency bound as retry_after"
            );
            shed += 1;
        } else {
            let msg = resp.error.as_deref().unwrap_or("");
            assert!(
                msg.contains("deadline exceeded"),
                "only shed / deadline failures expected, got {msg:?}"
            );
            expired += 1;
        }
    }
    assert_eq!(ok + shed + expired, n, "answers partition the flood exactly");
    assert!(ok > 0, "the server kept serving under overload");
    assert!(shed > 0, "a 10x-capacity flood must shed at the gate");
    assert!(expired > 0, "queued requests outlived the residency bound");

    let m = &server.metrics;
    assert_eq!(m.shed.load(Ordering::Relaxed), shed as u64);
    assert_eq!(m.expired.load(Ordering::Relaxed), expired as u64);
    assert_eq!(m.responses_out.load(Ordering::Relaxed), ok as u64);
    // no batch slot carried an expired request: every real row formed
    // into a batch was delivered as a served response
    assert_eq!(m.real_rows.load(Ordering::Relaxed), ok as u64);
    assert_eq!(
        m.queue_depth.load(Ordering::Relaxed),
        0,
        "intake gauge returns to zero once the flood is answered"
    );
    let report = m.report();
    assert!(report.contains("shed="), "{report}");
    assert!(report.contains("expired="), "{report}");
    server.shutdown();
}

#[test]
fn overload_shutdown_answers_every_queued_request() {
    // shutdown during overload: the batcher drains the admission queue
    // with shutdown errors -- no queued reply channel is silently
    // dropped (the pre-fix behavior) and none is left to serve.
    const CLASSES: usize = 5;
    let seq_len = 8;
    let row = 3 * seq_len * NUM_JOINTS;
    let policy = BatchPolicy {
        batch_size: 4,
        max_wait: Duration::from_millis(1),
        seq_len,
    };
    let enc = EncoderConfig {
        shards: 1,
        min_sparsity: 0.10,
        parallel_threshold: usize::MAX,
    };
    let admission = AdmissionPolicy {
        capacity: 64,
        max_queue_wait: Duration::from_secs(30),
        default_deadline: None,
    };
    let cluster = ShardCluster::loopback(
        2,
        slow_model(CLASSES, Duration::from_millis(200)),
        enc,
    );
    let server =
        Server::start_cluster_admitted(policy, admission, enc, cluster, CLASSES);
    let metrics = server.metrics.clone();
    let clip = vec![0.5f32; row];
    let n = 12;
    let rxs: Vec<_> = (0..n).map(|_| server.submit(clip.clone())).collect();
    server.shutdown(); // joins every thread: all answers are in by now

    let (mut served, mut refused) = (0usize, 0usize);
    for rx in rxs {
        let resp = rx.try_recv().expect(
            "shutdown answers every queued request (pre-fix the reply \
             channels were dropped silently)",
        );
        if resp.is_ok() {
            served += 1;
        } else {
            assert!(
                resp.error
                    .as_deref()
                    .unwrap_or("")
                    .contains("shutting down"),
                "{:?}",
                resp.error
            );
            refused += 1;
        }
    }
    assert_eq!(served + refused, n);
    assert!(
        refused > 0,
        "requests queued behind the in-flight batch get shutdown errors"
    );
    assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 0);
    assert!(metrics.failures.load(Ordering::Relaxed) >= refused as u64);
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn serves_all_requests() {
    let Some((m, engine)) = setup() else { return };
    let server = Server::start(
        &engine,
        &m,
        BatchPolicy {
            batch_size: m.batch,
            max_wait: Duration::from_millis(10),
            seq_len: m.seq_len,
        },
    )
    .unwrap();
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: m.num_classes,
            seq_len: m.seq_len,
            noise: 0.02,
        },
        1,
    );
    let n = m.batch * 3 + 1; // force a padded final batch
    let rxs: Vec<_> = (0..n)
        .map(|_| server.submit(gen.sample().0))
        .collect();
    let mut answered = 0;
    for rx in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("response within deadline");
        assert_eq!(resp.logits.len(), m.num_classes);
        assert!(resp.predicted < m.num_classes);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        assert!(resp.latency_s >= 0.0);
        answered += 1;
    }
    assert_eq!(answered, n);
    assert_eq!(
        server
            .metrics
            .responses_out
            .load(std::sync::atomic::Ordering::Relaxed),
        n as u64
    );
    // at least one padded batch happened
    assert!(server.metrics.padding_fraction() > 0.0);
    server.shutdown();
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn distinct_requests_get_distinct_ids_and_logits_rows() {
    let Some((m, engine)) = setup() else { return };
    let server = Server::start(
        &engine,
        &m,
        BatchPolicy {
            batch_size: m.batch,
            max_wait: Duration::from_millis(5),
            seq_len: m.seq_len,
        },
    )
    .unwrap();
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: m.num_classes,
            seq_len: m.seq_len,
            noise: 0.02,
        },
        2,
    );
    let a = server.submit(gen.sample().0);
    let b = server.submit(gen.sample().0);
    let ra = a.recv_timeout(Duration::from_secs(120)).unwrap();
    let rb = b.recv_timeout(Duration::from_secs(120)).unwrap();
    assert_ne!(ra.id, rb.id);
    assert_ne!(ra.logits, rb.logits, "distinct clips, distinct logits");
    server.shutdown();
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn sharded_server_matches_single_node_server() {
    let Some((m, engine)) = setup() else { return };
    let policy = BatchPolicy {
        batch_size: m.batch,
        max_wait: Duration::from_millis(50),
        seq_len: m.seq_len,
    };
    let single = Server::start(&engine, &m, policy.clone()).unwrap();
    let sharded = Server::start_sharded(
        &engine,
        &m,
        policy,
        EncoderConfig::default(),
        4,
    )
    .unwrap();
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: m.num_classes,
            seq_len: m.seq_len,
            noise: 0.02,
        },
        4,
    );
    // exactly one full batch each, so batch composition is identical
    let clips: Vec<Vec<f32>> = (0..m.batch).map(|_| gen.sample().0).collect();
    let a: Vec<_> = clips.iter().map(|c| single.submit(c.clone())).collect();
    let b: Vec<_> = clips.iter().map(|c| sharded.submit(c.clone())).collect();
    for (i, (ra, rb)) in a.into_iter().zip(b).enumerate() {
        let ra = ra.recv_timeout(Duration::from_secs(120)).unwrap();
        let rb = rb.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(
            ra.logits, rb.logits,
            "clip {i}: sharded serving diverged from single-node"
        );
        assert_eq!(ra.predicted, rb.predicted);
    }
    // the sharded path recorded per-node wire traffic
    let nodes = sharded.metrics.node_transport();
    assert!(!nodes.is_empty());
    assert!(nodes.iter().any(|n| n.shards > 0));
    single.shutdown();
    sharded.shutdown();
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn throughput_metrics_populate() {
    let Some((m, engine)) = setup() else { return };
    let server = Server::start(
        &engine,
        &m,
        BatchPolicy {
            batch_size: m.batch,
            max_wait: Duration::from_millis(5),
            seq_len: m.seq_len,
        },
    )
    .unwrap();
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: m.num_classes,
            seq_len: m.seq_len,
            noise: 0.02,
        },
        3,
    );
    let rxs: Vec<_> = (0..m.batch * 2)
        .map(|_| server.submit(gen.sample().0))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(120)).unwrap();
    }
    assert!(server.metrics.throughput_fps() > 0.0);
    let lat = server.metrics.latency_summary();
    assert_eq!(lat.n, m.batch * 2);
    assert!(lat.p99_s >= lat.p50_s);
    server.shutdown();
}
