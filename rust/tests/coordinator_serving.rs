//! Coordinator end-to-end: submit clips, get classified responses, with
//! batching and latency accounting intact.
//!
//! Quarantine note: these tests need the AOT artifacts, so they are
//! `#[ignore]`d unless the `aot-artifacts` feature is on (tracking: the
//! gates go away once artifact export runs in CI).

use std::time::Duration;

use rfc_hypgcn::coordinator::{BatchPolicy, Server};
use rfc_hypgcn::data::{GenConfig, SkeletonGen};
use rfc_hypgcn::meta::Manifest;
use rfc_hypgcn::runtime::Engine;

fn setup() -> Option<(Manifest, Engine)> {
    let dir = Manifest::default_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some((Manifest::load(&dir).unwrap(), Engine::cpu().unwrap()))
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn serves_all_requests() {
    let Some((m, engine)) = setup() else { return };
    let server = Server::start(
        &engine,
        &m,
        BatchPolicy {
            batch_size: m.batch,
            max_wait: Duration::from_millis(10),
            seq_len: m.seq_len,
        },
    )
    .unwrap();
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: m.num_classes,
            seq_len: m.seq_len,
            noise: 0.02,
        },
        1,
    );
    let n = m.batch * 3 + 1; // force a padded final batch
    let rxs: Vec<_> = (0..n)
        .map(|_| server.submit(gen.sample().0))
        .collect();
    let mut answered = 0;
    for rx in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("response within deadline");
        assert_eq!(resp.logits.len(), m.num_classes);
        assert!(resp.predicted < m.num_classes);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        assert!(resp.latency_s >= 0.0);
        answered += 1;
    }
    assert_eq!(answered, n);
    assert_eq!(
        server
            .metrics
            .responses_out
            .load(std::sync::atomic::Ordering::Relaxed),
        n as u64
    );
    // at least one padded batch happened
    assert!(server.metrics.padding_fraction() > 0.0);
    server.shutdown();
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn distinct_requests_get_distinct_ids_and_logits_rows() {
    let Some((m, engine)) = setup() else { return };
    let server = Server::start(
        &engine,
        &m,
        BatchPolicy {
            batch_size: m.batch,
            max_wait: Duration::from_millis(5),
            seq_len: m.seq_len,
        },
    )
    .unwrap();
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: m.num_classes,
            seq_len: m.seq_len,
            noise: 0.02,
        },
        2,
    );
    let a = server.submit(gen.sample().0);
    let b = server.submit(gen.sample().0);
    let ra = a.recv_timeout(Duration::from_secs(120)).unwrap();
    let rb = b.recv_timeout(Duration::from_secs(120)).unwrap();
    assert_ne!(ra.id, rb.id);
    assert_ne!(ra.logits, rb.logits, "distinct clips, distinct logits");
    server.shutdown();
}

#[test]
#[cfg_attr(
    not(feature = "aot-artifacts"),
    ignore = "needs AOT artifacts (make artifacts); run with --features aot-artifacts"
)]
fn throughput_metrics_populate() {
    let Some((m, engine)) = setup() else { return };
    let server = Server::start(
        &engine,
        &m,
        BatchPolicy {
            batch_size: m.batch,
            max_wait: Duration::from_millis(5),
            seq_len: m.seq_len,
        },
    )
    .unwrap();
    let mut gen = SkeletonGen::new(
        GenConfig {
            num_classes: m.num_classes,
            seq_len: m.seq_len,
            noise: 0.02,
        },
        3,
    );
    let rxs: Vec<_> = (0..m.batch * 2)
        .map(|_| server.submit(gen.sample().0))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(120)).unwrap();
    }
    assert!(server.metrics.throughput_fps() > 0.0);
    let lat = server.metrics.latency_summary();
    assert_eq!(lat.n, m.batch * 2);
    assert!(lat.p99_s >= lat.p50_s);
    server.shutdown();
}
