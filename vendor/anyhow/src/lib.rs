//! Offline work-alike for the `anyhow` crate, covering the API surface
//! this workspace uses: [`Error`], [`Result`], the [`Context`] trait and
//! the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build environment has no crates.io access, so this vendored
//! subset stands in as a path dependency.  Semantics match real anyhow
//! where it matters here: `{}` displays the outermost message, `{:#}`
//! displays the whole context chain, and any `std::error::Error` value
//! converts via `?`.  Differences: the source error is stringified at
//! conversion time (no downcasting), and backtraces are not captured.

use std::convert::Infallible;
use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A stringly error with a context chain, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow's Debug prints the message plus its causes; the joined
        // chain carries the same information for `unwrap()` diagnostics.
        write!(f, "{}", self.chain.join(": "))
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`;
// that is what keeps this blanket conversion coherent (same trick as
// real anyhow).
impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42);
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn std_errors_convert_with_sources() {
        fn parse() -> Result<i32> {
            let n: i32 = "nope".parse()?;
            Ok(n)
        }
        let e = parse().unwrap_err();
        assert!(format!("{e}").contains("invalid digit"));
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn ensure_and_with_context() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        let e = check(-1).with_context(|| "checking input").unwrap_err();
        assert_eq!(format!("{e:#}"), "checking input: x must be positive, got -1");
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(format!("{a}"), "plain");
        let x = 3;
        let b = anyhow!("formatted {x} {}", 4);
        assert_eq!(format!("{b}"), "formatted 3 4");
        let c = anyhow!(String::from("from expr"));
        assert_eq!(format!("{c}"), "from expr");
    }
}
