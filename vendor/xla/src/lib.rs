//! Offline stand-in for the `xla` crate (xla-rs PJRT bindings).
//!
//! The build environment has no native XLA/PJRT libraries, so this
//! vendored crate mirrors the subset of the xla-rs API the workspace
//! uses (`PjRtClient` -> `compile` -> `execute` over [`Literal`]s) and
//! backs it with a small reference interpreter over **HLO text**.  The
//! interpreter covers the element-wise subset the artifact-free tests
//! exercise (parameter / constant / broadcast / binary arithmetic /
//! reshape / convert / tuple); executing a full AOT model module still
//! requires the real bindings, which drop in without source changes.

use std::error::Error as StdError;
use std::fmt;

mod interp;

/// Stringly error type (the real crate wraps XLA status codes).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl StdError for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

/// Element types this stub stores (subset of XLA's).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S16,
    S32,
    F32,
    F64,
}

/// Typed storage behind a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    S16(Vec<i16>),
    Tuple(Vec<Literal>),
}

/// Host element types convertible to/from [`Literal`] storage.
pub trait NativeType: Clone + Sized {
    const TY: PrimitiveType;
    fn from_data(data: &Data) -> Option<&[Self]>;
    fn into_data(v: Vec<Self>) -> Data;
}

impl NativeType for f32 {
    const TY: PrimitiveType = PrimitiveType::F32;
    fn from_data(data: &Data) -> Option<&[f32]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
    fn into_data(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
}

impl NativeType for i16 {
    const TY: PrimitiveType = PrimitiveType::S16;
    fn from_data(data: &Data) -> Option<&[i16]> {
        match data {
            Data::S16(v) => Some(v),
            _ => None,
        }
    }
    fn into_data(v: Vec<i16>) -> Data {
        Data::S16(v)
    }
}

/// The dims + element type of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: PrimitiveType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }
}

/// A host tensor value (array or tuple).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// 1-D f32 literal.
    pub fn vec1(values: &[f32]) -> Literal {
        Literal {
            dims: vec![values.len() as i64],
            data: Data::F32(values.to_vec()),
        }
    }

    /// f32 scalar literal.
    pub fn scalar_f32(v: f32) -> Literal {
        Literal {
            dims: Vec::new(),
            data: Data::F32(vec![v]),
        }
    }

    /// Tuple literal from parts.
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: Vec::new(),
            data: Data::Tuple(parts),
        }
    }

    /// Zero-filled literal of the given type and dims.
    ///
    /// Panics on element types the stub does not store (only F32/S16
    /// literals are constructible host-side, matching workspace usage).
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let n: usize = dims.iter().product();
        let data = match ty {
            PrimitiveType::F32 => Data::F32(vec![0.0; n]),
            PrimitiveType::S16 => Data::S16(vec![0; n]),
            other => panic!("xla stub: create_from_shape({other:?}) unsupported"),
        };
        Literal {
            dims: dims.iter().map(|&d| d as i64).collect(),
            data,
        }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::S16(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    pub fn ty(&self) -> Option<PrimitiveType> {
        match &self.data {
            Data::F32(_) => Some(PrimitiveType::F32),
            Data::S16(_) => Some(PrimitiveType::S16),
            Data::Tuple(_) => None,
        }
    }

    /// Same data, new dims (element counts must agree).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return err(format!(
                "reshape to {dims:?} ({n} elements) from {} elements",
                self.element_count()
            ));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.ty() {
            Some(ty) => Ok(ArrayShape {
                dims: self.dims.clone(),
                ty,
            }),
            None => err("tuple literal has no array shape"),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match T::from_data(&self.data) {
            Some(s) => Ok(s.to_vec()),
            None => err(format!("literal does not hold {:?} elements", T::TY)),
        }
    }

    /// Tuple elements (errors on a non-tuple literal).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(v) => Ok(v.clone()),
            _ => err("literal is not a tuple"),
        }
    }

    /// Single element of a 1-tuple.
    pub fn to_tuple1(&self) -> Result<Literal> {
        let mut v = self.to_tuple()?;
        if v.len() != 1 {
            return err(format!("expected a 1-tuple, got {} elements", v.len()));
        }
        Ok(v.pop().unwrap())
    }

    /// Overwrite this literal's storage from a raw host slice.
    pub fn copy_raw_from<T: NativeType>(&mut self, src: &[T]) -> Result<()> {
        if self.ty() != Some(T::TY) {
            return err(format!("copy_raw_from: literal is not {:?}", T::TY));
        }
        if src.len() != self.element_count() {
            return err(format!(
                "copy_raw_from: {} elements into a literal of {}",
                src.len(),
                self.element_count()
            ));
        }
        self.data = T::into_data(src.to_vec());
        Ok(())
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed-but-unvalidated HLO text (the real crate holds a protobuf).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text(text: &str) -> Result<HloModuleProto> {
        if !text.trim_start().starts_with("HloModule") {
            return err("not HLO text (missing HloModule header)");
        }
        Ok(HloModuleProto {
            text: text.to_string(),
        })
    }

    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Self::from_text(&text)
    }
}

/// A computation handed to the client for compilation.
pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            text: proto.text.clone(),
        }
    }
}

/// The stub "device": compiles by parsing, executes by interpreting.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "interpreter-stub".to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        let module = interp::parse_module(&comp.text)?;
        Ok(PjRtLoadedExecutable { module })
    }
}

/// A compiled (parsed) module ready to interpret.
pub struct PjRtLoadedExecutable {
    module: interp::HloModule,
}

impl PjRtLoadedExecutable {
    /// Execute on one "device"; mirrors the real API's
    /// per-device/per-output nesting (`result[0][0]`).
    pub fn execute<T: AsRef<Literal>>(&self, args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let lits: Vec<&Literal> = args.iter().map(AsRef::as_ref).collect();
        let out = interp::evaluate(&self.module, &lits)?;
        Ok(vec![vec![PjRtBuffer { lit: out }]])
    }
}

/// A device buffer (host-resident in the stub).
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"
HloModule tiny, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  two = f32[] constant(2)
  twob = f32[4]{0} broadcast(two), dimensions={}
  one = f32[] constant(1)
  oneb = f32[4]{0} broadcast(one), dimensions={}
  mul = f32[4]{0} multiply(x, twob)
  add = f32[4]{0} add(mul, oneb)
  ROOT out = (f32[4]{0}) tuple(add)
}
"#;

    #[test]
    fn interprets_elementwise_module() {
        let proto = HloModuleProto::from_text(TINY).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&comp).unwrap();
        let x = Literal::vec1(&[0.0, 1.0, 2.0, -3.0]);
        let out = exe.execute::<Literal>(&[x]).unwrap();
        let lit = out[0][0].to_literal_sync().unwrap();
        let y = lit.to_tuple1().unwrap();
        assert_eq!(y.to_vec::<f32>().unwrap(), vec![1.0, 3.0, 5.0, -5.0]);
    }

    #[test]
    fn literal_reshape_and_shape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert!(l.reshape(&[4]).is_err());
    }

    #[test]
    fn s16_copy_raw_roundtrip() {
        let mut l = Literal::create_from_shape(PrimitiveType::S16, &[2, 2]);
        l.copy_raw_from(&[1i16, -2, 3, -4]).unwrap();
        assert_eq!(l.to_vec::<i16>().unwrap(), vec![1, -2, 3, -4]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn unsupported_op_reports_cleanly() {
        let text = "HloModule t\n\nENTRY main {\n  x = f32[2]{0} parameter(0)\n  ROOT y = f32[2]{0} tanh(x)\n}\n";
        let proto = HloModuleProto::from_text(text).unwrap();
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let e = exe.execute::<Literal>(&[Literal::vec1(&[1.0, 2.0])]);
        assert!(e.is_err());
        assert!(e.unwrap_err().0.contains("tanh"));
    }
}
