//! Reference interpreter over HLO text.
//!
//! Parses the `ENTRY` computation of an `HloModule` dump and evaluates
//! its instruction list in program order (HLO text is topologically
//! sorted).  Supported ops: `parameter`, `constant` (scalar or flat
//! dense), `broadcast`, `add`, `subtract`, `multiply`, `divide`,
//! `maximum`, `minimum`, `negate`, `reshape`, `convert`, `copy`,
//! `tuple`, `get-tuple-element`.  Anything else (dot, convolution,
//! fusions, called computations...) errors with the op name so callers
//! know to use the real PJRT backend.

use std::collections::HashMap;

use super::{err, Data, Error, Literal, PrimitiveType, Result};

/// A parsed module: just its entry computation.
pub struct HloModule {
    entry: Computation,
}

struct Computation {
    instructions: Vec<Instruction>,
    root: usize,
}

struct Instruction {
    name: String,
    shape: Shape,
    op: String,
    /// operand names (last whitespace token of each operand, `%` stripped)
    operands: Vec<String>,
    /// raw parenthesized payload (used by `constant` / `parameter`)
    raw: String,
    attrs: Vec<(String, String)>,
}

#[derive(Debug, Clone)]
enum Shape {
    Array(PrimitiveType, Vec<i64>),
    Tuple(Vec<Shape>),
}

// ---------------------------------------------------------------- parsing

/// Split `s` at top-level commas (depth tracked over `([{`).
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0i32;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '(' | '[' | '{' => {
                depth += 1;
                cur.push(c);
            }
            ')' | ']' | '}' => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 => {
                parts.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur.trim().to_string());
    }
    parts
}

fn parse_type(s: &str) -> Result<PrimitiveType> {
    match s {
        "f32" => Ok(PrimitiveType::F32),
        "f64" => Ok(PrimitiveType::F64),
        "s16" => Ok(PrimitiveType::S16),
        "s32" => Ok(PrimitiveType::S32),
        "pred" => Ok(PrimitiveType::Pred),
        other => err(format!("unsupported element type {other:?}")),
    }
}

fn parse_shape(s: &str) -> Result<Shape> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('(') {
        let inner = inner.strip_suffix(')').unwrap_or(inner);
        let parts = split_top_level(inner);
        let shapes: Result<Vec<Shape>> = parts.iter().map(|p| parse_shape(p)).collect();
        return Ok(Shape::Tuple(shapes?));
    }
    let lb = match s.find('[') {
        Some(i) => i,
        None => return err(format!("malformed shape {s:?}")),
    };
    let rb = match s.find(']') {
        Some(i) => i,
        None => return err(format!("malformed shape {s:?}")),
    };
    let ty = parse_type(&s[..lb])?;
    let dims_str = s[lb + 1..rb].trim();
    let mut dims = Vec::new();
    if !dims_str.is_empty() {
        for d in dims_str.split(',') {
            dims.push(
                d.trim()
                    .parse::<i64>()
                    .map_err(|e| Error(format!("shape dim {d:?}: {e}")))?,
            );
        }
    }
    Ok(Shape::Array(ty, dims))
}

/// Consume a shape token from the head of `s` (stops at whitespace at
/// bracket depth 0); returns (shape_str, rest).
fn take_shape_token(s: &str) -> (&str, &str) {
    let mut depth = 0i32;
    for (i, c) in s.char_indices() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            c if c.is_whitespace() && depth == 0 => return (&s[..i], &s[i..]),
            _ => {}
        }
    }
    (s, "")
}

/// Find the parenthesized operand list of the opcode; returns
/// (inner, rest_after_close_paren).
fn take_paren_group(s: &str) -> Result<(&str, &str)> {
    let open = match s.find('(') {
        Some(i) => i,
        None => return err(format!("missing operand list in {s:?}")),
    };
    let mut depth = 0i32;
    for (i, c) in s[open..].char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    let at = open + i;
                    return Ok((&s[open + 1..at], &s[at + 1..]));
                }
            }
            _ => {}
        }
    }
    err(format!("unbalanced parens in {s:?}"))
}

fn parse_instruction(line: &str) -> Result<(Instruction, bool)> {
    let line = line.trim().trim_end_matches(',');
    let (is_root, line) = match line.strip_prefix("ROOT ") {
        Some(rest) => (true, rest),
        None => (false, line),
    };
    let eq = match line.find(" = ") {
        Some(i) => i,
        None => return err(format!("malformed instruction {line:?}")),
    };
    let name = line[..eq].trim().trim_start_matches('%').to_string();
    let rest = line[eq + 3..].trim_start();
    let (shape_str, rest) = take_shape_token(rest);
    let shape = parse_shape(shape_str)?;
    let rest = rest.trim_start();
    let op_end = rest.find('(').unwrap_or(rest.len());
    let op = rest[..op_end].trim().to_string();
    if op.is_empty() {
        return err(format!("missing opcode in {line:?}"));
    }
    let (raw, after) = take_paren_group(rest)?;
    // operand tokens may carry shapes ("f32[4]{0} %x"): keep the last word
    let operands: Vec<String> = split_top_level(raw)
        .into_iter()
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.split_whitespace()
                .last()
                .unwrap_or("")
                .trim_start_matches('%')
                .to_string()
        })
        .collect();
    let attrs: Vec<(String, String)> = split_top_level(after)
        .into_iter()
        .filter_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            Some((k.trim().to_string(), v.trim().to_string()))
        })
        .collect();
    Ok((
        Instruction {
            name,
            shape,
            op,
            operands,
            raw: raw.trim().to_string(),
            attrs,
        },
        is_root,
    ))
}

/// Parse the ENTRY computation out of an HLO text dump.
pub fn parse_module(text: &str) -> Result<HloModule> {
    let mut in_entry = false;
    let mut instructions = Vec::new();
    let mut root = None;
    for line in text.lines() {
        let t = line.trim();
        if !in_entry {
            if t.starts_with("ENTRY") && t.ends_with('{') {
                in_entry = true;
            }
            continue;
        }
        if t == "}" {
            let root = root.unwrap_or(instructions.len().saturating_sub(1));
            if instructions.is_empty() {
                return err("ENTRY computation has no instructions");
            }
            return Ok(HloModule {
                entry: Computation { instructions, root },
            });
        }
        if t.is_empty() || t.starts_with("//") {
            continue;
        }
        let (inst, is_root) = parse_instruction(t)?;
        if is_root {
            root = Some(instructions.len());
        }
        instructions.push(inst);
    }
    err("no ENTRY computation found in HLO text")
}

// ------------------------------------------------------------- evaluation

fn attr<'a>(inst: &'a Instruction, key: &str) -> Option<&'a str> {
    inst.attrs
        .iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v.as_str())
}

fn parse_braced_usizes(s: &str) -> Result<Vec<usize>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    for p in inner.split(',') {
        let p = p.trim();
        if p.is_empty() {
            continue;
        }
        out.push(
            p.parse::<usize>()
                .map_err(|e| Error(format!("attr value {p:?}: {e}")))?,
        );
    }
    Ok(out)
}

fn shape_dims(shape: &Shape) -> Result<(PrimitiveType, Vec<i64>)> {
    match shape {
        Shape::Array(ty, dims) => Ok((*ty, dims.clone())),
        Shape::Tuple(_) => err("expected an array shape"),
    }
}

fn constant_from_raw(inst: &Instruction) -> Result<Literal> {
    let (ty, dims) = shape_dims(&inst.shape)?;
    let n: usize = dims.iter().map(|&d| d as usize).product();
    let flat: String = inst
        .raw
        .chars()
        .map(|c| if c == '{' || c == '}' { ' ' } else { c })
        .collect();
    let mut values = Vec::new();
    for tok in flat.split(|c: char| c == ',' || c.is_whitespace()) {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        values.push(
            tok.parse::<f64>()
                .map_err(|e| Error(format!("constant value {tok:?}: {e}")))?,
        );
    }
    if values.len() != n {
        return err(format!(
            "constant {} has {} values for {} elements",
            inst.name,
            values.len(),
            n
        ));
    }
    let data = match ty {
        PrimitiveType::F32 => Data::F32(values.iter().map(|&v| v as f32).collect()),
        PrimitiveType::S16 => Data::S16(values.iter().map(|&v| v as i16).collect()),
        other => return err(format!("constant of type {other:?} unsupported")),
    };
    Ok(Literal { dims, data })
}

fn strides(dims: &[i64]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1] as usize;
    }
    s
}

fn broadcast_indices<T: Copy>(
    src: &[T],
    src_dims: &[i64],
    out_dims: &[i64],
    bdims: &[usize],
    out: &mut Vec<T>,
) -> Result<()> {
    if bdims.len() != src_dims.len() {
        return err(format!(
            "broadcast dimensions {bdims:?} do not match operand rank {}",
            src_dims.len()
        ));
    }
    // validate up front so malformed modules error instead of panicking
    for (k, &od) in bdims.iter().enumerate() {
        if od >= out_dims.len() {
            return err(format!(
                "broadcast dimension {od} out of range for output rank {}",
                out_dims.len()
            ));
        }
        if src_dims[k] != out_dims[od] && src_dims[k] != 1 {
            return err(format!(
                "broadcast operand dim {k} (size {}) incompatible with \
                 output dim {od} (size {})",
                src_dims[k], out_dims[od]
            ));
        }
    }
    let out_strides = strides(out_dims);
    let src_strides = strides(src_dims);
    let out_n: usize = out_dims.iter().map(|&d| d as usize).product();
    out.reserve(out_n);
    for oi in 0..out_n {
        let mut si = 0usize;
        for (k, &od) in bdims.iter().enumerate() {
            if src_dims[k] == 1 {
                continue; // degenerate (size-1) dim: stays at index 0
            }
            let coord = (oi / out_strides[od]) % out_dims[od] as usize;
            si += coord * src_strides[k];
        }
        match src.get(si) {
            Some(&v) => out.push(v),
            None => {
                return err(format!(
                    "broadcast index {si} out of range for operand of {}",
                    src.len()
                ))
            }
        }
    }
    Ok(())
}

fn broadcast(x: &Literal, out_dims: &[i64], bdims: &[usize]) -> Result<Literal> {
    match &x.data {
        Data::F32(src) => {
            let mut out = Vec::new();
            broadcast_indices(src, &x.dims, out_dims, bdims, &mut out)?;
            Ok(Literal {
                dims: out_dims.to_vec(),
                data: Data::F32(out),
            })
        }
        Data::S16(src) => {
            let mut out = Vec::new();
            broadcast_indices(src, &x.dims, out_dims, bdims, &mut out)?;
            Ok(Literal {
                dims: out_dims.to_vec(),
                data: Data::S16(out),
            })
        }
        Data::Tuple(_) => err("cannot broadcast a tuple"),
    }
}

fn binop(op: &str, a: &Literal, b: &Literal) -> Result<Literal> {
    match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => {
            if x.len() != y.len() {
                return err(format!("{op}: operand sizes {} vs {}", x.len(), y.len()));
            }
            let out: Vec<f32> = x
                .iter()
                .zip(y)
                .map(|(&p, &q)| match op {
                    "add" => p + q,
                    "subtract" => p - q,
                    "multiply" => p * q,
                    "divide" => p / q,
                    "maximum" => p.max(q),
                    "minimum" => p.min(q),
                    _ => f32::NAN,
                })
                .collect();
            Ok(Literal {
                dims: a.dims.clone(),
                data: Data::F32(out),
            })
        }
        (Data::S16(x), Data::S16(y)) => {
            if x.len() != y.len() {
                return err(format!("{op}: operand sizes {} vs {}", x.len(), y.len()));
            }
            let out: Vec<i16> = x
                .iter()
                .zip(y)
                .map(|(&p, &q)| match op {
                    "add" => p.wrapping_add(q),
                    "subtract" => p.wrapping_sub(q),
                    "multiply" => p.wrapping_mul(q),
                    "divide" => {
                        if q == 0 {
                            0
                        } else {
                            p.wrapping_div(q)
                        }
                    }
                    "maximum" => p.max(q),
                    "minimum" => p.min(q),
                    _ => 0,
                })
                .collect();
            Ok(Literal {
                dims: a.dims.clone(),
                data: Data::S16(out),
            })
        }
        _ => err(format!("{op}: mismatched or tuple operand types")),
    }
}

fn convert(x: &Literal, ty: PrimitiveType) -> Result<Literal> {
    let data = match (&x.data, ty) {
        (Data::F32(v), PrimitiveType::F32) => Data::F32(v.clone()),
        (Data::S16(v), PrimitiveType::S16) => Data::S16(v.clone()),
        (Data::F32(v), PrimitiveType::S16) => Data::S16(v.iter().map(|&p| p as i16).collect()),
        (Data::S16(v), PrimitiveType::F32) => Data::F32(v.iter().map(|&p| p as f32).collect()),
        (_, other) => return err(format!("convert to {other:?} unsupported")),
    };
    Ok(Literal {
        dims: x.dims.clone(),
        data,
    })
}

fn eval_instruction(
    inst: &Instruction,
    args: &[&Literal],
    env: &HashMap<String, Literal>,
) -> Result<Literal> {
    let operand = |i: usize| -> Result<&Literal> {
        let name = inst
            .operands
            .get(i)
            .ok_or_else(|| Error(format!("{}: missing operand {i}", inst.name)))?;
        env.get(name)
            .ok_or_else(|| Error(format!("{}: unknown operand {name:?}", inst.name)))
    };
    match inst.op.as_str() {
        "parameter" => {
            let idx: usize = inst
                .raw
                .trim()
                .parse()
                .map_err(|e| Error(format!("parameter index {:?}: {e}", inst.raw)))?;
            args.get(idx)
                .map(|l| (*l).clone())
                .ok_or_else(|| Error(format!("parameter({idx}) but only {} args", args.len())))
        }
        "constant" => constant_from_raw(inst),
        "broadcast" => {
            let (_, out_dims) = shape_dims(&inst.shape)?;
            let bdims = match attr(inst, "dimensions") {
                Some(v) => parse_braced_usizes(v)?,
                None => Vec::new(),
            };
            broadcast(operand(0)?, &out_dims, &bdims)
        }
        "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" => {
            binop(&inst.op, operand(0)?, operand(1)?)
        }
        "negate" => {
            let x = operand(0)?;
            match &x.data {
                Data::F32(v) => Ok(Literal {
                    dims: x.dims.clone(),
                    data: Data::F32(v.iter().map(|&p| -p).collect()),
                }),
                Data::S16(v) => Ok(Literal {
                    dims: x.dims.clone(),
                    data: Data::S16(v.iter().map(|&p| p.wrapping_neg()).collect()),
                }),
                Data::Tuple(_) => err("cannot negate a tuple"),
            }
        }
        "reshape" | "bitcast" => {
            let (_, out_dims) = shape_dims(&inst.shape)?;
            operand(0)?.reshape(&out_dims)
        }
        "copy" => Ok(operand(0)?.clone()),
        "convert" => {
            let (ty, _) = shape_dims(&inst.shape)?;
            convert(operand(0)?, ty)
        }
        "tuple" => {
            let mut parts = Vec::with_capacity(inst.operands.len());
            for i in 0..inst.operands.len() {
                parts.push(operand(i)?.clone());
            }
            Ok(Literal::tuple(parts))
        }
        "get-tuple-element" => {
            let idx: usize = match attr(inst, "index") {
                Some(v) => v
                    .parse()
                    .map_err(|e| Error(format!("tuple index {v:?}: {e}")))?,
                None => return err(format!("{}: get-tuple-element without index", inst.name)),
            };
            let parts = operand(0)?.to_tuple()?;
            parts
                .get(idx)
                .cloned()
                .ok_or_else(|| Error(format!("tuple index {idx} out of range")))
        }
        other => err(format!(
            "HLO op {other:?} is not supported by the stub interpreter \
             (install the real PJRT backend for full model execution)"
        )),
    }
}

/// Evaluate the entry computation against positional arguments.
pub fn evaluate(module: &HloModule, args: &[&Literal]) -> Result<Literal> {
    let comp = &module.entry;
    let mut env: HashMap<String, Literal> = HashMap::with_capacity(comp.instructions.len());
    for inst in &comp.instructions {
        let v = eval_instruction(inst, args, &env)?;
        env.insert(inst.name.clone(), v);
    }
    let root = &comp.instructions[comp.root];
    env.remove(&root.name)
        .ok_or_else(|| Error("root instruction produced no value".to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_top_level_respects_depth() {
        assert_eq!(split_top_level("a, b(c, d), e"), vec!["a", "b(c, d)", "e"]);
        assert_eq!(split_top_level("{1, 2}, x"), vec!["{1, 2}", "x"]);
    }

    #[test]
    fn parses_shapes() {
        match parse_shape("f32[4,3]{1,0}").unwrap() {
            Shape::Array(ty, dims) => {
                assert_eq!(ty, PrimitiveType::F32);
                assert_eq!(dims, vec![4, 3]);
            }
            _ => panic!("expected array"),
        }
        match parse_shape("(f32[4]{0}, s16[2]{0})").unwrap() {
            Shape::Tuple(parts) => assert_eq!(parts.len(), 2),
            _ => panic!("expected tuple"),
        }
    }

    #[test]
    fn instruction_with_shaped_operands() {
        let (inst, root) =
            parse_instruction("ROOT r = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)").unwrap();
        assert!(root);
        assert_eq!(inst.op, "add");
        assert_eq!(inst.operands, vec!["a", "b"]);
    }

    #[test]
    fn broadcast_general_dims() {
        // operand f32[2] broadcast into f32[2,3] along dim 0
        let x = Literal {
            dims: vec![2],
            data: Data::F32(vec![10.0, 20.0]),
        };
        let y = broadcast(&x, &[2, 3], &[0]).unwrap();
        assert_eq!(
            y.to_vec::<f32>().unwrap(),
            vec![10.0, 10.0, 10.0, 20.0, 20.0, 20.0]
        );
    }

    #[test]
    fn broadcast_degenerate_and_mismatched_dims() {
        // size-1 operand dim stretches instead of indexing out of bounds
        let x = Literal {
            dims: vec![1],
            data: Data::F32(vec![5.0]),
        };
        let y = broadcast(&x, &[2, 3], &[0]).unwrap();
        assert_eq!(y.to_vec::<f32>().unwrap(), vec![5.0; 6]);
        // mismatched (non-1) dim errors cleanly rather than panicking
        let z = Literal {
            dims: vec![2],
            data: Data::F32(vec![1.0, 2.0]),
        };
        assert!(broadcast(&z, &[3, 4], &[0]).is_err());
        assert!(broadcast(&z, &[2, 3], &[5]).is_err());
    }
}
