"""Pallas kernels vs pure-jnp oracle -- the CORE Layer-1 correctness
signal, plus hypothesis sweeps over shapes and values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import pruning
from compile.kernels import fused_gconv, temporal_conv, quant_matmul, ref

RTOL = 2e-5
ATOL = 2e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


class TestFusedGconv:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(0)
        f, g, w = _rand(rng, 64, 25, 16), _rand(rng, 3, 25, 25), \
            _rand(rng, 3, 16, 24)
        np.testing.assert_allclose(
            fused_gconv(f, g, w, block_t=32), ref.fused_gconv(f, g, w),
            rtol=RTOL, atol=ATOL)

    def test_single_subset(self):
        rng = np.random.default_rng(1)
        f, g, w = _rand(rng, 32, 25, 8), _rand(rng, 1, 25, 25), \
            _rand(rng, 1, 8, 8)
        np.testing.assert_allclose(
            fused_gconv(f, g, w, block_t=16), ref.fused_gconv(f, g, w),
            rtol=RTOL, atol=ATOL)

    def test_identity_graph_reduces_to_1x1_conv(self):
        """With G = I the fused op must equal a plain 1x1 convolution."""
        rng = np.random.default_rng(2)
        f, w = _rand(rng, 32, 25, 8), _rand(rng, 1, 8, 16)
        g = jnp.eye(25, dtype=jnp.float32)[None]
        out = fused_gconv(f, g, w, block_t=32)
        np.testing.assert_allclose(
            out, jnp.einsum("tpi,io->tpo", f, w[0]), rtol=RTOL, atol=ATOL)

    def test_channel_pruning_equivalence(self):
        """Compacting kept channels == zeroing dropped channels (the
        dataflow-reorganization guarantee, eq. 5)."""
        rng = np.random.default_rng(3)
        f, g, w = _rand(rng, 32, 25, 16), _rand(rng, 3, 25, 25), \
            _rand(rng, 3, 16, 8)
        kept = np.array([0, 2, 5, 9, 11, 15])
        w_zeroed = np.zeros_like(w)
        w_zeroed = w_zeroed.at[:, kept, :].set(w[:, kept, :]) \
            if hasattr(w_zeroed, "at") else w_zeroed
        wz = jnp.zeros_like(w).at[:, kept, :].set(w[:, kept, :])
        full = fused_gconv(f, g, wz, block_t=32)
        compact = fused_gconv(f[:, :, kept], g, w[:, kept, :], block_t=32)
        np.testing.assert_allclose(full, compact, rtol=RTOL, atol=ATOL)

    def test_rejects_bad_block(self):
        rng = np.random.default_rng(4)
        with pytest.raises(ValueError):
            fused_gconv(_rand(rng, 30, 25, 4), _rand(rng, 3, 25, 25),
                        _rand(rng, 3, 4, 4), block_t=32)

    def test_jit_composes(self):
        """Kernels are inference-path ops: they must jit cleanly (autodiff
        is deliberately unsupported -- training uses the jnp path)."""
        rng = np.random.default_rng(5)
        f, g, w = _rand(rng, 32, 25, 8), _rand(rng, 3, 25, 25), \
            _rand(rng, 3, 8, 8)
        fn = jax.jit(lambda f: fused_gconv(f, g, w, block_t=32))
        np.testing.assert_allclose(fn(f), ref.fused_gconv(f, g, w),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        tb=st.sampled_from([8, 16, 32]),
        nblk=st.integers(1, 3),
        ic=st.sampled_from([3, 8, 16]),
        oc=st.sampled_from([8, 16, 24]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, tb, nblk, ic, oc, seed):
        rng = np.random.default_rng(seed)
        t = tb * nblk
        f, g, w = _rand(rng, t, 25, ic), _rand(rng, 3, 25, 25), \
            _rand(rng, 3, ic, oc)
        np.testing.assert_allclose(
            fused_gconv(f, g, w, block_t=tb), ref.fused_gconv(f, g, w),
            rtol=1e-4, atol=1e-4)


class TestTemporalConv:
    @pytest.mark.parametrize("stride", [1, 2])
    @pytest.mark.parametrize("scheme_name",
                             ["dense", "cav-50", "cav-70-1", "cav-75-2"])
    def test_matches_ref(self, stride, scheme_name):
        rng = np.random.default_rng(0)
        scheme = pruning.CAVITY_SCHEMES[scheme_name]
        f = _rand(rng, 64, 25, 12)
        w = _rand(rng, 9, 12, 16)
        out = temporal_conv(f, w, scheme, stride=stride, block_t=16)
        exp = ref.temporal_conv(f, w, scheme.as_array(), stride=stride)
        np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)

    def test_pruned_taps_do_not_contribute(self):
        """Corrupting weights at pruned taps must not change the output."""
        rng = np.random.default_rng(1)
        scheme = pruning.CAV_70_1
        f = _rand(rng, 32, 25, 8)
        w = np.asarray(_rand(rng, 9, 8, 16))
        w2 = w.copy()
        mask = scheme.as_array()
        for oc in range(16):
            for tap in range(9):
                if not mask[oc % 8][tap]:
                    w2[tap, :, oc] = 1e6  # poison pruned positions
        o1 = temporal_conv(f, jnp.asarray(w), scheme, block_t=16)
        o2 = temporal_conv(f, jnp.asarray(w2), scheme, block_t=16)
        np.testing.assert_allclose(o1, o2, rtol=RTOL, atol=ATOL)

    def test_mask_group_assignment(self):
        """Filter oc uses cavity row oc % 8 (interleaved, not slabs)."""
        rng = np.random.default_rng(2)
        scheme = pruning.CAV_70_1
        f = _rand(rng, 16, 25, 4)
        w = _rand(rng, 9, 4, 16)
        out = temporal_conv(f, w, scheme, block_t=16)
        exp = ref.temporal_conv(f, w, scheme.as_array())
        np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)

    def test_rejects_bad_oc(self):
        rng = np.random.default_rng(3)
        with pytest.raises(ValueError):
            temporal_conv(_rand(rng, 16, 25, 4), _rand(rng, 9, 4, 12),
                          pruning.CAV_70_1, block_t=16)

    def test_rejects_bad_kernel_size(self):
        rng = np.random.default_rng(4)
        with pytest.raises(ValueError):
            temporal_conv(_rand(rng, 16, 25, 4), _rand(rng, 5, 4, 8),
                          pruning.CAV_70_1, block_t=16)

    @settings(max_examples=8, deadline=None)
    @given(
        t=st.sampled_from([16, 32, 64]),
        ic=st.sampled_from([4, 8, 12]),
        ocg=st.sampled_from([1, 2]),
        stride=st.sampled_from([1, 2]),
        scheme_name=st.sampled_from(["cav-50", "cav-67", "cav-70-1"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, t, ic, ocg, stride, scheme_name, seed):
        rng = np.random.default_rng(seed)
        scheme = pruning.CAVITY_SCHEMES[scheme_name]
        f = _rand(rng, t, 25, ic)
        w = _rand(rng, 9, ic, 8 * ocg)
        bt = min(16, t // stride)
        out = temporal_conv(f, w, scheme, stride=stride, block_t=bt)
        exp = ref.temporal_conv(f, w, scheme.as_array(), stride=stride)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


class TestQuantMatmul:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        xq = jnp.asarray(rng.integers(-3000, 3000, (128, 32)), jnp.int16)
        wq = jnp.asarray(rng.integers(-3000, 3000, (32, 16)), jnp.int16)
        np.testing.assert_array_equal(
            quant_matmul(xq, wq, block_m=64), ref.quant_matmul(xq, wq))

    def test_saturation(self):
        # 8 * 10000 * 10000 = 8e8 (fits int32); >> 8 = 3.125e6 -> saturate
        xq = jnp.full((64, 8), 10000, jnp.int16)
        wq = jnp.full((8, 4), 10000, jnp.int16)
        out = quant_matmul(xq, wq, block_m=64)
        assert np.all(np.asarray(out) == 32767)

    def test_negative_saturation(self):
        xq = jnp.full((64, 8), 10000, jnp.int16)
        wq = jnp.full((8, 4), -10000, jnp.int16)
        out = quant_matmul(xq, wq, block_m=64)
        assert np.all(np.asarray(out) == -32768)

    def test_arithmetic_shift_semantics(self):
        """-1 >> 8 must be -1 (arithmetic), not 0 (logical/trunc)."""
        xq = jnp.asarray([[-1]], jnp.int16).repeat(64, 0)
        wq = jnp.asarray([[1]], jnp.int16)
        out = quant_matmul(xq, wq, block_m=64)
        assert np.all(np.asarray(out) == -1)

    def test_rejects_bad_m(self):
        with pytest.raises(ValueError):
            quant_matmul(jnp.zeros((30, 8), jnp.int16),
                         jnp.zeros((8, 4), jnp.int16), block_m=64)

    @settings(max_examples=10, deadline=None)
    @given(
        m=st.sampled_from([64, 128]),
        k=st.integers(1, 48),
        n=st.integers(1, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        xq = jnp.asarray(rng.integers(-32768, 32768, (m, k)), jnp.int16)
        wq = jnp.asarray(rng.integers(-32768, 32768, (k, n)), jnp.int16)
        np.testing.assert_array_equal(
            quant_matmul(xq, wq, block_m=64), ref.quant_matmul(xq, wq))
