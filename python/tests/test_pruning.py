"""Hybrid-pruning invariants (compile.pruning)."""

import numpy as np
import pytest

from compile import pruning


class TestCavitySchemes:
    def test_registry_contains_paper_schemes(self):
        for name in ("cav-50", "cav-67", "cav-70-1", "cav-70-2",
                     "cav-75-1", "cav-75-2", "dense"):
            assert name in pruning.CAVITY_SCHEMES

    def test_shapes(self):
        for s in pruning.CAVITY_SCHEMES.values():
            assert s.as_array().shape == (8, 9)

    def test_prune_ratios(self):
        assert pruning.CAV_50.prune_ratio == pytest.approx(0.5)
        assert pruning.CAV_67.prune_ratio == pytest.approx(2 / 3, abs=0.01)
        assert pruning.CAV_70_1.prune_ratio == pytest.approx(0.70, abs=0.01)
        assert pruning.CAV_75_1.prune_ratio == pytest.approx(0.75)
        assert pruning.DENSE_SCHEME.prune_ratio == 0.0

    def test_matched_compression_pairs(self):
        """-1/-2 scheme pairs must keep the same weight count so Fig. 10
        isolates *balance*, not compression."""
        assert pruning.CAV_70_1.as_array().sum() == \
            pruning.CAV_70_2.as_array().sum()
        assert pruning.CAV_75_1.as_array().sum() == \
            pruning.CAV_75_2.as_array().sum()

    def test_balanced_schemes_have_small_spread(self):
        # "every weight line in cav-70-1 has two or three sampling chances"
        cov = pruning.CAV_70_1.tap_coverage()
        assert set(cov.tolist()) <= {2, 3}
        assert pruning.CAV_70_1.balance_spread() <= 1
        assert pruning.CAV_75_1.balance_spread() == 0

    def test_unbalanced_controls_have_larger_spread(self):
        assert pruning.CAV_70_2.balance_spread() > \
            pruning.CAV_70_1.balance_spread()
        assert pruning.CAV_75_2.balance_spread() > \
            pruning.CAV_75_1.balance_spread()

    def test_kept_taps_consistent_with_masks(self):
        s = pruning.CAV_70_1
        for i in range(16):  # wraps mod 8
            taps = s.kept_taps(i)
            row = s.masks[i % 8]
            assert taps == [t for t in range(9) if row[t]]

    def test_every_filter_keeps_at_least_one_tap_in_balanced(self):
        for s in (pruning.CAV_50, pruning.CAV_67, pruning.CAV_70_1,
                  pruning.CAV_75_1):
            for i in range(8):
                assert len(s.kept_taps(i)) >= 1

    def test_max_taps(self):
        assert pruning.CAV_70_1.max_taps() == 3
        assert pruning.DENSE_SCHEME.max_taps() == 9


class TestChannelSelection:
    def test_keeps_largest_magnitude_channels(self):
        rng = np.random.default_rng(0)
        w = rng.normal(size=(3, 16, 32)).astype(np.float32)
        w[:, 3, :] *= 100  # make channel 3 dominant
        w[:, 7, :] *= 0.001
        kept = pruning.select_kept_channels(w, 0.25)
        assert 3 in kept
        assert 7 not in kept
        assert len(kept) == 12

    def test_sorted_and_unique(self):
        rng = np.random.default_rng(1)
        w = rng.normal(size=(3, 32, 32))
        kept = pruning.select_kept_channels(w, 0.5)
        assert np.all(np.diff(kept) > 0)

    def test_zero_drop_keeps_all(self):
        w = np.ones((3, 8, 8))
        kept = pruning.select_kept_channels(w, 0.0)
        np.testing.assert_array_equal(kept, np.arange(8))

    def test_never_drops_everything(self):
        w = np.ones((3, 4, 4))
        kept = pruning.select_kept_channels(w, 0.99)
        assert len(kept) >= 1

    def test_invalid_rate_raises(self):
        w = np.ones((3, 4, 4))
        with pytest.raises(ValueError):
            pruning.select_kept_channels(w, 1.0)
        with pytest.raises(ValueError):
            pruning.select_kept_channels(w, -0.1)


class TestPlan:
    def _weights(self, widths, k_v=3, seed=0):
        rng = np.random.default_rng(seed)
        ws, ic = [], 3
        for oc in widths:
            ws.append(rng.normal(size=(k_v, ic, oc)).astype(np.float32))
            ic = oc
        return ws

    def test_coarse_rule_couples_blocks(self):
        """Temporal filters kept in block l == spatial in-channels kept in
        block l+1 (paper Fig. 2)."""
        widths = [16] * 10
        ws = self._weights(widths)
        plan = pruning.build_plan(ws, widths, "drop-1")
        for l in range(9):
            np.testing.assert_array_equal(
                plan.kept_temporal_out[l], plan.kept_spatial_in[l + 1])

    def test_block1_never_pruned(self):
        widths = [16] * 10
        plan = pruning.build_plan(self._weights(widths), widths, "drop-1")
        assert len(plan.kept_spatial_in[0]) == 3

    def test_last_temporal_unpruned(self):
        widths = [16] * 10
        plan = pruning.build_plan(self._weights(widths), widths, "drop-1")
        assert len(plan.kept_temporal_out[-1]) == 16

    def test_schedule_mismatch_raises(self):
        widths = [16] * 3
        with pytest.raises(ValueError):
            pruning.build_plan(self._weights(widths), widths, "drop-1")

    def test_graph_skip_ratio_monotone_in_schedule(self):
        widths = [16] * 10
        ws = self._weights(widths)
        ics = [3] + widths[:-1]
        r = [pruning.build_plan(ws, widths, s).graph_skip_ratio(ics)
             for s in ("drop-1", "drop-2", "drop-3")]
        assert r[0] < r[1] < r[2]

    def test_compression_ratio_monotone(self):
        widths = [16] * 10
        ws = self._weights(widths)
        ics = [3] + widths[:-1]
        ratios = []
        for s in ("drop-0", "drop-1", "drop-2", "drop-3"):
            plan = pruning.build_plan(ws, widths, s)
            ratios.append(pruning.model_compression_ratio(ics, widths, plan))
        assert ratios[0] < ratios[1] < ratios[2] < ratios[3]

    def test_dense_plan_compression_from_cavity_only(self):
        widths = [16] * 10
        ws = self._weights(widths)
        ics = [3] + widths[:-1]
        plan = pruning.build_plan(ws, widths, "drop-0",
                                  cavity=pruning.DENSE_SCHEME)
        ratio = pruning.model_compression_ratio(ics, widths, plan)
        assert ratio == pytest.approx(1.0)


class TestUnstructured:
    def test_mask_rate(self):
        rng = np.random.default_rng(0)
        w = rng.normal(size=(64, 64))
        m = pruning.unstructured_prune(w, 0.7)
        assert (m == 0).mean() == pytest.approx(0.7, abs=0.02)

    def test_keeps_largest(self):
        w = np.array([[0.1, -5.0], [0.01, 2.0]])
        m = pruning.unstructured_prune(w, 0.5)
        assert m[0, 1] == 1 and m[1, 1] == 1
        assert m[0, 0] == 0 and m[1, 0] == 0

    def test_zero_rate_identity(self):
        w = np.ones((4, 4))
        np.testing.assert_array_equal(
            pruning.unstructured_prune(w, 0.0), np.ones((4, 4)))


class TestParamCounts:
    def test_temporal_param_count_cavity(self):
        kept = np.arange(16)
        n = pruning.temporal_param_count(8, kept, pruning.CAV_70_1)
        # 2 loops of 8 filters, 22 taps per loop, x8 input channels
        assert n == 22 * 2 * 8

    def test_spatial_param_count(self):
        assert pruning.spatial_param_count(np.arange(10), 32) == 3 * 10 * 32
