"""Layer primitives (agcn.layers): norms, gconv, tconv, shortcut,
gather/scatter."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import pruning
from compile.agcn import layers
from compile.kernels import ref as kref


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


class TestNorms:
    def test_batch_norm_normalizes(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, 8, 16, 25, 4) * 5 + 3
        y = layers.batch_norm(x, jnp.ones(4), jnp.zeros(4))
        np.testing.assert_allclose(np.asarray(y).mean(axis=(0, 1, 2)),
                                   0.0, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y).std(axis=(0, 1, 2)),
                                   1.0, atol=1e-3)

    def test_fold_bn_equivalence(self):
        """affine(x, *fold_bn(...)) == batch_norm with those stats."""
        rng = np.random.default_rng(1)
        x = _rand(rng, 8, 16, 25, 4) * 2 + 1
        scale = np.asarray(_rand(rng, 4)) + 2.0
        bias = np.asarray(_rand(rng, 4))
        mean = np.asarray(x).mean(axis=(0, 1, 2))
        var = np.asarray(x).var(axis=(0, 1, 2))
        s, b = layers.fold_bn(scale, bias, mean, var)
        direct = (np.asarray(x) - mean) / np.sqrt(var + layers.EPS) \
            * scale + bias
        np.testing.assert_allclose(layers.affine(x, s, b), direct,
                                   rtol=1e-4, atol=1e-4)

    def test_relu(self):
        x = jnp.asarray([-1.0, 0.0, 2.0])
        np.testing.assert_array_equal(layers.relu(x), [0.0, 0.0, 2.0])


class TestGconv:
    def test_matches_einsum_definition(self):
        rng = np.random.default_rng(0)
        x, g, w = _rand(rng, 2, 8, 25, 6), _rand(rng, 3, 25, 25), \
            _rand(rng, 3, 6, 10)
        out = layers.gconv(x, g, w)
        exp = jnp.einsum("ntpi,kpw,kio->ntwo", x, g, w)
        np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)

    def test_kernel_path_matches(self):
        rng = np.random.default_rng(1)
        x, g, w = _rand(rng, 2, 16, 25, 8), _rand(rng, 3, 25, 25), \
            _rand(rng, 3, 8, 8)
        np.testing.assert_allclose(
            layers.gconv(x, g, w, use_kernels=True),
            layers.gconv(x, g, w), rtol=1e-4, atol=1e-4)

    def test_kernel_path_pads_ragged_time(self):
        rng = np.random.default_rng(2)
        x, g, w = _rand(rng, 3, 10, 25, 4), _rand(rng, 3, 25, 25), \
            _rand(rng, 3, 4, 4)  # 30 rows, not a multiple of 32
        np.testing.assert_allclose(
            layers.gconv(x, g, w, use_kernels=True),
            layers.gconv(x, g, w), rtol=1e-4, atol=1e-4)

    def test_per_sample_graph_variant(self):
        rng = np.random.default_rng(3)
        x, w = _rand(rng, 2, 8, 25, 6), _rand(rng, 3, 6, 10)
        g = _rand(rng, 2, 3, 25, 25)  # per-sample graphs (C_k path)
        out = layers.gconv(x, g, w)
        exp = jnp.einsum("ntpi,nkpw,kio->ntwo", x, g, w)
        np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


class TestSelfSimilarity:
    def test_rows_softmax_normalized(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, 2, 8, 25, 6)
        c = layers.self_similarity(x, _rand(rng, 6, 4), _rand(rng, 6, 4))
        assert c.shape == (2, 25, 25)
        np.testing.assert_allclose(np.asarray(c).sum(axis=-1), 1.0,
                                   atol=1e-5)
        assert np.all(np.asarray(c) >= 0)


class TestTconv:
    @pytest.mark.parametrize("stride", [1, 2])
    def test_conv_path_matches_ref_oracle(self, stride):
        """layers.tconv (native conv) == kernels.ref (einsum taps)."""
        rng = np.random.default_rng(0)
        x = _rand(rng, 2, 32, 25, 8)
        w = _rand(rng, 9, 8, 16)
        scheme = pruning.CAV_70_1
        out = layers.tconv(x, w, scheme, stride=stride)
        exp = jax.vmap(
            lambda f: kref.temporal_conv(f, w, scheme.as_array(),
                                         stride=stride))(x)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)

    def test_kernel_path_matches_conv_path(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, 2, 32, 25, 8)
        w = _rand(rng, 9, 8, 16)
        np.testing.assert_allclose(
            layers.tconv(x, w, pruning.CAV_50, use_kernels=True),
            layers.tconv(x, w, pruning.CAV_50), rtol=1e-4, atol=1e-4)


class TestShortcut:
    def test_identity(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, 2, 8, 25, 4)
        np.testing.assert_array_equal(layers.shortcut(x), x)

    def test_stride_subsamples_time(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, 2, 8, 25, 4)
        out = layers.shortcut(x, stride=2)
        np.testing.assert_array_equal(out, np.asarray(x)[:, ::2])

    def test_projection(self):
        rng = np.random.default_rng(2)
        x, w = _rand(rng, 2, 8, 25, 4), _rand(rng, 4, 6)
        out = layers.shortcut(x, w, stride=2)
        exp = jnp.einsum("ntvi,io->ntvo", x[:, ::2], w)
        np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


class TestGatherScatter:
    def test_roundtrip(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, 2, 4, 25, 6)
        kept = np.array([1, 3, 4])
        g = layers.gather_channels(x, kept)
        s = layers.scatter_channels(g, kept, 6)
        np.testing.assert_array_equal(
            np.asarray(s)[..., kept], np.asarray(x)[..., kept])
        dropped = [0, 2, 5]
        assert np.all(np.asarray(s)[..., dropped] == 0)

    def test_gather_shape(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, 2, 4, 25, 6)
        assert layers.gather_channels(x, np.array([0, 5])).shape \
            == (2, 4, 25, 2)
