"""Two-stream fusion invariants (compile.ensemble)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import ensemble


def test_fuse_is_probability_distribution():
    lj = jnp.asarray([[2.0, 0.0, -1.0]])
    lb = jnp.asarray([[0.0, 1.0, 0.0]])
    f = ensemble.fuse_logits(lj, lb)
    assert np.all(np.asarray(f) >= 0)
    np.testing.assert_allclose(np.asarray(f).sum(axis=-1), 1.0, atol=1e-6)


def test_alpha_one_is_joint_only():
    lj = jnp.asarray([[5.0, 0.0]])
    lb = jnp.asarray([[0.0, 5.0]])
    f1 = ensemble.fuse_logits(lj, lb, alpha=1.0)
    assert np.argmax(np.asarray(f1)) == 0
    f0 = ensemble.fuse_logits(lj, lb, alpha=0.0)
    assert np.argmax(np.asarray(f0)) == 1


def test_agreeing_streams_reinforce():
    lj = jnp.asarray([[1.0, 0.0]])
    lb = jnp.asarray([[1.0, 0.0]])
    f = ensemble.fuse_logits(lj, lb)
    single = jnp.exp(1.0) / (jnp.exp(1.0) + 1.0)
    np.testing.assert_allclose(float(f[0, 0]), float(single), atol=1e-6)
    assert float(f[0, 0]) > 0.5
