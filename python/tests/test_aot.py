"""AOT export path (compile.aot): HLO text emission, FLOP accounting,
manifest sanity against built artifacts when present."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, pruning
from compile.agcn import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_emits_parseable_module(tmp_path):
    fn = lambda x: (jnp.matmul(x, x) + 1.0,)
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    info = aot.export(fn, (spec,), str(tmp_path / "t.hlo.txt"))
    text = (tmp_path / "t.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "f32[4,4]" in text
    assert info["bytes"] == len(text)


def test_hlo_text_not_proto():
    """Interchange must be text -- serialized protos break xla 0.5.1."""
    fn = lambda x: (x + 1.0,)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert isinstance(text, str)
    assert "ENTRY" in text


class TestFlops:
    CFG = M.ModelConfig(num_classes=8, seq_len=32, width_mult=0.25)

    def test_dense_flops_positive_increasing_with_width(self):
        table = aot.flops_table(self.CFG, None)
        assert all(row["total"] > 0 for row in table)
        # deeper blocks have more channels but fewer frames
        assert table[1]["total"] != table[8]["total"]

    def test_pruned_less_than_dense(self):
        params = M.init_params(self.CFG, seed=0)
        plan = M.make_plan(params, self.CFG, "drop-2", pruning.CAV_70_1)
        dense = sum(r["total"] for r in aot.flops_table(self.CFG, None))
        pruned = sum(r["total"] for r in aot.flops_table(self.CFG, plan))
        assert pruned < 0.6 * dense

    def test_graph_share_of_dense_workload(self):
        """Paper: graph computation ~49.83% of eq. 3 workloads. With a
        square channel count, graph vs spatial share depends on V vs OC;
        just assert both components are material."""
        table = aot.flops_table(self.CFG, None)
        g = sum(r["graph"] for r in table)
        s = sum(r["spatial"] for r in table)
        assert g > 0.1 * (g + s)
        assert s > 0.1 * (g + s)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "meta.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    @pytest.fixture(scope="class")
    def meta(self):
        with open(os.path.join(ART, "meta.json")) as f:
            return json.load(f)

    def test_blocks_chain(self, meta):
        blocks = meta["blocks"]
        assert len(blocks) == 10
        for a, b in zip(blocks, blocks[1:]):
            assert a["out_shape"] == b["in_shape"]

    def test_block_files_exist(self, meta):
        for b in meta["blocks"]:
            assert os.path.exists(os.path.join(ART, b["hlo"]))

    def test_variant_files_exist(self, meta):
        for name in ("model_dense", "model_ck", "model_pruned",
                     "model_skip", "head", "quant_demo"):
            assert os.path.exists(
                os.path.join(ART, meta["artifacts"][name]["hlo"]))

    def test_coarse_rule_in_manifest(self, meta):
        blocks = meta["blocks"]
        for a, b in zip(blocks, blocks[1:]):
            assert a["kept_t_out"] == b["kept_in"]

    def test_cavity_masks_shape(self, meta):
        masks = meta["cavity"]["masks"]
        assert len(masks) == 8
        assert all(len(m) == 9 for m in masks)

    def test_flops_pruned_below_dense(self, meta):
        d = sum(r["total"] for r in meta["flops"]["dense_per_sample"])
        p = sum(r["total"] for r in meta["flops"]["pruned_per_sample"])
        assert p < d

    def test_sparsity_buckets_normalized(self, meta):
        for name, s in meta["sparsity"].items():
            assert sum(s["buckets_I_II_III_IV"]) == pytest.approx(1.0,
                                                                  abs=1e-6)
