"""Q8.8 quantization invariants (compile.quantize)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as Q


def test_roundtrip_exact_on_grid():
    """Values on the 1/256 grid survive quantization exactly."""
    x = jnp.asarray([0.0, 1.0, -1.0, 0.5, 127.99609375, -128.0])
    np.testing.assert_array_equal(Q.dequantize(Q.quantize(x)), x)


def test_error_bound_in_range():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-100, 100, 1000), jnp.float32)
    err = Q.quant_error(x)
    assert err <= 0.5 / Q.SCALE + 1e-7


def test_saturation():
    x = jnp.asarray([1e6, -1e6])
    q = Q.quantize(x)
    np.testing.assert_array_equal(q, [Q.QMAX, Q.QMIN])


def test_fake_quant_idempotent():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(-10, 10, 100), jnp.float32)
    once = Q.fake_quant(x)
    twice = Q.fake_quant(once)
    np.testing.assert_array_equal(once, twice)


def test_fake_quant_tree():
    tree = {"a": jnp.asarray([0.12345]), "b": [jnp.asarray([1.5])]}
    out = Q.fake_quant_tree(tree)
    assert float(out["b"][0][0]) == 1.5           # on-grid survives
    assert abs(float(out["a"][0]) - 0.12345) <= 0.5 / Q.SCALE


def test_dtype():
    assert Q.quantize(jnp.asarray([1.0])).dtype == jnp.int16


@settings(max_examples=25, deadline=None)
@given(st.floats(-128.0, 127.9, allow_nan=False))
def test_hypothesis_error_bound(v):
    err = Q.quant_error(jnp.asarray([v], jnp.float32))
    assert err <= 0.5 / Q.SCALE + 1e-6


@settings(max_examples=25, deadline=None)
@given(st.integers(Q.QMIN, Q.QMAX))
def test_hypothesis_int_roundtrip(q):
    x = Q.dequantize(jnp.asarray([q], jnp.int16))
    assert int(Q.quantize(x)[0]) == q
