"""Trainer sanity (compile.train): loss decreases, masks hold, pruned
fine-tune path runs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, pruning, train as T
from compile.agcn import model as M

CFG = M.ModelConfig(num_classes=4, seq_len=16, width_mult=0.25)
DCFG = data.DataConfig(num_classes=4, seq_len=16)


def _tiny_dataset():
    xtr, ytr = data.generate(DCFG, 96, seed=0)
    xte, yte = data.generate(DCFG, 48, seed=1)
    return xtr, ytr, xte, yte


@pytest.fixture(scope="module")
def trained():
    tcfg = T.TrainConfig(steps=30, batch=24, log_every=10)
    return T.train(CFG, tcfg, dataset=_tiny_dataset(), verbose=False)


def test_loss_decreases(trained):
    _, hist = trained
    assert hist["loss"][-1] < hist["loss"][0]


def test_accuracy_above_chance(trained):
    _, hist = trained
    assert hist["test_acc"] > 1.5 / CFG.num_classes


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0], [0.0, 3.0]])
    labels = jnp.asarray([0, 1])
    expected = -np.mean([
        2.0 - np.log(np.exp(2.0) + 1.0),
        3.0 - np.log(np.exp(3.0) + 1.0),
    ])
    assert float(T.cross_entropy(logits, labels)) == pytest.approx(
        expected, abs=1e-5)


def test_accuracy_fn():
    logits = jnp.asarray([[1.0, 0.0], [0.0, 1.0], [1.0, 0.0]])
    labels = jnp.asarray([0, 1, 1])
    assert T.accuracy(logits, labels) == pytest.approx(2 / 3)


def test_unstructured_mask_rate_and_scope(trained):
    params, _ = trained
    mask = T.unstructured_mask(params, 0.6)
    flat_conv = np.concatenate(
        [np.asarray(b["w_spatial"]).ravel() for b in mask["blocks"]]
        + [np.asarray(b["w_temporal"]).ravel() for b in mask["blocks"]])
    assert (flat_conv == 0).mean() == pytest.approx(0.6, abs=0.05)
    # graph params stay dense
    assert np.all(np.asarray(mask["blocks"][0]["bk"]) == 1)
    # BN/FC leaves stay dense
    m_fc = np.asarray(mask["fc"]["w"])
    assert np.all(m_fc == 1)


def test_masked_finetune_preserves_zeros(trained):
    params, _ = trained
    mask = T.unstructured_mask(params, 0.5)
    tcfg = T.TrainConfig(steps=5, batch=16, log_every=10)
    tuned, _ = T.train(CFG, tcfg, params=jax.tree_util.tree_map(
        np.asarray, params), mask=mask, dataset=_tiny_dataset(),
        verbose=False)
    w = np.asarray(tuned["blocks"][3]["w_spatial"])
    m = np.asarray(mask["blocks"][3]["w_spatial"])
    assert np.all(w[m == 0] == 0)


def test_pruned_finetune_runs(trained):
    params, _ = trained
    plan = M.make_plan(params, CFG, "drop-1", pruning.CAV_70_1)
    tcfg = T.TrainConfig(steps=5, batch=16, log_every=10)
    _, hist = T.train(CFG, tcfg, params=jax.tree_util.tree_map(
        np.asarray, params), plan=plan, dataset=_tiny_dataset(),
        verbose=False)
    assert np.isfinite(hist["loss"][-1])
