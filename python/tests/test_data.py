"""Synthetic skeleton dataset invariants (compile.data)."""

import numpy as np
import pytest

from compile import data
from compile.agcn import graph

CFG = data.DataConfig(num_classes=8, seq_len=32)


def test_shapes_and_dtypes():
    x, y = data.generate(CFG, 16, seed=0)
    assert x.shape == (16, 3, 32, 25)
    assert x.dtype == np.float32
    assert y.shape == (16,)
    assert y.dtype == np.int32


def test_labels_in_range():
    _, y = data.generate(CFG, 64, seed=1)
    assert y.min() >= 0 and y.max() < CFG.num_classes


def test_deterministic_given_seed():
    a = data.generate(CFG, 8, seed=42)
    b = data.generate(CFG, 8, seed=42)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_different_seeds_differ():
    a = data.generate(CFG, 8, seed=0)[0]
    b = data.generate(CFG, 8, seed=1)[0]
    assert not np.allclose(a, b)


def test_classes_are_distinguishable():
    """Nearest-centroid on per-joint motion energy must beat chance by a
    wide margin -- the dataset must carry learnable class signal."""
    x, y = data.generate(CFG, 256, seed=0)
    feats = np.abs(np.diff(x, axis=2)).mean(axis=(1, 2))  # (N, V)
    xt, yt = data.generate(CFG, 128, seed=99)
    ft = np.abs(np.diff(xt, axis=2)).mean(axis=(1, 2))
    cents = np.stack([feats[y == c].mean(axis=0)
                      for c in range(CFG.num_classes)])
    pred = np.argmin(
        ((ft[:, None, :] - cents[None]) ** 2).sum(-1), axis=1)
    acc = (pred == yt).mean()
    assert acc > 2.5 / CFG.num_classes, f"centroid acc {acc:.3f} ~ chance"


def test_motion_present():
    x, _ = data.generate(CFG, 8, seed=0)
    assert np.abs(np.diff(x, axis=2)).max() > 0.01


def test_bone_stream_root_is_untouched_joint_diff():
    x, _ = data.generate(CFG, 4, seed=0)
    b = data.bone_stream(x)
    for child, parent in graph.bone_pairs():
        np.testing.assert_allclose(
            b[..., child], x[..., child] - x[..., parent], atol=1e-6)


def test_bone_stream_shape():
    x, _ = data.generate(CFG, 4, seed=0)
    assert data.bone_stream(x).shape == x.shape


def test_input_skip_halves_time():
    x, _ = data.generate(CFG, 4, seed=0)
    s = data.input_skip(x)
    assert s.shape == (4, 3, 16, 25)
    np.testing.assert_array_equal(s, x[:, :, ::2, :])


def test_input_skip_factor():
    x, _ = data.generate(CFG, 4, seed=0)
    assert data.input_skip(x, factor=4).shape[2] == 8
