"""Skeleton-graph invariants (agcn.graph)."""

import numpy as np
import pytest

from compile.agcn import graph


def test_adjacency_shape_and_symmetry():
    a = graph.adjacency()
    assert a.shape == (25, 25)
    np.testing.assert_array_equal(a, a.T)


def test_adjacency_self_loops():
    a = graph.adjacency()
    assert np.all(np.diag(a) == 1.0)


def test_edge_count():
    # 24 bones in the 25-joint NTU skeleton
    a = graph.adjacency()
    off_diag = a.sum() - 25
    assert off_diag == 2 * 24


def test_graph_is_connected():
    dist = graph.hop_distance()
    assert np.all(np.isfinite(dist)), "skeleton must be one component"


def test_hop_distance_properties():
    dist = graph.hop_distance()
    assert np.all(np.diag(dist) == 0)
    # neighbours at hop 1
    for i, j in graph.EDGES:
        assert dist[i, j] == 1


def test_partitions_shape_dtype():
    p = graph.spatial_partitions()
    assert p.shape == (graph.K_V, 25, 25)
    assert p.dtype == np.float32


def test_partitions_cover_normalized_adjacency():
    """Subsets are a disjoint cover of the normalized one-hop adjacency."""
    p = graph.spatial_partitions().astype(np.float64)
    total = p.sum(axis=0)
    a_norm = graph._normalize_digraph(graph.adjacency())
    dist = graph.hop_distance()
    expected = np.where(dist <= 1, a_norm, 0.0)
    np.testing.assert_allclose(total, expected, atol=1e-6)


def test_partitions_disjoint():
    p = graph.spatial_partitions()
    nz = (p != 0).astype(int).sum(axis=0)
    assert nz.max() <= 1, "an entry may live in at most one subset"


def test_root_subset_contains_self_loops():
    p = graph.spatial_partitions()
    assert np.all(np.diag(p[0]) > 0)


def test_centripetal_centrifugal_antisymmetry():
    """If (i<-j) is centripetal then (j<-i) is centrifugal (off-centre)."""
    p = graph.spatial_partitions()
    dist = graph.hop_distance()
    cd = dist[:, graph.CENTER]
    for i, j in graph.EDGES:
        if cd[i] == cd[j]:
            continue
        near, far = (i, j) if cd[i] < cd[j] else (j, i)
        # centripetal subset (1): target j farther than source i
        assert p[1][near, far] > 0
        assert p[2][far, near] > 0


def test_bone_pairs_match_edges():
    assert graph.bone_pairs() == graph.EDGES
    assert len(graph.bone_pairs()) == 24
