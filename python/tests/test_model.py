"""Full-model invariants (agcn.model): variants, equivalences, folding,
save/load."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, pruning
from compile.agcn import model as M

CFG = M.ModelConfig(num_classes=8, seq_len=32, width_mult=0.25)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def batch():
    x, y = data.generate(
        data.DataConfig(num_classes=8, seq_len=32), 4, seed=0)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.fixture(scope="module")
def plan(params):
    return M.make_plan(params, CFG, "drop-1", pruning.CAV_70_1)


class TestConfig:
    def test_block_specs_chain(self):
        specs = CFG.block_specs()
        assert len(specs) == 10
        assert specs[0].in_channels == 3
        for a, b in zip(specs, specs[1:]):
            assert a.out_channels == b.in_channels

    def test_widths_are_multiples_of_8(self):
        for s in CFG.block_specs():
            assert s.out_channels % 8 == 0

    def test_strides_follow_plan(self):
        specs = CFG.block_specs()
        assert [s.stride for s in specs] == [1, 1, 1, 1, 2, 1, 1, 2, 1, 1]

    def test_out_seq_len(self):
        assert CFG.out_seq_len() == 8  # 32 / 2 / 2

    def test_full_width_at_mult_1(self):
        cfg = M.ModelConfig(width_mult=1.0)
        assert [s.out_channels for s in cfg.block_specs()] == \
            M.FULL_CHANNELS


class TestForward:
    def test_logit_shape(self, params, batch):
        logits = M.forward(params, batch[0], CFG)
        assert logits.shape == (4, 8)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_with_ck_changes_output(self, params, batch):
        a = M.forward(params, batch[0], CFG)
        b = M.forward(params, batch[0], CFG, with_ck=True)
        assert not np.allclose(np.asarray(a), np.asarray(b))

    def test_pruned_forward_finite(self, params, batch, plan):
        logits = M.forward(params, batch[0], CFG, plan=plan)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_kernel_path_equivalence_dense(self, params, batch):
        a = M.forward(params, batch[0], CFG)
        b = M.forward(params, batch[0], CFG, use_kernels=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)

    def test_kernel_path_equivalence_pruned(self, params, batch, plan):
        a = M.forward(params, batch[0], CFG, plan=plan)
        b = M.forward(params, batch[0], CFG, plan=plan, use_kernels=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)

    def test_forward_collect_names_and_shapes(self, params, batch):
        logits, acts = M.forward_collect(params, batch[0], CFG)
        assert logits.shape == (4, 8)
        assert len(acts) == 20  # sconv + tconv per block
        assert acts[0][0] == "b1.sconv"
        assert acts[-1][0] == "b10.tconv"
        for name, a in acts:
            assert np.all(np.asarray(a) >= 0), "post-ReLU must be >= 0"

    def test_pruned_channels_are_dead(self, params, batch, plan):
        """Outputs on dropped temporal channels must be exactly zero
        before the shortcut -- verified via the sconv gather: dropped
        input channels never affect the result."""
        x = np.asarray(batch[0]).copy()
        logits_a = M.forward(params, jnp.asarray(x), CFG, plan=plan)
        # poison the dropped input channels of block 2 by scaling the
        # corresponding temporal filters of block 1: they are pruned, so
        # nothing may change
        p2 = jax.tree_util.tree_map(np.asarray, params)
        kept = set(plan.kept_temporal_out[0].tolist())
        dropped = [c for c in range(CFG.block_specs()[0].out_channels)
                   if c not in kept]
        if dropped:
            p2["blocks"][0]["w_temporal"][:, :, dropped] *= 123.0
            logits_b = M.forward(p2, jnp.asarray(x), CFG, plan=plan)
            np.testing.assert_allclose(np.asarray(logits_a),
                                       np.asarray(logits_b),
                                       rtol=1e-4, atol=1e-4)


class TestCalibrationFold:
    def test_folded_matches_batchnorm_on_calibration_batch(self, params):
        x, _ = data.generate(
            data.DataConfig(num_classes=8, seq_len=32), 16, seed=3)
        x = jnp.asarray(x)
        folded = M.calibrate_fold(params, x, CFG)
        a = M.forward(params, x, CFG)
        b = M.forward(folded, x, CFG, folded_bn=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-2, atol=5e-2)

    def test_folded_is_deterministic_per_sample(self, params, batch):
        """Folded BN must not mix batch statistics: single-sample results
        equal batched results."""
        x, _ = data.generate(
            data.DataConfig(num_classes=8, seq_len=32), 8, seed=3)
        folded = M.calibrate_fold(params, jnp.asarray(x), CFG)
        full = M.forward(folded, jnp.asarray(x), CFG, folded_bn=True)
        single = M.forward(folded, jnp.asarray(x[:1]), CFG, folded_bn=True)
        np.testing.assert_allclose(np.asarray(full)[:1], np.asarray(single),
                                   rtol=1e-4, atol=1e-4)

    def test_fold_with_plan(self, params, plan):
        x, _ = data.generate(
            data.DataConfig(num_classes=8, seq_len=32), 8, seed=4)
        folded = M.calibrate_fold(params, jnp.asarray(x), CFG, plan=plan)
        out = M.forward(folded, jnp.asarray(x), CFG, plan=plan,
                        folded_bn=True)
        assert np.all(np.isfinite(np.asarray(out)))


class TestSaveLoad:
    def test_roundtrip(self, params):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "p.npz")
            M.save_params(path, params)
            loaded = M.load_params(path, CFG)
        la, lb = jax.tree_util.tree_leaves(params), \
            jax.tree_util.tree_leaves(loaded)
        assert len(la) == len(lb)
        for a, b in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestPlanHelpers:
    def test_make_plan_respects_schedule(self, params):
        p1 = M.make_plan(params, CFG, "drop-1")
        p3 = M.make_plan(params, CFG, "drop-3")
        k1 = sum(len(k) for k in p1.kept_spatial_in)
        k3 = sum(len(k) for k in p3.kept_spatial_in)
        assert k3 < k1

    def test_compression_in_paper_band(self, params):
        """Paper reports 3.0x-8.4x across its design points."""
        lo = M.compression_ratio(CFG, M.make_plan(params, CFG, "drop-1",
                                                  pruning.CAV_50))
        hi = M.compression_ratio(CFG, M.make_plan(params, CFG, "drop-3",
                                                  pruning.CAV_75_1))
        assert 2.0 < lo < hi < 12.0

    def test_block_io_shapes_chain(self):
        io = M.block_io_shapes(CFG, 4)
        assert io[0][0] == (4, 32, 25, 3)
        for a, b in zip(io, io[1:]):
            assert a[1] == b[0]
