"""Synthetic NTU-RGB+D-like skeleton data (substitution, see DESIGN.md).

The real NTU-RGB+D dataset (37k train / 18k test clips, 60 action classes)
is not available here, so this module generates class-conditioned skeleton
motion with the *same tensor contract*: ``(N, C=3, T, V=25)`` joint
coordinates over the genuine NTU bone topology.

Generator design: each class is a deterministic set of per-joint sinusoidal
motion programs (frequency, phase, amplitude, axis mix) layered on a shared
rest pose, propagated down the kinematic tree so children inherit parent
motion (as real limbs do), plus i.i.d. sensor noise and a random global
rotation/scale per sample.  Classes differ in which limbs move and how fast
-- coarse analogues of "waving" vs "kicking".  The resulting problem is
genuinely learnable but not trivial, so pruning-vs-accuracy *trends*
(Figs. 8-10) are measurable.

Also provides the *bone stream* (second stream of 2s-AGCN): per-bone
vectors ``x[child] - x[parent]``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .agcn import graph

# Rest pose: a rough standing human in metres, indexed by NTU joint.
_REST = np.zeros((graph.NUM_JOINTS, 3), dtype=np.float64)
_REST[:, 1] = np.array([
    0.0, 0.25, 0.50, 0.60,          # spine base, mid, neck, head
    0.45, 0.30, 0.10, 0.00,         # left shoulder..hand
    0.45, 0.30, 0.10, 0.00,         # right shoulder..hand
    -0.05, -0.45, -0.85, -0.95,     # left hip..foot
    -0.05, -0.45, -0.85, -0.95,     # right hip..foot
    0.40,                            # spine (joint 21)
    0.00, 0.02, 0.00, 0.02,         # hand tips / thumbs
])
_REST[:, 0] = np.array([
    0.0, 0.0, 0.0, 0.0,
    -0.18, -0.28, -0.32, -0.34,
    0.18, 0.28, 0.32, 0.34,
    -0.09, -0.10, -0.11, -0.12,
    0.09, 0.10, 0.11, 0.12,
    0.0,
    -0.36, -0.33, 0.36, 0.33,
])

# Limb groups used to give classes distinct motion signatures.
_LIMBS = {
    "left_arm": [4, 5, 6, 7, 21, 22],
    "right_arm": [8, 9, 10, 11, 23, 24],
    "left_leg": [12, 13, 14, 15],
    "right_leg": [16, 17, 18, 19],
    "torso": [0, 1, 2, 3, 20],
}


@dataclass(frozen=True)
class DataConfig:
    """Synthetic dataset parameters."""

    num_classes: int = 8
    seq_len: int = 64           # paper uses 300 frames; scaled testbed
    noise: float = 0.02
    num_joints: int = graph.NUM_JOINTS


def _class_programs(cfg: DataConfig) -> list[dict]:
    """Deterministic per-class motion programs."""
    rng = np.random.default_rng(1234)
    limb_names = list(_LIMBS)
    programs = []
    for c in range(cfg.num_classes):
        active = [limb_names[c % len(limb_names)],
                  limb_names[(c // len(limb_names) + 1) % len(limb_names)]]
        programs.append({
            "limbs": active,
            "freq": 0.5 + 0.35 * (c % 5) + rng.uniform(0, 0.1),
            "amp": 0.10 + 0.04 * (c % 3),
            "phase": rng.uniform(0, 2 * np.pi),
            "axis": rng.dirichlet(np.ones(3)),
        })
    return programs


def generate(cfg: DataConfig, num_samples: int, seed: int = 0
             ) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``(x, y)``: ``x`` is ``(N, 3, T, V)`` float32, ``y`` int32."""
    rng = np.random.default_rng(seed)
    programs = _class_programs(cfg)
    t = np.arange(cfg.seq_len) / cfg.seq_len * 2 * np.pi
    x = np.zeros((num_samples, 3, cfg.seq_len, cfg.num_joints),
                 dtype=np.float64)
    y = rng.integers(0, cfg.num_classes, size=num_samples).astype(np.int32)
    for n in range(num_samples):
        prog = programs[y[n]]
        pose = np.broadcast_to(
            _REST.T[:, None, :], (3, cfg.seq_len, cfg.num_joints)).copy()
        # limb motion: sinusoid on the active limbs, children move more
        for limb in prog["limbs"]:
            joints = _LIMBS[limb]
            for depth, j in enumerate(joints):
                amp = prog["amp"] * (1.0 + 0.35 * depth)
                wave = amp * np.sin(prog["freq"] * t * cfg.seq_len / 16
                                    + prog["phase"] + 0.3 * depth)
                for ax in range(3):
                    pose[ax, :, j] += prog["axis"][ax] * wave
        # random global rotation about y + scale (camera variation)
        theta = rng.uniform(-0.4, 0.4)
        s = rng.uniform(0.9, 1.1)
        rot = np.array([[np.cos(theta), 0, np.sin(theta)],
                        [0, 1, 0],
                        [-np.sin(theta), 0, np.cos(theta)]])
        pose = np.einsum("ab,btv->atv", rot * s, pose)
        pose += rng.normal(0, cfg.noise, size=pose.shape)
        x[n] = pose
    return x.astype(np.float32), y


def bone_stream(x: np.ndarray) -> np.ndarray:
    """Second stream of 2s-AGCN: bone vectors ``x[child] - x[parent]``."""
    out = np.zeros_like(x)
    for child, parent in graph.bone_pairs():
        out[..., child] = x[..., child] - x[..., parent]
    return out


def input_skip(x: np.ndarray, factor: int = 2) -> np.ndarray:
    """Paper's input-skipping: keep every ``factor``-th skeleton vector
    (half the 300 input frames in the paper), halving total compute."""
    return np.ascontiguousarray(x[:, :, ::factor, :])
