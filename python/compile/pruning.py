"""Hybrid pruning for 2s-AGCN (paper §IV).

Three cooperating mechanisms:

1. **Dataflow reorganization** (§IV-A, eq. 4 -> eq. 5): pruning an entire
   *input channel* of the 1x1 spatial-conv weight lets the accelerator skip
   the matching *graph* contraction too.  Channel selection drops the input
   channels with the least mean absolute weight
   (:func:`select_kept_channels`).  Per-layer drop-rate schedules Drop-1/2/3
   reproduce Fig. 9.

2. **Coarse-grained temporal pruning** (§IV-B, Fig. 2): a spatial input
   channel of block *l* is fed by temporal filter *ic* of block *l-1*;
   dropping the former makes the latter dead weight, so its whole 9x1xC
   filter is removed with zero extra accuracy cost
   (:func:`coarse_temporal_kept`).

3. **Fine-grained "cavity" pruning** (§IV-B, Fig. 3): recurrent sampling
   patterns over the 9 temporal taps, one 9-bit mask per filter in a loop of
   8 filters.  Balanced patterns (every tap row kept 2-3 times across the
   loop, e.g. ``cav-70-1``) keep accuracy and hardware balance; unbalanced
   ones (``cav-70-2``) are included as the paper's negative control.

Also provided: an **unstructured magnitude-pruning baseline** (Fig. 8's
comparator) and compression-ratio accounting used by Figs. 8-10 and the
Rust resource model.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

TEMPORAL_K = 9   # 9x1 temporal kernel
LOOP = 8         # cavity patterns recur over loops of 8 filters


# --------------------------------------------------------------------------
# Cavity (fine-grained temporal) patterns
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class CavityScheme:
    """A recurrent fine-grained pruning pattern for 9x1 temporal filters.

    ``masks`` is an ``(8, 9)`` boolean array: row *i* is the tap-keep mask
    applied to every filter whose output-channel index is ``i (mod 8)``.
    """

    name: str
    masks: tuple[tuple[bool, ...], ...]

    def as_array(self) -> np.ndarray:
        return np.asarray(self.masks, dtype=bool)

    @property
    def keep_ratio(self) -> float:
        m = self.as_array()
        return float(m.sum()) / m.size

    @property
    def prune_ratio(self) -> float:
        return 1.0 - self.keep_ratio

    def tap_coverage(self) -> np.ndarray:
        """How many of the 8 filters keep each of the 9 taps (Fig. 3/10)."""
        return self.as_array().sum(axis=0)

    def balance_spread(self) -> int:
        """max - min tap coverage; 0-1 = balanced, large = unbalanced."""
        cov = self.tap_coverage()
        return int(cov.max() - cov.min())

    def kept_taps(self, filter_index: int) -> list[int]:
        """Static tap indices kept for filter ``filter_index``."""
        return [t for t in range(TEMPORAL_K)
                if self.masks[filter_index % LOOP][t]]

    def max_taps(self) -> int:
        return max(len(self.kept_taps(i)) for i in range(LOOP))


def _masks_from_strings(rows: list[str]) -> tuple[tuple[bool, ...], ...]:
    assert len(rows) == LOOP
    out = []
    for r in rows:
        assert len(r) == TEMPORAL_K
        out.append(tuple(c == "1" for c in r))
    return tuple(out)


def _interleave(interval: int, offsets: list[int]) -> list[str]:
    """Sampling-style masks: filter i keeps taps t with (t+off_i)%interval==0."""
    rows = []
    for i in range(LOOP):
        off = offsets[i % len(offsets)]
        rows.append("".join(
            "1" if (t + off) % interval == 0 else "0"
            for t in range(TEMPORAL_K)))
    return rows


# The schemes explored in Fig. 10. Keep-counts: dense keeps all 72 positions
# of the 9x8 loop; cav-NN keeps ~(1-NN%)*72.
CAVITY_SCHEMES: dict[str, CavityScheme] = {}


def _register(name: str, rows: list[str]) -> CavityScheme:
    s = CavityScheme(name, _masks_from_strings(rows))
    CAVITY_SCHEMES[name] = s
    return s


# 50% pruned: interval-2 sampling, alternating phase -> every tap kept 4x.
CAV_50 = _register("cav-50", _interleave(2, [0, 1]))

# 67% pruned: interval-3 sampling with rotating phase -> taps kept ~2-3x.
CAV_67 = _register("cav-67", _interleave(3, [0, 1, 2]))

# ~70% pruned, balanced (the paper's chosen design): 22/72 kept, each tap
# row sampled 2-3 times across the loop ("two or three sampling chances").
CAV_70_1 = _register("cav-70-1", [
    "100100100",  # taps 0,3,6
    "010010010",  # taps 1,4,7
    "001001001",  # taps 2,5,8
    "111000000",  # taps 0,1,2
    "000111000",  # taps 3,4,5
    "100000100",  # taps 0,6
    "010100010",  # taps 1,3,7
    "001000001",  # taps 2,8
])

# ~70% pruned, unbalanced control: same 22 kept weights, but tap rows are
# sampled from 1 to 4 times -> worse accuracy in Fig. 10.
CAV_70_2 = _register("cav-70-2", [
    "111000000",
    "110100000",
    "110010000",
    "110001000",
    "001100100",
    "001010010",
    "000100001",
    "001001000",
])

# 75% pruned, balanced: 18/72 kept, every tap row exactly 2x.
CAV_75_1 = _register("cav-75-1", [
    "100100100",
    "010010010",
    "001001001",
    "110000000",
    "000110000",
    "001000100",
    "000001000",
    "000000011",
])

# 75% pruned, unbalanced control: 18/72 kept, tap coverage ranges 0-6.
CAV_75_2 = _register("cav-75-2", [
    "111100000",
    "111000000",
    "110000000",
    "110000000",
    "100000000",
    "100000001",
    "010000000",
    "111000000",
])

DENSE_SCHEME = _register("dense", ["1" * TEMPORAL_K] * LOOP)


# --------------------------------------------------------------------------
# Channel dropping (dataflow reorganization)
# --------------------------------------------------------------------------

def select_kept_channels(w_spatial: np.ndarray, drop_rate: float) -> np.ndarray:
    """Choose spatial-conv input channels to keep.

    ``w_spatial`` has shape ``(K_V, IC, OC)`` (1x1 kernels).  Following the
    paper, the input channels with the least mean |w| across all k_v subsets
    and output channels are dropped; the survivors are returned as a sorted
    index array.  ``drop_rate`` is the fraction of input channels removed.
    """
    if not 0.0 <= drop_rate < 1.0:
        raise ValueError(f"drop_rate must be in [0, 1), got {drop_rate}")
    ic = w_spatial.shape[1]
    n_drop = int(round(drop_rate * ic))
    n_keep = max(1, ic - n_drop)
    score = np.abs(w_spatial).mean(axis=(0, 2))  # (IC,)
    kept = np.sort(np.argsort(score)[::-1][:n_keep])
    return kept.astype(np.int32)


def coarse_temporal_kept(next_block_kept: np.ndarray) -> np.ndarray:
    """Coarse-grained rule (Fig. 2): temporal filters of block *l* that feed
    dropped spatial input channels of block *l+1* are pruned.  The kept
    temporal filter (output-channel) indices are exactly the kept spatial
    input channels of the next block."""
    return np.asarray(next_block_kept, dtype=np.int32)


# Per-layer channel-drop schedules explored in Fig. 9.  Block 1 is never
# pruned (only 3 input channels).  Rates loosely track the per-layer feature
# sparsity (Drop-1) and are raised progressively (Drop-2, Drop-3).
DROP_SCHEDULES: dict[str, list[float]] = {
    # blocks:   1     2     3     4     5     6     7     8     9    10
    "drop-0": [0.0] * 10,
    "drop-1": [0.0, 0.25, 0.25, 0.375, 0.375, 0.50, 0.50, 0.50, 0.625, 0.625],
    "drop-2": [0.0, 0.375, 0.375, 0.50, 0.50, 0.625, 0.625, 0.625, 0.75, 0.75],
    "drop-3": [0.0, 0.50, 0.50, 0.625, 0.625, 0.75, 0.75, 0.75, 0.875, 0.875],
}


# --------------------------------------------------------------------------
# Whole-model pruning plan
# --------------------------------------------------------------------------

@dataclass
class PruningPlan:
    """Everything the hardware (and the JAX model) needs to apply hybrid
    pruning: per-block kept input channels for the spatial conv, per-block
    kept output filters for the temporal conv, and the cavity scheme."""

    kept_spatial_in: list[np.ndarray]   # per block, kept IC indices
    kept_temporal_out: list[np.ndarray]  # per block, kept OC indices
    cavity: CavityScheme
    schedule: str = "drop-1"
    meta: dict = field(default_factory=dict)

    @property
    def num_blocks(self) -> int:
        return len(self.kept_spatial_in)

    def graph_skip_ratio(self, in_channels: list[int]) -> float:
        """Fraction of graph-contraction work skipped (paper: 73.20% for the
        balanced design) = dropped input channels weighted by per-block
        graph workload (proportional to T*V*V*IC)."""
        total = 0.0
        skipped = 0.0
        for kept, ic in zip(self.kept_spatial_in, in_channels):
            total += ic
            skipped += ic - len(kept)
        return skipped / total if total else 0.0


def build_plan(
    spatial_weights: list[np.ndarray],
    out_channels: list[int],
    schedule: str = "drop-1",
    cavity: CavityScheme = CAV_70_1,
) -> PruningPlan:
    """Derive a :class:`PruningPlan` from trained spatial weights.

    ``spatial_weights[l]`` has shape ``(K_V, IC_l, OC_l)``.  The temporal
    filters kept in block *l* are the spatial input channels kept in block
    *l+1* (coarse rule); the last block's temporal filters all survive
    because they feed the FC layer directly.
    """
    rates = DROP_SCHEDULES[schedule]
    if len(spatial_weights) != len(rates):
        raise ValueError(
            f"schedule {schedule} covers {len(rates)} blocks, "
            f"model has {len(spatial_weights)}")
    kept_in = [select_kept_channels(w, r)
               for w, r in zip(spatial_weights, rates)]
    kept_t: list[np.ndarray] = []
    for l in range(len(spatial_weights)):
        if l + 1 < len(spatial_weights):
            kept_t.append(coarse_temporal_kept(kept_in[l + 1]))
        else:
            kept_t.append(np.arange(out_channels[l], dtype=np.int32))
    return PruningPlan(kept_in, kept_t, cavity, schedule)


# --------------------------------------------------------------------------
# Compression accounting + unstructured baseline
# --------------------------------------------------------------------------

def spatial_param_count(kept_in: np.ndarray, oc: int, k_v: int = 3) -> int:
    return k_v * len(kept_in) * oc


def temporal_param_count(ic: int, kept_out: np.ndarray,
                         cavity: CavityScheme) -> int:
    """Kept temporal weights: per kept filter, only the cavity-kept taps."""
    total = 0
    for i, _ in enumerate(kept_out):
        total += len(cavity.kept_taps(i)) * ic
    return total


def model_compression_ratio(
    in_channels: list[int], out_channels: list[int], plan: PruningPlan,
    k_v: int = 3,
) -> float:
    """dense params / pruned params over all conv blocks (paper: 3.0x-8.4x)."""
    dense = 0
    pruned = 0
    for l, (ic, oc) in enumerate(zip(in_channels, out_channels)):
        dense += k_v * ic * oc                    # spatial
        dense += TEMPORAL_K * oc * oc             # temporal (oc -> oc)
        pruned += spatial_param_count(plan.kept_spatial_in[l], oc, k_v)
        pruned += temporal_param_count(oc, plan.kept_temporal_out[l],
                                       plan.cavity)
    return dense / max(1, pruned)


def unstructured_prune(w: np.ndarray, rate: float) -> np.ndarray:
    """Magnitude pruning baseline: zero the ``rate`` fraction of smallest
    |w| entries (the Fig. 8 comparator).  Returns a 0/1 mask."""
    flat = np.abs(w).ravel()
    k = int(round(rate * flat.size))
    if k == 0:
        return np.ones_like(w)
    thresh = np.partition(flat, k - 1)[k - 1]
    return (np.abs(w) > thresh).astype(w.dtype)
