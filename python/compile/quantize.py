"""Q8.8 fixed-point quantization (paper SSVI-A).

The paper converts the pruned model to 16-bit fixed point, "where eight
bits are allocated to decimal part and eight to integer part".  That is
symmetric Q8.8: value = q / 256, q in int16, representable range
[-128, 128) with 1/256 resolution.

Both a numpy/jnp *simulated* path (quantize -> dequantize, used to measure
accuracy impact in Fig. 8's "+quant" points) and true int16 helpers (used
with :mod:`kernels.quant_matmul`) are provided.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

FRAC_BITS = 8
SCALE = 1 << FRAC_BITS          # 256
QMIN, QMAX = -32768, 32767


def quantize(x, frac_bits: int = FRAC_BITS):
    """float -> int16 Q(16-f).f with round-to-nearest and saturation."""
    scale = 1 << frac_bits
    q = jnp.round(jnp.asarray(x) * scale)
    return jnp.clip(q, QMIN, QMAX).astype(jnp.int16)


def dequantize(q, frac_bits: int = FRAC_BITS):
    """int16 Q(16-f).f -> float32."""
    return q.astype(jnp.float32) / (1 << frac_bits)


def fake_quant(x, frac_bits: int = FRAC_BITS):
    """Quantize-dequantize in float (straight-through in value space).

    This is what the accuracy experiments apply to weights and activations
    to measure the Q8.8 accuracy cost without running integer kernels.
    """
    return dequantize(quantize(x, frac_bits), frac_bits)


def fake_quant_tree(params, frac_bits: int = FRAC_BITS):
    """Apply :func:`fake_quant` to every leaf of a parameter pytree."""
    return jax.tree_util.tree_map(lambda p: fake_quant(p, frac_bits), params)


def quant_error(x, frac_bits: int = FRAC_BITS) -> float:
    """Max |x - fake_quant(x)| -- bounded by 1/2^(f+1) within range."""
    return float(np.max(np.abs(np.asarray(x) - np.asarray(fake_quant(x, frac_bits)))))
