"""Two-stream ensemble (the "2s" in 2s-AGCN).

2s-AGCN runs two identical AGCN networks -- one on joint coordinates, one
on bone vectors (child - parent along the skeleton) -- and sums their
softmax scores.  The accelerator paper prunes and maps a single stream;
this module provides the second stream so the reproduction covers the
complete published model: train both streams, fuse, and measure the
ensemble gain.

Run: ``python -m compile.ensemble [--steps N]``
Writes ``artifacts/experiments/ensemble.json``.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import train as train_mod
from .agcn import model as model_mod

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                   "experiments")


def fuse_logits(logits_joint, logits_bone, alpha: float = 0.5):
    """Score-level fusion: weighted sum of per-stream softmax scores."""
    pj = jax.nn.softmax(jnp.asarray(logits_joint), axis=-1)
    pb = jax.nn.softmax(jnp.asarray(logits_bone), axis=-1)
    return alpha * pj + (1.0 - alpha) * pb


def evaluate_ensemble(params_j, params_b, cfg, xte, yte, alpha=0.5,
                      batch=128):
    """Accuracy of joint-only, bone-only and the fused two-stream model."""
    fn = jax.jit(lambda p, x: model_mod.forward(p, x, cfg))
    xb = data_mod.bone_stream(xte)
    accs = {"joint": 0.0, "bone": 0.0, "fused": 0.0}
    n = 0
    for i in range(0, len(xte), batch):
        xj = jnp.asarray(xte[i:i + batch])
        xbn = jnp.asarray(xb[i:i + batch])
        y = jnp.asarray(yte[i:i + batch])
        lj = fn(params_j, xj)
        lb = fn(params_b, xbn)
        k = len(y)
        accs["joint"] += train_mod.accuracy(lj, y) * k
        accs["bone"] += train_mod.accuracy(lb, y) * k
        fused = fuse_logits(lj, lb, alpha)
        accs["fused"] += float((jnp.argmax(fused, 1) == y).mean()) * k
        n += k
    return {k: v / n for k, v in accs.items()}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--noise", type=float, default=0.22)
    args = ap.parse_args()

    cfg = model_mod.ModelConfig(num_classes=args.classes,
                                seq_len=args.seq_len, width_mult=0.25)
    dcfg = data_mod.DataConfig(num_classes=args.classes,
                               seq_len=args.seq_len, noise=args.noise)
    xtr, ytr = data_mod.generate(dcfg, 512, seed=0)
    xte, yte = data_mod.generate(dcfg, 256, seed=10_000)
    tcfg = train_mod.TrainConfig(steps=args.steps, batch=32,
                                 num_train=len(xtr))

    print("training joint stream...")
    pj, hj = train_mod.train(cfg, tcfg, dataset=(xtr, ytr, xte, yte),
                             verbose=False)
    print(f"  joint acc {hj['test_acc']:.4f}")
    print("training bone stream...")
    xtr_b = data_mod.bone_stream(xtr)
    pb, hb = train_mod.train(cfg, tcfg,
                             dataset=(xtr_b, ytr,
                                      data_mod.bone_stream(xte), yte),
                             verbose=False)
    print(f"  bone acc {hb['test_acc']:.4f}")

    accs = evaluate_ensemble(pj, pb, cfg, xte, yte)
    print(f"ensemble: {accs}")
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "ensemble.json"), "w") as f:
        json.dump({"accuracy": accs,
                   "config": {"classes": args.classes,
                              "seq_len": args.seq_len,
                              "steps": args.steps}}, f, indent=2)
    print("wrote ensemble.json")


if __name__ == "__main__":
    main()
