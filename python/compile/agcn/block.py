"""One 2s-AGCN convolutional block (paper Fig. 1, left).

Per block: graph computation + spatial conv (fused, reorganized dataflow)
-> BN -> ReLU -> 9x1 temporal conv -> BN -> (+ shortcut) -> ReLU.

The block supports four execution variants, combinable:

- ``with_ck``      -- add the self-similarity graph ``C_k`` (eq. 1);
- pruned           -- apply a :class:`..pruning.PruningPlan`: kept input
  channels are *gathered* before the fused gconv (graph skip!), kept
  temporal filters computed and *scattered* back to full width, so block
  I/O stays full-width and exactly matches mask-based semantics;
- ``use_kernels``  -- route the heavy math through the Pallas kernels;
- ``folded_bn``    -- use affine (calibration-folded) normalization, the
  hardware/AOT path.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .. import pruning
from . import layers


@dataclass(frozen=True)
class BlockSpec:
    """Static per-block hyperparameters."""

    in_channels: int
    out_channels: int
    stride: int = 1

    @property
    def has_projection(self) -> bool:
        return self.in_channels != self.out_channels or self.stride != 1


def init_block(rng: np.random.Generator, spec: BlockSpec, k_v: int = 3,
               embed_dim: Optional[int] = None) -> dict:
    """He-style init for one block's parameters (numpy, converted lazily)."""
    ic, oc = spec.in_channels, spec.out_channels
    e = embed_dim or max(4, oc // 4)

    def he(*shape, fan):
        return (rng.standard_normal(shape) * np.sqrt(2.0 / fan)
                ).astype(np.float32)

    p = {
        "bk": np.zeros((k_v, 25, 25), dtype=np.float32),  # learnable graph
        "w_spatial": he(k_v, ic, oc, fan=ic * k_v),
        "bn_s": {"scale": np.ones(oc, np.float32),
                 "bias": np.zeros(oc, np.float32)},
        "w_temporal": he(pruning.TEMPORAL_K, oc, oc,
                         fan=oc * pruning.TEMPORAL_K),
        "bn_t": {"scale": np.ones(oc, np.float32),
                 "bias": np.zeros(oc, np.float32)},
        "w_theta": he(ic, e, fan=ic),
        "w_phi": he(ic, e, fan=ic),
    }
    if spec.has_projection:
        p["w_short"] = he(ic, oc, fan=ic)
        p["bn_sc"] = {"scale": np.ones(oc, np.float32),
                      "bias": np.zeros(oc, np.float32)}
    return p


def block_forward(
    params: dict,
    x,
    spec: BlockSpec,
    a_stack,
    *,
    with_ck: bool = False,
    kept_in: Optional[np.ndarray] = None,
    kept_t_out: Optional[np.ndarray] = None,
    cavity: pruning.CavityScheme = pruning.DENSE_SCHEME,
    use_kernels: bool = False,
    folded_bn: bool = False,
    collect: Optional[list] = None,
    norm_fn=None,
):
    """Run one block. ``x``: ``(N, T, V, IC)`` -> ``(N, T', V, OC)``.

    ``kept_in`` / ``kept_t_out``: kept spatial input channels and kept
    temporal output filters (from a PruningPlan).  ``None`` = dense.
    ``collect``: if given, the post-ReLU spatial-conv activation and the
    block output are appended as ("sconv", y) / ("tconv", out) -- the
    traces behind Table III and the RFC mini-bank sizing.
    """
    norm = norm_fn or (layers.affine if folded_bn else layers.batch_norm)
    g = a_stack + jnp.asarray(params["bk"])          # (K, V, V)

    w_s = jnp.asarray(params["w_spatial"])
    xin = x
    if kept_in is not None:
        # dataflow reorganization: dropped channels never enter the graph
        # contraction -- this is the paper's graph-skipping.
        xin = layers.gather_channels(x, kept_in)
        w_s = jnp.take(w_s, jnp.asarray(kept_in), axis=1)

    if with_ck:
        ck = layers.self_similarity(xin, jnp.asarray(params["w_theta"]),
                                    jnp.asarray(params["w_phi"]))
        g_full = g[None, :, :, :] + ck[:, None, :, :]
        y = layers.gconv(xin, g_full, w_s)
    else:
        y = layers.gconv(xin, g, w_s, use_kernels=use_kernels)

    y = norm(y, jnp.asarray(params["bn_s"]["scale"]),
             jnp.asarray(params["bn_s"]["bias"]))
    y = layers.relu(y)
    if collect is not None:
        collect.append(("sconv", y))

    w_t = jnp.asarray(params["w_temporal"])
    if kept_t_out is not None:
        w_t = jnp.take(w_t, jnp.asarray(kept_t_out), axis=2)
        # kernel path needs OC % 8 == 0: pad filters up, scatter back after
        pad = (-len(kept_t_out)) % pruning.LOOP
        if pad and use_kernels:
            w_t = jnp.pad(w_t, ((0, 0), (0, 0), (0, pad)))
    yt = layers.tconv(y, w_t, cavity, stride=spec.stride,
                      use_kernels=use_kernels)
    if kept_t_out is not None:
        if use_kernels and (-len(kept_t_out)) % pruning.LOOP:
            yt = yt[..., : len(kept_t_out)]
        yt = layers.scatter_channels(yt, kept_t_out, spec.out_channels)
    yt = norm(yt, jnp.asarray(params["bn_t"]["scale"]),
              jnp.asarray(params["bn_t"]["bias"]))

    if spec.has_projection:
        sc = layers.shortcut(x, jnp.asarray(params["w_short"]),
                             stride=spec.stride)
        sc = norm(sc, jnp.asarray(params["bn_sc"]["scale"]),
                  jnp.asarray(params["bn_sc"]["bias"]))
    else:
        sc = layers.shortcut(x, stride=spec.stride)
    out = layers.relu(yt + sc)
    if collect is not None:
        collect.append(("tconv", out))
    return out
