"""The full 2s-AGCN network and its variants.

Ten convolutional blocks + global pooling + FC (paper SSII).  The full-size
channel plan is 3 -> 64x4 -> 128x3 -> 256x3 with temporal strides 2 at the
width changes; ``width_mult`` scales every width (multiples of 8 preserved
for the cavity loop) so the testbed model trains in seconds on CPU.

Variant axes (all combinable):

==============  ==========================================================
``with_ck``     add the self-similarity graph (Table I's w/C row)
``plan``        a :class:`..pruning.PruningPlan` -- hybrid-pruned forward
``use_kernels`` route heavy math through the Pallas kernels (AOT path)
``folded_bn``   affine normalization with calibration-folded statistics
==============  ==========================================================
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import pruning
from . import block as block_mod
from . import graph, layers

FULL_CHANNELS = [64, 64, 64, 64, 128, 128, 128, 256, 256, 256]
FULL_STRIDES = [1, 1, 1, 1, 2, 1, 1, 2, 1, 1]


@dataclass(frozen=True)
class ModelConfig:
    """Static network hyperparameters."""

    num_classes: int = 8
    seq_len: int = 64
    width_mult: float = 0.25
    in_channels: int = 3
    num_blocks: int = 10

    def block_specs(self) -> list[block_mod.BlockSpec]:
        widths = [max(8, int(c * self.width_mult) // 8 * 8)
                  for c in FULL_CHANNELS[: self.num_blocks]]
        specs = []
        ic = self.in_channels
        for w, s in zip(widths, FULL_STRIDES[: self.num_blocks]):
            specs.append(block_mod.BlockSpec(ic, w, s))
            ic = w
        return specs

    def out_seq_len(self) -> int:
        t = self.seq_len
        for s in FULL_STRIDES[: self.num_blocks]:
            t = -(-t // s)
        return t


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Initialise all parameters (numpy arrays; jit converts lazily)."""
    rng = np.random.default_rng(seed)
    specs = cfg.block_specs()
    blocks = [block_mod.init_block(rng, s) for s in specs]
    c_last = specs[-1].out_channels
    fc_w = (rng.standard_normal((c_last, cfg.num_classes))
            * np.sqrt(1.0 / c_last)).astype(np.float32)
    fc_b = np.zeros(cfg.num_classes, np.float32)
    return {
        "input_bn": {"scale": np.ones(cfg.in_channels, np.float32),
                     "bias": np.zeros(cfg.in_channels, np.float32)},
        "blocks": blocks,
        "fc": {"w": fc_w, "b": fc_b},
    }


def forward(
    params: dict,
    x,
    cfg: ModelConfig,
    *,
    with_ck: bool = False,
    plan: Optional[pruning.PruningPlan] = None,
    use_kernels: bool = False,
    folded_bn: bool = False,
    norm_fn=None,
):
    """Full-network forward. ``x``: ``(N, C, T, V)`` -> logits ``(N, cls)``."""
    a_stack = jnp.asarray(graph.spatial_partitions())
    norm = norm_fn or (layers.affine if folded_bn else layers.batch_norm)
    h = jnp.transpose(jnp.asarray(x), (0, 2, 3, 1))  # (N, T, V, C)
    h = norm(h, jnp.asarray(params["input_bn"]["scale"]),
             jnp.asarray(params["input_bn"]["bias"]))
    specs = cfg.block_specs()
    for l, (p, spec) in enumerate(zip(params["blocks"], specs)):
        kept_in = plan.kept_spatial_in[l] if plan else None
        kept_t = plan.kept_temporal_out[l] if plan else None
        cavity = plan.cavity if plan else pruning.DENSE_SCHEME
        # never prune block 1 (3 input channels) nor the last temporal
        # filters feeding FC -- build_plan already guarantees both.
        h = block_mod.block_forward(
            p, h, spec, a_stack,
            with_ck=with_ck, kept_in=kept_in, kept_t_out=kept_t,
            cavity=cavity, use_kernels=use_kernels, folded_bn=folded_bn,
            norm_fn=norm_fn)
    pooled = h.mean(axis=(1, 2))                     # (N, C_last)
    return pooled @ jnp.asarray(params["fc"]["w"]) + jnp.asarray(
        params["fc"]["b"])


def forward_collect(params, x, cfg: ModelConfig, *,
                    plan: Optional[pruning.PruningPlan] = None,
                    with_ck: bool = False):
    """Like :func:`forward` but also returns per-layer post-ReLU
    activations ``[("b{l}.sconv", act), ("b{l}.tconv", act), ...]`` --
    the traces behind Table III / Fig. 9 / RFC sizing."""
    a_stack = jnp.asarray(graph.spatial_partitions())
    h = jnp.transpose(jnp.asarray(x), (0, 2, 3, 1))
    h = layers.batch_norm(h, jnp.asarray(params["input_bn"]["scale"]),
                          jnp.asarray(params["input_bn"]["bias"]))
    acts: list = []
    specs = cfg.block_specs()
    for l, (p, spec) in enumerate(zip(params["blocks"], specs)):
        coll: list = []
        h = block_mod.block_forward(
            p, h, spec, a_stack,
            with_ck=with_ck,
            kept_in=plan.kept_spatial_in[l] if plan else None,
            kept_t_out=plan.kept_temporal_out[l] if plan else None,
            cavity=plan.cavity if plan else pruning.DENSE_SCHEME,
            collect=coll)
        acts.extend((f"b{l + 1}.{name}", a) for name, a in coll)
    pooled = h.mean(axis=(1, 2))
    logits = pooled @ jnp.asarray(params["fc"]["w"]) + jnp.asarray(
        params["fc"]["b"])
    return logits, acts


def calibrate_fold(params: dict, x, cfg: ModelConfig, *,
                   plan: Optional[pruning.PruningPlan] = None) -> dict:
    """Fold batch-norm into affine (scale, bias) using calibration data.

    Runs one eager forward over calibration batch ``x`` capturing the
    batch statistics at every norm site in call order (input_bn, then per
    block bn_s, bn_t, [bn_sc]), then returns a parameter tree where each
    bn dict holds the *folded* scale/bias -- the deterministic
    inference-time normalization the hardware uses (use with
    ``folded_bn=True``).
    """
    stats: list[tuple[np.ndarray, np.ndarray]] = []

    def capture(h, scale, bias):
        mean = h.mean(axis=(0, 1, 2))
        var = h.var(axis=(0, 1, 2))
        stats.append((np.asarray(mean), np.asarray(var)))
        return (h - mean) * jax.lax.rsqrt(var + layers.EPS) * scale + bias

    forward(params, x, cfg, plan=plan, norm_fn=capture)

    folded = jax.tree_util.tree_map(np.asarray, params)
    order = iter(stats)

    def fold(bn):
        mean, var = next(order)
        s, b = layers.fold_bn(np.asarray(bn["scale"]),
                              np.asarray(bn["bias"]), mean, var)
        return {"scale": s.astype(np.float32), "bias": b.astype(np.float32)}

    folded["input_bn"] = fold(folded["input_bn"])
    for bp, spec in zip(folded["blocks"], cfg.block_specs()):
        bp["bn_s"] = fold(bp["bn_s"])
        bp["bn_t"] = fold(bp["bn_t"])
        if spec.has_projection:
            bp["bn_sc"] = fold(bp["bn_sc"])
    remaining = len(list(order))
    if remaining:
        raise RuntimeError(f"unconsumed calibration stats: {remaining}")
    return folded


def save_params(path: str, params: dict) -> None:
    """Flatten the parameter pytree into an .npz keyed by tree paths."""
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}/{k}", v)
        elif isinstance(node, list):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = np.asarray(node)

    walk("p", params)
    np.savez(path, **flat)


def load_params(path: str, cfg: ModelConfig) -> dict:
    """Inverse of :func:`save_params` (structure from ``init_params``)."""
    flat = dict(np.load(path))
    template = init_params(cfg)

    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}/{k}", v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(f"{prefix}/{i}", v) for i, v in enumerate(node)]
        return flat[prefix]

    return walk("p", template)


def block_io_shapes(cfg: ModelConfig, batch: int) -> list[tuple]:
    """(in_shape, out_shape) per block in (N, T, V, C) layout -- consumed
    by aot.py and mirrored in artifacts/meta.json for the Rust pipeline."""
    shapes = []
    t = cfg.seq_len
    for spec in cfg.block_specs():
        t_out = -(-t // spec.stride)
        shapes.append(((batch, t, graph.NUM_JOINTS, spec.in_channels),
                       (batch, t_out, graph.NUM_JOINTS, spec.out_channels)))
        t = t_out
    return shapes


def spatial_weights(params: dict) -> list[np.ndarray]:
    """Per-block spatial weights ``(K, IC, OC)`` for pruning selection."""
    return [np.asarray(b["w_spatial"]) for b in params["blocks"]]


def make_plan(params: dict, cfg: ModelConfig, schedule: str = "drop-1",
              cavity: pruning.CavityScheme = pruning.CAV_70_1
              ) -> pruning.PruningPlan:
    """Build a hybrid-pruning plan from this model's trained weights."""
    specs = cfg.block_specs()
    rates = pruning.DROP_SCHEDULES[schedule][: cfg.num_blocks]
    if len(rates) < cfg.num_blocks:
        rates = rates + [rates[-1]] * (cfg.num_blocks - len(rates))
    saved = pruning.DROP_SCHEDULES.get("__tmp__")
    pruning.DROP_SCHEDULES["__tmp__"] = rates
    try:
        plan = pruning.build_plan(
            spatial_weights(params),
            [s.out_channels for s in specs],
            schedule="__tmp__", cavity=cavity)
        plan.schedule = schedule
    finally:
        if saved is None:
            pruning.DROP_SCHEDULES.pop("__tmp__", None)
        else:
            pruning.DROP_SCHEDULES["__tmp__"] = saved
    return plan


def compression_ratio(cfg: ModelConfig, plan: pruning.PruningPlan) -> float:
    specs = cfg.block_specs()
    return pruning.model_compression_ratio(
        [s.in_channels for s in specs], [s.out_channels for s in specs],
        plan)
