"""Primitive layers for 2s-AGCN (functional JAX).

Internal layout is ``(N, T, V, C)`` (time-major, channels-last) so the
graph axis ``V`` and channel axes line up with the Pallas kernels.  The
public dataset layout ``(N, C, T, V)`` is converted at the model boundary.

Two execution paths exist for the heavy ops:

- **jnp path** (default for training) -- the pure-jnp oracles from
  :mod:`..kernels.ref`, fast under jit on CPU.
- **kernel path** (``use_kernels=True``, used for AOT export and
  kernel-equivalence tests) -- the Pallas kernels in interpret mode, which
  lower into the exported HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import pruning
from ..kernels.fused_gconv import fused_gconv as _fused_gconv
from ..kernels.temporal_conv import temporal_conv as _temporal_conv
from ..kernels import ref as kref

EPS = 1e-5


# --------------------------------------------------------------------------
# Normalization / activation
# --------------------------------------------------------------------------

def batch_norm(x, scale, bias):
    """Batch-stat batch-norm over all axes but the channel (last) axis."""
    mean = x.mean(axis=(0, 1, 2), keepdims=True)
    var = x.var(axis=(0, 1, 2), keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + EPS) * scale + bias


def affine(x, scale, bias):
    """Folded batch-norm (inference/AOT path): ``x * scale + bias``."""
    return x * scale + bias


def fold_bn(scale, bias, mean, var, dead_var: float = 1e-8):
    """Fold calibration statistics into an affine (scale', bias').

    Channels with ~zero calibration variance (dead/pruned channels) would
    fold into huge gains (scale / sqrt(eps)) that explode on any runtime
    deviation from the calibration constant; batch-norm itself maps a
    constant channel to plain ``bias``, so the fold pins those channels to
    (scale'=0, bias'=bias).
    """
    var = np.asarray(var)
    s = scale / np.sqrt(var + EPS)
    dead = var < dead_var
    s = np.where(dead, 0.0, s)
    b = np.where(dead, bias, bias - mean * s)
    return s, b


def relu(x):
    return jnp.maximum(x, 0.0)


# --------------------------------------------------------------------------
# Graph + spatial convolution (reorganized dataflow, eq. 5)
# --------------------------------------------------------------------------

def gconv(x, g_stack, w_spatial, *, use_kernels: bool = False,
          block_t: int = 32):
    """Graph contraction + 1x1 spatial conv, summed over the K_V subsets.

    Args:
      x: ``(N, T, V, IC)``.
      g_stack: ``(K, V, V)`` -- ``A_k + B_k`` (plus ``C_k`` already added by
        the caller for the with-C variant, in which case g_stack is
        ``(N, K, V, V)``).
      w_spatial: ``(K, IC, OC)``.

    Returns ``(N, T, V, OC)``.
    """
    n, t, v, ic = x.shape
    if g_stack.ndim == 4:
        # per-sample graphs (C_k variant): jnp path only
        return jnp.einsum("ntpi,nkpw,kio->ntwo", x, g_stack, w_spatial)
    if use_kernels:
        flat = x.reshape(n * t, v, ic)
        pad = (-flat.shape[0]) % block_t
        if pad:
            flat = jnp.pad(flat, ((0, pad), (0, 0), (0, 0)))
        out = _fused_gconv(flat, g_stack, w_spatial,
                                           block_t=block_t)
        if pad:
            out = out[: n * t]
        return out.reshape(n, t, v, -1)
    return jnp.einsum("ntpi,kpw,kio->ntwo", x, g_stack, w_spatial)


def self_similarity(x, w_theta, w_phi):
    """The data-dependent graph ``C_k`` (paper eq. 1, 2s-AGCN style).

    Args:
      x: ``(N, T, V, C)``.
      w_theta, w_phi: ``(C, Ce)`` embedding projections.

    Returns ``(N, V, V)`` row-softmax similarity.
    """
    th = jnp.einsum("ntvc,ce->ntve", x, w_theta)
    ph = jnp.einsum("ntvc,ce->ntve", x, w_phi)
    n, t, v, e = th.shape
    a = jnp.einsum("ntve,ntwe->nvw", th, ph) / (t * e)
    return jax.nn.softmax(a, axis=-1)


# --------------------------------------------------------------------------
# Temporal convolution (9x1, cavity-masked)
# --------------------------------------------------------------------------

def tconv(x, w_temporal, scheme: pruning.CavityScheme, *, stride: int = 1,
          use_kernels: bool = False, block_t: int = 16):
    """Cavity-masked 9x1 temporal conv over the T axis.

    Args:
      x: ``(N, T, V, IC)``.
      w_temporal: ``(9, IC, OC)``; OC must be a multiple of 8 on the
        kernel path.

    Returns ``(N, ceil(T/stride), V, OC)``.
    """
    if use_kernels:
        t_out = -(-x.shape[1] // stride)
        bt = block_t
        while t_out % bt:
            bt //= 2  # T is a power-of-two multiple in all our configs
        fn = lambda f: _temporal_conv(
            f, w_temporal, scheme, stride=stride, block_t=max(1, bt))
        return jax.vmap(fn)(x)
    # jnp path: mask the taps, then let XLA's native conv do the work
    # (~3x faster than 9 tap einsums on CPU; equivalence is tested).
    oc = w_temporal.shape[2]
    masks = jnp.asarray(scheme.as_array(), dtype=w_temporal.dtype)
    reps = (oc + pruning.LOOP - 1) // pruning.LOOP
    tap_mask = jnp.tile(masks, (reps, 1))[:oc]           # (OC, 9)
    w_masked = w_temporal * tap_mask.T[:, None, :]       # (9, IC, OC)
    # explicit padding: pad_lo = 4 always (matches ref/kernel indexing);
    # XLA's SAME would split (3, 4) for even T at stride 2.
    t = x.shape[1]
    t_out = -(-t // stride)
    pad_hi = (t_out - 1) * stride + pruning.TEMPORAL_K - 4 - t
    return jax.lax.conv_general_dilated(
        x, w_masked[:, None], (stride, 1), ((4, pad_hi), (0, 0)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# --------------------------------------------------------------------------
# Shortcut path
# --------------------------------------------------------------------------

def shortcut(x, w=None, *, stride: int = 1):
    """Residual branch: identity, or strided 1x1 projection when the block
    changes width/stride (``w``: ``(IC, OC)``)."""
    if stride != 1:
        x = x[:, ::stride]
    if w is not None:
        x = jnp.einsum("ntvi,io->ntvo", x, w)
    return x


# --------------------------------------------------------------------------
# Channel gather/scatter for the pruned (compacted) forward
# --------------------------------------------------------------------------

def gather_channels(x, kept: np.ndarray):
    """Select kept input channels (the dataflow-reorganization skip).

    ``mode="clip"``: indices are statically in-bounds; jnp.take's default
    ``fill`` mode emits a NaN-fill gather that the AOT consumer
    (xla_extension 0.5.1 via HLO text) mis-executes.
    """
    return jnp.take(x, jnp.asarray(kept), axis=-1, mode="clip")


def scatter_channels(x_kept, kept: np.ndarray, full: int):
    """Scatter kept-channel results back to full width (zeros elsewhere)."""
    n, t, v, _ = x_kept.shape
    out = jnp.zeros((n, t, v, full), dtype=x_kept.dtype)
    return out.at[..., jnp.asarray(kept)].set(x_kept, mode="drop")
