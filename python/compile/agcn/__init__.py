"""2s-AGCN model components (JAX, build-time only).

The package mirrors the structure of the published 2s-AGCN network
(Shi et al., CVPR 2019) that RFC-HyPGCN accelerates:

- :mod:`graph`   -- the NTU-RGB+D 25-joint skeleton graph and its
  three-partition (k_v = 3) normalized adjacency stack ``A_k``.
- :mod:`layers`  -- primitive layers: graph+spatial convolution (with the
  paper's reorganized dataflow, eq. 5), 9x1 temporal convolution with
  cavity masks, batch-norm, shortcut projections.
- :mod:`block`   -- one convolutional block (graph conv -> spatial conv ->
  temporal conv -> shortcut), ten of which form the network.
- :mod:`model`   -- the full network, its pruned / quantized / input-skipped
  variants and parameter initialisation.
"""

from . import graph, layers, block, model  # noqa: F401

__all__ = ["graph", "layers", "block", "model"]
