"""NTU-RGB+D 25-joint skeleton graph.

Builds the three-partition adjacency stack ``A_k`` (k_v = 3) used by
ST-GCN / 2s-AGCN: identity (root), centripetal (towards the body centre)
and centrifugal (away from the centre) subsets, each D^-1-normalized.

The paper's eq. (2) computes ``sum_k f_in (A_k + B_k + C_k) (x) W_k``;
``A_k`` here is the static, unchangeable skeleton part. ``B_k`` (learnable,
dense) is a model parameter initialised to zero; ``C_k`` (self-similarity)
is computed at runtime by the model when the ``with_ck`` variant is chosen.
"""

from __future__ import annotations

import numpy as np

NUM_JOINTS = 25
K_V = 3  # neighbour partition count, fixed to 3 in 2s-AGCN
CENTER = 21 - 1  # joint 21 (spine mid, "21" in 1-based NTU labelling)

# NTU-RGB+D bone list, 1-based as published with the dataset.
_NTU_EDGES_1BASED = [
    (1, 2), (2, 21), (3, 21), (4, 3), (5, 21), (6, 5), (7, 6), (8, 7),
    (9, 21), (10, 9), (11, 10), (12, 11), (13, 1), (14, 13), (15, 14),
    (16, 15), (17, 1), (18, 17), (19, 18), (20, 19), (22, 23), (23, 8),
    (24, 25), (25, 12),
]

EDGES = [(i - 1, j - 1) for i, j in _NTU_EDGES_1BASED]


def adjacency() -> np.ndarray:
    """Symmetric 0/1 adjacency with self-loops, shape ``(V, V)``."""
    a = np.zeros((NUM_JOINTS, NUM_JOINTS), dtype=np.float64)
    for i, j in EDGES:
        a[i, j] = 1.0
        a[j, i] = 1.0
    np.fill_diagonal(a, 1.0)
    return a


def hop_distance(max_hop: int = NUM_JOINTS) -> np.ndarray:
    """All-pairs hop distance on the skeleton (inf where unreachable)."""
    a = adjacency()
    v = NUM_JOINTS
    dist = np.full((v, v), np.inf)
    power = np.eye(v)
    reach = np.zeros((v, v), dtype=bool)
    for d in range(max_hop + 1):
        newly = (power > 0) & ~reach
        dist[newly] = d
        reach |= power > 0
        power = power @ a
    return dist


def _normalize_digraph(a: np.ndarray) -> np.ndarray:
    """Column-normalize: ``a @ D^-1`` with D the column-sum degree."""
    deg = a.sum(axis=0)
    dn = np.zeros_like(a)
    idx = deg > 0
    dn[idx, idx] = 1.0 / deg[idx]
    return a @ dn


def spatial_partitions() -> np.ndarray:
    """The ``A_k`` stack, shape ``(K_V, V, V)``, float32.

    Partition follows the ST-GCN "spatial configuration": for each edge
    (i, j) with hop(i, j) <= 1, the contribution lands in
      - subset 0 if hop(j, center) == hop(i, center)  (root / same ring)
      - subset 1 if hop(j, center) >  hop(i, center)  (centripetal)
      - subset 2 otherwise                            (centrifugal)
    computed on the D^-1-normalized one-hop adjacency.
    """
    dist = hop_distance()
    a_norm = _normalize_digraph(adjacency())
    center_d = dist[:, CENTER]
    stack = np.zeros((K_V, NUM_JOINTS, NUM_JOINTS), dtype=np.float64)
    for i in range(NUM_JOINTS):
        for j in range(NUM_JOINTS):
            if dist[i, j] <= 1:  # one-hop neighbourhood incl. self
                if center_d[j] == center_d[i]:
                    stack[0, i, j] = a_norm[i, j]
                elif center_d[j] > center_d[i]:
                    stack[1, i, j] = a_norm[i, j]
                else:
                    stack[2, i, j] = a_norm[i, j]
    return stack.astype(np.float32)


def bone_pairs() -> list[tuple[int, int]]:
    """(joint, parent) pairs used to derive the bone-stream input."""
    return list(EDGES)
