"""RFC-HyPGCN build-time Python package (Layers 1 and 2).

Everything in here runs only at *compile* time (``make artifacts``): model
definition, hybrid pruning, quantization, training for the accuracy
experiments, and AOT lowering to HLO text.  Nothing in this package is on
the Rust request path.
"""
