"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Exports (under ``artifacts/``):

==========================  ================================================
``blocks/block{01..10}.hlo.txt``  one pruned+BN-folded conv block each,
                                  Pallas-kernel path -- the units the Rust
                                  layer-pipeline coordinator chains
``head.hlo.txt``            global pool + FC
``model_dense.hlo.txt``     original full model (Table V "original")
``model_ck.hlo.txt``        full model incl. self-similarity C_k (Table I)
``model_pruned.hlo.txt``    hybrid-pruned full model (w/o C)
``model_skip.hlo.txt``      pruned + input-skipping (Table V "skip")
``quant_demo.hlo.txt``      Q8.8 quantized matmul kernel (int16 path)
``meta.json``               shapes, pruning plan, cavity masks, FLOP
                            accounting and sparsity stats for Rust
==========================  ================================================

Python runs once at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import pruning
from .agcn import graph, model as model_mod
from .kernels.quant_matmul import quant_matmul as _quant_matmul

ART_DEFAULT = os.path.join(os.path.dirname(__file__), "..", "..",
                           "artifacts")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe format).

    ``as_hlo_text(True)`` = print_large_constants: the default printer
    ELIDES big weight constants as ``constant({...})``, which the HLO text
    parser on the Rust side silently reads back as zeros -- the model
    "runs" and returns all-zero logits.  Always print in full.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text(True)


def export(fn, example_args, path: str) -> dict:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    return {"path": os.path.relpath(path, os.path.dirname(path) + "/.."),
            "bytes": len(text)}


# --------------------------------------------------------------------------
# FLOP accounting (feeds GOP/s rows in Tables IV/V)
# --------------------------------------------------------------------------

def block_flops(spec, t_in: int, kept_in: int, kept_t_out_counts: list[int],
                v: int = graph.NUM_JOINTS, k_v: int = graph.K_V) -> dict:
    """Multiply-accumulate counts (x2 for MAC->FLOP) for one block."""
    t_out = -(-t_in // spec.stride)
    graph_f = 2 * k_v * t_in * v * v * kept_in
    spatial_f = 2 * k_v * t_in * v * kept_in * spec.out_channels
    temporal_f = 2 * t_out * v * spec.out_channels * sum(kept_t_out_counts)
    short_f = (2 * t_out * v * spec.in_channels * spec.out_channels
               if spec.has_projection else 0)
    return {"graph": graph_f, "spatial": spatial_f,
            "temporal": temporal_f, "shortcut": short_f,
            "total": graph_f + spatial_f + temporal_f + short_f}


def flops_table(cfg: model_mod.ModelConfig,
                plan: pruning.PruningPlan | None) -> list[dict]:
    out = []
    t = cfg.seq_len
    for l, spec in enumerate(cfg.block_specs()):
        if plan is None:
            kept_in = spec.in_channels
            taps = [pruning.TEMPORAL_K] * spec.out_channels
        else:
            kept_in = len(plan.kept_spatial_in[l])
            taps = [len(plan.cavity.kept_taps(j))
                    for j in range(len(plan.kept_temporal_out[l]))]
        out.append(block_flops(spec, t, kept_in, taps))
        t = -(-t // spec.stride)
    return out


# --------------------------------------------------------------------------
# Sparsity statistics (RFC mini-bank sizing; Table III on the export model)
# --------------------------------------------------------------------------

def sparsity_stats(params, cfg, plan, batch: int = 32) -> dict:
    x, _ = data_mod.generate(
        data_mod.DataConfig(num_classes=cfg.num_classes,
                            seq_len=cfg.seq_len), batch, seed=7)
    _, acts = model_mod.forward_collect(params, jnp.asarray(x), cfg,
                                        plan=plan)
    out = {}
    for name, a in acts:
        a = np.asarray(a)
        vecs = a.reshape(-1, a.shape[-1])
        s = (vecs == 0).mean(axis=1)
        out[name] = {
            "mean_sparsity": float(s.mean()),
            "buckets_I_II_III_IV": [
                float(((s >= lo) & (s < hi)).mean())
                for lo, hi in ((0.75, 1.01), (0.5, 0.75),
                               (0.25, 0.5), (-0.01, 0.25))],
            "channels": int(a.shape[-1]),
        }
    return out


# --------------------------------------------------------------------------
# Main export
# --------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=ART_DEFAULT)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--schedule", default="drop-1")
    ap.add_argument("--cavity", default="cav-70-1")
    ap.add_argument(
        "--params",
        default=os.path.join(ART_DEFAULT, "experiments",
                             "params_dense.npz"),
        help=".npz from train/experiments; random init if absent")
    args = ap.parse_args()
    art = os.path.abspath(args.out)
    os.makedirs(art, exist_ok=True)

    cfg = model_mod.ModelConfig(num_classes=args.classes,
                                seq_len=args.seq_len,
                                width_mult=args.width)
    if args.params and os.path.exists(args.params):
        params = model_mod.load_params(args.params, cfg)
        params_src = args.params
    else:
        params = model_mod.init_params(cfg, seed=0)
        params_src = "random-init (throughput artifacts are weight-agnostic)"

    cavity = pruning.CAVITY_SCHEMES[args.cavity]
    plan = model_mod.make_plan(params, cfg, args.schedule, cavity)

    # calibration batch for BN folding
    xcal, _ = data_mod.generate(
        data_mod.DataConfig(num_classes=cfg.num_classes,
                            seq_len=cfg.seq_len), 32, seed=3)
    folded = model_mod.calibrate_fold(params, jnp.asarray(xcal), cfg,
                                      plan=plan)
    folded_dense = model_mod.calibrate_fold(params, jnp.asarray(xcal), cfg)

    n = args.batch
    manifest: dict = {
        "batch": n, "seq_len": cfg.seq_len, "width_mult": cfg.width_mult,
        "num_classes": cfg.num_classes, "num_joints": graph.NUM_JOINTS,
        "params_source": params_src,
        "schedule": args.schedule,
        "cavity": {"name": cavity.name,
                   "masks": ["".join("1" if b else "0" for b in row)
                             for row in cavity.masks]},
        "artifacts": {}, "blocks": [], }

    # ---- per-block executables (the Rust pipeline's stages) ----
    specs = cfg.block_specs()
    a_stack = jnp.asarray(graph.spatial_partitions())
    io = model_mod.block_io_shapes(cfg, n)
    from .agcn import block as block_mod
    for l, spec in enumerate(specs):
        bp = jax.tree_util.tree_map(jnp.asarray, folded["blocks"][l])
        blk = functools.partial(
            block_mod.block_forward, bp,
            spec=spec, a_stack=a_stack,
            kept_in=plan.kept_spatial_in[l],
            kept_t_out=plan.kept_temporal_out[l],
            cavity=cavity, use_kernels=True, folded_bn=True)
        if l == 0:
            # block 1 swallows the (folded) input normalization so the
            # Rust pipeline can chain raw (N,T,V,3) clips end to end
            in_s = jnp.asarray(folded["input_bn"]["scale"])
            in_b = jnp.asarray(folded["input_bn"]["bias"])
            fn = (lambda blk_, s_, b_: lambda x: blk_(x * s_ + b_))(
                blk, in_s, in_b)
        else:
            fn = blk
        in_shape, out_shape = io[l]
        info = export(
            lambda x: (fn(x),),
            (jax.ShapeDtypeStruct(in_shape, jnp.float32),),
            os.path.join(art, "blocks", f"block{l + 1:02d}.hlo.txt"))
        manifest["blocks"].append({
            "hlo": f"blocks/block{l + 1:02d}.hlo.txt",
            "in_shape": list(in_shape), "out_shape": list(out_shape),
            "in_channels": spec.in_channels,
            "out_channels": spec.out_channels, "stride": spec.stride,
            "kept_in": [int(i) for i in plan.kept_spatial_in[l]],
            "kept_t_out": [int(i) for i in plan.kept_temporal_out[l]],
            "bytes": info["bytes"],
        })

    # ---- head: global pool + FC ----
    c_last = specs[-1].out_channels
    t_last = manifest["blocks"][-1]["out_shape"][1]
    fcw = jnp.asarray(folded["fc"]["w"])
    fcb = jnp.asarray(folded["fc"]["b"])
    head_in = (n, t_last, graph.NUM_JOINTS, c_last)
    export(lambda h: (h.mean(axis=(1, 2)) @ fcw + fcb,),
           (jax.ShapeDtypeStruct(head_in, jnp.float32),),
           os.path.join(art, "head.hlo.txt"))
    manifest["artifacts"]["head"] = {"hlo": "head.hlo.txt",
                                     "in_shape": list(head_in),
                                     "out_shape": [n, cfg.num_classes]}

    # ---- full-model variants ----
    xin = jax.ShapeDtypeStruct((n, 3, cfg.seq_len, graph.NUM_JOINTS),
                               jnp.float32)
    fd = jax.tree_util.tree_map(jnp.asarray, folded_dense)
    fp = jax.tree_util.tree_map(jnp.asarray, folded)
    variants = {
        "model_dense": (lambda x: (model_mod.forward(
            fd, x, cfg, folded_bn=True),), xin),
        "model_ck": (lambda x: (model_mod.forward(
            fd, x, cfg, with_ck=True, folded_bn=True),), xin),
        "model_pruned": (lambda x: (model_mod.forward(
            fp, x, cfg, plan=plan, folded_bn=True),), xin),
    }
    skip_len = cfg.seq_len // 2
    cfg_skip = model_mod.ModelConfig(
        num_classes=cfg.num_classes, seq_len=skip_len,
        width_mult=cfg.width_mult)
    xin_skip = jax.ShapeDtypeStruct((n, 3, skip_len, graph.NUM_JOINTS),
                                    jnp.float32)
    variants["model_skip"] = (lambda x: (model_mod.forward(
        fp, x, cfg_skip, plan=plan, folded_bn=True),), xin_skip)
    for name, (fn, spec_in) in variants.items():
        info = export(fn, (spec_in,), os.path.join(art, f"{name}.hlo.txt"))
        manifest["artifacts"][name] = {
            "hlo": f"{name}.hlo.txt", "in_shape": list(spec_in.shape),
            "out_shape": [spec_in.shape[0], cfg.num_classes],
            "bytes": info["bytes"]}

    # ---- quantized kernel demo (int16 Q8.8 path) ----
    export(lambda x, w: (_quant_matmul(x, w),),
           (jax.ShapeDtypeStruct((64, 32), jnp.int16),
            jax.ShapeDtypeStruct((32, 32), jnp.int16)),
           os.path.join(art, "quant_demo.hlo.txt"))
    manifest["artifacts"]["quant_demo"] = {
        "hlo": "quant_demo.hlo.txt", "in_shape": [64, 32],
        "rhs_shape": [32, 32], "out_shape": [64, 32], "dtype": "s16"}

    # ---- FLOPs + sparsity for the Rust benches / simulator ----
    manifest["flops"] = {
        "dense_per_sample": flops_table(cfg, None),
        "pruned_per_sample": flops_table(cfg, plan),
    }
    manifest["graph_skip_ratio"] = plan.graph_skip_ratio(
        [s.in_channels for s in specs])
    manifest["compression_ratio"] = model_mod.compression_ratio(cfg, plan)
    manifest["sparsity"] = sparsity_stats(params, cfg, plan)

    with open(os.path.join(art, "meta.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    total = sum(b["bytes"] for b in manifest["blocks"])
    print(f"exported {len(manifest['blocks'])} blocks "
          f"({total} bytes HLO), 4 model variants, head, quant demo")
    print(f"compression_ratio={manifest['compression_ratio']:.2f}x "
          f"graph_skip={manifest['graph_skip_ratio']:.2%}")


if __name__ == "__main__":
    main()
