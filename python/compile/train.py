"""Training / fine-tuning loop for the accuracy experiments (Figs. 8-10).

A deliberately dependency-free trainer (SGD + momentum, cosine decay,
cross-entropy) sufficient to rank pruning schemes on the synthetic NTU-like
task.  Supports:

- dense training (baseline accuracy);
- hybrid-pruned fine-tuning: forward uses the compacted
  :class:`.pruning.PruningPlan` path, gradients flow only through kept
  weights;
- unstructured-pruning fine-tuning (Fig. 8 comparator): a 0/1 mask pytree
  is re-applied to the weights after every update (lottery-style).

Run as a module for the end-to-end driver (EXPERIMENTS.md SSE2E):

    python -m compile.train --steps 300 --width 0.5
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import pruning
from .agcn import model as model_mod


@dataclass
class TrainConfig:
    steps: int = 200
    batch: int = 64
    lr: float = 0.05
    momentum: float = 0.9
    weight_decay: float = 1e-4
    num_train: int = 1024
    num_test: int = 256
    seed: int = 0
    log_every: int = 25


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def accuracy(logits, labels) -> float:
    return float((jnp.argmax(logits, axis=1) == labels).mean())


def _tree_map2(f, a, b):
    return jax.tree_util.tree_map(f, a, b)


def make_update_fn(cfg: model_mod.ModelConfig, tcfg: TrainConfig,
                   plan: Optional[pruning.PruningPlan] = None,
                   with_ck: bool = False):
    """Build a jitted SGD-momentum step closed over the model variant."""

    def loss_fn(params, x, y):
        logits = model_mod.forward(params, x, cfg, plan=plan, with_ck=with_ck)
        return cross_entropy(logits, y), logits

    @jax.jit
    def step(params, vel, x, y, lr):
        (loss, logits), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, x, y)
        vel = _tree_map2(
            lambda v, g: tcfg.momentum * v + g, vel, grads)
        params = _tree_map2(
            lambda p, v: p - lr * (v + tcfg.weight_decay * p), params, vel)
        return params, vel, loss, logits

    return step


def train(
    cfg: model_mod.ModelConfig,
    tcfg: TrainConfig,
    *,
    params: Optional[dict] = None,
    plan: Optional[pruning.PruningPlan] = None,
    mask: Optional[dict] = None,
    with_ck: bool = False,
    dataset=None,
    verbose: bool = True,
) -> tuple[dict, dict]:
    """Train/fine-tune; returns ``(params, history)``.

    ``mask`` (a pytree of 0/1 arrays matching ``params``) implements the
    unstructured baseline -- reapplied after each update.
    """
    dcfg = data_mod.DataConfig(num_classes=cfg.num_classes,
                               seq_len=cfg.seq_len)
    if dataset is None:
        xtr, ytr = data_mod.generate(dcfg, tcfg.num_train, seed=tcfg.seed)
        xte, yte = data_mod.generate(dcfg, tcfg.num_test,
                                     seed=tcfg.seed + 10_000)
    else:
        xtr, ytr, xte, yte = dataset
    if params is None:
        params = model_mod.init_params(cfg, seed=tcfg.seed)
    params = jax.tree_util.tree_map(jnp.asarray, params)
    if mask is not None:
        params = _tree_map2(lambda p, m: p * m, params, mask)
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    step_fn = make_update_fn(cfg, tcfg, plan=plan, with_ck=with_ck)
    eval_fn = jax.jit(lambda p, x: model_mod.forward(
        p, x, cfg, plan=plan, with_ck=with_ck))

    rng = np.random.default_rng(tcfg.seed)
    history = {"loss": [], "step": [], "test_acc": None,
               "train_acc": None, "wall_s": None}
    t0 = time.time()
    for it in range(tcfg.steps):
        idx = rng.integers(0, len(xtr), size=tcfg.batch)
        lr = tcfg.lr * 0.5 * (1 + np.cos(np.pi * it / tcfg.steps))
        params, vel, loss, _ = step_fn(
            params, vel, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]),
            jnp.float32(lr))
        if mask is not None:
            params = _tree_map2(lambda p, m: p * m, params, mask)
        if it % tcfg.log_every == 0 or it == tcfg.steps - 1:
            history["loss"].append(float(loss))
            history["step"].append(it)
            if verbose:
                print(f"step {it:5d}  loss {float(loss):.4f}  lr {lr:.4f}")
    history["wall_s"] = time.time() - t0

    def batched_acc(x, y, bs=128):
        accs, n = 0.0, 0
        for i in range(0, len(x), bs):
            lg = eval_fn(params, jnp.asarray(x[i:i + bs]))
            accs += accuracy(lg, jnp.asarray(y[i:i + bs])) * len(x[i:i + bs])
            n += len(x[i:i + bs])
        return accs / n

    history["train_acc"] = batched_acc(xtr[: len(xte)], ytr[: len(xte)])
    history["test_acc"] = batched_acc(xte, yte)
    if verbose:
        print(f"train_acc {history['train_acc']:.4f}  "
              f"test_acc {history['test_acc']:.4f}  "
              f"wall {history['wall_s']:.1f}s")
    return params, history


def unstructured_mask(params: dict, rate: float) -> dict:
    """Global magnitude mask over conv weights (Fig. 8 baseline). BN, FC
    and graph params stay dense, matching how the paper prunes."""
    def mk(path, p):
        name = "/".join(str(k) for k in path)
        if "w_spatial" in name or "w_temporal" in name:
            return pruning.unstructured_prune(np.asarray(p), rate)
        return np.ones_like(np.asarray(p))
    return jax.tree_util.tree_map_with_path(
        lambda kp, p: jnp.asarray(mk([getattr(k, "key", getattr(k, "idx", ""))
                                      for k in kp], p)), params)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--out", type=str, default=None,
                    help="write loss-curve JSON here")
    args = ap.parse_args()
    cfg = model_mod.ModelConfig(num_classes=args.classes,
                                seq_len=args.seq_len,
                                width_mult=args.width)
    tcfg = TrainConfig(steps=args.steps, batch=args.batch)
    _, hist = train(cfg, tcfg)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
