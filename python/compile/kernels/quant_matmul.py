"""Pallas kernel: Q8.8 fixed-point matmul (paper SSVI-A quantization).

The paper quantizes the pruned model to 16-bit fixed point with 8 integer
and 8 fractional bits ("eight bits are allocated to decimal part and eight
to integer part").  Products of two Q8.8 values are Q16.16 in int32; the
accelerator accumulates in 32 bits and rescales with an arithmetic right
shift of 8 back to Q8.8, saturating to int16.

The kernel tiles M; K and N stay resident.  int16 multiplies map to the
FPGA's DSP48 slices; on TPU the analog is int8/bf16 MXU issue -- the
structural point (integer accumulate + shift + saturate in one fused body)
is preserved.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FRAC_BITS = 8
DEFAULT_BLOCK_M = 64


def _kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc = jax.lax.dot_general(
        x, w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    scaled = jax.lax.shift_right_arithmetic(acc, FRAC_BITS)
    o_ref[...] = jnp.clip(scaled, -32768, 32767).astype(jnp.int16)


def quant_matmul(xq, wq, *, block_m: int = DEFAULT_BLOCK_M,
                 interpret: bool = True):
    """``(M, K) int16 x (K, N) int16 -> (M, N) int16`` in Q8.8.

    ``M`` must be a multiple of ``block_m``.
    """
    m, k = xq.shape
    _, n = wq.shape
    if m % block_m != 0:
        raise ValueError(f"M={m} not a multiple of block_m={block_m}")
    return pl.pallas_call(
        _kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int16),
        interpret=interpret,
    )(xq, wq)
