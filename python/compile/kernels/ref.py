"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` ops only.  ``python/tests`` asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-generated shapes;
this is the core correctness signal for Layer 1.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import pruning


def fused_gconv(f, g, w):
    """Reorganized graph + spatial convolution (paper eq. 5).

    Args:
      f: features ``(T, V, IC)`` -- time (with batch folded in), joints,
         kept input channels.
      g: graph stack ``(K, V, V)`` (``A_k + B_k`` per subset).
      w: spatial 1x1 weights ``(K, IC, OC)``, rows already compacted to the
         kept input channels.

    Returns:
      ``(T, V, OC)`` output ``X`` of eq. (4)/(5) summed over the K subsets.
    """
    # X(t, w, oc) = sum_k sum_i sum_p f(t, p, i) G_k(p, w) W_k(i, oc)
    return jnp.einsum("tpi,kpw,kio->two", f, g, w)


def temporal_conv(f, w, masks, stride: int = 1):
    """9x1 temporal convolution with recurrent cavity masks.

    Args:
      f: features ``(T, V, IC)``; ``T`` is *unpadded* -- the reference pads
         SAME (4 each side).
      w: dense temporal weights ``(9, IC, OC)``.
      masks: cavity masks -- either recurrent ``(8, 9)`` (filter ``oc``
         uses row ``oc % 8``) or explicit per-channel ``(OC, 9)``.
      stride: temporal stride (1 or 2).

    Returns:
      ``(ceil(T / stride), V, OC)``.
    """
    k = w.shape[0]
    masks = jnp.asarray(masks, dtype=w.dtype)
    oc = w.shape[2]
    if masks.shape[0] == oc and oc != pruning.LOOP:
        tap_mask = masks                                 # explicit (OC, 9)
    else:
        reps = (oc + pruning.LOOP - 1) // pruning.LOOP
        tap_mask = jnp.tile(masks, (reps, 1))[:oc]       # recurrent (OC, 9)
    w_masked = w * tap_mask.T[:, None, :]               # (9, IC, OC)
    pad = (k - 1) // 2
    fp = jnp.pad(f, ((pad, pad), (0, 0), (0, 0)))
    t_out = -(-f.shape[0] // stride)
    out = jnp.zeros((t_out, f.shape[1], oc), dtype=f.dtype)
    for tap in range(k):
        sl = fp[tap : tap + (t_out - 1) * stride + 1 : stride]
        out = out + jnp.einsum("tvi,io->tvo", sl, w_masked[tap])
    return out


def quant_matmul(xq, wq, frac_bits: int = 8):
    """Q(16-frac).frac fixed-point matmul with int32 accumulation.

    Args:
      xq: ``(M, K)`` int16 quantized activations.
      wq: ``(K, N)`` int16 quantized weights.
      frac_bits: fractional bits (paper: 8 integer + 8 decimal).

    Returns:
      ``(M, N)`` int16, product rescaled by an arithmetic right shift of
      ``frac_bits`` (rounding toward -inf, matching hardware) and saturated
      to int16.
    """
    acc = jnp.matmul(xq.astype(jnp.int32), wq.astype(jnp.int32))
    scaled = acc >> frac_bits
    return jnp.clip(scaled, -32768, 32767).astype(jnp.int16)
