"""Pallas kernel: reorganized graph + spatial convolution (paper eq. 5).

The paper's dataflow-reorganization insight is that the graph contraction
``f_in . G_k`` and the 1x1 spatial convolution ``. W_k`` commute per input
channel, so pruning input channel *i* of ``W_k`` removes the *graph*
workload for that channel too.  On the FPGA this is realised by never
sending dropped channels to the feature buffer; on a TPU-style core the
same insight turns the sparse problem dense: the kept channels are
compacted, and the kernel below runs two *dense* MXU contractions on the
compacted operands

    tmp(t, w, i) = sum_p G_k(p, w) * f(t, p, i)      (graph, VMEM-resident)
    X(t, w, oc) += sum_i tmp(t, w, i) * W_k(i, oc)   (spatial 1x1)

summed over the K = 3 partition subsets inside one kernel invocation so the
intermediate ``tmp`` never leaves VMEM.

Blocking: the grid tiles the folded batchxtime axis; the joint axis (25) and
channel axes stay resident per block.  VMEM per step =
``Tb*V*IC + K*V*V + K*IC*OC + Tb*V*OC`` floats -- see DESIGN.md SSPerf for
the per-layer budget.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same module runs
under the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 32


def _kernel(f_ref, g_ref, w_ref, o_ref, *, k_v: int):
    f = f_ref[...]                      # (Tb, V, IC)
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for k in range(k_v):                # static unroll over the 3 subsets
        g = g_ref[k]                    # (V, V)
        w = w_ref[k]                    # (IC, OC)
        # graph contraction: tmp(t, w, i) = sum_p f(t, p, i) g(p, w)
        tmp = jax.lax.dot_general(
            f, g,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                               # (Tb, IC, Vw): tmp(t,i,w) = sum_p f(t,p,i) g(p,w)
        # spatial 1x1: out(t, v, oc) = sum_i tmp(t, i, v) w(i, oc)
        acc = acc + jax.lax.dot_general(
            tmp, w,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                               # (Tb, Vw, OC)
    o_ref[...] = acc.astype(o_ref.dtype)


def fused_gconv(f, g, w, *, block_t: int = DEFAULT_BLOCK_T,
                interpret: bool = True):
    """Fused graph + pruned spatial convolution.

    Args:
      f: ``(T, V, IC)`` float32 features; ``T`` must be a multiple of
         ``block_t`` (callers pad; the model folds batch into ``T``).
      g: ``(K, V, V)`` graph stack (``A_k + B_k``).
      w: ``(K, IC, OC)`` spatial weights compacted to kept channels.
      block_t: time-axis tile size per grid step.
      interpret: run the kernel in interpret mode (required on CPU).

    Returns:
      ``(T, V, OC)`` float32.
    """
    t, v, ic = f.shape
    k_v, _, oc = w.shape
    if t % block_t != 0:
        raise ValueError(f"T={t} not a multiple of block_t={block_t}")
    grid = (t // block_t,)
    return pl.pallas_call(
        functools.partial(_kernel, k_v=k_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, v, ic), lambda i: (i, 0, 0)),
            pl.BlockSpec((k_v, v, v), lambda i: (0, 0, 0)),
            pl.BlockSpec((k_v, ic, oc), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, v, oc), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, v, oc), f.dtype),
        interpret=interpret,
    )(f, g, w)
