"""Pallas kernel: 9x1 temporal convolution with recurrent cavity masks.

The paper's fine-grained pruning treats zero temporal-tap weights as "not
sampling" a time step (Fig. 3).  Because the cavity schemes recur over
loops of 8 filters and are fixed at compile time, the kernel specialises on
them *statically*: output channels are processed in 8 pattern groups, and
for group ``gidx`` only its kept taps are touched -- a pruned tap costs
nothing, exactly like the FPGA's Dyn-Mult-PE never enqueueing a dropped
weight.  The per-tap work is a dense (Tb*V, IC) x (IC, OCg) contraction on
the MXU.

The time axis is tiled by the grid; the input block carries an 8-element
halo (kernel size 9, SAME padding) by mapping the *padded* input array with
overlapping reads via ``pl.dslice`` on a whole-array block.

Hardware adaptation note (DESIGN.md SSHardware-Adaptation): the FPGA's
waiting queues + dynamic DSP dispatch exploit *feature* zeros at runtime;
a systolic MXU cannot skip individual zero elements, so runtime feature
sparsity is exploited by the L3 cycle simulator instead, while this kernel
realises the *static* cavity sparsity as compacted dense compute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import pruning

DEFAULT_BLOCK_T = 32


def _kernel(fp_ref, w_ref, o_ref, *, kept_taps, ocg, stride, block_t):
    """One time-tile of the cavity temporal conv.

    fp_ref: padded features, whole array ``(T + 8, V, IC)``.
    w_ref:  dense weights ``(9, IC, OC)`` (masked taps are never read).
    o_ref:  output tile ``(block_t, V, OC)``.
    """
    t0 = pl.program_id(0) * (block_t * stride)
    v = o_ref.shape[1]
    loop = len(kept_taps)
    accs = []
    for gidx, taps in enumerate(kept_taps):     # 8 static pattern groups
        acc = jnp.zeros((block_t, v, ocg), dtype=jnp.float32)
        for tap in taps:                        # static kept taps only
            # rows t0+tap, t0+tap+stride, ... (block_t rows)
            if stride == 1:
                x = fp_ref[pl.dslice(t0 + tap, block_t)]
            else:
                x = fp_ref[pl.dslice(t0 + tap, block_t * stride)]
                x = x[::stride]
            # channels of group g are oc with oc % 8 == g (interleaved)
            wk = w_ref[tap][:, gidx::loop]      # (IC, OCg)
            acc = acc + jax.lax.dot_general(
                x, wk,
                dimension_numbers=(((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        accs.append(acc)
    # interleave groups back: channel j*8+g comes from group g column j
    out = jnp.stack(accs, axis=-1)              # (Tb, V, OCg, 8)
    o_ref[...] = out.reshape(block_t, v, ocg * loop).astype(o_ref.dtype)


def temporal_conv(f, w, scheme: pruning.CavityScheme, *, stride: int = 1,
                  block_t: int = DEFAULT_BLOCK_T, interpret: bool = True):
    """Cavity-pruned 9x1 temporal convolution, SAME padding.

    Args:
      f: ``(T, V, IC)`` float32 (batch folded into T is NOT allowed here --
         the 9-tap window must not straddle samples; the model vmaps/maps
         over batch instead).
      w: ``(9, IC, OC)`` dense weights; taps pruned by ``scheme`` are
         ignored (callers may keep them zero or arbitrary).
      scheme: cavity scheme; output channel ``oc`` uses mask ``oc % 8``.
      stride: 1 or 2.
      block_t: output-tile size along time.

    Returns:
      ``(ceil(T / stride), V, OC)`` float32.

    Requires ``OC % 8 == 0`` and ``ceil(T / stride) % block_t == 0``.
    """
    t, v, ic = f.shape
    k, _, oc = w.shape
    if k != pruning.TEMPORAL_K:
        raise ValueError(f"kernel size must be 9, got {k}")
    if oc % pruning.LOOP != 0:
        raise ValueError(f"OC={oc} must be a multiple of {pruning.LOOP}")
    t_out = -(-t // stride)
    if t_out % block_t != 0:
        raise ValueError(
            f"ceil(T/stride)={t_out} not a multiple of block_t={block_t}")
    pad = (k - 1) // 2
    fp = jnp.pad(f, ((pad, pad + (stride - 1)), (0, 0), (0, 0)))
    kept_taps = tuple(tuple(scheme.kept_taps(i)) for i in range(pruning.LOOP))
    ocg = oc // pruning.LOOP
    grid = (t_out // block_t,)
    return pl.pallas_call(
        functools.partial(_kernel, kept_taps=kept_taps, ocg=ocg,
                          stride=stride, block_t=block_t),
        grid=grid,
        in_specs=[
            # whole padded array visible each step; halo handled by dslice
            pl.BlockSpec(fp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec((k, ic, oc), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, v, oc), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t_out, v, oc), f.dtype),
        interpret=interpret,
    )(fp, w)
