"""Layer-1 Pallas kernels for RFC-HyPGCN.

- :mod:`fused_gconv`    -- reorganized graph + spatial conv (paper eq. 5),
  the dataflow that makes channel pruning skip graph work.
- :mod:`temporal_conv`  -- 9x1 temporal conv with recurrent cavity masks
  (paper Fig. 3), static tap skipping.
- :mod:`quant_matmul`   -- Q8.8 fixed-point matmul (paper's quantization).
- :mod:`ref`            -- pure-jnp oracles for all of the above.

All kernels run with ``interpret=True`` so they lower to plain HLO the CPU
PJRT client (and therefore the Rust runtime) can execute.
"""

from .fused_gconv import fused_gconv  # noqa: F401
from .temporal_conv import temporal_conv  # noqa: F401
from .quant_matmul import quant_matmul  # noqa: F401
from . import ref  # noqa: F401

__all__ = ["fused_gconv", "temporal_conv", "quant_matmul", "ref"]
