"""Software-side experiment drivers (paper SSVI-A): Figs. 8-10, Table I
accuracy, Table III sparsity.

Each driver trains/fine-tunes the scaled 2s-AGCN on the synthetic NTU-like
task and writes a JSON result under ``artifacts/experiments/``.  The Rust
benches (`cargo bench`) consume these JSONs to print the paper's tables;
``table3``'s sparsity trace additionally drives the RFC mini-bank sizing
in the cycle simulator.

Run everything:  ``python -m compile.experiments all``
Run one figure:  ``python -m compile.experiments fig8``

Protocol (documented in EXPERIMENTS.md): a dense baseline is trained once,
then every pruning variant fine-tunes from the dense weights -- the
prune-then-finetune regime the paper uses.  Absolute accuracies are on the
synthetic task; the *claims* under test are relational (hybrid >=
unstructured at equal compression; balanced cavity > unbalanced; accuracy
falls as drop rates leave the sparsity-guided point).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import pruning, quantize
from . import train as train_mod
from .agcn import model as model_mod

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
OUT = os.path.join(ART, "experiments")

# Scaled-testbed experiment configuration (1-core CPU budget).  The noise
# and class count are tuned so the dense model lands well below 100%
# accuracy -- pruning schemes must have headroom to separate (Figs. 8-10).
CFG = model_mod.ModelConfig(num_classes=16, seq_len=32, width_mult=0.25)
DCFG = data_mod.DataConfig(num_classes=16, seq_len=32, noise=0.22)
BASE_STEPS = 150
TUNE_STEPS = 50


def _write(name: str, payload: dict) -> str:
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, f"{name}.json")
    payload["config"] = {
        "num_classes": CFG.num_classes, "seq_len": CFG.seq_len,
        "width_mult": CFG.width_mult, "base_steps": BASE_STEPS,
        "tune_steps": TUNE_STEPS, "noise": DCFG.noise,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    return path


def _dataset(seed: int = 0):
    xtr, ytr = data_mod.generate(DCFG, 512, seed=seed)
    xte, yte = data_mod.generate(DCFG, 256, seed=seed + 10_000)
    return xtr, ytr, xte, yte


_DENSE_CACHE: dict = {}


def dense_baseline(dataset, with_ck: bool = False):
    """Train the dense model once per variant; cached across drivers."""
    key = ("ck" if with_ck else "plain")
    if key not in _DENSE_CACHE:
        tcfg = train_mod.TrainConfig(steps=BASE_STEPS, batch=32,
                                     num_train=len(dataset[0]))
        params, hist = train_mod.train(
            CFG, tcfg, with_ck=with_ck, dataset=dataset, verbose=False)
        print(f"[dense {key}] test_acc={hist['test_acc']:.4f} "
              f"({hist['wall_s']:.0f}s)")
        _DENSE_CACHE[key] = (params, hist)
        if not with_ck:
            # persist for aot.py (--params): the serving artifacts then
            # carry trained weights instead of random init
            os.makedirs(OUT, exist_ok=True)
            model_mod.save_params(
                os.path.join(OUT, "params_dense.npz"), params)
    return _DENSE_CACHE[key]


def _finetune(dataset, params, plan=None, mask=None):
    tcfg = train_mod.TrainConfig(steps=TUNE_STEPS, batch=32, lr=0.01,
                                 num_train=len(dataset[0]))
    return train_mod.train(CFG, tcfg, params=jax.tree_util.tree_map(
        np.asarray, params), plan=plan, mask=mask, dataset=dataset,
        verbose=False)


def _param_reduction(plan) -> float:
    """Fraction of conv parameters removed by a hybrid plan."""
    return 1.0 - 1.0 / model_mod.compression_ratio(CFG, plan)


# --------------------------------------------------------------------------
# Fig. 8 -- hybrid vs unstructured pruning at matched compression
# --------------------------------------------------------------------------

def fig8() -> dict:
    ds = _dataset()
    dense_params, dense_hist = dense_baseline(ds)
    points = []
    settings = [
        ("drop-1", pruning.CAV_50),
        ("drop-1", pruning.CAV_70_1),
        ("drop-2", pruning.CAV_70_1),
        ("drop-3", pruning.CAV_75_1),
    ]
    for schedule, cavity in settings:
        plan = model_mod.make_plan(dense_params, CFG, schedule, cavity)
        p, hist = _finetune(ds, dense_params, plan=plan)
        red = _param_reduction(plan)
        # unstructured baseline at the SAME parameter-reduction rate
        mask = train_mod.unstructured_mask(dense_params, red)
        _, uhist = _finetune(ds, dense_params, mask=mask)
        # + quantization on the hybrid model (paper's "+quant" point)
        qparams = quantize.fake_quant_tree(p)
        qacc = _eval_acc(qparams, ds, plan=plan)
        points.append({
            "schedule": schedule, "cavity": cavity.name,
            "param_reduction": red,
            "compression_ratio": model_mod.compression_ratio(CFG, plan),
            "hybrid_acc": hist["test_acc"],
            "unstructured_acc": uhist["test_acc"],
            "hybrid_quant_acc": qacc,
        })
        print(f"[fig8] {schedule}+{cavity.name}: red={red:.2f} "
              f"hybrid={hist['test_acc']:.4f} "
              f"unstructured={uhist['test_acc']:.4f} quant={qacc:.4f}")
    return _write("fig8", {"dense_acc": dense_hist["test_acc"],
                           "points": points})


def _eval_acc(params, ds, plan=None, with_ck=False, skip=False) -> float:
    xte, yte = ds[2], ds[3]
    if skip:
        xte = data_mod.input_skip(xte)
        cfg = model_mod.ModelConfig(num_classes=CFG.num_classes,
                                    seq_len=xte.shape[2],
                                    width_mult=CFG.width_mult)
    else:
        cfg = CFG
    fn = jax.jit(lambda p, x: model_mod.forward(p, x, cfg, plan=plan,
                                                with_ck=with_ck))
    accs, n = 0.0, 0
    for i in range(0, len(xte), 128):
        lg = fn(params, jnp.asarray(xte[i:i + 128]))
        accs += train_mod.accuracy(lg, jnp.asarray(yte[i:i + 128])) * len(
            xte[i:i + 128])
        n += len(xte[i:i + 128])
    return accs / n


# --------------------------------------------------------------------------
# Fig. 9 -- channel-dropping exploration + per-layer feature sparsity
# --------------------------------------------------------------------------

def fig9() -> dict:
    ds = _dataset()
    dense_params, dense_hist = dense_baseline(ds)
    # per-layer feature sparsity of the dense model (guides Drop-1)
    _, acts = model_mod.forward_collect(dense_params, jnp.asarray(ds[0][:64]),
                                        CFG)
    layer_sparsity = {name: float((np.asarray(a) == 0).mean())
                      for name, a in acts}
    rows = []
    for schedule in ("drop-1", "drop-2", "drop-3"):
        # cavity excluded (DENSE) to isolate the reorganization method,
        # exactly as the paper does for Fig. 9.
        plan = model_mod.make_plan(dense_params, CFG, schedule,
                                   pruning.DENSE_SCHEME)
        _, hist = _finetune(ds, dense_params, plan=plan)
        specs = CFG.block_specs()
        gskip = plan.graph_skip_ratio([s.in_channels for s in specs])
        rows.append({
            "schedule": schedule, "test_acc": hist["test_acc"],
            "graph_skip_ratio": gskip,
            "param_reduction": _param_reduction(plan),
            "kept_per_block": [int(len(k)) for k in plan.kept_spatial_in],
        })
        print(f"[fig9] {schedule}: acc={hist['test_acc']:.4f} "
              f"graph_skip={gskip:.3f}")
    return _write("fig9", {"dense_acc": dense_hist["test_acc"],
                           "layer_sparsity": layer_sparsity, "rows": rows})


# --------------------------------------------------------------------------
# Fig. 10 -- fine-grained cavity scheme exploration (on Drop-1)
# --------------------------------------------------------------------------

def fig10() -> dict:
    ds = _dataset()
    dense_params, dense_hist = dense_baseline(ds)
    rows = []
    for name in ("cav-50", "cav-67", "cav-70-1", "cav-70-2",
                 "cav-75-1", "cav-75-2"):
        scheme = pruning.CAVITY_SCHEMES[name]
        plan = model_mod.make_plan(dense_params, CFG, "drop-1", scheme)
        _, hist = _finetune(ds, dense_params, plan=plan)
        rows.append({
            "scheme": name, "prune_ratio": scheme.prune_ratio,
            "balance_spread": scheme.balance_spread(),
            "tap_coverage": [int(c) for c in scheme.tap_coverage()],
            "test_acc": hist["test_acc"],
        })
        print(f"[fig10] {name}: acc={hist['test_acc']:.4f} "
              f"spread={scheme.balance_spread()}")
    return _write("fig10", {"dense_acc": dense_hist["test_acc"],
                            "rows": rows})


# --------------------------------------------------------------------------
# Table I -- accuracy with / without the self-similarity graph C_k
# --------------------------------------------------------------------------

def table1() -> dict:
    ds = _dataset()
    _, hist_plain = dense_baseline(ds, with_ck=False)
    _, hist_ck = dense_baseline(ds, with_ck=True)
    return _write("table1_acc", {
        "acc_with_ck": hist_ck["test_acc"],
        "acc_without_ck": hist_plain["test_acc"],
        "note": "throughput columns are measured by the rust runtime "
                "(cargo bench --bench table1)",
    })


# --------------------------------------------------------------------------
# Table III -- feature sparsity distribution (drives RFC mini-bank sizing)
# --------------------------------------------------------------------------

def table3() -> dict:
    ds = _dataset()
    dense_params, _ = dense_baseline(ds)
    plan = model_mod.make_plan(dense_params, CFG, "drop-1", pruning.CAV_70_1)
    tuned, _ = _finetune(ds, dense_params, plan=plan)
    _, acts = model_mod.forward_collect(
        tuned, jnp.asarray(ds[0][:64]), CFG, plan=plan)
    layers_out = {}
    for name, a in acts:
        a = np.asarray(a)                       # (N, T, V, C)
        vecs = a.reshape(-1, a.shape[-1])       # feature vectors across C
        s = (vecs == 0).mean(axis=1)            # per-vector sparsity
        buckets = [float(((s >= lo) & (s < hi)).mean())
                   for lo, hi in ((0.75, 1.01), (0.5, 0.75),
                                  (0.25, 0.5), (-0.01, 0.25))]
        layers_out[name] = {
            "mean_sparsity": float(s.mean()),
            "buckets_I_II_III_IV": buckets,
            "channels": int(a.shape[-1]),
        }
    return _write("table3_sparsity", {"layers": layers_out})


DRIVERS = {"fig8": fig8, "fig9": fig9, "fig10": fig10, "table1": table1,
           "table3": table3}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("which", choices=[*DRIVERS, "all"])
    args = ap.parse_args()
    t0 = time.time()
    names = list(DRIVERS) if args.which == "all" else [args.which]
    for n in names:
        DRIVERS[n]()
    print(f"total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
