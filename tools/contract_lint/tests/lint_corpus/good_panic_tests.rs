//! Good corpus: unwraps only inside (nested) test regions.

pub fn double(n: u32) -> u32 {
    n.wrapping_mul(2)
}

#[cfg(test)]
mod tests {
    use super::double;

    mod nested {
        #[test]
        fn inner() {
            Some(super::super::double(2)).unwrap();
        }
    }

    #[test]
    fn outer() {
        Some(double(1)).unwrap();
    }
}
