//! Good corpus: FMA tokens appear only in comments and strings.

// vfmaq_f32 would contract the rounding step; we deliberately keep
// separate mul and add so runtime == sim bit-for-bit.
pub fn label() -> &'static str {
    "uses _mm256_fmadd_ps? no: separate mul and add, see mul_add ban"
}

pub fn formula(a: f32, b: f32, c: f32) -> f32 {
    let simulated = a * b + c;
    simulated
}
