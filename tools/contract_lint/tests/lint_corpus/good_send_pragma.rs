//! Good corpus: an audited send-discard exception, plus a non-send discard.

use std::sync::mpsc::Sender;

pub fn best_effort(tx: &Sender<u32>, v: u32) {
    // receiver death during shutdown is an acceptable outcome here
    // lint: allow(send-discard): best-effort shutdown notification
    let _ = tx.send(v);
}

pub fn not_a_send(f: std::fs::File) {
    let _ = f.sync_all();
}
