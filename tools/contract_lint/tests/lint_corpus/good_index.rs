//! Good corpus: plain indexing, macros and attributes are not flagged.

#[derive(Clone)]
pub struct Buf(pub Vec<u8>);

pub fn first(v: &[u8], i: usize) -> u8 {
    v[i]
}

pub fn build(n: usize) -> Vec<u8> {
    vec![0u8; n + 1]
}

pub fn shifted(v: &[u8], j: usize) -> u8 {
    let k = j + 1;
    v[k]
}
