//! Bad corpus: panic-family tokens on the serving path.

pub fn handle(v: Option<u32>, n: u64) -> u32 {
    let x = v.unwrap();
    let y = v.expect("present");
    debug_assert!(n > 0);
    if n == 0 {
        panic!("zero");
    }
    x + y
}

#[cfg(test)]
mod tests {
    #[test]
    fn inside_tests_unwrap_is_fine() {
        super::handle(Some(1), 1).checked_add(1).unwrap();
    }
}
