//! Bad corpus: malformed pragmas are findings themselves.

pub fn a(v: Option<u32>) -> u32 {
    // lint: allow(panic)
    v.unwrap()
}

pub fn b(v: &[u8], i: usize) -> u8 {
    // lint: allow(bounds-are-fine): trust me
    v[i + 1]
}
