//! Bad corpus: computed-offset indexing on the serving path.

pub fn row(v: &[f32], i: usize, width: usize) -> &[f32] {
    &v[i * width..(i + 1) * width]
}
