//! Bad corpus: `unsafe` without a `// SAFETY:` justification.

pub unsafe fn raw_read(p: *const u8) -> u8 {
    *p
}

pub fn call(p: *const u8) -> u8 {
    // not a safety comment, just a comment
    unsafe { raw_read(p) }
}
