//! Bad corpus: FMA contractions inside the kernel reach set.

pub fn scalar(a: f32, b: f32, c: f32) -> f32 {
    a.mul_add(b, c)
}

pub fn avx(x: __m256, y: __m256, z: __m256) -> __m256 {
    _mm256_fmadd_ps(x, y, z)
}

pub fn neon(a: float32x4_t, b: float32x4_t, c: float32x4_t) -> float32x4_t {
    vfmaq_f32(a, b, c)
}
