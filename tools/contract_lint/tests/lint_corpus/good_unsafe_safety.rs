//! Good corpus: every `unsafe` is justified; decoys must not count.

// SAFETY: the caller upholds p's validity; attribute lines between
// the comment and the item are allowed by the walk.
#[inline]
pub unsafe fn raw_read(p: *const u8) -> u8 {
    *p
}

/// Reads a byte.
///
/// # Safety
/// `p` must be valid for reads.
#[inline]
pub unsafe fn doc_read(p: *const u8) -> u8 {
    *p
}

pub fn decoy() -> &'static str {
    "unsafe { inside a string literal does not count }"
}

pub fn call(p: *const u8) -> u8 {
    // SAFETY: p comes from a live &u8 in the caller.
    unsafe { raw_read(p) }
}
