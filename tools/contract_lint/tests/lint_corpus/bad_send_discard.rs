//! Bad corpus: discarded send result on the serving path.

use std::sync::mpsc::Sender;

pub fn reply(tx: &Sender<u32>, v: u32) {
    let _ = tx.send(v);
}
